//! The paper's §III experiment end to end: BFS over the synthetic trees
//! (B=4, D=7 and D=9), DAE vs non-DAE, on the HardCilk simulator — plus
//! the Fig. 6 resource table. Both program variants are compiled exactly
//! once (`BfsExperiment` holds one `CompileSession` each); the runtime
//! comparison and the resource estimator share those cached modules.
//!
//! ```sh
//! cargo run --release --example bfs_dae
//! ```

use anyhow::Result;

use bombyx::coordinator::BfsExperiment;
use bombyx::hls::{estimate, CostModel};
use bombyx::sim::SimConfig;
use bombyx::util::table::{commas, Table};
use bombyx::workloads::graphgen;

fn main() -> Result<()> {
    let cfg = SimConfig::paper();
    let exp = BfsExperiment::new()?;

    println!("== Paper §III: DAE vs non-DAE runtime (HardCilk sim, 1 PE/type) ==");
    let mut table = Table::new(["graph", "nodes", "non-DAE cycles", "DAE cycles", "reduction"]);
    let mut reductions = Vec::new();
    for (label, depth) in [("B=4 D=7", 7u32), ("B=4 D=9", 9u32)] {
        let graph = graphgen::tree(4, depth);
        let cmp = exp.run(&graph, &cfg)?;
        reductions.push(cmp.reduction());
        table.row([
            label.to_string(),
            commas(graph.nodes() as u64),
            commas(cmp.plain_cycles),
            commas(cmp.dae_cycles),
            format!("{:.1}%", cmp.reduction() * 100.0),
        ]);
    }
    print!("{}", table.render());
    let overall = reductions.iter().sum::<f64>() / reductions.len() as f64;
    println!("overall reduction: {:.1}%   (paper reports 26.5%)\n", overall * 100.0);

    println!("== Paper Fig. 6: synthesis results for the DAE PEs (estimated) ==");
    let model = CostModel::default();
    let est = |m: &bombyx::ir::Module, name: &str| {
        let f = &m.funcs[m.func_by_name(name).unwrap()];
        estimate(&model, m, f)
    };
    let rows = [
        ("Non-DAE", est(exp.plain.explicit(), "visit"), (2657, 2305, 2)),
        ("Spawner", est(exp.dae.explicit(), "visit"), (133, 387, 0)),
        ("Executor", est(exp.dae.explicit(), "visit__k1"), (1999, 1913, 2)),
        ("Access", est(exp.dae.explicit(), "adj_off_access"), (1764, 1164, 2)),
    ];
    let mut fig6 = Table::new([
        "PE",
        "LUT (est)",
        "LUT (paper)",
        "FF (est)",
        "FF (paper)",
        "BRAM (est)",
        "BRAM (paper)",
    ]);
    for (name, e, (pl, pf, pb)) in rows {
        fig6.row([
            name.to_string(),
            e.lut.to_string(),
            pl.to_string(),
            e.ff.to_string(),
            pf.to_string(),
            e.bram.to_string(),
            pb.to_string(),
        ]);
    }
    print!("{}", fig6.render());
    println!("\nbfs_dae OK");
    Ok(())
}
