//! Inspect the RTL backend output for the DAE-annotated BFS: the per-PE
//! report (implementation style, initiation interval, resource
//! estimates), the structural lint verdict, and the generated Verilog
//! written to `target/rtl_bfs_dae/`.
//!
//! The headline: the DAE access PE (`adj_off_access`) is implemented as a
//! pipelined datapath with II=1 — a new memory-access task enters every
//! cycle — while the executor continuation stays a sequential FSM, which
//! is exactly the §II-C asymmetry that motivates the DAE transformation.
//!
//! ```sh
//! cargo run --release --example bfs_rtl
//! ```

use anyhow::Result;

use bombyx::backend::rtl::PeStyle;
use bombyx::lower::{CompileOptions, CompileSession};

fn main() -> Result<()> {
    let source = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/examples/cilk/bfs_dae.cilk"
    ))?;
    let mut session = CompileSession::new("bfs_dae.cilk", &source, &CompileOptions::standard())?;

    // Generated through the rtl_emit pass (timed, lint-verified) and
    // memoized on the session.
    let system = session.rtl_system("bfs_dae_system")?;

    println!("== Per-PE report ==");
    print!("{}", system.report());

    let errors = system.lint();
    println!(
        "\n== Structural lint == {}",
        if errors.is_empty() { "clean".to_string() } else { format!("{errors:#?}") }
    );

    for pe in &system.pes {
        if let PeStyle::Pipelined { ii } = pe.style {
            println!(
                "\n`{}` pipelines at II={ii}: address datapath is combinational from the\n\
                 closure; the continuation rides an in-flight FIFO to the memory response.",
                pe.task
            );
        }
    }

    let dir = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/target/rtl_bfs_dae"));
    system.write_to(dir)?;
    println!(
        "\nwrote {} files ({} LoC) to {}",
        system.files().len(),
        system.total_loc(),
        dir.display()
    );

    println!("\n== rtl_emit pass timing ==");
    for t in session.timings() {
        if t.pass == "rtl_emit" {
            println!("{}: {:?}", t.pass, t.duration);
        }
    }
    Ok(())
}
