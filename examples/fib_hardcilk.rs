//! Inspect the HardCilk backend output for fib: the generated HLS C++
//! PEs, the shared header with padded closure structs, and the JSON
//! system descriptor (paper §II-B). Writes everything to
//! `target/hardcilk_fib/`.
//!
//! ```sh
//! cargo run --release --example fib_hardcilk
//! ```

use anyhow::Result;

use bombyx::ir::explicit::closure_layout;
use bombyx::lower::{CompileOptions, CompileSession};
use bombyx::util::table::Table;

fn main() -> Result<()> {
    let source = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/examples/cilk/fib.cilk"
    ))?;
    let mut session = CompileSession::new("fib.cilk", &source, &CompileOptions::standard())?;

    println!("== Closure layouts (padded to power-of-two widths) ==");
    let mut table = Table::new(["task", "payload bits", "padded bits", "padding"]);
    for (_, f) in session.explicit().funcs.iter() {
        if f.task.is_some() {
            let l = closure_layout(f);
            table.row([
                f.name.clone(),
                l.payload_bits.to_string(),
                l.padded_bits.to_string(),
                l.padding_bits().to_string(),
            ]);
        }
    }
    print!("{}", table.render());

    // Generated once and memoized on the session.
    let system = session.hardcilk_system("fib_system")?;

    println!("\n== Generated PE kernel: pe_fib.cpp ==");
    println!("{}", system.pes[0].2);

    println!("== System descriptor (JSON) ==");
    println!("{}", system.descriptor.pretty());

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("target/hardcilk_fib");
    system.write_to(&out)?;
    println!("wrote the full system to {out:?}");
    println!("\nfib_hardcilk OK");
    Ok(())
}
