//! End-to-end three-layer run: the graph-relaxation workload whose numeric
//! PE datapath is the AOT-compiled Pallas/XLA executable, driven from the
//! Rust coordinator (Python never runs here — build artifacts first with
//! `make artifacts`).
//!
//! The workload is compiled once (`RelaxExperiment`); the batched XLA path
//! and the scalar reference datapath both run against the same cached
//! explicit module and are validated against each other.
//!
//! ```sh
//! make artifacts && cargo run --release --example graph_relax_xla
//! ```

use anyhow::Result;

use bombyx::coordinator::RelaxExperiment;
use bombyx::runtime::XlaRuntime;
use bombyx::sim::SimConfig;
use bombyx::util::table::commas;
use bombyx::workloads::graphgen;

fn main() -> Result<()> {
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let runtime = XlaRuntime::load_dir(artifacts)?;
    println!("loaded AOT executables: {:?}", runtime.names());

    let graph = graphgen::tree(4, 7); // 5,461 nodes — the paper's small set
    let seed = 42;
    let cfg = SimConfig::default();
    let exp = RelaxExperiment::new()?;

    let xla = exp.run_sim(runtime, &graph, seed, &cfg)?;
    println!(
        "XLA datapath:    {} nodes expanded, {} cycles, {} XLA batches",
        commas(xla.nodes_expanded),
        commas(xla.cycles),
        xla.xla_batches
    );

    let scalar = exp.run_scalar(&graph, seed, &cfg)?;
    println!(
        "scalar datapath: {} nodes expanded, {} cycles",
        commas(scalar.nodes_expanded),
        commas(scalar.cycles)
    );

    assert_eq!(
        xla.nodes_expanded, scalar.nodes_expanded,
        "traversal shape must match between XLA and scalar datapaths"
    );
    let rel = (xla.feat_checksum - scalar.feat_checksum).abs()
        / scalar.feat_checksum.abs().max(1e-9);
    println!(
        "feature checksum: xla={:.4} scalar={:.4} (rel diff {:.2e})",
        xla.feat_checksum, scalar.feat_checksum, rel
    );
    assert!(rel < 1e-3, "feature images diverged");
    println!("\ngraph_relax_xla OK");
    Ok(())
}
