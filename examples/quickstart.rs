//! Quickstart: compile the paper's fib (Fig. 1) through the whole Bombyx
//! pipeline and run it on every execution engine.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;

use bombyx::backend::hardcilk;
use bombyx::interp::{explicit_exec::ExplicitExec, oracle::run_oracle, Memory, NoXla};
use bombyx::ir::expr::Value;
use bombyx::ir::print::print_cilk1;
use bombyx::lower::{compile, CompileOptions};
use bombyx::sim::{simulate, NoSimXla, SimConfig};
use bombyx::ws::{self, SharedMemory, WsConfig};

fn main() -> Result<()> {
    let source = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/examples/cilk/fib.cilk"
    ))?;
    let n = 20i64;

    // 1. Compile: OpenCilk-style source -> implicit IR -> explicit IR.
    let result = compile("fib.cilk", &source, &CompileOptions::standard())?;
    println!("== Cilk-1 view of the explicit tasks (paper Fig. 2) ==");
    for (_, f) in result.explicit.funcs.iter() {
        if f.task.is_some() && f.body.is_some() {
            print!("{}", print_cilk1(&result.explicit, f));
        }
    }

    // 2. Sequential oracle (the C elision).
    let (v_oracle, _) =
        run_oracle(&result.implicit, Memory::new(&result.implicit), "fib", &[Value::I64(n)])?;

    // 3. Explicit-IR abstract machine.
    let mut exec = ExplicitExec::new(&result.explicit, Memory::new(&result.explicit), NoXla);
    let v_explicit = exec.run("fib", &[Value::I64(n)])?;

    // 4. Multithreaded work-stealing runtime (the Cilk-1 emulation layer).
    let (v_ws, _, ws_stats) = ws::run(
        &result.explicit,
        SharedMemory::new(&result.explicit),
        "fib",
        &[Value::I64(n)],
        &WsConfig::default(),
        Box::new(ws::NoXlaSink),
    )?;

    // 5. HardCilk cycle simulator.
    let cfg = SimConfig::default();
    let (v_sim, _, sim_stats) = simulate(
        &result.explicit,
        Memory::new(&result.explicit),
        "fib",
        &[Value::I64(n)],
        &cfg,
        &mut NoSimXla,
    )?;

    println!("\nfib({n}):");
    println!("  oracle   = {v_oracle}");
    println!("  explicit = {v_explicit}");
    println!("  ws       = {v_ws}   ({} tasks, {} steals)", ws_stats.tasks_run, ws_stats.steals);
    println!(
        "  sim      = {v_sim}   ({} cycles = {:.1} us @ {} MHz)",
        sim_stats.cycles,
        cfg.cycles_to_us(sim_stats.cycles),
        cfg.freq_mhz
    );
    assert_eq!(v_oracle, v_explicit);
    assert_eq!(v_oracle, v_ws);
    assert_eq!(v_oracle, v_sim);

    // 6. HardCilk codegen.
    let system = hardcilk::generate(&result.explicit, "fib_system")?;
    println!(
        "\nHardCilk backend: {} PE kernels, {} lines of HLS C++, descriptor with {} tasks",
        system.pes.len(),
        system.total_loc(),
        system.descriptor.get("tasks").and_then(|t| t.as_array()).map(|a| a.len()).unwrap_or(0)
    );
    println!("\nquickstart OK");
    Ok(())
}
