//! Quickstart: compile the paper's fib (Fig. 1) once into a
//! `CompileSession` and run the cached explicit module on every execution
//! engine.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;

use bombyx::ir::expr::Value;
use bombyx::ir::print::print_cilk1;
use bombyx::lower::{CompileOptions, CompileSession};
use bombyx::sim::{NoSimXla, SimConfig};
use bombyx::util::bench::timing_table;
use bombyx::ws::{self, WsConfig};

fn main() -> Result<()> {
    let source = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/examples/cilk/fib.cilk"
    ))?;
    let n = 20i64;

    // 1. Compile once: OpenCilk-style source -> implicit IR -> explicit IR.
    //    Every engine below consumes the session's cached module.
    let mut session = CompileSession::new("fib.cilk", &source, &CompileOptions::standard())?;
    println!("== Cilk-1 view of the explicit tasks (paper Fig. 2) ==");
    for (_, f) in session.explicit().funcs.iter() {
        if f.task.is_some() && f.body.is_some() {
            print!("{}", print_cilk1(session.explicit(), f));
        }
    }
    println!("\n== Pass timings (one-time lowering) ==");
    print!("{}", timing_table(session.timings()));

    // 2. Sequential oracle (the C elision).
    let (v_oracle, _) =
        session.run_oracle(session.implicit_memory(), "fib", &[Value::I64(n)])?;

    // 3. Explicit-IR abstract machine.
    let (v_explicit, _) = session.run_explicit(session.memory(), "fib", &[Value::I64(n)])?;

    // 4. Multithreaded work-stealing runtime (the Cilk-1 emulation layer).
    let (v_ws, _, ws_stats) = session.run_ws(
        session.shared_memory(),
        "fib",
        &[Value::I64(n)],
        &WsConfig::default(),
        Box::new(ws::NoXlaSink),
    )?;

    // 5. HardCilk cycle simulator.
    let cfg = SimConfig::default();
    let (v_sim, _, sim_stats) =
        session.simulate(session.memory(), "fib", &[Value::I64(n)], &cfg, &mut NoSimXla)?;

    println!("\nfib({n}):");
    println!("  oracle   = {v_oracle}");
    println!("  explicit = {v_explicit}");
    println!("  ws       = {v_ws}   ({} tasks, {} steals)", ws_stats.tasks_run, ws_stats.steals);
    println!(
        "  sim      = {v_sim}   ({} cycles = {:.1} us @ {} MHz)",
        sim_stats.cycles,
        cfg.cycles_to_us(sim_stats.cycles),
        cfg.freq_mhz
    );
    assert_eq!(v_oracle, v_explicit);
    assert_eq!(v_oracle, v_ws);
    assert_eq!(v_oracle, v_sim);

    // 6. HardCilk codegen — memoized on the session.
    let system = session.hardcilk_system("fib_system")?;
    println!(
        "\nHardCilk backend: {} PE kernels, {} lines of HLS C++, descriptor with {} tasks",
        system.pes.len(),
        system.total_loc(),
        system.descriptor.get("tasks").and_then(|t| t.as_array()).map(|a| a.len()).unwrap_or(0)
    );
    println!("\nquickstart OK");
    Ok(())
}
