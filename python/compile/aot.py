"""AOT lowering: jax → HLO *text* → artifacts/ for the Rust runtime.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run from `python/`:  python -m compile.aot --out-dir ../artifacts
Artifacts are pure build outputs — Python never runs on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.ref import F
from .model import relax_step

# Batch-size variants compiled ahead of time; the Rust batcher picks the
# smallest variant that fits and pads.
BATCHES = (64, 256)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(batch: int) -> str:
    x = jax.ShapeDtypeStruct((batch, F), jnp.float32)
    w = jax.ShapeDtypeStruct((F, F), jnp.float32)
    b = jax.ShapeDtypeStruct((F,), jnp.float32)
    return to_hlo_text(jax.jit(relax_step).lower(x, w, b))


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"kernel": "relax", "feature_width": F, "variants": []}
    for batch in BATCHES:
        text = lower_variant(batch)
        name = f"relax_b{batch}_f{F}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["variants"].append({"batch": batch, "file": name})
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
