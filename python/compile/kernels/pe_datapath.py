"""L1 — the Pallas kernel for the relax PE datapath.

Batched closure evaluation: a tile of B ready `relax` tasks is evaluated
at once — `y = relu(x @ W + b)`, plus the frontier score per row. On TPU
the BlockSpec below maps row tiles of the closure batch into VMEM while
the weight tile stays resident, feeding the MXU (see DESIGN.md
§Hardware-Adaptation — this is the DAE write-buffer idea restated as an
HBM→VMEM schedule). `interpret=True` everywhere: the CPU PJRT plugin
cannot run Mosaic custom-calls; real-TPU numbers are estimated
structurally in DESIGN.md.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of the batch processed per grid step (VMEM tile height).
ROW_TILE = 32


def _relax_kernel(x_ref, w_ref, b_ref, y_ref, score_ref):
    """One grid step: a [ROW_TILE, F] tile through the datapath."""
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.maximum(jnp.dot(x, w) + b[None, :], 0.0)
    y_ref[...] = y
    score_ref[...] = jnp.sum(y, axis=-1)


def relax_pallas(x, w, b):
    """Apply the datapath to a [B, F] batch (B % ROW_TILE == 0)."""
    batch, feat = x.shape
    assert batch % ROW_TILE == 0, f"batch {batch} not a multiple of {ROW_TILE}"
    grid = (batch // ROW_TILE,)
    return pl.pallas_call(
        _relax_kernel,
        grid=grid,
        in_specs=[
            # Row tiles stream through VMEM...
            pl.BlockSpec((ROW_TILE, feat), lambda i: (i, 0)),
            # ...while weights and bias stay resident across the grid.
            pl.BlockSpec((feat, feat), lambda i: (0, 0)),
            pl.BlockSpec((feat,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((ROW_TILE, feat), lambda i: (i, 0)),
            pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, feat), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.float32),
        ],
        interpret=True,
    )(x, w, b)
