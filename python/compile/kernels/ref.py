"""Pure-jnp oracle for the relax PE datapath, plus the deterministic
weight generator shared bit-for-bit with the Rust side.

The Rust coordinator (`rust/src/workloads/relax.rs`) generates the same
weights from the same xorshift64*/splitmix64 PRNG; `tests/test_kernel.py`
pins a golden vector so cross-language drift is caught immediately.
"""

import numpy as np

M64 = (1 << 64) - 1


def _splitmix64(state: int) -> tuple[int, int]:
    state = (state + 0x9E3779B97F4A7C15) & M64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return state, z ^ (z >> 31)


class Rng:
    """Port of bombyx::util::rng::Rng (xorshift64*)."""

    def __init__(self, seed: int):
        _, v = _splitmix64(seed & M64)
        self.state = v | 1

    def next_u64(self) -> int:
        x = self.state
        x ^= x >> 12
        x = (x ^ (x << 25)) & M64
        x ^= x >> 27
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & M64

    def unit_f32(self) -> np.float32:
        # Matches Rust: (next_u64() >> 11) as f64 * 2^-53, then `as f32`.
        return np.float32((self.next_u64() >> 11) * (1.0 / (1 << 53)))


F = 16  # feature width; must match rust/src/workloads/relax.rs::F


def weights(seed: int) -> tuple[np.ndarray, np.ndarray]:
    """W[F,F] and b[F], float32, identical to the Rust `weights(seed)`."""
    rng = Rng(seed)
    half = np.float32(0.5)
    w = np.empty(F * F, dtype=np.float32)
    for i in range(F * F):
        w[i] = (rng.unit_f32() - half) * np.float32(0.25)
    b = np.empty(F, dtype=np.float32)
    for i in range(F):
        b[i] = (rng.unit_f32() - half) * np.float32(0.1)
    return w.reshape(F, F), b


def relax_ref(x: np.ndarray, w: np.ndarray, b: np.ndarray):
    """Reference datapath: y = relu(x @ w + b); score = sum(y, axis=-1).

    Implemented in float64-free numpy float32 to mirror both the Pallas
    kernel and the Rust scalar path.
    """
    x = np.asarray(x, dtype=np.float32)
    y = np.maximum(x @ w + b, np.float32(0.0)).astype(np.float32)
    score = y.sum(axis=-1, dtype=np.float32)
    return y, score
