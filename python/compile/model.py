"""L2 — the JAX compute graph around the Pallas kernel.

`relax_step` is what gets AOT-lowered: it evaluates the batched PE
datapath and additionally emits the integer frontier scores the Cilk-1
continuation protocol carries (`send_argument(k, score)` — scores are
fixed-point ×1000 int32 on the wire, saturating, exactly like the Rust
scalar path)."""

import jax.numpy as jnp

from .kernels.pe_datapath import relax_pallas


def relax_step(x, w, b):
    """x: [B, F] float32; returns (y [B,F] f32, score_milli [B] i32)."""
    y, score = relax_pallas(x, w, b)
    score_milli = jnp.clip(
        score * 1000.0, jnp.float32(-2**31), jnp.float32(2**31 - 256)
    ).astype(jnp.int32)
    return y, score_milli
