"""Kernel correctness: Pallas vs pure-jnp/numpy oracle, weight parity with
the Rust PRNG, and AOT lowering sanity. Hypothesis sweeps shapes/values."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pe_datapath import ROW_TILE, relax_pallas
from compile.kernels.ref import F, Rng, relax_ref, weights
from compile.model import relax_step


def rand_batch(rng: np.random.Generator, batch: int, lo=-2.0, hi=2.0):
    return rng.uniform(lo, hi, size=(batch, F)).astype(np.float32)


# ---- weight generator parity with Rust ------------------------------------

# Golden values mirrored in rust/tests/golden_tests.rs (same seed=1).
GOLDEN_W_SEED1_FIRST4 = [-0.051488318, 0.085822836, -0.032146744, -0.06721322]


def test_rng_matches_rust_golden():
    w, b = weights(1)
    golden = np.array(GOLDEN_W_SEED1_FIRST4, dtype=np.float32)
    np.testing.assert_array_equal(w.flatten()[:4], golden)
    assert w.shape == (F, F) and b.shape == (F,)
    assert w.dtype == np.float32 and b.dtype == np.float32


def test_rng_determinism_and_seed_sensitivity():
    w1, b1 = weights(7)
    w2, b2 = weights(7)
    assert np.array_equal(w1, w2) and np.array_equal(b1, b2)
    w3, _ = weights(8)
    assert not np.array_equal(w1, w3)


def test_rng_uniformity():
    r = Rng(123)
    vals = np.array([r.unit_f32() for _ in range(4000)])
    assert 0.0 <= vals.min() and vals.max() < 1.0
    assert abs(vals.mean() - 0.5) < 0.03


# ---- Pallas kernel vs oracle ----------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    batch_tiles=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    scale=st.floats(min_value=0.01, max_value=8.0),
)
def test_pallas_matches_ref(batch_tiles, seed, scale):
    batch = batch_tiles * ROW_TILE
    rng = np.random.default_rng(seed)
    x = rand_batch(rng, batch, -scale, scale)
    w, b = weights(seed & 0xFFFF)
    y_p, s_p = relax_pallas(x, w, b)
    y_r, s_r = relax_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(y_p), y_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_p), s_r, rtol=1e-4, atol=1e-4)


def test_relu_clamps_negatives():
    x = -np.ones((ROW_TILE, F), dtype=np.float32) * 100.0
    w = np.eye(F, dtype=np.float32)
    b = np.zeros(F, dtype=np.float32)
    y, s = relax_pallas(x, w, b)
    assert np.all(np.asarray(y) == 0.0)
    assert np.all(np.asarray(s) == 0.0)


def test_batch_rows_are_independent():
    rng = np.random.default_rng(0)
    w, b = weights(1)
    x = rand_batch(rng, 2 * ROW_TILE)
    y_full, _ = relax_pallas(x, w, b)
    y_half, _ = relax_pallas(x[:ROW_TILE], w, b)
    np.testing.assert_allclose(np.asarray(y_full)[:ROW_TILE], np.asarray(y_half), rtol=1e-6)


def test_non_tile_multiple_rejected():
    x = np.zeros((ROW_TILE + 1, F), dtype=np.float32)
    w, b = weights(1)
    with pytest.raises(AssertionError):
        relax_pallas(x, w, b)


# ---- L2 model --------------------------------------------------------------

def test_relax_step_scores_are_milli_ints():
    rng = np.random.default_rng(3)
    x = rand_batch(rng, ROW_TILE, 0.0, 1.0)
    w, b = weights(1)
    y, s_milli = relax_step(x, w, b)
    _, s_ref = relax_ref(x, w, b)
    s_milli = np.asarray(s_milli)
    assert s_milli.dtype == np.int32
    np.testing.assert_allclose(s_milli, (s_ref * 1000.0).astype(np.int32), atol=2)


def test_relax_step_saturates():
    x = np.full((ROW_TILE, F), 1e30, dtype=np.float32)
    w = np.eye(F, dtype=np.float32)
    b = np.zeros(F, dtype=np.float32)
    _, s = relax_step(x, w, b)
    assert np.all(np.asarray(s) > 0)  # saturated, not wrapped negative


# ---- AOT lowering -----------------------------------------------------------

def test_lowering_produces_hlo_text():
    from compile.aot import lower_variant

    text = lower_variant(64)
    assert "HloModule" in text
    assert "f32[64,16]" in text, text[:500]
    # Tuple-returning entry (the Rust side unwraps a 2-tuple).
    assert "(f32[64,16]" in text and "s32[64]" in text
