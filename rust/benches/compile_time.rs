//! A-compile ablation: compiler throughput per stage for every example
//! program.

use bombyx::frontend;
use bombyx::lower::{compile, CompileOptions};
use bombyx::util::bench::{banner, bench};
use bombyx::workloads::{bfs, fib, nqueens, qsort, relax};

fn main() {
    banner("compile_time", "Compiler stage timings on the example programs.");
    let programs: &[(&str, &str)] = &[
        ("fib", fib::FIB_SRC),
        ("bfs", bfs::BFS_SRC),
        ("bfs_dae", bfs::BFS_DAE_SRC),
        ("nqueens", nqueens::NQUEENS_SRC),
        ("qsort", qsort::QSORT_SRC),
        ("relax", relax::RELAX_SRC),
    ];
    for (name, src) in programs {
        bench(&format!("parse+sema {name}"), 50, || {
            frontend::parse_and_check(name, src).unwrap()
        });
        bench(&format!("full pipeline {name}"), 50, || {
            compile(name, src, &CompileOptions::standard()).unwrap()
        });
        bench(&format!("hardcilk codegen {name}"), 50, || {
            let r = compile(name, src, &CompileOptions::standard()).unwrap();
            bombyx::backend::hardcilk::generate(&r.explicit, name).unwrap()
        });
    }
}
