//! A-compile ablation: compiler throughput per stage for every example
//! program, via the pass manager's per-pass timing counters.

use std::time::Duration;

use bombyx::frontend;
use bombyx::lower::{CompileOptions, CompileSession};
use bombyx::util::bench::{banner, bench, timing_table};
use bombyx::workloads::{bfs, fib, nqueens, qsort, relax};

fn main() {
    banner("compile_time", "Compiler stage timings on the example programs.");
    let programs: &[(&str, &str)] = &[
        ("fib", fib::FIB_SRC),
        ("bfs", bfs::BFS_SRC),
        ("bfs_dae", bfs::BFS_DAE_SRC),
        ("nqueens", nqueens::NQUEENS_SRC),
        ("qsort", qsort::QSORT_SRC),
        ("relax", relax::RELAX_SRC),
    ];
    for (name, src) in programs {
        bench(&format!("parse+sema {name}"), 50, || {
            frontend::parse_and_check(name, src).unwrap()
        });
        bench(&format!("compile session {name}"), 50, || {
            CompileSession::new(name, src, &CompileOptions::standard()).unwrap()
        });

        // Per-pass breakdown: median of the PassManager's own timing
        // counters over repeated compiles.
        let mut per_pass: Vec<(&'static str, Vec<Duration>, bool)> = Vec::new();
        for _ in 0..20 {
            let session = CompileSession::new(name, src, &CompileOptions::standard()).unwrap();
            for t in session.timings() {
                match per_pass.iter_mut().find(|(n, _, _)| *n == t.pass) {
                    Some((_, samples, _)) => samples.push(t.duration),
                    None => per_pass.push((t.pass, vec![t.duration], t.ran)),
                }
            }
        }
        let rows: Vec<bombyx::lower::PassTiming> = per_pass
            .iter()
            .map(|(pass, samples, ran)| {
                let mut sorted = samples.clone();
                sorted.sort();
                bombyx::lower::PassTiming {
                    pass: *pass,
                    duration: sorted[sorted.len() / 2],
                    ran: *ran,
                }
            })
            .collect();
        println!("per-pass medians for {name}:");
        println!("{}", timing_table(&rows));

        // Codegen on the session's cached explicit module: the compiler
        // runs once, only the backend is timed per iteration.
        let mut session = CompileSession::new(name, src, &CompileOptions::standard()).unwrap();
        bench(&format!("hardcilk codegen {name}"), 50, || {
            bombyx::backend::hardcilk::generate(session.explicit(), name).unwrap()
        });
        // Memoized target artifact: repeated requests are free.
        let _ = session.hardcilk_system(name).unwrap();
        let _ = session.hardcilk_system(name).unwrap();
    }
}
