//! A-compile ablation: compiler throughput per stage for every example
//! program, plus the two throughput layers on top of the pass manager —
//! parallel batch compilation (serial vs. `--jobs 4` over the six-program
//! corpus) and incremental per-function recompilation (one-function edit
//! vs. cold compile, in both wall time and pass work).
//!
//! Emits `BENCH_compile.json` (machine-readable) next to the text report
//! so the perf trajectory has a committed datapoint per run.
//!
//! `BOMBYX_BENCH_SMOKE=1` switches to a reduced-iteration mode used by CI
//! to catch bench bit-rot without paying full measurement cost.

use std::time::Duration;

use bombyx::frontend;
use bombyx::lower::{compile_batch, pass_work, CompileOptions, CompileSession, RecompileMode};
use bombyx::util::bench::{banner, bench, timing_table};
use bombyx::util::json::Json;
use bombyx::workloads::{bfs, fib, nqueens, qsort, relax};

/// Four functions so a one-function edit leaves three untouched: the
/// incremental section needs clean functions to skip.
const INCR_SRC: &str = "\
global int acc[4];
int leaf_a(int a) { return a * 3 + 1; }
int leaf_b(int a) { return a - 2; }
int work(int n) {
    if (n < 2) { int t = leaf_a(n); return t; }
    int x = cilk_spawn work(n - 1);
    int y = cilk_spawn work(n - 2);
    cilk_sync;
    int r = leaf_b(x + y);
    return r;
}
void top(int n) {
    int r = cilk_spawn work(n);
    cilk_sync;
    atomic_add(acc, 0, r);
}
";

fn main() {
    let smoke = std::env::var("BOMBYX_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let samples = if smoke { 3 } else { 50 };
    let pass_iters = if smoke { 3 } else { 20 };
    banner(
        "compile_time",
        "Compiler stage timings, batch throughput and incremental recompilation.",
    );
    if smoke {
        println!("(smoke mode: reduced iterations)");
    }
    let programs: &[(&str, &str)] = &[
        ("fib", fib::FIB_SRC),
        ("bfs", bfs::BFS_SRC),
        ("bfs_dae", bfs::BFS_DAE_SRC),
        ("nqueens", nqueens::NQUEENS_SRC),
        ("qsort", qsort::QSORT_SRC),
        ("relax", relax::RELAX_SRC),
    ];

    // ---- section 1: per-program stage timings ------------------------------
    for (name, src) in programs {
        bench(&format!("parse+sema {name}"), samples, || {
            frontend::parse_and_check(name, src).unwrap()
        });
        bench(&format!("compile session {name}"), samples, || {
            CompileSession::new(name, src, &CompileOptions::standard()).unwrap()
        });

        // Per-pass breakdown: median of the PassManager's own timing
        // counters over repeated compiles.
        let mut per_pass: Vec<(&'static str, Vec<Duration>, bool, usize)> = Vec::new();
        for _ in 0..pass_iters {
            let session = CompileSession::new(name, src, &CompileOptions::standard()).unwrap();
            for t in session.timings() {
                match per_pass.iter_mut().find(|(n, _, _, _)| *n == t.pass) {
                    Some((_, samples, _, _)) => samples.push(t.duration),
                    None => per_pass.push((t.pass, vec![t.duration], t.ran, t.funcs)),
                }
            }
        }
        let rows: Vec<bombyx::lower::PassTiming> = per_pass
            .iter()
            .map(|(pass, samples, ran, funcs)| {
                let mut sorted = samples.clone();
                sorted.sort();
                bombyx::lower::PassTiming {
                    pass: *pass,
                    duration: sorted[sorted.len() / 2],
                    ran: *ran,
                    funcs: *funcs,
                }
            })
            .collect();
        println!("per-pass medians for {name}:");
        println!("{}", timing_table(&rows));

        // Codegen on the session's cached explicit module: the compiler
        // runs once, only the backend is timed per iteration.
        let mut session = CompileSession::new(name, src, &CompileOptions::standard()).unwrap();
        bench(&format!("hardcilk codegen {name}"), samples, || {
            bombyx::backend::hardcilk::generate(session.explicit(), name).unwrap()
        });
        // Memoized target artifact: repeated requests are free.
        let _ = session.hardcilk_system(name).unwrap();
        let _ = session.hardcilk_system(name).unwrap();
    }

    // ---- section 2: batch compilation, serial vs parallel ------------------
    println!("== batch: {} programs, serial vs --jobs 4 ==", programs.len());
    let serial = bench("batch compile (jobs=1)", samples, || {
        let b = compile_batch(programs, &CompileOptions::standard(), 1);
        assert!(b.errors().is_empty(), "corpus must compile: {:?}", b.errors());
        b
    });
    let par4 = bench("batch compile (jobs=4)", samples, || {
        let b = compile_batch(programs, &CompileOptions::standard(), 4);
        assert!(b.errors().is_empty(), "corpus must compile in parallel: {:?}", b.errors());
        b
    });
    let speedup = serial.median.as_secs_f64() / par4.median.as_secs_f64().max(1e-12);
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!(
        "batch speedup (serial / jobs=4): {speedup:.2}x on {cores} available core(s)"
    );

    // ---- section 3: incremental recompilation ------------------------------
    println!("== incremental: one-function edit vs cold compile ==");
    let edited = INCR_SRC.replace("a * 3 + 1", "a * 7 + 1");
    let opts = CompileOptions::standard();
    let cold_session = CompileSession::new("incr", INCR_SRC, &opts).unwrap();
    let cold_work = pass_work(cold_session.timings());

    let cold = bench("cold compile (4 funcs)", samples, || {
        CompileSession::new("incr", &edited, &opts).unwrap()
    });
    // Alternate between the two sources: every call is exactly a
    // one-function edit against the session's cached state.
    let mut session = CompileSession::new("incr", INCR_SRC, &opts).unwrap();
    let mut flip = false;
    let mut incr_work = 0usize;
    let incr = bench("incremental recompile (1 dirty func)", samples, || {
        flip = !flip;
        let src: &str = if flip { &edited } else { INCR_SRC };
        let outcome = session.recompile(src).unwrap();
        assert_eq!(
            outcome.mode,
            RecompileMode::Incremental,
            "a body edit must recompile incrementally"
        );
        incr_work = incr_work.max(pass_work(&outcome.timings));
        outcome.mode
    });
    let work_ratio = incr_work as f64 / cold_work.max(1) as f64;
    let wall_ratio = incr.median.as_secs_f64() / cold.median.as_secs_f64().max(1e-12);
    println!(
        "incremental pass work: {incr_work} vs cold {cold_work} ({:.0}% of cold); wall {:.0}% of cold",
        work_ratio * 100.0,
        wall_ratio * 100.0
    );
    assert!(
        work_ratio < 0.5,
        "one-function recompile must run < 50% of cold pass work ({incr_work}/{cold_work})"
    );

    // ---- section 4: rtl emission memoization -------------------------------
    let mut session = CompileSession::new("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let _ = session.rtl_system("fib_system").unwrap();
    let passes_after_first = session.timings().len();
    let _ = session.rtl_system("fib_system").unwrap();
    let passes_after_second = session.timings().len();
    assert_eq!(
        passes_after_first, passes_after_second,
        "a second rtl_system call must do zero lowering/emission work"
    );
    println!("rtl memoization: second emission ran {} extra passes (expected 0)", passes_after_second - passes_after_first);

    // ---- machine-readable output -------------------------------------------
    let mut batch_json = Json::object();
    batch_json
        .set("programs", programs.len())
        .set("serial_ms", serial.median.as_secs_f64() * 1e3)
        .set("jobs4_ms", par4.median.as_secs_f64() * 1e3)
        .set("speedup", speedup)
        .set("available_cores", cores);
    let mut incr_json = Json::object();
    incr_json
        .set("cold_ms", cold.median.as_secs_f64() * 1e3)
        .set("incremental_ms", incr.median.as_secs_f64() * 1e3)
        .set("wall_ratio", wall_ratio)
        .set("cold_pass_work", cold_work)
        .set("incremental_pass_work", incr_work)
        .set("work_ratio", work_ratio)
        .set("dirty_funcs", 1usize)
        .set("total_funcs", 4usize);
    let mut rtl_json = Json::object();
    rtl_json.set("second_emission_extra_passes", passes_after_second - passes_after_first);
    let mut root = Json::object();
    root.set("bench", "compile_time")
        .set("mode", if cfg!(debug_assertions) { "debug" } else { "release" })
        .set("smoke", smoke)
        .set("batch", batch_json)
        .set("incremental", incr_json)
        .set("rtl_memoization", rtl_json);
    let path = "BENCH_compile.json";
    std::fs::write(path, root.pretty() + "\n").expect("write BENCH_compile.json");
    println!("wrote {path}");
}
