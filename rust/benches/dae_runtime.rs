//! E-runtime: the paper's §III headline — DAE vs non-DAE runtime on
//! synthetic trees B=4, D∈{7,9}, one PE per task type. Paper: 26.5 %
//! overall reduction. Both program variants are compiled once (one
//! `CompileSession` each, inside `BfsExperiment`) and reused per graph.

use bombyx::coordinator::BfsExperiment;
use bombyx::sim::SimConfig;
use bombyx::util::bench::{banner, timing_table};
use bombyx::util::table::{commas, Table};
use bombyx::workloads::graphgen;

fn main() {
    banner(
        "dae_runtime",
        "Paper §III headline: execution time to traverse the whole graph, DAE vs non-DAE\n\
         (HardCilk simulator, 1 PE per task type, 300 MHz).",
    );
    let exp = BfsExperiment::new().expect("compile bfs sessions");
    println!("one-time compile of the DAE variant, per pass:");
    println!("{}", timing_table(exp.dae.timings()));

    let cfg = SimConfig::paper();
    let mut table =
        Table::new(["graph", "nodes", "non-DAE cycles", "DAE cycles", "reduction", "paper"]);
    let mut reductions = Vec::new();
    for depth in [7u32, 9] {
        let graph = graphgen::tree(4, depth);
        let cmp = exp.run(&graph, &cfg).expect("simulation");
        reductions.push(cmp.reduction());
        table.row([
            format!("tree B=4 D={depth}"),
            commas(graph.nodes() as u64),
            commas(cmp.plain_cycles),
            commas(cmp.dae_cycles),
            format!("{:.1}%", cmp.reduction() * 100.0),
            "26.5% overall".to_string(),
        ]);
    }
    print!("{}", table.render());
    let overall = reductions.iter().sum::<f64>() / reductions.len() as f64;
    println!("\noverall runtime reduction: {:.1}% (paper: 26.5%)", overall * 100.0);
    assert!(
        (0.15..0.40).contains(&overall),
        "reproduction drifted out of band: {overall}"
    );
}
