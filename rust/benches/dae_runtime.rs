//! E-runtime: the paper's §III headline — DAE vs non-DAE runtime on
//! synthetic trees B=4, D∈{7,9}, one PE per task type. Paper: 26.5 %
//! overall reduction. Both program variants are compiled once (one
//! `CompileSession` each, inside `BfsExperiment`) and reused per graph.
//!
//! Emits `BENCH_dae_runtime.json`: a `bombyx-metrics-v1` registry
//! document (same schema as `--metrics-json`), so the perf-trajectory
//! tooling reads every bench artifact the same way.

use bombyx::coordinator::BfsExperiment;
use bombyx::obs::metrics::Registry;
use bombyx::sim::SimConfig;
use bombyx::util::bench::{banner, timing_table};
use bombyx::util::json::Json;
use bombyx::util::table::{commas, Table};
use bombyx::workloads::graphgen;

fn main() {
    banner(
        "dae_runtime",
        "Paper §III headline: execution time to traverse the whole graph, DAE vs non-DAE\n\
         (HardCilk simulator, 1 PE per task type, 300 MHz).",
    );
    let exp = BfsExperiment::new().expect("compile bfs sessions");
    println!("one-time compile of the DAE variant, per pass:");
    println!("{}", timing_table(exp.dae.timings()));

    let cfg = SimConfig::paper();
    let mut reg = Registry::new();
    let mut table =
        Table::new(["graph", "nodes", "non-DAE cycles", "DAE cycles", "reduction", "paper"]);
    let mut reductions = Vec::new();
    for depth in [7u32, 9] {
        let graph = graphgen::tree(4, depth);
        let cmp = exp.run(&graph, &cfg).expect("simulation");
        reductions.push(cmp.reduction());
        reg.counter_add("dae_runtime.graphs", 1);
        let key = format!("dae_runtime.tree_b4_d{depth}");
        reg.counter_set(&format!("{key}.nodes"), graph.nodes() as u64);
        reg.counter_set(&format!("{key}.plain_cycles"), cmp.plain_cycles);
        reg.counter_set(&format!("{key}.dae_cycles"), cmp.dae_cycles);
        reg.gauge_set(&format!("{key}.reduction"), cmp.reduction());
        reg.observe("dae_runtime.reduction", cmp.reduction());
        table.row([
            format!("tree B=4 D={depth}"),
            commas(graph.nodes() as u64),
            commas(cmp.plain_cycles),
            commas(cmp.dae_cycles),
            format!("{:.1}%", cmp.reduction() * 100.0),
            "26.5% overall".to_string(),
        ]);
    }
    print!("{}", table.render());
    let overall = reductions.iter().sum::<f64>() / reductions.len() as f64;
    println!("\noverall runtime reduction: {:.1}% (paper: 26.5%)", overall * 100.0);
    reg.gauge_set("dae_runtime.overall_reduction", overall);
    reg.gauge_set("dae_runtime.paper_reduction", 0.265);

    let mut root = Json::object();
    root.set("bench", "dae_runtime")
        .set("mode", if cfg!(debug_assertions) { "debug" } else { "release" })
        .set("metrics", reg.to_json());
    let path = "BENCH_dae_runtime.json";
    std::fs::write(path, root.pretty() + "\n").expect("write BENCH_dae_runtime.json");
    println!("wrote {path}");

    assert!(
        (0.15..0.40).contains(&overall),
        "reproduction drifted out of band: {overall}"
    );
}
