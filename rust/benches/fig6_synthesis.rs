//! E-fig6: regenerate the paper's Fig. 6 synthesis table (LUT/FF/BRAM for
//! the DAE-optimization PEs), via the calibrated HLS resource estimator
//! (Vivado 2024.1 / xcu55c @ 300 MHz in the paper). Each program variant
//! is compiled once into a `CompileSession`; the estimator reads the
//! cached explicit modules.

use bombyx::hls::{estimate, CostModel};
use bombyx::lower::{CompileOptions, CompileSession};
use bombyx::util::bench::banner;
use bombyx::util::table::{pct_delta, Table};
use bombyx::workloads::bfs;

fn main() {
    banner("fig6_synthesis", "Paper Fig. 6: synthesis results for DAE optimization PEs.");
    let model = CostModel::default();
    let non_dae =
        CompileSession::new("bfs", bfs::BFS_SRC, &CompileOptions::no_dae()).unwrap();
    let dae =
        CompileSession::new("bfs_dae", bfs::BFS_DAE_SRC, &CompileOptions::standard()).unwrap();
    let est = |m: &bombyx::ir::Module, name: &str| {
        let f = &m.funcs[m.func_by_name(name).unwrap()];
        estimate(&model, m, f)
    };

    let non = est(non_dae.explicit(), "visit");
    let spawner = est(dae.explicit(), "visit");
    let executor = est(dae.explicit(), "visit__k1");
    let access = est(dae.explicit(), "adj_off_access");
    let dae_total = spawner + executor + access;

    let paper = [
        ("Non-DAE", (2657u32, 2305u32, 2u32)),
        ("Spawner", (133, 387, 0)),
        ("Executor", (1999, 1913, 2)),
        ("Access", (1764, 1164, 2)),
        ("DAE (total)", (3896, 3464, 4)),
    ];
    let ours = [non, spawner, executor, access, dae_total];

    let mut table = Table::new([
        "PE", "LUT est", "LUT paper", "LUT err", "FF est", "FF paper", "FF err", "BRAM est",
        "BRAM paper",
    ]);
    for ((name, (pl, pf, pb)), e) in paper.iter().zip(ours) {
        let lut_err = (e.lut as f64 - *pl as f64) / *pl as f64 * 100.0;
        let ff_err = (e.ff as f64 - *pf as f64) / *pf as f64 * 100.0;
        table.row([
            name.to_string(),
            e.lut.to_string(),
            pl.to_string(),
            format!("{lut_err:+.1}%"),
            e.ff.to_string(),
            pf.to_string(),
            format!("{ff_err:+.1}%"),
            e.bram.to_string(),
            pb.to_string(),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\nDAE overhead: LUT {}, FF {} (paper: +47% LUT, +50% FF)",
        pct_delta(dae_total.lut as f64 / non.lut as f64),
        pct_delta(dae_total.ff as f64 / non.ff as f64),
    );
}
