//! E-fig1/2/4/5: regenerate the paper's listings and IR figures as golden
//! artifacts — Fig. 1 (OpenCilk fib), Fig. 2 (Cilk-1 fib), Fig. 4(b)/(c)
//! (implicit & explicit CFGs), Fig. 5 (BFS listing).

use bombyx::ir::print::{print_cilk1, print_module};
use bombyx::lower::{CompileOptions, CompileSession};
use bombyx::util::bench::banner;
use bombyx::workloads::{bfs, fib};

fn main() {
    banner("figures", "Regenerates paper Figs. 1, 2, 4(b), 4(c), 5 from the compiler.");

    println!("==== Fig. 1: OpenCilk fib (Cilk-C source) ====\n{}", fib::FIB_SRC);

    let session = CompileSession::new("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    println!("==== Fig. 4(b): implicit IR (CFG with sync terminator) ====");
    let implicit = session.implicit();
    let f = &implicit.funcs[implicit.func_by_name("fib").unwrap()];
    println!("{}", bombyx::ir::print::print_func(implicit, f));

    println!("==== Fig. 4(c): explicit IR (paths -> terminating tasks) ====");
    print!("{}", print_module(session.explicit()));

    println!("==== Fig. 2: Cilk-1 concrete syntax ====");
    for (_, f) in session.explicit().funcs.iter() {
        if f.task.is_some() && f.body.is_some() {
            println!("{}", print_cilk1(session.explicit(), f));
        }
    }

    println!("==== Fig. 5: parallel BFS (Cilk-C source) ====\n{}", bfs::BFS_SRC);
    println!("==== Fig. 5 + DAE pragma (paper §III) ====\n{}", bfs::BFS_DAE_SRC);
}
