//! A-mem ablation: memory-latency sweep — DAE's benefit as a function of
//! HBM service latency (the §II-C mechanism made quantitative). One
//! `BfsExperiment` serves every latency point; the grid is sharded across
//! OS threads (`BfsExperiment::run_grid`).

use bombyx::coordinator::BfsExperiment;
use bombyx::sim::SimConfig;
use bombyx::util::bench::banner;
use bombyx::util::table::{commas, Table};
use bombyx::workloads::graphgen;

fn main() {
    banner(
        "memlat_sweep",
        "Ablation: memory latency 10..320 cycles on the B=4 D=7 tree, 1 PE/type.",
    );
    let exp = BfsExperiment::new().expect("compile bfs sessions");
    let graph = graphgen::tree(4, 7);
    let latencies = [10u32, 20, 40, 80, 160, 320];
    let configs: Vec<SimConfig> = latencies
        .iter()
        .map(|&lat| SimConfig { mem_latency: lat, ..SimConfig::paper() })
        .collect();
    let results = exp.run_grid(&graph, &configs).expect("simulation");
    let mut table = Table::new(["mem latency", "non-DAE cycles", "DAE cycles", "reduction"]);
    let mut last_reduction = -1.0f64;
    let mut monotone = true;
    for (lat, cmp) in latencies.iter().zip(&results) {
        if cmp.reduction() < last_reduction {
            monotone = false;
        }
        last_reduction = cmp.reduction();
        table.row([
            lat.to_string(),
            commas(cmp.plain_cycles),
            commas(cmp.dae_cycles),
            format!("{:.1}%", cmp.reduction() * 100.0),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\nDAE benefit grows with memory latency: {}",
        if monotone { "confirmed (monotone)" } else { "NOT monotone — investigate" }
    );
}
