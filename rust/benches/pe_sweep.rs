//! A-pes ablation: PE-count sweep — how the DAE advantage evolves as the
//! system scales from the paper's 1-PE configuration to 16 PEs per type.
//! One `BfsExperiment` (two compile sessions) serves the whole sweep; the
//! grid points are sharded across OS threads (`BfsExperiment::run_grid`),
//! so the bench scales with cores — only the simulator runs per
//! configuration.
//!
//! Emits `BENCH_pe_sweep.json`: a `bombyx-metrics-v1` registry document
//! (same schema as `--metrics-json`), so the perf-trajectory tooling
//! reads every bench artifact the same way.

use bombyx::coordinator::BfsExperiment;
use bombyx::obs::metrics::Registry;
use bombyx::sim::SimConfig;
use bombyx::util::bench::banner;
use bombyx::util::json::Json;
use bombyx::util::table::{commas, Table};
use bombyx::workloads::graphgen;

fn main() {
    banner(
        "pe_sweep",
        "Ablation: PEs per task type 1..16 on the B=4 D=7 tree (DAE vs non-DAE).",
    );
    let exp = BfsExperiment::new().expect("compile bfs sessions");
    let graph = graphgen::tree(4, 7);
    let pe_counts = [1u32, 2, 4, 8, 16];
    let configs: Vec<SimConfig> = pe_counts
        .iter()
        .map(|&pes| SimConfig { default_pes: pes, ..SimConfig::paper() })
        .collect();
    let t0 = std::time::Instant::now();
    let results = exp.run_grid(&graph, &configs).expect("simulation");
    let elapsed = t0.elapsed();
    let mut reg = Registry::new();
    let mut table = Table::new([
        "PEs/type",
        "non-DAE cycles",
        "DAE cycles",
        "reduction",
        "DAE speedup vs 1 PE",
    ]);
    let base_dae = results[0].dae_cycles;
    for (pes, cmp) in pe_counts.iter().zip(&results) {
        let speedup = base_dae as f64 / cmp.dae_cycles as f64;
        reg.counter_add("pe_sweep.grid_points", 1);
        let key = format!("pe_sweep.pes_{pes}");
        reg.counter_set(&format!("{key}.plain_cycles"), cmp.plain_cycles);
        reg.counter_set(&format!("{key}.dae_cycles"), cmp.dae_cycles);
        reg.gauge_set(&format!("{key}.reduction"), cmp.reduction());
        reg.gauge_set(&format!("{key}.dae_speedup_vs_1pe"), speedup);
        reg.observe("pe_sweep.reduction", cmp.reduction());
        table.row([
            pes.to_string(),
            commas(cmp.plain_cycles),
            commas(cmp.dae_cycles),
            format!("{:.1}%", cmp.reduction() * 100.0),
            format!("{speedup:.2}x"),
        ]);
    }
    print!("{}", table.render());
    let workers = BfsExperiment::grid_workers(configs.len());
    println!(
        "\n({} grid points simulated in {:.2}s across {} worker threads.)",
        configs.len(),
        elapsed.as_secs_f64(),
        workers
    );
    println!("(The paper evaluates only the 1-PE configurations; the sweep probes the\n design point where the memory channel rather than the PE count saturates.)");
    reg.counter_set("pe_sweep.nodes", graph.nodes() as u64);
    reg.counter_set("pe_sweep.grid_workers", workers as u64);
    reg.gauge_set("pe_sweep.grid_wall_s", elapsed.as_secs_f64());

    let mut root = Json::object();
    root.set("bench", "pe_sweep")
        .set("mode", if cfg!(debug_assertions) { "debug" } else { "release" })
        .set("metrics", reg.to_json());
    let path = "BENCH_pe_sweep.json";
    std::fs::write(path, root.pretty() + "\n").expect("write BENCH_pe_sweep.json");
    println!("wrote {path}");
}
