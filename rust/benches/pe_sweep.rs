//! A-pes ablation: PE-count sweep — how the DAE advantage evolves as the
//! system scales from the paper's 1-PE configuration to 16 PEs per type.
//! One `BfsExperiment` (two compile sessions) serves the whole sweep; the
//! grid points are sharded across OS threads (`BfsExperiment::run_grid`),
//! so the bench scales with cores — only the simulator runs per
//! configuration.

use bombyx::coordinator::BfsExperiment;
use bombyx::sim::SimConfig;
use bombyx::util::bench::banner;
use bombyx::util::table::{commas, Table};
use bombyx::workloads::graphgen;

fn main() {
    banner(
        "pe_sweep",
        "Ablation: PEs per task type 1..16 on the B=4 D=7 tree (DAE vs non-DAE).",
    );
    let exp = BfsExperiment::new().expect("compile bfs sessions");
    let graph = graphgen::tree(4, 7);
    let pe_counts = [1u32, 2, 4, 8, 16];
    let configs: Vec<SimConfig> = pe_counts
        .iter()
        .map(|&pes| SimConfig { default_pes: pes, ..SimConfig::paper() })
        .collect();
    let t0 = std::time::Instant::now();
    let results = exp.run_grid(&graph, &configs).expect("simulation");
    let elapsed = t0.elapsed();
    let mut table = Table::new([
        "PEs/type",
        "non-DAE cycles",
        "DAE cycles",
        "reduction",
        "DAE speedup vs 1 PE",
    ]);
    let base_dae = results[0].dae_cycles;
    for (pes, cmp) in pe_counts.iter().zip(&results) {
        table.row([
            pes.to_string(),
            commas(cmp.plain_cycles),
            commas(cmp.dae_cycles),
            format!("{:.1}%", cmp.reduction() * 100.0),
            format!("{:.2}x", base_dae as f64 / cmp.dae_cycles as f64),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\n({} grid points simulated in {:.2}s across {} worker threads.)",
        configs.len(),
        elapsed.as_secs_f64(),
        BfsExperiment::grid_workers(configs.len())
    );
    println!("(The paper evaluates only the 1-PE configurations; the sweep probes the\n design point where the memory channel rather than the PE count saturates.)");
}
