//! Compile-service throughput and latency: an in-process `bombyx serve`
//! daemon on a temp socket, driven over the real unix-socket protocol.
//!
//! Sections: (1) cold vs warm single-edit recompile latency (the warm
//! path must land at <= 0.5x cold p50), (2) sustained compiles/sec,
//! serial requests vs one batched `--jobs 4` request (>= 2x where >= 4
//! cores are available), (3) identical-template dedup (the daemon must
//! record dedup hits and serve them faster than cold).
//!
//! Emits `BENCH_serve.json`. `BOMBYX_BENCH_SMOKE=1` reduces iterations
//! and additionally arms obs to dump `SERVE_TRACE_smoke.json` /
//! `SERVE_METRICS_smoke.json` for CI artifact validation
//! (`serve_tests::ci_serve_artifacts_validate`).

use std::path::PathBuf;
use std::time::Instant;

use bombyx::obs;
use bombyx::serve::{Client, ServeConfig, Server};
use bombyx::util::bench::banner;
use bombyx::util::json::Json;

/// A compile unit big enough that lowering dominates protocol overhead:
/// `leaves` leaf functions plus a spawning task pair, all names
/// suffixed by `tag` so distinct tags are structurally unrelated
/// (defeating both dedup tiers — genuinely cold compiles).
fn program(tag: &str, leaves: usize) -> String {
    assert!(leaves >= 3);
    let mut src = String::new();
    for i in 0..leaves {
        src.push_str(&format!("int leaf_{tag}_{i}(int a) {{ return a * {} + {i}; }}\n", i + 3));
    }
    src.push_str(&format!(
        "int work_{tag}(int n) {{\n\
         \x20   if (n < 2) {{ int t = leaf_{tag}_0(n); return t; }}\n\
         \x20   int x = cilk_spawn work_{tag}(n - 1);\n\
         \x20   int y = cilk_spawn work_{tag}(n - 2);\n\
         \x20   cilk_sync;\n\
         \x20   int r = leaf_{tag}_1(x + y);\n\
         \x20   return r;\n}}\n"
    ));
    src.push_str(&format!(
        "void top_{tag}(int n) {{\n\
         \x20   int r = cilk_spawn work_{tag}(n);\n\
         \x20   cilk_sync;\n\
         \x20   int u = leaf_{tag}_2(r);\n\
         \x20   return;\n}}\n"
    ));
    src
}

fn p50(samples_ms: &mut Vec<f64>) -> f64 {
    samples_ms.sort_by(f64::total_cmp);
    if samples_ms.is_empty() {
        0.0
    } else {
        samples_ms[samples_ms.len() / 2]
    }
}

fn expect_mode(resp: &Json, want: &str, what: &str) {
    assert_eq!(
        resp.get("ok"),
        Some(&Json::Bool(true)),
        "{what} failed: {}",
        resp.compact()
    );
    assert_eq!(
        resp.get("mode").and_then(Json::as_str),
        Some(want),
        "{what}: unexpected mode in {}",
        resp.compact()
    );
}

fn main() {
    let smoke = std::env::var("BOMBYX_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let lat_samples = if smoke { 5 } else { 60 };
    let rounds = if smoke { 2 } else { 8 };
    let fleet = if smoke { 8 } else { 24 };
    let template_ids = if smoke { 6 } else { 16 };
    let leaves = 16;
    banner("serve_bench", "Compile-service daemon: latency, throughput and dedup.");
    if smoke {
        println!("(smoke mode: reduced iterations, obs armed for artifact dump)");
        obs::set_trace(true);
        obs::set_metrics(true);
    }

    let socket: PathBuf =
        std::env::temp_dir().join(format!("bx-bench-{}.sock", std::process::id()));
    let mut config = ServeConfig::new(&socket);
    // Small enough to exercise the LRU under the cold fleets below,
    // large enough that the warm/dedup sections never lose their donor.
    config.capacity = 32;
    let server = Server::start(config).expect("server starts");
    let mut client = Client::connect(&socket).expect("connect");
    let mut uniq = 0usize;
    let mut fresh = |prefix: &str| {
        uniq += 1;
        format!("{prefix}{uniq}")
    };

    // ---- section 1: cold vs warm single-edit latency -----------------------
    let mut cold_ms: Vec<f64> = Vec::with_capacity(lat_samples);
    for _ in 0..lat_samples {
        let tag = fresh("c");
        let src = program(&tag, leaves);
        let t0 = Instant::now();
        let resp = client.compile(&tag, &src).expect("cold compile");
        cold_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        expect_mode(&resp, "cold", "cold compile");
    }
    let cold_p50 = p50(&mut cold_ms);

    // Alternate a one-leaf edit against one resident session: every
    // request is a warm single-edit recompile.
    let warm_tag = fresh("w");
    let base = program(&warm_tag, leaves);
    let edited = base.replace("a * 3 + 0", "a * 91 + 0");
    assert_ne!(base, edited, "warm edit must apply");
    let resp = client.compile(&warm_tag, &base).expect("warm seed");
    expect_mode(&resp, "cold", "warm seed");
    let mut warm_ms: Vec<f64> = Vec::with_capacity(lat_samples);
    let mut flip = false;
    for _ in 0..lat_samples {
        flip = !flip;
        let src: &str = if flip { &edited } else { &base };
        let t0 = Instant::now();
        let resp = client.recompile(&warm_tag, src).expect("warm recompile");
        warm_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        expect_mode(&resp, "incremental", "warm recompile");
        assert_eq!(resp.get("warm"), Some(&Json::Bool(true)));
    }
    let warm_p50 = p50(&mut warm_ms);
    let warm_speedup = cold_p50 / warm_p50.max(1e-9);
    println!(
        "latency p50: cold {cold_p50:.3} ms, warm single-edit {warm_p50:.3} ms ({warm_speedup:.2}x)"
    );
    assert!(
        warm_p50 <= 0.5 * cold_p50,
        "warm single-edit recompile p50 ({warm_p50:.3} ms) must be <= 0.5x cold p50 ({cold_p50:.3} ms)"
    );

    // ---- section 2: sustained throughput, serial vs batch --jobs 4 ---------
    let jobs = 4usize;
    let mut serial_cps_rounds: Vec<f64> = Vec::new();
    let mut batch_cps_rounds: Vec<f64> = Vec::new();
    for _ in 0..rounds {
        let tags: Vec<String> = (0..fleet).map(|_| fresh("s")).collect();
        let sources: Vec<String> = tags.iter().map(|t| program(t, leaves)).collect();
        let t0 = Instant::now();
        for (tag, src) in tags.iter().zip(&sources) {
            let resp = client.compile(tag, src).expect("serial compile");
            expect_mode(&resp, "cold", "serial compile");
        }
        serial_cps_rounds.push(fleet as f64 / t0.elapsed().as_secs_f64().max(1e-9));

        let tags: Vec<String> = (0..fleet).map(|_| fresh("p")).collect();
        let sources: Vec<String> = tags.iter().map(|t| program(t, leaves)).collect();
        let items: Vec<(&str, &str)> =
            tags.iter().zip(&sources).map(|(t, s)| (t.as_str(), s.as_str())).collect();
        let t0 = Instant::now();
        let resp = client.batch(&items, jobs).expect("batch compile");
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{}", resp.compact());
        let results = resp.get("results").and_then(Json::as_array).expect("results");
        assert_eq!(results.len(), fleet);
        for r in results {
            expect_mode(r, "cold", "batch item");
        }
        batch_cps_rounds.push(fleet as f64 / secs);
    }
    let serial_cps = p50(&mut serial_cps_rounds);
    let batch_cps = p50(&mut batch_cps_rounds);
    let batch_speedup = batch_cps / serial_cps.max(1e-9);
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!(
        "throughput: serial {serial_cps:.1} compiles/s, batch --jobs {jobs} {batch_cps:.1} compiles/s \
         ({batch_speedup:.2}x on {cores} core(s))"
    );
    if cores >= jobs {
        assert!(
            batch_speedup >= 2.0,
            "batched --jobs {jobs} throughput ({batch_cps:.1}/s) must be >= 2x serial \
             ({serial_cps:.1}/s) on {cores} cores"
        );
    } else {
        println!("(skipping the >=2x batch assertion: only {cores} core(s) available)");
    }

    // ---- section 3: identical-template dedup -------------------------------
    let template = program(&fresh("t"), leaves);
    let first = client.compile(&fresh("tpl_"), &template).expect("template seed");
    expect_mode(&first, "cold", "template seed");
    let mut dedup_ms: Vec<f64> = Vec::with_capacity(template_ids);
    for _ in 0..template_ids {
        let id = fresh("tpl_");
        let t0 = Instant::now();
        let resp = client.compile(&id, &template).expect("template compile");
        dedup_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        expect_mode(&resp, "identical", "template compile");
    }
    let dedup_p50 = p50(&mut dedup_ms);
    println!(
        "dedup: {template_ids} identical-template ids served at p50 {dedup_p50:.3} ms \
         (cold p50 {cold_p50:.3} ms)"
    );

    client.shutdown().expect("shutdown");
    let snap = server.join().expect("join");
    println!(
        "daemon lifetime: {} requests, {} compiles, {} warm hits, {} dedup hits, {} evictions",
        snap.requests, snap.compiles, snap.cache_hits, snap.dedup_hits, snap.evictions
    );
    assert!(snap.dedup_hits > 0, "template workload must record dedup hits");
    assert_eq!(snap.errors, 0, "bench workload must not error");

    // ---- machine-readable output -------------------------------------------
    let mut root = Json::object();
    root.set("bench", "serve")
        .set("mode", if cfg!(debug_assertions) { "debug" } else { "release" })
        .set("smoke", smoke)
        .set("available_cores", cores)
        .set("program_funcs", leaves + 2)
        .set("cold_ms_p50", cold_p50)
        .set("warm_ms_p50", warm_p50)
        .set("warm_speedup", warm_speedup)
        .set("serial_cps", serial_cps)
        .set("batch_cps", batch_cps)
        .set("batch_speedup", batch_speedup)
        .set("batch_jobs", jobs)
        .set("fleet", fleet)
        .set("dedup_ms_p50", dedup_p50)
        .set("dedup_hits", snap.dedup_hits as i64)
        .set("requests", snap.requests as i64)
        .set("compiles", snap.compiles as i64)
        .set("cache_hits", snap.cache_hits as i64)
        .set("evictions", snap.evictions as i64);
    let path = "BENCH_serve.json";
    std::fs::write(path, root.pretty() + "\n").expect("write BENCH_serve.json");
    println!("wrote {path}");

    if smoke {
        obs::set_trace(false);
        obs::set_metrics(false);
        let trace = obs::trace::export_current();
        std::fs::write("SERVE_TRACE_smoke.json", trace.pretty() + "\n")
            .expect("write SERVE_TRACE_smoke.json");
        let metrics = obs::metrics::export_json();
        std::fs::write("SERVE_METRICS_smoke.json", metrics.pretty() + "\n")
            .expect("write SERVE_METRICS_smoke.json");
        println!("wrote SERVE_TRACE_smoke.json and SERVE_METRICS_smoke.json");
        obs::reset_all();
    }
}
