//! A-ws ablation: software work-stealing runtime (the Cilk-1 emulation
//! backend) — throughput and scaling on fib / BFS / N-Queens. Each program
//! is one `CompileSession`; every worker-count configuration reuses its
//! cached explicit module.

use bombyx::lower::{CompileOptions, CompileSession};
use bombyx::util::bench::{banner, bench, throughput};
use bombyx::workloads::{bfs, fib, graphgen, nqueens};
use bombyx::ws::{self, WsConfig};

fn main() {
    banner(
        "ws_throughput",
        "Cilk-1 emulation layer: task throughput on the multithreaded WS runtime.",
    );

    // fib(25): ~485k tasks.
    let session = CompileSession::new("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let mut tasks_run = 0u64;
    for workers in [1usize, 2, 4, 8] {
        let cfg = WsConfig { workers, steal_tries: 4 };
        let stats = bench(&format!("ws fib(25) workers={workers}"), 5, || {
            let (v, _, s) = session
                .run_ws(
                    session.shared_memory(),
                    "fib",
                    &[bombyx::ir::Value::I64(25)],
                    &cfg,
                    Box::new(ws::NoXlaSink),
                )
                .unwrap();
            assert_eq!(v.as_i64(), 75_025);
            tasks_run = s.tasks_run;
            s.tasks_run
        });
        throughput(&format!("ws fib(25) workers={workers}"), &stats, tasks_run, "tasks");
    }

    // BFS D=7 tree.
    let sb = CompileSession::new("bfs", bfs::BFS_SRC, &CompileOptions::no_dae()).unwrap();
    let g = graphgen::paper_tree_small();
    let cfg = WsConfig { workers: 8, steal_tries: 4 };
    let stats = bench("ws bfs(B=4,D=7) workers=8", 5, || {
        let mut mem = sb.shared_memory();
        mem.fill_i64(sb.explicit().global_by_name("adj_off").unwrap(), &g.adj_off);
        mem.fill_i64(sb.explicit().global_by_name("adj_edges").unwrap(), &g.adj_edges);
        mem.resize(sb.explicit().global_by_name("visited").unwrap(), g.nodes());
        sb.run_ws(mem, "visit", &[bombyx::ir::Value::I64(0)], &cfg, Box::new(ws::NoXlaSink))
            .unwrap()
            .2
            .tasks_run
    });
    throughput("ws bfs(B=4,D=7)", &stats, 2 * g.nodes() as u64, "tasks");

    // N-Queens 8.
    let sq = CompileSession::new("nq", nqueens::NQUEENS_SRC, &CompileOptions::no_dae()).unwrap();
    let stats = bench("ws nqueens(8) workers=8", 5, || {
        let args: Vec<bombyx::ir::Value> =
            [8i64, 0, 0, 0, 0].iter().map(|&v| bombyx::ir::Value::I64(v)).collect();
        let (_, mem, s) = sq
            .run_ws(sq.shared_memory(), "place", &args, &cfg, Box::new(ws::NoXlaSink))
            .unwrap();
        let sols = mem.dump_i64(sq.explicit().global_by_name("solutions").unwrap())[0];
        assert_eq!(sols, 92);
        s.tasks_run
    });
    throughput("ws nqueens(8)", &stats, 4000, "tasks");
}
