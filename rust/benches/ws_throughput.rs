//! A-ws ablation: software work-stealing runtime (the Cilk-1 emulation
//! backend) — throughput and scaling on fib / BFS / N-Queens.

use bombyx::lower::{compile, CompileOptions};
use bombyx::util::bench::{banner, bench, throughput};
use bombyx::workloads::{bfs, fib, graphgen, nqueens};
use bombyx::ws::{self, SharedMemory, WsConfig};

fn main() {
    banner(
        "ws_throughput",
        "Cilk-1 emulation layer: task throughput on the multithreaded WS runtime.",
    );

    // fib(25): ~485k tasks.
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let mut tasks_run = 0u64;
    for workers in [1usize, 2, 4, 8] {
        let cfg = WsConfig { workers, steal_tries: 4 };
        let stats = bench(&format!("ws fib(25) workers={workers}"), 5, || {
            let mem = SharedMemory::new(&r.explicit);
            let (v, _, s) = ws::run(
                &r.explicit,
                mem,
                "fib",
                &[bombyx::ir::Value::I64(25)],
                &cfg,
                Box::new(ws::NoXlaSink),
            )
            .unwrap();
            assert_eq!(v.as_i64(), 75_025);
            tasks_run = s.tasks_run;
            s.tasks_run
        });
        throughput(&format!("ws fib(25) workers={workers}"), &stats, tasks_run, "tasks");
    }

    // BFS D=7 tree.
    let rb = compile("bfs", bfs::BFS_SRC, &CompileOptions::no_dae()).unwrap();
    let g = graphgen::paper_tree_small();
    let cfg = WsConfig { workers: 8, steal_tries: 4 };
    let stats = bench("ws bfs(B=4,D=7) workers=8", 5, || {
        let mut mem = SharedMemory::new(&rb.explicit);
        mem.fill_i64(rb.explicit.global_by_name("adj_off").unwrap(), &g.adj_off);
        mem.fill_i64(rb.explicit.global_by_name("adj_edges").unwrap(), &g.adj_edges);
        mem.resize(rb.explicit.global_by_name("visited").unwrap(), g.nodes());
        ws::run(
            &rb.explicit,
            mem,
            "visit",
            &[bombyx::ir::Value::I64(0)],
            &cfg,
            Box::new(ws::NoXlaSink),
        )
        .unwrap()
        .2
        .tasks_run
    });
    throughput("ws bfs(B=4,D=7)", &stats, 2 * g.nodes() as u64, "tasks");

    // N-Queens 8.
    let rq = compile("nq", nqueens::NQUEENS_SRC, &CompileOptions::no_dae()).unwrap();
    let stats = bench("ws nqueens(8) workers=8", 5, || {
        let mem = SharedMemory::new(&rq.explicit);
        let args: Vec<bombyx::ir::Value> =
            [8i64, 0, 0, 0, 0].iter().map(|&v| bombyx::ir::Value::I64(v)).collect();
        let (_, mem, s) =
            ws::run(&rq.explicit, mem, "place", &args, &cfg, Box::new(ws::NoXlaSink)).unwrap();
        let sols = mem.dump_i64(rq.explicit.global_by_name("solutions").unwrap())[0];
        assert_eq!(sols, 92);
        s.tasks_run
    });
    throughput("ws nqueens(8)", &stats, 4000, "tasks");
}
