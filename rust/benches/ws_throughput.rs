//! A-ws ablation: the software execution stack after the kernel rework.
//!
//! Six sections, emitted to `BENCH_ws.json` (machine-readable, same
//! convention as `BENCH_compile.json` — the committed file is pinned by
//! one run in a toolchain environment):
//!
//! 1. **kernel-vs-tree**: single-worker explicit execution on the
//!    compiled register bytecode vs a frozen copy of the pre-kernel
//!    tree-walking executor (kept below), on fib and N-Queens — the
//!    headline speedup of the kernel layer. Pinned to the interpreter
//!    tier (the jit gets its own section).
//! 2. **ws scaling**: work-stealing throughput and efficiency at 1/2/4
//!    workers on fib (lock-free deques + backoff); steal counts and
//!    live-closure peaks.
//! 3. **fused dispatch**: superinstruction fusion on vs off over the
//!    same direct-threaded loop — dispatches retired, static
//!    fused_ratio, single-worker fib speedup. Asserts `fused_ratio > 0`
//!    on fib (the CI bench-smoke fusion gate).
//! 4. **multi-job steady state**: interleaved mixed-corpus jobs flooded
//!    through one resident executor (`coordinator::WsServeExperiment`) —
//!    jobs/s throughput plus p50/p95/p99 submission-to-completion
//!    latency, every job verified against its reference.
//! 5. **fault injection**: the same flood under the seeded chaos plan
//!    (injected panics, transients, delays) with retry enabled — every
//!    non-shed job must still verify; reports degraded throughput as a
//!    fraction of the clean flood's.
//! 6. **jit**: the native tier (forced, threshold 0) vs the pinned
//!    interpreter on fib and N-Queens — wall-clock and retired-dispatch
//!    throughput speedups plus per-kernel compile time and code size.
//!    Asserts the jit retires fib dispatches at ≥2x the interpreter's
//!    rate wherever native codegen is available; on other targets the
//!    section records `available: false` and the disabled reason.
//!
//! `BOMBYX_BENCH_SMOKE=1` switches to reduced iterations/sizes (the CI
//! bench-smoke step) and arms the telemetry layer for the measured
//! flood, emitting `TRACE_smoke.json` / `METRICS_smoke.json` — the
//! observability artifacts CI schema-validates via `obs_tests`.

use std::collections::VecDeque;
use std::sync::Arc;

use bombyx::coordinator::WsServeExperiment;
use bombyx::exec::jit::{self, JitConfig};
use bombyx::exec::{compile_module_with, KernelMode};
use bombyx::interp::explicit_exec::ExplicitExec;
use bombyx::interp::{Memory, NoXla};
use bombyx::ir::cfg::{FuncId, FuncKind, Module, Op, RetTarget, Term};
use bombyx::ir::expr::{self, Value, VarId};
use bombyx::lower::{CompileOptions, CompileSession};
use bombyx::util::bench::{banner, bench, throughput};
use bombyx::util::json::Json;
use bombyx::workloads::{fib, nqueens};
use bombyx::ws::{self, WsConfig};

/// Frozen pre-kernel baseline: the tree-walking single-threaded explicit
/// machine as it existed before the `exec` layer (re-walks `Expr` trees
/// via `expr::eval` on every op, allocates arg vectors per spawn). Kept
/// here, not in src/, purely as the differential baseline.
mod tree_baseline {
    use super::*;

    #[derive(Clone, Copy)]
    pub enum TCont {
        Root,
        Slot { clos: usize, slot: u32 },
        Counter { clos: usize },
    }

    pub struct TClosure {
        task: FuncId,
        slots: Vec<Value>,
        cont: TCont,
        counter: u32,
        freed: bool,
    }

    pub struct TreeExec<'m> {
        pub module: &'m Module,
        pub memory: Memory,
        pub tasks_run: u64,
        closures: Vec<TClosure>,
        ready: VecDeque<(FuncId, Vec<Value>, TCont)>,
        result: Option<Value>,
    }

    impl<'m> TreeExec<'m> {
        pub fn new(module: &'m Module, memory: Memory) -> Self {
            TreeExec {
                module,
                memory,
                tasks_run: 0,
                closures: Vec::new(),
                ready: VecDeque::new(),
                result: None,
            }
        }

        pub fn run(&mut self, name: &str, args: &[Value]) -> Value {
            let fid = self.module.func_by_name(name).expect("entry task");
            self.ready.push_back((fid, args.to_vec(), TCont::Root));
            while let Some((task, args, cont)) = self.ready.pop_back() {
                self.run_task(task, args, cont);
            }
            self.result.take().expect("root result")
        }

        fn deliver(&mut self, cont: TCont, value: Value) {
            match cont {
                TCont::Root => self.result = Some(value),
                TCont::Slot { clos, slot } => {
                    let c = &mut self.closures[clos];
                    let ty = self.module.funcs[c.task].vars[VarId::new(slot as usize)].ty;
                    c.slots[slot as usize] = value.coerce(ty);
                    c.counter -= 1;
                    self.fire_if_ready(clos);
                }
                TCont::Counter { clos } => {
                    self.closures[clos].counter -= 1;
                    self.fire_if_ready(clos);
                }
            }
        }

        fn fire_if_ready(&mut self, clos: usize) {
            let c = &mut self.closures[clos];
            if c.counter == 0 && !c.freed {
                c.freed = true;
                let inst = (c.task, c.slots.clone(), c.cont);
                self.ready.push_back(inst);
            }
        }

        fn run_task(&mut self, task: FuncId, args: Vec<Value>, cont: TCont) {
            self.tasks_run += 1;
            let func = &self.module.funcs[task];
            if func.kind == FuncKind::Leaf {
                let out = self.eval_leaf(task, &args);
                self.deliver(cont, out);
                return;
            }
            assert!(func.kind == FuncKind::Task, "baseline has no xla support");
            let cfg = func.cfg();
            let mut env: Vec<Value> =
                func.vars.values().map(|v| Value::zero_of(v.ty)).collect();
            for (i, a) in args.iter().enumerate() {
                env[i] = a.coerce(func.vars[VarId::new(i)].ty);
            }
            let mut block = cfg.entry;
            loop {
                let b = &cfg.blocks[block];
                for op in &b.ops {
                    match op {
                        Op::Assign { dst, src } => {
                            let v = expr::eval(src, &|v| env[v.index()]);
                            env[dst.index()] = v.coerce(func.vars[*dst].ty);
                        }
                        Op::Load { dst, arr, index, .. } => {
                            let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                            env[dst.index()] = self.memory.load(*arr, idx).unwrap();
                        }
                        Op::Store { arr, index, value } => {
                            let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                            let val = expr::eval(value, &|v| env[v.index()]);
                            self.memory.store(*arr, idx, val).unwrap();
                        }
                        Op::AtomicAdd { arr, index, value } => {
                            let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                            let val = expr::eval(value, &|v| env[v.index()]);
                            self.memory.atomic_add(*arr, idx, val).unwrap();
                        }
                        Op::Call { dst, callee, args } => {
                            let vals: Vec<Value> = args
                                .iter()
                                .map(|a| expr::eval(a, &|v| env[v.index()]))
                                .collect();
                            let r = self.eval_leaf(*callee, &vals);
                            if let Some(d) = dst {
                                env[d.index()] = r.coerce(func.vars[*d].ty);
                            }
                        }
                        Op::MakeClosure { dst, task } => {
                            let t = &self.module.funcs[*task];
                            let c = TClosure {
                                task: *task,
                                slots: t
                                    .param_ids()
                                    .map(|p| Value::zero_of(t.vars[p].ty))
                                    .collect(),
                                cont,
                                counter: 1,
                                freed: false,
                            };
                            self.closures.push(c);
                            env[dst.index()] = Value::I64(self.closures.len() as i64 - 1);
                        }
                        Op::ClosureStore { clos, field, value } => {
                            let h = env[clos.index()].as_i64() as usize;
                            let val = expr::eval(value, &|v| env[v.index()]);
                            let c = &mut self.closures[h];
                            let ty = self.module.funcs[c.task].vars
                                [VarId::new(*field as usize)]
                            .ty;
                            c.slots[*field as usize] = val.coerce(ty);
                        }
                        Op::SpawnChild { callee, args, ret } => {
                            let vals: Vec<Value> = args
                                .iter()
                                .map(|a| expr::eval(a, &|v| env[v.index()]))
                                .collect();
                            let child_cont = match ret {
                                RetTarget::Slot { clos, field } => {
                                    let h = env[clos.index()].as_i64() as usize;
                                    self.closures[h].counter += 1;
                                    TCont::Slot { clos: h, slot: *field }
                                }
                                RetTarget::Counter { clos } => {
                                    let h = env[clos.index()].as_i64() as usize;
                                    self.closures[h].counter += 1;
                                    TCont::Counter { clos: h }
                                }
                                RetTarget::Forward => cont,
                            };
                            self.ready.push_back((*callee, vals, child_cont));
                        }
                        Op::CloseSpawns { clos } => {
                            let h = env[clos.index()].as_i64() as usize;
                            self.closures[h].counter -= 1;
                            self.fire_if_ready(h);
                        }
                        Op::SendArgument { value } => {
                            let v = match value {
                                Some(e) => {
                                    expr::eval(e, &|v| env[v.index()]).coerce(func.ret)
                                }
                                None => Value::Unit,
                            };
                            self.deliver(cont, v);
                        }
                        other => panic!("baseline: unexpected op {other:?}"),
                    }
                }
                match &b.term {
                    Term::Jump(next) => block = *next,
                    Term::Branch { cond, then_, else_ } => {
                        let c = expr::eval(cond, &|v| env[v.index()]).as_bool();
                        block = if c { *then_ } else { *else_ };
                    }
                    Term::Halt => return,
                    other => panic!("baseline: terminator {other:?}"),
                }
            }
        }

        fn eval_leaf(&mut self, fid: FuncId, args: &[Value]) -> Value {
            let func = &self.module.funcs[fid];
            let cfg = func.cfg();
            let mut env: Vec<Value> =
                func.vars.values().map(|v| Value::zero_of(v.ty)).collect();
            for (i, a) in args.iter().enumerate() {
                env[i] = a.coerce(func.vars[VarId::new(i)].ty);
            }
            let mut block = cfg.entry;
            loop {
                let b = &cfg.blocks[block];
                for op in &b.ops {
                    match op {
                        Op::Assign { dst, src } => {
                            let v = expr::eval(src, &|v| env[v.index()]);
                            env[dst.index()] = v.coerce(func.vars[*dst].ty);
                        }
                        Op::Load { dst, arr, index, .. } => {
                            let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                            env[dst.index()] = self.memory.load(*arr, idx).unwrap();
                        }
                        Op::Store { arr, index, value } => {
                            let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                            let val = expr::eval(value, &|v| env[v.index()]);
                            self.memory.store(*arr, idx, val).unwrap();
                        }
                        Op::AtomicAdd { arr, index, value } => {
                            let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                            let val = expr::eval(value, &|v| env[v.index()]);
                            self.memory.atomic_add(*arr, idx, val).unwrap();
                        }
                        Op::Call { dst, callee, args } => {
                            let vals: Vec<Value> = args
                                .iter()
                                .map(|a| expr::eval(a, &|v| env[v.index()]))
                                .collect();
                            let r = self.eval_leaf(*callee, &vals);
                            if let Some(d) = dst {
                                env[d.index()] = r.coerce(func.vars[*d].ty);
                            }
                        }
                        other => panic!("baseline leaf: op {other:?}"),
                    }
                }
                match &b.term {
                    Term::Jump(next) => block = *next,
                    Term::Branch { cond, then_, else_ } => {
                        let c = expr::eval(cond, &|v| env[v.index()]).as_bool();
                        block = if c { *then_ } else { *else_ };
                    }
                    Term::Return(value) => {
                        return match value {
                            Some(e) => {
                                expr::eval(e, &|v| env[v.index()]).coerce(func.ret)
                            }
                            None => Value::Unit,
                        };
                    }
                    other => panic!("baseline leaf: terminator {other:?}"),
                }
            }
        }
    }
}

fn main() {
    let smoke = std::env::var("BOMBYX_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let samples = if smoke { 2 } else { 5 };
    banner(
        "ws_throughput",
        "Execution stack: kernel-vs-tree single-worker speedup, WS scaling, footprint.",
    );
    if smoke {
        println!("(smoke mode: reduced iterations and sizes)");
    }

    // ---- section 1: kernel vs tree, single-threaded ------------------------
    let fib_n: i64 = if smoke { 18 } else { 22 };
    let fib_expect = fib::fib_ref(fib_n as u64) as i64;
    let nq_n: i64 = if smoke { 6 } else { 7 };
    let nq_expect = nqueens::nqueens_ref(nq_n as usize) as i64;

    let sf = CompileSession::new("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let sq = CompileSession::new("nq", nqueens::NQUEENS_SRC, &CompileOptions::no_dae()).unwrap();
    let fib_kernels = sf.explicit_kernels().unwrap();
    let nq_kernels = sq.explicit_kernels().unwrap();
    let nq_args: Vec<Value> =
        [nq_n, 0, 0, 0, 0].iter().map(|&v| Value::I64(v)).collect();

    let mut tree_tasks = 0u64;
    let tree_fib = bench(&format!("tree  fib({fib_n}) 1-thread"), samples, || {
        let mut ex = tree_baseline::TreeExec::new(sf.explicit(), sf.memory());
        let v = ex.run("fib", &[Value::I64(fib_n)]);
        assert_eq!(v.as_i64(), fib_expect);
        tree_tasks = ex.tasks_run;
        ex.tasks_run
    });
    let mut kernel_tasks = 0u64;
    let kernel_fib = bench(&format!("kernel fib({fib_n}) 1-thread"), samples, || {
        let mut ex = ExplicitExec::with_kernels(
            sf.explicit(),
            sf.memory(),
            NoXla,
            std::sync::Arc::clone(&fib_kernels),
        );
        ex.set_jit(JitConfig::disabled()); // this section measures the interpreter tier
        let v = ex.run("fib", &[Value::I64(fib_n)]).unwrap();
        assert_eq!(v.as_i64(), fib_expect);
        kernel_tasks = ex.stats.tasks_run;
        ex.stats.tasks_run
    });
    assert_eq!(tree_tasks, kernel_tasks, "same task graph on both executors");
    throughput(&format!("kernel fib({fib_n})"), &kernel_fib, kernel_tasks, "tasks");
    let fib_speedup =
        tree_fib.median.as_secs_f64() / kernel_fib.median.as_secs_f64().max(1e-12);
    println!("kernel-vs-tree speedup on fib({fib_n}): {fib_speedup:.2}x");

    let tree_nq = bench(&format!("tree  nqueens({nq_n}) 1-thread"), samples, || {
        let mut ex = tree_baseline::TreeExec::new(sq.explicit(), sq.memory());
        ex.run("place", &nq_args);
        let sols = ex.memory.dump_i64(sq.explicit().global_by_name("solutions").unwrap())[0];
        assert_eq!(sols, nq_expect);
        ex.tasks_run
    });
    let kernel_nq = bench(&format!("kernel nqueens({nq_n}) 1-thread"), samples, || {
        let mut ex = ExplicitExec::with_kernels(
            sq.explicit(),
            sq.memory(),
            NoXla,
            std::sync::Arc::clone(&nq_kernels),
        );
        ex.set_jit(JitConfig::disabled());
        ex.run("place", &nq_args).unwrap();
        let sols = ex.memory.dump_i64(sq.explicit().global_by_name("solutions").unwrap())[0];
        assert_eq!(sols, nq_expect);
        ex.stats.tasks_run
    });
    let nq_speedup = tree_nq.median.as_secs_f64() / kernel_nq.median.as_secs_f64().max(1e-12);
    println!("kernel-vs-tree speedup on nqueens({nq_n}): {nq_speedup:.2}x");

    // ---- section 2: ws scaling at 1/2/4 workers ----------------------------
    let ws_n: i64 = if smoke { 19 } else { 23 };
    let ws_expect = fib::fib_ref(ws_n as u64) as i64;
    let mut scaling = Vec::new(); // (workers, median_s, tasks, steals, peak)
    for workers in [1usize, 2, 4] {
        let cfg = WsConfig { workers, steal_tries: 4 };
        let mut tasks = 0u64;
        let mut steals = 0u64;
        let mut peak = 0u64;
        let stats = bench(&format!("ws fib({ws_n}) workers={workers}"), samples, || {
            let (v, _, s) = sf
                .run_ws(
                    sf.shared_memory(),
                    "fib",
                    &[Value::I64(ws_n)],
                    &cfg,
                    Box::new(ws::NoXlaSink),
                )
                .unwrap();
            assert_eq!(v.as_i64(), ws_expect);
            tasks = s.tasks_run;
            steals = s.steals;
            peak = s.max_live_closures;
            s.tasks_run
        });
        throughput(&format!("ws fib({ws_n}) workers={workers}"), &stats, tasks, "tasks");
        scaling.push((workers, stats.median.as_secs_f64(), tasks, steals, peak));
    }
    let t1 = scaling[0].1;
    for &(workers, tn, _, _, _) in &scaling {
        let eff = t1 / (workers as f64 * tn.max(1e-12));
        println!("ws scaling efficiency at {workers} worker(s): {:.0}%", eff * 100.0);
    }

    // ---- section 3: fused vs unfused dispatch ------------------------------
    // Same direct-threaded loop, same task graph; only the
    // superinstruction fusion stage differs. `fused_ratio > 0` on fib is
    // the CI bench-smoke gate that fusion actually fires.
    let fd_n: i64 = if smoke { 18 } else { 22 };
    let fd_expect = fib::fib_ref(fd_n as u64) as i64;
    let fused_prog =
        Arc::new(compile_module_with(sf.explicit(), KernelMode::Explicit, true).unwrap());
    let unfused_prog =
        Arc::new(compile_module_with(sf.explicit(), KernelMode::Explicit, false).unwrap());
    let fused_ratio = fused_prog.fused_ratio();
    assert!(fused_ratio > 0.0, "superinstruction fusion must fire on fib");
    let (pairs, before) = fused_prog.fusion();
    println!(
        "fib kernels: {} fused pairs over {} instrs (fused_ratio {fused_ratio:.3})",
        pairs, before
    );
    let mut fused_retired = 0u64;
    let fused_run = bench(&format!("fused   fib({fd_n}) 1-thread"), samples, || {
        let mut ex = ExplicitExec::with_kernels(
            sf.explicit(),
            sf.memory(),
            NoXla,
            Arc::clone(&fused_prog),
        );
        // `stats.instrs` counts interpreter-retired dispatches, so both
        // sides of this differential must stay on the cold tier.
        ex.set_jit(JitConfig::disabled());
        let v = ex.run("fib", &[Value::I64(fd_n)]).unwrap();
        assert_eq!(v.as_i64(), fd_expect);
        fused_retired = ex.stats.instrs;
        ex.stats.instrs
    });
    let mut unfused_retired = 0u64;
    let unfused_run = bench(&format!("unfused fib({fd_n}) 1-thread"), samples, || {
        let mut ex = ExplicitExec::with_kernels(
            sf.explicit(),
            sf.memory(),
            NoXla,
            Arc::clone(&unfused_prog),
        );
        ex.set_jit(JitConfig::disabled());
        let v = ex.run("fib", &[Value::I64(fd_n)]).unwrap();
        assert_eq!(v.as_i64(), fd_expect);
        unfused_retired = ex.stats.instrs;
        ex.stats.instrs
    });
    assert!(
        fused_retired < unfused_retired,
        "fusion must shrink retired dispatches: {fused_retired} vs {unfused_retired}"
    );
    let dispatch_speedup =
        unfused_run.median.as_secs_f64() / fused_run.median.as_secs_f64().max(1e-12);
    println!(
        "fused-vs-unfused on fib({fd_n}): {dispatch_speedup:.2}x, retired {} vs {}",
        fused_retired, unfused_retired
    );

    // ---- section 4: multi-job steady state ---------------------------------
    // One resident executor serving interleaved mixed-corpus jobs: a
    // warmup wave to fault in every session's kernels, then the measured
    // flood. Every job's root result and final memory are verified.
    let serve = WsServeExperiment::new().unwrap();
    let flood_workers = 4usize;
    let (flood_jobs, flood_repeat) = if smoke { (10usize, 1usize) } else { (64, 3) };
    serve.flood(flood_workers, serve.corpus_len(), 1).unwrap(); // warmup
    // Smoke mode doubles as the CI observability gate: the measured
    // flood runs with the telemetry layer armed and its trace + metrics
    // exports land next to BENCH_ws.json for schema validation
    // (`obs_tests`, `BOMBYX_OBS_ARTIFACTS`).
    if smoke {
        bombyx::obs::set_trace(true);
        bombyx::obs::set_metrics(true);
    }
    let flood = serve.flood(flood_workers, flood_jobs, flood_repeat).unwrap();
    assert_eq!(flood.verified, flood.jobs, "every flooded job must verify");
    if smoke {
        let events = bombyx::obs::trace::drain();
        let trace_doc = bombyx::obs::trace::export_json(&events);
        std::fs::write("TRACE_smoke.json", trace_doc.pretty() + "\n")
            .expect("write TRACE_smoke.json");
        std::fs::write("METRICS_smoke.json", bombyx::obs::metrics::export_json().pretty() + "\n")
            .expect("write METRICS_smoke.json");
        bombyx::obs::set_trace(false);
        bombyx::obs::set_metrics(false);
        println!("wrote TRACE_smoke.json ({} events) + METRICS_smoke.json", events.len());
    }
    println!(
        "multi-job: {} jobs on {} workers, {:.1} jobs/s, corpus [{}]",
        flood.jobs,
        flood.workers,
        flood.jobs_per_s,
        serve.corpus_names().join(", ")
    );
    println!(
        "multi-job latency: p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms",
        flood.p50.as_secs_f64() * 1e3,
        flood.p95.as_secs_f64() * 1e3,
        flood.p99.as_secs_f64() * 1e3
    );

    // ---- section 5: fault injection ----------------------------------------
    // Same load, fixed chaos seed: the executor must absorb injected
    // panics, transients and delays (retrying transparently) and every
    // job that was not shed must still verify against its reference —
    // the throughput cost of containment is the measurement.
    let chaos_seed = 42u64;
    let chaos = serve.flood_chaos(flood_workers, flood_jobs, flood_repeat, chaos_seed).unwrap();
    for (i, outcome) in chaos.outcomes.iter().enumerate() {
        assert!(
            outcome.is_none() || outcome.as_deref() == Some("shed"),
            "chaos job {i}: every non-shed job must verify, got {outcome:?}"
        );
    }
    let retained = chaos.jobs_per_s / flood.jobs_per_s.max(1e-12);
    println!(
        "fault injection (seed {chaos_seed}): {} of {} verified, {} retried, {} shed, \
         {:.1} jobs/s ({:.0}% of clean)",
        chaos.verified,
        chaos.jobs,
        chaos.stats.jobs_retried,
        chaos.stats.jobs_shed,
        chaos.jobs_per_s,
        retained * 100.0
    );

    // ---- section 6: native jit tier ----------------------------------------
    // Forced tier (threshold 0, native from the first dispatch) vs the
    // pinned interpreter on the same kernel programs. Retired-dispatch
    // throughput divides the interpreter run's dispatch count by each
    // tier's wall time: the task graph is identical on both sides, the
    // jit just retires the same dispatches as native code.
    let mut jd = Json::object();
    match jit::available() {
        Err(reason) => {
            println!("jit: native codegen unavailable here ({reason}); section skipped");
            jd.set("available", false).set("disabled_reason", reason);
        }
        Ok(()) => {
            jd.set("available", true);
            let jn: i64 = if smoke { 18 } else { 22 };
            let jn_expect = fib::fib_ref(jn as u64) as i64;
            // Hold tiers over both programs so the per-kernel compile
            // stats survive the short-lived engines below.
            let _pin_f = jit::tier_with(&fib_kernels, JitConfig::forced(0));
            let _pin_q = jit::tier_with(&nq_kernels, JitConfig::forced(0));

            let mut interp_retired = 0u64;
            let interp_fib = bench(&format!("interp fib({jn}) 1-thread"), samples, || {
                let mut ex = ExplicitExec::with_kernels(
                    sf.explicit(),
                    sf.memory(),
                    NoXla,
                    Arc::clone(&fib_kernels),
                );
                ex.set_jit(JitConfig::disabled());
                let v = ex.run("fib", &[Value::I64(jn)]).unwrap();
                assert_eq!(v.as_i64(), jn_expect);
                interp_retired = ex.stats.instrs;
                ex.stats.instrs
            });
            let jit_fib = bench(&format!("jit    fib({jn}) 1-thread"), samples, || {
                let mut ex = ExplicitExec::with_kernels(
                    sf.explicit(),
                    sf.memory(),
                    NoXla,
                    Arc::clone(&fib_kernels),
                );
                ex.set_jit(JitConfig::forced(0));
                let v = ex.run("fib", &[Value::I64(jn)]).unwrap();
                assert_eq!(v.as_i64(), jn_expect);
                ex.stats.tasks_run
            });
            let interp_s = interp_fib.median.as_secs_f64().max(1e-12);
            let jit_s = jit_fib.median.as_secs_f64().max(1e-12);
            let jit_fib_speedup = interp_s / jit_s;
            let interp_tput = interp_retired as f64 / interp_s;
            let jit_tput = interp_retired as f64 / jit_s;
            println!(
                "jit-vs-interp on fib({jn}): {jit_fib_speedup:.2}x \
                 ({:.2} vs {:.2} Mdispatch/s over {} retired)",
                jit_tput / 1e6,
                interp_tput / 1e6,
                interp_retired
            );
            assert!(
                jit_tput >= 2.0 * interp_tput,
                "jit must retire fib dispatches at >=2x the interpreter: \
                 {jit_tput:.0}/s vs {interp_tput:.0}/s"
            );

            let jit_nq = bench(&format!("jit    nqueens({nq_n}) 1-thread"), samples, || {
                let mut ex = ExplicitExec::with_kernels(
                    sq.explicit(),
                    sq.memory(),
                    NoXla,
                    Arc::clone(&nq_kernels),
                );
                ex.set_jit(JitConfig::forced(0));
                ex.run("place", &nq_args).unwrap();
                let sols =
                    ex.memory.dump_i64(sq.explicit().global_by_name("solutions").unwrap())[0];
                assert_eq!(sols, nq_expect);
                ex.stats.tasks_run
            });
            // Section 1's pinned kernel run is the interpreter baseline.
            let jit_nq_speedup =
                kernel_nq.median.as_secs_f64() / jit_nq.median.as_secs_f64().max(1e-12);
            println!("jit-vs-interp on nqueens({nq_n}): {jit_nq_speedup:.2}x");

            let mut kernel_rows = Vec::new();
            for (prog, kernels) in [("fib", &fib_kernels), ("nqueens", &nq_kernels)] {
                for s in jit::stats_for(kernels) {
                    if s.code_bytes == 0 && s.uncompilable.is_none() {
                        continue; // never promoted (e.g. dead kernels)
                    }
                    println!(
                        "jit kernel {prog}/{}: compile {:.3} ms, {} bytes, \
                         {} entries, {} bails",
                        s.name, s.compile_ms, s.code_bytes, s.entries, s.bails
                    );
                    let mut row = Json::object();
                    row.set("program", prog)
                        .set("kernel", s.name.as_str())
                        .set("compile_ms", s.compile_ms)
                        .set("code_bytes", s.code_bytes)
                        .set("entries", s.entries as i64)
                        .set("bails", s.bails as i64);
                    if let Some(u) = s.uncompilable {
                        row.set("uncompilable", u);
                    }
                    kernel_rows.push(row);
                }
            }

            let mut jfib = Json::object();
            jfib.set("n", jn)
                .set("interp_ms", interp_s * 1e3)
                .set("jit_ms", jit_s * 1e3)
                .set("retired_dispatches", interp_retired as i64)
                .set("interp_dispatch_per_s", interp_tput)
                .set("jit_dispatch_per_s", jit_tput)
                .set("speedup", jit_fib_speedup);
            let mut jnq = Json::object();
            jnq.set("n", nq_n)
                .set("interp_ms", kernel_nq.median.as_secs_f64() * 1e3)
                .set("jit_ms", jit_nq.median.as_secs_f64() * 1e3)
                .set("speedup", jit_nq_speedup);
            jd.set("fib", jfib).set("nqueens", jnq).set("kernels", Json::Array(kernel_rows));
        }
    }

    // ---- machine-readable output -------------------------------------------
    let mut kvt = Json::object();
    let mut kvt_fib = Json::object();
    kvt_fib
        .set("n", fib_n)
        .set("tree_ms", tree_fib.median.as_secs_f64() * 1e3)
        .set("kernel_ms", kernel_fib.median.as_secs_f64() * 1e3)
        .set("speedup", fib_speedup)
        .set("tasks", kernel_tasks as i64);
    let mut kvt_nq = Json::object();
    kvt_nq
        .set("n", nq_n)
        .set("tree_ms", tree_nq.median.as_secs_f64() * 1e3)
        .set("kernel_ms", kernel_nq.median.as_secs_f64() * 1e3)
        .set("speedup", nq_speedup);
    kvt.set("fib", kvt_fib).set("nqueens", kvt_nq);

    let mut scale_json = Json::object();
    scale_json.set("fib_n", ws_n);
    let rows: Vec<Json> = scaling
        .iter()
        .map(|&(workers, secs, tasks, steals, peak)| {
            let mut row = Json::object();
            row.set("workers", workers)
                .set("median_ms", secs * 1e3)
                .set("tasks", tasks as i64)
                .set("tasks_per_s", tasks as f64 / secs.max(1e-12))
                .set("efficiency", t1 / (workers as f64 * secs.max(1e-12)))
                .set("steals", steals as i64)
                .set("max_live_closures", peak as i64);
            row
        })
        .collect();
    scale_json.set("workers", Json::Array(rows));

    let mut fd = Json::object();
    fd.set("fib_n", fd_n)
        .set("fused_ratio", fused_ratio)
        .set("fused_pairs", pairs as i64)
        .set("dispatches_retired_fused", fused_retired as i64)
        .set("dispatches_retired_unfused", unfused_retired as i64)
        .set("fused_ms", fused_run.median.as_secs_f64() * 1e3)
        .set("unfused_ms", unfused_run.median.as_secs_f64() * 1e3)
        .set("speedup", dispatch_speedup);

    let mut mj = Json::object();
    mj.set("workers", flood.workers)
        .set("jobs", flood.jobs)
        .set(
            "corpus",
            Json::Array(serve.corpus_names().iter().map(|&n| Json::from(n)).collect()),
        )
        .set("wall_ms", flood.wall.as_secs_f64() * 1e3)
        .set("jobs_per_s", flood.jobs_per_s)
        .set("p50_ms", flood.p50.as_secs_f64() * 1e3)
        .set("p95_ms", flood.p95.as_secs_f64() * 1e3)
        .set("p99_ms", flood.p99.as_secs_f64() * 1e3)
        .set("tasks_run", flood.stats.tasks_run as i64)
        .set("steals", flood.stats.steals as i64);

    let mut fi = Json::object();
    fi.set("seed", chaos_seed as i64)
        .set("workers", chaos.workers)
        .set("jobs", chaos.jobs)
        .set("verified", chaos.verified)
        .set("failed", chaos.failed)
        .set("jobs_retried", chaos.stats.jobs_retried as i64)
        .set("jobs_shed", chaos.stats.jobs_shed as i64)
        .set("workers_respawned", chaos.stats.workers_respawned as i64)
        .set("jobs_per_s", chaos.jobs_per_s)
        .set("p99_ms", chaos.p99.as_secs_f64() * 1e3)
        .set("throughput_retained", retained);
    let outcome_rows: Vec<Json> = chaos
        .outcome_breakdown()
        .into_iter()
        .map(|(tag, n)| {
            let mut row = Json::object();
            row.set("outcome", tag).set("jobs", n);
            row
        })
        .collect();
    fi.set("outcomes", Json::Array(outcome_rows));

    let mut root = Json::object();
    root.set("bench", "ws_throughput")
        .set("mode", if cfg!(debug_assertions) { "debug" } else { "release" })
        .set("smoke", smoke)
        .set("kernel_vs_tree", kvt)
        .set("ws_scaling", scale_json)
        .set("fused_dispatch", fd)
        .set("multi_job", mj)
        .set("fault_injection", fi)
        .set("jit", jd);
    let path = "BENCH_ws.json";
    std::fs::write(path, root.pretty() + "\n").expect("write BENCH_ws.json");
    println!("wrote {path}");
}
