//! A-xla ablation: batched XLA PE-datapath throughput vs batch size — the
//! batching amortization that plays the DAE role in the three-layer stack
//! (DESIGN.md §Hardware-Adaptation). Requires `make artifacts`.

use bombyx::ir::Value;
use bombyx::lower::{CompileOptions, CompileSession};
use bombyx::runtime::{RelaxXla, XlaRuntime};
use bombyx::sim::SimXla;
use bombyx::util::bench::{banner, bench, throughput};
use bombyx::workloads::relax;

fn main() {
    banner(
        "xla_batch",
        "Batched relax datapath (AOT Pallas/XLA) throughput vs batch size.",
    );
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let runtime = match XlaRuntime::load_dir(artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIPPED: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let session =
        CompileSession::new("relax", relax::RELAX_SRC, &CompileOptions::no_dae()).unwrap();
    let m = session.explicit();
    let mut xla = RelaxXla::new(runtime, m, 1).unwrap();

    let n_rows = 4096usize;
    for batch_size in [1usize, 8, 32, 64, 128, 256] {
        let mut mem = session.memory();
        let feats: Vec<f32> = (0..n_rows * relax::F).map(|i| (i % 13) as f32 * 0.07).collect();
        mem.fill_f32(m.global_by_name("feat").unwrap(), &feats);
        let stats = bench(&format!("relax batch={batch_size}"), 5, || {
            let mut done = 0usize;
            while done < n_rows {
                let take = batch_size.min(n_rows - done);
                let batch: Vec<Vec<Value>> =
                    (done..done + take).map(|n| vec![Value::I64(n as i64)]).collect();
                SimXla::exec_batch(&mut xla, "relax", &batch, &mut mem).unwrap();
                done += take;
            }
            done
        });
        throughput(&format!("relax batch={batch_size}"), &stats, n_rows as u64, "rows");
    }
    println!(
        "\n(Amortization story: per-dispatch overhead dominates at batch=1; the AOT\n executable reaches its roofline once batches fill the compiled tile.)"
    );
}
