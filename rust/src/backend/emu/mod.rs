//! Cilk-1 emulation backend (paper §II-B's second target).
//!
//! The paper lowers the explicit IR back onto the OpenCilk runtime by
//! implementing `spawn` / `spawn_next` / `send_argument` as library calls,
//! "to verify the equivalence of the original program in software once
//! compiled". Our equivalent: package the explicit module together with
//! entry metadata for the from-scratch work-stealing runtime
//! ([`crate::ws`]), and provide the one-call differential check used
//! throughout the test suite: oracle (implicit, sequential) vs emulation
//! (explicit, parallel).

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::interp::{oracle, Memory};
use crate::ir::cfg::{FuncKind, Module};
use crate::ir::expr::Value;
use crate::lower::CompileResult;
use crate::ws::{self, SharedMemory, WsConfig, XlaSink};

/// An executable emulation program: the explicit module plus its entry
/// points (every original task function is invocable). The module is a
/// shared handle into the compile session's cached explicit IR —
/// packaging never copies the module, and the execution kernels compile
/// once per program (lazily, shared across runs).
#[derive(Clone, Debug)]
pub struct EmuProgram {
    pub module: Arc<Module>,
    pub entries: Vec<String>,
    /// Kernel program for the WS runtime, compiled on first run.
    kernels: std::sync::OnceLock<Arc<crate::exec::KernelProgram>>,
}

/// Build the emulation program from a compile result.
pub fn package(result: &CompileResult) -> EmuProgram {
    let entries = result
        .explicit
        .funcs
        .values()
        .filter(|f| {
            f.task
                .as_ref()
                .map(|t| t.role == crate::ir::TaskRole::Entry || t.role == crate::ir::TaskRole::Access)
                .unwrap_or(false)
                || f.kind == FuncKind::Leaf
        })
        .map(|f| f.name.clone())
        .collect();
    EmuProgram {
        module: Arc::clone(&result.explicit),
        entries,
        kernels: std::sync::OnceLock::new(),
    }
}

impl EmuProgram {
    /// The program's compiled execution kernels, built on first request
    /// and shared across runs.
    pub fn kernels(&self) -> Result<Arc<crate::exec::KernelProgram>> {
        crate::exec::memo_kernels(&self.kernels, || {
            crate::exec::compile_module(&self.module, crate::exec::KernelMode::Explicit)
        })
    }

    /// Run on the WS runtime (kernels compiled once per program).
    pub fn run(
        &self,
        memory: SharedMemory,
        entry: &str,
        args: &[Value],
        config: &WsConfig,
        sink: Box<dyn XlaSink>,
    ) -> Result<(Value, SharedMemory, ws::WsStats)> {
        if !self.entries.iter().any(|e| e == entry) {
            return Err(anyhow!(
                "`{entry}` is not an entry task (available: {:?})",
                self.entries
            ));
        }
        ws::run_with_kernels(self.kernels()?, memory, entry, args, config, sink)
    }
}

/// Differential check: run `entry(args)` through the sequential oracle on
/// the implicit IR and through the WS runtime on the explicit IR; verify
/// result and final memory agree. Returns (value, oracle memory).
///
/// `init` seeds both memories identically.
pub fn check_equivalence(
    result: &CompileResult,
    entry: &str,
    args: &[Value],
    init: impl Fn(&Module, &mut Memory) -> Result<()>,
    workers: usize,
) -> Result<(Value, Memory)> {
    // Oracle on the pre-DAE implicit IR (the original program).
    let mut mem_o = Memory::new(&result.implicit);
    init(&result.implicit, &mut mem_o)?;
    let (v_oracle, mem_o) = oracle::run_oracle(&result.implicit, mem_o, entry, args)?;

    // Emulation on the explicit IR.
    let emu = package(result);
    let mut mem_seed = Memory::new(&emu.module);
    init(&emu.module, &mut mem_seed)?;
    let shared = shared_from(&emu.module, &mem_seed);
    let cfg = WsConfig { workers, steal_tries: 4 };
    let (v_emu, mem_e, _) =
        emu.run(shared, entry, args, &cfg, Box::new(ws::NoXlaSink))?;

    if v_oracle != v_emu && !(v_oracle == Value::Unit && v_emu == Value::Unit) {
        return Err(anyhow!("result mismatch: oracle={v_oracle:?} emu={v_emu:?}"));
    }
    // Compare memory images global-by-global.
    for (gid, g) in result.implicit.globals.iter() {
        let a = mem_o.dump_i64(gid);
        let egid = emu
            .module
            .global_by_name(&g.name)
            .ok_or_else(|| anyhow!("global `{}` lost in explicitization", g.name))?;
        let b = mem_e.dump_i64(egid);
        if a != b {
            return Err(anyhow!(
                "memory mismatch in `{}`: oracle {:?} vs emu {:?}",
                g.name,
                &a[..a.len().min(16)],
                &b[..b.len().min(16)]
            ));
        }
    }
    Ok((v_oracle, mem_o))
}

/// Copy a sequential memory image into a fresh SharedMemory.
pub fn shared_from(module: &Module, mem: &Memory) -> SharedMemory {
    let mut values = Vec::new();
    for (gid, g) in module.globals.iter() {
        let _ = g;
        let vals: Vec<Value> = match module.globals[gid].elem {
            crate::frontend::ast::Type::Float => {
                mem.dump_f32(gid).into_iter().map(Value::F32).collect()
            }
            _ => mem.dump_i64(gid).into_iter().map(Value::I64).collect(),
        };
        values.push(vals);
    }
    SharedMemory::from_values(module, values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::{compile, CompileOptions};

    #[test]
    fn fib_equivalence_oracle_vs_ws() {
        let r = compile(
            "t",
            "int fib(int n) {
                if (n < 2) return n;
                int x = cilk_spawn fib(n - 1);
                int y = cilk_spawn fib(n - 2);
                cilk_sync;
                return x + y;
            }",
            &CompileOptions::no_dae(),
        )
        .unwrap();
        let (v, _) = check_equivalence(&r, "fib", &[Value::I64(14)], |_, _| Ok(()), 4).unwrap();
        assert_eq!(v, Value::I64(377));
    }

    #[test]
    fn bfs_equivalence_with_and_without_dae() {
        let src = "global int adj_off[];
            global int adj_edges[];
            global int visited[];
            void visit(int n) {
                #pragma bombyx dae
                int off = adj_off[n];
                #pragma bombyx dae
                int end = adj_off[n + 1];
                visited[n] = 1;
                for (int i = off; i < end; i = i + 1) {
                    cilk_spawn visit(adj_edges[i]);
                }
                cilk_sync;
            }";
        for opts in [CompileOptions::no_dae(), CompileOptions::standard()] {
            let r = compile("t", src, &opts).unwrap();
            check_equivalence(
                &r,
                "visit",
                &[Value::I64(0)],
                |m, mem| {
                    mem.fill_i64(m.global_by_name("adj_off").unwrap(), &[0, 2, 4, 6, 6, 6, 6, 6]);
                    mem.fill_i64(m.global_by_name("adj_edges").unwrap(), &[1, 2, 3, 4, 5, 6]);
                    mem.resize(m.global_by_name("visited").unwrap(), 7);
                    Ok(())
                },
                4,
            )
            .unwrap();
        }
    }

    #[test]
    fn entry_check_rejects_continuations() {
        let r = compile(
            "t",
            "int f(int n) { int x = cilk_spawn f(n); cilk_sync; return x; }",
            &CompileOptions::no_dae(),
        )
        .unwrap();
        let emu = package(&r);
        assert!(emu.entries.contains(&"f".to_string()));
        assert!(!emu.entries.contains(&"f__k1".to_string()));
    }
}
