//! HardCilk JSON system descriptor (paper §II-B).
//!
//! "HardCilk requires a JSON configuration file serving as a descriptor for
//! the relations among tasks in the system. The JSON contains the size of
//! closures in the system, a list of which tasks a given task may spawn,
//! spawn_next, or send_argument to, and others." Bombyx derives all of it
//! by static analysis of the explicit IR.

use crate::ir::cfg::{FuncKind, Module};
use crate::ir::explicit::{closure_layout, explicit_tasks, task_relations};
use crate::util::json::Json;

/// Build the system descriptor for an explicit module.
pub fn system_descriptor(module: &Module, system_name: &str) -> Json {
    let mut doc = Json::object();
    doc.set("system", system_name);
    doc.set("generator", "bombyx");
    doc.set("closure_align_bits", crate::ir::explicit::MIN_CLOSURE_BITS as i64);

    let mut tasks = Vec::new();
    for fid in explicit_tasks(module) {
        let f = &module.funcs[fid];
        let meta = f.task.as_ref().unwrap();
        let layout = closure_layout(f);
        let rel = task_relations(module, fid);
        let mut t = Json::object();
        t.set("name", f.name.as_str());
        t.set("role", meta.role.name());
        t.set("source_function", meta.source.as_str());
        t.set("closure_bits", layout.padded_bits as i64);
        t.set("closure_payload_bits", layout.payload_bits as i64);
        t.set("is_xla_blackbox", f.kind == FuncKind::Xla);
        let params: Vec<Json> = layout
            .fields
            .iter()
            .map(|fld| {
                let mut p = Json::object();
                p.set("name", fld.name.as_str());
                p.set("type", fld.ty.name());
                p.set("offset_bits", fld.offset_bits as i64);
                p.set("width_bits", fld.width_bits as i64);
                p.clone()
            })
            .collect();
        t.set("params", params);
        t.set("cont_offset_bits", layout.cont_offset_bits as i64);
        t.set("join_counter_offset_bits", layout.counter_offset_bits as i64);
        let names = |ids: &[crate::ir::FuncId]| -> Vec<Json> {
            ids.iter().map(|&i| Json::from(module.funcs[i].name.as_str())).collect()
        };
        t.set("spawns", names(&rel.spawns));
        t.set("spawn_nexts", names(&rel.spawn_nexts));
        t.set("send_argument_to", names(&rel.sends_to));
        // Write-buffer side-band info (paper: "the write buffer requires
        // the HLS code to include extra information about the
        // argument/task being written").
        let mut wb = Json::object();
        wb.set("closure_bytes", (layout.padded_bits / 8) as i64);
        wb.set("max_spawn_args", f.params.min(8));
        t.set("write_buffer", wb.clone());
        tasks.push(t);
    }
    doc.set("tasks", tasks);

    let globals: Vec<Json> = module
        .globals
        .values()
        .map(|g| {
            let mut j = Json::object();
            j.set("name", g.name.as_str());
            j.set("elem", g.elem.name());
            match g.size {
                Some(s) => j.set("elems", s as i64),
                None => j.set("elems", Json::Null),
            };
            j.clone()
        })
        .collect();
    doc.set("memory", globals);
    doc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::{compile, CompileOptions};
    use crate::util::json;

    const FIB: &str = "int fib(int n) {
        if (n < 2) return n;
        int x = cilk_spawn fib(n - 1);
        int y = cilk_spawn fib(n - 2);
        cilk_sync;
        return x + y;
    }";

    #[test]
    fn fib_descriptor_contents() {
        let r = compile("t", FIB, &CompileOptions::no_dae()).unwrap();
        let doc = system_descriptor(&r.explicit, "fib_system");
        let text = doc.pretty();
        // Round-trips through our parser.
        let back = json::parse(&text).unwrap();
        assert_eq!(back, doc);

        let tasks = doc.get("tasks").unwrap().as_array().unwrap();
        assert_eq!(tasks.len(), 2);
        let fib = &tasks[0];
        assert_eq!(fib.get("name").unwrap().as_str(), Some("fib"));
        assert_eq!(fib.get("role").unwrap().as_str(), Some("entry"));
        // fib spawns itself; spawn_nexts its continuation.
        let spawns = fib.get("spawns").unwrap().as_array().unwrap();
        assert!(spawns.iter().any(|s| s.as_str() == Some("fib")));
        let nexts = fib.get("spawn_nexts").unwrap().as_array().unwrap();
        assert!(nexts.iter().any(|s| s.as_str() == Some("fib__k1")));
        // Continuation closure is 256 bits.
        let cont = &tasks[1];
        assert_eq!(cont.get("closure_bits").unwrap().as_i64(), Some(256));
        // The child fib sends into the continuation's closure.
        let sends = fib.get("send_argument_to").unwrap().as_array().unwrap();
        assert!(sends.iter().any(|s| s.as_str() == Some("fib__k1")), "{text}");
    }

    #[test]
    fn dae_descriptor_has_access_role() {
        let src = "global int a[];
            void g(int v) { atomic_add(a, 0, v); }
            void f(int i) {
                #pragma bombyx dae
                int x = a[i];
                cilk_spawn g(x);
                cilk_sync;
            }";
        let r = compile("t", src, &CompileOptions::standard()).unwrap();
        let doc = system_descriptor(&r.explicit, "dae_system");
        let tasks = doc.get("tasks").unwrap().as_array().unwrap();
        let roles: Vec<&str> =
            tasks.iter().filter_map(|t| t.get("role").unwrap().as_str()).collect();
        assert!(roles.contains(&"access"), "{roles:?}");
        assert!(roles.contains(&"entry"));
        assert!(roles.contains(&"continuation"));
    }

    #[test]
    fn memory_section_lists_globals() {
        let src = "global int a[64];
            global float w[];
            void g(int v) { atomic_add(a, 0, v); }
            void f(int i) { cilk_spawn g(i); cilk_sync; }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let doc = system_descriptor(&r.explicit, "s");
        let mem = doc.get("memory").unwrap().as_array().unwrap();
        assert_eq!(mem.len(), 2);
        assert_eq!(mem[0].get("elems").unwrap().as_i64(), Some(64));
        assert_eq!(mem[1].get("elems"), Some(&Json::Null));
    }
}
