//! HardCilk backend: explicit IR → synthesizable HLS C++ PEs + the JSON
//! system descriptor HardCilk's architecture generator consumes
//! (paper §II-B).

pub mod cpp_gen;
pub mod json_desc;
pub mod structurize;

use anyhow::Result;

use crate::ir::cfg::Module;
use crate::ir::explicit::explicit_tasks;
use crate::util::json::Json;

/// The full generated system.
#[derive(Clone, Debug)]
pub struct HardCilkSystem {
    pub name: String,
    /// Shared header (`bombyx_system.h`).
    pub header: String,
    /// One C++ source per PE: (task name, file name, contents).
    pub pes: Vec<(String, String, String)>,
    /// System descriptor.
    pub descriptor: Json,
}

/// Generate the complete HardCilk system from an explicit module.
pub fn generate(module: &Module, system_name: &str) -> Result<HardCilkSystem> {
    let header = cpp_gen::gen_header(module)?;
    let mut pes = Vec::new();
    for fid in explicit_tasks(module) {
        let name = module.funcs[fid].name.clone();
        let source = cpp_gen::gen_pe(module, fid)?;
        let file = format!("pe_{}.cpp", name.replace("__", "_k_"));
        pes.push((name, file, source));
    }
    Ok(HardCilkSystem {
        name: system_name.to_string(),
        header,
        pes,
        descriptor: json_desc::system_descriptor(module, system_name),
    })
}

impl HardCilkSystem {
    /// Write all files into a directory.
    pub fn write_to(&self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("bombyx_system.h"), &self.header)?;
        for (_, file, src) in &self.pes {
            std::fs::write(dir.join(file), src)?;
        }
        std::fs::write(dir.join(format!("{}.json", self.name)), self.descriptor.pretty())?;
        Ok(())
    }

    pub fn total_loc(&self) -> usize {
        self.header.lines().count()
            + self.pes.iter().map(|(_, _, s)| s.lines().count()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::{compile, CompileOptions};

    #[test]
    fn generate_full_fib_system() {
        let r = compile(
            "t",
            "int fib(int n) {
                if (n < 2) return n;
                int x = cilk_spawn fib(n - 1);
                int y = cilk_spawn fib(n - 2);
                cilk_sync;
                return x + y;
            }",
            &CompileOptions::no_dae(),
        )
        .unwrap();
        let sys = generate(&r.explicit, "fib_system").unwrap();
        assert_eq!(sys.pes.len(), 2);
        assert!(sys.header.contains("closure_fib"));
        assert!(sys.total_loc() > 50);
        let names: Vec<&str> = sys.pes.iter().map(|(n, _, _)| n.as_str()).collect();
        assert_eq!(names, vec!["fib", "fib__k1"]);
    }

    #[test]
    fn write_to_disk() {
        let r = compile(
            "t",
            "int f(int n) {
                int x = cilk_spawn f(n - 1);
                cilk_sync;
                return x;
            }",
            &CompileOptions::no_dae(),
        )
        .unwrap();
        let sys = generate(&r.explicit, "sys").unwrap();
        let dir = std::env::temp_dir().join(format!("bombyx_test_{}", std::process::id()));
        sys.write_to(&dir).unwrap();
        assert!(dir.join("bombyx_system.h").exists());
        assert!(dir.join("sys.json").exists());
        assert!(dir.join("pe_f.cpp").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
