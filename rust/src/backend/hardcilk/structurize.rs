//! Structural control-flow reconstruction for HLS C++ emission.
//!
//! Vitis HLS rejects `goto`, so PE bodies must be emitted as structured
//! C++. Our CFGs originate from structured Cilk-C (plus fission, which
//! preserves reducibility), so a simple pattern-driven structurizer
//! suffices: linear chains, if/else with a post-dominator join, and natural
//! `while` loops (header-branch, single back edge). Anything that doesn't
//! match (should not happen, but the fallback keeps codegen total) is
//! emitted as a synthesizable `switch`-FSM.

use std::collections::HashSet;

use crate::ir::cfg::{BlockId, Cfg, Term};
use crate::ir::expr::Expr;
use crate::lower::analysis::{dominators, natural_loops};

/// Structured program tree over CFG blocks.
#[derive(Clone, Debug)]
pub enum SNode {
    /// Straight-line ops of a block (terminator handled by the parent).
    Ops(BlockId),
    /// Terminal ops of a block (Halt/Return terminator).
    Tail(BlockId),
    Seq(Vec<SNode>),
    If { cond_block: BlockId, cond: Expr, then_: Box<SNode>, else_: Box<SNode> },
    While { header: BlockId, cond: Expr, body: Box<SNode> },
    /// Fallback: blocks to emit as a switch FSM.
    Fsm(Vec<BlockId>),
}

/// Reconstruct structured control flow for a (small) task CFG.
pub fn structurize(cfg: &Cfg) -> SNode {
    let idom = dominators(cfg);
    let loops = natural_loops(cfg, &idom);
    let headers: HashSet<BlockId> = loops.iter().map(|(h, _)| *h).collect();
    let ipdom = postdominators(cfg);
    let mut cx = Cx { cfg, loops: &loops, headers: &headers, ipdom: &ipdom, fuel: 10_000 };
    match cx.region(Some(cfg.entry), None) {
        Some(node) => node,
        None => SNode::Fsm(cfg.reachable_ids()),
    }
}

struct Cx<'a> {
    cfg: &'a Cfg,
    loops: &'a [(BlockId, HashSet<BlockId>)],
    headers: &'a HashSet<BlockId>,
    ipdom: &'a [Option<BlockId>],
    fuel: u32,
}

impl<'a> Cx<'a> {
    /// Emit the region starting at `b`, stopping when reaching `stop`
    /// (exclusive). Returns None if the shape is unsupported.
    fn region(&mut self, mut b: Option<BlockId>, stop: Option<BlockId>) -> Option<SNode> {
        let mut seq = Vec::new();
        loop {
            self.fuel = self.fuel.checked_sub(1)?;
            let Some(cur) = b else { break };
            if Some(cur) == stop {
                break;
            }
            // Loop header?
            if self.headers.contains(&cur) {
                let (_, body_set) = self.loops.iter().find(|(h, _)| *h == cur)?;
                let Term::Branch { cond, then_, else_ } = &self.cfg.blocks[cur].term else {
                    return None; // non-while loop shape -> FSM
                };
                let (body_entry, exit, cond_expr) = if body_set.contains(then_) {
                    (*then_, *else_, cond.clone())
                } else if body_set.contains(else_) {
                    // while (!cond)
                    (
                        *else_,
                        *then_,
                        Expr::Unary(crate::frontend::ast::UnOp::Not, Box::new(cond.clone())),
                    )
                } else {
                    return None;
                };
                // Header must carry no side ops for a clean while — if it
                // does, they belong to both iteration and entry; our
                // lowering puts the condition alone in the header, but ops
                // can appear after merging. Fall back if present.
                let body = self.region(Some(body_entry), Some(cur))?;
                if !self.cfg.blocks[cur].ops.is_empty() {
                    return None;
                }
                seq.push(SNode::While { header: cur, cond: cond_expr, body: Box::new(body) });
                b = Some(exit);
                continue;
            }
            match &self.cfg.blocks[cur].term {
                Term::Jump(t) => {
                    seq.push(SNode::Ops(cur));
                    b = Some(*t);
                }
                Term::Return(_) | Term::Halt | Term::Sync { .. } => {
                    seq.push(SNode::Tail(cur));
                    break;
                }
                Term::Branch { cond, then_, else_ } => {
                    // If/else with join at the immediate postdominator.
                    let join = self.ipdom[cur.index()];
                    seq.push(SNode::Ops(cur));
                    let t = self.region(Some(*then_), join)?;
                    let e = self.region(Some(*else_), join)?;
                    seq.push(SNode::If {
                        cond_block: cur,
                        cond: cond.clone(),
                        then_: Box::new(t),
                        else_: Box::new(e),
                    });
                    b = join;
                }
            }
        }
        Some(match seq.len() {
            1 => seq.pop().unwrap(),
            _ => SNode::Seq(seq),
        })
    }
}

/// Immediate postdominators via dominators of the reversed CFG with a
/// virtual exit. Blocks that cannot reach an exit get `None`.
pub fn postdominators(cfg: &Cfg) -> Vec<Option<BlockId>> {
    let n = cfg.blocks.len();
    // Build reverse adjacency with virtual exit node index n.
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n + 1]; // preds in reverse graph = succs in original
    let mut rsuccs: Vec<Vec<usize>> = vec![Vec::new(); n + 1];
    for (bid, block) in cfg.blocks.iter() {
        let succs = block.term.successors();
        if succs.is_empty() {
            // edge exit -> bid in reverse graph
            rsuccs[n].push(bid.index());
            preds[bid.index()].push(n);
        }
        for s in succs {
            rsuccs[s.index()].push(bid.index());
            preds[bid.index()].push(s.index());
        }
    }
    // RPO of reverse graph from virtual exit.
    let mut visited = vec![false; n + 1];
    let mut order = Vec::new();
    let mut stack = vec![(n, false)];
    while let Some((b, post)) = stack.pop() {
        if post {
            order.push(b);
            continue;
        }
        if visited[b] {
            continue;
        }
        visited[b] = true;
        stack.push((b, true));
        for &s in &rsuccs[b] {
            if !visited[s] {
                stack.push((s, false));
            }
        }
    }
    order.reverse();
    let mut rpo_index = vec![usize::MAX; n + 1];
    for (i, &b) in order.iter().enumerate() {
        rpo_index[b] = i;
    }
    let mut idom: Vec<Option<usize>> = vec![None; n + 1];
    idom[n] = Some(n);
    let intersect = |idom: &[Option<usize>], rpo_index: &[usize], mut a: usize, mut b: usize| {
        while a != b {
            while rpo_index[a] > rpo_index[b] {
                a = idom[a].unwrap();
            }
            while rpo_index[b] > rpo_index[a] {
                b = idom[b].unwrap();
            }
        }
        a
    };
    let mut changed = true;
    while changed {
        changed = false;
        for &b in order.iter().skip(1) {
            let mut new: Option<usize> = None;
            for &p in &preds[b] {
                if idom[p].is_none() {
                    continue;
                }
                new = Some(match new {
                    None => p,
                    Some(cur) => intersect(&idom, &rpo_index, cur, p),
                });
            }
            if let Some(ni) = new {
                if idom[b] != Some(ni) {
                    idom[b] = Some(ni);
                    changed = true;
                }
            }
        }
    }
    (0..n)
        .map(|b| match idom[b] {
            Some(d) if d < n => Some(BlockId::new(d)),
            _ => None,
        })
        .collect()
}

impl Cfg {
    /// Reachable block ids, ascending (helper for the FSM fallback).
    pub fn reachable_ids(&self) -> Vec<BlockId> {
        let r = self.reachable();
        self.blocks.ids().filter(|b| r[b.index()]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_check;
    use crate::lower::ast_to_cfg::lower_program;
    use crate::lower::simplify::simplify_module;

    fn cfg_of(src: &str, name: &str) -> Cfg {
        let (p, _) = parse_and_check("t", src).unwrap();
        let mut m = lower_program(&p).unwrap();
        simplify_module(&mut m);
        m.funcs[m.func_by_name(name).unwrap()].cfg().clone()
    }

    fn count_fsm(n: &SNode) -> usize {
        match n {
            SNode::Fsm(_) => 1,
            SNode::Seq(items) => items.iter().map(count_fsm).sum(),
            SNode::If { then_, else_, .. } => count_fsm(then_) + count_fsm(else_),
            SNode::While { body, .. } => count_fsm(body),
            _ => 0,
        }
    }

    #[test]
    fn linear_function_is_seq() {
        let cfg = cfg_of("int f(int n) { int x = n + 1; return x * 2; }", "f");
        let s = structurize(&cfg);
        assert_eq!(count_fsm(&s), 0);
        assert!(matches!(s, SNode::Tail(_) | SNode::Seq(_)));
    }

    #[test]
    fn if_else_structure() {
        let cfg = cfg_of("int f(int n) { if (n < 0) { return -n; } else { return n; } }", "f");
        let s = structurize(&cfg);
        assert_eq!(count_fsm(&s), 0);
        fn has_if(n: &SNode) -> bool {
            match n {
                SNode::If { .. } => true,
                SNode::Seq(items) => items.iter().any(has_if),
                _ => false,
            }
        }
        assert!(has_if(&s), "{s:?}");
    }

    #[test]
    fn while_loop_structure() {
        let cfg = cfg_of(
            "int f(int n) { int acc = 0; int i = 0; while (i < n) { acc = acc + i; i = i + 1; } return acc; }",
            "f",
        );
        let s = structurize(&cfg);
        assert_eq!(count_fsm(&s), 0);
        fn has_while(n: &SNode) -> bool {
            match n {
                SNode::While { .. } => true,
                SNode::Seq(items) => items.iter().any(has_while),
                SNode::If { then_, else_, .. } => has_while(then_) || has_while(else_),
                _ => false,
            }
        }
        assert!(has_while(&s), "{s:?}");
    }

    #[test]
    fn nested_loops_and_ifs() {
        let cfg = cfg_of(
            "int f(int n) {
                int acc = 0;
                for (int i = 0; i < n; i = i + 1) {
                    if (i % 2 == 0) {
                        for (int j = 0; j < i; j = j + 1) { acc = acc + j; }
                    } else {
                        acc = acc - 1;
                    }
                }
                return acc;
            }",
            "f",
        );
        let s = structurize(&cfg);
        assert_eq!(count_fsm(&s), 0, "{s:?}");
    }

    #[test]
    fn postdominators_diamond() {
        let cfg = cfg_of("int f(int n) { int x = 0; if (n > 0) { x = 1; } else { x = 2; } return x; }", "f");
        let ipdom = postdominators(&cfg);
        // The entry's ipdom is the join block (which returns).
        let join = ipdom[cfg.entry.index()].expect("entry has a postdominator");
        assert!(matches!(cfg.blocks[join].term, Term::Return(_)));
    }
}
