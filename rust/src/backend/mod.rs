//! Compilation targets for the explicit IR.
//!
//! - [`hardcilk`]: synthesizable HLS C++ PEs + JSON system descriptor (the
//!   paper's primary backend, §II-B);
//! - [`rtl`]: direct synthesizable Verilog — FSM+datapath PEs, pipelined
//!   DAE access PEs at II=1, task queues and a dispatch stub, with no HLS
//!   tool in the loop;
//! - [`emu`]: the Cilk-1 emulation backend — packages an explicit module
//!   for execution on the software work-stealing runtime ([`crate::ws`]),
//!   used to verify semantic equivalence with the original program.

pub mod emu;
pub mod hardcilk;
pub mod rtl;
