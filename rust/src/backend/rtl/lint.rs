//! Structural lint for the emitted Verilog.
//!
//! Not a simulator and not a full parser — a token-level checker for the
//! invariants a structurally-sane netlist must satisfy, tuned to (and
//! enforced against) the emitter's own output style:
//!
//! - balanced `module`/`endmodule`, no nested modules;
//! - every identifier used inside a module is declared **before** use
//!   (ports, `wire`, `reg`, `parameter`/`localparam`, instance names);
//! - one driver per `reg` (a reg is assigned from at most one `always`
//!   block and never by a continuous `assign`), at most one `assign` per
//!   wire, and no assignment to input ports;
//! - instantiated module names resolve within the linted file set.
//!
//! The lint runs as the pass-manager's post-verification for the `rtl`
//! stage (see `lower::pass`), so a codegen regression that emits an
//! undeclared wire or a doubly-driven register fails the pipeline at the
//! pass boundary, with the module and line in the error.

use std::collections::{HashMap, HashSet};

#[derive(Clone, Copy, PartialEq, Eq)]
enum Kind {
    Ident,
    Number,
    Sym,
}

struct Tok {
    kind: Kind,
    text: String,
    line: usize,
}

const KEYWORDS: &[&str] = &[
    "module", "endmodule", "input", "output", "inout", "wire", "reg", "signed", "assign",
    "always", "posedge", "negedge", "if", "else", "begin", "end", "case", "endcase", "default",
    "localparam", "parameter", "integer", "genvar", "generate", "endgenerate",
];

fn is_keyword(s: &str) -> bool {
    KEYWORDS.contains(&s)
}

fn lex(source: &str) -> Vec<Tok> {
    let mut toks = Vec::new();
    let bytes: Vec<char> = source.chars().collect();
    let mut i = 0;
    let mut line = 1;
    while i < bytes.len() {
        let c = bytes[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c.is_whitespace() {
            i += 1;
        } else if c == '/' && i + 1 < bytes.len() && bytes[i + 1] == '/' {
            while i < bytes.len() && bytes[i] != '\n' {
                i += 1;
            }
        } else if c == '/' && i + 1 < bytes.len() && bytes[i + 1] == '*' {
            i += 2;
            while i + 1 < bytes.len() && !(bytes[i] == '*' && bytes[i + 1] == '/') {
                if bytes[i] == '\n' {
                    line += 1;
                }
                i += 1;
            }
            i = (i + 2).min(bytes.len());
        } else if c.is_alphabetic() || c == '_' || c == '$' {
            let start = i;
            while i < bytes.len()
                && (bytes[i].is_alphanumeric() || bytes[i] == '_' || bytes[i] == '$')
            {
                i += 1;
            }
            toks.push(Tok {
                kind: Kind::Ident,
                text: bytes[start..i].iter().collect(),
                line,
            });
        } else if c.is_ascii_digit() {
            let start = i;
            while i < bytes.len() && (bytes[i].is_ascii_digit() || bytes[i] == '_') {
                i += 1;
            }
            // Sized literal: 16'd42, 1'b0, 64'sd7, 64'hFF...
            if i < bytes.len() && bytes[i] == '\'' {
                i += 1;
                if i < bytes.len() && (bytes[i] == 's' || bytes[i] == 'S') {
                    i += 1;
                }
                if i < bytes.len() && "bdhoBDHO".contains(bytes[i]) {
                    i += 1;
                }
                while i < bytes.len()
                    && (bytes[i].is_ascii_hexdigit()
                        || bytes[i] == '_'
                        || bytes[i] == 'x'
                        || bytes[i] == 'X'
                        || bytes[i] == 'z'
                        || bytes[i] == 'Z')
                {
                    i += 1;
                }
            }
            toks.push(Tok {
                kind: Kind::Number,
                text: bytes[start..i].iter().collect(),
                line,
            });
        } else if c == '<' && i + 1 < bytes.len() && bytes[i + 1] == '=' {
            toks.push(Tok { kind: Kind::Sym, text: "<=".to_string(), line });
            i += 2;
        } else {
            toks.push(Tok { kind: Kind::Sym, text: c.to_string(), line });
            i += 1;
        }
    }
    toks
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Decl {
    PortIn,
    PortOut,
    Wire,
    Reg,
    Param,
    Instance,
}

/// Lint a self-contained Verilog text (all instantiated modules must be
/// defined in `source` itself).
pub fn lint(source: &str) -> Vec<String> {
    let known = collect_module_names(source);
    lint_with_modules(source, &known)
}

/// Module names defined in a text (for multi-file lint runs).
pub fn collect_module_names(source: &str) -> HashSet<String> {
    let toks = lex(source);
    let mut names = HashSet::new();
    let mut i = 0;
    while i < toks.len() {
        if toks[i].kind == Kind::Ident && toks[i].text == "module" {
            if let Some(t) = toks.get(i + 1) {
                if t.kind == Kind::Ident {
                    names.insert(t.text.clone());
                }
            }
        }
        i += 1;
    }
    names
}

/// Lint one file against a set of externally-known module names.
pub fn lint_with_modules(source: &str, known_modules: &HashSet<String>) -> Vec<String> {
    let toks = lex(source);
    let mut errors = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if toks[i].kind == Kind::Ident && toks[i].text == "module" {
            i = lint_module(&toks, i, known_modules, &mut errors);
        } else if toks[i].kind == Kind::Ident && toks[i].text == "endmodule" {
            errors.push(format!(
                "line {}: `endmodule` without a matching `module`",
                toks[i].line
            ));
            i += 1;
        } else {
            i += 1;
        }
    }
    errors
}

struct ModCx {
    name: String,
    declared: HashMap<String, (usize, Decl)>, // name -> (token idx, kind)
    skip_use: HashSet<usize>,                 // token idxs excluded from use-checking
    assign_drivers: HashMap<String, usize>,   // name -> count of `assign` statements
    reg_drivers: HashMap<String, HashSet<usize>>, // name -> always-block ids
    always_count: usize,
}

impl ModCx {
    fn declare(&mut self, toks: &[Tok], idx: usize, kind: Decl, errors: &mut Vec<String>) {
        let name = toks[idx].text.clone();
        self.skip_use.insert(idx);
        if let Some((_, prev)) = self.declared.get(&name) {
            errors.push(format!(
                "line {}: module `{}`: `{}` redeclared (first as {:?})",
                toks[idx].line, self.name, name, prev
            ));
        } else {
            self.declared.insert(name, (idx, kind));
        }
    }
}

/// Skip a balanced `open...close` group starting at `i` (which must point
/// at `open`); returns the index just past the matching close.
fn skip_balanced(toks: &[Tok], mut i: usize, open: &str, close: &str) -> usize {
    let mut depth = 0;
    while i < toks.len() {
        if toks[i].kind == Kind::Sym && toks[i].text == open {
            depth += 1;
        } else if toks[i].kind == Kind::Sym && toks[i].text == close {
            depth -= 1;
            if depth == 0 {
                return i + 1;
            }
        }
        i += 1;
    }
    i
}

fn is_sym(toks: &[Tok], i: usize, s: &str) -> bool {
    toks.get(i).map(|t| t.kind == Kind::Sym && t.text == s).unwrap_or(false)
}

fn is_kw(toks: &[Tok], i: usize, s: &str) -> bool {
    toks.get(i).map(|t| t.kind == Kind::Ident && t.text == s).unwrap_or(false)
}

/// Parse one module starting at the `module` keyword; returns the index
/// just past `endmodule`.
fn lint_module(
    toks: &[Tok],
    start: usize,
    known_modules: &HashSet<String>,
    errors: &mut Vec<String>,
) -> usize {
    let mut i = start + 1;
    let mut cx = ModCx {
        name: String::new(),
        declared: HashMap::new(),
        skip_use: HashSet::new(),
        assign_drivers: HashMap::new(),
        reg_drivers: HashMap::new(),
        always_count: 0,
    };
    if toks.get(i).map(|t| t.kind) == Some(Kind::Ident) {
        cx.name = toks[i].text.clone();
        cx.skip_use.insert(i);
        i += 1;
    } else {
        errors.push(format!("line {}: `module` without a name", toks[start].line));
    }
    // Parameter header: #( parameter X = ..., ... )
    if is_sym(toks, i, "#") {
        let close = skip_balanced(toks, i + 1, "(", ")");
        let mut j = i + 2;
        while j + 1 < close {
            if is_kw(toks, j, "parameter") {
                j += 1;
                while is_sym(toks, j, "[") {
                    j = skip_balanced(toks, j, "[", "]");
                }
                if toks.get(j).map(|t| t.kind) == Some(Kind::Ident) {
                    cx.declare(toks, j, Decl::Param, errors);
                }
            }
            j += 1;
        }
        i = close;
    }
    // Port list.
    if is_sym(toks, i, "(") {
        let close = skip_balanced(toks, i, "(", ")");
        let mut j = i + 1;
        while j + 1 < close {
            if is_kw(toks, j, "input") || is_kw(toks, j, "output") || is_kw(toks, j, "inout") {
                let kind = if toks[j].text == "input" { Decl::PortIn } else { Decl::PortOut };
                j += 1;
                while is_kw(toks, j, "wire") || is_kw(toks, j, "reg") || is_kw(toks, j, "signed")
                {
                    j += 1;
                }
                while is_sym(toks, j, "[") {
                    j = skip_balanced(toks, j, "[", "]");
                }
                if toks.get(j).map(|t| t.kind) == Some(Kind::Ident) {
                    cx.declare(toks, j, kind, errors);
                }
            }
            j += 1;
        }
        i = close;
    }
    if is_sym(toks, i, ";") {
        i += 1;
    }
    // Body.
    let body_start = i;
    let mut body_end = None;
    while i < toks.len() {
        if toks[i].kind == Kind::Ident {
            match toks[i].text.as_str() {
                "endmodule" => {
                    body_end = Some(i);
                    break;
                }
                "module" => {
                    errors.push(format!(
                        "line {}: module `{}`: nested `module` before `endmodule`",
                        toks[i].line, cx.name
                    ));
                    body_end = Some(i);
                    break;
                }
                "wire" | "reg" | "integer" | "genvar" => {
                    let kind = if toks[i].text == "reg" { Decl::Reg } else { Decl::Wire };
                    i = parse_decl(toks, i + 1, kind, &mut cx, errors);
                }
                "localparam" | "parameter" => {
                    i = parse_decl(toks, i + 1, Decl::Param, &mut cx, errors);
                }
                "assign" => {
                    i = parse_assign(toks, i + 1, &mut cx, errors);
                }
                "always" => {
                    i = parse_always(toks, i + 1, &mut cx);
                }
                _ => {
                    i = parse_instantiation(toks, i, &mut cx, known_modules, errors);
                }
            }
        } else {
            i += 1;
        }
    }
    let Some(end) = body_end else {
        errors.push(format!(
            "line {}: module `{}` is missing its `endmodule`",
            toks[start].line, cx.name
        ));
        return toks.len();
    };

    // Use-before-declaration check over the whole module span.
    for idx in start..end {
        let t = &toks[idx];
        if t.kind != Kind::Ident || is_keyword(&t.text) || t.text.starts_with('$') {
            continue;
        }
        if cx.skip_use.contains(&idx) {
            continue;
        }
        // `.port` connection names are not module-scope identifiers.
        if idx > 0 && toks[idx - 1].kind == Kind::Sym && toks[idx - 1].text == "." {
            continue;
        }
        match cx.declared.get(&t.text) {
            None => errors.push(format!(
                "line {}: module `{}`: `{}` used but never declared",
                t.line, cx.name, t.text
            )),
            Some((decl_idx, _)) if *decl_idx > idx => errors.push(format!(
                "line {}: module `{}`: `{}` used before its declaration",
                t.line, cx.name, t.text
            )),
            _ => {}
        }
    }

    // Driver checks.
    for (name, blocks) in &cx.reg_drivers {
        match cx.declared.get(name) {
            Some((_, Decl::Reg)) => {
                if blocks.len() > 1 {
                    errors.push(format!(
                        "module `{}`: reg `{}` is driven from {} always blocks",
                        cx.name,
                        name,
                        blocks.len()
                    ));
                }
                if cx.assign_drivers.contains_key(name) {
                    errors.push(format!(
                        "module `{}`: reg `{}` has both procedural and continuous drivers",
                        cx.name, name
                    ));
                }
            }
            Some((_, kind)) => errors.push(format!(
                "module `{}`: non-blocking assignment to `{}` which is {:?}, not a reg",
                cx.name, name, kind
            )),
            None => {} // already reported as undeclared
        }
    }
    for (name, count) in &cx.assign_drivers {
        if *count > 1 {
            errors.push(format!(
                "module `{}`: `{}` has {count} continuous `assign` drivers",
                cx.name, name
            ));
        }
        if let Some((_, Decl::PortIn)) = cx.declared.get(name) {
            errors.push(format!(
                "module `{}`: `assign` drives input port `{}`",
                cx.name, name
            ));
        }
    }
    let _ = body_start;
    end + 1
}

fn parse_decl(
    toks: &[Tok],
    mut i: usize,
    kind: Decl,
    cx: &mut ModCx,
    errors: &mut Vec<String>,
) -> usize {
    while is_kw(toks, i, "signed") {
        i += 1;
    }
    while is_sym(toks, i, "[") {
        i = skip_balanced(toks, i, "[", "]");
    }
    loop {
        if toks.get(i).map(|t| t.kind) == Some(Kind::Ident) {
            cx.declare(toks, i, kind, errors);
            i += 1;
        } else {
            break;
        }
        while is_sym(toks, i, "[") {
            i = skip_balanced(toks, i, "[", "]"); // array bounds
        }
        if is_sym(toks, i, "=") {
            i += 1;
            while i < toks.len() && !is_sym(toks, i, ",") && !is_sym(toks, i, ";") {
                if is_sym(toks, i, "(") {
                    i = skip_balanced(toks, i, "(", ")");
                } else if is_sym(toks, i, "{") {
                    i = skip_balanced(toks, i, "{", "}");
                } else {
                    i += 1;
                }
            }
        }
        if is_sym(toks, i, ",") {
            i += 1;
            continue;
        }
        break;
    }
    if is_sym(toks, i, ";") {
        i += 1;
    }
    i
}

fn parse_assign(toks: &[Tok], mut i: usize, cx: &mut ModCx, errors: &mut Vec<String>) -> usize {
    if toks.get(i).map(|t| t.kind) == Some(Kind::Ident) {
        let name = toks[i].text.clone();
        if !cx.declared.contains_key(&name) {
            errors.push(format!(
                "line {}: module `{}`: `assign` drives undeclared `{}`",
                toks[i].line, cx.name, name
            ));
        }
        cx.skip_use.insert(i);
        *cx.assign_drivers.entry(name).or_insert(0) += 1;
        i += 1;
    }
    while is_sym(toks, i, "[") {
        i = skip_balanced(toks, i, "[", "]");
    }
    while i < toks.len() && !is_sym(toks, i, ";") {
        i += 1;
    }
    i + 1
}

fn parse_always(toks: &[Tok], mut i: usize, cx: &mut ModCx) -> usize {
    let always_id = cx.always_count;
    cx.always_count += 1;
    if is_sym(toks, i, "@") {
        i += 1;
        if is_sym(toks, i, "(") {
            i = skip_balanced(toks, i, "(", ")");
        }
    }
    if !is_kw(toks, i, "begin") {
        // Single-statement always (not emitted by the generator); scan to `;`.
        while i < toks.len() && !is_sym(toks, i, ";") {
            i += 1;
        }
        return i + 1;
    }
    let body_start = i;
    let mut depth = 0;
    let mut end = i;
    while end < toks.len() {
        if is_kw(toks, end, "begin") {
            depth += 1;
        } else if is_kw(toks, end, "end") {
            depth -= 1;
            if depth == 0 {
                break;
            }
        }
        end += 1;
    }
    // Non-blocking driver scan.
    let mut at_stmt_start = true;
    let mut j = body_start;
    while j <= end && j < toks.len() {
        let t = &toks[j];
        if t.kind == Kind::Ident && !is_keyword(&t.text) && !t.text.starts_with('$') {
            if at_stmt_start {
                let mut k = j + 1;
                while is_sym(toks, k, "[") {
                    k = skip_balanced(toks, k, "[", "]");
                }
                if is_sym(toks, k, "<=") {
                    cx.reg_drivers.entry(t.text.clone()).or_default().insert(always_id);
                }
            }
            at_stmt_start = false;
        } else if t.kind == Kind::Ident {
            at_stmt_start = matches!(t.text.as_str(), "begin" | "end" | "else" | "default");
        } else if t.kind == Kind::Sym {
            at_stmt_start = matches!(t.text.as_str(), ";" | ":" | ")");
        } else {
            at_stmt_start = false;
        }
        j += 1;
    }
    end + 1
}

fn parse_instantiation(
    toks: &[Tok],
    mut i: usize,
    cx: &mut ModCx,
    known_modules: &HashSet<String>,
    errors: &mut Vec<String>,
) -> usize {
    let mod_ref = toks[i].text.clone();
    let mod_line = toks[i].line;
    cx.skip_use.insert(i);
    if !known_modules.contains(&mod_ref) {
        errors.push(format!(
            "line {mod_line}: module `{}`: instantiated module `{mod_ref}` is not defined",
            cx.name
        ));
    }
    i += 1;
    if is_sym(toks, i, "#") {
        i += 1;
        if is_sym(toks, i, "(") {
            i = skip_balanced(toks, i, "(", ")");
        }
    }
    if toks.get(i).map(|t| t.kind) == Some(Kind::Ident) {
        cx.declare(toks, i, Decl::Instance, errors);
        i += 1;
    } else {
        errors.push(format!(
            "line {mod_line}: module `{}`: instantiation of `{mod_ref}` has no instance name",
            cx.name
        ));
    }
    if is_sym(toks, i, "(") {
        i = skip_balanced(toks, i, "(", ")");
    }
    if is_sym(toks, i, ";") {
        i += 1;
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "\
module adder (
  input  wire clk,
  input  wire rst_n,
  input  wire signed [63:0] a,
  input  wire signed [63:0] b,
  output wire signed [63:0] sum
);
  reg signed [63:0] acc;
  assign sum = acc;
  always @(posedge clk) begin
    if (!rst_n) begin
      acc <= 64'sd0;
    end else begin
      acc <= (a + b);
    end
  end
endmodule
";

    #[test]
    fn clean_module_lints_clean() {
        assert_eq!(lint(GOOD), Vec::<String>::new());
    }

    #[test]
    fn unbalanced_endmodule_reported() {
        let errs = lint("module m (\n  input wire clk\n);\n"); // no endmodule
        assert!(errs.iter().any(|e| e.contains("missing its `endmodule`")), "{errs:?}");
        let errs = lint("endmodule\n");
        assert!(errs.iter().any(|e| e.contains("without a matching")), "{errs:?}");
    }

    #[test]
    fn undeclared_wire_reported() {
        let src = "module m (\n  input wire clk,\n  output wire y\n);\n\
                   assign y = mystery;\nendmodule\n";
        let errs = lint(src);
        assert!(
            errs.iter().any(|e| e.contains("`mystery` used but never declared")),
            "{errs:?}"
        );
    }

    #[test]
    fn use_before_declaration_reported() {
        let src = "module m (\n  input wire clk,\n  output wire y\n);\n\
                   assign y = late;\n  wire late;\nendmodule\n";
        let errs = lint(src);
        assert!(errs.iter().any(|e| e.contains("used before its declaration")), "{errs:?}");
    }

    #[test]
    fn double_driven_reg_reported() {
        let src = "module m (\n  input wire clk\n);\n  reg r;\n\
                   always @(posedge clk) begin\n    r <= 1'b0;\n  end\n\
                   always @(posedge clk) begin\n    r <= 1'b1;\n  end\n\
                   endmodule\n";
        let errs = lint(src);
        assert!(errs.iter().any(|e| e.contains("driven from 2 always blocks")), "{errs:?}");
    }

    #[test]
    fn double_assign_reported() {
        let src = "module m (\n  input wire a,\n  output wire y\n);\n\
                   assign y = a;\n  assign y = !a;\nendmodule\n";
        let errs = lint(src);
        assert!(errs.iter().any(|e| e.contains("2 continuous `assign` drivers")), "{errs:?}");
    }

    #[test]
    fn unknown_instantiated_module_reported() {
        let src = "module m (\n  input wire clk\n);\n\
                   ghost u_g (\n    .clk(clk)\n  );\nendmodule\n";
        let errs = lint(src);
        assert!(errs.iter().any(|e| e.contains("`ghost` is not defined")), "{errs:?}");
    }
}
