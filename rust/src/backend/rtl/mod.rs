//! RTL backend: explicit Cilk-1 IR → synthesizable Verilog PEs + system
//! wrapper — the HLS-free compilation target.
//!
//! Where [`crate::backend::hardcilk`] emits HLS C++ and leaves scheduling
//! to Vitis, this backend lowers each task directly to an FSM+datapath
//! module ([`pe_gen`]), pipelines DAE access tasks at II=1 without an HLS
//! tool in the loop, and wraps the PEs with task queues and a dispatch
//! stub ([`system`]). Emitted files are checked by a structural linter
//! ([`lint`]) which doubles as the pass-manager's verification for the
//! `rtl` pipeline stage.
//!
//! The backend rides the compile session: `CompileSession::rtl_system`
//! memoizes one [`RtlSystem`] per system name, generated through the
//! [`RtlEmit`] pass so emission shows up in the per-pass timing counters
//! next to `ast_to_cfg`/`explicitize`.

pub mod lint;
pub mod pe_gen;
pub mod system;
pub mod verilog;

use std::collections::HashSet;

use anyhow::{bail, Result};

use crate::hls::resource::{estimate, CostModel, ResourceEstimate};
use crate::ir::cfg::{Module, Op};
use crate::ir::explicit::explicit_tasks;
use crate::ir::FuncId;
use crate::lower::{Artifact, CompileOptions, Pass, PipelineStage};
use crate::util::table::Table;

use self::verilog::vname;

/// Hardware implementation style of one generated PE.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeStyle {
    /// Task-pipelined datapath accepting a new task every `ii` cycles.
    Pipelined { ii: u32 },
    /// One task at a time through the state machine.
    Fsm,
    /// Interface shell for an `extern xla` datapath.
    Blackbox,
}

impl PeStyle {
    pub fn name(self) -> &'static str {
        match self {
            PeStyle::Pipelined { .. } => "pipelined",
            PeStyle::Fsm => "fsm",
            PeStyle::Blackbox => "blackbox",
        }
    }
}

/// One PE of the generated system.
#[derive(Clone, Debug)]
pub struct RtlPe {
    pub task: String,
    pub role: &'static str,
    pub file: String,
    pub style: PeStyle,
    /// FSM state count (0 for pipelined/blackbox PEs).
    pub states: u32,
    /// Linear-model estimate from [`crate::hls::resource`].
    pub resources: ResourceEstimate,
    pub source: String,
}

/// The full generated RTL system.
#[derive(Clone, Debug)]
pub struct RtlSystem {
    pub name: String,
    /// `bx_rtl_pkg.v`: the FIFO primitive + leaf-function modules.
    pub package: String,
    pub pes: Vec<RtlPe>,
    /// `<name>_top.v`: dispatch stub + top wrapper.
    pub top: String,
}

/// Generate the complete RTL system from an explicit module.
pub fn generate(module: &Module, system_name: &str) -> Result<RtlSystem> {
    let model = CostModel::default();
    let mut generated: Vec<(String, pe_gen::GeneratedPe)> = Vec::new();
    let mut leaves: Vec<FuncId> = Vec::new();
    for fid in explicit_tasks(module) {
        let func = &module.funcs[fid];
        let pe = pe_gen::gen_pe(module, fid)?;
        if let Some(cfg) = func.body.as_ref() {
            for block in cfg.blocks.values() {
                for op in &block.ops {
                    if let Op::Call { callee, .. } = op {
                        if !leaves.contains(callee) {
                            leaves.push(*callee);
                        }
                    }
                }
            }
        }
        generated.push((func.name.clone(), pe));
    }
    let mut package = system::gen_package();
    for &lf in &leaves {
        package.push('\n');
        package.push_str(&pe_gen::gen_leaf(module, lf)?);
    }
    let top = system::gen_top(module, system_name, &generated);
    let pes = generated
        .into_iter()
        .map(|(task, pe)| {
            let fid = module.func_by_name(&task).expect("task name from this module");
            let func = &module.funcs[fid];
            let resources = estimate(&model, module, func);
            let header = format!("// est. resources: {resources}\n");
            RtlPe {
                file: format!("pe_{}.v", vname(&task)),
                role: func.task.as_ref().map(|t| t.role.name()).unwrap_or("task"),
                style: pe.style,
                states: pe.states,
                resources,
                source: format!("{header}{}", pe.source),
                task,
            }
        })
        .collect();
    Ok(RtlSystem { name: system_name.to_string(), package, pes, top })
}

impl RtlSystem {
    /// All files of the system as (file name, contents), emission order.
    pub fn files(&self) -> Vec<(String, &str)> {
        let mut out = vec![("bx_rtl_pkg.v".to_string(), self.package.as_str())];
        for pe in &self.pes {
            out.push((pe.file.clone(), pe.source.as_str()));
        }
        out.push((format!("{}_top.v", vname(&self.name)), self.top.as_str()));
        out
    }

    /// The whole system as one concatenated text (goldens, linting).
    pub fn concatenated(&self) -> String {
        let mut out = String::new();
        for (file, text) in self.files() {
            out.push_str(&format!("// ==== {file} ====\n"));
            out.push_str(text);
            out.push('\n');
        }
        out
    }

    /// Run the structural lint over every file of the system.
    pub fn lint(&self) -> Vec<String> {
        let mut known: HashSet<String> = HashSet::new();
        for (_, text) in self.files() {
            known.extend(lint::collect_module_names(text));
        }
        let mut errors = Vec::new();
        for (file, text) in self.files() {
            for e in lint::lint_with_modules(text, &known) {
                errors.push(format!("{file}: {e}"));
            }
        }
        errors
    }

    /// Write all files into a directory.
    pub fn write_to(&self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        for (file, text) in self.files() {
            std::fs::write(dir.join(file), text)?;
        }
        Ok(())
    }

    pub fn total_loc(&self) -> usize {
        self.files().iter().map(|(_, text)| text.lines().count()).sum()
    }

    /// Human-readable per-PE report: style, II, FSM size, resources.
    pub fn report(&self) -> String {
        let mut table = Table::new(["task", "role", "impl", "II", "states", "LUT", "FF", "BRAM"]);
        for pe in &self.pes {
            let ii = match pe.style {
                PeStyle::Pipelined { ii } => ii.to_string(),
                _ => "-".to_string(),
            };
            table.row([
                pe.task.clone(),
                pe.role.to_string(),
                pe.style.name().to_string(),
                ii,
                pe.states.to_string(),
                pe.resources.lut.to_string(),
                pe.resources.ff.to_string(),
                pe.resources.bram.to_string(),
            ]);
        }
        let mut out = table.render();
        for pe in &self.pes {
            if let PeStyle::Pipelined { ii } = pe.style {
                out.push_str(&format!(
                    "{}: task-pipelined at II={ii} (a new task enters every {ii} cycle(s))\n",
                    pe.task
                ));
            }
        }
        out
    }
}

/// The `rtl_emit` pass: explicit IR → [`RtlSystem`], run through the
/// [`crate::lower::PassManager`] so emission is timed and the produced
/// artifact is lint-verified at the pass boundary.
pub struct RtlEmit {
    pub system_name: String,
}

impl Pass for RtlEmit {
    fn name(&self) -> &'static str {
        "rtl_emit"
    }

    fn input_stage(&self) -> PipelineStage {
        PipelineStage::Explicit
    }

    fn output_stage(&self) -> PipelineStage {
        PipelineStage::Rtl
    }

    fn run(&self, artifact: Artifact, _opts: &CompileOptions) -> Result<Artifact> {
        match artifact {
            Artifact::Module(m) => Ok(Artifact::Rtl(generate(&m, &self.system_name)?)),
            _ => bail!("pass `rtl_emit` requires explicit-IR input"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::{compile, CompileOptions};

    const FIB: &str = "int fib(int n) {
        if (n < 2) return n;
        int x = cilk_spawn fib(n - 1);
        int y = cilk_spawn fib(n - 2);
        cilk_sync;
        return x + y;
    }";

    #[test]
    fn fib_system_generates_and_lints() {
        let r = compile("t", FIB, &CompileOptions::no_dae()).unwrap();
        let sys = generate(&r.explicit, "fib_system").unwrap();
        assert_eq!(sys.pes.len(), 2);
        assert!(sys.pes[0].source.contains("module pe_fib ("), "{}", sys.pes[0].source);
        assert!(sys.top.contains("module fib_system_top ("), "{}", sys.top);
        let errors = sys.lint();
        assert!(errors.is_empty(), "{errors:#?}\n{}", sys.concatenated());
    }

    #[test]
    fn generation_is_deterministic() {
        let r = compile("t", FIB, &CompileOptions::no_dae()).unwrap();
        let a = generate(&r.explicit, "s").unwrap();
        let b = generate(&r.explicit, "s").unwrap();
        assert_eq!(a.concatenated(), b.concatenated());
    }

    #[test]
    fn write_to_disk() {
        let r = compile("t", FIB, &CompileOptions::no_dae()).unwrap();
        let sys = generate(&r.explicit, "sys").unwrap();
        let dir = std::env::temp_dir().join(format!("bombyx_rtl_test_{}", std::process::id()));
        sys.write_to(&dir).unwrap();
        assert!(dir.join("bx_rtl_pkg.v").exists());
        assert!(dir.join("pe_fib.v").exists());
        assert!(dir.join("sys_top.v").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
