//! Per-task processing-element generation: explicit IR → synthesizable
//! Verilog FSM + datapath modules.
//!
//! Every explicit task becomes one `pe_<task>` module:
//!
//! - **FSM style** (the general case): one state per straight-line op
//!   (plus a wait state for split-phase ops: loads, `spawn_next` closure
//!   allocation, leaf calls), a branch-decision state per conditional
//!   terminator, and a latency counter on datapath states driven by
//!   [`crate::hls::schedule::op_cycles`] — the RTL schedule matches the
//!   cycle model the simulator charges.
//! - **Pipelined style** (DAE access tasks): a task whose body is
//!   `loads → send_argument` needs no FSM at all. The index datapath is
//!   combinational from the incoming closure, the memory request issues
//!   the same cycle the task is accepted, and the continuation rides a
//!   small in-flight FIFO until the response returns — one new task enters
//!   per cycle (II = 1), which is the §II-C property the HLS flow can only
//!   approximate through `#pragma HLS PIPELINE`.
//!
//! Stream interfaces are ready/valid with the same payload layout the
//! HardCilk JSON descriptor documents (closure bits from
//! [`closure_layout`], spawn/send/spawn_next message fields mirroring
//! `bx_spawn_req` / `bx_send_req` / `bx_spawn_next_req` in the HLS
//! header). Memory is a per-global request/response port pair; the AXI
//! adapter behind it serializes atomics per bank, exactly as the HLS
//! backend assumes.

use std::collections::HashMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

use crate::frontend::ast::Type;
use crate::ir::cfg::{BlockId, Cfg, Func, FuncId, FuncKind, Module, Op, RetTarget, TaskRole, Term};
use crate::ir::explicit::{closure_layout, explicit_tasks};
use crate::ir::expr::{Expr, VarId};
use crate::ir::GlobalId;
use crate::hls::schedule::{op_cycles, rtl_initiation_interval, ScheduleModel};

use super::verilog::{part_select, vcond, vexpr, vname};
use super::PeStyle;

/// Stream payload widths (bits). Layouts are documented inline where the
/// words are packed; they mirror the HLS structs in `bombyx_system.h`.
pub const SEND_BITS: u32 = 130; // {target[64], bits[64], kind[2]}
pub const SPAWN_BITS: u32 = 632; // {task[32], ret[64], nargs[8], bytes[16], arg0..7[64]}
pub const SPAWN_NEXT_BITS: u32 = 112; // {task[32], cont[64], bytes[16]}
pub const MAX_SPAWN_ARGS: usize = 8;

/// One generated PE module.
#[derive(Clone, Debug)]
pub struct GeneratedPe {
    pub source: String,
    pub style: PeStyle,
    /// FSM state count (0 for pipelined / blackbox PEs).
    pub states: u32,
    /// Interface summary consumed by the system wrapper.
    pub iface: PeInterface,
}

/// What ports a PE module exposes (beyond clk/rst_n/task_in).
#[derive(Clone, Debug, Default)]
pub struct PeInterface {
    pub has_spawn: bool,
    pub has_spawn_next: bool,
    pub has_send: bool,
    /// Globals with a direct memory port on this PE, in first-use order.
    pub globals: Vec<GlobalId>,
    /// Pass-through memory ports of leaf-call instances:
    /// (port prefix, global).
    pub leaf_mems: Vec<(String, GlobalId)>,
    pub closure_bits: u32,
}

/// Stable task id for stream messages: position in [`explicit_tasks`].
pub fn task_stream_id(module: &Module, fid: FuncId) -> u32 {
    explicit_tasks(module)
        .iter()
        .position(|&f| f == fid)
        .map(|p| p as u32)
        .unwrap_or(u32::MAX)
}

fn used_globals(func: &Func) -> Vec<GlobalId> {
    let mut out = Vec::new();
    let Some(cfg) = func.body.as_ref() else { return out };
    for b in cfg.reachable_ids() {
        for op in &cfg.blocks[b].ops {
            let g = match op {
                Op::Load { arr, .. } | Op::Store { arr, .. } | Op::AtomicAdd { arr, .. } => {
                    Some(*arr)
                }
                _ => None,
            };
            if let Some(g) = g {
                if !out.contains(&g) {
                    out.push(g);
                }
            }
        }
    }
    out
}

/// Per-variable register names, collision-free and deterministic.
fn var_names(func: &Func) -> Vec<String> {
    let mut seen: HashMap<String, u32> = HashMap::new();
    let mut out = Vec::with_capacity(func.vars.len());
    for (_, v) in func.vars.iter() {
        let base = format!("v_{}", vname(&v.name));
        let n = seen.entry(base.clone()).or_insert(0);
        let name = if *n == 0 { base.clone() } else { format!("{base}_{n}") };
        *n += 1;
        out.push(name);
    }
    out
}

/// Generate the PE module for an explicit task.
pub fn gen_pe(module: &Module, fid: FuncId) -> Result<GeneratedPe> {
    let func = &module.funcs[fid];
    let Some(meta) = func.task.as_ref() else {
        bail!("`{}` is not an explicit task", func.name);
    };
    if func.kind == FuncKind::Xla {
        return gen_xla_blackbox(module, fid);
    }
    if meta.role == TaskRole::Access {
        if let Some(pattern) = match_access_pipeline(func) {
            return gen_access_pipelined(module, fid, &pattern);
        }
    }
    gen_fsm_module(module, fid, FsmKind::Task)
}

/// Generate the FSM module for a leaf function (instantiated by PEs).
pub fn gen_leaf(module: &Module, fid: FuncId) -> Result<String> {
    Ok(gen_fsm_module(module, fid, FsmKind::Leaf)?.source)
}

// ---------------------------------------------------------------------------
// Pipelined access PE
// ---------------------------------------------------------------------------

/// The recognized access-task shape: pure assigns, one load, send the
/// loaded value.
struct AccessPattern {
    pre_assigns: Vec<(VarId, Expr)>,
    arr: GlobalId,
    index: Expr,
}

fn match_access_pipeline(func: &Func) -> Option<AccessPattern> {
    if rtl_initiation_interval(func).is_none() {
        return None;
    }
    let cfg = func.body.as_ref()?;
    let reachable = cfg.reachable_ids();
    if reachable.len() != 1 || reachable[0] != cfg.entry {
        return None;
    }
    let block = &cfg.blocks[cfg.entry];
    if !matches!(block.term, Term::Halt) {
        return None;
    }
    let mut pre_assigns = Vec::new();
    let mut load: Option<(VarId, GlobalId, Expr)> = None;
    let mut sent = false;
    for op in &block.ops {
        match op {
            Op::Assign { dst, src } if load.is_none() => {
                pre_assigns.push((*dst, src.clone()));
            }
            Op::Load { dst, arr, index, .. } if load.is_none() => {
                load = Some((*dst, *arr, index.clone()));
            }
            Op::SendArgument { value: Some(Expr::Var(v)) } if !sent => {
                let (dst, _, _) = load.as_ref()?;
                if v != dst {
                    return None;
                }
                sent = true;
            }
            _ => return None,
        }
    }
    let (_, arr, index) = load?;
    if !sent {
        return None;
    }
    Some(AccessPattern { pre_assigns, arr, index })
}

fn gen_access_pipelined(
    module: &Module,
    fid: FuncId,
    pattern: &AccessPattern,
) -> Result<GeneratedPe> {
    let func = &module.funcs[fid];
    let name = vname(&func.name);
    let layout = closure_layout(func);
    let gname = vname(&module.globals[pattern.arr].name);
    let ii = rtl_initiation_interval(func).unwrap_or(1);

    // Combinational field wires: params from the closure word, then the
    // pre-assign datapath on top of them.
    let names = var_names(func);
    let wire_of = |v: VarId| format!("f_{}", &names[v.index()][2..]);
    let mut field_wires = String::new();
    for (i, p) in func.param_ids().enumerate() {
        let fld = &layout.fields[i];
        if fld.ty == Type::Float {
            bail!("access task `{}`: float fields have no RTL datapath", func.name);
        }
        let sel = part_select("task_in_data", fld.offset_bits, fld.width_bits);
        let rhs = if fld.width_bits == 64 {
            format!("$signed({sel})")
        } else {
            format!("$signed({{32'd0, {sel}}})")
        };
        let _ = writeln!(field_wires, "  wire signed [63:0] {};", wire_of(p));
        let _ = writeln!(field_wires, "  assign {} = {rhs};", wire_of(p));
    }
    for (dst, src) in &pattern.pre_assigns {
        let rhs = vexpr(src, &|v| wire_of(v))?;
        let _ = writeln!(field_wires, "  wire signed [63:0] {};", wire_of(*dst));
        let _ = writeln!(field_wires, "  assign {} = {rhs};", wire_of(*dst));
    }
    let addr = vexpr(&pattern.index, &|v| wire_of(v))?;
    let cont_sel = part_select("task_in_data", layout.cont_offset_bits, 64);

    let mut out = String::new();
    let _ = writeln!(
        out,
        "// PE for access task `{}` (source fn: {}) — PIPELINED, II={ii}.\n\
         // A new task is accepted every cycle: the address datapath is\n\
         // combinational from the closure and the continuation rides the\n\
         // in-flight FIFO until the memory response returns (paper §II-C).",
        func.name, func.task.as_ref().unwrap().source
    );
    let _ = writeln!(out, "module pe_{name} (");
    let _ = writeln!(out, "  input  wire clk,");
    let _ = writeln!(out, "  input  wire rst_n,");
    let _ = writeln!(out, "  input  wire task_in_valid,");
    let _ = writeln!(out, "  output wire task_in_ready,");
    let _ = writeln!(out, "  input  wire [{}:0] task_in_data,", layout.padded_bits - 1);
    let _ = writeln!(out, "  output wire send_out_valid,");
    let _ = writeln!(out, "  input  wire send_out_ready,");
    let _ = writeln!(out, "  output wire [{}:0] send_out_data,", SEND_BITS - 1);
    let _ = writeln!(out, "  output wire mem_{gname}_req_valid,");
    let _ = writeln!(out, "  input  wire mem_{gname}_req_ready,");
    let _ = writeln!(out, "  output wire mem_{gname}_req_write,");
    let _ = writeln!(out, "  output wire mem_{gname}_req_atomic,");
    let _ = writeln!(out, "  output wire [63:0] mem_{gname}_req_addr,");
    let _ = writeln!(out, "  output wire [63:0] mem_{gname}_req_wdata,");
    let _ = writeln!(out, "  input  wire mem_{gname}_resp_valid,");
    let _ = writeln!(out, "  output wire mem_{gname}_resp_ready,");
    let _ = writeln!(out, "  input  wire [63:0] mem_{gname}_resp_data");
    let _ = writeln!(out, ");");
    out.push_str(&field_wires);
    let _ = writeln!(out, "  wire [63:0] k_in;");
    let _ = writeln!(out, "  assign k_in = {cont_sel};");
    let _ = writeln!(out, "  wire inflight_in_ready;");
    let _ = writeln!(out, "  wire inflight_out_valid;");
    let _ = writeln!(out, "  wire [63:0] k_head;");
    let _ = writeln!(out, "  // Accept when both the memory channel and the FIFO have room.");
    let _ = writeln!(
        out,
        "  assign task_in_ready = mem_{gname}_req_ready && inflight_in_ready;"
    );
    let _ = writeln!(
        out,
        "  assign mem_{gname}_req_valid = task_in_valid && inflight_in_ready;"
    );
    let _ = writeln!(out, "  assign mem_{gname}_req_write = 1'b0;");
    let _ = writeln!(out, "  assign mem_{gname}_req_atomic = 1'b0;");
    let _ = writeln!(out, "  assign mem_{gname}_req_addr = {addr};");
    let _ = writeln!(out, "  assign mem_{gname}_req_wdata = 64'd0;");
    let _ = writeln!(
        out,
        "  bx_fifo #(.WIDTH(64), .DEPTH_LOG2(3)) inflight (\n    \
         .clk(clk), .rst_n(rst_n),\n    \
         .in_valid(task_in_valid && mem_{gname}_req_ready), .in_ready(inflight_in_ready), .in_data(k_in),\n    \
         .out_valid(inflight_out_valid), .out_ready(send_out_valid && send_out_ready), .out_data(k_head)\n  );"
    );
    let _ = writeln!(out, "  assign send_out_valid = mem_{gname}_resp_valid && inflight_out_valid;");
    let _ = writeln!(out, "  assign mem_{gname}_resp_ready = send_out_ready && inflight_out_valid;");
    let _ = writeln!(
        out,
        "  // {{target[129:66], bits[65:2], kind[1:0]}} — kind 1 = BX_DEC.\n  \
         assign send_out_data = {{k_head, mem_{gname}_resp_data, 2'd1}};"
    );
    let _ = writeln!(out, "endmodule");

    Ok(GeneratedPe {
        source: out,
        style: PeStyle::Pipelined { ii },
        states: 0,
        iface: PeInterface {
            has_send: true,
            globals: vec![pattern.arr],
            closure_bits: layout.padded_bits,
            ..Default::default()
        },
    })
}

// ---------------------------------------------------------------------------
// XLA blackbox PE
// ---------------------------------------------------------------------------

fn gen_xla_blackbox(module: &Module, fid: FuncId) -> Result<GeneratedPe> {
    let func = &module.funcs[fid];
    let name = vname(&func.name);
    let layout = closure_layout(func);
    let mut out = String::new();
    let _ = writeln!(
        out,
        "// PE for `extern xla` task `{}` — BLACKBOX.\n\
         // The real datapath is the AOT-compiled XLA/Pallas executable\n\
         // (python/compile/); on silicon this shell fronts an RTL\n\
         // systolic-array macro. Outputs are tied off in the stub.",
        func.name
    );
    let _ = writeln!(out, "module pe_{name} (");
    let _ = writeln!(out, "  input  wire clk,");
    let _ = writeln!(out, "  input  wire rst_n,");
    let _ = writeln!(out, "  input  wire task_in_valid,");
    let _ = writeln!(out, "  output wire task_in_ready,");
    let _ = writeln!(out, "  input  wire [{}:0] task_in_data,", layout.padded_bits - 1);
    let _ = writeln!(out, "  output wire send_out_valid,");
    let _ = writeln!(out, "  input  wire send_out_ready,");
    let _ = writeln!(out, "  output wire [{}:0] send_out_data", SEND_BITS - 1);
    let _ = writeln!(out, ");");
    let _ = writeln!(out, "  assign task_in_ready = 1'b0;");
    let _ = writeln!(out, "  assign send_out_valid = 1'b0;");
    let _ = writeln!(out, "  assign send_out_data = {}'d0;", SEND_BITS);
    let _ = writeln!(out, "endmodule");
    Ok(GeneratedPe {
        source: out,
        style: PeStyle::Blackbox,
        states: 0,
        iface: PeInterface {
            has_send: true,
            closure_bits: layout.padded_bits,
            ..Default::default()
        },
    })
}

// ---------------------------------------------------------------------------
// FSM modules (general tasks and leaf functions)
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq)]
enum FsmKind {
    Task,
    Leaf,
}

/// State allocation: numbered, named states per (block, op, phase).
/// `S_IDLE` is always state 0; ops get `S_B<b>_O<i>` (+ `_W` wait phases
/// for split-phase ops), blocks with value-reading terminators get
/// `S_B<b>_T`, and leaf modules end with `S_DONE`.
struct States {
    names: Vec<String>,
    op_state: HashMap<(usize, usize), usize>,
    wait_state: HashMap<(usize, usize), usize>,
    term_state: HashMap<usize, usize>,
    block_entry: HashMap<usize, usize>,
    done: Option<usize>,
}

impl States {
    fn name(&self, i: usize) -> &str {
        &self.names[i]
    }
}

fn op_has_wait(op: &Op) -> bool {
    matches!(op, Op::Load { .. } | Op::MakeClosure { .. } | Op::Call { .. })
}

/// Does the block need a dedicated terminator state? Branch conditions and
/// return values may read the *last* op's destination, which is only
/// visible one cycle after its state latches it (non-blocking semantics).
fn needs_term_state(block_ops: usize, term: &Term) -> bool {
    match term {
        Term::Branch { .. } | Term::Return(Some(_)) => true,
        _ => block_ops == 0,
    }
}

fn alloc_states(cfg: &Cfg, kind: FsmKind) -> States {
    let mut st = States {
        names: Vec::new(),
        op_state: HashMap::new(),
        wait_state: HashMap::new(),
        term_state: HashMap::new(),
        block_entry: HashMap::new(),
        done: None,
    };
    st.names.push("S_IDLE".to_string());
    for b in cfg.reachable_ids() {
        let block = &cfg.blocks[b];
        let bi = b.index();
        let mut first: Option<usize> = None;
        for (i, op) in block.ops.iter().enumerate() {
            let s = st.names.len();
            st.names.push(format!("S_B{bi}_O{i}"));
            st.op_state.insert((bi, i), s);
            first.get_or_insert(s);
            if op_has_wait(op) {
                let w = st.names.len();
                st.names.push(format!("S_B{bi}_O{i}_W"));
                st.wait_state.insert((bi, i), w);
            }
        }
        if needs_term_state(block.ops.len(), &block.term) {
            let t = st.names.len();
            st.names.push(format!("S_B{bi}_T"));
            st.term_state.insert(bi, t);
            first.get_or_insert(t);
        }
        st.block_entry.insert(bi, first.expect("every block yields at least one state"));
    }
    if kind == FsmKind::Leaf {
        let d = st.names.len();
        st.names.push("S_DONE".to_string());
        st.done = Some(d);
    }
    st
}

/// Stream/memory side-band data collected during emission, rendered as
/// combinational muxes keyed on the state register.
#[derive(Default)]
struct Muxes {
    spawn: Vec<(String, String)>,      // (state, packed word)
    spawn_next: Vec<(String, String)>, // (state, packed word)
    send: Vec<(String, String)>,       // (state, packed word)
    /// global -> (issue states with full request info)
    mem_issue: HashMap<usize, Vec<MemIssue>>,
    /// global -> wait states (response side)
    mem_wait: HashMap<usize, Vec<String>>,
}

struct MemIssue {
    state: String,
    write: bool,
    atomic: bool,
    addr: String,
    wdata: String,
}

struct LeafCall {
    prefix: String,
    callee: FuncId,
    call_state: String,
    wait_state: String,
    args: Vec<String>,
}

fn gen_fsm_module(module: &Module, fid: FuncId, kind: FsmKind) -> Result<GeneratedPe> {
    let func = &module.funcs[fid];
    let Some(cfg) = func.body.as_ref() else {
        bail!("`{}` has no body to lower to RTL", func.name);
    };
    let model = ScheduleModel::default();
    let names = var_names(func);
    let var = |v: VarId| names[v.index()].clone();
    let st = alloc_states(cfg, kind);
    let layout = closure_layout(func);
    let globals = used_globals(func);
    let name = vname(&func.name);

    // Interface discovery.
    let mut has_spawn = false;
    let mut has_next = false;
    let mut has_send = false;
    let mut call_sites: Vec<(BlockId, usize, FuncId, Option<VarId>, Vec<Expr>)> = Vec::new();
    for b in cfg.reachable_ids() {
        for (i, op) in cfg.blocks[b].ops.iter().enumerate() {
            match op {
                Op::SpawnChild { .. } => has_spawn = true,
                Op::MakeClosure { .. } => has_next = true,
                Op::ClosureStore { .. } | Op::CloseSpawns { .. } | Op::SendArgument { .. } => {
                    has_send = true
                }
                Op::Call { dst, callee, args } => {
                    if kind == FsmKind::Leaf {
                        bail!(
                            "leaf `{}` calls `{}`: nested leaf calls are not supported by the \
                             RTL backend yet",
                            func.name,
                            module.funcs[*callee].name
                        );
                    }
                    call_sites.push((b, i, *callee, *dst, args.clone()));
                }
                Op::Spawn { .. } => {
                    bail!("implicit `spawn` reached RTL codegen in `{}`", func.name)
                }
                _ => {}
            }
        }
    }
    if kind == FsmKind::Leaf && (has_spawn || has_next || has_send) {
        bail!("leaf `{}` contains task ops", func.name);
    }

    // Leaf-call instances: index by (block, op).
    let mut leaf_of: HashMap<(usize, usize), usize> = HashMap::new();
    let mut leaf_calls: Vec<LeafCall> = Vec::new();
    for (b, i, callee, _dst, args) in &call_sites {
        let k = leaf_calls.len();
        leaf_of.insert((b.index(), *i), k);
        let call_state = st.name(st.op_state[&(b.index(), *i)]).to_string();
        let wait_state = st.name(st.wait_state[&(b.index(), *i)]).to_string();
        let rendered: Vec<String> =
            args.iter().map(|a| vexpr(a, &|v| var(v))).collect::<Result<_>>()?;
        leaf_calls.push(LeafCall {
            prefix: format!("l{k}"),
            callee: *callee,
            call_state,
            wait_state,
            args: rendered,
        });
    }

    // ---- ports -----------------------------------------------------------
    let mut ports: Vec<String> = vec![
        "  input  wire clk".to_string(),
        "  input  wire rst_n".to_string(),
    ];
    match kind {
        FsmKind::Task => {
            ports.push("  input  wire task_in_valid".to_string());
            ports.push("  output wire task_in_ready".to_string());
            ports.push(format!("  input  wire [{}:0] task_in_data", layout.padded_bits - 1));
            if has_spawn {
                ports.push("  output wire spawn_out_valid".to_string());
                ports.push("  input  wire spawn_out_ready".to_string());
                ports.push(format!("  output wire [{}:0] spawn_out_data", SPAWN_BITS - 1));
            }
            if has_next {
                ports.push("  output wire spawn_next_out_valid".to_string());
                ports.push("  input  wire spawn_next_out_ready".to_string());
                ports.push(format!(
                    "  output wire [{}:0] spawn_next_out_data",
                    SPAWN_NEXT_BITS - 1
                ));
                ports.push("  input  wire addr_in_valid".to_string());
                ports.push("  output wire addr_in_ready".to_string());
                ports.push("  input  wire [63:0] addr_in_data".to_string());
            }
            if has_send {
                ports.push("  output wire send_out_valid".to_string());
                ports.push("  input  wire send_out_ready".to_string());
                ports.push(format!("  output wire [{}:0] send_out_data", SEND_BITS - 1));
            }
        }
        FsmKind::Leaf => {
            ports.push("  input  wire start_valid".to_string());
            ports.push("  output wire start_ready".to_string());
            for p in func.param_ids() {
                ports.push(format!("  input  wire signed [63:0] a_{}", vname(&func.vars[p].name)));
            }
            ports.push("  output wire done_valid".to_string());
            ports.push("  input  wire done_ready".to_string());
            ports.push("  output wire signed [63:0] result".to_string());
        }
    }
    let mut leaf_mems: Vec<(String, GlobalId)> = Vec::new();
    for lc in &leaf_calls {
        for g in used_globals(&module.funcs[lc.callee]) {
            leaf_mems.push((lc.prefix.clone(), g));
        }
    }
    let mem_port = |prefix: &str, gname: &str, ports: &mut Vec<String>| {
        ports.push(format!("  output wire {prefix}mem_{gname}_req_valid"));
        ports.push(format!("  input  wire {prefix}mem_{gname}_req_ready"));
        ports.push(format!("  output wire {prefix}mem_{gname}_req_write"));
        ports.push(format!("  output wire {prefix}mem_{gname}_req_atomic"));
        ports.push(format!("  output wire [63:0] {prefix}mem_{gname}_req_addr"));
        ports.push(format!("  output wire [63:0] {prefix}mem_{gname}_req_wdata"));
        ports.push(format!("  input  wire {prefix}mem_{gname}_resp_valid"));
        ports.push(format!("  output wire {prefix}mem_{gname}_resp_ready"));
        ports.push(format!("  input  wire [63:0] {prefix}mem_{gname}_resp_data"));
    };
    for &g in &globals {
        mem_port("", &vname(&module.globals[g].name), &mut ports);
    }
    for (prefix, g) in &leaf_mems {
        mem_port(&format!("{prefix}_"), &vname(&module.globals[*g].name), &mut ports);
    }

    // ---- walk ops: build always-block arms + muxes -----------------------
    let mut muxes = Muxes::default();
    let mut arms: Vec<(String, String)> = Vec::new(); // (state name, body lines)

    // IDLE arm.
    {
        let mut body = String::new();
        let _ = writeln!(body, "          lat <= 16'd0;");
        let accept = match kind {
            FsmKind::Task => "task_in_valid",
            FsmKind::Leaf => "start_valid",
        };
        let _ = writeln!(body, "          if ({accept}) begin");
        match kind {
            FsmKind::Task => {
                for (i, p) in func.param_ids().enumerate() {
                    let fld = &layout.fields[i];
                    if fld.ty == Type::Float {
                        bail!("task `{}`: float closure fields have no RTL datapath", func.name);
                    }
                    let sel = part_select("task_in_data", fld.offset_bits, fld.width_bits);
                    let rhs = if fld.width_bits == 64 {
                        format!("$signed({sel})")
                    } else {
                        format!("$signed({{32'd0, {sel}}})")
                    };
                    let _ = writeln!(body, "            {} <= {rhs};", var(p));
                }
                let cont = part_select("task_in_data", layout.cont_offset_bits, 64);
                let _ = writeln!(body, "            k_r <= {cont};");
            }
            FsmKind::Leaf => {
                for p in func.param_ids() {
                    let _ = writeln!(
                        body,
                        "            {} <= a_{};",
                        var(p),
                        vname(&func.vars[p].name)
                    );
                }
            }
        }
        for (vid, v) in func.vars.iter() {
            if vid.index() >= func.params {
                if v.ty == Type::Float {
                    bail!("`{}`: float locals have no RTL datapath", func.name);
                }
                let _ = writeln!(body, "            {} <= 64'sd0;", var(vid));
            }
        }
        let entry = st.name(st.block_entry[&cfg.entry.index()]);
        let _ = writeln!(body, "            state <= {entry};");
        let _ = writeln!(body, "          end");
        arms.push(("S_IDLE".to_string(), body));
    }

    // Next-state target after op i of block b completes.
    let next_after = |b: BlockId, i: usize| -> Result<String> {
        let bi = b.index();
        let block = &cfg.blocks[b];
        if i + 1 < block.ops.len() {
            return Ok(st.name(st.op_state[&(bi, i + 1)]).to_string());
        }
        if let Some(&t) = st.term_state.get(&bi) {
            return Ok(st.name(t).to_string());
        }
        static_succ(&st, &block.term, kind)
    };

    for b in cfg.reachable_ids() {
        let bi = b.index();
        let block = &cfg.blocks[b];
        for (i, op) in block.ops.iter().enumerate() {
            let s_name = st.name(st.op_state[&(bi, i)]).to_string();
            let next = next_after(b, i)?;
            let mut body = String::new();
            match op {
                Op::Assign { dst, src } => {
                    let lat = op_cycles(&model, op).max(1) - 1;
                    let rhs = vexpr(src, &|v| var(v))?;
                    let _ = writeln!(body, "          if (lat >= 16'd{lat}) begin");
                    let _ = writeln!(body, "            lat <= 16'd0;");
                    let _ = writeln!(body, "            {} <= {rhs};", var(*dst));
                    let _ = writeln!(body, "            state <= {next};");
                    let _ = writeln!(body, "          end else begin");
                    let _ = writeln!(body, "            lat <= lat + 16'd1;");
                    let _ = writeln!(body, "          end");
                }
                Op::Load { dst, arr, index, .. } => {
                    let gname = vname(&module.globals[*arr].name);
                    let addr = vexpr(index, &|v| var(v))?;
                    muxes.mem_issue.entry(arr.index()).or_default().push(MemIssue {
                        state: s_name.clone(),
                        write: false,
                        atomic: false,
                        addr,
                        wdata: "64'd0".to_string(),
                    });
                    let w_name = st.name(st.wait_state[&(bi, i)]).to_string();
                    let _ = writeln!(body, "          if (mem_{gname}_req_ready) begin");
                    let _ = writeln!(body, "            state <= {w_name};");
                    let _ = writeln!(body, "          end");
                    muxes.mem_wait.entry(arr.index()).or_default().push(w_name.clone());
                    let mut wbody = String::new();
                    let _ = writeln!(wbody, "          if (mem_{gname}_resp_valid) begin");
                    let _ = writeln!(
                        wbody,
                        "            {} <= $signed(mem_{gname}_resp_data);",
                        var(*dst)
                    );
                    let _ = writeln!(wbody, "            state <= {next};");
                    let _ = writeln!(wbody, "          end");
                    arms.push((s_name, body));
                    arms.push((w_name, wbody));
                    continue;
                }
                Op::Store { arr, index, value } | Op::AtomicAdd { arr, index, value } => {
                    let gname = vname(&module.globals[*arr].name);
                    let addr = vexpr(index, &|v| var(v))?;
                    let wdata = vexpr(value, &|v| var(v))?;
                    muxes.mem_issue.entry(arr.index()).or_default().push(MemIssue {
                        state: s_name.clone(),
                        write: true,
                        atomic: matches!(op, Op::AtomicAdd { .. }),
                        addr,
                        wdata,
                    });
                    let _ = writeln!(body, "          if (mem_{gname}_req_ready) begin");
                    let _ = writeln!(body, "            state <= {next};");
                    let _ = writeln!(body, "          end");
                }
                Op::Call { dst, .. } => {
                    let k = leaf_of[&(bi, i)];
                    let prefix = leaf_calls[k].prefix.clone();
                    let w_name = st.name(st.wait_state[&(bi, i)]).to_string();
                    let _ = writeln!(body, "          if ({prefix}_start_ready) begin");
                    let _ = writeln!(body, "            state <= {w_name};");
                    let _ = writeln!(body, "          end");
                    let mut wbody = String::new();
                    let _ = writeln!(wbody, "          if ({prefix}_done_valid) begin");
                    if let Some(d) = dst {
                        let _ = writeln!(wbody, "            {} <= {prefix}_result;", var(*d));
                    }
                    let _ = writeln!(wbody, "            state <= {next};");
                    let _ = writeln!(wbody, "          end");
                    arms.push((s_name, body));
                    arms.push((w_name, wbody));
                    continue;
                }
                Op::MakeClosure { dst, task } => {
                    let tid = task_stream_id(module, *task);
                    let bytes = closure_layout(&module.funcs[*task]).padded_bits / 8;
                    // {task[111:80], cont[79:16], bytes[15:0]}
                    muxes.spawn_next.push((
                        s_name.clone(),
                        format!("{{32'd{tid}, k_r, 16'd{bytes}}}"),
                    ));
                    let w_name = st.name(st.wait_state[&(bi, i)]).to_string();
                    let _ = writeln!(body, "          if (spawn_next_out_ready) begin");
                    let _ = writeln!(body, "            state <= {w_name};");
                    let _ = writeln!(body, "          end");
                    let mut wbody = String::new();
                    let _ = writeln!(wbody, "          if (addr_in_valid) begin");
                    let _ = writeln!(wbody, "            {} <= $signed(addr_in_data);", var(*dst));
                    let _ = writeln!(wbody, "            state <= {next};");
                    let _ = writeln!(wbody, "          end");
                    arms.push((s_name, body));
                    arms.push((w_name, wbody));
                    continue;
                }
                Op::SpawnChild { callee, args, ret } => {
                    if args.len() > MAX_SPAWN_ARGS {
                        bail!(
                            "task `{}` spawned with >{MAX_SPAWN_ARGS} args (widen the spawn word)",
                            module.funcs[*callee].name
                        );
                    }
                    let tid = task_stream_id(module, *callee);
                    let bytes = closure_layout(&module.funcs[*callee]).padded_bits / 8;
                    let ret_s = match ret {
                        RetTarget::Slot { clos, field } => {
                            format!("(({} << 16) | 64'd{field})", var(*clos))
                        }
                        RetTarget::Counter { clos } => {
                            format!("(({} << 16) | 64'd32768)", var(*clos))
                        }
                        RetTarget::Forward => "k_r".to_string(),
                    };
                    let mut words: Vec<String> = vec![
                        format!("32'd{tid}"),
                        ret_s,
                        format!("8'd{}", args.len()),
                        format!("16'd{bytes}"),
                    ];
                    for a in args {
                        words.push(vexpr(a, &|v| var(v))?);
                    }
                    for _ in args.len()..MAX_SPAWN_ARGS {
                        words.push("64'd0".to_string());
                    }
                    // {task[631:600], ret[599:536], nargs[535:528],
                    //  bytes[527:512], arg0..arg7 (arg0 at [511:448])}
                    muxes.spawn.push((s_name.clone(), format!("{{{}}}", words.join(", "))));
                    let _ = writeln!(body, "          if (spawn_out_ready) begin");
                    let _ = writeln!(body, "            state <= {next};");
                    let _ = writeln!(body, "          end");
                }
                Op::ClosureStore { clos, field, value } => {
                    let bits = vexpr(value, &|v| var(v))?;
                    let target = format!("(({} << 16) | 64'd{field})", var(*clos));
                    // kind 0 = BX_READY
                    muxes.send.push((s_name.clone(), format!("{{{target}, {bits}, 2'd0}}")));
                    let _ = writeln!(body, "          if (send_out_ready) begin");
                    let _ = writeln!(body, "            state <= {next};");
                    let _ = writeln!(body, "          end");
                }
                Op::CloseSpawns { clos } => {
                    let target = format!("(({} << 16) | 64'd32768)", var(*clos));
                    // kind 2 = BX_CLOSE
                    muxes.send.push((s_name.clone(), format!("{{{target}, 64'd0, 2'd2}}")));
                    let _ = writeln!(body, "          if (send_out_ready) begin");
                    let _ = writeln!(body, "            state <= {next};");
                    let _ = writeln!(body, "          end");
                }
                Op::SendArgument { value } => {
                    let bits = match value {
                        Some(v) => vexpr(v, &|vv| var(vv))?,
                        None => "64'd0".to_string(),
                    };
                    // kind 1 = BX_DEC
                    muxes.send.push((s_name.clone(), format!("{{k_r, {bits}, 2'd1}}")));
                    let _ = writeln!(body, "          if (send_out_ready) begin");
                    let _ = writeln!(body, "            state <= {next};");
                    let _ = writeln!(body, "          end");
                }
                Op::Spawn { .. } => unreachable!("rejected above"),
            }
            arms.push((s_name, body));
        }
        // Terminator state (branch decision / return value / empty block).
        if let Some(&t) = st.term_state.get(&bi) {
            let mut body = String::new();
            match &block.term {
                Term::Branch { cond, then_, else_ } => {
                    let c = vcond(cond, &|v| var(v))?;
                    let t_s = st.name(st.block_entry[&then_.index()]);
                    let e_s = st.name(st.block_entry[&else_.index()]);
                    let _ = writeln!(body, "          state <= {c} ? {t_s} : {e_s};");
                }
                Term::Return(Some(e)) => {
                    if kind != FsmKind::Leaf {
                        bail!("task `{}` ends in `return` after explicitization", func.name);
                    }
                    let rhs = vexpr(e, &|v| var(v))?;
                    let _ = writeln!(body, "          res_r <= {rhs};");
                    let done = st.name(st.done.expect("leaf has a done state"));
                    let _ = writeln!(body, "          state <= {done};");
                }
                term => {
                    let target = static_succ(&st, term, kind)?;
                    let _ = writeln!(body, "          state <= {target};");
                }
            }
            arms.push((st.name(t).to_string(), body));
        }
    }
    if let Some(d) = st.done {
        let mut body = String::new();
        let _ = writeln!(body, "          if (done_ready) begin");
        let _ = writeln!(body, "            state <= S_IDLE;");
        let _ = writeln!(body, "          end");
        arms.push((st.name(d).to_string(), body));
    }

    // ---- assemble the module --------------------------------------------
    let mut out = String::new();
    let role = func.task.as_ref().map(|t| t.role.name()).unwrap_or("leaf");
    let module_name = match kind {
        FsmKind::Task => format!("pe_{name}"),
        FsmKind::Leaf => format!("leaf_{name}"),
    };
    let _ = writeln!(
        out,
        "// {} `{}` (role: {role}) — FSM+datapath, {} states.",
        if kind == FsmKind::Task { "PE for task" } else { "Leaf function" },
        func.name,
        st.names.len()
    );
    let _ = writeln!(out, "module {module_name} (");
    let _ = writeln!(out, "{}", ports.join(",\n"));
    let _ = writeln!(out, ");");

    for (i, n) in st.names.iter().enumerate() {
        let _ = writeln!(out, "  localparam [15:0] {n} = 16'd{i};");
    }
    let _ = writeln!(out, "  reg [15:0] state;");
    let _ = writeln!(out, "  reg [15:0] lat;");
    if kind == FsmKind::Task {
        let _ = writeln!(out, "  reg [63:0] k_r;");
    } else {
        let _ = writeln!(out, "  reg signed [63:0] res_r;");
    }
    for (vid, _) in func.vars.iter() {
        let _ = writeln!(out, "  reg signed [63:0] {};", var(vid));
    }
    for lc in &leaf_calls {
        let p = &lc.prefix;
        let _ = writeln!(out, "  wire {p}_start_ready;");
        let _ = writeln!(out, "  wire {p}_done_valid;");
        let _ = writeln!(out, "  wire signed [63:0] {p}_result;");
        for (j, _) in lc.args.iter().enumerate() {
            let _ = writeln!(out, "  wire signed [63:0] {p}_arg{j};");
        }
    }

    // Handshake outputs.
    match kind {
        FsmKind::Task => {
            let _ = writeln!(out, "  assign task_in_ready = (state == S_IDLE);");
        }
        FsmKind::Leaf => {
            let _ = writeln!(out, "  assign start_ready = (state == S_IDLE);");
            let done = st.name(st.done.expect("leaf has a done state"));
            let _ = writeln!(out, "  assign done_valid = (state == {done});");
            let _ = writeln!(out, "  assign result = res_r;");
        }
    }
    let or_states = |list: &[String]| -> String {
        if list.is_empty() {
            "1'b0".to_string()
        } else {
            list.iter()
                .map(|s| format!("(state == {s})"))
                .collect::<Vec<_>>()
                .join(" || ")
        }
    };
    let mux = |items: &[(String, String)], width: u32| -> String {
        let mut s = String::new();
        for (state, word) in items {
            s.push_str(&format!("(state == {state}) ? {word} :\n      "));
        }
        s.push_str(&format!("{width}'d0"));
        s
    };
    if has_spawn {
        let states: Vec<String> = muxes.spawn.iter().map(|(s, _)| s.clone()).collect();
        let _ = writeln!(out, "  assign spawn_out_valid = {};", or_states(&states));
        let _ = writeln!(out, "  assign spawn_out_data =\n      {};", mux(&muxes.spawn, SPAWN_BITS));
    }
    if has_next {
        let states: Vec<String> = muxes.spawn_next.iter().map(|(s, _)| s.clone()).collect();
        let _ = writeln!(out, "  assign spawn_next_out_valid = {};", or_states(&states));
        let _ = writeln!(
            out,
            "  assign spawn_next_out_data =\n      {};",
            mux(&muxes.spawn_next, SPAWN_NEXT_BITS)
        );
        let mut waits: Vec<String> = Vec::new();
        for b in cfg.reachable_ids() {
            for (i, op) in cfg.blocks[b].ops.iter().enumerate() {
                if matches!(op, Op::MakeClosure { .. }) {
                    waits.push(st.name(st.wait_state[&(b.index(), i)]).to_string());
                }
            }
        }
        let _ = writeln!(out, "  assign addr_in_ready = {};", or_states(&waits));
    }
    if has_send {
        let states: Vec<String> = muxes.send.iter().map(|(s, _)| s.clone()).collect();
        let _ = writeln!(out, "  assign send_out_valid = {};", or_states(&states));
        let _ = writeln!(out, "  assign send_out_data =\n      {};", mux(&muxes.send, SEND_BITS));
    }
    for &g in &globals {
        let gname = vname(&module.globals[g].name);
        let issues = muxes.mem_issue.get(&g.index()).map(Vec::as_slice).unwrap_or(&[]);
        let all: Vec<String> = issues.iter().map(|m| m.state.clone()).collect();
        let writes: Vec<String> =
            issues.iter().filter(|m| m.write).map(|m| m.state.clone()).collect();
        let atomics: Vec<String> =
            issues.iter().filter(|m| m.atomic).map(|m| m.state.clone()).collect();
        let _ = writeln!(out, "  assign mem_{gname}_req_valid = {};", or_states(&all));
        let _ = writeln!(out, "  assign mem_{gname}_req_write = {};", or_states(&writes));
        let _ = writeln!(out, "  assign mem_{gname}_req_atomic = {};", or_states(&atomics));
        let addr_items: Vec<(String, String)> =
            issues.iter().map(|m| (m.state.clone(), m.addr.clone())).collect();
        let _ = writeln!(out, "  assign mem_{gname}_req_addr =\n      {};", mux(&addr_items, 64));
        let wdata_items: Vec<(String, String)> = issues
            .iter()
            .filter(|m| m.write)
            .map(|m| (m.state.clone(), m.wdata.clone()))
            .collect();
        let _ = writeln!(out, "  assign mem_{gname}_req_wdata =\n      {};", mux(&wdata_items, 64));
        let waits = muxes.mem_wait.get(&g.index()).cloned().unwrap_or_default();
        let _ = writeln!(out, "  assign mem_{gname}_resp_ready = {};", or_states(&waits));
    }

    // Leaf instances.
    for (k, lc) in leaf_calls.iter().enumerate() {
        let p = &lc.prefix;
        let leaf = &module.funcs[lc.callee];
        let leaf_name = vname(&leaf.name);
        for (j, a) in lc.args.iter().enumerate() {
            let _ = writeln!(out, "  assign {p}_arg{j} = {a};");
        }
        let mut conns: Vec<String> = vec![
            "    .clk(clk)".to_string(),
            "    .rst_n(rst_n)".to_string(),
            format!("    .start_valid(state == {})", lc.call_state),
            format!("    .start_ready({p}_start_ready)"),
        ];
        for (j, pid) in leaf.param_ids().enumerate() {
            conns.push(format!("    .a_{}({p}_arg{j})", vname(&leaf.vars[pid].name)));
        }
        conns.push(format!("    .done_valid({p}_done_valid)"));
        conns.push(format!("    .done_ready(state == {})", lc.wait_state));
        conns.push(format!("    .result({p}_result)"));
        for g in used_globals(leaf) {
            let gname = vname(&module.globals[g].name);
            for suffix in [
                "req_valid",
                "req_ready",
                "req_write",
                "req_atomic",
                "req_addr",
                "req_wdata",
                "resp_valid",
                "resp_ready",
                "resp_data",
            ] {
                conns.push(format!(
                    "    .mem_{gname}_{suffix}({p}_mem_{gname}_{suffix})"
                ));
            }
        }
        let _ = writeln!(out, "  leaf_{leaf_name} u_leaf{k} (\n{}\n  );", conns.join(",\n"));
    }

    // The FSM.
    let _ = writeln!(out, "  always @(posedge clk) begin");
    let _ = writeln!(out, "    if (!rst_n) begin");
    let _ = writeln!(out, "      state <= S_IDLE;");
    let _ = writeln!(out, "      lat <= 16'd0;");
    let _ = writeln!(out, "    end else begin");
    let _ = writeln!(out, "      case (state)");
    for (s_name, body) in &arms {
        let _ = writeln!(out, "        {s_name}: begin");
        out.push_str(body);
        let _ = writeln!(out, "        end");
    }
    let _ = writeln!(out, "        default: state <= S_IDLE;");
    let _ = writeln!(out, "      endcase");
    let _ = writeln!(out, "    end");
    let _ = writeln!(out, "  end");
    let _ = writeln!(out, "endmodule");

    Ok(GeneratedPe {
        source: out,
        style: PeStyle::Fsm,
        states: st.names.len() as u32,
        iface: PeInterface {
            has_spawn,
            has_spawn_next: has_next,
            has_send,
            globals,
            leaf_mems,
            closure_bits: layout.padded_bits,
        },
    })
}

/// Static successor state for terminators that read no values.
fn static_succ(st: &States, term: &Term, kind: FsmKind) -> Result<String> {
    match term {
        Term::Jump(t) => Ok(st.name(st.block_entry[&t.index()]).to_string()),
        Term::Halt => Ok("S_IDLE".to_string()),
        Term::Return(None) => match kind {
            FsmKind::Leaf => Ok(st.name(st.done.expect("leaf has a done state")).to_string()),
            FsmKind::Task => bail!("task ends in `return` after explicitization"),
        },
        Term::Return(Some(_)) | Term::Branch { .. } => {
            unreachable!("value-reading terminators get a dedicated state")
        }
        Term::Sync { .. } => bail!("`sync` terminator reached RTL codegen"),
    }
}
