//! System-level Verilog: the support package, the per-system dispatch
//! stub and the top wrapper instantiating PEs and task queues.
//!
//! The wrapper mirrors HardCilk's architecture (paper §II-B): every task
//! type gets a closure queue feeding its PE; PE spawn/send/spawn_next
//! streams flow into a dispatch component that owns closure allocation,
//! argument routing and the virtual steal network. Here the dispatch is an
//! interface-complete **stub** (inputs always ready, outputs idle) — the
//! real scheduler is HardCilk's; Bombyx's contribution is the PEs and
//! their contracts. Memory request/response ports are exported per PE at
//! the top level, one AXI adapter per port, as in the HLS flow.

use std::fmt::Write as _;

use crate::ir::cfg::Module;

use super::pe_gen::{GeneratedPe, SEND_BITS, SPAWN_BITS, SPAWN_NEXT_BITS};
use super::verilog::vname;

/// The shared support package: one synthesizable ready/valid FIFO used for
/// task queues and in-flight tracking.
pub fn gen_package() -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "// Bombyx RTL support package — generated, do not edit.\n\
         // bx_fifo: power-of-two ready/valid FIFO (task queues, in-flight\n\
         // continuation tracking in pipelined access PEs)."
    );
    out.push_str(
        "module bx_fifo #(\n\
         \x20 parameter WIDTH = 64,\n\
         \x20 parameter DEPTH_LOG2 = 4\n\
         ) (\n\
         \x20 input  wire clk,\n\
         \x20 input  wire rst_n,\n\
         \x20 input  wire in_valid,\n\
         \x20 output wire in_ready,\n\
         \x20 input  wire [WIDTH-1:0] in_data,\n\
         \x20 output wire out_valid,\n\
         \x20 input  wire out_ready,\n\
         \x20 output wire [WIDTH-1:0] out_data\n\
         );\n\
         \x20 reg [WIDTH-1:0] store [0:(1 << DEPTH_LOG2) - 1];\n\
         \x20 reg [DEPTH_LOG2:0] rd_ptr;\n\
         \x20 reg [DEPTH_LOG2:0] wr_ptr;\n\
         \x20 wire [DEPTH_LOG2:0] count;\n\
         \x20 assign count = wr_ptr - rd_ptr;\n\
         \x20 assign in_ready = (count != (1 << DEPTH_LOG2));\n\
         \x20 assign out_valid = (count != 0);\n\
         \x20 assign out_data = store[rd_ptr[DEPTH_LOG2-1:0]];\n\
         \x20 always @(posedge clk) begin\n\
         \x20   if (!rst_n) begin\n\
         \x20     rd_ptr <= 0;\n\
         \x20     wr_ptr <= 0;\n\
         \x20   end else begin\n\
         \x20     if (in_valid && in_ready) begin\n\
         \x20       store[wr_ptr[DEPTH_LOG2-1:0]] <= in_data;\n\
         \x20       wr_ptr <= wr_ptr + 1'b1;\n\
         \x20     end\n\
         \x20     if (out_valid && out_ready) begin\n\
         \x20       rd_ptr <= rd_ptr + 1'b1;\n\
         \x20     end\n\
         \x20   end\n\
         \x20 end\n\
         endmodule\n",
    );
    out
}

/// The dispatch/steal-network stub plus the top-level wrapper.
pub fn gen_top(module: &Module, system_name: &str, pes: &[(String, GeneratedPe)]) -> String {
    let sys = vname(system_name);
    let mut out = String::new();

    // ---- dispatch stub ---------------------------------------------------
    let _ = writeln!(
        out,
        "// Dispatch STUB for `{system_name}`: interface-complete placeholder\n\
         // for HardCilk's scheduler (closure allocation, send_argument\n\
         // routing, task dispatch, virtual steal network). Inputs are\n\
         // always ready, outputs idle — replace with the real scheduler\n\
         // to close the system."
    );
    let mut ports: Vec<String> = vec![
        "  input  wire clk".to_string(),
        "  input  wire rst_n".to_string(),
        "  input  wire host_spawn_valid".to_string(),
        "  output wire host_spawn_ready".to_string(),
        format!("  input  wire [{}:0] host_spawn_data", SPAWN_BITS - 1),
    ];
    let mut stub_body: Vec<String> = vec!["  assign host_spawn_ready = 1'b1;".to_string()];
    for (task, pe) in pes {
        let t = vname(task);
        if pe.iface.has_spawn {
            ports.push(format!("  input  wire {t}_spawn_valid"));
            ports.push(format!("  output wire {t}_spawn_ready"));
            ports.push(format!("  input  wire [{}:0] {t}_spawn_data", SPAWN_BITS - 1));
            stub_body.push(format!("  assign {t}_spawn_ready = 1'b1;"));
        }
        if pe.iface.has_spawn_next {
            ports.push(format!("  input  wire {t}_spawn_next_valid"));
            ports.push(format!("  output wire {t}_spawn_next_ready"));
            ports.push(format!(
                "  input  wire [{}:0] {t}_spawn_next_data",
                SPAWN_NEXT_BITS - 1
            ));
            ports.push(format!("  output wire {t}_addr_valid"));
            ports.push(format!("  input  wire {t}_addr_ready"));
            ports.push(format!("  output wire [63:0] {t}_addr_data"));
            stub_body.push(format!("  assign {t}_spawn_next_ready = 1'b1;"));
            stub_body.push(format!("  assign {t}_addr_valid = 1'b0;"));
            stub_body.push(format!("  assign {t}_addr_data = 64'd0;"));
        }
        if pe.iface.has_send {
            ports.push(format!("  input  wire {t}_send_valid"));
            ports.push(format!("  output wire {t}_send_ready"));
            ports.push(format!("  input  wire [{}:0] {t}_send_data", SEND_BITS - 1));
            stub_body.push(format!("  assign {t}_send_ready = 1'b1;"));
        }
        let w = pe.iface.closure_bits;
        ports.push(format!("  output wire {t}_q_valid"));
        ports.push(format!("  input  wire {t}_q_ready"));
        ports.push(format!("  output wire [{}:0] {t}_q_data", w - 1));
        stub_body.push(format!("  assign {t}_q_valid = 1'b0;"));
        stub_body.push(format!("  assign {t}_q_data = {w}'d0;"));
    }
    let _ = writeln!(out, "module {sys}_dispatch (");
    let _ = writeln!(out, "{}", ports.join(",\n"));
    let _ = writeln!(out, ");");
    for line in &stub_body {
        let _ = writeln!(out, "{line}");
    }
    let _ = writeln!(out, "endmodule");
    out.push('\n');

    // ---- top wrapper -----------------------------------------------------
    let _ = writeln!(
        out,
        "// Top-level wrapper for `{system_name}`: task queues + PEs +\n\
         // dispatch stub. Memory ports are exported per PE (one AXI\n\
         // adapter per port)."
    );
    let mut tports: Vec<String> = vec![
        "  input  wire clk".to_string(),
        "  input  wire rst_n".to_string(),
        "  input  wire host_spawn_valid".to_string(),
        "  output wire host_spawn_ready".to_string(),
        format!("  input  wire [{}:0] host_spawn_data", SPAWN_BITS - 1),
    ];
    let mem_port_names = [
        ("output wire ", "req_valid"),
        ("input  wire ", "req_ready"),
        ("output wire ", "req_write"),
        ("output wire ", "req_atomic"),
        ("output wire [63:0] ", "req_addr"),
        ("output wire [63:0] ", "req_wdata"),
        ("input  wire ", "resp_valid"),
        ("output wire ", "resp_ready"),
        ("input  wire [63:0] ", "resp_data"),
    ];
    for (task, pe) in pes {
        let t = vname(task);
        for &g in &pe.iface.globals {
            let gname = vname(&module.globals[g].name);
            for (dir, suffix) in mem_port_names {
                tports.push(format!("  {dir}{t}_mem_{gname}_{suffix}"));
            }
        }
        for (prefix, g) in &pe.iface.leaf_mems {
            let gname = vname(&module.globals[*g].name);
            for (dir, suffix) in mem_port_names {
                tports.push(format!("  {dir}{t}_{prefix}_mem_{gname}_{suffix}"));
            }
        }
    }
    let _ = writeln!(out, "module {sys}_top (");
    let _ = writeln!(out, "{}", tports.join(",\n"));
    let _ = writeln!(out, ");");

    // Inter-component wires.
    for (task, pe) in pes {
        let t = vname(task);
        let w = pe.iface.closure_bits;
        let _ = writeln!(out, "  wire {t}_disp_q_valid;");
        let _ = writeln!(out, "  wire {t}_disp_q_ready;");
        let _ = writeln!(out, "  wire [{}:0] {t}_disp_q_data;", w - 1);
        let _ = writeln!(out, "  wire {t}_task_valid;");
        let _ = writeln!(out, "  wire {t}_task_ready;");
        let _ = writeln!(out, "  wire [{}:0] {t}_task_data;", w - 1);
        if pe.iface.has_spawn {
            let _ = writeln!(out, "  wire {t}_spawn_valid;");
            let _ = writeln!(out, "  wire {t}_spawn_ready;");
            let _ = writeln!(out, "  wire [{}:0] {t}_spawn_data;", SPAWN_BITS - 1);
        }
        if pe.iface.has_spawn_next {
            let _ = writeln!(out, "  wire {t}_spawn_next_valid;");
            let _ = writeln!(out, "  wire {t}_spawn_next_ready;");
            let _ = writeln!(out, "  wire [{}:0] {t}_spawn_next_data;", SPAWN_NEXT_BITS - 1);
            let _ = writeln!(out, "  wire {t}_addr_valid;");
            let _ = writeln!(out, "  wire {t}_addr_ready;");
            let _ = writeln!(out, "  wire [63:0] {t}_addr_data;");
        }
        if pe.iface.has_send {
            let _ = writeln!(out, "  wire {t}_send_valid;");
            let _ = writeln!(out, "  wire {t}_send_ready;");
            let _ = writeln!(out, "  wire [{}:0] {t}_send_data;", SEND_BITS - 1);
        }
    }

    // Task queues.
    for (task, pe) in pes {
        let t = vname(task);
        let _ = writeln!(
            out,
            "  bx_fifo #(.WIDTH({w}), .DEPTH_LOG2(4)) q_{t} (\n    \
             .clk(clk), .rst_n(rst_n),\n    \
             .in_valid({t}_disp_q_valid), .in_ready({t}_disp_q_ready), .in_data({t}_disp_q_data),\n    \
             .out_valid({t}_task_valid), .out_ready({t}_task_ready), .out_data({t}_task_data)\n  );",
            w = pe.iface.closure_bits
        );
    }

    // PE instances.
    for (task, pe) in pes {
        let t = vname(task);
        let mut conns: Vec<String> = vec![
            "    .clk(clk)".to_string(),
            "    .rst_n(rst_n)".to_string(),
            format!("    .task_in_valid({t}_task_valid)"),
            format!("    .task_in_ready({t}_task_ready)"),
            format!("    .task_in_data({t}_task_data)"),
        ];
        if pe.iface.has_spawn {
            conns.push(format!("    .spawn_out_valid({t}_spawn_valid)"));
            conns.push(format!("    .spawn_out_ready({t}_spawn_ready)"));
            conns.push(format!("    .spawn_out_data({t}_spawn_data)"));
        }
        if pe.iface.has_spawn_next {
            conns.push(format!("    .spawn_next_out_valid({t}_spawn_next_valid)"));
            conns.push(format!("    .spawn_next_out_ready({t}_spawn_next_ready)"));
            conns.push(format!("    .spawn_next_out_data({t}_spawn_next_data)"));
            conns.push(format!("    .addr_in_valid({t}_addr_valid)"));
            conns.push(format!("    .addr_in_ready({t}_addr_ready)"));
            conns.push(format!("    .addr_in_data({t}_addr_data)"));
        }
        if pe.iface.has_send {
            conns.push(format!("    .send_out_valid({t}_send_valid)"));
            conns.push(format!("    .send_out_ready({t}_send_ready)"));
            conns.push(format!("    .send_out_data({t}_send_data)"));
        }
        for &g in &pe.iface.globals {
            let gname = vname(&module.globals[g].name);
            for (_, suffix) in mem_port_names {
                conns.push(format!(
                    "    .mem_{gname}_{suffix}({t}_mem_{gname}_{suffix})"
                ));
            }
        }
        for (prefix, g) in &pe.iface.leaf_mems {
            let gname = vname(&module.globals[*g].name);
            for (_, suffix) in mem_port_names {
                conns.push(format!(
                    "    .{prefix}_mem_{gname}_{suffix}({t}_{prefix}_mem_{gname}_{suffix})"
                ));
            }
        }
        let _ = writeln!(out, "  pe_{t} u_{t} (\n{}\n  );", conns.join(",\n"));
    }

    // Dispatch stub instance.
    let mut conns: Vec<String> = vec![
        "    .clk(clk)".to_string(),
        "    .rst_n(rst_n)".to_string(),
        "    .host_spawn_valid(host_spawn_valid)".to_string(),
        "    .host_spawn_ready(host_spawn_ready)".to_string(),
        "    .host_spawn_data(host_spawn_data)".to_string(),
    ];
    for (task, pe) in pes {
        let t = vname(task);
        if pe.iface.has_spawn {
            conns.push(format!("    .{t}_spawn_valid({t}_spawn_valid)"));
            conns.push(format!("    .{t}_spawn_ready({t}_spawn_ready)"));
            conns.push(format!("    .{t}_spawn_data({t}_spawn_data)"));
        }
        if pe.iface.has_spawn_next {
            conns.push(format!("    .{t}_spawn_next_valid({t}_spawn_next_valid)"));
            conns.push(format!("    .{t}_spawn_next_ready({t}_spawn_next_ready)"));
            conns.push(format!("    .{t}_spawn_next_data({t}_spawn_next_data)"));
            conns.push(format!("    .{t}_addr_valid({t}_addr_valid)"));
            conns.push(format!("    .{t}_addr_ready({t}_addr_ready)"));
            conns.push(format!("    .{t}_addr_data({t}_addr_data)"));
        }
        if pe.iface.has_send {
            conns.push(format!("    .{t}_send_valid({t}_send_valid)"));
            conns.push(format!("    .{t}_send_ready({t}_send_ready)"));
            conns.push(format!("    .{t}_send_data({t}_send_data)"));
        }
        conns.push(format!("    .{t}_q_valid({t}_disp_q_valid)"));
        conns.push(format!("    .{t}_q_ready({t}_disp_q_ready)"));
        conns.push(format!("    .{t}_q_data({t}_disp_q_data)"));
    }
    let _ = writeln!(out, "  {sys}_dispatch u_dispatch (\n{}\n  );", conns.join(",\n"));
    let _ = writeln!(out, "endmodule");
    out
}
