//! Low-level Verilog rendering: identifiers, literals and expressions.
//!
//! Everything the RTL backend emits is Verilog-2001. The datapath is a
//! uniform 64-bit signed world (matching the IR's `int` semantics):
//! comparisons and logical operators produce `64'sd0/64'sd1` via the
//! conditional operator, `>>` renders as the arithmetic `>>>`, and every
//! sub-expression is parenthesized so operator precedence can never bite.
//! Floats have no RTL datapath — float expressions are rejected with a
//! descriptive error (the float path in Bombyx is the XLA blackbox PE).

use anyhow::{bail, Result};

use crate::frontend::ast::{BinOp, UnOp};
use crate::ir::expr::{Builtin, Expr, VarId};

/// Sanitize a task/function name into a Verilog identifier (mirrors the
/// HLS backend's `cname` so file and module names line up across targets).
pub fn vname(name: &str) -> String {
    name.replace("__", "_k_").replace(|c: char| !c.is_alphanumeric() && c != '_', "_")
}

/// A 64-bit signed literal for any `i64`, including `i64::MIN`.
pub fn vlit(v: i64) -> String {
    if v >= 0 {
        format!("64'sd{v}")
    } else if v == i64::MIN {
        "$signed(64'h8000000000000000)".to_string()
    } else {
        format!("(-64'sd{})", -v)
    }
}

/// Render an expression as a 64-bit signed Verilog expression. `var` maps
/// a variable to the register/wire name carrying its value.
pub fn vexpr(e: &Expr, var: &dyn Fn(VarId) -> String) -> Result<String> {
    Ok(match e {
        Expr::ConstI(v) => vlit(*v),
        Expr::ConstB(b) => vlit(i64::from(*b)),
        Expr::ConstF(_) | Expr::IntToFloat(_) => {
            bail!("float expressions have no RTL datapath (floats run on the XLA blackbox PE)")
        }
        Expr::Var(v) => var(*v),
        Expr::Unary(op, inner) => {
            let a = vexpr(inner, var)?;
            match op {
                UnOp::Neg => format!("(-{a})"),
                UnOp::Not => format!("(({a} == 64'sd0) ? 64'sd1 : 64'sd0)"),
            }
        }
        Expr::Builtin(b, args) => {
            let rendered: Vec<String> =
                args.iter().map(|a| vexpr(a, var)).collect::<Result<_>>()?;
            match b {
                Builtin::Min => {
                    format!(
                        "(({a} < {b}) ? {a} : {b})",
                        a = rendered[0],
                        b = rendered[1]
                    )
                }
                Builtin::Max => {
                    format!(
                        "(({a} > {b}) ? {a} : {b})",
                        a = rendered[0],
                        b = rendered[1]
                    )
                }
                Builtin::Abs => {
                    format!("(({a} < 64'sd0) ? (-{a}) : {a})", a = rendered[0])
                }
            }
        }
        Expr::Binary(op, lhs, rhs) => {
            let a = vexpr(lhs, var)?;
            let b = vexpr(rhs, var)?;
            match op {
                BinOp::Add => format!("({a} + {b})"),
                BinOp::Sub => format!("({a} - {b})"),
                BinOp::Mul => format!("({a} * {b})"),
                BinOp::Div => format!("({a} / {b})"),
                BinOp::Rem => format!("({a} % {b})"),
                BinOp::Shl => format!("({a} << {b})"),
                // Arithmetic shift: the operands are signed, `>>>` keeps
                // the IR's i64 semantics.
                BinOp::Shr => format!("({a} >>> {b})"),
                BinOp::BitAnd => format!("({a} & {b})"),
                BinOp::BitOr => format!("({a} | {b})"),
                BinOp::BitXor => format!("({a} ^ {b})"),
                BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge | BinOp::Eq | BinOp::Ne => {
                    format!("(({a} {} {b}) ? 64'sd1 : 64'sd0)", op.symbol())
                }
                BinOp::And => {
                    format!("((({a} != 64'sd0) && ({b} != 64'sd0)) ? 64'sd1 : 64'sd0)")
                }
                BinOp::Or => {
                    format!("((({a} != 64'sd0) || ({b} != 64'sd0)) ? 64'sd1 : 64'sd0)")
                }
            }
        }
    })
}

/// Render an expression as a 1-bit condition.
pub fn vcond(e: &Expr, var: &dyn Fn(VarId) -> String) -> Result<String> {
    Ok(format!("({} != 64'sd0)", vexpr(e, var)?))
}

/// A `data[msb:lsb]` part-select for a closure field.
pub fn part_select(signal: &str, offset_bits: u32, width_bits: u32) -> String {
    format!("{signal}[{}:{}]", offset_bits + width_bits - 1, offset_bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_cover_the_i64_range() {
        assert_eq!(vlit(0), "64'sd0");
        assert_eq!(vlit(42), "64'sd42");
        assert_eq!(vlit(-7), "(-64'sd7)");
        assert_eq!(vlit(i64::MIN), "$signed(64'h8000000000000000)");
    }

    #[test]
    fn names_match_the_hls_backend() {
        assert_eq!(vname("fib__k1"), "fib_k_k1");
        assert_eq!(vname("adj_off_access"), "adj_off_access");
    }

    #[test]
    fn comparisons_produce_select_form() {
        let e = Expr::Binary(
            BinOp::Lt,
            Box::new(Expr::ConstI(1)),
            Box::new(Expr::ConstI(2)),
        );
        let s = vexpr(&e, &|_| unreachable!()).unwrap();
        assert_eq!(s, "((64'sd1 < 64'sd2) ? 64'sd1 : 64'sd0)");
    }

    #[test]
    fn floats_are_rejected() {
        let e = Expr::ConstF(1.0);
        assert!(vexpr(&e, &|_| unreachable!()).is_err());
    }
}
