//! End-to-end drivers for the paper's experiments and the relax workload.
//!
//! Both experiment drivers are built on [`CompileSession`]: the workload is
//! lowered once per session and every simulated configuration (PE counts,
//! memory latencies, graphs) reuses the cached explicit module — which is
//! what makes the sweep benches scale without re-running the compiler per
//! data point.

use anyhow::{anyhow, Result};

use crate::interp::Memory;
use crate::ir::expr::Value;
use crate::lower::{CompileOptions, CompileSession};
use crate::runtime::{RelaxXla, XlaRuntime};
use crate::sim::{NoSimXla, SimConfig, SimStats};
use crate::workloads::{bfs, graphgen::CsrGraph, relax};

/// Result of the paper's §III experiment on one graph.
#[derive(Clone, Debug)]
pub struct BfsComparison {
    pub nodes: usize,
    pub plain_cycles: u64,
    pub dae_cycles: u64,
    pub plain_stats: SimStats,
    pub dae_stats: SimStats,
}

impl BfsComparison {
    pub fn reduction(&self) -> f64 {
        1.0 - self.dae_cycles as f64 / self.plain_cycles as f64
    }
}

/// The paper's §III experiment pair, compiled once: the plain BFS and the
/// DAE-annotated BFS as two [`CompileSession`]s. Call [`BfsExperiment::run`]
/// per graph/config without re-lowering anything.
pub struct BfsExperiment {
    pub plain: CompileSession,
    pub dae: CompileSession,
}

impl BfsExperiment {
    pub fn new() -> Result<BfsExperiment> {
        Ok(BfsExperiment {
            plain: CompileSession::new("bfs", bfs::BFS_SRC, &CompileOptions::no_dae())?,
            dae: CompileSession::new("bfs_dae", bfs::BFS_DAE_SRC, &CompileOptions::standard())?,
        })
    }

    /// Run the DAE-vs-non-DAE HardCilk comparison on a graph.
    pub fn run(&self, graph: &CsrGraph, config: &SimConfig) -> Result<BfsComparison> {
        let run_one = |session: &CompileSession| -> Result<SimStats> {
            let mut mem = session.memory();
            bfs::init_memory(session.explicit(), &mut mem, graph)?;
            let (_, mem, stats) =
                session.simulate(mem, "visit", &[Value::I64(0)], config, &mut NoSimXla)?;
            bfs::check_all_visited(session.explicit(), &mem, graph)?;
            Ok(stats)
        };
        let plain_stats = run_one(&self.plain)?;
        let dae_stats = run_one(&self.dae)?;
        Ok(BfsComparison {
            nodes: graph.nodes(),
            plain_cycles: plain_stats.cycles,
            dae_cycles: dae_stats.cycles,
            plain_stats,
            dae_stats,
        })
    }
}

impl BfsExperiment {
    /// Worker threads [`BfsExperiment::run_grid`] uses for a grid of `n`
    /// configurations (exposed so benches can report the real fan-out).
    pub fn grid_workers(n: usize) -> usize {
        crate::util::parallel::default_workers(n)
    }

    /// Run a whole grid of simulator configurations, sharded across OS
    /// threads via [`crate::util::parallel::shard_map`] (the same idiom
    /// `lower::compile_batch` uses for the compiler side). The two
    /// compile sessions are only read (each configuration builds its own
    /// memory image), so every worker shares `&self`; results come back
    /// in `configs` order. This is what lets the `pe_sweep` /
    /// `memlat_sweep` benches scale with cores instead of walking the
    /// grid serially.
    pub fn run_grid(
        &self,
        graph: &CsrGraph,
        configs: &[SimConfig],
    ) -> Result<Vec<BfsComparison>> {
        let workers = BfsExperiment::grid_workers(configs.len());
        crate::util::parallel::shard_map(configs, workers, |cfg| self.run(graph, cfg))
            .into_iter()
            .collect()
    }
}

/// One-shot convenience wrapper (compiles both variants, runs one graph).
pub fn run_bfs_comparison(graph: &CsrGraph, config: &SimConfig) -> Result<BfsComparison> {
    BfsExperiment::new()?.run(graph, config)
}

/// Result of a relax end-to-end run on the simulator with the XLA PE.
#[derive(Clone, Debug)]
pub struct RelaxRun {
    pub nodes_expanded: u64,
    pub cycles: u64,
    pub xla_batches: u64,
    /// Sum of final feature values (fingerprint for equivalence checks).
    pub feat_checksum: f64,
}

/// The relax workload compiled once; both the batched-XLA and the scalar
/// reference datapaths run against the same cached explicit module.
pub struct RelaxExperiment {
    session: CompileSession,
}

impl RelaxExperiment {
    pub fn new() -> Result<RelaxExperiment> {
        Ok(RelaxExperiment {
            session: CompileSession::new("relax", relax::RELAX_SRC, &CompileOptions::no_dae())?,
        })
    }

    pub fn session(&self) -> &CompileSession {
        &self.session
    }

    /// Simulate with the AOT XLA datapath. `runtime` must have the relax
    /// artifacts loaded (`make artifacts`).
    pub fn run_sim(
        &self,
        runtime: XlaRuntime,
        graph: &CsrGraph,
        seed: u64,
        config: &SimConfig,
    ) -> Result<RelaxRun> {
        let m = self.session.explicit();
        let mut mem = self.session.memory();
        relax::init_memory(m, &mut mem, graph, seed)?;
        let mut xla = RelaxXla::new(runtime, m, seed)?;
        let (_, mem, stats) =
            self.session.simulate(mem, "expand", &[Value::I64(0)], config, &mut xla)?;
        let work = mem.dump_i64(
            m.global_by_name("work_done")
                .ok_or_else(|| anyhow!("no work_done global"))?,
        )[0] as u64;
        let feat = mem.dump_f32(m.global_by_name("feat").unwrap());
        Ok(RelaxRun {
            nodes_expanded: work,
            cycles: stats.cycles,
            xla_batches: stats.xla_batches,
            feat_checksum: feat.iter().map(|&v| v as f64).sum(),
        })
    }

    /// The same run with the scalar reference datapath (no XLA) — used to
    /// verify the batched path end to end.
    pub fn run_scalar(
        &self,
        graph: &CsrGraph,
        seed: u64,
        config: &SimConfig,
    ) -> Result<RelaxRun> {
        let m = self.session.explicit();
        let mut mem = self.session.memory();
        relax::init_memory(m, &mut mem, graph, seed)?;

        /// Scalar datapath over simulator memory (reference mode).
        struct InlineScalar {
            w: Vec<f32>,
            b: Vec<f32>,
            feat: crate::ir::GlobalId,
        }
        impl crate::sim::SimXla for InlineScalar {
            fn exec_batch(
                &mut self,
                _name: &str,
                batch: &[Vec<Value>],
                memory: &mut Memory,
            ) -> Result<Vec<Value>> {
                let f = relax::F;
                batch
                    .iter()
                    .map(|args| {
                        let n = args[0].as_i64() as usize;
                        let x: Vec<f32> = (0..f)
                            .map(|j| {
                                memory.load(self.feat, (n * f + j) as i64).map(|v| v.as_f32())
                            })
                            .collect::<Result<_>>()?;
                        let (y, score) = relax::relax_ref(&x, &self.w, &self.b);
                        for (j, &v) in y.iter().enumerate() {
                            memory.store(self.feat, (n * f + j) as i64, Value::F32(v))?;
                        }
                        Ok(Value::I64((score * 1000.0) as i64))
                    })
                    .collect()
            }
        }
        let (w, b) = relax::weights(seed);
        let mut xla = InlineScalar { w, b, feat: m.global_by_name("feat").unwrap() };
        let (_, mem, stats) =
            self.session.simulate(mem, "expand", &[Value::I64(0)], config, &mut xla)?;
        let work = mem.dump_i64(m.global_by_name("work_done").unwrap())[0] as u64;
        let feat = mem.dump_f32(m.global_by_name("feat").unwrap());
        Ok(RelaxRun {
            nodes_expanded: work,
            cycles: stats.cycles,
            xla_batches: stats.xla_batches,
            feat_checksum: feat.iter().map(|&v| v as f64).sum(),
        })
    }
}

/// Compile + simulate the relax workload with the AOT XLA datapath.
pub fn run_relax_sim(
    runtime: XlaRuntime,
    graph: &CsrGraph,
    seed: u64,
    config: &SimConfig,
) -> Result<RelaxRun> {
    RelaxExperiment::new()?.run_sim(runtime, graph, seed, config)
}

/// Compile + simulate the relax workload with the scalar reference datapath.
pub fn run_relax_scalar(graph: &CsrGraph, seed: u64, config: &SimConfig) -> Result<RelaxRun> {
    RelaxExperiment::new()?.run_scalar(graph, seed, config)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::graphgen;

    #[test]
    fn run_grid_matches_serial_runs() {
        let exp = BfsExperiment::new().unwrap();
        let graph = graphgen::tree(2, 3);
        let a = SimConfig { default_pes: 1, ..SimConfig::default() };
        let b = SimConfig { default_pes: 2, ..SimConfig::default() };
        let grid = exp.run_grid(&graph, &[a.clone(), b.clone()]).unwrap();
        assert_eq!(grid.len(), 2);
        let sa = exp.run(&graph, &a).unwrap();
        let sb = exp.run(&graph, &b).unwrap();
        assert_eq!(grid[0].plain_cycles, sa.plain_cycles);
        assert_eq!(grid[0].dae_cycles, sa.dae_cycles);
        assert_eq!(grid[1].plain_cycles, sb.plain_cycles);
        assert_eq!(grid[1].dae_cycles, sb.dae_cycles);
    }

    #[test]
    fn run_grid_on_empty_grid_is_empty() {
        let exp = BfsExperiment::new().unwrap();
        let graph = graphgen::tree(2, 2);
        assert!(exp.run_grid(&graph, &[]).unwrap().is_empty());
    }
}
