//! End-to-end drivers for the paper's experiments and the relax workload.
//!
//! The experiment drivers are built on [`CompileSession`]: the workload is
//! lowered once per session and every simulated configuration (PE counts,
//! memory latencies, graphs) reuses the cached explicit module — which is
//! what makes the sweep benches scale without re-running the compiler per
//! data point. [`WsServeExperiment`] is the runtime-side counterpart: a
//! mixed corpus of compiled workloads flooded through the resident
//! [`crate::ws::Executor`] to measure multi-job serving throughput and
//! latency.

use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::interp::Memory;
use crate::ir::expr::Value;
use crate::lower::{CompileOptions, CompileSession};
use crate::runtime::{RelaxXla, XlaRuntime};
use crate::sim::{NoSimXla, SimConfig, SimStats};
use crate::workloads::{bfs, fib, graphgen, graphgen::CsrGraph, nqueens, qsort, relax};
use crate::ws;

/// Result of the paper's §III experiment on one graph.
#[derive(Clone, Debug)]
pub struct BfsComparison {
    pub nodes: usize,
    pub plain_cycles: u64,
    pub dae_cycles: u64,
    pub plain_stats: SimStats,
    pub dae_stats: SimStats,
}

impl BfsComparison {
    pub fn reduction(&self) -> f64 {
        1.0 - self.dae_cycles as f64 / self.plain_cycles as f64
    }
}

/// The paper's §III experiment pair, compiled once: the plain BFS and the
/// DAE-annotated BFS as two [`CompileSession`]s. Call [`BfsExperiment::run`]
/// per graph/config without re-lowering anything.
pub struct BfsExperiment {
    pub plain: CompileSession,
    pub dae: CompileSession,
}

impl BfsExperiment {
    pub fn new() -> Result<BfsExperiment> {
        Ok(BfsExperiment {
            plain: CompileSession::new("bfs", bfs::BFS_SRC, &CompileOptions::no_dae())?,
            dae: CompileSession::new("bfs_dae", bfs::BFS_DAE_SRC, &CompileOptions::standard())?,
        })
    }

    /// Run the DAE-vs-non-DAE HardCilk comparison on a graph.
    pub fn run(&self, graph: &CsrGraph, config: &SimConfig) -> Result<BfsComparison> {
        let run_one = |session: &CompileSession| -> Result<SimStats> {
            let mut mem = session.memory();
            bfs::init_memory(session.explicit(), &mut mem, graph)?;
            let (_, mem, stats) =
                session.simulate(mem, "visit", &[Value::I64(0)], config, &mut NoSimXla)?;
            bfs::check_all_visited(session.explicit(), &mem, graph)?;
            Ok(stats)
        };
        let plain_stats = run_one(&self.plain)?;
        let dae_stats = run_one(&self.dae)?;
        Ok(BfsComparison {
            nodes: graph.nodes(),
            plain_cycles: plain_stats.cycles,
            dae_cycles: dae_stats.cycles,
            plain_stats,
            dae_stats,
        })
    }
}

impl BfsExperiment {
    /// Worker threads [`BfsExperiment::run_grid`] uses for a grid of `n`
    /// configurations (exposed so benches can report the real fan-out).
    pub fn grid_workers(n: usize) -> usize {
        crate::util::parallel::default_workers(n)
    }

    /// Run a whole grid of simulator configurations, sharded across OS
    /// threads via [`crate::util::parallel::shard_map`] (the same idiom
    /// `lower::compile_batch` uses for the compiler side). The two
    /// compile sessions are only read (each configuration builds its own
    /// memory image), so every worker shares `&self`; results come back
    /// in `configs` order. This is what lets the `pe_sweep` /
    /// `memlat_sweep` benches scale with cores instead of walking the
    /// grid serially.
    pub fn run_grid(
        &self,
        graph: &CsrGraph,
        configs: &[SimConfig],
    ) -> Result<Vec<BfsComparison>> {
        let workers = BfsExperiment::grid_workers(configs.len());
        crate::util::parallel::shard_map(configs, workers, |cfg| self.run(graph, cfg))
            .into_iter()
            .collect()
    }
}

/// One-shot convenience wrapper (compiles both variants, runs one graph).
pub fn run_bfs_comparison(graph: &CsrGraph, config: &SimConfig) -> Result<BfsComparison> {
    BfsExperiment::new()?.run(graph, config)
}

/// Result of a relax end-to-end run on the simulator with the XLA PE.
#[derive(Clone, Debug)]
pub struct RelaxRun {
    pub nodes_expanded: u64,
    pub cycles: u64,
    pub xla_batches: u64,
    /// Sum of final feature values (fingerprint for equivalence checks).
    pub feat_checksum: f64,
}

/// The relax workload compiled once; both the batched-XLA and the scalar
/// reference datapaths run against the same cached explicit module.
pub struct RelaxExperiment {
    session: CompileSession,
}

impl RelaxExperiment {
    pub fn new() -> Result<RelaxExperiment> {
        Ok(RelaxExperiment {
            session: CompileSession::new("relax", relax::RELAX_SRC, &CompileOptions::no_dae())?,
        })
    }

    pub fn session(&self) -> &CompileSession {
        &self.session
    }

    /// Simulate with the AOT XLA datapath. `runtime` must have the relax
    /// artifacts loaded (`make artifacts`).
    pub fn run_sim(
        &self,
        runtime: XlaRuntime,
        graph: &CsrGraph,
        seed: u64,
        config: &SimConfig,
    ) -> Result<RelaxRun> {
        let m = self.session.explicit();
        let mut mem = self.session.memory();
        relax::init_memory(m, &mut mem, graph, seed)?;
        let mut xla = RelaxXla::new(runtime, m, seed)?;
        let (_, mem, stats) =
            self.session.simulate(mem, "expand", &[Value::I64(0)], config, &mut xla)?;
        let work = mem.dump_i64(
            m.global_by_name("work_done")
                .ok_or_else(|| anyhow!("no work_done global"))?,
        )[0] as u64;
        let feat = mem.dump_f32(m.global_by_name("feat").unwrap());
        Ok(RelaxRun {
            nodes_expanded: work,
            cycles: stats.cycles,
            xla_batches: stats.xla_batches,
            feat_checksum: feat.iter().map(|&v| v as f64).sum(),
        })
    }

    /// The same run with the scalar reference datapath (no XLA) — used to
    /// verify the batched path end to end.
    pub fn run_scalar(
        &self,
        graph: &CsrGraph,
        seed: u64,
        config: &SimConfig,
    ) -> Result<RelaxRun> {
        let m = self.session.explicit();
        let mut mem = self.session.memory();
        relax::init_memory(m, &mut mem, graph, seed)?;

        /// Scalar datapath over simulator memory (reference mode).
        struct InlineScalar {
            w: Vec<f32>,
            b: Vec<f32>,
            feat: crate::ir::GlobalId,
        }
        impl crate::sim::SimXla for InlineScalar {
            fn exec_batch(
                &mut self,
                _name: &str,
                batch: &[Vec<Value>],
                memory: &mut Memory,
            ) -> Result<Vec<Value>> {
                let f = relax::F;
                batch
                    .iter()
                    .map(|args| {
                        let n = args[0].as_i64() as usize;
                        let x: Vec<f32> = (0..f)
                            .map(|j| {
                                memory.load(self.feat, (n * f + j) as i64).map(|v| v.as_f32())
                            })
                            .collect::<Result<_>>()?;
                        let (y, score) = relax::relax_ref(&x, &self.w, &self.b);
                        for (j, &v) in y.iter().enumerate() {
                            memory.store(self.feat, (n * f + j) as i64, Value::F32(v))?;
                        }
                        Ok(Value::I64((score * 1000.0) as i64))
                    })
                    .collect()
            }
        }
        let (w, b) = relax::weights(seed);
        let mut xla = InlineScalar { w, b, feat: m.global_by_name("feat").unwrap() };
        let (_, mem, stats) =
            self.session.simulate(mem, "expand", &[Value::I64(0)], config, &mut xla)?;
        let work = mem.dump_i64(m.global_by_name("work_done").unwrap())[0] as u64;
        let feat = mem.dump_f32(m.global_by_name("feat").unwrap());
        Ok(RelaxRun {
            nodes_expanded: work,
            cycles: stats.cycles,
            xla_batches: stats.xla_batches,
            feat_checksum: feat.iter().map(|&v| v as f64).sum(),
        })
    }
}

/// Compile + simulate the relax workload with the AOT XLA datapath.
pub fn run_relax_sim(
    runtime: XlaRuntime,
    graph: &CsrGraph,
    seed: u64,
    config: &SimConfig,
) -> Result<RelaxRun> {
    RelaxExperiment::new()?.run_sim(runtime, graph, seed, config)
}

/// Compile + simulate the relax workload with the scalar reference datapath.
pub fn run_relax_scalar(graph: &CsrGraph, seed: u64, config: &SimConfig) -> Result<RelaxRun> {
    RelaxExperiment::new()?.run_scalar(graph, seed, config)
}

/// Expected final state of one corpus program (checked per job).
enum Check {
    /// Root result is this integer.
    RootI64(i64),
    /// One cell of a global equals this value.
    CellI64 { global: &'static str, index: usize, expect: i64 },
    /// A whole int global equals this image.
    AllI64 { global: &'static str, expect: Vec<i64> },
}

/// One member of the mixed serving corpus: a compiled session plus how
/// to seed a job's memory and verify its result.
struct CorpusProgram {
    name: &'static str,
    session: CompileSession,
    entry: &'static str,
    args: Vec<Value>,
    /// Globals filled with explicit values before submission.
    seed: Vec<(&'static str, Vec<i64>)>,
    /// Globals zero-resized before submission.
    resize: Vec<(&'static str, usize)>,
    checks: Vec<Check>,
}

/// Summary of one multi-job flood through the resident executor.
#[derive(Clone, Debug)]
pub struct FloodReport {
    pub jobs: usize,
    pub workers: usize,
    pub wall: Duration,
    pub jobs_per_s: f64,
    /// Submission-to-completion latency percentiles across jobs.
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    /// Jobs whose results matched the per-program expectation.
    pub verified: usize,
    /// Jobs that terminated with an error or a result mismatch
    /// (`verified + failed == jobs`).
    pub failed: usize,
    /// Per-job outcome in submission order: `None` for a verified job,
    /// otherwise the [`ws::JobErrorKind`] tag (`"panicked"`,
    /// `"transient"`, `"shed"`, …) or a `"mismatch: …"` description.
    /// Stable across runs for a fixed corpus and chaos seed — the
    /// chaos-determinism tests compare these vectors verbatim.
    pub outcomes: Vec<Option<String>>,
    pub stats: ws::ExecutorStats,
}

impl FloodReport {
    /// Terminal jobs bucketed by outcome tag (`"verified"` for clean
    /// jobs), sorted by descending count — the `--stats`/flood-report
    /// breakdown.
    pub fn outcome_breakdown(&self) -> Vec<(String, usize)> {
        let mut counts: Vec<(String, usize)> = Vec::new();
        for outcome in &self.outcomes {
            let tag = match outcome {
                None => "verified",
                Some(o) if o.starts_with("mismatch") => "mismatch",
                Some(o) => o.as_str(),
            };
            match counts.iter_mut().find(|(t, _)| t == tag) {
                Some((_, n)) => *n += 1,
                None => counts.push((tag.to_string(), 1)),
            }
        }
        counts.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        counts
    }
}

/// The multi-job serving experiment: a heterogeneous corpus (fib at two
/// sizes, nqueens, parallel quicksort, BFS over a CSR tree) compiled
/// once, then streamed through a resident [`ws::Executor`] as
/// interleaved jobs. Job `i` runs corpus program `i % corpus_len()`, so
/// every flood mixes task-tree shapes — value-returning recursion, void
/// atomics, data-dependent spawn trees, and memory-bound traversal.
pub struct WsServeExperiment {
    corpus: Vec<CorpusProgram>,
}

fn global_id(m: &crate::ir::cfg::Module, name: &str) -> Result<crate::ir::GlobalId> {
    m.global_by_name(name).ok_or_else(|| anyhow!("no global `{name}`"))
}

/// Clamped nearest-rank latency percentile, routed through the one
/// percentile implementation in the tree
/// ([`crate::obs::metrics::Histogram`]). Empty input → zero; one sample
/// → that sample at every quantile; output is always finite — the old
/// `((len-1)·q).round()` index under-reported tail quantiles on small
/// floods (p99 of 10 samples picked the 10th-rank element only by
/// rounding luck).
fn percentile(sorted: &[Duration], q: f64) -> Duration {
    let ms: Vec<f64> = sorted.iter().map(|d| d.as_secs_f64() * 1e3).collect();
    let h = crate::obs::metrics::Histogram::from_samples(&ms);
    Duration::from_secs_f64(h.percentile(q) / 1e3)
}

impl WsServeExperiment {
    pub fn new() -> Result<WsServeExperiment> {
        let opts = CompileOptions::no_dae();
        let fib_session = |name: &str| CompileSession::new(name, fib::FIB_SRC, &opts);
        // Deterministically seeded unsorted array for the qsort member.
        let mut rng = crate::util::rng::Rng::new(7);
        let unsorted: Vec<i64> = (0..48).map(|_| rng.below(1000) as i64).collect();
        let mut sorted = unsorted.clone();
        sorted.sort();
        // BFS member: a branch-3 depth-4 CSR tree, every node visited.
        let graph = graphgen::tree(3, 4);
        let nodes = graph.nodes();
        let corpus = vec![
            CorpusProgram {
                name: "fib18",
                session: fib_session("serve_fib18")?,
                entry: "fib",
                args: vec![Value::I64(18)],
                seed: vec![],
                resize: vec![],
                checks: vec![Check::RootI64(fib::fib_ref(18) as i64)],
            },
            CorpusProgram {
                name: "fib12",
                session: fib_session("serve_fib12")?,
                entry: "fib",
                args: vec![Value::I64(12)],
                seed: vec![],
                resize: vec![],
                checks: vec![Check::RootI64(fib::fib_ref(12) as i64)],
            },
            CorpusProgram {
                name: "nqueens6",
                session: CompileSession::new("serve_nqueens", nqueens::NQUEENS_SRC, &opts)?,
                entry: "place",
                args: vec![
                    Value::I64(6),
                    Value::I64(0),
                    Value::I64(0),
                    Value::I64(0),
                    Value::I64(0),
                ],
                seed: vec![],
                resize: vec![],
                checks: vec![Check::CellI64 {
                    global: "solutions",
                    index: 0,
                    expect: nqueens::nqueens_ref(6) as i64,
                }],
            },
            CorpusProgram {
                name: "qsort48",
                session: CompileSession::new("serve_qsort", qsort::QSORT_SRC, &opts)?,
                entry: "qsort_",
                args: vec![Value::I64(0), Value::I64(47)],
                seed: vec![("data", unsorted)],
                resize: vec![],
                checks: vec![Check::AllI64 { global: "data", expect: sorted }],
            },
            CorpusProgram {
                name: "bfs_tree",
                session: CompileSession::new("serve_bfs", bfs::BFS_SRC, &opts)?,
                entry: "visit",
                args: vec![Value::I64(0)],
                seed: vec![
                    ("adj_off", graph.adj_off.clone()),
                    ("adj_edges", graph.adj_edges.clone()),
                ],
                resize: vec![("visited", nodes)],
                checks: vec![Check::AllI64 { global: "visited", expect: vec![1; nodes] }],
            },
        ];
        Ok(WsServeExperiment { corpus })
    }

    pub fn corpus_len(&self) -> usize {
        self.corpus.len()
    }

    pub fn corpus_names(&self) -> Vec<&'static str> {
        self.corpus.iter().map(|p| p.name).collect()
    }

    fn program(&self, i: usize) -> &CorpusProgram {
        &self.corpus[i % self.corpus.len()]
    }

    /// Build job `i` (a fresh memory image over the session-cached
    /// kernel program of corpus member `i % corpus_len()`).
    pub fn job(&self, i: usize) -> Result<ws::Job> {
        let p = self.program(i);
        let m = p.session.explicit();
        let mut job = p.session.ws_job(p.entry, &p.args)?;
        for (name, values) in &p.seed {
            job.memory.fill_i64(global_id(m, name)?, values);
        }
        for (name, len) in &p.resize {
            job.memory.resize(global_id(m, name)?, *len);
        }
        Ok(job)
    }

    /// Check job `i`'s root result and final memory against the corpus
    /// expectation.
    pub fn verify(&self, i: usize, value: &Value, mem: &ws::SharedMemory) -> Result<()> {
        let p = self.program(i);
        let m = p.session.explicit();
        for check in &p.checks {
            match check {
                Check::RootI64(expect) => {
                    if value.as_i64() != *expect {
                        bail!("{}: root result {value:?}, expected {expect}", p.name);
                    }
                }
                Check::CellI64 { global, index, expect } => {
                    let got = mem.dump_i64(global_id(m, global)?);
                    if got.get(*index) != Some(expect) {
                        bail!(
                            "{}: {global}[{index}] = {:?}, expected {expect}",
                            p.name,
                            got.get(*index)
                        );
                    }
                }
                Check::AllI64 { global, expect } => {
                    let got = mem.dump_i64(global_id(m, global)?);
                    if &got != expect {
                        bail!("{}: global `{global}` diverged from the reference image", p.name);
                    }
                }
            }
        }
        Ok(())
    }

    /// Full final-memory image of job `i` (every global as i64 words) —
    /// the byte-level fingerprint determinism tests compare across
    /// worker counts and against one-shot runs.
    pub fn memory_image(&self, i: usize, mem: &ws::SharedMemory) -> Vec<Vec<i64>> {
        let p = self.program(i);
        p.session.explicit().globals.iter().map(|(id, _)| mem.dump_i64(id)).collect()
    }

    /// Reference run: job `i` through the one-shot [`ws::run_with_kernels`]
    /// wrapper (its own pool, its own lifecycle).
    pub fn one_shot(
        &self,
        i: usize,
        workers: usize,
    ) -> Result<(Value, ws::SharedMemory, ws::WsStats)> {
        let p = self.program(i);
        let job = self.job(i)?;
        let config = ws::WsConfig { workers, steal_tries: 4 };
        ws::run_with_kernels(job.kernels, job.memory, p.entry, &p.args, &config, job.xla_sink)
    }

    /// Flood a resident executor: submit `jobs` interleaved mixed-corpus
    /// jobs per wave, `repeat` waves, verifying every result. Returns
    /// throughput and per-job latency percentiles. Strict: any job
    /// failure (or mismatch) fails the flood — use [`Self::flood_chaos`]
    /// for fault-tolerant runs.
    pub fn flood(&self, workers: usize, jobs: usize, repeat: usize) -> Result<FloodReport> {
        let config = ws::ExecutorConfig {
            ws: ws::WsConfig { workers: workers.max(1), steal_tries: 4 },
            // A clean flood must stay clean even under an ambient
            // BOMBYX_CHAOS environment (the CI chaos-smoke job).
            fault: Some(ws::FaultPlan::disabled()),
            ..ws::ExecutorConfig::default()
        };
        let report = self.flood_with_config(config, jobs, repeat)?;
        if report.failed > 0 {
            let first = report
                .outcomes
                .iter()
                .flatten()
                .next()
                .cloned()
                .unwrap_or_default();
            bail!("{} of {} flood jobs failed (first: {first})", report.failed, report.jobs);
        }
        Ok(report)
    }

    /// Chaos flood: the same mixed-corpus flood under a seeded
    /// [`ws::FaultPlan`] with a retry-friendly default spec (transients
    /// and contained panics re-run with backoff). Job failures become
    /// per-job outcomes instead of failing the flood — compare against a
    /// clean [`Self::flood`] for the degraded-vs-clean throughput story.
    pub fn flood_chaos(
        &self,
        workers: usize,
        jobs: usize,
        repeat: usize,
        seed: u64,
    ) -> Result<FloodReport> {
        let config = ws::ExecutorConfig {
            ws: ws::WsConfig { workers: workers.max(1), steal_tries: 4 },
            fault: Some(ws::FaultPlan::chaos(seed)),
            default_spec: ws::JobSpec {
                retry: ws::RetryPolicy {
                    // FaultPlan::chaos goes fault-free from attempt 4, so
                    // 6 attempts always converge.
                    max_attempts: 6,
                    backoff: Duration::from_millis(2),
                    retry_on_panic: true,
                },
                ..ws::JobSpec::default()
            },
            ..ws::ExecutorConfig::default()
        };
        self.flood_with_config(config, jobs, repeat)
    }

    /// The flood core, tolerant of per-job failures: sheds and job
    /// errors land in `FloodReport::outcomes` (submission order) rather
    /// than aborting the flood. Only infrastructure errors (corpus
    /// compilation, executor construction) abort.
    pub fn flood_with_config(
        &self,
        config: ws::ExecutorConfig,
        jobs: usize,
        repeat: usize,
    ) -> Result<FloodReport> {
        let workers = config.ws.workers;
        let executor = ws::Executor::new(config)?;
        let repeat = repeat.max(1);
        let total = jobs * repeat;
        let mut latencies: Vec<Duration> = Vec::with_capacity(total);
        let mut outcomes: Vec<Option<String>> = Vec::with_capacity(total);
        let mut verified = 0usize;
        let mut failed = 0usize;
        let start = Instant::now();
        for _ in 0..repeat {
            let mut handles = Vec::with_capacity(jobs);
            for i in 0..jobs {
                handles.push((i, executor.submit(self.job(i)?)));
            }
            for (i, submitted) in handles {
                let outcome = match submitted {
                    Err(e) => Some(e.kind().tag().to_string()),
                    Ok(handle) => {
                        handle.wait();
                        if let Some(latency) = handle.latency() {
                            latencies.push(latency);
                        }
                        match handle.join() {
                            Err(e) => Some(e.kind().tag().to_string()),
                            Ok((value, mem, _stats)) => match self.verify(i, &value, &mem) {
                                Ok(()) => None,
                                Err(e) => Some(format!("mismatch: {e}")),
                            },
                        }
                    }
                };
                match outcome {
                    None => verified += 1,
                    Some(_) => failed += 1,
                }
                outcomes.push(outcome);
            }
        }
        let wall = start.elapsed();
        executor.publish_metrics();
        let stats = executor.stats();
        drop(executor);
        latencies.sort();
        for latency in &latencies {
            crate::obs::metrics::observe_ms("ws.flood.latency_ms", *latency);
        }
        crate::obs::metrics::gauge_set(
            "ws.flood.jobs_per_s",
            total as f64 / wall.as_secs_f64().max(1e-9),
        );
        Ok(FloodReport {
            jobs: total,
            workers,
            wall,
            jobs_per_s: total as f64 / wall.as_secs_f64().max(1e-9),
            p50: percentile(&latencies, 0.50),
            p95: percentile(&latencies, 0.95),
            p99: percentile(&latencies, 0.99),
            verified,
            failed,
            outcomes,
            stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::graphgen;

    #[test]
    fn run_grid_matches_serial_runs() {
        let exp = BfsExperiment::new().unwrap();
        let graph = graphgen::tree(2, 3);
        let a = SimConfig { default_pes: 1, ..SimConfig::default() };
        let b = SimConfig { default_pes: 2, ..SimConfig::default() };
        let grid = exp.run_grid(&graph, &[a.clone(), b.clone()]).unwrap();
        assert_eq!(grid.len(), 2);
        let sa = exp.run(&graph, &a).unwrap();
        let sb = exp.run(&graph, &b).unwrap();
        assert_eq!(grid[0].plain_cycles, sa.plain_cycles);
        assert_eq!(grid[0].dae_cycles, sa.dae_cycles);
        assert_eq!(grid[1].plain_cycles, sb.plain_cycles);
        assert_eq!(grid[1].dae_cycles, sb.dae_cycles);
    }

    #[test]
    fn run_grid_on_empty_grid_is_empty() {
        let exp = BfsExperiment::new().unwrap();
        let graph = graphgen::tree(2, 2);
        assert!(exp.run_grid(&graph, &[]).unwrap().is_empty());
    }

    #[test]
    fn ws_serve_corpus_verifies_one_shot() {
        let exp = WsServeExperiment::new().unwrap();
        for i in 0..exp.corpus_len() {
            let (value, mem, stats) = exp.one_shot(i, 1).unwrap();
            exp.verify(i, &value, &mem).unwrap();
            assert!(stats.tasks_run > 0);
        }
    }

    #[test]
    fn ws_serve_flood_smoke() {
        let exp = WsServeExperiment::new().unwrap();
        let report = exp.flood(2, exp.corpus_len(), 2).unwrap();
        assert_eq!(report.jobs, exp.corpus_len() * 2);
        assert_eq!(report.verified, report.jobs);
        assert_eq!(report.failed, 0);
        assert!(report.outcomes.iter().all(Option::is_none));
        assert_eq!(report.outcome_breakdown(), vec![("verified".to_string(), report.jobs)]);
        assert_eq!(report.stats.jobs_completed, report.jobs as u64);
        assert_eq!(report.stats.jobs_failed, 0);
        assert!(report.jobs_per_s > 0.0);
        assert!(report.p50 <= report.p95 && report.p95 <= report.p99);
    }

    #[test]
    fn ws_serve_chaos_flood_converges_and_is_seed_deterministic() {
        let exp = WsServeExperiment::new().unwrap();
        let n = exp.corpus_len() * 2;
        let a = exp.flood_chaos(2, n, 1, 42).unwrap();
        let b = exp.flood_chaos(2, n, 1, 42).unwrap();
        assert_eq!(a.outcomes, b.outcomes, "same seed must give identical per-job outcomes");
        assert_eq!(a.verified + a.failed, a.jobs);
        // The chaos plan goes fault-free from attempt 4 and the chaos
        // default spec allows 6, so every non-shed job converges.
        for (i, outcome) in a.outcomes.iter().enumerate() {
            if let Some(tag) = outcome {
                assert_eq!(tag.as_str(), "shed", "job {i}: unexpected terminal outcome `{tag}`");
            }
        }
    }
}
