//! End-to-end drivers for the paper's experiments and the relax workload.

use anyhow::{anyhow, Result};

use crate::interp::Memory;
use crate::ir::expr::Value;
use crate::lower::{compile, CompileOptions};
use crate::runtime::{RelaxXla, XlaRuntime};
use crate::sim::{simulate, NoSimXla, SimConfig, SimStats};
use crate::workloads::{bfs, graphgen::CsrGraph, relax};

/// Result of the paper's §III experiment on one graph.
#[derive(Clone, Debug)]
pub struct BfsComparison {
    pub nodes: usize,
    pub plain_cycles: u64,
    pub dae_cycles: u64,
    pub plain_stats: SimStats,
    pub dae_stats: SimStats,
}

impl BfsComparison {
    pub fn reduction(&self) -> f64 {
        1.0 - self.dae_cycles as f64 / self.plain_cycles as f64
    }
}

/// Run the DAE-vs-non-DAE HardCilk comparison (paper §III) on a graph.
pub fn run_bfs_comparison(graph: &CsrGraph, config: &SimConfig) -> Result<BfsComparison> {
    let mut cycles = Vec::new();
    let mut stats = Vec::new();
    for (src, opts) in [
        (bfs::BFS_SRC, CompileOptions::no_dae()),
        (bfs::BFS_DAE_SRC, CompileOptions::standard()),
    ] {
        let r = compile("bfs", src, &opts)?;
        let m = &r.explicit;
        let mut mem = Memory::new(m);
        bfs::init_memory(m, &mut mem, graph)?;
        let (_, mem, s) = simulate(m, mem, "visit", &[Value::I64(0)], config, &mut NoSimXla)?;
        bfs::check_all_visited(m, &mem, graph)?;
        cycles.push(s.cycles);
        stats.push(s);
    }
    let dae_stats = stats.pop().unwrap();
    let plain_stats = stats.pop().unwrap();
    Ok(BfsComparison {
        nodes: graph.nodes(),
        plain_cycles: cycles[0],
        dae_cycles: cycles[1],
        plain_stats,
        dae_stats,
    })
}

/// Result of a relax end-to-end run on the simulator with the XLA PE.
#[derive(Clone, Debug)]
pub struct RelaxRun {
    pub nodes_expanded: u64,
    pub cycles: u64,
    pub xla_batches: u64,
    /// Sum of final feature values (fingerprint for equivalence checks).
    pub feat_checksum: f64,
}

/// Compile + simulate the relax workload with the AOT XLA datapath.
/// `runtime` must have the relax artifacts loaded (`make artifacts`).
pub fn run_relax_sim(
    runtime: XlaRuntime,
    graph: &CsrGraph,
    seed: u64,
    config: &SimConfig,
) -> Result<RelaxRun> {
    let r = compile("relax", relax::RELAX_SRC, &CompileOptions::no_dae())?;
    let m = &r.explicit;
    let mut mem = Memory::new(m);
    relax::init_memory(m, &mut mem, graph, seed)?;
    let mut xla = RelaxXla::new(runtime, m, seed)?;
    let (_, mem, stats) = simulate(m, mem, "expand", &[Value::I64(0)], config, &mut xla)?;
    let work = mem.dump_i64(
        m.global_by_name("work_done")
            .ok_or_else(|| anyhow!("no work_done global"))?,
    )[0] as u64;
    let feat = mem.dump_f32(m.global_by_name("feat").unwrap());
    Ok(RelaxRun {
        nodes_expanded: work,
        cycles: stats.cycles,
        xla_batches: stats.xla_batches,
        feat_checksum: feat.iter().map(|&v| v as f64).sum(),
    })
}

/// The same relax run with the scalar reference datapath (no XLA) — used
/// to verify the batched path end to end.
pub fn run_relax_scalar(
    graph: &CsrGraph,
    seed: u64,
    config: &SimConfig,
) -> Result<RelaxRun> {
    let r = compile("relax", relax::RELAX_SRC, &CompileOptions::no_dae())?;
    let m = &r.explicit;
    let mut mem = Memory::new(m);
    relax::init_memory(m, &mut mem, graph, seed)?;

    /// Scalar datapath over simulator memory (reference mode).
    struct InlineScalar {
        w: Vec<f32>,
        b: Vec<f32>,
        feat: crate::ir::GlobalId,
    }
    impl crate::sim::SimXla for InlineScalar {
        fn exec_batch(
            &mut self,
            _name: &str,
            batch: &[Vec<Value>],
            memory: &mut Memory,
        ) -> Result<Vec<Value>> {
            let f = relax::F;
            batch
                .iter()
                .map(|args| {
                    let n = args[0].as_i64() as usize;
                    let x: Vec<f32> = (0..f)
                        .map(|j| memory.load(self.feat, (n * f + j) as i64).map(|v| v.as_f32()))
                        .collect::<Result<_>>()?;
                    let (y, score) = relax::relax_ref(&x, &self.w, &self.b);
                    for (j, &v) in y.iter().enumerate() {
                        memory.store(self.feat, (n * f + j) as i64, Value::F32(v))?;
                    }
                    Ok(Value::I64((score * 1000.0) as i64))
                })
                .collect()
        }
    }
    let (w, b) = relax::weights(seed);
    let mut xla = InlineScalar {
        w,
        b,
        feat: m.global_by_name("feat").unwrap(),
    };
    let (_, mem, stats) = simulate(m, mem, "expand", &[Value::I64(0)], config, &mut xla)?;
    let work = mem.dump_i64(m.global_by_name("work_done").unwrap())[0] as u64;
    let feat = mem.dump_f32(m.global_by_name("feat").unwrap());
    Ok(RelaxRun {
        nodes_expanded: work,
        cycles: stats.cycles,
        xla_batches: stats.xla_batches,
        feat_checksum: feat.iter().map(|&v| v as f64).sum(),
    })
}
