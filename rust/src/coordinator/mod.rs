//! The coordinator: end-to-end drivers gluing compiler, runtimes,
//! simulator, backends and the XLA batcher together. This is what the CLI
//! (`rust/src/main.rs`), the examples and the benches call.

pub mod driver;

pub use driver::{
    run_bfs_comparison, run_relax_scalar, run_relax_sim, BfsComparison, BfsExperiment,
    FloodReport, RelaxExperiment, RelaxRun, WsServeExperiment,
};
