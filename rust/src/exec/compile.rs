//! CFG → kernel bytecode compiler.
//!
//! Each function's CFG is flattened once, in reverse post-order:
//!
//! - expressions become register instructions over a frame of slots
//!   (declared variables first, then per-op temporaries — temporaries
//!   are recycled between ops, so frames stay small);
//! - constant subexpressions are folded into immediates here, via the
//!   tree-walking `expr::eval` (its one remaining compile-time use);
//! - call/spawn arguments are staged into consecutive slots so dispatch
//!   passes a slice instead of building a `Vec`;
//! - branch targets resolve to instruction offsets;
//! - every source IR op attaches a [`KCost`] mirroring `hls::op_cycles`
//!   (operator counts measured on the *pre-fold* trees, so simulated
//!   cycle counts are unchanged by folding).
//!
//! Left-to-right evaluation order is preserved everywhere, and argument
//! staging slots are allocated before their value computations' own
//! temporaries, so monotonically growing per-op temp allocation can
//! never clobber a staged value.
//!
//! # Superinstruction fusion
//!
//! After straight-line emission (and branch-target fixup) a peephole
//! stage ([`fuse_code`]) collapses hot adjacent windows into one fused
//! dispatch — widest first. Triples: a load feeding a bin feeding the
//! next store ([`KOp::LoadBinStore`]). Pairs: compare+branch on the
//! just-written slot ([`KOp::CmpBranch`]), load/bin feeding a plain
//! `Mov` of the same slot ([`KOp::LoadMov`]/[`KOp::BinMov`]), a bin
//! whose result is the next `Store`'s / `AtomicAdd`'s value
//! ([`KOp::StoreBin`]/[`KOp::BinAtomicAdd`]), bin+return
//! ([`KOp::ReturnBin`]) and bin+send ([`KOp::SendBin`]). Fused handlers
//! replay every component op verbatim (every frame write included), and
//! [`KCost`] entries merge only under rules that keep the simulator's
//! timed traces byte-for-byte unchanged:
//!
//! - pure-compute pairs concatenate their expr counts (the unfused
//!   charges were adjacent `Compute` segments the trace merged anyway);
//! - a pair whose first op emits a trace element between the charges
//!   (`LoadMov`'s `Seg::Load`) fuses only when the second op's cost is
//!   provably zero for every schedule model; the load+bin+store triple
//!   instead carries a *second* cost id (`cost2`) charged after the load,
//!   so the `Seg::Load` still lands between the load's charge and the
//!   merged bin+store charge;
//! - a branch target landing on a *non-first* instruction of a window
//!   suppresses fusion (defensive — the block emitter always puts a
//!   terminator before a block start, but hand-built or future bytecode
//!   may not).
//!
//! Fusion is on by default and gated by `BOMBYX_KERNEL_FUSE=0`
//! (escape hatch for bisection); [`compile_module_with`] selects it
//! programmatically.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::frontend::ast::Type;
use crate::ir::cfg::{BlockId, Func, FuncKind, Module, Op, RetTarget, Term};
use crate::ir::expr::{self, Expr, Value};

use super::kernel::{
    is_cmp_op, FuncKernel, KBase, KCost, KInstr, KOp, KRet, KernelMode, KernelProgram, Operand,
    NO_COST,
};

/// Is superinstruction fusion enabled for this process? On by default;
/// `BOMBYX_KERNEL_FUSE=0` is the escape hatch.
pub fn fuse_enabled() -> bool {
    fuse_from(std::env::var("BOMBYX_KERNEL_FUSE").ok().as_deref())
}

fn fuse_from(v: Option<&str>) -> bool {
    !matches!(v, Some("0"))
}

/// Compile every function of `module` into bytecode kernels (fusion per
/// the `BOMBYX_KERNEL_FUSE` gate). The result passes
/// [`KernelProgram::validate`] (checked here; a failure is a compiler
/// bug, reported like a pass post-verification failure).
pub fn compile_module(module: &Module, mode: KernelMode) -> Result<KernelProgram> {
    compile_module_with(module, mode, fuse_enabled())
}

/// [`compile_module`] with fusion selected programmatically (the
/// fusion-on-vs-off differential suite and the dispatch bench drive
/// this directly, independent of the process environment).
pub fn compile_module_with(
    module: &Module,
    mode: KernelMode,
    fuse: bool,
) -> Result<KernelProgram> {
    let prog = compile_module_unvalidated_with(module, mode, fuse)?;
    let errors = prog.validate();
    if !errors.is_empty() {
        bail!(
            "kernel compilation produced invalid bytecode:\n  {}",
            errors.join("\n  ")
        );
    }
    Ok(prog)
}

/// [`compile_module`] without the built-in validation — for callers whose
/// own boundary runs the validator (the `kernel_compile` pass, whose
/// post-verification IS [`KernelProgram::validate`]); avoids walking
/// every instruction twice on that path.
pub(crate) fn compile_module_unvalidated(
    module: &Module,
    mode: KernelMode,
) -> Result<KernelProgram> {
    compile_module_unvalidated_with(module, mode, fuse_enabled())
}

fn compile_module_unvalidated_with(
    module: &Module,
    mode: KernelMode,
    fuse: bool,
) -> Result<KernelProgram> {
    let mut funcs = Vec::with_capacity(module.funcs.len());
    for (_, f) in module.funcs.iter() {
        let mut k = compile_func(module, f, mode)?;
        k.unfused_len = k.code.len() as u32;
        if fuse {
            k.fused = fuse_code(&mut k.code, &mut k.costs);
        }
        funcs.push(k);
    }
    let global_tys = module.globals.iter().map(|(_, g)| g.elem).collect();
    Ok(KernelProgram { mode, funcs, global_tys })
}

fn role_of(f: &Func) -> &'static str {
    f.task.as_ref().map(|t| t.role.name()).unwrap_or(match f.kind {
        FuncKind::Leaf => "leaf",
        FuncKind::Xla => "xla",
        FuncKind::Task => "task",
    })
}

fn compile_func(module: &Module, f: &Func, mode: KernelMode) -> Result<FuncKernel> {
    let param_tys: Arc<[Type]> =
        f.param_ids().map(|p| f.vars[p].ty).collect::<Vec<_>>().into();
    if f.kind == FuncKind::Xla {
        return Ok(FuncKernel {
            name: f.name.clone(),
            kind: f.kind,
            role: role_of(f),
            params: f.params,
            param_tys,
            ret: f.ret,
            frame: Vec::new(),
            code: Vec::new(),
            costs: Vec::new(),
            fused: 0,
            unfused_len: 0,
        });
    }
    let Some(cfg) = f.body.as_ref() else {
        bail!("function `{}` has no body", f.name);
    };
    let n_vars = f.vars.len() as u32;
    let mut c = FnCompiler {
        func: f,
        mode,
        leaf: f.kind == FuncKind::Leaf,
        code: Vec::new(),
        costs: Vec::new(),
        n_vars,
        next_temp: n_vars,
        max_slots: n_vars,
    };
    if mode == KernelMode::Explicit {
        // Sequential calls target leaf (or xla) callees only; catching a
        // task callee here turns the old runtime bail into a compile error.
        for block in cfg.blocks.values() {
            for op in &block.ops {
                if let Op::Call { callee, .. } = op {
                    let ck = &module.funcs[*callee];
                    if ck.kind == FuncKind::Task {
                        bail!("sequential call to non-leaf `{}` in `{}`", ck.name, f.name);
                    }
                }
            }
        }
    }
    let rpo = cfg.reverse_postorder();
    let mut offsets = vec![u32::MAX; cfg.blocks.len()];
    let mut fixups: Vec<(usize, u8, BlockId)> = Vec::new();
    for &bid in &rpo {
        offsets[bid.index()] = c.code.len() as u32;
        let block = &cfg.blocks[bid];
        for op in &block.ops {
            c.reset_temps();
            c.emit_op(op)?;
        }
        c.reset_temps();
        c.emit_term(&block.term, &mut fixups)?;
    }
    for (idx, field, target) in fixups {
        let off = offsets[target.index()];
        if off == u32::MAX {
            bail!("`{}`: terminator targets unreachable bb{}", f.name, target.index());
        }
        match (&mut c.code[idx].op, field) {
            (KOp::Jump { target }, 0) => *target = off,
            (KOp::Branch { then_, .. }, 1) => *then_ = off,
            (KOp::Branch { else_, .. }, 2) => *else_ = off,
            (other, _) => bail!("`{}`: fixup mismatch at pc {idx}: {other:?}", f.name),
        }
    }
    let mut frame: Vec<Value> = f.vars.values().map(|v| Value::zero_of(v.ty)).collect();
    frame.resize(c.max_slots as usize, Value::Unit);
    Ok(FuncKernel {
        name: f.name.clone(),
        kind: f.kind,
        role: role_of(f),
        params: f.params,
        param_tys,
        ret: f.ret,
        frame,
        code: c.code,
        costs: c.costs,
        fused: 0,
        unfused_len: 0,
    })
}

// ---------------------------------------------------------------------------
// Superinstruction fusion (see module docs)

/// Peephole-fuse hot adjacent windows of `code` in place — triples first
/// (load+bin+store), then pairs — remapping branch targets over the
/// removed instructions. Returns the number of instructions *eliminated*
/// (1 per fused pair, 2 per fused triple). `costs` gains merged entries
/// where both components carried one (stale entries of consumed
/// instructions stay — the table is index-addressed, never iterated for
/// timing).
fn fuse_code(code: &mut Vec<KInstr>, costs: &mut Vec<KCost>) -> u32 {
    let n = code.len();
    if n < 2 {
        return 0;
    }
    // A branch target landing on a non-first instruction of a window must
    // suppress fusion: the fused instruction replays the earlier
    // components too, which a jump into the middle must skip.
    let mut is_target = vec![false; n + 1];
    for instr in code.iter() {
        match &instr.op {
            KOp::Jump { target } => is_target[*target as usize] = true,
            KOp::Branch { then_, else_, .. } => {
                is_target[*then_ as usize] = true;
                is_target[*else_ as usize] = true;
            }
            _ => {}
        }
    }
    let old = std::mem::take(code);
    let mut new_pc = vec![0u32; n + 1];
    let mut fused = 0u32;
    let mut i = 0usize;
    while i < n {
        new_pc[i] = code.len() as u32;
        // Widest window first: a load whose value feeds a bin feeding the
        // next store beats the narrower pairs it overlaps.
        let triple = if i + 2 < n && !is_target[i + 1] && !is_target[i + 2] {
            try_fuse3(&old[i], &old[i + 1], &old[i + 2], costs)
        } else {
            None
        };
        if let Some(instr) = triple {
            // Consumed slots map to the fused instruction; nothing
            // targets them (suppressed above), the mapping just keeps
            // the table total.
            new_pc[i + 1] = code.len() as u32;
            new_pc[i + 2] = code.len() as u32;
            code.push(instr);
            fused += 2;
            i += 3;
            continue;
        }
        let pair = if i + 1 < n && !is_target[i + 1] {
            try_fuse(&old[i], &old[i + 1], costs)
        } else {
            None
        };
        match pair {
            Some(instr) => {
                new_pc[i + 1] = code.len() as u32;
                code.push(instr);
                fused += 1;
                i += 2;
            }
            None => {
                code.push(old[i].clone());
                i += 1;
            }
        }
    }
    new_pc[n] = code.len() as u32;
    for instr in code.iter_mut() {
        match &mut instr.op {
            KOp::Jump { target } => *target = new_pc[*target as usize],
            KOp::Branch { then_, else_, .. } | KOp::CmpBranch { then_, else_, .. } => {
                *then_ = new_pc[*then_ as usize];
                *else_ = new_pc[*else_ as usize];
            }
            _ => {}
        }
    }
    fused
}

/// Is this cost zero cycles under *every* schedule model? (`Zero` base
/// and all-zero operator counts — `ceil(0/ops_per_cycle)` is 0 for any
/// divisor.)
fn zero_cycle(cost: u32, costs: &[KCost]) -> bool {
    cost == NO_COST || {
        let c = &costs[cost as usize];
        c.base == KBase::Zero && c.exprs.iter().all(|&e| e == 0)
    }
}

/// Merge the costs of two *pure-compute* ops (neither emits a trace
/// element, so their unfused charges were adjacent `Compute` pushes that
/// the trace collapsed into one segment — concatenating expr counts
/// yields the byte-identical segment). Returns `None` when both carry a
/// non-`Zero` base (no single base can represent the pair).
fn merge_compute_costs(a: u32, b: u32, costs: &mut Vec<KCost>) -> Option<u32> {
    match (a == NO_COST, b == NO_COST) {
        (true, true) => Some(NO_COST),
        (false, true) => Some(a),
        (true, false) => Some(b),
        (false, false) => {
            let (ca, cb) = (&costs[a as usize], &costs[b as usize]);
            let base = match (ca.base, cb.base) {
                (KBase::Zero, other) | (other, KBase::Zero) => other,
                _ => return None,
            };
            let mut exprs = ca.exprs.clone();
            exprs.extend_from_slice(&cb.exprs);
            let id = costs.len() as u32;
            costs.push(KCost { base, exprs });
            Some(id)
        }
    }
}

/// Try to fuse the adjacent pair `(a, b)` into one superinstruction.
fn try_fuse(a: &KInstr, b: &KInstr, costs: &mut Vec<KCost>) -> Option<KInstr> {
    match (&a.op, &b.op) {
        // Compare feeding the branch on its just-written slot. Restricted
        // to cost-free compares (branch-condition temporaries): the
        // merged charge is then exactly the branch's, trivially
        // trace-identical, and `costs_mirror_hls_op_cycles`-style
        // terminator accounting stays clean.
        (
            KOp::Bin { op, dst, lhs, rhs, ty },
            KOp::Branch { cond: Operand::Slot(c), then_, else_ },
        ) if is_cmp_op(*op) && *c == *dst && a.cost == NO_COST => Some(KInstr::new(
            KOp::CmpBranch {
                op: *op,
                dst: *dst,
                lhs: *lhs,
                rhs: *rhs,
                ty: *ty,
                then_: *then_,
                else_: *else_,
            },
            b.cost,
        )),
        // Load feeding a plain Mov of the loaded slot. A `Seg::Load` sits
        // between the two unfused charges, so the Mov's cost must be
        // zero-cycle under every model for the single up-front charge to
        // leave the trace untouched.
        (KOp::Load { dst, arr, index }, KOp::Mov { dst: mdst, src: Operand::Slot(s), ty })
            if *s == *dst && zero_cycle(b.cost, costs) =>
        {
            let cost = if a.cost != NO_COST { a.cost } else { b.cost };
            Some(KInstr::new(
                KOp::LoadMov { ldst: *dst, arr: *arr, index: *index, dst: *mdst, ty: *ty },
                cost,
            ))
        }
        // Bin feeding a plain Mov of its just-written slot.
        (
            KOp::Bin { op, dst, lhs, rhs, ty: bty },
            KOp::Mov { dst: mdst, src: Operand::Slot(s), ty },
        ) if *s == *dst => {
            let cost = merge_compute_costs(a.cost, b.cost, costs)?;
            Some(KInstr::new(
                KOp::BinMov {
                    op: *op,
                    bdst: *dst,
                    lhs: *lhs,
                    rhs: *rhs,
                    bty: *bty,
                    dst: *mdst,
                    ty: *ty,
                },
                cost,
            ))
        }
        // Bin feeding the following store's value operand. (Stores emit
        // no trace element, so cost merging follows the compute rule.)
        (
            KOp::Bin { op, dst, lhs, rhs, ty: bty },
            KOp::Store { arr, index, value: Operand::Slot(s) },
        ) if *s == *dst => {
            let cost = merge_compute_costs(a.cost, b.cost, costs)?;
            Some(KInstr::new(
                KOp::StoreBin {
                    op: *op,
                    bdst: *dst,
                    lhs: *lhs,
                    rhs: *rhs,
                    bty: *bty,
                    arr: *arr,
                    index: *index,
                },
                cost,
            ))
        }
        // Bin feeding the return value.
        (
            KOp::Bin { op, dst, lhs, rhs, ty: bty },
            KOp::Return { value: Some(Operand::Slot(s)) },
        ) if *s == *dst => {
            let cost = merge_compute_costs(a.cost, b.cost, costs)?;
            Some(KInstr::new(
                KOp::ReturnBin { op: *op, bdst: *dst, lhs: *lhs, rhs: *rhs, bty: *bty },
                cost,
            ))
        }
        // Bin feeding the following atomic-add's value operand.
        // (`atomic_add` emits no trace element, so cost merging follows
        // the compute rule, exactly like `StoreBin`.)
        (
            KOp::Bin { op, dst, lhs, rhs, ty: bty },
            KOp::AtomicAdd { arr, index, value: Operand::Slot(s) },
        ) if *s == *dst => {
            let cost = merge_compute_costs(a.cost, b.cost, costs)?;
            Some(KInstr::new(
                KOp::BinAtomicAdd {
                    op: *op,
                    bdst: *dst,
                    lhs: *lhs,
                    rhs: *rhs,
                    bty: *bty,
                    arr: *arr,
                    index: *index,
                },
                cost,
            ))
        }
        // Bin feeding the outgoing argument send. `send_argument` pushes
        // its `Seg::Effect` *after* both unfused charges, so the charges
        // were adjacent computes and the compute merge rule applies.
        (
            KOp::Bin { op, dst, lhs, rhs, ty: bty },
            KOp::SendArgument { value: Some(Operand::Slot(s)) },
        ) if *s == *dst => {
            let cost = merge_compute_costs(a.cost, b.cost, costs)?;
            Some(KInstr::new(
                KOp::SendBin { op: *op, bdst: *dst, lhs: *lhs, rhs: *rhs, bty: *bty },
                cost,
            ))
        }
        _ => None,
    }
}

/// Try to fuse the adjacent triple `(a, b, c)` — a load whose value feeds
/// a bin whose result is the next store's value — into one
/// [`KOp::LoadBinStore`]. The load's own cost stays the up-front
/// `instr.cost` (its `Seg::Load` interposes before the bin/store
/// charges); the bin+store costs merge under the compute rule into the
/// second charge (`cost2`), which the handler applies after the load.
fn try_fuse3(a: &KInstr, b: &KInstr, c: &KInstr, costs: &mut Vec<KCost>) -> Option<KInstr> {
    match (&a.op, &b.op, &c.op) {
        (
            KOp::Load { dst: ldst, arr, index },
            KOp::Bin { op, dst: bdst, lhs, rhs, ty: bty },
            KOp::Store { arr: sarr, index: sindex, value: Operand::Slot(s) },
        ) if *s == *bdst
            && (*lhs == Operand::Slot(*ldst) || *rhs == Operand::Slot(*ldst)) =>
        {
            let cost2 = merge_compute_costs(b.cost, c.cost, costs)?;
            Some(KInstr::new(
                KOp::LoadBinStore {
                    ldst: *ldst,
                    arr: *arr,
                    index: *index,
                    cost2,
                    op: *op,
                    bdst: *bdst,
                    lhs: *lhs,
                    rhs: *rhs,
                    bty: *bty,
                    sarr: *sarr,
                    sindex: *sindex,
                },
                a.cost,
            ))
        }
        _ => None,
    }
}

/// Operator count of an expression — the figure `hls::expr_cycles`
/// divides by `ops_per_cycle` (Binary/Unary/Builtin nodes).
fn ops_in(e: &Expr) -> u32 {
    let mut n = 0u32;
    e.for_each_node(&mut |x| {
        if matches!(x, Expr::Binary(..) | Expr::Unary(..) | Expr::Builtin(..)) {
            n += 1;
        }
    });
    n
}

/// Fold a variable-free subexpression to its value (the retained use of
/// the tree evaluator: compile-time constant folding).
fn const_fold(e: &Expr) -> Option<Value> {
    let mut has_var = false;
    e.for_each_var(&mut |_| has_var = true);
    if has_var {
        None
    } else {
        Some(expr::eval(e, &|_| Value::Unit))
    }
}

struct FnCompiler<'m> {
    func: &'m Func,
    mode: KernelMode,
    leaf: bool,
    code: Vec<KInstr>,
    costs: Vec<KCost>,
    n_vars: u32,
    next_temp: u32,
    max_slots: u32,
}

impl<'m> FnCompiler<'m> {
    fn reset_temps(&mut self) {
        self.next_temp = self.n_vars;
    }

    fn alloc_temp(&mut self) -> u32 {
        let t = self.next_temp;
        self.next_temp += 1;
        self.max_slots = self.max_slots.max(self.next_temp);
        t
    }

    fn alloc_range(&mut self, n: u32) -> u32 {
        let a0 = self.next_temp;
        self.next_temp += n;
        self.max_slots = self.max_slots.max(self.next_temp);
        a0
    }

    fn push(&mut self, op: KOp) {
        self.code.push(KInstr::new(op, NO_COST));
    }

    fn push_costed(&mut self, op: KOp, cost: KCost) {
        let id = self.costs.len() as u32;
        self.costs.push(cost);
        self.code.push(KInstr::new(op, id));
    }

    /// Attach a cost to the most recently emitted instruction (the
    /// anchor of a multi-instruction op like `Assign`).
    fn set_last_cost(&mut self, cost: KCost) {
        let id = self.costs.len() as u32;
        self.costs.push(cost);
        self.code.last_mut().expect("instruction just emitted").cost = id;
    }

    fn emit_expr(&mut self, e: &Expr) -> Result<Operand> {
        if let Some(v) = const_fold(e) {
            return Ok(Operand::Imm(v));
        }
        if let Expr::Var(v) = e {
            return Ok(Operand::Slot(v.index() as u32));
        }
        let t = self.alloc_temp();
        self.emit_expr_to(t, None, e)?;
        Ok(Operand::Slot(t))
    }

    fn emit_expr_to(&mut self, dst: u32, ty: Option<Type>, e: &Expr) -> Result<()> {
        if let Some(v) = const_fold(e) {
            self.push(KOp::Mov { dst, src: Operand::Imm(v), ty });
            return Ok(());
        }
        match e {
            Expr::ConstI(v) => {
                self.push(KOp::Mov { dst, src: Operand::Imm(Value::I64(*v)), ty })
            }
            Expr::ConstF(v) => {
                self.push(KOp::Mov { dst, src: Operand::Imm(Value::F32(*v)), ty })
            }
            Expr::ConstB(v) => {
                self.push(KOp::Mov { dst, src: Operand::Imm(Value::Bool(*v)), ty })
            }
            Expr::Var(v) => {
                self.push(KOp::Mov { dst, src: Operand::Slot(v.index() as u32), ty })
            }
            Expr::Binary(op, a, b) => {
                let lhs = self.emit_expr(a)?;
                let rhs = self.emit_expr(b)?;
                self.push(KOp::Bin { op: *op, dst, lhs, rhs, ty });
            }
            Expr::Unary(op, a) => {
                let src = self.emit_expr(a)?;
                self.push(KOp::Un { op: *op, dst, src, ty });
            }
            Expr::IntToFloat(a) => {
                let src = self.emit_expr(a)?;
                self.push(KOp::IntToFloat { dst, src, ty });
            }
            Expr::Builtin(b, args) => match args.len() {
                1 => {
                    let src = self.emit_expr(&args[0])?;
                    self.push(KOp::Builtin1 { b: *b, dst, src, ty });
                }
                2 => {
                    let lhs = self.emit_expr(&args[0])?;
                    let rhs = self.emit_expr(&args[1])?;
                    self.push(KOp::Builtin2 { b: *b, dst, lhs, rhs, ty });
                }
                n => bail!("builtin `{}` with unsupported arity {n}", b.name()),
            },
        }
        Ok(())
    }

    /// Evaluate `args` left-to-right into consecutive slots; returns
    /// (first slot, count).
    fn stage_args(&mut self, args: &[Expr]) -> Result<(u32, u32)> {
        let n = args.len() as u32;
        let a0 = self.alloc_range(n);
        for (i, a) in args.iter().enumerate() {
            self.emit_expr_to(a0 + i as u32, None, a)?;
        }
        Ok((a0, n))
    }

    fn emit_op(&mut self, op: &Op) -> Result<()> {
        if self.leaf
            && !matches!(
                op,
                Op::Assign { .. }
                    | Op::Load { .. }
                    | Op::Store { .. }
                    | Op::AtomicAdd { .. }
                    | Op::Call { .. }
            )
        {
            bail!("op {op:?} not allowed in leaf `{}`", self.func.name);
        }
        if self.mode == KernelMode::Implicit && op.is_explicit_only() {
            bail!("explicit-only op {op:?} in implicit IR function `{}`", self.func.name);
        }
        match op {
            Op::Assign { dst, src } => {
                let ty = self.func.vars[*dst].ty;
                self.emit_expr_to(dst.index() as u32, Some(ty), src)?;
                self.set_last_cost(KCost { base: KBase::Zero, exprs: vec![ops_in(src)] });
            }
            Op::Load { dst, arr, index, .. } => {
                let idx = self.emit_expr(index)?;
                self.push_costed(
                    KOp::Load { dst: dst.index() as u32, arr: *arr, index: idx },
                    KCost { base: KBase::LoadIssue, exprs: vec![ops_in(index)] },
                );
            }
            Op::Store { arr, index, value } => {
                let idx = self.emit_expr(index)?;
                let val = self.emit_expr(value)?;
                self.push_costed(
                    KOp::Store { arr: *arr, index: idx, value: val },
                    KCost { base: KBase::StoreIssue, exprs: vec![ops_in(index), ops_in(value)] },
                );
            }
            Op::AtomicAdd { arr, index, value } => {
                let idx = self.emit_expr(index)?;
                let val = self.emit_expr(value)?;
                self.push_costed(
                    KOp::AtomicAdd { arr: *arr, index: idx, value: val },
                    KCost { base: KBase::StoreIssue, exprs: vec![ops_in(index), ops_in(value)] },
                );
            }
            Op::Call { dst, callee, args } => {
                let (a0, n) = self.stage_args(args)?;
                let d = dst.map(|d| (d.index() as u32, self.func.vars[d].ty));
                // No cost: the HLS model charges the (inlined) callee's
                // own ops, which the callee kernel carries.
                self.push(KOp::Call { dst: d, callee: *callee, args_at: a0, nargs: n });
            }
            Op::Spawn { dst, callee, args } => {
                if self.mode == KernelMode::Explicit {
                    bail!("implicit Spawn in explicit IR (`{}`)", self.func.name);
                }
                let (a0, n) = self.stage_args(args)?;
                let d = dst.map(|d| (d.index() as u32, self.func.vars[d].ty));
                self.push_costed(
                    KOp::SpawnSeq { dst: d, callee: *callee, args_at: a0, nargs: n },
                    KCost { base: KBase::StreamWrite, exprs: vec![] },
                );
            }
            Op::MakeClosure { dst, task } => {
                self.push_costed(
                    KOp::MakeClosure { dst: dst.index() as u32, task: *task },
                    KCost { base: KBase::SpawnNextRtt, exprs: vec![] },
                );
            }
            Op::ClosureStore { clos, field, value } => {
                let val = self.emit_expr(value)?;
                self.push_costed(
                    KOp::ClosureStore { clos: clos.index() as u32, field: *field, value: val },
                    KCost { base: KBase::StreamWrite, exprs: vec![ops_in(value)] },
                );
            }
            Op::SpawnChild { callee, args, ret } => {
                let (a0, n) = self.stage_args(args)?;
                let kret = match ret {
                    RetTarget::Slot { clos, field } => {
                        KRet::Slot { clos: clos.index() as u32, field: *field }
                    }
                    RetTarget::Counter { clos } => KRet::Counter { clos: clos.index() as u32 },
                    RetTarget::Forward => KRet::Forward,
                };
                let exprs: Vec<u32> = args.iter().map(ops_in).collect();
                self.push_costed(
                    KOp::SpawnChild { callee: *callee, args_at: a0, nargs: n, ret: kret },
                    KCost { base: KBase::StreamWrite, exprs },
                );
            }
            Op::CloseSpawns { clos } => {
                self.push_costed(
                    KOp::CloseSpawns { clos: clos.index() as u32 },
                    KCost { base: KBase::StreamWrite, exprs: vec![] },
                );
            }
            Op::SendArgument { value } => {
                let val = match value {
                    Some(e) => Some(self.emit_expr(e)?),
                    None => None,
                };
                let exprs = value.as_ref().map(|e| vec![ops_in(e)]).unwrap_or_default();
                self.push_costed(
                    KOp::SendArgument { value: val },
                    KCost { base: KBase::StreamWrite, exprs },
                );
            }
        }
        Ok(())
    }

    fn emit_term(&mut self, term: &Term, fixups: &mut Vec<(usize, u8, BlockId)>) -> Result<()> {
        match term {
            Term::Jump(b) => {
                let pc = self.code.len();
                if self.leaf {
                    // Leaf bodies never charged branch latency on plain
                    // jumps (they are inlined straight-line code in HLS).
                    self.push(KOp::Jump { target: u32::MAX });
                } else {
                    self.push_costed(
                        KOp::Jump { target: u32::MAX },
                        KCost { base: KBase::Branch, exprs: vec![] },
                    );
                }
                fixups.push((pc, 0, *b));
            }
            Term::Sync { next } => {
                if self.mode == KernelMode::Explicit {
                    bail!("Sync terminator in explicit IR (`{}`)", self.func.name);
                }
                // Serial elision: children already ran; fall through.
                let pc = self.code.len();
                self.push(KOp::Jump { target: u32::MAX });
                fixups.push((pc, 0, *next));
            }
            Term::Branch { cond, then_, else_ } => {
                let c = self.emit_expr(cond)?;
                let pc = self.code.len();
                self.push_costed(
                    KOp::Branch { cond: c, then_: u32::MAX, else_: u32::MAX },
                    KCost { base: KBase::Branch, exprs: vec![] },
                );
                fixups.push((pc, 1, *then_));
                fixups.push((pc, 2, *else_));
            }
            Term::Return(value) => {
                if self.mode == KernelMode::Explicit && !self.leaf {
                    bail!("non-explicit terminator Return in task `{}`", self.func.name);
                }
                let val = match value {
                    Some(e) => Some(self.emit_expr(e)?),
                    None => None,
                };
                self.push(KOp::Return { value: val });
            }
            Term::Halt => {
                if self.mode == KernelMode::Implicit {
                    bail!("Halt terminator in implicit IR (`{}`)", self.func.name);
                }
                if self.leaf {
                    bail!("Halt terminator in leaf `{}`", self.func.name);
                }
                self.push(KOp::Halt);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::kernel::{run_kernel, KStack, Machine};
    use crate::interp::Memory;
    use crate::ir::cfg::GlobalId;
    use crate::lower::{compile, CompileOptions};
    use crate::workloads::{bfs, fib, nqueens, qsort, relax};

    /// Minimal machine for implicit kernels: real memory, no tasks.
    struct SerialMachine {
        mem: Memory,
    }

    impl Machine for SerialMachine {
        fn load(&mut self, arr: GlobalId, index: i64) -> Result<Value> {
            self.mem.load(arr, index)
        }
        fn store(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()> {
            self.mem.store(arr, index, value)
        }
        fn atomic_add(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()> {
            self.mem.atomic_add(arr, index, value)
        }
    }

    fn run_implicit(src: &str, entry: &str, args: &[Value]) -> (Value, SerialMachine) {
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let prog = compile_module(&r.implicit, KernelMode::Implicit).unwrap();
        let fid = prog.func_by_name(entry).unwrap();
        let mut m = SerialMachine { mem: Memory::new(&r.implicit) };
        let mut stack = KStack::new();
        let v = run_kernel(&prog, fid, args, &mut stack, &mut m, 100_000_000).unwrap();
        (v, m)
    }

    #[test]
    fn fib_runs_on_implicit_kernels() {
        for (n, expect) in [(0, 0), (1, 1), (10, 55), (15, 610)] {
            let (v, _) = run_implicit(fib::FIB_SRC, "fib", &[Value::I64(n)]);
            assert_eq!(v, Value::I64(expect), "fib({n})");
        }
    }

    #[test]
    fn loops_memory_and_leaf_calls() {
        let src = "global int a[8];
            int put(int i, int v) { a[i] = v; return v; }
            int go(int n) {
                int acc = 0;
                for (int i = 0; i < n; i = i + 1) {
                    int w = put(i, i * 3);
                    acc = acc + w;
                }
                return acc;
            }";
        let (v, m) = run_implicit(src, "go", &[Value::I64(8)]);
        assert_eq!(v, Value::I64(3 * (0 + 1 + 2 + 3 + 4 + 5 + 6 + 7)));
        let g = GlobalId::new(0);
        assert_eq!(m.mem.dump_i64(g), vec![0, 3, 6, 9, 12, 15, 18, 21]);
    }

    #[test]
    fn float_promotion_matches_tree_semantics() {
        let src = "float scale(float x, int n) {
            float acc = x;
            for (int i = 0; i < n; i = i + 1) { acc = acc * 1.5; }
            return acc;
        }";
        let (v, _) = run_implicit(src, "scale", &[Value::F32(2.0), Value::I64(3)]);
        assert_eq!(v, Value::F32(6.75));
    }

    #[test]
    fn infinite_loop_hits_fuel() {
        let src = "int f(int n) { while (true) { n = n + 1; } return n; }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let prog = compile_module(&r.implicit, KernelMode::Implicit).unwrap();
        let fid = prog.func_by_name("f").unwrap();
        let mut m = SerialMachine { mem: Memory::new(&r.implicit) };
        let mut stack = KStack::new();
        let err =
            run_kernel(&prog, fid, &[Value::I64(0)], &mut stack, &mut m, 10_000).unwrap_err();
        assert!(err.to_string().contains("step limit"), "{err}");
    }

    #[test]
    fn constants_fold_into_immediates() {
        let src = "int f(int n) { return n + 2 * 3; }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let prog = compile_module(&r.implicit, KernelMode::Implicit).unwrap();
        let disasm = prog.disasm();
        assert!(disasm.contains("imm(6)"), "folded constant missing:\n{disasm}");
        // And the folded program still computes correctly.
        let (v, _) = run_implicit(src, "f", &[Value::I64(4)]);
        assert_eq!(v, Value::I64(10));
    }

    #[test]
    fn all_corpus_workloads_compile_in_both_modes() {
        let programs: &[(&str, CompileOptions)] = &[
            (fib::FIB_SRC, CompileOptions::no_dae()),
            (bfs::BFS_SRC, CompileOptions::no_dae()),
            (bfs::BFS_DAE_SRC, CompileOptions::standard()),
            (nqueens::NQUEENS_SRC, CompileOptions::no_dae()),
            (qsort::QSORT_SRC, CompileOptions::no_dae()),
            (relax::RELAX_SRC, CompileOptions::standard()),
        ];
        for (i, (src, opts)) in programs.iter().enumerate() {
            let r = compile("t", src, opts).unwrap();
            let imp = compile_module(&r.implicit, KernelMode::Implicit).unwrap();
            assert!(imp.validate().is_empty(), "program {i} implicit");
            assert!(imp.instr_count() > 0);
            let exp = compile_module(&r.explicit, KernelMode::Explicit).unwrap();
            assert!(exp.validate().is_empty(), "program {i} explicit");
            // Explicit kernels never contain the serial-elision spawn.
            for k in &exp.funcs {
                for instr in &k.code {
                    assert!(
                        !matches!(instr.op, KOp::SpawnSeq { .. }),
                        "SpawnSeq leaked into explicit kernel `{}`",
                        k.name
                    );
                }
            }
        }
    }

    #[test]
    fn costs_mirror_hls_op_cycles() {
        use crate::hls::{op_cycles, ScheduleModel};
        // Explicit fib: for every costed instruction whose source op is
        // unambiguous, total cost cycles equal the HLS figure. Spot-check
        // the aggregate per kernel instead of per-op bookkeeping.
        let r = compile("t", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
        let prog = compile_module(&r.explicit, KernelMode::Explicit).unwrap();
        let model = ScheduleModel::default();
        for (fid, f) in r.explicit.funcs.iter() {
            let Some(cfg) = f.body.as_ref() else { continue };
            if f.kind == FuncKind::Xla {
                continue;
            }
            // HLS total over ops (terminator branch costs excluded — the
            // kernel charges those per *executed* terminator, as the
            // simulator always did).
            let mut hls_total = 0u32;
            for block in cfg.blocks.values() {
                for op in &block.ops {
                    if matches!(op, Op::Call { .. }) {
                        continue; // never charged at the call site
                    }
                    hls_total += op_cycles(&model, op);
                }
            }
            let k = prog.kernel(fid);
            let mut kernel_total = 0u32;
            for instr in &k.code {
                // Terminator costs stay excluded; a fused CmpBranch
                // carries exactly the branch terminator's cost (the
                // compare half is restricted to cost-free temporaries).
                if instr.cost != NO_COST
                    && !matches!(
                        instr.op,
                        KOp::Jump { .. } | KOp::Branch { .. } | KOp::CmpBranch { .. }
                    )
                {
                    kernel_total += k.costs[instr.cost as usize].cycles(&model);
                }
            }
            assert_eq!(kernel_total, hls_total, "kernel `{}`", k.name);
        }
    }

    fn has_fused(prog: &KernelProgram) -> bool {
        prog.funcs.iter().any(|k| {
            k.code.iter().any(|i| {
                matches!(
                    i.op,
                    KOp::CmpBranch { .. }
                        | KOp::LoadMov { .. }
                        | KOp::BinMov { .. }
                        | KOp::StoreBin { .. }
                        | KOp::ReturnBin { .. }
                        | KOp::LoadBinStore { .. }
                        | KOp::BinAtomicAdd { .. }
                        | KOp::SendBin { .. }
                )
            })
        })
    }

    #[test]
    fn fusion_fires_on_fib_and_gate_disables_it() {
        let r = compile("t", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
        for (module, mode) in [
            (&r.implicit, KernelMode::Implicit),
            (&r.explicit, KernelMode::Explicit),
        ] {
            let fused = compile_module_with(module, mode, true).unwrap();
            assert!(has_fused(&fused), "no fused ops in fib ({mode:?})");
            assert!(
                fused
                    .funcs
                    .iter()
                    .any(|k| k.code.iter().any(|i| matches!(i.op, KOp::CmpBranch { .. }))),
                "fib's `n < 2` must fuse to CmpBranch"
            );
            assert!(fused.fused_ratio() > 0.0);
            assert!(fused.validate().is_empty(), "{:?}", fused.validate());
            let unfused = compile_module_with(module, mode, false).unwrap();
            assert!(!has_fused(&unfused));
            assert_eq!(unfused.fused_ratio(), 0.0);
            assert!(fused.instr_count() < unfused.instr_count());
        }
    }

    #[test]
    fn fused_kernels_compute_the_same_values() {
        let src = "global int a[8];
            int f(int n) {
                int acc = 0;
                for (int i = 0; i < n; i = i + 1) {
                    a[i] = i * 2 + 1;
                    int w = a[i];
                    acc = acc + w;
                }
                return acc + n;
            }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let mut results = Vec::new();
        for fuse in [true, false] {
            let prog = compile_module_with(&r.implicit, KernelMode::Implicit, fuse).unwrap();
            let fid = prog.func_by_name("f").unwrap();
            let mut m = SerialMachine { mem: Memory::new(&r.implicit) };
            let mut stack = KStack::new();
            let v =
                run_kernel(&prog, fid, &[Value::I64(8)], &mut stack, &mut m, 1_000_000).unwrap();
            results.push((v, m.mem.dump_i64(GlobalId::new(0))));
        }
        assert_eq!(results[0], results[1], "fusion changed observable behavior");
    }

    #[test]
    fn branch_target_into_pair_second_suppresses_fusion() {
        use crate::frontend::ast::BinOp;
        // [0] cmp, [1] branch on it, [2] jump back *into* the branch,
        // [3] return. Fusing 0+1 would make pc 1 unreachable as a target.
        let cmp = KOp::Bin {
            op: BinOp::Lt,
            dst: 1,
            lhs: Operand::Slot(0),
            rhs: Operand::Imm(Value::I64(2)),
            ty: None,
        };
        let branch = KOp::Branch { cond: Operand::Slot(1), then_: 3, else_: 2 };
        let ret = KOp::Return { value: Some(Operand::Imm(Value::I64(0))) };
        let mut costs = Vec::new();
        let mut code = vec![
            KInstr::new(cmp.clone(), NO_COST),
            KInstr::new(branch.clone(), NO_COST),
            KInstr::new(KOp::Jump { target: 1 }, NO_COST),
            KInstr::new(ret.clone(), NO_COST),
        ];
        assert_eq!(fuse_code(&mut code, &mut costs), 0, "mid-pair target must suppress");
        assert_eq!(code.len(), 4);
        // Same shape, but the jump targets the *first* of the pair: fuses,
        // and every target remaps across the removed slot.
        let mut code = vec![
            KInstr::new(cmp, NO_COST),
            KInstr::new(branch, NO_COST),
            KInstr::new(KOp::Jump { target: 0 }, NO_COST),
            KInstr::new(ret, NO_COST),
        ];
        assert_eq!(fuse_code(&mut code, &mut costs), 1);
        assert_eq!(code.len(), 3);
        let KOp::CmpBranch { then_, else_, .. } = &code[0].op else {
            panic!("expected CmpBranch, got {:?}", code[0].op);
        };
        assert_eq!((*then_, *else_), (2, 1), "targets remapped over the fused pair");
        let KOp::Jump { target } = &code[1].op else {
            panic!("expected Jump, got {:?}", code[1].op);
        };
        assert_eq!(*target, 0);
    }

    #[test]
    fn fuse_gate_parses_env_values() {
        assert!(fuse_from(None));
        assert!(fuse_from(Some("1")));
        assert!(fuse_from(Some("")));
        assert!(!fuse_from(Some("0")));
    }

    #[test]
    fn triple_and_anchored_pair_windows_fuse() {
        use crate::frontend::ast::BinOp;
        let g = GlobalId::new(0);
        // Load → bin over the loaded slot → store of the bin result:
        // one LoadBinStore, two instructions eliminated.
        let mut costs = Vec::new();
        let mut code = vec![
            KInstr::new(KOp::Load { dst: 1, arr: g, index: Operand::Slot(0) }, NO_COST),
            KInstr::new(
                KOp::Bin {
                    op: BinOp::Add,
                    dst: 2,
                    lhs: Operand::Slot(1),
                    rhs: Operand::Imm(Value::I64(1)),
                    ty: None,
                },
                NO_COST,
            ),
            KInstr::new(
                KOp::Store { arr: g, index: Operand::Slot(0), value: Operand::Slot(2) },
                NO_COST,
            ),
            KInstr::new(KOp::Return { value: None }, NO_COST),
        ];
        assert_eq!(fuse_code(&mut code, &mut costs), 2);
        assert_eq!(code.len(), 2);
        assert!(matches!(code[0].op, KOp::LoadBinStore { .. }), "{:?}", code[0].op);

        // Bin feeding the next atomic_add's value operand.
        let mut code = vec![
            KInstr::new(
                KOp::Bin {
                    op: BinOp::Mul,
                    dst: 1,
                    lhs: Operand::Slot(0),
                    rhs: Operand::Imm(Value::I64(2)),
                    ty: None,
                },
                NO_COST,
            ),
            KInstr::new(
                KOp::AtomicAdd {
                    arr: g,
                    index: Operand::Imm(Value::I64(0)),
                    value: Operand::Slot(1),
                },
                NO_COST,
            ),
            KInstr::new(KOp::Return { value: None }, NO_COST),
        ];
        assert_eq!(fuse_code(&mut code, &mut costs), 1);
        assert!(matches!(code[0].op, KOp::BinAtomicAdd { .. }), "{:?}", code[0].op);

        // Bin feeding the outgoing argument send.
        let mut code = vec![
            KInstr::new(
                KOp::Bin {
                    op: BinOp::Add,
                    dst: 1,
                    lhs: Operand::Slot(0),
                    rhs: Operand::Slot(0),
                    ty: None,
                },
                NO_COST,
            ),
            KInstr::new(KOp::SendArgument { value: Some(Operand::Slot(1)) }, NO_COST),
            KInstr::new(KOp::Halt, NO_COST),
        ];
        assert_eq!(fuse_code(&mut code, &mut costs), 1);
        assert!(matches!(code[0].op, KOp::SendBin { .. }), "{:?}", code[0].op);
    }

    #[test]
    fn fused_rmw_shapes_compute_the_same_values() {
        use crate::workloads::rmw;
        let r = compile("t", rmw::RMW_SRC, &CompileOptions::no_dae()).unwrap();
        let fused = compile_module_with(&r.implicit, KernelMode::Implicit, true).unwrap();
        // The widened windows must actually fire on the source shapes.
        assert!(
            fused.funcs.iter().any(|k| k.code.iter().any(|i| matches!(
                i.op,
                KOp::LoadBinStore { .. } | KOp::BinAtomicAdd { .. } | KOp::SendBin { .. }
            ))),
            "no widened fused op in rmw:\n{}",
            fused.disasm()
        );
        let mut results = Vec::new();
        for fuse in [true, false] {
            let prog = compile_module_with(&r.implicit, KernelMode::Implicit, fuse).unwrap();
            let fid = prog.func_by_name("bump").unwrap();
            let mut m = SerialMachine { mem: Memory::new(&r.implicit) };
            rmw::init_memory(&r.implicit, &mut m.mem).unwrap();
            let mut stack = KStack::new();
            let v = run_kernel(
                &prog,
                fid,
                &[Value::I64(0), Value::I64(rmw::N as i64)],
                &mut stack,
                &mut m,
                1_000_000,
            )
            .unwrap();
            results.push((v, m.mem.dump_i64(GlobalId::new(0)), m.mem.dump_i64(GlobalId::new(1))));
        }
        assert_eq!(results[0], results[1], "fusion changed observable behavior");
        // And both match the Rust reference.
        let mut data = rmw::input();
        let (ret, acc) = rmw::rmw_ref(&mut data, 0, rmw::N as i64);
        assert_eq!(results[0].0, Value::I64(ret));
        assert_eq!(results[0].1, data);
        assert_eq!(results[0].2[0], acc);
    }
}
