//! Static analysis over the flat `KOp` stream, run once per kernel
//! before codegen.
//!
//! The JIT executes frame slots as raw `i64` bits, so it must know —
//! statically — what each slot's bits *mean*. A flow-insensitive
//! fixpoint assigns every slot a [`Tag`]:
//!
//! - `Int` / `Bool`: the slot always holds `Value::I64` / `Value::Bool`
//!   at every program point native code can observe it; its bits live in
//!   the JIT slot arena (`as_i64` image — for `Bool` always 0/1).
//! - `Unknown`: the slot is never written by a natively-executed
//!   instruction (every write that would produce `Unknown` bails), so
//!   the interpreter `Value` in `KStack::slots` stays authoritative;
//!   native reads see `as_i64` of the entry value (always `Unit` ⇒ 0,
//!   which is exactly what the interpreter's `as_*` accessors compute).
//! - `Poison`: the slot may hold `F32`. Same invariant as `Unknown`
//!   (never written natively — such writes bail), so runtime helpers can
//!   still materialize its true value from `KStack::slots`; only
//!   *inline* native reads are forbidden.
//!
//! On the same fixpoint, every instruction is classified into a
//! [`Kind`]: `Inline` (pure int compute / control flow, emitted as
//! native code), `Helper` (anything touching the [`Machine`] or slow
//! arithmetic — one out-call to the universal `exec_op` helper, which
//! replays the interpreter handler bit-for-bit), or `Bail` (terminal
//! for the native activation; the interpreter resumes at that pc).
//! Because a bail is terminal, a `Bail` instruction's frame writes are
//! unobservable by native code — they are still joined into the slot
//! tags, which only costs precision, never soundness.
//!
//! Finally a linear scan over slot use weights picks up to four hot
//! `Int`/`Bool` slots to pin in callee-saved registers for the whole
//! function body (intervals conservatively widened to the full range —
//! kernel frames are tiny, and whole-range pins need no boundary
//! loads/flushes anywhere except helper calls and bails).
//!
//! [`Machine`]: crate::exec::kernel::Machine

use crate::frontend::ast::{BinOp, Type, UnOp};
use crate::ir::expr::Value;

use super::super::kernel::{is_cmp_op, FuncKernel, KOp, KRet, Operand};
use super::asm::{Reg, R12, R15, RBP, RBX};

/// What a slot's raw bits mean to native code. Lattice order:
/// `Unknown < Int, Bool < Poison` (join goes toward `Poison`; `Int` and
/// `Bool` join to `Int`, which is sound because `Bool` bits are always a
/// valid 0/1 `i64` image and every consumer of an `Int`-tagged slot uses
/// `as_i64` semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Tag {
    Unknown,
    Int,
    Bool,
    Poison,
}

impl Tag {
    pub fn join(self, other: Tag) -> Tag {
        use Tag::*;
        match (self, other) {
            (Unknown, t) | (t, Unknown) => t,
            (Poison, _) | (_, Poison) => Poison,
            (Int, _) | (_, Int) => Int,
            (Bool, Bool) => Bool,
        }
    }
}

pub(crate) fn tag_of_type(ty: Type) -> Tag {
    match ty {
        Type::Int => Tag::Int,
        Type::Bool => Tag::Bool,
        Type::Float => Tag::Poison,
        Type::Void => Tag::Unknown,
    }
}

fn tag_of_value(v: Value) -> Tag {
    match v {
        Value::I64(_) => Tag::Int,
        Value::Bool(_) => Tag::Bool,
        Value::F32(_) => Tag::Poison,
        Value::Unit => Tag::Unknown,
    }
}

pub(crate) fn operand_tag(op: Operand, tags: &[Tag]) -> Tag {
    match op {
        Operand::Slot(s) => tags[s as usize],
        Operand::Imm(v) => tag_of_value(v),
    }
}

/// Tag of a value after the optional `coerce(ty)` every compute op
/// applies to its result.
fn apply_ty(raw: Tag, ty: Option<Type>) -> Tag {
    match ty {
        Some(t) => tag_of_type(t),
        None => raw,
    }
}

/// Result tag of `bin_value` given operand tags (mirrors its
/// float-promotion rule: only `Add|Sub|Mul|Div` promote, comparisons and
/// logic produce `Bool`, everything else goes through `as_i64`).
fn bin_tag(op: BinOp, a: Tag, b: Tag) -> Tag {
    use BinOp::*;
    match op {
        Lt | Le | Gt | Ge | Eq | Ne | And | Or => Tag::Bool,
        Add | Sub | Mul | Div => {
            if a == Tag::Poison || b == Tag::Poison {
                Tag::Poison
            } else {
                Tag::Int
            }
        }
        Rem | Shl | Shr | BitAnd | BitOr | BitXor => Tag::Int,
    }
}

fn un_tag(op: UnOp, v: Tag) -> Tag {
    match op {
        UnOp::Neg => {
            if v == Tag::Poison {
                Tag::Poison
            } else {
                Tag::Int
            }
        }
        UnOp::Not => Tag::Bool,
    }
}

/// `builtin1_value`/`builtin2_value` float-promote when any operand is
/// `F32`, otherwise stay `I64`.
fn builtin_tag(tags: &[Tag]) -> Tag {
    if tags.contains(&Tag::Poison) {
        Tag::Poison
    } else {
        Tag::Int
    }
}

/// Is `op` in the natively-inlined `bin_value` subset? `Div`/`Rem` trap
/// on hardware where the interpreter defines them (zero divisor,
/// `MIN/-1`), and `And`/`Or` produce `Bool` from `as_bool` semantics —
/// all four go through the helper instead.
pub(crate) fn bin_is_fast(op: BinOp) -> bool {
    use BinOp::*;
    matches!(op, Add | Sub | Mul | Shl | Shr | BitAnd | BitOr | BitXor) || is_cmp_op(op)
}

/// How one instruction executes inside a compiled kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Kind {
    /// Pure int compute / control flow, emitted as native code.
    Inline,
    /// One out-call to the universal `exec_op` runtime helper.
    Helper,
    /// Terminal: flush state, hand the frame back to the interpreter at
    /// this pc.
    Bail,
}

/// The per-kernel compilation plan.
pub(crate) struct Plan {
    pub tags: Vec<Tag>,
    pub kinds: Vec<Kind>,
    /// Hot `Int`/`Bool` slots pinned in callee-saved registers for the
    /// whole body, hottest first.
    pub pins: Vec<(u32, Reg)>,
}

fn for_each_read(op: &KOp, f: &mut impl FnMut(Operand)) {
    let mut args = |args_at: u32, nargs: u32| {
        for i in 0..nargs {
            f(Operand::Slot(args_at + i));
        }
    };
    match op {
        KOp::Mov { src, .. } => f(*src),
        KOp::Bin { lhs, rhs, .. }
        | KOp::Builtin2 { lhs, rhs, .. }
        | KOp::BinMov { lhs, rhs, .. }
        | KOp::ReturnBin { lhs, rhs, .. } => {
            f(*lhs);
            f(*rhs);
        }
        KOp::Un { src, .. } | KOp::Builtin1 { src, .. } | KOp::IntToFloat { src, .. } => f(*src),
        KOp::Load { index, .. } => f(*index),
        KOp::Store { index, value, .. } | KOp::AtomicAdd { index, value, .. } => {
            f(*index);
            f(*value);
        }
        KOp::Call { args_at, nargs, .. } | KOp::SpawnSeq { args_at, nargs, .. } => {
            args(*args_at, *nargs)
        }
        KOp::MakeClosure { .. } | KOp::Halt | KOp::Jump { .. } => {}
        KOp::ClosureStore { clos, value, .. } => {
            f(Operand::Slot(*clos));
            f(*value);
        }
        KOp::SpawnChild { args_at, nargs, ret, .. } => {
            args(*args_at, *nargs);
            match ret {
                KRet::Slot { clos, .. } | KRet::Counter { clos } => f(Operand::Slot(*clos)),
                KRet::Forward => {}
            }
        }
        KOp::CloseSpawns { clos } => f(Operand::Slot(*clos)),
        KOp::SendArgument { value } => {
            if let Some(o) = value {
                f(*o);
            }
        }
        KOp::Branch { cond, .. } => f(*cond),
        KOp::Return { value } => {
            if let Some(o) = value {
                f(*o);
            }
        }
        KOp::CmpBranch { lhs, rhs, .. } => {
            f(*lhs);
            f(*rhs);
        }
        KOp::LoadMov { index, .. } => f(*index),
        KOp::StoreBin { lhs, rhs, index, .. } => {
            f(*lhs);
            f(*rhs);
            f(*index);
        }
        KOp::LoadBinStore { index, lhs, rhs, sindex, .. } => {
            f(*index);
            f(*lhs);
            f(*rhs);
            f(*sindex);
        }
        KOp::BinAtomicAdd { lhs, rhs, index, .. } => {
            f(*lhs);
            f(*rhs);
            f(*index);
        }
        KOp::SendBin { lhs, rhs, .. } => {
            f(*lhs);
            f(*rhs);
        }
    }
}

/// Frame writes of `op` with the tag each would carry under the current
/// slot tags.
fn for_each_write(op: &KOp, tags: &[Tag], globals: &[Tag], f: &mut impl FnMut(u32, Tag)) {
    let ot = |o: &Operand| operand_tag(*o, tags);
    let gt = |g: &crate::ir::cfg::GlobalId| globals.get(g.index()).copied().unwrap_or(Tag::Poison);
    match op {
        KOp::Mov { dst, src, ty } => f(*dst, apply_ty(ot(src), *ty)),
        KOp::Bin { op, dst, lhs, rhs, ty } => {
            f(*dst, apply_ty(bin_tag(*op, ot(lhs), ot(rhs)), *ty))
        }
        KOp::Un { op, dst, src, ty } => f(*dst, apply_ty(un_tag(*op, ot(src)), *ty)),
        KOp::Builtin2 { dst, lhs, rhs, ty, .. } => {
            f(*dst, apply_ty(builtin_tag(&[ot(lhs), ot(rhs)]), *ty))
        }
        KOp::Builtin1 { dst, src, ty, .. } => f(*dst, apply_ty(builtin_tag(&[ot(src)]), *ty)),
        KOp::IntToFloat { dst, ty, .. } => f(*dst, apply_ty(Tag::Poison, *ty)),
        KOp::Load { dst, arr, .. } => f(*dst, gt(arr)),
        KOp::Call { dst, .. } | KOp::SpawnSeq { dst, .. } => {
            if let Some((d, t)) = dst {
                f(*d, tag_of_type(*t));
            }
        }
        KOp::MakeClosure { dst, .. } => f(*dst, Tag::Int),
        KOp::CmpBranch { dst, ty, .. } => f(*dst, apply_ty(Tag::Bool, *ty)),
        KOp::LoadMov { ldst, arr, dst, ty, .. } => {
            let g = gt(arr);
            f(*ldst, g);
            f(*dst, apply_ty(g, *ty));
        }
        KOp::BinMov { op, bdst, lhs, rhs, bty, dst, ty } => {
            let b = apply_ty(bin_tag(*op, ot(lhs), ot(rhs)), *bty);
            f(*bdst, b);
            f(*dst, apply_ty(b, *ty));
        }
        KOp::StoreBin { op, bdst, lhs, rhs, bty, .. }
        | KOp::ReturnBin { op, bdst, lhs, rhs, bty }
        | KOp::BinAtomicAdd { op, bdst, lhs, rhs, bty, .. }
        | KOp::SendBin { op, bdst, lhs, rhs, bty } => {
            f(*bdst, apply_ty(bin_tag(*op, ot(lhs), ot(rhs)), *bty));
        }
        KOp::LoadBinStore { ldst, arr, op, bdst, lhs, rhs, bty, .. } => {
            f(*ldst, gt(arr));
            f(*bdst, apply_ty(bin_tag(*op, ot(lhs), ot(rhs)), *bty));
        }
        KOp::Store { .. }
        | KOp::AtomicAdd { .. }
        | KOp::ClosureStore { .. }
        | KOp::SpawnChild { .. }
        | KOp::CloseSpawns { .. }
        | KOp::SendArgument { .. }
        | KOp::Jump { .. }
        | KOp::Branch { .. }
        | KOp::Return { .. }
        | KOp::Halt => {}
    }
}

/// Base execution kind by opcode alone (before tag-driven demotion).
fn base_kind(op: &KOp) -> Kind {
    match op {
        KOp::Mov { .. }
        | KOp::Un { .. }
        | KOp::Jump { .. }
        | KOp::Branch { .. }
        | KOp::Return { .. }
        | KOp::Halt
        | KOp::CmpBranch { .. } => Kind::Inline,
        KOp::Bin { op, .. } | KOp::BinMov { op, .. } => {
            if bin_is_fast(*op) {
                Kind::Inline
            } else {
                Kind::Helper
            }
        }
        KOp::ReturnBin { op, .. } => {
            // The slow-group result would have to thread through the
            // helper's return protocol; rare enough to hand back.
            if bin_is_fast(*op) {
                Kind::Inline
            } else {
                Kind::Bail
            }
        }
        // Rounds through f32 — unrepresentable in the int value model.
        KOp::IntToFloat { .. } => Kind::Bail,
        KOp::Builtin2 { .. }
        | KOp::Builtin1 { .. }
        | KOp::Load { .. }
        | KOp::Store { .. }
        | KOp::AtomicAdd { .. }
        | KOp::Call { .. }
        | KOp::SpawnSeq { .. }
        | KOp::MakeClosure { .. }
        | KOp::ClosureStore { .. }
        | KOp::SpawnChild { .. }
        | KOp::CloseSpawns { .. }
        | KOp::SendArgument { .. }
        | KOp::LoadMov { .. }
        | KOp::StoreBin { .. }
        | KOp::LoadBinStore { .. }
        | KOp::BinAtomicAdd { .. }
        | KOp::SendBin { .. } => Kind::Helper,
    }
}

fn classify(op: &KOp, tags: &[Tag], globals: &[Tag]) -> Kind {
    let kind = base_kind(op);
    if kind == Kind::Bail {
        return Kind::Bail;
    }
    // Inline code reads raw bits — a possibly-F32 operand sinks it.
    // Helpers materialize true `Value`s (Poison/Unknown slots read from
    // `KStack::slots`, which stays authoritative), so they keep going.
    if kind == Kind::Inline {
        let mut poisoned = false;
        for_each_read(op, &mut |o| poisoned |= operand_tag(o, tags) == Tag::Poison);
        if poisoned {
            return Kind::Bail;
        }
    }
    // No write may produce bits the arena can't represent (`Poison`) or
    // clobber a slot whose `KStack::slots` image must stay authoritative
    // (`Unknown`).
    let mut bad_write = false;
    for_each_write(op, tags, globals, &mut |_, t| {
        bad_write |= matches!(t, Tag::Poison | Tag::Unknown)
    });
    if bad_write {
        return Kind::Bail;
    }
    kind
}

/// Registers available for whole-body slot pins — callee-saved, so
/// runtime helpers preserve them for free.
const PIN_REGS: [Reg; 4] = [RBX, R12, R15, RBP];

/// Minimum inline-use weight for a pin to pay for its prologue load and
/// per-helper flush/reload traffic.
const PIN_MIN_WEIGHT: u32 = 3;

pub(crate) fn analyze(kernel: &FuncKernel, global_tags: &[Tag]) -> Plan {
    let nslots = kernel.frame.len();
    let mut tags: Vec<Tag> = kernel.frame.iter().map(|v| tag_of_value(*v)).collect();
    // Entry coerces every argument to its declared parameter type, so
    // param slots are typed by `param_tys` no matter what the caller
    // staged.
    for (i, ty) in kernel.param_tys.iter().enumerate().take(nslots) {
        tags[i] = tag_of_type(*ty);
    }

    // Flow-insensitive fixpoint: join every instruction's write tags
    // until stable. Monotone over a 4-point lattice, so it terminates
    // quickly; the iteration cap is a defensive backstop.
    for _ in 0..(2 * nslots + 4) {
        let mut changed = false;
        for instr in &kernel.code {
            for_each_write(&instr.op, &tags, global_tags, &mut |s, t| {
                let s = s as usize;
                let j = tags[s].join(t);
                if j != tags[s] {
                    tags[s] = j;
                    changed = true;
                }
            });
        }
        if !changed {
            break;
        }
    }

    let kinds: Vec<Kind> =
        kernel.code.iter().map(|i| classify(&i.op, &tags, global_tags)).collect();

    // Linear scan over use weights: pin the hottest pinnable slots.
    // Only `Inline` occurrences count — helper reads/writes go through
    // the arena memory either way.
    let mut weight = vec![0u32; nslots];
    for (instr, kind) in kernel.code.iter().zip(&kinds) {
        if *kind != Kind::Inline {
            continue;
        }
        for_each_read(&instr.op, &mut |o| {
            if let Operand::Slot(s) = o {
                weight[s as usize] += 1;
            }
        });
        for_each_write(&instr.op, &tags, global_tags, &mut |s, _| weight[s as usize] += 1);
    }
    let mut candidates: Vec<u32> = (0..nslots as u32)
        .filter(|&s| {
            matches!(tags[s as usize], Tag::Int | Tag::Bool)
                && weight[s as usize] >= PIN_MIN_WEIGHT
        })
        .collect();
    candidates.sort_by_key(|&s| (std::cmp::Reverse(weight[s as usize]), s));
    let pins = candidates.into_iter().zip(PIN_REGS).collect();

    Plan { tags, kinds, pins }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::kernel::{KInstr, NO_COST};
    use crate::ir::cfg::FuncKind;
    use std::sync::Arc;

    fn kernel(frame: Vec<Value>, params: Vec<Type>, code: Vec<KOp>) -> FuncKernel {
        let n = code.len() as u32;
        FuncKernel {
            name: "t".into(),
            kind: FuncKind::Task,
            role: "task",
            params: params.len(),
            param_tys: Arc::from(params.as_slice()),
            ret: Type::Int,
            frame,
            code: code.into_iter().map(|op| KInstr::new(op, NO_COST)).collect(),
            costs: Vec::new(),
            fused: 0,
            unfused_len: n,
        }
    }

    #[test]
    fn join_is_commutative_and_absorbing() {
        use Tag::*;
        for a in [Unknown, Int, Bool, Poison] {
            for b in [Unknown, Int, Bool, Poison] {
                assert_eq!(a.join(b), b.join(a));
                assert_eq!(a.join(Poison), Poison);
                assert_eq!(a.join(a), a);
            }
        }
        assert_eq!(Int.join(Bool), Int);
        assert_eq!(Unknown.join(Bool), Bool);
    }

    #[test]
    fn int_kernel_is_fully_inline_and_pins_hot_slots() {
        // param p0; t1 = p0 + p0 (x3 uses); return t1
        let k = kernel(
            vec![Value::I64(0), Value::Unit],
            vec![Type::Int],
            vec![
                KOp::Bin {
                    op: BinOp::Add,
                    dst: 1,
                    lhs: Operand::Slot(0),
                    rhs: Operand::Slot(0),
                    ty: None,
                },
                KOp::Bin {
                    op: BinOp::Add,
                    dst: 1,
                    lhs: Operand::Slot(1),
                    rhs: Operand::Slot(0),
                    ty: None,
                },
                KOp::Return { value: Some(Operand::Slot(1)) },
            ],
        );
        let plan = analyze(&k, &[]);
        assert_eq!(plan.tags, vec![Tag::Int, Tag::Int]);
        assert!(plan.kinds.iter().all(|k| *k == Kind::Inline));
        // Both slots have weight >= 3; slot 0 (weight 3) and slot 1
        // (weight 3+1 reads/writes) are pinned, hottest first.
        assert_eq!(plan.pins.len(), 2);
    }

    #[test]
    fn float_flow_poisons_and_bails() {
        // p0: float. mov t1 = p0 would carry F32 bits -> Bail; a store
        // of p0 only needs the helper -> Helper.
        let k = kernel(
            vec![Value::F32(0.0), Value::Unit],
            vec![Type::Float],
            vec![
                KOp::Mov { dst: 1, src: Operand::Slot(0), ty: None },
                KOp::Store {
                    arr: crate::util::idvec::Id::new(0),
                    index: Operand::Imm(Value::I64(0)),
                    value: Operand::Slot(0),
                },
                KOp::Return { value: None },
            ],
        );
        let plan = analyze(&k, &[Tag::Poison]);
        assert_eq!(plan.tags[0], Tag::Poison);
        assert_eq!(plan.kinds[0], Kind::Bail);
        assert_eq!(plan.kinds[1], Kind::Helper);
        assert_eq!(plan.kinds[2], Kind::Inline);
        assert!(plan.pins.is_empty());
    }

    #[test]
    fn slow_bins_take_the_helper_and_div_by_float_bails() {
        let k = kernel(
            vec![Value::I64(0), Value::Unit],
            vec![Type::Int],
            vec![
                KOp::Bin {
                    op: BinOp::Div,
                    dst: 1,
                    lhs: Operand::Slot(0),
                    rhs: Operand::Imm(Value::I64(3)),
                    ty: None,
                },
                KOp::Bin {
                    op: BinOp::Div,
                    dst: 1,
                    lhs: Operand::Slot(0),
                    rhs: Operand::Imm(Value::F32(2.0)),
                    ty: None,
                },
                KOp::Return { value: Some(Operand::Slot(1)) },
            ],
        );
        let plan = analyze(&k, &[]);
        assert_eq!(plan.kinds[0], Kind::Helper);
        // Float divisor promotes the result to F32: the write poisons,
        // the instruction bails, and slot 1 is poisoned for everyone.
        assert_eq!(plan.kinds[1], Kind::Bail);
        assert_eq!(plan.tags[1], Tag::Poison);
        // ...which also sinks the first Div (its write now computes
        // Poison via the join) and the Return read.
        assert_eq!(plan.kinds[2], Kind::Bail);
    }
}
