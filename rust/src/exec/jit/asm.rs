//! Minimal x86-64 instruction encoder for the JIT tier.
//!
//! Emits exactly the subset the kernel compiler needs — 64-bit
//! register/memory moves, the inlineable ALU group, `setcc`/`movzx`
//! flag materialization, variable shifts by `cl`, indirect calls through
//! the environment pointer, and rel32 control flow — into a flat byte
//! buffer. Branch targets are recorded symbolically (either a bytecode
//! `pc`, resolved against the per-instruction offset table, or an
//! internal [`Label`]) and patched in one pass by [`Asm::finalize`].
//!
//! Encoding notes: every integer op is emitted with `REX.W` (the kernel
//! value model is uniformly 64-bit), memory operands always use the
//! `mod=10` disp32 form (no compaction — compile time is off the hot
//! path and uniform encoding keeps this file small), and an SIB byte is
//! inserted only where the base register's low bits collide with the
//! SIB escape (`rsp`/`r12`).

/// A general-purpose register by hardware encoding number.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct Reg(pub u8);

pub(crate) const RAX: Reg = Reg(0);
pub(crate) const RCX: Reg = Reg(1);
#[allow(dead_code)]
pub(crate) const RDX: Reg = Reg(2);
pub(crate) const RBX: Reg = Reg(3);
pub(crate) const RSP: Reg = Reg(4);
pub(crate) const RBP: Reg = Reg(5);
pub(crate) const RSI: Reg = Reg(6);
pub(crate) const RDI: Reg = Reg(7);
#[allow(dead_code)]
pub(crate) const R8: Reg = Reg(8);
pub(crate) const R12: Reg = Reg(12);
pub(crate) const R13: Reg = Reg(13);
pub(crate) const R14: Reg = Reg(14);
pub(crate) const R15: Reg = Reg(15);

/// Condition codes (the low nibble of `setcc` / `jcc` opcodes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct Cc(pub u8);
pub(crate) const CC_E: Cc = Cc(0x4);
pub(crate) const CC_NE: Cc = Cc(0x5);
/// Unsigned above (used for the u64 step-budget compare).
pub(crate) const CC_A: Cc = Cc(0x7);
pub(crate) const CC_L: Cc = Cc(0xC);
pub(crate) const CC_GE: Cc = Cc(0xD);
pub(crate) const CC_LE: Cc = Cc(0xE);
pub(crate) const CC_G: Cc = Cc(0xF);

/// Internal jump target, bound at most once.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct Label(usize);

pub(crate) struct Asm {
    pub code: Vec<u8>,
    /// `(offset of a rel32 field, bytecode pc it targets)`.
    pc_refs: Vec<(usize, usize)>,
    /// `(offset of a rel32 field, label id it targets)`.
    label_refs: Vec<(usize, usize)>,
    label_offs: Vec<Option<usize>>,
}

impl Asm {
    pub fn new() -> Asm {
        Asm { code: Vec::with_capacity(1024), pc_refs: Vec::new(), label_refs: Vec::new(), label_offs: Vec::new() }
    }

    pub fn new_label(&mut self) -> Label {
        self.label_offs.push(None);
        Label(self.label_offs.len() - 1)
    }

    pub fn bind(&mut self, l: Label) {
        debug_assert!(self.label_offs[l.0].is_none(), "label bound twice");
        self.label_offs[l.0] = Some(self.code.len());
    }

    fn byte(&mut self, b: u8) {
        self.code.push(b);
    }

    fn i32le(&mut self, v: i32) {
        self.code.extend_from_slice(&v.to_le_bytes());
    }

    /// `REX.W` prefix for a 64-bit op with `reg` in the ModRM reg field
    /// and `rm` as the base/rm register.
    fn rex_w(&mut self, reg: Reg, rm: Reg) {
        self.byte(0x48 | ((reg.0 >> 3) << 2) | (rm.0 >> 3));
    }

    /// Optional `REX` (no W) — only when an extended register forces it.
    fn rex_opt(&mut self, reg: Reg, rm: Reg) {
        let b = 0x40 | ((reg.0 >> 3) << 2) | (rm.0 >> 3);
        if b != 0x40 {
            self.byte(b);
        }
    }

    fn modrm(&mut self, md: u8, reg: Reg, rm: Reg) {
        self.byte((md << 6) | ((reg.0 & 7) << 3) | (rm.0 & 7));
    }

    /// `[base + disp32]` memory operand (mod=10), with the SIB escape
    /// for `rsp`/`r12` bases.
    fn mem(&mut self, reg: Reg, base: Reg, disp: i32) {
        self.modrm(0b10, reg, base);
        if base.0 & 7 == 4 {
            self.byte(0x24); // SIB: scale=1, no index, base
        }
        self.i32le(disp);
    }

    // -- moves ------------------------------------------------------------

    /// `mov dst, src` (64-bit).
    pub fn mov_rr(&mut self, dst: Reg, src: Reg) {
        self.rex_w(src, dst);
        self.byte(0x89);
        self.modrm(0b11, src, dst);
    }

    /// `mov dst, [base + disp]`.
    pub fn mov_rm(&mut self, dst: Reg, base: Reg, disp: i32) {
        self.rex_w(dst, base);
        self.byte(0x8B);
        self.mem(dst, base, disp);
    }

    /// `mov [base + disp], src`.
    pub fn mov_mr(&mut self, base: Reg, disp: i32, src: Reg) {
        self.rex_w(src, base);
        self.byte(0x89);
        self.mem(src, base, disp);
    }

    /// `mov dst, imm` (sign-extended imm32 form when it fits, imm64
    /// otherwise).
    pub fn mov_ri(&mut self, dst: Reg, imm: i64) {
        if imm >= i32::MIN as i64 && imm <= i32::MAX as i64 {
            self.rex_w(Reg(0), dst);
            self.byte(0xC7);
            self.modrm(0b11, Reg(0), dst);
            self.i32le(imm as i32);
        } else {
            self.byte(0x48 | (dst.0 >> 3));
            self.byte(0xB8 + (dst.0 & 7));
            self.code.extend_from_slice(&imm.to_le_bytes());
        }
    }

    /// `mov eax, imm32` — zero-extends; used for the return status.
    pub fn mov_eax_imm(&mut self, imm: u32) {
        self.byte(0xB8);
        self.code.extend_from_slice(&imm.to_le_bytes());
    }

    // -- ALU --------------------------------------------------------------

    fn alu_rr(&mut self, opc: u8, dst: Reg, src: Reg) {
        self.rex_w(src, dst);
        self.byte(opc);
        self.modrm(0b11, src, dst);
    }

    pub fn add_rr(&mut self, dst: Reg, src: Reg) {
        self.alu_rr(0x01, dst, src);
    }

    pub fn sub_rr(&mut self, dst: Reg, src: Reg) {
        self.alu_rr(0x29, dst, src);
    }

    pub fn and_rr(&mut self, dst: Reg, src: Reg) {
        self.alu_rr(0x21, dst, src);
    }

    pub fn or_rr(&mut self, dst: Reg, src: Reg) {
        self.alu_rr(0x09, dst, src);
    }

    pub fn xor_rr(&mut self, dst: Reg, src: Reg) {
        self.alu_rr(0x31, dst, src);
    }

    pub fn cmp_rr(&mut self, a: Reg, b: Reg) {
        self.alu_rr(0x39, a, b);
    }

    /// `imul dst, src` (dst = dst * src, wrapping).
    pub fn imul_rr(&mut self, dst: Reg, src: Reg) {
        self.rex_w(dst, src);
        self.byte(0x0F);
        self.byte(0xAF);
        self.modrm(0b11, dst, src);
    }

    /// `neg r` (two's-complement, wrapping).
    pub fn neg(&mut self, r: Reg) {
        self.rex_w(Reg(3), r);
        self.byte(0xF7);
        self.modrm(0b11, Reg(3), r);
    }

    /// `test a, a` / `test a, b`.
    pub fn test_rr(&mut self, a: Reg, b: Reg) {
        self.rex_w(b, a);
        self.byte(0x85);
        self.modrm(0b11, b, a);
    }

    /// `add r, imm8` (sign-extended).
    pub fn add_ri8(&mut self, r: Reg, imm: i8) {
        self.rex_w(Reg(0), r);
        self.byte(0x83);
        self.modrm(0b11, Reg(0), r);
        self.byte(imm as u8);
    }

    /// `sub r, imm8` (sign-extended).
    pub fn sub_ri8(&mut self, r: Reg, imm: i8) {
        self.rex_w(Reg(5), r);
        self.byte(0x83);
        self.modrm(0b11, Reg(5), r);
        self.byte(imm as u8);
    }

    /// `cmp reg, [base + disp]`.
    pub fn cmp_rm(&mut self, reg: Reg, base: Reg, disp: i32) {
        self.rex_w(reg, base);
        self.byte(0x3B);
        self.mem(reg, base, disp);
    }

    /// `shl r, cl` (count masked to 63 by hardware — exactly
    /// `wrapping_shl`'s `& 63`).
    pub fn shl_cl(&mut self, r: Reg) {
        self.rex_w(Reg(4), r);
        self.byte(0xD3);
        self.modrm(0b11, Reg(4), r);
    }

    /// `sar r, cl` (arithmetic — `i64::wrapping_shr`).
    pub fn sar_cl(&mut self, r: Reg) {
        self.rex_w(Reg(7), r);
        self.byte(0xD3);
        self.modrm(0b11, Reg(7), r);
    }

    /// `setcc al ; movzx rax, al` — materialize the last compare's flag
    /// as 0/1 in `rax`.
    pub fn setcc_rax(&mut self, cc: Cc) {
        self.byte(0x0F);
        self.byte(0x90 + cc.0);
        self.byte(0xC0); // ModRM: /0, al
        self.byte(0x48);
        self.byte(0x0F);
        self.byte(0xB6);
        self.byte(0xC0); // movzx rax, al
    }

    /// Normalize `rax` to 0/1 (`test rax, rax ; setne al ; movzx`).
    pub fn bool_normalize_rax(&mut self) {
        self.test_rr(RAX, RAX);
        self.setcc_rax(CC_NE);
    }

    // -- calls and control flow -------------------------------------------

    /// `call qword [base + disp]`.
    pub fn call_mem(&mut self, base: Reg, disp: i32) {
        self.rex_opt(Reg(0), base);
        self.byte(0xFF);
        self.mem(Reg(2), base, disp);
    }

    pub fn push(&mut self, r: Reg) {
        if r.0 >= 8 {
            self.byte(0x41);
        }
        self.byte(0x50 + (r.0 & 7));
    }

    pub fn pop(&mut self, r: Reg) {
        if r.0 >= 8 {
            self.byte(0x41);
        }
        self.byte(0x58 + (r.0 & 7));
    }

    pub fn ret(&mut self) {
        self.byte(0xC3);
    }

    /// `jmp rel32` to a bytecode pc (patched by [`Asm::finalize`]).
    pub fn jmp_pc(&mut self, pc: usize) {
        self.byte(0xE9);
        self.pc_refs.push((self.code.len(), pc));
        self.i32le(0);
    }

    /// `jcc rel32` to a bytecode pc.
    pub fn jcc_pc(&mut self, cc: Cc, pc: usize) {
        self.byte(0x0F);
        self.byte(0x80 + cc.0);
        self.pc_refs.push((self.code.len(), pc));
        self.i32le(0);
    }

    /// `jmp rel32` to an internal label.
    pub fn jmp_label(&mut self, l: Label) {
        self.byte(0xE9);
        self.label_refs.push((self.code.len(), l.0));
        self.i32le(0);
    }

    /// `jcc rel32` to an internal label.
    pub fn jcc_label(&mut self, cc: Cc, l: Label) {
        self.byte(0x0F);
        self.byte(0x80 + cc.0);
        self.label_refs.push((self.code.len(), l.0));
        self.i32le(0);
    }

    /// Patch every recorded rel32 against the per-pc offset table and
    /// the bound labels. Returns the finished machine code.
    pub fn finalize(mut self, pc_offs: &[usize]) -> Vec<u8> {
        let patch = |code: &mut Vec<u8>, at: usize, target: usize| {
            let rel = target as i64 - (at as i64 + 4);
            debug_assert!(rel >= i32::MIN as i64 && rel <= i32::MAX as i64);
            code[at..at + 4].copy_from_slice(&(rel as i32).to_le_bytes());
        };
        let pc_refs = std::mem::take(&mut self.pc_refs);
        for (at, pc) in pc_refs {
            patch(&mut self.code, at, pc_offs[pc]);
        }
        let label_refs = std::mem::take(&mut self.label_refs);
        for (at, l) in label_refs {
            let target = self.label_offs[l].expect("unbound jit label");
            patch(&mut self.code, at, target);
        }
        self.code
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Byte-level pins for the trickiest encodings, checked against a
    // reference assembler's output.
    #[test]
    fn encodings_match_reference_bytes() {
        let mut a = Asm::new();
        a.mov_rr(R13, RDI); // mov r13, rdi -> 49 89 FD
        assert_eq!(a.code, [0x49, 0x89, 0xFD]);

        let mut a = Asm::new();
        a.mov_rm(R14, R13, 0); // mov r14, [r13+0] -> 4D 8B B5 00000000
        assert_eq!(a.code, [0x4D, 0x8B, 0xB5, 0, 0, 0, 0]);

        let mut a = Asm::new();
        a.mov_mr(R14, 8, RAX); // mov [r14+8], rax -> 49 89 86 08000000
        assert_eq!(a.code, [0x49, 0x89, 0x86, 0x08, 0, 0, 0, 0]);

        let mut a = Asm::new();
        a.mov_ri(RCX, 1); // mov rcx, 1 -> 48 C7 C1 01000000
        assert_eq!(a.code, [0x48, 0xC7, 0xC1, 1, 0, 0, 0]);

        let mut a = Asm::new();
        a.mov_ri(RAX, i64::MAX); // movabs
        assert_eq!(a.code[..2], [0x48, 0xB8]);
        assert_eq!(a.code.len(), 10);

        let mut a = Asm::new();
        a.call_mem(R13, 0x30); // call [r13+0x30] -> 41 FF 95 30000000
        assert_eq!(a.code, [0x41, 0xFF, 0x95, 0x30, 0, 0, 0, 0]);

        let mut a = Asm::new();
        a.imul_rr(RAX, RCX); // 48 0F AF C1
        assert_eq!(a.code, [0x48, 0x0F, 0xAF, 0xC1]);

        let mut a = Asm::new();
        a.setcc_rax(CC_L); // setl al; movzx rax, al
        assert_eq!(a.code, [0x0F, 0x9C, 0xC0, 0x48, 0x0F, 0xB6, 0xC0]);

        let mut a = Asm::new();
        a.push(R12);
        a.pop(RBX); // 41 54, 5B
        assert_eq!(a.code, [0x41, 0x54, 0x5B]);

        // rsp/r12 bases force an SIB byte.
        let mut a = Asm::new();
        a.mov_rm(RAX, RSP, 16); // mov rax, [rsp+16] -> 48 8B 84 24 10000000
        assert_eq!(a.code, [0x48, 0x8B, 0x84, 0x24, 0x10, 0, 0, 0, 0]);
    }

    #[test]
    fn rel32_patching_is_end_relative() {
        let mut a = Asm::new();
        let l = a.new_label();
        a.jmp_label(l); // 5 bytes
        a.ret(); // offset 5
        a.bind(l); // label at offset 6
        a.ret();
        let code = a.finalize(&[]);
        // rel32 = 6 - (1 + 4) = 1
        assert_eq!(&code[1..5], &1i32.to_le_bytes());
    }

    #[test]
    fn pc_refs_resolve_through_the_offset_table() {
        let mut a = Asm::new();
        a.jmp_pc(1); // 5 bytes at 0
        a.ret();
        let code = a.finalize(&[0, 6]);
        assert_eq!(&code[1..5], &1i32.to_le_bytes());
    }
}
