//! W^X executable code buffers, via raw Linux syscalls.
//!
//! The JIT needs `mmap`/`mprotect`/`munmap` and nothing else from the
//! OS, so rather than growing a dependency we issue the three syscalls
//! directly (x86-64 Linux ABI: number in `rax`, args in
//! `rdi/rsi/rdx/r10/r8/r9`, `rcx`/`r11` clobbered). Pages are mapped
//! read-write, filled, then flipped to read-execute before the first
//! call — never writable and executable at once, so the buffer works
//! under W^X-enforcing kernels. Environments that refuse even that
//! (e.g. seccomp'd sandboxes denying `mmap(PROT_EXEC)`) are detected by
//! [`probe`], which maps one page, runs a `mov eax, 42; ret` stub, and
//! reports failure as a reason string instead of faulting later.

const SYS_MMAP: usize = 9;
const SYS_MPROTECT: usize = 10;
const SYS_MUNMAP: usize = 11;

const PROT_READ: usize = 1;
const PROT_WRITE: usize = 2;
const PROT_EXEC: usize = 4;
const MAP_PRIVATE_ANON: usize = 0x22;

const PAGE: usize = 4096;

/// Raw syscall; returns the kernel's value (negative errno on failure,
/// encoded as a wrapped usize).
#[inline]
unsafe fn syscall6(num: usize, a1: usize, a2: usize, a3: usize, a4: usize, a5: usize, a6: usize) -> usize {
    let ret: usize;
    std::arch::asm!(
        "syscall",
        inlateout("rax") num => ret,
        in("rdi") a1,
        in("rsi") a2,
        in("rdx") a3,
        in("r10") a4,
        in("r8") a5,
        in("r9") a6,
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack),
    );
    ret
}

fn is_err(ret: usize) -> bool {
    // Errno range: -4095..=-1.
    ret > usize::MAX - 4096
}

/// An immutable, executable code region. `Send + Sync` because the
/// contents are sealed read-execute before the struct is constructed
/// and never modified afterwards.
pub(crate) struct ExecBuf {
    ptr: *const u8,
    len: usize,
}

unsafe impl Send for ExecBuf {}
unsafe impl Sync for ExecBuf {}

impl ExecBuf {
    /// Entry point of the published code.
    pub fn entry(&self) -> *const u8 {
        self.ptr
    }

    #[allow(dead_code)]
    pub fn len(&self) -> usize {
        self.len
    }

    /// Map, copy, and seal `code` as read-execute.
    pub fn publish(code: &[u8]) -> Result<ExecBuf, &'static str> {
        if code.is_empty() {
            return Err("jit: empty code buffer");
        }
        let len = (code.len() + PAGE - 1) & !(PAGE - 1);
        unsafe {
            let ptr = syscall6(SYS_MMAP, 0, len, PROT_READ | PROT_WRITE, MAP_PRIVATE_ANON, usize::MAX, 0);
            if is_err(ptr) {
                return Err("jit: mmap failed");
            }
            std::ptr::copy_nonoverlapping(code.as_ptr(), ptr as *mut u8, code.len());
            if is_err(syscall6(SYS_MPROTECT, ptr, len, PROT_READ | PROT_EXEC, 0, 0, 0)) {
                syscall6(SYS_MUNMAP, ptr, len, 0, 0, 0, 0);
                return Err("jit: mprotect(rx) refused (W^X-restricted environment)");
            }
            Ok(ExecBuf { ptr: ptr as *const u8, len })
        }
    }
}

impl Drop for ExecBuf {
    fn drop(&mut self) {
        unsafe {
            syscall6(SYS_MUNMAP, self.ptr as usize, self.len, 0, 0, 0, 0);
        }
    }
}

/// Map one page, run a trivial stub, verify the result. Proves at
/// runtime that this process may create and execute fresh code.
pub(crate) fn probe() -> Result<(), &'static str> {
    // mov eax, 42 ; ret
    let stub = [0xB8u8, 0x2A, 0x00, 0x00, 0x00, 0xC3];
    let buf = ExecBuf::publish(&stub)?;
    let f: extern "sysv64" fn() -> u32 = unsafe { std::mem::transmute(buf.entry()) };
    if f() == 42 {
        Ok(())
    } else {
        Err("jit: executable probe returned garbage")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_or_publish_agree() {
        // Either the environment supports runtime codegen (probe passes
        // and a published stub runs), or both fail cleanly.
        match probe() {
            Ok(()) => {
                let stub = [0xB8u8, 0x07, 0x00, 0x00, 0x00, 0xC3]; // mov eax, 7; ret
                let buf = ExecBuf::publish(&stub).expect("probe passed but publish failed");
                let f: extern "sysv64" fn() -> u32 = unsafe { std::mem::transmute(buf.entry()) };
                assert_eq!(f(), 7);
            }
            Err(reason) => assert!(!reason.is_empty()),
        }
    }
}
