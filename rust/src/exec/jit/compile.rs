//! Kernel bytecode → x86-64 lowering.
//!
//! One pass over the flat instruction stream, driven by the
//! [`analysis::Plan`]:
//!
//! - `Inline` ops become straight-line native code over the `i64` slot
//!   arena (`env.jslots`), with up to four hot slots pinned in
//!   callee-saved registers for the whole body.
//! - `Helper` ops compile to one out-call through the universal
//!   `exec_op` function pointer stored in the [`JitEnv`]: pins are
//!   flushed, `(env, pc)` is passed, and a nonzero status forwards
//!   straight to the epilogue (the runtime raises the stored error).
//! - `Bail` ops flush pins, record their pc in `env.bail_pc`, and
//!   return status 1 — the interpreter resumes the *same* frame
//!   activation at that pc with the step budget it left off at.
//!
//! Step budgeting matches the interpreter exactly: every `Jump` /
//! `Branch` / `CmpBranch` bumps `env.steps` against `env.limit` before
//! redirecting; on overflow the instruction bails *without* storing the
//! bumped count, so the interpreter re-executes it and raises the
//! step-limit error itself, bit-for-bit.
//!
//! Register conventions inside compiled code:
//!
//! | reg           | role                                    |
//! |---------------|-----------------------------------------|
//! | `r13`         | `*mut JitEnv`                           |
//! | `r14`         | `env.jslots` (this frame's slot arena)  |
//! | `rbx r12 r15 rbp` | pinned slots (callee-saved)         |
//! | `rax rcx rsi` | scratch                                 |
//!
//! The entry is `extern "sysv64" fn(*mut JitEnv) -> u64` with status
//! 0 = returned (`ret_bits`/`ret_kind` set), 1 = bailed (`bail_pc`),
//! 2 = helper error (stored in the runtime context).
//!
//! [`JitEnv`]: super::runtime::JitEnv

use crate::frontend::ast::{BinOp, Type, UnOp};
use crate::ir::cfg::FuncKind;
use crate::ir::expr::Value;

use super::super::kernel::{FuncKernel, KOp, Operand};
use super::analysis::{self, analyze, Kind, Plan, Tag};
use super::asm::{
    Asm, Cc, Label, Reg, CC_A, CC_E, CC_G, CC_GE, CC_L, CC_LE, CC_NE, R13, R14, RAX, RCX, RDI, RSI,
};
use super::buffer::ExecBuf;
use super::runtime::{
    OFF_BAIL_PC, OFF_HELPER, OFF_JSLOTS, OFF_LIMIT, OFF_RET_BITS, OFF_RET_KIND, OFF_STEPS,
};

/// Frames larger than this are not jitted (keeps every slot reachable
/// with an 8-bit-scaled disp32 and bounds arena carves).
pub(crate) const MAX_FRAME_SLOTS: usize = 4096;

/// A kernel compiled to native code, shared read-only across jobs.
pub(crate) struct CompiledKernel {
    pub buf: ExecBuf,
    /// Per-slot value tags — the runtime marshals/materializes with
    /// these.
    pub tags: Vec<Tag>,
    /// Machine-code size in bytes (stats only).
    pub code_bytes: usize,
}

fn cc_of(op: BinOp) -> Cc {
    match op {
        BinOp::Lt => CC_L,
        BinOp::Le => CC_LE,
        BinOp::Gt => CC_G,
        BinOp::Ge => CC_GE,
        BinOp::Eq => CC_E,
        BinOp::Ne => CC_NE,
        _ => unreachable!("cc_of on non-comparison"),
    }
}

struct Gen<'k> {
    a: Asm,
    plan: &'k Plan,
    epi: Label,
}

impl Gen<'_> {
    fn pin_of(&self, slot: u32) -> Option<Reg> {
        self.plan.pins.iter().find(|(s, _)| *s == slot).map(|(_, r)| *r)
    }

    fn load_slot(&mut self, dst: Reg, slot: u32) {
        match self.pin_of(slot) {
            Some(r) => self.a.mov_rr(dst, r),
            None => self.a.mov_rm(dst, R14, 8 * slot as i32),
        }
    }

    fn store_slot(&mut self, slot: u32, src: Reg) {
        match self.pin_of(slot) {
            Some(r) => self.a.mov_rr(r, src),
            None => self.a.mov_mr(R14, 8 * slot as i32, src),
        }
    }

    fn load_operand(&mut self, dst: Reg, o: Operand) {
        match o {
            Operand::Slot(s) => self.load_slot(dst, s),
            Operand::Imm(v) => {
                debug_assert!(!matches!(v, Value::F32(_)), "poison imm reached inline codegen");
                self.a.mov_ri(dst, v.as_i64());
            }
        }
    }

    fn flush_pins(&mut self) {
        for &(slot, reg) in &self.plan.pins {
            self.a.mov_mr(R14, 8 * slot as i32, reg);
        }
    }

    fn reload_pins(&mut self) {
        for &(slot, reg) in &self.plan.pins {
            self.a.mov_rm(reg, R14, 8 * slot as i32);
        }
    }

    /// Flush, record `pc`, return status 1.
    fn emit_bail(&mut self, pc: usize) {
        self.flush_pins();
        self.a.mov_ri(RAX, pc as i64);
        self.a.mov_mr(R13, OFF_BAIL_PC, RAX);
        self.a.mov_eax_imm(1);
        let epi = self.epi;
        self.a.jmp_label(epi);
    }

    /// `steps+1 > limit`? then bail (without storing — the interpreter
    /// re-executes this instruction and raises the error); else commit
    /// the bumped count. Leaves the bail label for the caller to bind
    /// after its terminal jumps.
    fn emit_budget(&mut self) -> Label {
        let lbail = self.a.new_label();
        self.a.mov_rm(RAX, R13, OFF_STEPS);
        self.a.add_ri8(RAX, 1);
        self.a.cmp_rm(RAX, R13, OFF_LIMIT);
        self.a.jcc_label(CC_A, lbail);
        self.a.mov_mr(R13, OFF_STEPS, RAX);
        lbail
    }

    /// One `exec_op` out-call for instruction `pc`.
    fn emit_helper_call(&mut self, pc: usize) {
        self.flush_pins();
        self.a.mov_rr(RDI, R13);
        self.a.mov_ri(RSI, pc as i64);
        self.a.call_mem(R13, OFF_HELPER);
        self.a.test_rr(RAX, RAX);
        let epi = self.epi;
        // Nonzero status (error) forwards as-is; pins reload only on
        // the success path (the helper may have rewritten their slots).
        self.a.jcc_label(CC_NE, epi);
        self.reload_pins();
    }

    /// Compute a fast `Bin` into `rax` from `lhs`/`rhs`, with the
    /// optional result coercion `ty` applied. `Bool` results are always
    /// canonical 0/1.
    fn emit_bin_fast(&mut self, op: BinOp, lhs: Operand, rhs: Operand, ty: Option<Type>) {
        self.load_operand(RAX, lhs);
        self.load_operand(RCX, rhs);
        if super::super::kernel::is_cmp_op(op) {
            self.a.cmp_rr(RAX, RCX);
            self.a.setcc_rax(cc_of(op));
            // coerce(Int)/coerce(Bool) are both bit-identity on 0/1.
            return;
        }
        match op {
            BinOp::Add => self.a.add_rr(RAX, RCX),
            BinOp::Sub => self.a.sub_rr(RAX, RCX),
            BinOp::Mul => self.a.imul_rr(RAX, RCX),
            BinOp::BitAnd => self.a.and_rr(RAX, RCX),
            BinOp::BitOr => self.a.or_rr(RAX, RCX),
            BinOp::BitXor => self.a.xor_rr(RAX, RCX),
            // Hardware masks the count to 63 — exactly the
            // interpreter's `wrapping_shl/shr(.. & 63)`.
            BinOp::Shl => self.a.shl_cl(RAX),
            BinOp::Shr => self.a.sar_cl(RAX),
            _ => unreachable!("slow bin reached inline codegen"),
        }
        if ty == Some(Type::Bool) {
            self.a.bool_normalize_rax();
        }
    }

    /// Coerce the `Int`-or-`Bool` value in `rax` (current tag `from`)
    /// to `ty`'s representation. Only `Bool` targets ever change bits.
    fn emit_coerce_rax(&mut self, from: Tag, ty: Option<Type>) {
        if ty == Some(Type::Bool) && from != Tag::Bool {
            self.a.bool_normalize_rax();
        }
    }
}

/// Compile one kernel, or say why it can't be.
pub(crate) fn compile_kernel(
    kernel: &FuncKernel,
    global_tags: &[Tag],
) -> Result<CompiledKernel, &'static str> {
    if kernel.kind == FuncKind::Xla {
        return Err("xla kernels have no body");
    }
    if kernel.code.is_empty() {
        return Err("empty kernel body");
    }
    if kernel.frame.len() > MAX_FRAME_SLOTS {
        return Err("frame too large");
    }
    let plan = analyze(kernel, global_tags);
    if plan.kinds[0] == Kind::Bail {
        return Err("entry instruction unsupported");
    }

    let n = kernel.code.len();
    let mut a = Asm::new();
    let epi = a.new_label();
    let mut g = Gen { a, plan: &plan, epi };

    // Prologue: save callee-saved state, align, load env/arena/pins.
    // 6 pushes + the return address leave rsp ≡ 0 (mod 16) after the
    // `sub`, so every helper call sees a standard-aligned stack.
    for r in [super::asm::RBP, super::asm::RBX, super::asm::R12, R13, R14, super::asm::R15] {
        g.a.push(r);
    }
    g.a.sub_ri8(super::asm::RSP, 8);
    g.a.mov_rr(R13, RDI);
    g.a.mov_rm(R14, R13, OFF_JSLOTS);
    g.reload_pins();

    let mut pc_offs = vec![0usize; n + 1];
    for (pc, instr) in kernel.code.iter().enumerate() {
        pc_offs[pc] = g.a.code.len();
        match plan.kinds[pc] {
            Kind::Bail => g.emit_bail(pc),
            Kind::Helper => g.emit_helper_call(pc),
            Kind::Inline => emit_inline(&mut g, pc, &instr.op, &plan),
        }
    }
    // Defensive: falling off the end re-enters the interpreter at
    // `pc == n`, which fails exactly like the interpreter would.
    pc_offs[n] = g.a.code.len();
    g.emit_bail(n);

    let epi = g.epi;
    g.a.bind(epi);
    g.a.add_ri8(super::asm::RSP, 8);
    for r in [super::asm::R15, R14, R13, super::asm::R12, super::asm::RBX, super::asm::RBP] {
        g.a.pop(r);
    }
    g.a.ret();

    let code = g.a.finalize(&pc_offs);
    let code_bytes = code.len();
    let buf = ExecBuf::publish(&code)?;
    Ok(CompiledKernel { buf, tags: plan.tags, code_bytes })
}

fn emit_inline(g: &mut Gen<'_>, pc: usize, op: &KOp, plan: &Plan) {
    let epi = g.epi;
    match op {
        KOp::Mov { dst, src, ty } => {
            g.load_operand(RAX, *src);
            g.emit_coerce_rax(analysis::operand_tag(*src, &plan.tags), *ty);
            g.store_slot(*dst, RAX);
        }
        KOp::Un { op, dst, src, ty } => {
            g.load_operand(RAX, *src);
            match op {
                UnOp::Neg => {
                    g.a.neg(RAX);
                    g.emit_coerce_rax(Tag::Int, *ty);
                }
                UnOp::Not => {
                    // `Bool(!as_bool(v))` — true iff the bits are zero.
                    g.a.test_rr(RAX, RAX);
                    g.a.setcc_rax(CC_E);
                }
            }
            g.store_slot(*dst, RAX);
        }
        KOp::Bin { op, dst, lhs, rhs, ty } => {
            g.emit_bin_fast(*op, *lhs, *rhs, *ty);
            g.store_slot(*dst, RAX);
        }
        KOp::BinMov { op, bdst, lhs, rhs, bty, dst, ty } => {
            g.emit_bin_fast(*op, *lhs, *rhs, *bty);
            g.store_slot(*bdst, RAX);
            let btag = if super::super::kernel::is_cmp_op(*op) || *bty == Some(Type::Bool) {
                Tag::Bool
            } else {
                Tag::Int
            };
            g.emit_coerce_rax(btag, *ty);
            g.store_slot(*dst, RAX);
        }
        KOp::Jump { target } => {
            let lbail = g.emit_budget();
            g.a.jmp_pc(*target as usize);
            g.a.bind(lbail);
            g.emit_bail(pc);
        }
        KOp::Branch { cond, then_, else_ } => {
            let lbail = g.emit_budget();
            g.load_operand(RAX, *cond);
            g.a.test_rr(RAX, RAX);
            g.a.jcc_pc(CC_NE, *then_ as usize);
            g.a.jmp_pc(*else_ as usize);
            g.a.bind(lbail);
            g.emit_bail(pc);
        }
        KOp::CmpBranch { op, dst, lhs, rhs, ty: _, then_, else_ } => {
            // Budget first: a budget bail then replays the *whole*
            // instruction in the interpreter from untouched state (which
            // writes `dst` and raises the step-limit error, exactly the
            // unjitted order). The bump itself is unobservable.
            let lbail = g.emit_budget();
            g.load_operand(RAX, *lhs);
            g.load_operand(RCX, *rhs);
            g.a.cmp_rr(RAX, RCX);
            g.a.setcc_rax(cc_of(*op));
            g.store_slot(*dst, RAX);
            g.a.test_rr(RAX, RAX);
            g.a.jcc_pc(CC_NE, *then_ as usize);
            g.a.jmp_pc(*else_ as usize);
            g.a.bind(lbail);
            g.emit_bail(pc);
        }
        KOp::Return { value } => {
            if let Some(o) = value {
                g.load_operand(RAX, *o);
                g.a.mov_mr(R13, OFF_RET_BITS, RAX);
                g.a.mov_ri(RCX, 1);
                g.a.mov_mr(R13, OFF_RET_KIND, RCX);
            }
            g.a.mov_eax_imm(0);
            g.a.jmp_label(epi);
        }
        KOp::ReturnBin { op, bdst, lhs, rhs, bty } => {
            g.emit_bin_fast(*op, *lhs, *rhs, *bty);
            g.store_slot(*bdst, RAX);
            g.a.mov_mr(R13, OFF_RET_BITS, RAX);
            g.a.mov_ri(RCX, 1);
            g.a.mov_mr(R13, OFF_RET_KIND, RCX);
            g.a.mov_eax_imm(0);
            g.a.jmp_label(epi);
        }
        KOp::Halt => {
            // ret_kind stays 0 (preset by the runtime) -> Unit.
            g.a.mov_eax_imm(0);
            g.a.jmp_label(epi);
        }
        _ => unreachable!("non-inline op {op:?} reached emit_inline"),
    }
}
