//! Tiered native execution for the kernel core.
//!
//! The interpreter ([`run_kernel`]) stays the baseline tier: every
//! kernel starts there, and the simulator (whose `KCost` timing is
//! defined in interpreter dispatch units) never leaves it. Engines that
//! opt in hand `exec_frame` a [`JitTier`]; once a kernel's per-tier
//! dispatch count passes the threshold it is compiled to x86-64
//! ([`compile`]) and subsequent activations run natively, calling back
//! into the engine's [`Machine`] for every effect and bailing to the
//! interpreter for anything unsupported ([`runtime`]).
//!
//! Compiled code is memoized per [`KernelProgram`] *identity* in a
//! process-wide intern table, so the resident executor's jobs (which
//! share one `CompileSession` kernel `Arc`) share machine code while
//! each keeps its own hotness counters.
//!
//! Tiering controls, in priority order: `--jit-threshold N` (CLI,
//! [`set_threshold_override`]) > `BOMBYX_JIT_THRESHOLD` > the default
//! of [`DEFAULT_THRESHOLD`]. `BOMBYX_JIT=0` disables the tier entirely,
//! restoring pure-interpreter behavior. Native codegen additionally
//! requires a runtime [`available`] probe to pass (x86-64 Linux and a
//! W^X-mappable page); anywhere it fails the tier silently stays
//! interpreted and the reason is surfaced as `jit.disabled_reason.*`
//! metrics.
//!
//! [`run_kernel`]: crate::exec::kernel::run_kernel
//! [`Machine`]: crate::exec::kernel::Machine
//! [`KernelProgram`]: crate::exec::kernel::KernelProgram

pub(crate) mod analysis;
pub(crate) mod asm;

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub(crate) mod buffer;
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub(crate) mod compile;
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub(crate) mod runtime;

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};

use crate::exec::kernel::KernelProgram;
use crate::ir::expr::Value;
use crate::obs;

use analysis::{tag_of_type, Tag};

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub(crate) use runtime::try_enter;

/// Dispatches a kernel stays interpreted before promotion.
pub const DEFAULT_THRESHOLD: u64 = 64;

/// Per-consumer tiering policy (resolved once per engine/job).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JitConfig {
    pub enabled: bool,
    pub threshold: u64,
}

/// CLI `--jit-threshold` override (wins over `BOMBYX_JIT_THRESHOLD`).
/// `-1` = unset.
static THRESHOLD_OVERRIDE: AtomicI64 = AtomicI64::new(-1);

pub fn set_threshold_override(threshold: u64) {
    THRESHOLD_OVERRIDE.store(threshold.min(i64::MAX as u64) as i64, Ordering::Relaxed);
}

impl JitConfig {
    /// Environment-driven default: on unless `BOMBYX_JIT=0`, threshold
    /// from the CLI override, then `BOMBYX_JIT_THRESHOLD`, then
    /// [`DEFAULT_THRESHOLD`].
    pub fn from_env() -> JitConfig {
        let enabled = std::env::var("BOMBYX_JIT").map_or(true, |v| v != "0");
        let threshold = match THRESHOLD_OVERRIDE.load(Ordering::Relaxed) {
            n if n >= 0 => n as u64,
            _ => std::env::var("BOMBYX_JIT_THRESHOLD")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(DEFAULT_THRESHOLD),
        };
        JitConfig { enabled, threshold }
    }

    pub fn disabled() -> JitConfig {
        JitConfig { enabled: false, threshold: DEFAULT_THRESHOLD }
    }

    /// Forced-on with an explicit threshold (tests; `0` = jit from the
    /// first dispatch).
    pub fn forced(threshold: u64) -> JitConfig {
        JitConfig { enabled: true, threshold }
    }
}

// ---------------------------------------------------------------------------
// Feature detection

/// Can this process generate and execute native code? Checked once:
/// compile-time target gates, then a live mmap/mprotect/execute probe
/// (W^X-restricted sandboxes fail here, not at first promotion). On the
/// first failure the reason lands in the metrics registry as
/// `jit.disabled` + `jit.disabled_reason.<slug>`.
pub fn available() -> Result<(), &'static str> {
    static PROBE: OnceLock<Result<(), &'static str>> = OnceLock::new();
    *PROBE.get_or_init(|| {
        let r = probe_target();
        if let Err(reason) = r {
            obs::metrics::counter_set("jit.disabled", 1);
            let mut slug: String = reason
                .trim_start_matches("jit: ")
                .chars()
                .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
                .collect();
            slug.truncate(48);
            obs::metrics::counter_set(&format!("jit.disabled_reason.{slug}"), 1);
        }
        r
    })
}

/// Why the JIT is off, if it is.
pub fn disabled_reason() -> Option<&'static str> {
    available().err()
}

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
fn probe_target() -> Result<(), &'static str> {
    buffer::probe()
}

#[cfg(not(all(target_arch = "x86_64", target_os = "linux")))]
fn probe_target() -> Result<(), &'static str> {
    Err("jit: unsupported target (requires x86-64 linux)")
}

// ---------------------------------------------------------------------------
// Compiled programs + tiers

/// Per-kernel native artifact and its lifetime counters.
pub struct JitFunc {
    /// `None` after a failed compile (the kernel stays interpreted).
    #[cfg(all(target_arch = "x86_64", target_os = "linux"))]
    cell: OnceLock<Option<compile::CompiledKernel>>,
    /// Dispatches seen by dropped tiers (live tiers flush on drop).
    pub dispatches: AtomicU64,
    /// Native activations entered.
    pub entries: AtomicU64,
    /// Native activations that bailed back to the interpreter.
    pub bails: AtomicU64,
    pub compile_ns: AtomicU64,
    /// Why compilation was refused, when it was.
    pub uncompilable: OnceLock<&'static str>,
}

impl JitFunc {
    fn new() -> JitFunc {
        JitFunc {
            #[cfg(all(target_arch = "x86_64", target_os = "linux"))]
            cell: OnceLock::new(),
            dispatches: AtomicU64::new(0),
            entries: AtomicU64::new(0),
            bails: AtomicU64::new(0),
            compile_ns: AtomicU64::new(0),
            uncompilable: OnceLock::new(),
        }
    }
}

/// All native state for one `KernelProgram`: compiled code cells plus
/// aggregate counters, shared by every tier over the same program.
pub struct JitProgram {
    pub(crate) kernels: Arc<KernelProgram>,
    #[cfg_attr(not(all(target_arch = "x86_64", target_os = "linux")), allow(dead_code))]
    global_tags: Vec<Tag>,
    pub funcs: Vec<JitFunc>,
}

impl JitProgram {
    fn new(kernels: Arc<KernelProgram>) -> JitProgram {
        let global_tags = kernels.global_tys.iter().map(|&t| tag_of_type(t)).collect();
        let funcs = (0..kernels.funcs.len()).map(|_| JitFunc::new()).collect();
        JitProgram { kernels, global_tags, funcs }
    }

    /// Get-or-compile kernel `fi`. Compilation happens once per program
    /// (all jobs share the artifact), under a `jit-compile` span.
    #[cfg(all(target_arch = "x86_64", target_os = "linux"))]
    pub(crate) fn compiled(&self, fi: usize) -> Option<&compile::CompiledKernel> {
        self.funcs[fi]
            .cell
            .get_or_init(|| {
                let kernel = &self.kernels.funcs[fi];
                let span = obs::Span::enter(format!("jit-compile {}", kernel.name), "jit");
                let r = compile::compile_kernel(kernel, &self.global_tags);
                let took = span.finish();
                self.funcs[fi].compile_ns.store(took.as_nanos() as u64, Ordering::Relaxed);
                match r {
                    Ok(ck) => {
                        obs::metrics::counter_add("jit.compiled", 1);
                        obs::metrics::observe_ms("jit.compile_ms", took);
                        Some(ck)
                    }
                    Err(reason) => {
                        let _ = self.funcs[fi].uncompilable.set(reason);
                        obs::metrics::counter_add("jit.uncompilable", 1);
                        None
                    }
                }
            })
            .as_ref()
    }
}

/// One consumer's handle on the tier: shared compiled code + private
/// hotness counters, so each job/engine crosses the promotion threshold
/// on its own dispatch volume.
pub struct JitTier {
    pub(crate) program: Arc<JitProgram>,
    pub(crate) threshold: u64,
    pub(crate) hot: Box<[AtomicU64]>,
}

impl Drop for JitTier {
    fn drop(&mut self) {
        for (h, f) in self.hot.iter().zip(&self.program.funcs) {
            let n = h.load(Ordering::Relaxed);
            if n > 0 {
                f.dispatches.fetch_add(n, Ordering::Relaxed);
            }
        }
    }
}

/// Process-wide intern table: one `JitProgram` per live `KernelProgram`
/// identity (`Arc` pointer). The strong `Arc<KernelProgram>` inside a
/// live `JitProgram` keeps the key's address from being reused.
static PROGRAMS: Mutex<Vec<(usize, Weak<JitProgram>)>> = Mutex::new(Vec::new());

fn intern(kernels: &Arc<KernelProgram>) -> Arc<JitProgram> {
    let key = Arc::as_ptr(kernels) as usize;
    let mut table = PROGRAMS.lock().unwrap();
    table.retain(|(_, w)| w.strong_count() > 0);
    if let Some(p) = table.iter().find(|(k, _)| *k == key).and_then(|(_, w)| w.upgrade()) {
        return p;
    }
    let p = Arc::new(JitProgram::new(Arc::clone(kernels)));
    table.push((key, Arc::downgrade(&p)));
    p
}

/// Acquire a tier for `kernels` under the environment-default config.
pub fn tier_for(kernels: &Arc<KernelProgram>) -> Option<Arc<JitTier>> {
    tier_with(kernels, JitConfig::from_env())
}

/// Acquire a tier under an explicit config. `None` = stay interpreted
/// (disabled, or native codegen unavailable here).
pub fn tier_with(kernels: &Arc<KernelProgram>, cfg: JitConfig) -> Option<Arc<JitTier>> {
    if !cfg.enabled || available().is_err() {
        return None;
    }
    let program = intern(kernels);
    let hot = (0..kernels.funcs.len()).map(|_| AtomicU64::new(0)).collect();
    Some(Arc::new(JitTier { program, threshold: cfg.threshold, hot }))
}

/// What one native activation produced.
pub(crate) enum Outcome {
    Done(Value),
    /// Resume the same frame activation in the interpreter at `pc` with
    /// `steps` of the budget already consumed.
    Bail { pc: usize, steps: u64 },
}

/// Stub tier entry for targets without native codegen ([`tier_with`]
/// never hands out a tier there, so this is unreachable in practice).
#[cfg(not(all(target_arch = "x86_64", target_os = "linux")))]
pub(crate) fn try_enter<M: crate::exec::kernel::Machine>(
    _tier: &JitTier,
    _prog: &KernelProgram,
    _fid: crate::ir::cfg::FuncId,
    _base: usize,
    _stack: &mut crate::exec::kernel::KStack,
    _machine: &mut M,
) -> anyhow::Result<Option<Outcome>> {
    Ok(None)
}

// ---------------------------------------------------------------------------
// Stats (the `run --stats` tier table)

/// Per-kernel tier stats, aggregated over every dropped tier of the
/// program (live tiers flush their dispatch counts on drop).
pub struct JitKernelStats {
    pub name: String,
    pub dispatches: u64,
    pub entries: u64,
    pub bails: u64,
    pub compile_ms: f64,
    pub code_bytes: usize,
    pub uncompilable: Option<&'static str>,
}

/// Peek the intern table for `kernels`' tier stats (empty when no tier
/// was ever created for it).
pub fn stats_for(kernels: &Arc<KernelProgram>) -> Vec<JitKernelStats> {
    let key = Arc::as_ptr(kernels) as usize;
    let prog = {
        let table = PROGRAMS.lock().unwrap();
        table.iter().find(|(k, _)| *k == key).and_then(|(_, w)| w.upgrade())
    };
    let Some(prog) = prog else { return Vec::new() };
    prog.funcs
        .iter()
        .enumerate()
        .map(|(i, f)| JitKernelStats {
            name: kernels.funcs[i].name.clone(),
            dispatches: f.dispatches.load(Ordering::Relaxed),
            entries: f.entries.load(Ordering::Relaxed),
            bails: f.bails.load(Ordering::Relaxed),
            compile_ms: f.compile_ns.load(Ordering::Relaxed) as f64 / 1e6,
            code_bytes: code_bytes(&prog, i),
            uncompilable: f.uncompilable.get().copied(),
        })
        .collect()
}

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
fn code_bytes(prog: &JitProgram, fi: usize) -> usize {
    prog.funcs[fi].cell.get().and_then(|c| c.as_ref()).map_or(0, |c| c.code_bytes)
}

#[cfg(not(all(target_arch = "x86_64", target_os = "linux")))]
fn code_bytes(_prog: &JitProgram, _fi: usize) -> usize {
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_resolution_order() {
        // Untouched env in tests is not guaranteed, so only exercise the
        // pure constructors here (env-driven behavior is covered by the
        // differential suite run under both BOMBYX_JIT settings).
        assert!(!JitConfig::disabled().enabled);
        assert_eq!(JitConfig::forced(0), JitConfig { enabled: true, threshold: 0 });
    }

    #[test]
    fn availability_is_stable_and_reasoned() {
        let first = available();
        assert_eq!(first, available());
        match first {
            Ok(()) => assert!(disabled_reason().is_none()),
            Err(reason) => assert!(reason.starts_with("jit:")),
        }
    }
}
