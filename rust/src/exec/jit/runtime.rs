//! The native⇄interpreter boundary: frame entry, the universal runtime
//! helper, and bailout.
//!
//! Compiled code runs over a flat `i64` slot arena carved out of
//! [`KStack::jslots`] (one lazily-allocated, never-reallocated block, so
//! parent-frame pointers stay valid across nested activations). All
//! communication goes through one `#[repr(C)]` [`JitEnv`] whose field
//! offsets are fixed constants shared with the code generator (pinned by
//! a layout test below).
//!
//! Everything effectful — memory, closures, spawns, sends, nested calls,
//! slow arithmetic — funnels through a single helper entry point,
//! [`exec_op_shim`], monomorphized per [`Machine`]. The helper decodes
//! the instruction at the pc the native code passes and replays the
//! interpreter handler's semantics bit-for-bit, materializing true
//! [`Value`]s from the arena bits (or from `KStack::slots` for
//! `Unknown`/`Poison` slots, which native code never writes). Panics
//! unwinding out of machine callbacks are caught at the FFI boundary,
//! stashed, and resumed on the Rust side of the native call.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

use anyhow::{anyhow, bail, Result};

use crate::exec::kernel::{
    bin_value, builtin1_value, builtin2_value, exec_frame, un_value, FuncKernel, KOp, KRet,
    KStack, KernelProgram, KontRef, Machine, Operand, MAX_DEPTH, NO_COST,
};
use crate::frontend::ast::Type;
use crate::ir::cfg::{FuncId, FuncKind};
use crate::ir::expr::Value;

use super::analysis::Tag;
use super::{JitTier, Outcome};

// Field offsets of `JitEnv`, shared with the code generator. Pinned by
// `jit_env_layout_is_the_codegen_contract`.
pub(crate) const OFF_JSLOTS: i32 = 0x00;
pub(crate) const OFF_STEPS: i32 = 0x08;
pub(crate) const OFF_LIMIT: i32 = 0x10;
pub(crate) const OFF_BAIL_PC: i32 = 0x18;
pub(crate) const OFF_RET_BITS: i32 = 0x20;
pub(crate) const OFF_RET_KIND: i32 = 0x28;
pub(crate) const OFF_HELPER: i32 = 0x30;
pub(crate) const OFF_CTX: i32 = 0x38;

/// The slot arena's fixed capacity (in slots). Allocated once per
/// `KStack` on first native entry and never grown — growth would move
/// the block under live parent-frame pointers.
const JSLOTS_CAP: usize = 1 << 16;

/// Per-activation environment handed to compiled code in `r13`.
#[repr(C)]
pub(crate) struct JitEnv {
    /// This activation's slot arena (`&stack.jslots[jbase]`).
    pub jslots: *mut i64,
    /// Step budget consumed (branches/jumps), mirrors `Ctx::steps`.
    pub steps: u64,
    /// `KStack::limit`.
    pub limit: u64,
    /// Set on status 1: the pc the interpreter resumes at.
    pub bail_pc: u64,
    /// Set on status 0 with `ret_kind == 1`: `as_i64` image of the
    /// return operand.
    pub ret_bits: u64,
    /// 0 = `Unit` return (`Halt` / bare `Return` — preset by the
    /// runtime), 1 = `ret_bits` carries a value.
    pub ret_kind: u64,
    /// The monomorphized `exec_op_shim::<M>`.
    pub helper: unsafe extern "sysv64" fn(*mut JitEnv, u64) -> u64,
    /// Type-erased `*mut HelperCtx<M>`.
    pub ctx: *mut (),
}

/// The Rust-side context the helper works against. `stack`/`machine`
/// are raw because the native activation logically holds the `&mut`s
/// for its whole duration; the helper reborrows them only while native
/// code is parked in the out-call.
struct HelperCtx<'a, M: Machine> {
    prog: &'a KernelProgram,
    kernel: &'a FuncKernel,
    tags: &'a [Tag],
    base: usize,
    jbase: usize,
    stack: *mut KStack,
    machine: *mut M,
    error: Option<anyhow::Error>,
    panic: Option<Box<dyn Any + Send>>,
}

/// Slot accessors for one native frame: arena bits for `Int`/`Bool`
/// slots, authoritative `KStack::slots` values for the rest.
#[derive(Clone, Copy)]
struct Fr<'h> {
    tags: &'h [Tag],
    base: usize,
    jbase: usize,
}

impl Fr<'_> {
    /// Materialize the true `Value` of a slot.
    fn get(&self, stack: &KStack, s: u32) -> Value {
        let s = s as usize;
        match self.tags[s] {
            Tag::Int => Value::I64(stack.jslots[self.jbase + s]),
            Tag::Bool => Value::Bool(stack.jslots[self.jbase + s] != 0),
            Tag::Unknown | Tag::Poison => stack.slots[self.base + s],
        }
    }

    fn rd(&self, stack: &KStack, o: Operand) -> Value {
        match o {
            Operand::Slot(s) => self.get(stack, s),
            Operand::Imm(v) => v,
        }
    }

    /// Write a slot the way the interpreter handler would, keeping the
    /// representation its tag promises: `Int`/`Bool` slots live in the
    /// arena as their `as_i64`/0-1 image; `Poison` slots keep
    /// `KStack::slots` authoritative (helpers may compute an `I64` into
    /// a slot that elsewhere holds `F32`). `Unknown` slots are never
    /// written (such writes bail), but fall through to the same
    /// authoritative store.
    fn wr(&self, stack: &mut KStack, s: u32, v: Value) {
        let s = s as usize;
        match self.tags[s] {
            Tag::Int => stack.jslots[self.jbase + s] = v.as_i64(),
            Tag::Bool => stack.jslots[self.jbase + s] = v.as_bool() as i64,
            Tag::Unknown | Tag::Poison => stack.slots[self.base + s] = v,
        }
    }
}

/// FFI entry of the runtime helper: decode `kernel.code[pc]`, replay it,
/// report status (0 = ok, 2 = error/panic stored in the context). Panics
/// must not unwind into native frames, so the body runs under
/// `catch_unwind` and the runtime re-raises after native code exits.
unsafe extern "sysv64" fn exec_op_shim<M: Machine>(env: *mut JitEnv, pc: u64) -> u64 {
    let ctx = (*env).ctx as *mut HelperCtx<'_, M>;
    let r = catch_unwind(AssertUnwindSafe(|| exec_op(&mut *ctx, pc as usize)));
    match r {
        Ok(Ok(())) => 0,
        Ok(Err(e)) => {
            (*ctx).error = Some(e);
            2
        }
        Err(p) => {
            (*ctx).panic = Some(p);
            2
        }
    }
}

/// Replay one instruction with interpreter semantics. Covers every op a
/// `Helper` classification can produce (and the pure ops for
/// defensiveness); control flow can never be an out-call.
fn exec_op<M: Machine>(hctx: &mut HelperCtx<'_, M>, pc: usize) -> Result<()> {
    let stack: &mut KStack = unsafe { &mut *hctx.stack };
    let machine: &mut M = unsafe { &mut *hctx.machine };
    let kernel = hctx.kernel;
    let fr = Fr { tags: hctx.tags, base: hctx.base, jbase: hctx.jbase };
    let instr = &kernel.code[pc];
    // Same charge the dispatch loop would have made (a no-op for every
    // machine that jits, but kept for faithfulness).
    if instr.cost != NO_COST {
        machine.charge(&kernel.costs[instr.cost as usize]);
    }
    match &instr.op {
        // -- pure compute (reachable when slow arithmetic or a
        // possibly-F32 flow forces the helper) --
        KOp::Mov { dst, src, ty } => {
            let mut v = fr.rd(stack, *src);
            if let Some(t) = ty {
                v = v.coerce(*t);
            }
            fr.wr(stack, *dst, v);
        }
        KOp::Bin { op, dst, lhs, rhs, ty } => {
            let (va, vb) = (fr.rd(stack, *lhs), fr.rd(stack, *rhs));
            let mut v = bin_value(*op, va, vb);
            if let Some(t) = ty {
                v = v.coerce(*t);
            }
            fr.wr(stack, *dst, v);
        }
        KOp::Un { op, dst, src, ty } => {
            let mut v = un_value(*op, fr.rd(stack, *src));
            if let Some(t) = ty {
                v = v.coerce(*t);
            }
            fr.wr(stack, *dst, v);
        }
        KOp::Builtin2 { b, dst, lhs, rhs, ty } => {
            let (va, vb) = (fr.rd(stack, *lhs), fr.rd(stack, *rhs));
            let mut v = builtin2_value(*b, va, vb);
            if let Some(t) = ty {
                v = v.coerce(*t);
            }
            fr.wr(stack, *dst, v);
        }
        KOp::Builtin1 { b, dst, src, ty } => {
            let mut v = builtin1_value(*b, fr.rd(stack, *src));
            if let Some(t) = ty {
                v = v.coerce(*t);
            }
            fr.wr(stack, *dst, v);
        }
        KOp::IntToFloat { dst, src, ty } => {
            let mut v = Value::F32(fr.rd(stack, *src).as_f32());
            if let Some(t) = ty {
                v = v.coerce(*t);
            }
            fr.wr(stack, *dst, v);
        }

        // -- machine effects --
        KOp::Load { dst, arr, index } => {
            let idx = fr.rd(stack, *index).as_i64();
            let v = machine.load(*arr, idx)?;
            fr.wr(stack, *dst, v);
        }
        KOp::Store { arr, index, value } => {
            let idx = fr.rd(stack, *index).as_i64();
            let v = fr.rd(stack, *value);
            machine.store(*arr, idx, v)?;
        }
        KOp::AtomicAdd { arr, index, value } => {
            let idx = fr.rd(stack, *index).as_i64();
            let v = fr.rd(stack, *value);
            machine.atomic_add(*arr, idx, v)?;
        }
        KOp::Call { dst, callee, args_at, nargs } => {
            jit_seq_call(hctx.prog, fr, stack, machine, *callee, *args_at, *nargs, *dst)?;
        }
        KOp::SpawnSeq { dst, callee, args_at, nargs } => {
            machine.on_spawn_seq();
            jit_seq_call(hctx.prog, fr, stack, machine, *callee, *args_at, *nargs, *dst)?;
        }
        KOp::MakeClosure { dst, task } => {
            let handle = machine.make_closure(*task)?;
            fr.wr(stack, *dst, handle);
        }
        KOp::ClosureStore { clos, field, value } => {
            let h = fr.get(stack, *clos);
            let v = fr.rd(stack, *value);
            machine.closure_store(h, *field, v)?;
        }
        KOp::SpawnChild { callee, args_at, nargs, ret } => {
            let kont = match ret {
                KRet::Slot { clos, field } => {
                    KontRef::Slot { clos: fr.get(stack, *clos), field: *field }
                }
                KRet::Counter { clos } => KontRef::Counter { clos: fr.get(stack, *clos) },
                KRet::Forward => KontRef::Forward,
            };
            with_args(fr, stack, *args_at, *nargs, |_stack, args| {
                machine.spawn_child(*callee, args, kont)
            })?;
        }
        KOp::CloseSpawns { clos } => {
            let h = fr.get(stack, *clos);
            machine.close_spawns(h)?;
        }
        KOp::SendArgument { value } => {
            let v = match value {
                Some(o) => fr.rd(stack, *o).coerce(kernel.ret),
                None => Value::Unit,
            };
            machine.send_argument(v)?;
        }

        // -- fused superinstructions: replay the components in handler
        // order, including every frame write --
        KOp::LoadMov { ldst, arr, index, dst, ty } => {
            let idx = fr.rd(stack, *index).as_i64();
            let v = machine.load(*arr, idx)?;
            fr.wr(stack, *ldst, v);
            let mut mv = v;
            if let Some(t) = ty {
                mv = mv.coerce(*t);
            }
            fr.wr(stack, *dst, mv);
        }
        KOp::StoreBin { op, bdst, lhs, rhs, bty, arr, index } => {
            let (va, vb) = (fr.rd(stack, *lhs), fr.rd(stack, *rhs));
            let mut v = bin_value(*op, va, vb);
            if let Some(t) = bty {
                v = v.coerce(*t);
            }
            fr.wr(stack, *bdst, v);
            // Index after the value write, like the unfused sequence.
            let idx = fr.rd(stack, *index).as_i64();
            let val = fr.get(stack, *bdst);
            machine.store(*arr, idx, val)?;
        }
        KOp::LoadBinStore { ldst, arr, index, cost2, op, bdst, lhs, rhs, bty, sarr, sindex } => {
            let idx = fr.rd(stack, *index).as_i64();
            let v = machine.load(*arr, idx)?;
            fr.wr(stack, *ldst, v);
            // The bin+store charge lands after the load (a `Seg::Load`
            // trace element interposes, so it can't merge up front).
            if *cost2 != NO_COST {
                machine.charge(&kernel.costs[*cost2 as usize]);
            }
            let (va, vb) = (fr.rd(stack, *lhs), fr.rd(stack, *rhs));
            let mut bv = bin_value(*op, va, vb);
            if let Some(t) = bty {
                bv = bv.coerce(*t);
            }
            fr.wr(stack, *bdst, bv);
            let sidx = fr.rd(stack, *sindex).as_i64();
            let val = fr.get(stack, *bdst);
            machine.store(*sarr, sidx, val)?;
        }
        KOp::BinAtomicAdd { op, bdst, lhs, rhs, bty, arr, index } => {
            let (va, vb) = (fr.rd(stack, *lhs), fr.rd(stack, *rhs));
            let mut v = bin_value(*op, va, vb);
            if let Some(t) = bty {
                v = v.coerce(*t);
            }
            fr.wr(stack, *bdst, v);
            let idx = fr.rd(stack, *index).as_i64();
            let val = fr.get(stack, *bdst);
            machine.atomic_add(*arr, idx, val)?;
        }
        KOp::SendBin { op, bdst, lhs, rhs, bty } => {
            let (va, vb) = (fr.rd(stack, *lhs), fr.rd(stack, *rhs));
            let mut v = bin_value(*op, va, vb);
            if let Some(t) = bty {
                v = v.coerce(*t);
            }
            fr.wr(stack, *bdst, v);
            machine.send_argument(fr.get(stack, *bdst).coerce(kernel.ret))?;
        }

        KOp::Jump { .. }
        | KOp::Branch { .. }
        | KOp::Return { .. }
        | KOp::Halt
        | KOp::CmpBranch { .. }
        | KOp::ReturnBin { .. } => {
            bail!("jit: control-flow op reached the runtime helper (classification bug)")
        }
    }
    Ok(())
}

/// Materialize `nargs` staged argument slots into a buffer (stack for
/// the common small arities) and run `f` on the slice.
fn with_args<R>(
    fr: Fr<'_>,
    stack: &mut KStack,
    args_at: u32,
    nargs: u32,
    f: impl FnOnce(&mut KStack, &[Value]) -> R,
) -> R {
    let n = nargs as usize;
    let mut buf = [Value::Unit; 8];
    if n <= buf.len() {
        for (i, b) in buf[..n].iter_mut().enumerate() {
            *b = fr.get(stack, args_at + i as u32);
        }
        f(stack, &buf[..n])
    } else {
        let heap: Vec<Value> = (0..n).map(|i| fr.get(stack, args_at + i as u32)).collect();
        f(stack, &heap)
    }
}

/// `Call`/`SpawnSeq` replay: xla-or-nested-kernel execution plus the
/// optional coerced dst write ([`seq_call`]'s exact semantics, with the
/// staged arguments materialized out of the native frame).
///
/// [`seq_call`]: crate::exec::kernel
#[allow(clippy::too_many_arguments)]
fn jit_seq_call<M: Machine>(
    prog: &KernelProgram,
    fr: Fr<'_>,
    stack: &mut KStack,
    machine: &mut M,
    callee: FuncId,
    args_at: u32,
    nargs: u32,
    dst: Option<(u32, Type)>,
) -> Result<()> {
    let v = with_args(fr, stack, args_at, nargs, |stack, args| {
        if prog.kernel(callee).kind == FuncKind::Xla {
            machine.xla_call(callee, args)
        } else {
            jit_call_nested(prog, callee, args, stack, machine)
        }
    })?;
    if let Some((d, t)) = dst {
        fr.wr(stack, d, v.coerce(t));
    }
    Ok(())
}

/// `call_nested` with by-value arguments: push the callee frame, run it
/// through the tiered `exec_frame` (the callee gets its own promotion
/// decision), pop. Error strings match `call_nested` exactly.
fn jit_call_nested<M: Machine>(
    prog: &KernelProgram,
    callee: FuncId,
    args: &[Value],
    stack: &mut KStack,
    machine: &mut M,
) -> Result<Value> {
    let kernel = prog.kernel(callee);
    if args.len() != kernel.params {
        bail!("`{}` expects {} args, got {}", kernel.name, kernel.params, args.len());
    }
    stack.depth += 1;
    if stack.depth > MAX_DEPTH {
        bail!("kernel recursion limit exceeded in `{}`", kernel.name);
    }
    let base = stack.slots.len();
    stack.slots.extend_from_slice(&kernel.frame);
    for (i, a) in args.iter().enumerate() {
        stack.slots[base + i] = a.coerce(kernel.param_tys[i]);
    }
    let r = exec_frame(prog, callee, base, stack, machine);
    stack.slots.truncate(base);
    stack.depth -= 1;
    r
}

/// Tiered entry for one frame activation. `Ok(None)` = stay in the
/// interpreter (cold, uncompilable, arena exhausted, or jit disabled);
/// `Ok(Some(..))` = native code ran to a return or a bail.
///
/// Called from `exec_frame` *after* `Machine::on_dispatch`, so every
/// engine's dispatch accounting (and the obs hotness profile) sees
/// jitted frames exactly like interpreted ones.
pub(crate) fn try_enter<M: Machine>(
    tier: &JitTier,
    prog: &KernelProgram,
    fid: FuncId,
    base: usize,
    stack: &mut KStack,
    machine: &mut M,
) -> Result<Option<Outcome>> {
    debug_assert!(
        std::ptr::eq(&*tier.program.kernels, prog),
        "jit tier bound to a different kernel program"
    );
    let fi = fid.index();
    // Hotness: the first `threshold` dispatches stay interpreted.
    if tier.hot[fi].fetch_add(1, std::sync::atomic::Ordering::Relaxed) < tier.threshold {
        return Ok(None);
    }
    let Some(ck) = tier.program.compiled(fi) else { return Ok(None) };
    let kernel = prog.kernel(fid);
    let nslots = kernel.frame.len();

    // Carve this activation's arena slice.
    if stack.jslots.is_empty() {
        stack.jslots = vec![0; JSLOTS_CAP];
    }
    let jbase = stack.jtop;
    if jbase + nslots > JSLOTS_CAP {
        return Ok(None);
    }
    stack.jtop = jbase + nslots;

    // Entry marshal: `as_i64` image of every non-`Poison` slot (the
    // entry value of an `Unknown` slot is always `Unit` ⇒ 0). `Poison`
    // slots stay authoritative in `stack.slots`.
    for i in 0..nslots {
        stack.jslots[jbase + i] = match ck.tags[i] {
            Tag::Poison => 0,
            _ => stack.slots[base + i].as_i64(),
        };
    }

    let mut hctx = HelperCtx::<M> {
        prog,
        kernel,
        tags: &ck.tags,
        base,
        jbase,
        stack: stack as *mut KStack,
        machine: machine as *mut M,
        error: None,
        panic: None,
    };
    let mut env = JitEnv {
        jslots: unsafe { stack.jslots.as_mut_ptr().add(jbase) },
        steps: 0,
        limit: stack.limit,
        bail_pc: 0,
        ret_bits: 0,
        ret_kind: 0,
        helper: exec_op_shim::<M>,
        ctx: &mut hctx as *mut HelperCtx<'_, M> as *mut (),
    };

    tier.program.funcs[fi].entries.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let entry: unsafe extern "sysv64" fn(*mut JitEnv) -> u64 =
        unsafe { std::mem::transmute(ck.buf.entry()) };
    let status = unsafe { entry(&mut env) };
    stack.jtop = jbase;

    if let Some(p) = hctx.panic.take() {
        resume_unwind(p);
    }
    match status {
        0 => {
            let v = if env.ret_kind == 0 {
                Value::Unit
            } else {
                Value::I64(env.ret_bits as i64).coerce(kernel.ret)
            };
            Ok(Some(Outcome::Done(v)))
        }
        1 => {
            tier.program.funcs[fi].bails.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            // Hand the frame image back: `Int`/`Bool` slots materialize
            // from the arena; `Unknown`/`Poison` were never written
            // natively, so `stack.slots` is already current.
            for i in 0..nslots {
                match ck.tags[i] {
                    Tag::Int => stack.slots[base + i] = Value::I64(stack.jslots[jbase + i]),
                    Tag::Bool => {
                        stack.slots[base + i] = Value::Bool(stack.jslots[jbase + i] != 0)
                    }
                    Tag::Unknown | Tag::Poison => {}
                }
            }
            Ok(Some(Outcome::Bail { pc: env.bail_pc as usize, steps: env.steps }))
        }
        2 => Err(hctx
            .error
            .take()
            .unwrap_or_else(|| anyhow!("jit: helper reported an error without recording one"))),
        s => Err(anyhow!("jit: compiled code returned unknown status {s}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jit_env_layout_is_the_codegen_contract() {
        use std::mem::offset_of;
        assert_eq!(offset_of!(JitEnv, jslots), OFF_JSLOTS as usize);
        assert_eq!(offset_of!(JitEnv, steps), OFF_STEPS as usize);
        assert_eq!(offset_of!(JitEnv, limit), OFF_LIMIT as usize);
        assert_eq!(offset_of!(JitEnv, bail_pc), OFF_BAIL_PC as usize);
        assert_eq!(offset_of!(JitEnv, ret_bits), OFF_RET_BITS as usize);
        assert_eq!(offset_of!(JitEnv, ret_kind), OFF_RET_KIND as usize);
        assert_eq!(offset_of!(JitEnv, helper), OFF_HELPER as usize);
        assert_eq!(offset_of!(JitEnv, ctx), OFF_CTX as usize);
        assert_eq!(std::mem::size_of::<JitEnv>(), 0x40);
    }
}
