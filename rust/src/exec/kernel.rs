//! Register-based linear bytecode and the shared interpreter loop.
//!
//! A [`KernelProgram`] is the compiled form of one IR module: per function
//! a flat instruction array over a frame of value slots (parameters,
//! locals, then expression temporaries). The interpreter
//! ([`run_kernel`]) is generic over a [`Machine`] that realizes side
//! effects — memory, closures, spawns, sends — and meters whatever the
//! engine cares about (the simulator charges [`KCost`] cycles through
//! [`Machine::charge`]; the software engines leave it a no-op that
//! monomorphizes away).
//!
//! Semantics are bit-for-bit those of the old tree-walking executors:
//! the arithmetic helpers ([`bin_value`] & co.) replicate
//! `ir::expr::eval`'s dynamic float-promotion rules, writes to named
//! variables coerce to the variable's declared type exactly where the
//! tree walkers did, and the compiler ([`super::compile`]) preserves
//! left-to-right evaluation order.
//!
//! # Dispatch
//!
//! The interpreter is *direct-threaded*: every instruction carries a
//! pre-resolved handler index ([`KInstr::h`], assigned at kernel-compile
//! time from [`opcode_of`]), and the loop jumps through a per-[`Machine`]
//! monomorphized table of `fn(&mut Ctx<M>, &KOp) -> Result<Step>`
//! handlers instead of matching on the opcode per retired instruction.
//! Hot adjacent pairs are additionally collapsed into fused
//! superinstructions (`CmpBranch`, `LoadMov`, `BinMov`, `StoreBin`,
//! `ReturnBin`) by the peephole stage in [`super::compile`], halving the
//! dispatch count on comparison-driven control flow; each fused handler
//! replays both component ops verbatim (including every frame-slot
//! write), so fusion is observationally invisible — the
//! `BOMBYX_KERNEL_FUSE=0` escape hatch exists for bisection, not
//! correctness.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::frontend::ast::{BinOp, Type, UnOp};
use crate::hls::ScheduleModel;
use crate::ir::cfg::{FuncId, FuncKind, GlobalId};
use crate::ir::expr::{Builtin, Value};

/// Sentinel for "this instruction carries no cycle-cost metadata".
pub const NO_COST: u32 = u32::MAX;

/// Which IR a program was compiled from. Implicit kernels keep
/// `cilk_spawn` as a sequential call ([`KOp::SpawnSeq`], the serial
/// elision the oracle runs); explicit kernels carry the Cilk-1 ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    Implicit,
    Explicit,
}

/// An instruction operand: a frame slot or a folded immediate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Operand {
    Slot(u32),
    Imm(Value),
}

/// Where a spawned child delivers its result (pre-resolved
/// [`crate::ir::cfg::RetTarget`]; `clos` fields are frame slots holding
/// closure handles).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KRet {
    Slot { clos: u32, field: u32 },
    Counter { clos: u32 },
    Forward,
}

/// A resolved continuation target handed to [`Machine::spawn_child`]:
/// closure-handle *values* read out of the frame.
#[derive(Clone, Copy, Debug)]
pub enum KontRef {
    Slot { clos: Value, field: u32 },
    Counter { clos: Value },
    Forward,
}

/// One bytecode instruction: the operation, an optional index into the
/// kernel's [`KCost`] table (attached to the anchor instruction of each
/// source IR op; [`NO_COST`] on expression-temporary instructions, whose
/// cycles are folded into their anchor's cost — exactly how the HLS
/// model charged whole ops), and the pre-resolved dispatch-handler index
/// (`h`, always `opcode_of(&op)` — enforced by the validator).
#[derive(Clone, Debug)]
pub struct KInstr {
    pub op: KOp,
    pub cost: u32,
    /// Direct-threaded dispatch index into the per-machine handler table.
    pub h: u8,
}

impl KInstr {
    #[inline]
    pub fn new(op: KOp, cost: u32) -> KInstr {
        let h = opcode_of(&op);
        KInstr { op, cost, h }
    }
}

#[derive(Clone, Debug)]
pub enum KOp {
    /// `dst = src` (with optional coercion to a declared variable type).
    Mov { dst: u32, src: Operand, ty: Option<Type> },
    Bin { op: BinOp, dst: u32, lhs: Operand, rhs: Operand, ty: Option<Type> },
    Un { op: UnOp, dst: u32, src: Operand, ty: Option<Type> },
    /// Two-argument builtin (min/max) — arity fixed at compile time.
    Builtin2 { b: Builtin, dst: u32, lhs: Operand, rhs: Operand, ty: Option<Type> },
    /// One-argument builtin (abs).
    Builtin1 { b: Builtin, dst: u32, src: Operand, ty: Option<Type> },
    IntToFloat { dst: u32, src: Operand, ty: Option<Type> },
    Load { dst: u32, arr: GlobalId, index: Operand },
    Store { arr: GlobalId, index: Operand, value: Operand },
    AtomicAdd { arr: GlobalId, index: Operand, value: Operand },
    /// Sequential call; args staged in `nargs` consecutive frame slots
    /// starting at `args_at`. `dst` carries the destination slot and its
    /// coercion type.
    Call { dst: Option<(u32, Type)>, callee: FuncId, args_at: u32, nargs: u32 },
    /// `cilk_spawn` under serial elision (implicit kernels only).
    SpawnSeq { dst: Option<(u32, Type)>, callee: FuncId, args_at: u32, nargs: u32 },
    MakeClosure { dst: u32, task: FuncId },
    ClosureStore { clos: u32, field: u32, value: Operand },
    SpawnChild { callee: FuncId, args_at: u32, nargs: u32, ret: KRet },
    CloseSpawns { clos: u32 },
    SendArgument { value: Option<Operand> },
    Jump { target: u32 },
    Branch { cond: Operand, then_: u32, else_: u32 },
    Return { value: Option<Operand> },
    Halt,

    // -- fused superinstructions (peephole stage in `super::compile`) --
    // Each replays its component ops verbatim, including every frame
    // write, so fusion never changes observable behavior; only the
    // dispatch count shrinks. Costs are merged at fusion time under
    // rules that keep the simulator's timed traces byte-identical.
    /// `Bin{cmp} ; Branch` on the just-written slot.
    CmpBranch {
        op: BinOp,
        dst: u32,
        lhs: Operand,
        rhs: Operand,
        ty: Option<Type>,
        then_: u32,
        else_: u32,
    },
    /// `Load ; Mov` of the just-loaded slot.
    LoadMov { ldst: u32, arr: GlobalId, index: Operand, dst: u32, ty: Option<Type> },
    /// `Bin ; Mov` of the just-written slot.
    BinMov {
        op: BinOp,
        bdst: u32,
        lhs: Operand,
        rhs: Operand,
        bty: Option<Type>,
        dst: u32,
        ty: Option<Type>,
    },
    /// `Bin ; Store` whose value is the just-written slot.
    StoreBin {
        op: BinOp,
        bdst: u32,
        lhs: Operand,
        rhs: Operand,
        bty: Option<Type>,
        arr: GlobalId,
        index: Operand,
    },
    /// `Bin ; Return` of the just-written slot.
    ReturnBin { op: BinOp, bdst: u32, lhs: Operand, rhs: Operand, bty: Option<Type> },
    /// `Load ; Bin ; Store` — the 3-op read-modify-write chain. The
    /// anchor cost is the load's; `cost2` carries the merged bin+store
    /// charge, applied *after* the load (a `Seg::Load` trace element
    /// interposes, so the charges can't merge up front).
    LoadBinStore {
        ldst: u32,
        arr: GlobalId,
        index: Operand,
        cost2: u32,
        op: BinOp,
        bdst: u32,
        lhs: Operand,
        rhs: Operand,
        bty: Option<Type>,
        sarr: GlobalId,
        sindex: Operand,
    },
    /// `Bin ; AtomicAdd` whose added value is the just-written slot.
    BinAtomicAdd {
        op: BinOp,
        bdst: u32,
        lhs: Operand,
        rhs: Operand,
        bty: Option<Type>,
        arr: GlobalId,
        index: Operand,
    },
    /// `Bin ; SendArgument` of the just-written slot.
    SendBin { op: BinOp, bdst: u32, lhs: Operand, rhs: Operand, bty: Option<Type> },
}

/// Dispatch-handler indices, one per [`KOp`] variant. The handler table
/// ([`run_kernel`]'s direct-threaded loop) is indexed by these, so their
/// order must match `HANDLERS` exactly.
pub mod opcode {
    pub const MOV: u8 = 0;
    pub const BIN: u8 = 1;
    pub const UN: u8 = 2;
    pub const BUILTIN2: u8 = 3;
    pub const BUILTIN1: u8 = 4;
    pub const INT_TO_FLOAT: u8 = 5;
    pub const LOAD: u8 = 6;
    pub const STORE: u8 = 7;
    pub const ATOMIC_ADD: u8 = 8;
    pub const CALL: u8 = 9;
    pub const SPAWN_SEQ: u8 = 10;
    pub const MAKE_CLOSURE: u8 = 11;
    pub const CLOSURE_STORE: u8 = 12;
    pub const SPAWN_CHILD: u8 = 13;
    pub const CLOSE_SPAWNS: u8 = 14;
    pub const SEND_ARGUMENT: u8 = 15;
    pub const JUMP: u8 = 16;
    pub const BRANCH: u8 = 17;
    pub const RETURN: u8 = 18;
    pub const HALT: u8 = 19;
    pub const CMP_BRANCH: u8 = 20;
    pub const LOAD_MOV: u8 = 21;
    pub const BIN_MOV: u8 = 22;
    pub const STORE_BIN: u8 = 23;
    pub const RETURN_BIN: u8 = 24;
    pub const LOAD_BIN_STORE: u8 = 25;
    pub const BIN_ATOMIC_ADD: u8 = 26;
    pub const SEND_BIN: u8 = 27;
    /// Number of opcodes (handler-table length).
    pub const N: usize = 28;
}

/// The dispatch-handler index of an op — resolved once at kernel-compile
/// time ([`KInstr::new`]), never on the hot path.
pub fn opcode_of(op: &KOp) -> u8 {
    match op {
        KOp::Mov { .. } => opcode::MOV,
        KOp::Bin { .. } => opcode::BIN,
        KOp::Un { .. } => opcode::UN,
        KOp::Builtin2 { .. } => opcode::BUILTIN2,
        KOp::Builtin1 { .. } => opcode::BUILTIN1,
        KOp::IntToFloat { .. } => opcode::INT_TO_FLOAT,
        KOp::Load { .. } => opcode::LOAD,
        KOp::Store { .. } => opcode::STORE,
        KOp::AtomicAdd { .. } => opcode::ATOMIC_ADD,
        KOp::Call { .. } => opcode::CALL,
        KOp::SpawnSeq { .. } => opcode::SPAWN_SEQ,
        KOp::MakeClosure { .. } => opcode::MAKE_CLOSURE,
        KOp::ClosureStore { .. } => opcode::CLOSURE_STORE,
        KOp::SpawnChild { .. } => opcode::SPAWN_CHILD,
        KOp::CloseSpawns { .. } => opcode::CLOSE_SPAWNS,
        KOp::SendArgument { .. } => opcode::SEND_ARGUMENT,
        KOp::Jump { .. } => opcode::JUMP,
        KOp::Branch { .. } => opcode::BRANCH,
        KOp::Return { .. } => opcode::RETURN,
        KOp::Halt => opcode::HALT,
        KOp::CmpBranch { .. } => opcode::CMP_BRANCH,
        KOp::LoadMov { .. } => opcode::LOAD_MOV,
        KOp::BinMov { .. } => opcode::BIN_MOV,
        KOp::StoreBin { .. } => opcode::STORE_BIN,
        KOp::ReturnBin { .. } => opcode::RETURN_BIN,
        KOp::LoadBinStore { .. } => opcode::LOAD_BIN_STORE,
        KOp::BinAtomicAdd { .. } => opcode::BIN_ATOMIC_ADD,
        KOp::SendBin { .. } => opcode::SEND_BIN,
    }
}

/// Is `op` one of the comparison operators eligible for `CmpBranch`
/// fusion (and required by the validator on fused compare-branches)?
pub fn is_cmp_op(op: BinOp) -> bool {
    matches!(op, BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge | BinOp::Eq | BinOp::Ne)
}

/// Cycle-cost metadata for one source IR op, resolved against a
/// [`ScheduleModel`] at simulation time. Mirrors `hls::op_cycles`: a
/// base latency plus one independently-rounded datapath figure per
/// operand expression (operator counts measured on the *original* tree,
/// so constant folding never changes simulated timing).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KCost {
    pub base: KBase,
    /// Operator counts of the op's operand expressions, each charged
    /// `ceil(n / ops_per_cycle)` like `hls::expr_cycles`.
    pub exprs: Vec<u32>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KBase {
    Zero,
    LoadIssue,
    StoreIssue,
    StreamWrite,
    SpawnNextRtt,
    Branch,
}

impl KCost {
    pub fn cycles(&self, model: &ScheduleModel) -> u32 {
        let base = match self.base {
            KBase::Zero => 0,
            KBase::LoadIssue => model.load_issue,
            KBase::StoreIssue => model.store_issue,
            KBase::StreamWrite => model.stream_write,
            KBase::SpawnNextRtt => model.spawn_next_rtt,
            KBase::Branch => model.branch,
        };
        base + self
            .exprs
            .iter()
            .map(|&n| n.div_ceil(model.ops_per_cycle))
            .sum::<u32>()
    }
}

/// One function's compiled kernel.
#[derive(Clone, Debug)]
pub struct FuncKernel {
    pub name: String,
    pub kind: FuncKind,
    /// Task role name (`entry`/`continuation`/`join`/`access`/`xla`) or
    /// `"leaf"` for spawned leaf functions — the per-role stats key.
    pub role: &'static str,
    pub params: usize,
    /// Parameter types, shared (`Arc`) into every closure created for
    /// this task so closure allocation never clones a type vector.
    pub param_tys: Arc<[Type]>,
    pub ret: Type,
    /// Zero-initialized frame prototype: one `zero_of(ty)` per declared
    /// variable, then `Unit` for expression temporaries.
    pub frame: Vec<Value>,
    /// Empty for `extern xla` declarations (no body).
    pub code: Vec<KInstr>,
    pub costs: Vec<KCost>,
    /// Instructions eliminated by the fusion stage — 1 per fused pair,
    /// 2 per fused triple (0 when fusion is disabled).
    pub fused: u32,
    /// Instruction count before fusion (== `code.len()` when nothing
    /// fused).
    pub unfused_len: u32,
}

/// A compiled module: kernels indexed by [`FuncId`].
#[derive(Clone, Debug)]
pub struct KernelProgram {
    pub mode: KernelMode,
    pub funcs: Vec<FuncKernel>,
    /// Element type of each global array, indexed by
    /// [`GlobalId`]. The JIT's slot-tag analysis types `Load` results
    /// with this.
    pub global_tys: Vec<Type>,
}

impl KernelProgram {
    #[inline]
    pub fn kernel(&self, fid: FuncId) -> &FuncKernel {
        &self.funcs[fid.index()]
    }

    pub fn func_by_name(&self, name: &str) -> Option<FuncId> {
        self.funcs
            .iter()
            .position(|k| k.name == name)
            .map(FuncId::new)
    }

    pub fn instr_count(&self) -> usize {
        self.funcs.iter().map(|k| k.code.len()).sum()
    }

    /// Aggregate fusion stats: `(instructions eliminated, instructions
    /// before fusion)`.
    pub fn fusion(&self) -> (u64, u64) {
        let pairs = self.funcs.iter().map(|k| k.fused as u64).sum();
        let before = self.funcs.iter().map(|k| k.unfused_len as u64).sum();
        (pairs, before)
    }

    /// Fraction of pre-fusion instructions covered by fusion
    /// (`2 * eliminated / pre-fusion count`; 0.0 when fusion is off).
    /// With pairs only this is exact coverage; a fused triple covers 3
    /// pre-fusion instructions but counts as 4 here, so the figure is
    /// slightly optimistic on triple-heavy code.
    pub fn fused_ratio(&self) -> f64 {
        let (pairs, before) = self.fusion();
        if before == 0 {
            0.0
        } else {
            2.0 * pairs as f64 / before as f64
        }
    }

    /// Fusion stats broken down by task role: `(role, instructions
    /// eliminated, instructions before fusion)` in first-appearance order. Shapes
    /// that resist fusion (e.g. `join` continuations full of closure
    /// traffic) show up as low per-role ratios that the global
    /// [`KernelProgram::fused_ratio`] averages away.
    pub fn fusion_by_role(&self) -> Vec<(&'static str, u64, u64)> {
        let mut rows: Vec<(&'static str, u64, u64)> = Vec::new();
        for k in &self.funcs {
            match rows.iter_mut().find(|(role, _, _)| *role == k.role) {
                Some((_, pairs, before)) => {
                    *pairs += k.fused as u64;
                    *before += k.unfused_len as u64;
                }
                None => rows.push((k.role, k.fused as u64, k.unfused_len as u64)),
            }
        }
        rows
    }

    /// Structural validation — the post-pass lint of the `kernel_compile`
    /// pass. Returns the list of violations (empty = OK).
    pub fn validate(&self) -> Vec<String> {
        let mut errors = Vec::new();
        for (i, k) in self.funcs.iter().enumerate() {
            let ctx = |msg: String| format!("kernel `{}` (#{i}): {msg}", k.name);
            if k.kind == FuncKind::Xla {
                if !k.code.is_empty() {
                    errors.push(ctx("xla kernel must have no code".into()));
                }
                continue;
            }
            if k.code.is_empty() {
                errors.push(ctx("empty code".into()));
                continue;
            }
            if !matches!(
                k.code[k.code.len() - 1].op,
                KOp::Jump { .. } | KOp::Branch { .. } | KOp::Return { .. } | KOp::Halt
            ) {
                errors.push(ctx("code does not end with a block terminator".into()));
            }
            if k.params > k.frame.len() {
                errors.push(ctx("more params than frame slots".into()));
            }
            let nslots = k.frame.len() as u32;
            let ncode = k.code.len() as u32;
            let nfuncs = self.funcs.len();
            let slot_ok = |s: u32| s < nslots;
            let opnd_ok = |o: &Operand| match o {
                Operand::Slot(s) => *s < nslots,
                Operand::Imm(_) => true,
            };
            for (pc, instr) in k.code.iter().enumerate() {
                if instr.cost != NO_COST && instr.cost as usize >= k.costs.len() {
                    errors.push(ctx(format!("pc {pc}: cost index out of range")));
                }
                if instr.h != opcode_of(&instr.op) {
                    errors.push(ctx(format!(
                        "pc {pc}: handler index {} does not match opcode {} of {:?}",
                        instr.h,
                        opcode_of(&instr.op),
                        instr.op
                    )));
                }
                let mut bad = false;
                match &instr.op {
                    KOp::Mov { dst, src, .. }
                    | KOp::Un { dst, src, .. }
                    | KOp::Builtin1 { dst, src, .. }
                    | KOp::IntToFloat { dst, src, .. } => {
                        bad = !slot_ok(*dst) || !opnd_ok(src);
                    }
                    KOp::Bin { dst, lhs, rhs, .. } | KOp::Builtin2 { dst, lhs, rhs, .. } => {
                        bad = !slot_ok(*dst) || !opnd_ok(lhs) || !opnd_ok(rhs);
                    }
                    KOp::Load { dst, index, .. } => bad = !slot_ok(*dst) || !opnd_ok(index),
                    KOp::Store { index, value, .. } | KOp::AtomicAdd { index, value, .. } => {
                        bad = !opnd_ok(index) || !opnd_ok(value);
                    }
                    KOp::Call { dst, callee, args_at, nargs }
                    | KOp::SpawnSeq { dst, callee, args_at, nargs } => {
                        bad = args_at + nargs > nslots
                            || callee.index() >= nfuncs
                            || dst.map(|(d, _)| !slot_ok(d)).unwrap_or(false);
                        if matches!(instr.op, KOp::SpawnSeq { .. })
                            && self.mode == KernelMode::Explicit
                        {
                            errors.push(ctx(format!("pc {pc}: SpawnSeq in explicit kernel")));
                        }
                    }
                    KOp::MakeClosure { dst, task } => {
                        bad = !slot_ok(*dst) || task.index() >= nfuncs;
                    }
                    KOp::ClosureStore { clos, value, .. } => {
                        bad = !slot_ok(*clos) || !opnd_ok(value);
                    }
                    KOp::SpawnChild { callee, args_at, nargs, ret } => {
                        bad = args_at + nargs > nslots || callee.index() >= nfuncs;
                        match ret {
                            KRet::Slot { clos, .. } | KRet::Counter { clos } => {
                                bad = bad || !slot_ok(*clos);
                            }
                            KRet::Forward => {}
                        }
                    }
                    KOp::CloseSpawns { clos } => bad = !slot_ok(*clos),
                    KOp::SendArgument { value } => {
                        bad = value.as_ref().map(|v| !opnd_ok(v)).unwrap_or(false);
                    }
                    KOp::Jump { target } => bad = *target >= ncode,
                    KOp::Branch { cond, then_, else_ } => {
                        bad = !opnd_ok(cond) || *then_ >= ncode || *else_ >= ncode;
                    }
                    KOp::Return { value } => {
                        bad = value.as_ref().map(|v| !opnd_ok(v)).unwrap_or(false);
                    }
                    KOp::Halt => {
                        if self.mode == KernelMode::Implicit {
                            errors.push(ctx(format!("pc {pc}: Halt in implicit kernel")));
                        }
                    }
                    KOp::CmpBranch { op, dst, lhs, rhs, then_, else_, .. } => {
                        bad = !slot_ok(*dst)
                            || !opnd_ok(lhs)
                            || !opnd_ok(rhs)
                            || *then_ >= ncode
                            || *else_ >= ncode;
                        if !is_cmp_op(*op) {
                            errors.push(ctx(format!(
                                "pc {pc}: CmpBranch fused over non-comparison {op:?}"
                            )));
                        }
                    }
                    KOp::LoadMov { ldst, index, dst, .. } => {
                        bad = !slot_ok(*ldst) || !slot_ok(*dst) || !opnd_ok(index);
                    }
                    KOp::BinMov { bdst, lhs, rhs, dst, .. } => {
                        bad = !slot_ok(*bdst) || !slot_ok(*dst) || !opnd_ok(lhs) || !opnd_ok(rhs);
                    }
                    KOp::StoreBin { bdst, lhs, rhs, index, .. } => {
                        bad = !slot_ok(*bdst) || !opnd_ok(lhs) || !opnd_ok(rhs) || !opnd_ok(index);
                    }
                    KOp::ReturnBin { bdst, lhs, rhs, .. } => {
                        bad = !slot_ok(*bdst) || !opnd_ok(lhs) || !opnd_ok(rhs);
                    }
                    KOp::LoadBinStore { ldst, index, cost2, bdst, lhs, rhs, sindex, .. } => {
                        bad = !slot_ok(*ldst)
                            || !slot_ok(*bdst)
                            || !opnd_ok(index)
                            || !opnd_ok(lhs)
                            || !opnd_ok(rhs)
                            || !opnd_ok(sindex);
                        if *cost2 != NO_COST && *cost2 as usize >= k.costs.len() {
                            errors.push(ctx(format!("pc {pc}: cost2 index out of range")));
                        }
                    }
                    KOp::BinAtomicAdd { bdst, lhs, rhs, index, .. } => {
                        bad = !slot_ok(*bdst) || !opnd_ok(lhs) || !opnd_ok(rhs) || !opnd_ok(index);
                    }
                    KOp::SendBin { bdst, lhs, rhs, .. } => {
                        bad = !slot_ok(*bdst) || !opnd_ok(lhs) || !opnd_ok(rhs);
                    }
                }
                if self.mode == KernelMode::Implicit
                    && matches!(
                        instr.op,
                        KOp::MakeClosure { .. }
                            | KOp::ClosureStore { .. }
                            | KOp::SpawnChild { .. }
                            | KOp::CloseSpawns { .. }
                            | KOp::SendArgument { .. }
                            | KOp::SendBin { .. }
                    )
                {
                    errors.push(ctx(format!("pc {pc}: explicit-only op in implicit kernel")));
                }
                if bad {
                    errors.push(ctx(format!("pc {pc}: operand out of range: {:?}", instr.op)));
                }
            }
        }
        errors
    }

    /// Human-readable listing (stable — used by the disassembly golden).
    pub fn disasm(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mode = match self.mode {
            KernelMode::Implicit => "implicit",
            KernelMode::Explicit => "explicit",
        };
        let _ = writeln!(out, "; kernel program ({mode} IR, {} kernels)", self.funcs.len());
        for (i, k) in self.funcs.iter().enumerate() {
            let fused = if k.fused > 0 {
                format!(", fused={} of {}", k.fused, k.unfused_len)
            } else {
                String::new()
            };
            let _ = writeln!(
                out,
                "\nkernel `{}` #{i} ({:?}, role={}, params={}, frame={}, ret={:?}{}):",
                k.name,
                k.kind,
                k.role,
                k.params,
                k.frame.len(),
                k.ret,
                fused
            );
            if k.code.is_empty() {
                let _ = writeln!(out, "  <extern>");
                continue;
            }
            for (pc, instr) in k.code.iter().enumerate() {
                let mut line = format!("  {pc:>3}: {}", fmt_op(&instr.op, self));
                if instr.cost != NO_COST {
                    let c = &k.costs[instr.cost as usize];
                    let _ = write!(line, "    ; cost {:?}{:?}", c.base, c.exprs);
                }
                let _ = writeln!(out, "{line}");
            }
        }
        out
    }
}

fn fmt_operand(o: &Operand) -> String {
    match o {
        Operand::Slot(s) => format!("r{s}"),
        Operand::Imm(v) => format!("imm({v})"),
    }
}

fn fmt_dst(dst: u32, ty: &Option<Type>) -> String {
    match ty {
        Some(t) => format!("r{dst}:{t:?}"),
        None => format!("r{dst}"),
    }
}

fn fmt_op(op: &KOp, prog: &KernelProgram) -> String {
    let fname = |f: &FuncId| prog.funcs[f.index()].name.clone();
    match op {
        KOp::Mov { dst, src, ty } => format!("{} = {}", fmt_dst(*dst, ty), fmt_operand(src)),
        KOp::Bin { op, dst, lhs, rhs, ty } => format!(
            "{} = {:?} {}, {}",
            fmt_dst(*dst, ty),
            op,
            fmt_operand(lhs),
            fmt_operand(rhs)
        ),
        KOp::Un { op, dst, src, ty } => {
            format!("{} = {:?} {}", fmt_dst(*dst, ty), op, fmt_operand(src))
        }
        KOp::Builtin2 { b, dst, lhs, rhs, ty } => format!(
            "{} = {} {}, {}",
            fmt_dst(*dst, ty),
            b.name(),
            fmt_operand(lhs),
            fmt_operand(rhs)
        ),
        KOp::Builtin1 { b, dst, src, ty } => {
            format!("{} = {} {}", fmt_dst(*dst, ty), b.name(), fmt_operand(src))
        }
        KOp::IntToFloat { dst, src, ty } => {
            format!("{} = i2f {}", fmt_dst(*dst, ty), fmt_operand(src))
        }
        KOp::Load { dst, arr, index } => {
            format!("r{dst} = load g{}[{}]", arr.index(), fmt_operand(index))
        }
        KOp::Store { arr, index, value } => format!(
            "store g{}[{}] = {}",
            arr.index(),
            fmt_operand(index),
            fmt_operand(value)
        ),
        KOp::AtomicAdd { arr, index, value } => format!(
            "atomic_add g{}[{}], {}",
            arr.index(),
            fmt_operand(index),
            fmt_operand(value)
        ),
        KOp::Call { dst, callee, args_at, nargs } => format!(
            "{}call `{}` args r{}..r{}",
            dst.map(|(d, t)| format!("r{d}:{t:?} = ")).unwrap_or_default(),
            fname(callee),
            args_at,
            args_at + nargs
        ),
        KOp::SpawnSeq { dst, callee, args_at, nargs } => format!(
            "{}spawn_seq `{}` args r{}..r{}",
            dst.map(|(d, t)| format!("r{d}:{t:?} = ")).unwrap_or_default(),
            fname(callee),
            args_at,
            args_at + nargs
        ),
        KOp::MakeClosure { dst, task } => format!("r{dst} = spawn_next `{}`", fname(task)),
        KOp::ClosureStore { clos, field, value } => {
            format!("closure r{clos}[{field}] = {}", fmt_operand(value))
        }
        KOp::SpawnChild { callee, args_at, nargs, ret } => format!(
            "spawn `{}` args r{}..r{} ret {:?}",
            fname(callee),
            args_at,
            args_at + nargs,
            ret
        ),
        KOp::CloseSpawns { clos } => format!("close_spawns r{clos}"),
        KOp::SendArgument { value } => format!(
            "send_argument {}",
            value.as_ref().map(|v| fmt_operand(v)).unwrap_or_else(|| "-".into())
        ),
        KOp::Jump { target } => format!("jump @{target}"),
        KOp::Branch { cond, then_, else_ } => {
            format!("branch {} ? @{then_} : @{else_}", fmt_operand(cond))
        }
        KOp::Return { value } => format!(
            "return {}",
            value.as_ref().map(|v| fmt_operand(v)).unwrap_or_else(|| "-".into())
        ),
        KOp::Halt => "halt".to_string(),
        KOp::CmpBranch { op, dst, lhs, rhs, ty, then_, else_ } => format!(
            "{} = {:?} {}, {} ; branch r{dst} ? @{then_} : @{else_}",
            fmt_dst(*dst, ty),
            op,
            fmt_operand(lhs),
            fmt_operand(rhs)
        ),
        KOp::LoadMov { ldst, arr, index, dst, ty } => format!(
            "r{ldst} = load g{}[{}] ; {} = r{ldst}",
            arr.index(),
            fmt_operand(index),
            fmt_dst(*dst, ty)
        ),
        KOp::BinMov { op, bdst, lhs, rhs, bty, dst, ty } => format!(
            "{} = {:?} {}, {} ; {} = r{bdst}",
            fmt_dst(*bdst, bty),
            op,
            fmt_operand(lhs),
            fmt_operand(rhs),
            fmt_dst(*dst, ty)
        ),
        KOp::StoreBin { op, bdst, lhs, rhs, bty, arr, index } => format!(
            "{} = {:?} {}, {} ; store g{}[{}] = r{bdst}",
            fmt_dst(*bdst, bty),
            op,
            fmt_operand(lhs),
            fmt_operand(rhs),
            arr.index(),
            fmt_operand(index)
        ),
        KOp::ReturnBin { op, bdst, lhs, rhs, bty } => format!(
            "{} = {:?} {}, {} ; return r{bdst}",
            fmt_dst(*bdst, bty),
            op,
            fmt_operand(lhs),
            fmt_operand(rhs)
        ),
        KOp::LoadBinStore { ldst, arr, index, op, bdst, lhs, rhs, bty, sarr, sindex, .. } => {
            format!(
                "r{ldst} = load g{}[{}] ; {} = {:?} {}, {} ; store g{}[{}] = r{bdst}",
                arr.index(),
                fmt_operand(index),
                fmt_dst(*bdst, bty),
                op,
                fmt_operand(lhs),
                fmt_operand(rhs),
                sarr.index(),
                fmt_operand(sindex)
            )
        }
        KOp::BinAtomicAdd { op, bdst, lhs, rhs, bty, arr, index } => format!(
            "{} = {:?} {}, {} ; atomic_add g{}[{}], r{bdst}",
            fmt_dst(*bdst, bty),
            op,
            fmt_operand(lhs),
            fmt_operand(rhs),
            arr.index(),
            fmt_operand(index)
        ),
        KOp::SendBin { op, bdst, lhs, rhs, bty } => format!(
            "{} = {:?} {}, {} ; send_argument r{bdst}",
            fmt_dst(*bdst, bty),
            op,
            fmt_operand(lhs),
            fmt_operand(rhs)
        ),
    }
}

// ---------------------------------------------------------------------------
// Argument lists

/// Number of argument values stored inline (no heap) in an [`ArgList`].
pub const ARG_INLINE: usize = 6;

/// A small-size-optimized argument vector: task instances with up to
/// [`ARG_INLINE`] arguments (every corpus workload) carry them inline, so
/// spawning a task allocates nothing.
#[derive(Clone, Debug)]
pub enum ArgList {
    Inline { len: u8, buf: [Value; ARG_INLINE] },
    Heap(Vec<Value>),
}

impl ArgList {
    pub fn new() -> ArgList {
        ArgList::Inline { len: 0, buf: [Value::Unit; ARG_INLINE] }
    }

    pub fn from_slice(vals: &[Value]) -> ArgList {
        if vals.len() <= ARG_INLINE {
            let mut buf = [Value::Unit; ARG_INLINE];
            buf[..vals.len()].copy_from_slice(vals);
            ArgList::Inline { len: vals.len() as u8, buf }
        } else {
            ArgList::Heap(vals.to_vec())
        }
    }

    /// Build from an element generator (used to snapshot closure slots
    /// without an intermediate `Vec`).
    pub fn from_fn(len: usize, mut f: impl FnMut(usize) -> Value) -> ArgList {
        if len <= ARG_INLINE {
            let mut buf = [Value::Unit; ARG_INLINE];
            for (i, slot) in buf.iter_mut().enumerate().take(len) {
                *slot = f(i);
            }
            ArgList::Inline { len: len as u8, buf }
        } else {
            ArgList::Heap((0..len).map(f).collect())
        }
    }

    pub fn as_slice(&self) -> &[Value] {
        match self {
            ArgList::Inline { len, buf } => &buf[..*len as usize],
            ArgList::Heap(v) => v,
        }
    }

    pub fn into_vec(self) -> Vec<Value> {
        match self {
            ArgList::Inline { len, buf } => buf[..len as usize].to_vec(),
            ArgList::Heap(v) => v,
        }
    }
}

impl Default for ArgList {
    fn default() -> ArgList {
        ArgList::new()
    }
}

impl std::ops::Deref for ArgList {
    type Target = [Value];
    fn deref(&self) -> &[Value] {
        self.as_slice()
    }
}

impl From<Vec<Value>> for ArgList {
    fn from(v: Vec<Value>) -> ArgList {
        if v.len() <= ARG_INLINE {
            ArgList::from_slice(&v)
        } else {
            ArgList::Heap(v)
        }
    }
}

impl From<&[Value]> for ArgList {
    fn from(v: &[Value]) -> ArgList {
        ArgList::from_slice(v)
    }
}

impl PartialEq for ArgList {
    fn eq(&self, other: &ArgList) -> bool {
        self.as_slice() == other.as_slice()
    }
}

// ---------------------------------------------------------------------------
// Arithmetic (bit-for-bit `ir::expr::eval` semantics)

#[inline]
pub fn un_value(op: UnOp, v: Value) -> Value {
    match op {
        UnOp::Neg => match v {
            Value::F32(f) => Value::F32(-f),
            other => Value::I64(-other.as_i64()),
        },
        UnOp::Not => Value::Bool(!v.as_bool()),
    }
}

#[inline]
pub fn builtin1_value(b: Builtin, v: Value) -> Value {
    let float = matches!(v, Value::F32(_));
    match (b, float) {
        (Builtin::Abs, false) => Value::I64(v.as_i64().abs()),
        (Builtin::Abs, true) => Value::F32(v.as_f32().abs()),
        // min/max never compile to Builtin1 (arity 2 checked by sema and
        // the kernel compiler); keep eval-compatible fallbacks anyway.
        (Builtin::Min, false) | (Builtin::Max, false) => Value::I64(v.as_i64()),
        (Builtin::Min, true) | (Builtin::Max, true) => Value::F32(v.as_f32()),
    }
}

#[inline]
pub fn builtin2_value(b: Builtin, va: Value, vb: Value) -> Value {
    let float = matches!(va, Value::F32(_)) || matches!(vb, Value::F32(_));
    match (b, float) {
        (Builtin::Min, false) => Value::I64(va.as_i64().min(vb.as_i64())),
        (Builtin::Max, false) => Value::I64(va.as_i64().max(vb.as_i64())),
        (Builtin::Abs, false) => Value::I64(va.as_i64().abs()),
        (Builtin::Min, true) => Value::F32(va.as_f32().min(vb.as_f32())),
        (Builtin::Max, true) => Value::F32(va.as_f32().max(vb.as_f32())),
        (Builtin::Abs, true) => Value::F32(va.as_f32().abs()),
    }
}

#[inline]
pub fn bin_value(op: BinOp, va: Value, vb: Value) -> Value {
    let float = matches!(va, Value::F32(_)) || matches!(vb, Value::F32(_));
    use BinOp::*;
    match op {
        Add | Sub | Mul | Div if float => {
            let (x, y) = (va.as_f32(), vb.as_f32());
            Value::F32(match op {
                Add => x + y,
                Sub => x - y,
                Mul => x * y,
                Div => x / y,
                _ => unreachable!(),
            })
        }
        Add => Value::I64(va.as_i64().wrapping_add(vb.as_i64())),
        Sub => Value::I64(va.as_i64().wrapping_sub(vb.as_i64())),
        Mul => Value::I64(va.as_i64().wrapping_mul(vb.as_i64())),
        Div => {
            let d = vb.as_i64();
            Value::I64(if d == 0 { 0 } else { va.as_i64().wrapping_div(d) })
        }
        Rem => {
            let d = vb.as_i64();
            Value::I64(if d == 0 { 0 } else { va.as_i64().wrapping_rem(d) })
        }
        Shl => Value::I64(va.as_i64().wrapping_shl(vb.as_i64() as u32 & 63)),
        Shr => Value::I64(va.as_i64().wrapping_shr(vb.as_i64() as u32 & 63)),
        BitAnd => Value::I64(va.as_i64() & vb.as_i64()),
        BitOr => Value::I64(va.as_i64() | vb.as_i64()),
        BitXor => Value::I64(va.as_i64() ^ vb.as_i64()),
        And => Value::Bool(va.as_bool() && vb.as_bool()),
        Or => Value::Bool(va.as_bool() || vb.as_bool()),
        Lt | Le | Gt | Ge | Eq | Ne => {
            let r = if float {
                let (x, y) = (va.as_f32(), vb.as_f32());
                match op {
                    Lt => x < y,
                    Le => x <= y,
                    Gt => x > y,
                    Ge => x >= y,
                    Eq => x == y,
                    Ne => x != y,
                    _ => unreachable!(),
                }
            } else {
                let (x, y) = (va.as_i64(), vb.as_i64());
                match op {
                    Lt => x < y,
                    Le => x <= y,
                    Gt => x > y,
                    Ge => x >= y,
                    Eq => x == y,
                    Ne => x != y,
                    _ => unreachable!(),
                }
            };
            Value::Bool(r)
        }
    }
}

// ---------------------------------------------------------------------------
// Machine trait + interpreter

/// Engine-specific side of kernel execution. The interpreter handles all
/// pure computation and control flow; a machine realizes memory, task
/// and closure effects, and meters what its engine cares about. Methods
/// an engine's kernels can never reach keep the bailing defaults.
pub trait Machine {
    /// Cycle metering (simulator only); default no-op.
    #[inline]
    fn charge(&mut self, _cost: &KCost) {}

    /// Invoked at every frame entry (top-level and nested calls) with
    /// the nesting depth (0 = top). The oracle uses it for call counting
    /// and recursion limiting.
    #[inline]
    fn on_dispatch(&mut self, _fid: FuncId, _depth: usize) -> Result<()> {
        Ok(())
    }

    /// Invoked before each `SpawnSeq` dispatch (oracle spawn counter).
    #[inline]
    fn on_spawn_seq(&mut self) {}

    /// The native tier this machine's frames may promote into, or
    /// `None` to stay interpreted (the default — and mandatory for the
    /// simulator, whose `KCost` timing is defined in interpreter
    /// dispatch units). Returns an owned handle so the tier can call
    /// back into `&mut self` while executing.
    #[inline]
    fn jit(&mut self) -> Option<std::sync::Arc<crate::exec::jit::JitTier>> {
        None
    }

    fn load(&mut self, arr: GlobalId, index: i64) -> Result<Value>;
    fn store(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()>;
    fn atomic_add(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()>;

    /// Sequential dispatch of an `extern xla` callee.
    fn xla_call(&mut self, _fid: FuncId, _args: &[Value]) -> Result<Value> {
        Err(anyhow!("xla call not supported by this machine"))
    }

    fn make_closure(&mut self, _task: FuncId) -> Result<Value> {
        Err(anyhow!("explicit-IR op MakeClosure reached a non-explicit machine"))
    }

    fn closure_store(&mut self, _clos: Value, _field: u32, _value: Value) -> Result<()> {
        Err(anyhow!("explicit-IR op ClosureStore reached a non-explicit machine"))
    }

    fn spawn_child(&mut self, _callee: FuncId, _args: &[Value], _ret: KontRef) -> Result<()> {
        Err(anyhow!("explicit-IR op SpawnChild reached a non-explicit machine"))
    }

    fn close_spawns(&mut self, _clos: Value) -> Result<()> {
        Err(anyhow!("explicit-IR op CloseSpawns reached a non-explicit machine"))
    }

    fn send_argument(&mut self, _value: Value) -> Result<()> {
        Err(anyhow!("explicit-IR op SendArgument reached a non-explicit machine"))
    }
}

/// Get-or-compile memoization over a shared kernel-program cell — the
/// one caching idiom used by every holder of a cached `KernelProgram`
/// (compile sessions, emu programs).
pub fn memo_kernels(
    cell: &std::sync::OnceLock<Arc<KernelProgram>>,
    build: impl FnOnce() -> Result<KernelProgram>,
) -> Result<Arc<KernelProgram>> {
    if let Some(k) = cell.get() {
        return Ok(Arc::clone(k));
    }
    let k = Arc::new(build()?);
    Ok(Arc::clone(cell.get_or_init(|| k)))
}

/// Reusable execution stack: frames are carved out of one `Vec`, so task
/// dispatch allocates nothing after warmup.
#[derive(Debug)]
pub struct KStack {
    pub(crate) slots: Vec<Value>,
    pub(crate) depth: usize,
    /// Per-frame-activation step budget (see [`run_kernel`]).
    pub(crate) limit: u64,
    /// Instructions retired over this stack's lifetime (cumulative across
    /// runs — a fused pair retires as one dispatch). Engines surface this
    /// through their stats for `bombyx run --stats`.
    retired: u64,
    /// The JIT tier's `i64` slot arena: allocated at fixed capacity on
    /// first native entry and never grown (parent native frames hold
    /// pointers into it). Empty until then.
    pub(crate) jslots: Vec<i64>,
    /// Arena high-water mark — native activations carve
    /// `jtop..jtop+frame` and restore on exit.
    pub(crate) jtop: usize,
}

impl Default for KStack {
    fn default() -> KStack {
        KStack::new()
    }
}

impl KStack {
    pub fn new() -> KStack {
        KStack {
            slots: Vec::with_capacity(256),
            depth: 0,
            limit: 0,
            retired: 0,
            jslots: Vec::new(),
            jtop: 0,
        }
    }

    /// Cumulative dispatches retired through this stack.
    pub fn retired(&self) -> u64 {
        self.retired
    }
}

/// Hard recursion backstop (the oracle applies its configurable limit
/// first via [`Machine::on_dispatch`]).
pub(crate) const MAX_DEPTH: usize = 1_000_000;

#[inline]
fn rd(slots: &[Value], base: usize, op: Operand) -> Value {
    match op {
        Operand::Slot(s) => slots[base + s as usize],
        Operand::Imm(v) => v,
    }
}

/// Run one task/function kernel to completion. `step_limit` bounds the
/// branches/jumps executed *per frame activation* (≈ basic-block
/// executions, exactly the unit and scope the tree walkers limited —
/// each nested sequential call gets its own budget, so large terminating
/// programs never trip it). Returns the `Return` value (or `Unit` after
/// `Halt`).
pub fn run_kernel<M: Machine>(
    prog: &KernelProgram,
    fid: FuncId,
    args: &[Value],
    stack: &mut KStack,
    machine: &mut M,
    step_limit: u64,
) -> Result<Value> {
    stack.slots.clear();
    stack.limit = step_limit;
    stack.depth = 0;
    stack.jtop = 0;
    let kernel = prog.kernel(fid);
    if kernel.kind == FuncKind::Xla {
        bail!("xla task `{}` has no kernel body (dispatch it to the XLA handler)", kernel.name);
    }
    if args.len() != kernel.params {
        bail!(
            "task `{}` expects {} args, got {} (closure layout bug)",
            kernel.name,
            kernel.params,
            args.len()
        );
    }
    stack.slots.extend_from_slice(&kernel.frame);
    for (i, a) in args.iter().enumerate() {
        stack.slots[i] = a.coerce(kernel.param_tys[i]);
    }
    exec_frame(prog, fid, 0, stack, machine)
}

/// Push a nested frame whose arguments live in the caller's frame at
/// absolute slots `args_at_abs..args_at_abs+nargs`, run it, pop it.
fn call_nested<M: Machine>(
    prog: &KernelProgram,
    callee: FuncId,
    args_at_abs: usize,
    nargs: usize,
    stack: &mut KStack,
    machine: &mut M,
) -> Result<Value> {
    let kernel = prog.kernel(callee);
    if nargs != kernel.params {
        bail!("`{}` expects {} args, got {}", kernel.name, kernel.params, nargs);
    }
    stack.depth += 1;
    if stack.depth > MAX_DEPTH {
        bail!("kernel recursion limit exceeded in `{}`", kernel.name);
    }
    let base = stack.slots.len();
    stack.slots.extend_from_slice(&kernel.frame);
    for i in 0..nargs {
        let v = stack.slots[args_at_abs + i];
        stack.slots[base + i] = v.coerce(kernel.param_tys[i]);
    }
    let r = exec_frame(prog, callee, base, stack, machine);
    stack.slots.truncate(base);
    stack.depth -= 1;
    r
}

/// Sequential dispatch of a `Call` / serial-elision `SpawnSeq`: stage-slot
/// arguments, xla-or-nested-kernel execution, optional coerced dst write.
#[inline]
fn seq_call<M: Machine>(
    prog: &KernelProgram,
    callee: FuncId,
    base: usize,
    args_at: u32,
    nargs: u32,
    dst: Option<(u32, Type)>,
    stack: &mut KStack,
    machine: &mut M,
) -> Result<()> {
    let a0 = base + args_at as usize;
    let n = nargs as usize;
    let v = if prog.kernel(callee).kind == FuncKind::Xla {
        let args = &stack.slots[a0..a0 + n];
        machine.xla_call(callee, args)?
    } else {
        call_nested(prog, callee, a0, n, stack, machine)?
    };
    if let Some((d, t)) = dst {
        stack.slots[base + d as usize] = v.coerce(t);
    }
    Ok(())
}

/// Per-frame interpreter context handed to dispatch handlers.
pub struct Ctx<'e, M: Machine> {
    prog: &'e KernelProgram,
    kernel: &'e FuncKernel,
    base: usize,
    pc: usize,
    /// Per-activation step budget consumed (branches/jumps).
    steps: u64,
    stack: &'e mut KStack,
    machine: &'e mut M,
}

/// Handler outcome: continue at `ctx.pc` (already advanced/redirected) or
/// unwind the frame with a value.
pub enum Step {
    Next,
    Return(Value),
}

/// One dispatch handler, monomorphized per machine. The `KOp` passed is
/// always the variant the handler's opcode index names (validated at
/// kernel compile); the `let .. else` destructure is a defensive check,
/// not dispatch.
type Handler<M> = for<'a, 'e, 'o> fn(&'a mut Ctx<'e, M>, &'o KOp) -> Result<Step>;

#[cold]
fn op_mismatch(op: &KOp) -> Result<Step> {
    Err(anyhow!("dispatch-table corruption: handler received mismatched op {op:?}"))
}

#[inline]
fn step_budget<M: Machine>(ctx: &mut Ctx<'_, M>) -> Result<()> {
    ctx.steps += 1;
    if ctx.steps > ctx.stack.limit {
        bail!("`{}` exceeded step limit (infinite loop?)", ctx.kernel.name);
    }
    Ok(())
}

fn h_mov<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::Mov { dst, src, ty } = op else { return op_mismatch(op) };
    let mut v = rd(&ctx.stack.slots, ctx.base, *src);
    if let Some(t) = ty {
        v = v.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *dst as usize] = v;
    Ok(Step::Next)
}

fn h_bin<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::Bin { op, dst, lhs, rhs, ty } = op else { return op_mismatch(op) };
    let va = rd(&ctx.stack.slots, ctx.base, *lhs);
    let vb = rd(&ctx.stack.slots, ctx.base, *rhs);
    let mut v = bin_value(*op, va, vb);
    if let Some(t) = ty {
        v = v.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *dst as usize] = v;
    Ok(Step::Next)
}

fn h_un<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::Un { op, dst, src, ty } = op else { return op_mismatch(op) };
    let mut v = un_value(*op, rd(&ctx.stack.slots, ctx.base, *src));
    if let Some(t) = ty {
        v = v.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *dst as usize] = v;
    Ok(Step::Next)
}

fn h_builtin2<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::Builtin2 { b, dst, lhs, rhs, ty } = op else { return op_mismatch(op) };
    let va = rd(&ctx.stack.slots, ctx.base, *lhs);
    let vb = rd(&ctx.stack.slots, ctx.base, *rhs);
    let mut v = builtin2_value(*b, va, vb);
    if let Some(t) = ty {
        v = v.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *dst as usize] = v;
    Ok(Step::Next)
}

fn h_builtin1<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::Builtin1 { b, dst, src, ty } = op else { return op_mismatch(op) };
    let mut v = builtin1_value(*b, rd(&ctx.stack.slots, ctx.base, *src));
    if let Some(t) = ty {
        v = v.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *dst as usize] = v;
    Ok(Step::Next)
}

fn h_int_to_float<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::IntToFloat { dst, src, ty } = op else { return op_mismatch(op) };
    let mut v = Value::F32(rd(&ctx.stack.slots, ctx.base, *src).as_f32());
    if let Some(t) = ty {
        v = v.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *dst as usize] = v;
    Ok(Step::Next)
}

fn h_load<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::Load { dst, arr, index } = op else { return op_mismatch(op) };
    let idx = rd(&ctx.stack.slots, ctx.base, *index).as_i64();
    let v = ctx.machine.load(*arr, idx)?;
    ctx.stack.slots[ctx.base + *dst as usize] = v;
    Ok(Step::Next)
}

fn h_store<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::Store { arr, index, value } = op else { return op_mismatch(op) };
    let idx = rd(&ctx.stack.slots, ctx.base, *index).as_i64();
    let v = rd(&ctx.stack.slots, ctx.base, *value);
    ctx.machine.store(*arr, idx, v)?;
    Ok(Step::Next)
}

fn h_atomic_add<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::AtomicAdd { arr, index, value } = op else { return op_mismatch(op) };
    let idx = rd(&ctx.stack.slots, ctx.base, *index).as_i64();
    let v = rd(&ctx.stack.slots, ctx.base, *value);
    ctx.machine.atomic_add(*arr, idx, v)?;
    Ok(Step::Next)
}

fn h_call<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::Call { dst, callee, args_at, nargs } = op else { return op_mismatch(op) };
    seq_call(
        ctx.prog,
        *callee,
        ctx.base,
        *args_at,
        *nargs,
        *dst,
        &mut *ctx.stack,
        &mut *ctx.machine,
    )?;
    Ok(Step::Next)
}

fn h_spawn_seq<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::SpawnSeq { dst, callee, args_at, nargs } = op else { return op_mismatch(op) };
    ctx.machine.on_spawn_seq();
    seq_call(
        ctx.prog,
        *callee,
        ctx.base,
        *args_at,
        *nargs,
        *dst,
        &mut *ctx.stack,
        &mut *ctx.machine,
    )?;
    Ok(Step::Next)
}

fn h_make_closure<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::MakeClosure { dst, task } = op else { return op_mismatch(op) };
    let handle = ctx.machine.make_closure(*task)?;
    ctx.stack.slots[ctx.base + *dst as usize] = handle;
    Ok(Step::Next)
}

fn h_closure_store<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::ClosureStore { clos, field, value } = op else { return op_mismatch(op) };
    let h = ctx.stack.slots[ctx.base + *clos as usize];
    let v = rd(&ctx.stack.slots, ctx.base, *value);
    ctx.machine.closure_store(h, *field, v)?;
    Ok(Step::Next)
}

fn h_spawn_child<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::SpawnChild { callee, args_at, nargs, ret } = op else { return op_mismatch(op) };
    let kont = match ret {
        KRet::Slot { clos, field } => KontRef::Slot {
            clos: ctx.stack.slots[ctx.base + *clos as usize],
            field: *field,
        },
        KRet::Counter { clos } => {
            KontRef::Counter { clos: ctx.stack.slots[ctx.base + *clos as usize] }
        }
        KRet::Forward => KontRef::Forward,
    };
    let a0 = ctx.base + *args_at as usize;
    let args = &ctx.stack.slots[a0..a0 + *nargs as usize];
    ctx.machine.spawn_child(*callee, args, kont)?;
    Ok(Step::Next)
}

fn h_close_spawns<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::CloseSpawns { clos } = op else { return op_mismatch(op) };
    let h = ctx.stack.slots[ctx.base + *clos as usize];
    ctx.machine.close_spawns(h)?;
    Ok(Step::Next)
}

fn h_send_argument<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::SendArgument { value } = op else { return op_mismatch(op) };
    let v = match value {
        Some(o) => rd(&ctx.stack.slots, ctx.base, *o).coerce(ctx.kernel.ret),
        None => Value::Unit,
    };
    ctx.machine.send_argument(v)?;
    Ok(Step::Next)
}

fn h_jump<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::Jump { target } = op else { return op_mismatch(op) };
    step_budget(ctx)?;
    ctx.pc = *target as usize;
    Ok(Step::Next)
}

fn h_branch<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::Branch { cond, then_, else_ } = op else { return op_mismatch(op) };
    step_budget(ctx)?;
    let c = rd(&ctx.stack.slots, ctx.base, *cond).as_bool();
    ctx.pc = if c { *then_ as usize } else { *else_ as usize };
    Ok(Step::Next)
}

fn h_return<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::Return { value } = op else { return op_mismatch(op) };
    Ok(Step::Return(match value {
        Some(o) => rd(&ctx.stack.slots, ctx.base, *o).coerce(ctx.kernel.ret),
        None => Value::Unit,
    }))
}

fn h_halt<M: Machine>(_ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::Halt = op else { return op_mismatch(op) };
    Ok(Step::Return(Value::Unit))
}

// -- fused-superinstruction handlers: each replays its component ops in
// order, including every frame write, so behavior (and the sim trace,
// given the fusion stage's cost-merge rules) is identical to the
// unfused pair.

fn h_cmp_branch<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::CmpBranch { op, dst, lhs, rhs, ty, then_, else_ } = op else {
        return op_mismatch(op);
    };
    let va = rd(&ctx.stack.slots, ctx.base, *lhs);
    let vb = rd(&ctx.stack.slots, ctx.base, *rhs);
    let mut v = bin_value(*op, va, vb);
    if let Some(t) = ty {
        v = v.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *dst as usize] = v;
    step_budget(ctx)?;
    ctx.pc = if v.as_bool() { *then_ as usize } else { *else_ as usize };
    Ok(Step::Next)
}

fn h_load_mov<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::LoadMov { ldst, arr, index, dst, ty } = op else { return op_mismatch(op) };
    let idx = rd(&ctx.stack.slots, ctx.base, *index).as_i64();
    let v = ctx.machine.load(*arr, idx)?;
    ctx.stack.slots[ctx.base + *ldst as usize] = v;
    let mut mv = v;
    if let Some(t) = ty {
        mv = mv.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *dst as usize] = mv;
    Ok(Step::Next)
}

fn h_bin_mov<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::BinMov { op, bdst, lhs, rhs, bty, dst, ty } = op else { return op_mismatch(op) };
    let va = rd(&ctx.stack.slots, ctx.base, *lhs);
    let vb = rd(&ctx.stack.slots, ctx.base, *rhs);
    let mut v = bin_value(*op, va, vb);
    if let Some(t) = bty {
        v = v.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *bdst as usize] = v;
    let mut mv = v;
    if let Some(t) = ty {
        mv = mv.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *dst as usize] = mv;
    Ok(Step::Next)
}

fn h_store_bin<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::StoreBin { op, bdst, lhs, rhs, bty, arr, index } = op else {
        return op_mismatch(op);
    };
    let va = rd(&ctx.stack.slots, ctx.base, *lhs);
    let vb = rd(&ctx.stack.slots, ctx.base, *rhs);
    let mut v = bin_value(*op, va, vb);
    if let Some(t) = bty {
        v = v.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *bdst as usize] = v;
    // Index is read after the value write, exactly like the unfused
    // sequence (it may name the just-written slot).
    let idx = rd(&ctx.stack.slots, ctx.base, *index).as_i64();
    let val = ctx.stack.slots[ctx.base + *bdst as usize];
    ctx.machine.store(*arr, idx, val)?;
    Ok(Step::Next)
}

fn h_return_bin<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::ReturnBin { op, bdst, lhs, rhs, bty } = op else { return op_mismatch(op) };
    let va = rd(&ctx.stack.slots, ctx.base, *lhs);
    let vb = rd(&ctx.stack.slots, ctx.base, *rhs);
    let mut v = bin_value(*op, va, vb);
    if let Some(t) = bty {
        v = v.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *bdst as usize] = v;
    Ok(Step::Return(v.coerce(ctx.kernel.ret)))
}

fn h_load_bin_store<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::LoadBinStore { ldst, arr, index, cost2, op, bdst, lhs, rhs, bty, sarr, sindex } = op
    else {
        return op_mismatch(op);
    };
    let idx = rd(&ctx.stack.slots, ctx.base, *index).as_i64();
    let lv = ctx.machine.load(*arr, idx)?;
    ctx.stack.slots[ctx.base + *ldst as usize] = lv;
    // The load's trace element (`Seg::Load`) interposes between the two
    // merged compute costs, so the bin+store cost is charged here — after
    // the load — not folded into the up-front `instr.cost`.
    if *cost2 != NO_COST {
        ctx.machine.charge(&ctx.kernel.costs[*cost2 as usize]);
    }
    let va = rd(&ctx.stack.slots, ctx.base, *lhs);
    let vb = rd(&ctx.stack.slots, ctx.base, *rhs);
    let mut v = bin_value(*op, va, vb);
    if let Some(t) = bty {
        v = v.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *bdst as usize] = v;
    // Store index is read after the bin write, like the unfused sequence.
    let sidx = rd(&ctx.stack.slots, ctx.base, *sindex).as_i64();
    let val = ctx.stack.slots[ctx.base + *bdst as usize];
    ctx.machine.store(*sarr, sidx, val)?;
    Ok(Step::Next)
}

fn h_bin_atomic_add<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::BinAtomicAdd { op, bdst, lhs, rhs, bty, arr, index } = op else {
        return op_mismatch(op);
    };
    let va = rd(&ctx.stack.slots, ctx.base, *lhs);
    let vb = rd(&ctx.stack.slots, ctx.base, *rhs);
    let mut v = bin_value(*op, va, vb);
    if let Some(t) = bty {
        v = v.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *bdst as usize] = v;
    // Index is read after the value write, exactly like the unfused
    // sequence (it may name the just-written slot).
    let idx = rd(&ctx.stack.slots, ctx.base, *index).as_i64();
    let val = ctx.stack.slots[ctx.base + *bdst as usize];
    ctx.machine.atomic_add(*arr, idx, val)?;
    Ok(Step::Next)
}

fn h_send_bin<M: Machine>(ctx: &mut Ctx<'_, M>, op: &KOp) -> Result<Step> {
    let KOp::SendBin { op, bdst, lhs, rhs, bty } = op else { return op_mismatch(op) };
    let va = rd(&ctx.stack.slots, ctx.base, *lhs);
    let vb = rd(&ctx.stack.slots, ctx.base, *rhs);
    let mut v = bin_value(*op, va, vb);
    if let Some(t) = bty {
        v = v.coerce(*t);
    }
    ctx.stack.slots[ctx.base + *bdst as usize] = v;
    let sent = ctx.stack.slots[ctx.base + *bdst as usize].coerce(ctx.kernel.ret);
    ctx.machine.send_argument(sent)?;
    Ok(Step::Next)
}

/// The per-machine handler table. Order must match [`opcode`]'s indices
/// (enforced by a unit test over every variant and by the validator's
/// per-instruction `h == opcode_of(op)` check).
#[allow(dead_code)] // only the associated const is used
struct Handlers<M: Machine>(std::marker::PhantomData<M>);

impl<M: Machine> Handlers<M> {
    const TABLE: [Handler<M>; opcode::N] = [
        h_mov::<M>,
        h_bin::<M>,
        h_un::<M>,
        h_builtin2::<M>,
        h_builtin1::<M>,
        h_int_to_float::<M>,
        h_load::<M>,
        h_store::<M>,
        h_atomic_add::<M>,
        h_call::<M>,
        h_spawn_seq::<M>,
        h_make_closure::<M>,
        h_closure_store::<M>,
        h_spawn_child::<M>,
        h_close_spawns::<M>,
        h_send_argument::<M>,
        h_jump::<M>,
        h_branch::<M>,
        h_return::<M>,
        h_halt::<M>,
        h_cmp_branch::<M>,
        h_load_mov::<M>,
        h_bin_mov::<M>,
        h_store_bin::<M>,
        h_return_bin::<M>,
        h_load_bin_store::<M>,
        h_bin_atomic_add::<M>,
        h_send_bin::<M>,
    ];
}

pub(crate) fn exec_frame<M: Machine>(
    prog: &KernelProgram,
    fid: FuncId,
    base: usize,
    stack: &mut KStack,
    machine: &mut M,
) -> Result<Value> {
    machine.on_dispatch(fid, stack.depth)?;
    // Native-tier gate: machines that opt in hand back a tier handle and
    // hot kernels run as compiled x86-64 with runtime-helper out-calls. A
    // bailout resumes the interpreter at the exact pc/step the native
    // code left off; `None` (cold, uncompilable, unavailable) falls
    // through to the interpreter unchanged.
    if let Some(tier) = machine.jit() {
        match crate::exec::jit::try_enter(&tier, prog, fid, base, stack, machine)? {
            Some(crate::exec::jit::Outcome::Done(v)) => return Ok(v),
            Some(crate::exec::jit::Outcome::Bail { pc, steps }) => {
                return interp_frame(prog, fid, base, stack, machine, pc, steps);
            }
            None => {}
        }
    }
    interp_frame(prog, fid, base, stack, machine, 0, 0)
}

/// The retired interpreter loop: the cold tier, the bailout target, and
/// the differential oracle for the native tier. `start_pc`/`start_steps`
/// are nonzero only when resuming after a JIT bailout.
pub(crate) fn interp_frame<M: Machine>(
    prog: &KernelProgram,
    fid: FuncId,
    base: usize,
    stack: &mut KStack,
    machine: &mut M,
    start_pc: usize,
    start_steps: u64,
) -> Result<Value> {
    let kernel = prog.kernel(fid);
    let mut ctx = Ctx { prog, kernel, base, pc: start_pc, steps: start_steps, stack, machine };
    let table: &[Handler<M>; opcode::N] = &Handlers::<M>::TABLE;
    // Direct-threaded inner loop: fetch, charge, indirect-call the
    // pre-resolved handler. No opcode match on the retired path.
    //
    // RETIRED_FAST_PATH_BEGIN: no telemetry may appear between these
    // markers — tracing/metrics/profiling hook the once-per-frame
    // `on_dispatch` seam above, never the per-instruction loop. Pinned
    // by `obs_tests::retired_fast_path_has_no_telemetry`.
    loop {
        let instr = &kernel.code[ctx.pc];
        ctx.pc += 1;
        ctx.stack.retired += 1;
        if instr.cost != NO_COST {
            ctx.machine.charge(&kernel.costs[instr.cost as usize]);
        }
        match (table[instr.h as usize])(&mut ctx, &instr.op)? {
            Step::Next => {}
            Step::Return(v) => return Ok(v),
        }
    }
    // RETIRED_FAST_PATH_END
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fusion_by_role_partitions_the_global_stats() {
        let mk = |role: &'static str, fused: u32, unfused_len: u32| FuncKernel {
            name: format!("{role}_fn"),
            kind: FuncKind::Task,
            role,
            params: 0,
            param_tys: Vec::<Type>::new().into(),
            ret: Type::Void,
            frame: Vec::new(),
            code: Vec::new(),
            costs: Vec::new(),
            fused,
            unfused_len,
        };
        let prog = KernelProgram {
            mode: KernelMode::Explicit,
            funcs: vec![mk("entry", 3, 10), mk("join", 0, 6), mk("entry", 1, 4)],
            global_tys: Vec::new(),
        };
        let rows = prog.fusion_by_role();
        assert_eq!(rows, vec![("entry", 4, 14), ("join", 0, 6)]);
        // Per-role rows must sum back to the global aggregate.
        let (pairs, before) = prog.fusion();
        assert_eq!(rows.iter().map(|(_, p, _)| p).sum::<u64>(), pairs);
        assert_eq!(rows.iter().map(|(_, _, b)| b).sum::<u64>(), before);
    }

    #[test]
    fn arglist_inline_and_heap() {
        let short = ArgList::from_slice(&[Value::I64(1), Value::I64(2)]);
        assert!(matches!(short, ArgList::Inline { .. }));
        assert_eq!(&short[..], &[Value::I64(1), Value::I64(2)]);
        assert_eq!(short.len(), 2);
        let long: Vec<Value> = (0..10).map(Value::I64).collect();
        let heap = ArgList::from_slice(&long);
        assert!(matches!(heap, ArgList::Heap(_)));
        assert_eq!(heap.as_slice(), &long[..]);
        assert_eq!(heap.clone().into_vec(), long);
        let built = ArgList::from_fn(3, |i| Value::I64(i as i64));
        assert_eq!(&built[..], &[Value::I64(0), Value::I64(1), Value::I64(2)]);
    }

    #[test]
    fn opcode_indices_cover_every_variant_and_kinstr_pins_them() {
        use crate::frontend::ast::BinOp;
        // One sample per variant, in opcode order.
        let samples: Vec<KOp> = vec![
            KOp::Mov { dst: 0, src: Operand::Imm(Value::I64(1)), ty: None },
            KOp::Bin {
                op: BinOp::Add,
                dst: 0,
                lhs: Operand::Slot(0),
                rhs: Operand::Slot(0),
                ty: None,
            },
            KOp::Un { op: UnOp::Neg, dst: 0, src: Operand::Slot(0), ty: None },
            KOp::Builtin2 {
                b: Builtin::Min,
                dst: 0,
                lhs: Operand::Slot(0),
                rhs: Operand::Slot(0),
                ty: None,
            },
            KOp::Builtin1 { b: Builtin::Abs, dst: 0, src: Operand::Slot(0), ty: None },
            KOp::IntToFloat { dst: 0, src: Operand::Slot(0), ty: None },
            KOp::Load { dst: 0, arr: GlobalId::new(0), index: Operand::Slot(0) },
            KOp::Store {
                arr: GlobalId::new(0),
                index: Operand::Slot(0),
                value: Operand::Slot(0),
            },
            KOp::AtomicAdd {
                arr: GlobalId::new(0),
                index: Operand::Slot(0),
                value: Operand::Slot(0),
            },
            KOp::Call { dst: None, callee: FuncId::new(0), args_at: 0, nargs: 0 },
            KOp::SpawnSeq { dst: None, callee: FuncId::new(0), args_at: 0, nargs: 0 },
            KOp::MakeClosure { dst: 0, task: FuncId::new(0) },
            KOp::ClosureStore { clos: 0, field: 0, value: Operand::Slot(0) },
            KOp::SpawnChild {
                callee: FuncId::new(0),
                args_at: 0,
                nargs: 0,
                ret: KRet::Forward,
            },
            KOp::CloseSpawns { clos: 0 },
            KOp::SendArgument { value: None },
            KOp::Jump { target: 0 },
            KOp::Branch { cond: Operand::Slot(0), then_: 0, else_: 0 },
            KOp::Return { value: None },
            KOp::Halt,
            KOp::CmpBranch {
                op: BinOp::Lt,
                dst: 0,
                lhs: Operand::Slot(0),
                rhs: Operand::Slot(0),
                ty: None,
                then_: 0,
                else_: 0,
            },
            KOp::LoadMov {
                ldst: 0,
                arr: GlobalId::new(0),
                index: Operand::Slot(0),
                dst: 0,
                ty: None,
            },
            KOp::BinMov {
                op: BinOp::Add,
                bdst: 0,
                lhs: Operand::Slot(0),
                rhs: Operand::Slot(0),
                bty: None,
                dst: 0,
                ty: None,
            },
            KOp::StoreBin {
                op: BinOp::Add,
                bdst: 0,
                lhs: Operand::Slot(0),
                rhs: Operand::Slot(0),
                bty: None,
                arr: GlobalId::new(0),
                index: Operand::Slot(0),
            },
            KOp::ReturnBin {
                op: BinOp::Add,
                bdst: 0,
                lhs: Operand::Slot(0),
                rhs: Operand::Slot(0),
                bty: None,
            },
            KOp::LoadBinStore {
                ldst: 0,
                arr: GlobalId::new(0),
                index: Operand::Slot(0),
                cost2: NO_COST,
                op: BinOp::Add,
                bdst: 0,
                lhs: Operand::Slot(0),
                rhs: Operand::Slot(0),
                bty: None,
                sarr: GlobalId::new(0),
                sindex: Operand::Slot(0),
            },
            KOp::BinAtomicAdd {
                op: BinOp::Add,
                bdst: 0,
                lhs: Operand::Slot(0),
                rhs: Operand::Slot(0),
                bty: None,
                arr: GlobalId::new(0),
                index: Operand::Slot(0),
            },
            KOp::SendBin {
                op: BinOp::Add,
                bdst: 0,
                lhs: Operand::Slot(0),
                rhs: Operand::Slot(0),
                bty: None,
            },
        ];
        assert_eq!(samples.len(), opcode::N, "one sample per opcode");
        for (i, op) in samples.into_iter().enumerate() {
            assert_eq!(opcode_of(&op) as usize, i, "opcode order drifted at {op:?}");
            let instr = KInstr::new(op, NO_COST);
            assert_eq!(instr.h, i as u8, "KInstr::new must pin the handler index");
        }
    }

    #[test]
    fn kcost_cycles_match_hls_model() {
        let model = ScheduleModel::default();
        // Store with a 1-op index and a 5-op value:
        // store_issue + ceil(1/4) + ceil(5/4) = 3 + 1 + 2 = 6.
        let c = KCost { base: KBase::StoreIssue, exprs: vec![1, 5] };
        assert_eq!(c.cycles(&model), 6);
        let b = KCost { base: KBase::Branch, exprs: vec![] };
        assert_eq!(b.cycles(&model), model.branch);
        let z = KCost { base: KBase::Zero, exprs: vec![0] };
        assert_eq!(z.cycles(&model), 0);
    }

    #[test]
    fn bin_value_matches_tree_eval() {
        use crate::frontend::ast::BinOp;
        use crate::ir::expr::{eval, Expr};
        let cases = [
            (BinOp::Add, Value::I64(3), Value::I64(4)),
            (BinOp::Add, Value::F32(1.5), Value::I64(2)),
            (BinOp::Div, Value::I64(7), Value::I64(0)),
            (BinOp::Rem, Value::I64(7), Value::I64(0)),
            (BinOp::Lt, Value::I64(1), Value::F32(2.0)),
            (BinOp::And, Value::Bool(true), Value::I64(0)),
            (BinOp::Shl, Value::I64(1), Value::I64(65)),
        ];
        for (op, a, b) in cases {
            let tree = Expr::Binary(
                op,
                Box::new(imm_expr(a)),
                Box::new(imm_expr(b)),
            );
            assert_eq!(bin_value(op, a, b), eval(&tree, &|_| Value::Unit), "{op:?}");
        }
    }

    fn imm_expr(v: Value) -> crate::ir::expr::Expr {
        use crate::ir::expr::Expr;
        match v {
            Value::I64(x) => Expr::ConstI(x),
            Value::F32(x) => Expr::ConstF(x),
            Value::Bool(x) => Expr::ConstB(x),
            Value::Unit => Expr::ConstI(0),
        }
    }
}
