//! Register-based linear bytecode and the shared interpreter loop.
//!
//! A [`KernelProgram`] is the compiled form of one IR module: per function
//! a flat instruction array over a frame of value slots (parameters,
//! locals, then expression temporaries). The interpreter
//! ([`run_kernel`]) is generic over a [`Machine`] that realizes side
//! effects — memory, closures, spawns, sends — and meters whatever the
//! engine cares about (the simulator charges [`KCost`] cycles through
//! [`Machine::charge`]; the software engines leave it a no-op that
//! monomorphizes away).
//!
//! Semantics are bit-for-bit those of the old tree-walking executors:
//! the arithmetic helpers ([`bin_value`] & co.) replicate
//! `ir::expr::eval`'s dynamic float-promotion rules, writes to named
//! variables coerce to the variable's declared type exactly where the
//! tree walkers did, and the compiler ([`super::compile`]) preserves
//! left-to-right evaluation order.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::frontend::ast::{BinOp, Type, UnOp};
use crate::hls::ScheduleModel;
use crate::ir::cfg::{FuncId, FuncKind, GlobalId};
use crate::ir::expr::{Builtin, Value};

/// Sentinel for "this instruction carries no cycle-cost metadata".
pub const NO_COST: u32 = u32::MAX;

/// Which IR a program was compiled from. Implicit kernels keep
/// `cilk_spawn` as a sequential call ([`KOp::SpawnSeq`], the serial
/// elision the oracle runs); explicit kernels carry the Cilk-1 ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    Implicit,
    Explicit,
}

/// An instruction operand: a frame slot or a folded immediate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Operand {
    Slot(u32),
    Imm(Value),
}

/// Where a spawned child delivers its result (pre-resolved
/// [`crate::ir::cfg::RetTarget`]; `clos` fields are frame slots holding
/// closure handles).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KRet {
    Slot { clos: u32, field: u32 },
    Counter { clos: u32 },
    Forward,
}

/// A resolved continuation target handed to [`Machine::spawn_child`]:
/// closure-handle *values* read out of the frame.
#[derive(Clone, Copy, Debug)]
pub enum KontRef {
    Slot { clos: Value, field: u32 },
    Counter { clos: Value },
    Forward,
}

/// One bytecode instruction: the operation plus an optional index into
/// the kernel's [`KCost`] table (attached to the anchor instruction of
/// each source IR op; [`NO_COST`] on expression-temporary instructions,
/// whose cycles are folded into their anchor's cost — exactly how the
/// HLS model charged whole ops).
#[derive(Clone, Debug)]
pub struct KInstr {
    pub op: KOp,
    pub cost: u32,
}

#[derive(Clone, Debug)]
pub enum KOp {
    /// `dst = src` (with optional coercion to a declared variable type).
    Mov { dst: u32, src: Operand, ty: Option<Type> },
    Bin { op: BinOp, dst: u32, lhs: Operand, rhs: Operand, ty: Option<Type> },
    Un { op: UnOp, dst: u32, src: Operand, ty: Option<Type> },
    /// Two-argument builtin (min/max) — arity fixed at compile time.
    Builtin2 { b: Builtin, dst: u32, lhs: Operand, rhs: Operand, ty: Option<Type> },
    /// One-argument builtin (abs).
    Builtin1 { b: Builtin, dst: u32, src: Operand, ty: Option<Type> },
    IntToFloat { dst: u32, src: Operand, ty: Option<Type> },
    Load { dst: u32, arr: GlobalId, index: Operand },
    Store { arr: GlobalId, index: Operand, value: Operand },
    AtomicAdd { arr: GlobalId, index: Operand, value: Operand },
    /// Sequential call; args staged in `nargs` consecutive frame slots
    /// starting at `args_at`. `dst` carries the destination slot and its
    /// coercion type.
    Call { dst: Option<(u32, Type)>, callee: FuncId, args_at: u32, nargs: u32 },
    /// `cilk_spawn` under serial elision (implicit kernels only).
    SpawnSeq { dst: Option<(u32, Type)>, callee: FuncId, args_at: u32, nargs: u32 },
    MakeClosure { dst: u32, task: FuncId },
    ClosureStore { clos: u32, field: u32, value: Operand },
    SpawnChild { callee: FuncId, args_at: u32, nargs: u32, ret: KRet },
    CloseSpawns { clos: u32 },
    SendArgument { value: Option<Operand> },
    Jump { target: u32 },
    Branch { cond: Operand, then_: u32, else_: u32 },
    Return { value: Option<Operand> },
    Halt,
}

/// Cycle-cost metadata for one source IR op, resolved against a
/// [`ScheduleModel`] at simulation time. Mirrors `hls::op_cycles`: a
/// base latency plus one independently-rounded datapath figure per
/// operand expression (operator counts measured on the *original* tree,
/// so constant folding never changes simulated timing).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KCost {
    pub base: KBase,
    /// Operator counts of the op's operand expressions, each charged
    /// `ceil(n / ops_per_cycle)` like `hls::expr_cycles`.
    pub exprs: Vec<u32>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KBase {
    Zero,
    LoadIssue,
    StoreIssue,
    StreamWrite,
    SpawnNextRtt,
    Branch,
}

impl KCost {
    pub fn cycles(&self, model: &ScheduleModel) -> u32 {
        let base = match self.base {
            KBase::Zero => 0,
            KBase::LoadIssue => model.load_issue,
            KBase::StoreIssue => model.store_issue,
            KBase::StreamWrite => model.stream_write,
            KBase::SpawnNextRtt => model.spawn_next_rtt,
            KBase::Branch => model.branch,
        };
        base + self
            .exprs
            .iter()
            .map(|&n| n.div_ceil(model.ops_per_cycle))
            .sum::<u32>()
    }
}

/// One function's compiled kernel.
#[derive(Clone, Debug)]
pub struct FuncKernel {
    pub name: String,
    pub kind: FuncKind,
    /// Task role name (`entry`/`continuation`/`join`/`access`/`xla`) or
    /// `"leaf"` for spawned leaf functions — the per-role stats key.
    pub role: &'static str,
    pub params: usize,
    /// Parameter types, shared (`Arc`) into every closure created for
    /// this task so closure allocation never clones a type vector.
    pub param_tys: Arc<[Type]>,
    pub ret: Type,
    /// Zero-initialized frame prototype: one `zero_of(ty)` per declared
    /// variable, then `Unit` for expression temporaries.
    pub frame: Vec<Value>,
    /// Empty for `extern xla` declarations (no body).
    pub code: Vec<KInstr>,
    pub costs: Vec<KCost>,
}

/// A compiled module: kernels indexed by [`FuncId`].
#[derive(Clone, Debug)]
pub struct KernelProgram {
    pub mode: KernelMode,
    pub funcs: Vec<FuncKernel>,
}

impl KernelProgram {
    #[inline]
    pub fn kernel(&self, fid: FuncId) -> &FuncKernel {
        &self.funcs[fid.index()]
    }

    pub fn func_by_name(&self, name: &str) -> Option<FuncId> {
        self.funcs
            .iter()
            .position(|k| k.name == name)
            .map(FuncId::new)
    }

    pub fn instr_count(&self) -> usize {
        self.funcs.iter().map(|k| k.code.len()).sum()
    }

    /// Structural validation — the post-pass lint of the `kernel_compile`
    /// pass. Returns the list of violations (empty = OK).
    pub fn validate(&self) -> Vec<String> {
        let mut errors = Vec::new();
        for (i, k) in self.funcs.iter().enumerate() {
            let ctx = |msg: String| format!("kernel `{}` (#{i}): {msg}", k.name);
            if k.kind == FuncKind::Xla {
                if !k.code.is_empty() {
                    errors.push(ctx("xla kernel must have no code".into()));
                }
                continue;
            }
            if k.code.is_empty() {
                errors.push(ctx("empty code".into()));
                continue;
            }
            if !matches!(
                k.code[k.code.len() - 1].op,
                KOp::Jump { .. } | KOp::Branch { .. } | KOp::Return { .. } | KOp::Halt
            ) {
                errors.push(ctx("code does not end with a block terminator".into()));
            }
            if k.params > k.frame.len() {
                errors.push(ctx("more params than frame slots".into()));
            }
            let nslots = k.frame.len() as u32;
            let ncode = k.code.len() as u32;
            let nfuncs = self.funcs.len();
            let slot_ok = |s: u32| s < nslots;
            let opnd_ok = |o: &Operand| match o {
                Operand::Slot(s) => *s < nslots,
                Operand::Imm(_) => true,
            };
            for (pc, instr) in k.code.iter().enumerate() {
                if instr.cost != NO_COST && instr.cost as usize >= k.costs.len() {
                    errors.push(ctx(format!("pc {pc}: cost index out of range")));
                }
                let mut bad = false;
                match &instr.op {
                    KOp::Mov { dst, src, .. }
                    | KOp::Un { dst, src, .. }
                    | KOp::Builtin1 { dst, src, .. }
                    | KOp::IntToFloat { dst, src, .. } => {
                        bad = !slot_ok(*dst) || !opnd_ok(src);
                    }
                    KOp::Bin { dst, lhs, rhs, .. } | KOp::Builtin2 { dst, lhs, rhs, .. } => {
                        bad = !slot_ok(*dst) || !opnd_ok(lhs) || !opnd_ok(rhs);
                    }
                    KOp::Load { dst, index, .. } => bad = !slot_ok(*dst) || !opnd_ok(index),
                    KOp::Store { index, value, .. } | KOp::AtomicAdd { index, value, .. } => {
                        bad = !opnd_ok(index) || !opnd_ok(value);
                    }
                    KOp::Call { dst, callee, args_at, nargs }
                    | KOp::SpawnSeq { dst, callee, args_at, nargs } => {
                        bad = args_at + nargs > nslots
                            || callee.index() >= nfuncs
                            || dst.map(|(d, _)| !slot_ok(d)).unwrap_or(false);
                        if matches!(instr.op, KOp::SpawnSeq { .. })
                            && self.mode == KernelMode::Explicit
                        {
                            errors.push(ctx(format!("pc {pc}: SpawnSeq in explicit kernel")));
                        }
                    }
                    KOp::MakeClosure { dst, task } => {
                        bad = !slot_ok(*dst) || task.index() >= nfuncs;
                    }
                    KOp::ClosureStore { clos, value, .. } => {
                        bad = !slot_ok(*clos) || !opnd_ok(value);
                    }
                    KOp::SpawnChild { callee, args_at, nargs, ret } => {
                        bad = args_at + nargs > nslots || callee.index() >= nfuncs;
                        match ret {
                            KRet::Slot { clos, .. } | KRet::Counter { clos } => {
                                bad = bad || !slot_ok(*clos);
                            }
                            KRet::Forward => {}
                        }
                    }
                    KOp::CloseSpawns { clos } => bad = !slot_ok(*clos),
                    KOp::SendArgument { value } => {
                        bad = value.as_ref().map(|v| !opnd_ok(v)).unwrap_or(false);
                    }
                    KOp::Jump { target } => bad = *target >= ncode,
                    KOp::Branch { cond, then_, else_ } => {
                        bad = !opnd_ok(cond) || *then_ >= ncode || *else_ >= ncode;
                    }
                    KOp::Return { value } => {
                        bad = value.as_ref().map(|v| !opnd_ok(v)).unwrap_or(false);
                    }
                    KOp::Halt => {
                        if self.mode == KernelMode::Implicit {
                            errors.push(ctx(format!("pc {pc}: Halt in implicit kernel")));
                        }
                    }
                }
                if self.mode == KernelMode::Implicit
                    && matches!(
                        instr.op,
                        KOp::MakeClosure { .. }
                            | KOp::ClosureStore { .. }
                            | KOp::SpawnChild { .. }
                            | KOp::CloseSpawns { .. }
                            | KOp::SendArgument { .. }
                    )
                {
                    errors.push(ctx(format!("pc {pc}: explicit-only op in implicit kernel")));
                }
                if bad {
                    errors.push(ctx(format!("pc {pc}: operand out of range: {:?}", instr.op)));
                }
            }
        }
        errors
    }

    /// Human-readable listing (stable — used by the disassembly golden).
    pub fn disasm(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mode = match self.mode {
            KernelMode::Implicit => "implicit",
            KernelMode::Explicit => "explicit",
        };
        let _ = writeln!(out, "; kernel program ({mode} IR, {} kernels)", self.funcs.len());
        for (i, k) in self.funcs.iter().enumerate() {
            let _ = writeln!(
                out,
                "\nkernel `{}` #{i} ({:?}, role={}, params={}, frame={}, ret={:?}):",
                k.name,
                k.kind,
                k.role,
                k.params,
                k.frame.len(),
                k.ret
            );
            if k.code.is_empty() {
                let _ = writeln!(out, "  <extern>");
                continue;
            }
            for (pc, instr) in k.code.iter().enumerate() {
                let mut line = format!("  {pc:>3}: {}", fmt_op(&instr.op, self));
                if instr.cost != NO_COST {
                    let c = &k.costs[instr.cost as usize];
                    let _ = write!(line, "    ; cost {:?}{:?}", c.base, c.exprs);
                }
                let _ = writeln!(out, "{line}");
            }
        }
        out
    }
}

fn fmt_operand(o: &Operand) -> String {
    match o {
        Operand::Slot(s) => format!("r{s}"),
        Operand::Imm(v) => format!("imm({v})"),
    }
}

fn fmt_dst(dst: u32, ty: &Option<Type>) -> String {
    match ty {
        Some(t) => format!("r{dst}:{t:?}"),
        None => format!("r{dst}"),
    }
}

fn fmt_op(op: &KOp, prog: &KernelProgram) -> String {
    let fname = |f: &FuncId| prog.funcs[f.index()].name.clone();
    match op {
        KOp::Mov { dst, src, ty } => format!("{} = {}", fmt_dst(*dst, ty), fmt_operand(src)),
        KOp::Bin { op, dst, lhs, rhs, ty } => format!(
            "{} = {:?} {}, {}",
            fmt_dst(*dst, ty),
            op,
            fmt_operand(lhs),
            fmt_operand(rhs)
        ),
        KOp::Un { op, dst, src, ty } => {
            format!("{} = {:?} {}", fmt_dst(*dst, ty), op, fmt_operand(src))
        }
        KOp::Builtin2 { b, dst, lhs, rhs, ty } => format!(
            "{} = {} {}, {}",
            fmt_dst(*dst, ty),
            b.name(),
            fmt_operand(lhs),
            fmt_operand(rhs)
        ),
        KOp::Builtin1 { b, dst, src, ty } => {
            format!("{} = {} {}", fmt_dst(*dst, ty), b.name(), fmt_operand(src))
        }
        KOp::IntToFloat { dst, src, ty } => {
            format!("{} = i2f {}", fmt_dst(*dst, ty), fmt_operand(src))
        }
        KOp::Load { dst, arr, index } => {
            format!("r{dst} = load g{}[{}]", arr.index(), fmt_operand(index))
        }
        KOp::Store { arr, index, value } => format!(
            "store g{}[{}] = {}",
            arr.index(),
            fmt_operand(index),
            fmt_operand(value)
        ),
        KOp::AtomicAdd { arr, index, value } => format!(
            "atomic_add g{}[{}], {}",
            arr.index(),
            fmt_operand(index),
            fmt_operand(value)
        ),
        KOp::Call { dst, callee, args_at, nargs } => format!(
            "{}call `{}` args r{}..r{}",
            dst.map(|(d, t)| format!("r{d}:{t:?} = ")).unwrap_or_default(),
            fname(callee),
            args_at,
            args_at + nargs
        ),
        KOp::SpawnSeq { dst, callee, args_at, nargs } => format!(
            "{}spawn_seq `{}` args r{}..r{}",
            dst.map(|(d, t)| format!("r{d}:{t:?} = ")).unwrap_or_default(),
            fname(callee),
            args_at,
            args_at + nargs
        ),
        KOp::MakeClosure { dst, task } => format!("r{dst} = spawn_next `{}`", fname(task)),
        KOp::ClosureStore { clos, field, value } => {
            format!("closure r{clos}[{field}] = {}", fmt_operand(value))
        }
        KOp::SpawnChild { callee, args_at, nargs, ret } => format!(
            "spawn `{}` args r{}..r{} ret {:?}",
            fname(callee),
            args_at,
            args_at + nargs,
            ret
        ),
        KOp::CloseSpawns { clos } => format!("close_spawns r{clos}"),
        KOp::SendArgument { value } => format!(
            "send_argument {}",
            value.as_ref().map(|v| fmt_operand(v)).unwrap_or_else(|| "-".into())
        ),
        KOp::Jump { target } => format!("jump @{target}"),
        KOp::Branch { cond, then_, else_ } => {
            format!("branch {} ? @{then_} : @{else_}", fmt_operand(cond))
        }
        KOp::Return { value } => format!(
            "return {}",
            value.as_ref().map(|v| fmt_operand(v)).unwrap_or_else(|| "-".into())
        ),
        KOp::Halt => "halt".to_string(),
    }
}

// ---------------------------------------------------------------------------
// Argument lists

/// Number of argument values stored inline (no heap) in an [`ArgList`].
pub const ARG_INLINE: usize = 6;

/// A small-size-optimized argument vector: task instances with up to
/// [`ARG_INLINE`] arguments (every corpus workload) carry them inline, so
/// spawning a task allocates nothing.
#[derive(Clone, Debug)]
pub enum ArgList {
    Inline { len: u8, buf: [Value; ARG_INLINE] },
    Heap(Vec<Value>),
}

impl ArgList {
    pub fn new() -> ArgList {
        ArgList::Inline { len: 0, buf: [Value::Unit; ARG_INLINE] }
    }

    pub fn from_slice(vals: &[Value]) -> ArgList {
        if vals.len() <= ARG_INLINE {
            let mut buf = [Value::Unit; ARG_INLINE];
            buf[..vals.len()].copy_from_slice(vals);
            ArgList::Inline { len: vals.len() as u8, buf }
        } else {
            ArgList::Heap(vals.to_vec())
        }
    }

    /// Build from an element generator (used to snapshot closure slots
    /// without an intermediate `Vec`).
    pub fn from_fn(len: usize, mut f: impl FnMut(usize) -> Value) -> ArgList {
        if len <= ARG_INLINE {
            let mut buf = [Value::Unit; ARG_INLINE];
            for (i, slot) in buf.iter_mut().enumerate().take(len) {
                *slot = f(i);
            }
            ArgList::Inline { len: len as u8, buf }
        } else {
            ArgList::Heap((0..len).map(f).collect())
        }
    }

    pub fn as_slice(&self) -> &[Value] {
        match self {
            ArgList::Inline { len, buf } => &buf[..*len as usize],
            ArgList::Heap(v) => v,
        }
    }

    pub fn into_vec(self) -> Vec<Value> {
        match self {
            ArgList::Inline { len, buf } => buf[..len as usize].to_vec(),
            ArgList::Heap(v) => v,
        }
    }
}

impl Default for ArgList {
    fn default() -> ArgList {
        ArgList::new()
    }
}

impl std::ops::Deref for ArgList {
    type Target = [Value];
    fn deref(&self) -> &[Value] {
        self.as_slice()
    }
}

impl From<Vec<Value>> for ArgList {
    fn from(v: Vec<Value>) -> ArgList {
        if v.len() <= ARG_INLINE {
            ArgList::from_slice(&v)
        } else {
            ArgList::Heap(v)
        }
    }
}

impl From<&[Value]> for ArgList {
    fn from(v: &[Value]) -> ArgList {
        ArgList::from_slice(v)
    }
}

impl PartialEq for ArgList {
    fn eq(&self, other: &ArgList) -> bool {
        self.as_slice() == other.as_slice()
    }
}

// ---------------------------------------------------------------------------
// Arithmetic (bit-for-bit `ir::expr::eval` semantics)

#[inline]
pub fn un_value(op: UnOp, v: Value) -> Value {
    match op {
        UnOp::Neg => match v {
            Value::F32(f) => Value::F32(-f),
            other => Value::I64(-other.as_i64()),
        },
        UnOp::Not => Value::Bool(!v.as_bool()),
    }
}

#[inline]
pub fn builtin1_value(b: Builtin, v: Value) -> Value {
    let float = matches!(v, Value::F32(_));
    match (b, float) {
        (Builtin::Abs, false) => Value::I64(v.as_i64().abs()),
        (Builtin::Abs, true) => Value::F32(v.as_f32().abs()),
        // min/max never compile to Builtin1 (arity 2 checked by sema and
        // the kernel compiler); keep eval-compatible fallbacks anyway.
        (Builtin::Min, false) | (Builtin::Max, false) => Value::I64(v.as_i64()),
        (Builtin::Min, true) | (Builtin::Max, true) => Value::F32(v.as_f32()),
    }
}

#[inline]
pub fn builtin2_value(b: Builtin, va: Value, vb: Value) -> Value {
    let float = matches!(va, Value::F32(_)) || matches!(vb, Value::F32(_));
    match (b, float) {
        (Builtin::Min, false) => Value::I64(va.as_i64().min(vb.as_i64())),
        (Builtin::Max, false) => Value::I64(va.as_i64().max(vb.as_i64())),
        (Builtin::Abs, false) => Value::I64(va.as_i64().abs()),
        (Builtin::Min, true) => Value::F32(va.as_f32().min(vb.as_f32())),
        (Builtin::Max, true) => Value::F32(va.as_f32().max(vb.as_f32())),
        (Builtin::Abs, true) => Value::F32(va.as_f32().abs()),
    }
}

#[inline]
pub fn bin_value(op: BinOp, va: Value, vb: Value) -> Value {
    let float = matches!(va, Value::F32(_)) || matches!(vb, Value::F32(_));
    use BinOp::*;
    match op {
        Add | Sub | Mul | Div if float => {
            let (x, y) = (va.as_f32(), vb.as_f32());
            Value::F32(match op {
                Add => x + y,
                Sub => x - y,
                Mul => x * y,
                Div => x / y,
                _ => unreachable!(),
            })
        }
        Add => Value::I64(va.as_i64().wrapping_add(vb.as_i64())),
        Sub => Value::I64(va.as_i64().wrapping_sub(vb.as_i64())),
        Mul => Value::I64(va.as_i64().wrapping_mul(vb.as_i64())),
        Div => {
            let d = vb.as_i64();
            Value::I64(if d == 0 { 0 } else { va.as_i64().wrapping_div(d) })
        }
        Rem => {
            let d = vb.as_i64();
            Value::I64(if d == 0 { 0 } else { va.as_i64().wrapping_rem(d) })
        }
        Shl => Value::I64(va.as_i64().wrapping_shl(vb.as_i64() as u32 & 63)),
        Shr => Value::I64(va.as_i64().wrapping_shr(vb.as_i64() as u32 & 63)),
        BitAnd => Value::I64(va.as_i64() & vb.as_i64()),
        BitOr => Value::I64(va.as_i64() | vb.as_i64()),
        BitXor => Value::I64(va.as_i64() ^ vb.as_i64()),
        And => Value::Bool(va.as_bool() && vb.as_bool()),
        Or => Value::Bool(va.as_bool() || vb.as_bool()),
        Lt | Le | Gt | Ge | Eq | Ne => {
            let r = if float {
                let (x, y) = (va.as_f32(), vb.as_f32());
                match op {
                    Lt => x < y,
                    Le => x <= y,
                    Gt => x > y,
                    Ge => x >= y,
                    Eq => x == y,
                    Ne => x != y,
                    _ => unreachable!(),
                }
            } else {
                let (x, y) = (va.as_i64(), vb.as_i64());
                match op {
                    Lt => x < y,
                    Le => x <= y,
                    Gt => x > y,
                    Ge => x >= y,
                    Eq => x == y,
                    Ne => x != y,
                    _ => unreachable!(),
                }
            };
            Value::Bool(r)
        }
    }
}

// ---------------------------------------------------------------------------
// Machine trait + interpreter

/// Engine-specific side of kernel execution. The interpreter handles all
/// pure computation and control flow; a machine realizes memory, task
/// and closure effects, and meters what its engine cares about. Methods
/// an engine's kernels can never reach keep the bailing defaults.
pub trait Machine {
    /// Cycle metering (simulator only); default no-op.
    #[inline]
    fn charge(&mut self, _cost: &KCost) {}

    /// Invoked at every frame entry (top-level and nested calls) with
    /// the nesting depth (0 = top). The oracle uses it for call counting
    /// and recursion limiting.
    #[inline]
    fn on_dispatch(&mut self, _fid: FuncId, _depth: usize) -> Result<()> {
        Ok(())
    }

    /// Invoked before each `SpawnSeq` dispatch (oracle spawn counter).
    #[inline]
    fn on_spawn_seq(&mut self) {}

    fn load(&mut self, arr: GlobalId, index: i64) -> Result<Value>;
    fn store(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()>;
    fn atomic_add(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()>;

    /// Sequential dispatch of an `extern xla` callee.
    fn xla_call(&mut self, _fid: FuncId, _args: &[Value]) -> Result<Value> {
        Err(anyhow!("xla call not supported by this machine"))
    }

    fn make_closure(&mut self, _task: FuncId) -> Result<Value> {
        Err(anyhow!("explicit-IR op MakeClosure reached a non-explicit machine"))
    }

    fn closure_store(&mut self, _clos: Value, _field: u32, _value: Value) -> Result<()> {
        Err(anyhow!("explicit-IR op ClosureStore reached a non-explicit machine"))
    }

    fn spawn_child(&mut self, _callee: FuncId, _args: &[Value], _ret: KontRef) -> Result<()> {
        Err(anyhow!("explicit-IR op SpawnChild reached a non-explicit machine"))
    }

    fn close_spawns(&mut self, _clos: Value) -> Result<()> {
        Err(anyhow!("explicit-IR op CloseSpawns reached a non-explicit machine"))
    }

    fn send_argument(&mut self, _value: Value) -> Result<()> {
        Err(anyhow!("explicit-IR op SendArgument reached a non-explicit machine"))
    }
}

/// Get-or-compile memoization over a shared kernel-program cell — the
/// one caching idiom used by every holder of a cached `KernelProgram`
/// (compile sessions, emu programs).
pub fn memo_kernels(
    cell: &std::sync::OnceLock<Arc<KernelProgram>>,
    build: impl FnOnce() -> Result<KernelProgram>,
) -> Result<Arc<KernelProgram>> {
    if let Some(k) = cell.get() {
        return Ok(Arc::clone(k));
    }
    let k = Arc::new(build()?);
    Ok(Arc::clone(cell.get_or_init(|| k)))
}

/// Reusable execution stack: frames are carved out of one `Vec`, so task
/// dispatch allocates nothing after warmup.
#[derive(Debug)]
pub struct KStack {
    slots: Vec<Value>,
    depth: usize,
    /// Per-frame-activation step budget (see [`run_kernel`]).
    limit: u64,
}

impl Default for KStack {
    fn default() -> KStack {
        KStack::new()
    }
}

impl KStack {
    pub fn new() -> KStack {
        KStack { slots: Vec::with_capacity(256), depth: 0, limit: 0 }
    }
}

/// Hard recursion backstop (the oracle applies its configurable limit
/// first via [`Machine::on_dispatch`]).
const MAX_DEPTH: usize = 1_000_000;

#[inline]
fn rd(slots: &[Value], base: usize, op: Operand) -> Value {
    match op {
        Operand::Slot(s) => slots[base + s as usize],
        Operand::Imm(v) => v,
    }
}

/// Run one task/function kernel to completion. `step_limit` bounds the
/// branches/jumps executed *per frame activation* (≈ basic-block
/// executions, exactly the unit and scope the tree walkers limited —
/// each nested sequential call gets its own budget, so large terminating
/// programs never trip it). Returns the `Return` value (or `Unit` after
/// `Halt`).
pub fn run_kernel<M: Machine>(
    prog: &KernelProgram,
    fid: FuncId,
    args: &[Value],
    stack: &mut KStack,
    machine: &mut M,
    step_limit: u64,
) -> Result<Value> {
    stack.slots.clear();
    stack.limit = step_limit;
    stack.depth = 0;
    let kernel = prog.kernel(fid);
    if kernel.kind == FuncKind::Xla {
        bail!("xla task `{}` has no kernel body (dispatch it to the XLA handler)", kernel.name);
    }
    if args.len() != kernel.params {
        bail!(
            "task `{}` expects {} args, got {} (closure layout bug)",
            kernel.name,
            kernel.params,
            args.len()
        );
    }
    stack.slots.extend_from_slice(&kernel.frame);
    for (i, a) in args.iter().enumerate() {
        stack.slots[i] = a.coerce(kernel.param_tys[i]);
    }
    exec_frame(prog, fid, 0, stack, machine)
}

/// Push a nested frame whose arguments live in the caller's frame at
/// absolute slots `args_at_abs..args_at_abs+nargs`, run it, pop it.
fn call_nested<M: Machine>(
    prog: &KernelProgram,
    callee: FuncId,
    args_at_abs: usize,
    nargs: usize,
    stack: &mut KStack,
    machine: &mut M,
) -> Result<Value> {
    let kernel = prog.kernel(callee);
    if nargs != kernel.params {
        bail!("`{}` expects {} args, got {}", kernel.name, kernel.params, nargs);
    }
    stack.depth += 1;
    if stack.depth > MAX_DEPTH {
        bail!("kernel recursion limit exceeded in `{}`", kernel.name);
    }
    let base = stack.slots.len();
    stack.slots.extend_from_slice(&kernel.frame);
    for i in 0..nargs {
        let v = stack.slots[args_at_abs + i];
        stack.slots[base + i] = v.coerce(kernel.param_tys[i]);
    }
    let r = exec_frame(prog, callee, base, stack, machine);
    stack.slots.truncate(base);
    stack.depth -= 1;
    r
}

/// Sequential dispatch of a `Call` / serial-elision `SpawnSeq`: stage-slot
/// arguments, xla-or-nested-kernel execution, optional coerced dst write.
#[inline]
fn seq_call<M: Machine>(
    prog: &KernelProgram,
    callee: FuncId,
    base: usize,
    args_at: u32,
    nargs: u32,
    dst: Option<(u32, Type)>,
    stack: &mut KStack,
    machine: &mut M,
) -> Result<()> {
    let a0 = base + args_at as usize;
    let n = nargs as usize;
    let v = if prog.kernel(callee).kind == FuncKind::Xla {
        let args = &stack.slots[a0..a0 + n];
        machine.xla_call(callee, args)?
    } else {
        call_nested(prog, callee, a0, n, stack, machine)?
    };
    if let Some((d, t)) = dst {
        stack.slots[base + d as usize] = v.coerce(t);
    }
    Ok(())
}

fn exec_frame<M: Machine>(
    prog: &KernelProgram,
    fid: FuncId,
    base: usize,
    stack: &mut KStack,
    machine: &mut M,
) -> Result<Value> {
    machine.on_dispatch(fid, stack.depth)?;
    let kernel = prog.kernel(fid);
    let code = &kernel.code;
    let mut pc = 0usize;
    // Per-activation step budget (branches/jumps), matching the old
    // per-function-call limits of the tree-walking executors.
    let mut steps: u64 = 0;
    loop {
        let instr = &code[pc];
        pc += 1;
        if instr.cost != NO_COST {
            machine.charge(&kernel.costs[instr.cost as usize]);
        }
        match &instr.op {
            KOp::Mov { dst, src, ty } => {
                let mut v = rd(&stack.slots, base, *src);
                if let Some(t) = ty {
                    v = v.coerce(*t);
                }
                stack.slots[base + *dst as usize] = v;
            }
            KOp::Bin { op, dst, lhs, rhs, ty } => {
                let va = rd(&stack.slots, base, *lhs);
                let vb = rd(&stack.slots, base, *rhs);
                let mut v = bin_value(*op, va, vb);
                if let Some(t) = ty {
                    v = v.coerce(*t);
                }
                stack.slots[base + *dst as usize] = v;
            }
            KOp::Un { op, dst, src, ty } => {
                let mut v = un_value(*op, rd(&stack.slots, base, *src));
                if let Some(t) = ty {
                    v = v.coerce(*t);
                }
                stack.slots[base + *dst as usize] = v;
            }
            KOp::Builtin2 { b, dst, lhs, rhs, ty } => {
                let va = rd(&stack.slots, base, *lhs);
                let vb = rd(&stack.slots, base, *rhs);
                let mut v = builtin2_value(*b, va, vb);
                if let Some(t) = ty {
                    v = v.coerce(*t);
                }
                stack.slots[base + *dst as usize] = v;
            }
            KOp::Builtin1 { b, dst, src, ty } => {
                let mut v = builtin1_value(*b, rd(&stack.slots, base, *src));
                if let Some(t) = ty {
                    v = v.coerce(*t);
                }
                stack.slots[base + *dst as usize] = v;
            }
            KOp::IntToFloat { dst, src, ty } => {
                let mut v = Value::F32(rd(&stack.slots, base, *src).as_f32());
                if let Some(t) = ty {
                    v = v.coerce(*t);
                }
                stack.slots[base + *dst as usize] = v;
            }
            KOp::Load { dst, arr, index } => {
                let idx = rd(&stack.slots, base, *index).as_i64();
                let v = machine.load(*arr, idx)?;
                stack.slots[base + *dst as usize] = v;
            }
            KOp::Store { arr, index, value } => {
                let idx = rd(&stack.slots, base, *index).as_i64();
                let v = rd(&stack.slots, base, *value);
                machine.store(*arr, idx, v)?;
            }
            KOp::AtomicAdd { arr, index, value } => {
                let idx = rd(&stack.slots, base, *index).as_i64();
                let v = rd(&stack.slots, base, *value);
                machine.atomic_add(*arr, idx, v)?;
            }
            KOp::Call { dst, callee, args_at, nargs } => {
                seq_call(prog, *callee, base, *args_at, *nargs, *dst, stack, machine)?;
            }
            KOp::SpawnSeq { dst, callee, args_at, nargs } => {
                machine.on_spawn_seq();
                seq_call(prog, *callee, base, *args_at, *nargs, *dst, stack, machine)?;
            }
            KOp::MakeClosure { dst, task } => {
                let handle = machine.make_closure(*task)?;
                stack.slots[base + *dst as usize] = handle;
            }
            KOp::ClosureStore { clos, field, value } => {
                let h = stack.slots[base + *clos as usize];
                let v = rd(&stack.slots, base, *value);
                machine.closure_store(h, *field, v)?;
            }
            KOp::SpawnChild { callee, args_at, nargs, ret } => {
                let kont = match ret {
                    KRet::Slot { clos, field } => KontRef::Slot {
                        clos: stack.slots[base + *clos as usize],
                        field: *field,
                    },
                    KRet::Counter { clos } => {
                        KontRef::Counter { clos: stack.slots[base + *clos as usize] }
                    }
                    KRet::Forward => KontRef::Forward,
                };
                let a0 = base + *args_at as usize;
                let args = &stack.slots[a0..a0 + *nargs as usize];
                machine.spawn_child(*callee, args, kont)?;
            }
            KOp::CloseSpawns { clos } => {
                let h = stack.slots[base + *clos as usize];
                machine.close_spawns(h)?;
            }
            KOp::SendArgument { value } => {
                let v = match value {
                    Some(op) => rd(&stack.slots, base, *op).coerce(kernel.ret),
                    None => Value::Unit,
                };
                machine.send_argument(v)?;
            }
            KOp::Jump { target } => {
                steps += 1;
                if steps > stack.limit {
                    bail!("`{}` exceeded step limit (infinite loop?)", kernel.name);
                }
                pc = *target as usize;
            }
            KOp::Branch { cond, then_, else_ } => {
                steps += 1;
                if steps > stack.limit {
                    bail!("`{}` exceeded step limit (infinite loop?)", kernel.name);
                }
                let c = rd(&stack.slots, base, *cond).as_bool();
                pc = if c { *then_ as usize } else { *else_ as usize };
            }
            KOp::Return { value } => {
                return Ok(match value {
                    Some(op) => rd(&stack.slots, base, *op).coerce(kernel.ret),
                    None => Value::Unit,
                });
            }
            KOp::Halt => return Ok(Value::Unit),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arglist_inline_and_heap() {
        let short = ArgList::from_slice(&[Value::I64(1), Value::I64(2)]);
        assert!(matches!(short, ArgList::Inline { .. }));
        assert_eq!(&short[..], &[Value::I64(1), Value::I64(2)]);
        assert_eq!(short.len(), 2);
        let long: Vec<Value> = (0..10).map(Value::I64).collect();
        let heap = ArgList::from_slice(&long);
        assert!(matches!(heap, ArgList::Heap(_)));
        assert_eq!(heap.as_slice(), &long[..]);
        assert_eq!(heap.clone().into_vec(), long);
        let built = ArgList::from_fn(3, |i| Value::I64(i as i64));
        assert_eq!(&built[..], &[Value::I64(0), Value::I64(1), Value::I64(2)]);
    }

    #[test]
    fn kcost_cycles_match_hls_model() {
        let model = ScheduleModel::default();
        // Store with a 1-op index and a 5-op value:
        // store_issue + ceil(1/4) + ceil(5/4) = 3 + 1 + 2 = 6.
        let c = KCost { base: KBase::StoreIssue, exprs: vec![1, 5] };
        assert_eq!(c.cycles(&model), 6);
        let b = KCost { base: KBase::Branch, exprs: vec![] };
        assert_eq!(b.cycles(&model), model.branch);
        let z = KCost { base: KBase::Zero, exprs: vec![0] };
        assert_eq!(z.cycles(&model), 0);
    }

    #[test]
    fn bin_value_matches_tree_eval() {
        use crate::frontend::ast::BinOp;
        use crate::ir::expr::{eval, Expr};
        let cases = [
            (BinOp::Add, Value::I64(3), Value::I64(4)),
            (BinOp::Add, Value::F32(1.5), Value::I64(2)),
            (BinOp::Div, Value::I64(7), Value::I64(0)),
            (BinOp::Rem, Value::I64(7), Value::I64(0)),
            (BinOp::Lt, Value::I64(1), Value::F32(2.0)),
            (BinOp::And, Value::Bool(true), Value::I64(0)),
            (BinOp::Shl, Value::I64(1), Value::I64(65)),
        ];
        for (op, a, b) in cases {
            let tree = Expr::Binary(
                op,
                Box::new(imm_expr(a)),
                Box::new(imm_expr(b)),
            );
            assert_eq!(bin_value(op, a, b), eval(&tree, &|_| Value::Unit), "{op:?}");
        }
    }

    fn imm_expr(v: Value) -> crate::ir::expr::Expr {
        use crate::ir::expr::Expr;
        match v {
            Value::I64(x) => Expr::ConstI(x),
            Value::F32(x) => Expr::ConstF(x),
            Value::Bool(x) => Expr::ConstB(x),
            Value::Unit => Expr::ConstI(0),
        }
    }
}
