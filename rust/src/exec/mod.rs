//! The shared execution-kernel layer.
//!
//! Every execution engine in the repo — the sequential oracle
//! ([`crate::interp::oracle`]), the single-threaded explicit machine
//! ([`crate::interp::explicit_exec`]), the multithreaded work-stealing
//! runtime ([`crate::ws`]) and the cycle simulator ([`crate::sim`]) —
//! used to re-walk `ir::expr::Expr` trees through the recursive
//! `expr::eval` on every op of every task dispatch. This module compiles
//! each function's CFG **once** into a flat, register-based linear
//! bytecode ([`kernel::KernelProgram`]):
//!
//! - operand variable ids pre-resolved to frame slots;
//! - constant subexpressions folded into immediates at compile time (the
//!   one remaining use of the tree-walking `expr::eval`);
//! - builtin calls with their arity fixed (no per-call `Vec`);
//! - branch targets resolved to instruction offsets;
//! - per-instruction cycle-cost / load / effect metadata pre-attached
//!   ([`kernel::KCost`]) so the simulator builds its timed trace from the
//!   same kernel instead of re-tracing trees.
//!
//! The engines differ only in how they realize side effects (memory,
//! closures, spawns, sends) and in what they meter; each implements the
//! [`kernel::Machine`] trait and shares the one interpreter loop
//! ([`kernel::run_kernel`]), which is generic over the machine and
//! monomorphizes per engine.
//!
//! Two further interpreter-level optimizations ride the same loop:
//!
//! - **superinstruction fusion** — a peephole stage after emission
//!   collapses hot adjacent pairs (compare+branch, load/bin+mov,
//!   bin+store, bin+return) into single fused dispatches, with cost
//!   merging rules that keep the simulator's timed traces byte-for-byte
//!   unchanged; gated by `BOMBYX_KERNEL_FUSE=0`
//!   (see [`compile`]);
//! - **direct-threaded dispatch** — every instruction carries a handler
//!   index resolved at kernel-compile time, and the loop indirect-calls
//!   through a per-machine monomorphized handler table instead of
//!   matching on the opcode per retired instruction (see [`kernel`]).
//!
//! Above the interpreter sits an optional **native tier** ([`jit`]):
//! engines that opt in promote hot kernels to runtime-generated x86-64,
//! with the interpreter as the permanent cold tier, bailout target, and
//! differential oracle. The simulator never uses it — `KCost` timing is
//! defined in interpreter dispatch units.
//!
//! Compiled programs are cached per `CompileSession`
//! ([`crate::lower::CompileSession::explicit_kernels`]) behind `Arc`, the
//! same memoized-artifact pattern as `rtl_system`.

pub mod compile;
pub mod jit;
pub mod kernel;

pub use compile::{compile_module, compile_module_with, fuse_enabled};
pub use kernel::{
    is_cmp_op, memo_kernels, opcode_of, run_kernel, ArgList, FuncKernel, KBase, KCost, KInstr,
    KOp, KRet, KStack, KernelMode, KernelProgram, KontRef, Machine, Operand, NO_COST,
};
