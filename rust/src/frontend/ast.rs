//! Abstract syntax tree for Cilk-C (mirrors what Bombyx consumes from the
//! OpenCilk Clang AST — paper Fig. 3, stage 1).

use super::diag::Span;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Type {
    Int,
    Float,
    Bool,
    Void,
}

impl Type {
    pub fn name(self) -> &'static str {
        match self {
            Type::Int => "int",
            Type::Float => "float",
            Type::Bool => "bool",
            Type::Void => "void",
        }
    }

    /// Width in bits when stored in a closure field / memory word.
    pub fn bits(self) -> u32 {
        match self {
            Type::Int => 64,
            Type::Float => 32,
            Type::Bool => 8,
            Type::Void => 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Program {
    pub globals: Vec<GlobalDecl>,
    pub externs: Vec<ExternDecl>,
    pub funcs: Vec<FuncDef>,
}

/// `global int adj[1024];` — a shared memory array (models HBM on FPGA).
#[derive(Clone, Debug)]
pub struct GlobalDecl {
    pub name: String,
    pub ty: Type,
    /// Declared element count. `global int a[];` leaves it to the driver.
    pub size: Option<u64>,
    pub span: Span,
}

/// `extern xla int relax(int n);` — a task type executed by the AOT-compiled
/// XLA PE datapath instead of a scalar PE (DESIGN.md §Hardware-Adaptation).
#[derive(Clone, Debug)]
pub struct ExternDecl {
    pub name: String,
    pub ret: Type,
    pub params: Vec<Param>,
    pub span: Span,
}

#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub ty: Type,
    pub span: Span,
}

#[derive(Clone, Debug)]
pub struct FuncDef {
    pub name: String,
    pub ret: Type,
    pub params: Vec<Param>,
    pub body: Block,
    pub span: Span,
}

#[derive(Clone, Debug, Default)]
pub struct Block {
    pub stmts: Vec<Stmt>,
}

#[derive(Clone, Debug)]
pub struct Stmt {
    pub kind: StmtKind,
    /// `#pragma bombyx dae` attached to this statement.
    pub dae: bool,
    pub span: Span,
}

#[derive(Clone, Debug)]
pub enum StmtKind {
    /// `int x = <init>;` (init optional → zero-initialized)
    Decl { ty: Type, name: String, init: Option<Initializer> },
    /// `x = <init>;`
    Assign { name: String, value: Initializer },
    /// `arr[idx] = value;` — store to a global array.
    Store { arr: String, index: Expr, value: Expr },
    /// `cilk_spawn f(args);` — child result (if any) is discarded, but the
    /// spawn still participates in the enclosing sync.
    VoidSpawn(Call),
    /// `cilk_sync;`
    Sync,
    If { cond: Expr, then: Box<Stmt>, els: Option<Box<Stmt>> },
    While { cond: Expr, body: Box<Stmt> },
    For {
        init: Option<Box<Stmt>>,
        cond: Option<Expr>,
        step: Option<Box<Stmt>>,
        body: Box<Stmt>,
    },
    Return(Option<Expr>),
    /// `f(args);` — statement-level call (leaf function or builtin such as
    /// `atomic_add`).
    ExprCall(Call),
    Block(Block),
}

/// RHS of a declaration or assignment.
#[derive(Clone, Debug)]
pub enum Initializer {
    Expr(Expr),
    /// `cilk_spawn f(args)` — value-producing spawn.
    Spawn(Call),
    /// Direct (sequential) call to a leaf function: `x = helper(a, b);`
    Call(Call),
}

#[derive(Clone, Debug)]
pub struct Call {
    pub name: String,
    pub args: Vec<Expr>,
    pub span: Span,
}

#[derive(Clone, Debug)]
pub struct Expr {
    pub kind: ExprKind,
    pub span: Span,
}

#[derive(Clone, Debug)]
pub enum ExprKind {
    IntLit(i64),
    FloatLit(f32),
    BoolLit(bool),
    Var(String),
    /// `arr[idx]` — load from a global array. This is *the* memory-access
    /// primitive the DAE optimization targets.
    Load { arr: String, index: Box<Expr> },
    /// Pure builtin call inside an expression (`min`, `max`, `abs`).
    Builtin { name: String, args: Vec<Expr> },
    Binary { op: BinOp, lhs: Box<Expr>, rhs: Box<Expr> },
    Unary { op: UnOp, operand: Box<Expr> },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    Shl,
    Shr,
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
    And, // logical &&
    Or,  // logical ||
    BitAnd,
    BitOr,
    BitXor,
}

impl BinOp {
    pub fn symbol(self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
            BinOp::Rem => "%",
            BinOp::Shl => "<<",
            BinOp::Shr => ">>",
            BinOp::Lt => "<",
            BinOp::Le => "<=",
            BinOp::Gt => ">",
            BinOp::Ge => ">=",
            BinOp::Eq => "==",
            BinOp::Ne => "!=",
            BinOp::And => "&&",
            BinOp::Or => "||",
            BinOp::BitAnd => "&",
            BinOp::BitOr => "|",
            BinOp::BitXor => "^",
        }
    }

    pub fn is_comparison(self) -> bool {
        matches!(self, BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge | BinOp::Eq | BinOp::Ne)
    }

    pub fn is_logical(self) -> bool {
        matches!(self, BinOp::And | BinOp::Or)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnOp {
    Neg,
    Not,
}

/// Names of expression-level builtins.
pub const EXPR_BUILTINS: &[&str] = &["min", "max", "abs"];
/// Names of statement-level builtins.
pub const STMT_BUILTINS: &[&str] = &["atomic_add"];

pub fn is_expr_builtin(name: &str) -> bool {
    EXPR_BUILTINS.contains(&name)
}

pub fn is_stmt_builtin(name: &str) -> bool {
    STMT_BUILTINS.contains(&name)
}
