//! Source management and diagnostics with byte-span → line/column rendering.

/// A byte range into the source text.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct Span {
    pub start: u32,
    pub end: u32,
}

impl Span {
    pub fn new(start: usize, end: usize) -> Span {
        Span { start: start as u32, end: end as u32 }
    }

    pub fn join(self, other: Span) -> Span {
        Span { start: self.start.min(other.start), end: self.end.max(other.end) }
    }
}

/// A named source file with precomputed line starts.
#[derive(Clone, Debug)]
pub struct Source {
    pub name: String,
    pub text: String,
    line_starts: Vec<u32>,
}

impl Source {
    pub fn new(name: &str, text: &str) -> Source {
        let mut line_starts = vec![0u32];
        for (i, b) in text.bytes().enumerate() {
            if b == b'\n' {
                line_starts.push(i as u32 + 1);
            }
        }
        Source { name: name.to_string(), text: text.to_string(), line_starts }
    }

    /// 1-based (line, column) of a byte offset.
    pub fn line_col(&self, offset: u32) -> (usize, usize) {
        let line = match self.line_starts.binary_search(&offset) {
            Ok(idx) => idx,
            Err(idx) => idx - 1,
        };
        let col = offset - self.line_starts[line];
        (line + 1, col as usize + 1)
    }

    /// The text of a 1-based line, without trailing newline.
    pub fn line_text(&self, line: usize) -> &str {
        let start = self.line_starts[line - 1] as usize;
        let end = self
            .line_starts
            .get(line)
            .map(|&e| e as usize)
            .unwrap_or(self.text.len());
        self.text[start..end].trim_end_matches('\n')
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

/// A compiler diagnostic tied to a span.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub severity: Severity,
    pub message: String,
    pub span: Span,
}

impl Diagnostic {
    pub fn error(message: impl Into<String>, span: Span) -> Diagnostic {
        Diagnostic { severity: Severity::Error, message: message.into(), span }
    }

    pub fn warning(message: impl Into<String>, span: Span) -> Diagnostic {
        Diagnostic { severity: Severity::Warning, message: message.into(), span }
    }

    /// Render with a source snippet and caret underline.
    pub fn render(&self, source: &Source) -> String {
        let (line, col) = source.line_col(self.span.start);
        let sev = match self.severity {
            Severity::Error => "error",
            Severity::Warning => "warning",
        };
        let line_text = source.line_text(line);
        let width = ((self.span.end.saturating_sub(self.span.start)) as usize).max(1);
        let caret_width = width.min(line_text.len().saturating_sub(col - 1).max(1));
        format!(
            "{sev}: {msg}\n  --> {name}:{line}:{col}\n   |\n   | {line_text}\n   | {pad}{carets}",
            msg = self.message,
            name = source.name,
            pad = " ".repeat(col - 1),
            carets = "^".repeat(caret_width),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_col_mapping() {
        let s = Source::new("t.cilk", "abc\ndef\n\nx");
        assert_eq!(s.line_col(0), (1, 1));
        assert_eq!(s.line_col(2), (1, 3));
        assert_eq!(s.line_col(4), (2, 1));
        assert_eq!(s.line_col(8), (3, 1)); // the empty line
        assert_eq!(s.line_col(9), (4, 1));
    }

    #[test]
    fn line_text_extraction() {
        let s = Source::new("t", "first\nsecond\nthird");
        assert_eq!(s.line_text(1), "first");
        assert_eq!(s.line_text(2), "second");
        assert_eq!(s.line_text(3), "third");
    }

    #[test]
    fn render_has_caret() {
        let s = Source::new("t.cilk", "int x = $;");
        let d = Diagnostic::error("unexpected character", Span::new(8, 9));
        let r = d.render(&s);
        assert!(r.contains("t.cilk:1:9"));
        assert!(r.contains("int x = $;"));
        assert!(r.lines().last().unwrap().trim_end().ends_with('^'));
    }
}
