//! Hand-written lexer for Cilk-C.

use super::diag::{Diagnostic, Span};
use super::token::{Tok, Token};

pub fn lex(text: &str) -> Result<Vec<Token>, Diagnostic> {
    Lexer { bytes: text.as_bytes(), pos: 0 }.run()
}

struct Lexer<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Lexer<'a> {
    fn run(mut self) -> Result<Vec<Token>, Diagnostic> {
        let mut out = Vec::new();
        loop {
            self.skip_trivia()?;
            let start = self.pos;
            let Some(b) = self.peek() else {
                out.push(Token { tok: Tok::Eof, span: Span::new(start, start) });
                return Ok(out);
            };
            let tok = match b {
                b'#' => self.lex_pragma()?,
                b'0'..=b'9' => self.lex_number()?,
                b'a'..=b'z' | b'A'..=b'Z' | b'_' => self.lex_word(),
                _ => self.lex_punct()?,
            };
            out.push(Token { tok, span: Span::new(start, self.pos) });
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn peek2(&self) -> Option<u8> {
        self.bytes.get(self.pos + 1).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_trivia(&mut self) -> Result<(), Diagnostic> {
        loop {
            match self.peek() {
                Some(b' ' | b'\t' | b'\r' | b'\n') => {
                    self.pos += 1;
                }
                Some(b'/') if self.peek2() == Some(b'/') => {
                    while let Some(b) = self.peek() {
                        if b == b'\n' {
                            break;
                        }
                        self.pos += 1;
                    }
                }
                Some(b'/') if self.peek2() == Some(b'*') => {
                    let start = self.pos;
                    self.pos += 2;
                    loop {
                        match (self.peek(), self.peek2()) {
                            (Some(b'*'), Some(b'/')) => {
                                self.pos += 2;
                                break;
                            }
                            (Some(_), _) => self.pos += 1,
                            (None, _) => {
                                return Err(Diagnostic::error(
                                    "unterminated block comment",
                                    Span::new(start, self.pos),
                                ))
                            }
                        }
                    }
                }
                _ => return Ok(()),
            }
        }
    }

    /// `#pragma bombyx dae` — whole directive becomes one token. Unknown
    /// pragmas are an error (silently ignoring optimization pragmas is how
    /// performance bugs hide).
    fn lex_pragma(&mut self) -> Result<Tok, Diagnostic> {
        let start = self.pos;
        let line_end = self.bytes[self.pos..]
            .iter()
            .position(|&b| b == b'\n')
            .map(|i| self.pos + i)
            .unwrap_or(self.bytes.len());
        let line = std::str::from_utf8(&self.bytes[self.pos..line_end]).unwrap_or("");
        let words: Vec<&str> = line.split_whitespace().collect();
        let ok = (words.first() == Some(&"#pragma")
            && words.get(1) == Some(&"bombyx")
            && words.get(2) == Some(&"dae")
            && words.len() == 3)
            || (words.first() == Some(&"#PRAGMA")
                && words.get(1) == Some(&"BOMBYX")
                && words.get(2) == Some(&"DAE")
                && words.len() == 3);
        if !ok {
            return Err(Diagnostic::error(
                format!("unknown pragma `{line}` (expected `#pragma bombyx dae`)"),
                Span::new(start, line_end),
            ));
        }
        self.pos = line_end;
        Ok(Tok::PragmaDae)
    }

    fn lex_number(&mut self) -> Result<Tok, Diagnostic> {
        let start = self.pos;
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') && matches!(self.peek2(), Some(b'0'..=b'9')) {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if self.peek() == Some(b'f') {
            is_float = true;
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .unwrap()
            .trim_end_matches('f');
        if is_float {
            text.parse::<f32>()
                .map(Tok::Float)
                .map_err(|e| Diagnostic::error(format!("bad float literal: {e}"), Span::new(start, self.pos)))
        } else {
            text.parse::<i64>()
                .map(Tok::Int)
                .map_err(|e| Diagnostic::error(format!("bad integer literal: {e}"), Span::new(start, self.pos)))
        }
    }

    fn lex_word(&mut self) -> Tok {
        let start = self.pos;
        while matches!(self.peek(), Some(b'a'..=b'z' | b'A'..=b'Z' | b'0'..=b'9' | b'_')) {
            self.pos += 1;
        }
        let word = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match word {
            "int" => Tok::KwInt,
            "float" => Tok::KwFloat,
            "bool" => Tok::KwBool,
            "void" => Tok::KwVoid,
            "global" => Tok::KwGlobal,
            "extern" => Tok::KwExtern,
            "xla" => Tok::KwXla,
            "if" => Tok::KwIf,
            "else" => Tok::KwElse,
            "while" => Tok::KwWhile,
            "for" => Tok::KwFor,
            "return" => Tok::KwReturn,
            "true" => Tok::KwTrue,
            "false" => Tok::KwFalse,
            "cilk_spawn" => Tok::KwSpawn,
            "cilk_sync" => Tok::KwSync,
            _ => Tok::Ident(word.to_string()),
        }
    }

    fn lex_punct(&mut self) -> Result<Tok, Diagnostic> {
        let start = self.pos;
        let b = self.bump().unwrap();
        let tok = match b {
            b'(' => Tok::LParen,
            b')' => Tok::RParen,
            b'{' => Tok::LBrace,
            b'}' => Tok::RBrace,
            b'[' => Tok::LBracket,
            b']' => Tok::RBracket,
            b';' => Tok::Semi,
            b',' => Tok::Comma,
            b'+' => Tok::Plus,
            b'-' => Tok::Minus,
            b'*' => Tok::Star,
            b'/' => Tok::Slash,
            b'%' => Tok::Percent,
            b'^' => Tok::Caret,
            b'=' => {
                if self.peek() == Some(b'=') {
                    self.pos += 1;
                    Tok::EqEq
                } else {
                    Tok::Assign
                }
            }
            b'!' => {
                if self.peek() == Some(b'=') {
                    self.pos += 1;
                    Tok::NotEq
                } else {
                    Tok::Not
                }
            }
            b'<' => match self.peek() {
                Some(b'=') => {
                    self.pos += 1;
                    Tok::Le
                }
                Some(b'<') => {
                    self.pos += 1;
                    Tok::Shl
                }
                _ => Tok::Lt,
            },
            b'>' => match self.peek() {
                Some(b'=') => {
                    self.pos += 1;
                    Tok::Ge
                }
                Some(b'>') => {
                    self.pos += 1;
                    Tok::Shr
                }
                _ => Tok::Gt,
            },
            b'&' => {
                if self.peek() == Some(b'&') {
                    self.pos += 1;
                    Tok::AndAnd
                } else {
                    Tok::Amp
                }
            }
            b'|' => {
                if self.peek() == Some(b'|') {
                    self.pos += 1;
                    Tok::OrOr
                } else {
                    Tok::Pipe
                }
            }
            _ => {
                return Err(Diagnostic::error(
                    format!("unexpected character `{}`", b as char),
                    Span::new(start, self.pos),
                ))
            }
        };
        Ok(tok)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(text: &str) -> Vec<Tok> {
        lex(text).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn keywords_and_idents() {
        assert_eq!(
            toks("int fib cilk_spawn cilk_sync xfib"),
            vec![
                Tok::KwInt,
                Tok::Ident("fib".into()),
                Tok::KwSpawn,
                Tok::KwSync,
                Tok::Ident("xfib".into()),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn numbers() {
        assert_eq!(
            toks("42 3.5 1e3 2.5f"),
            vec![Tok::Int(42), Tok::Float(3.5), Tok::Float(1000.0), Tok::Float(2.5), Tok::Eof]
        );
    }

    #[test]
    fn two_char_operators() {
        assert_eq!(
            toks("<= >= == != && || << >> < >"),
            vec![
                Tok::Le,
                Tok::Ge,
                Tok::EqEq,
                Tok::NotEq,
                Tok::AndAnd,
                Tok::OrOr,
                Tok::Shl,
                Tok::Shr,
                Tok::Lt,
                Tok::Gt,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn comments_skipped() {
        assert_eq!(
            toks("a // line\n /* block\n spans */ b"),
            vec![Tok::Ident("a".into()), Tok::Ident("b".into()), Tok::Eof]
        );
    }

    #[test]
    fn pragma_dae() {
        assert_eq!(toks("#pragma bombyx dae\nx"), vec![Tok::PragmaDae, Tok::Ident("x".into()), Tok::Eof]);
        // Paper's spelling from §III.
        assert_eq!(toks("#PRAGMA BOMBYX DAE\n"), vec![Tok::PragmaDae, Tok::Eof]);
    }

    #[test]
    fn unknown_pragma_rejected() {
        assert!(lex("#pragma unroll 4\n").is_err());
    }

    #[test]
    fn unexpected_char_rejected() {
        let err = lex("int $x;").unwrap_err();
        assert!(err.message.contains("unexpected character"));
    }

    #[test]
    fn spans_cover_tokens() {
        let tokens = lex("ab + cd").unwrap();
        assert_eq!(tokens[0].span, Span::new(0, 2));
        assert_eq!(tokens[1].span, Span::new(3, 4));
        assert_eq!(tokens[2].span, Span::new(5, 7));
    }

    #[test]
    fn unterminated_block_comment() {
        assert!(lex("/* never ends").is_err());
    }
}
