//! Cilk-C frontend.
//!
//! The paper consumes OpenCilk C/C++ through the OpenCilk Clang AST. That
//! frontend is a multi-megaline dependency we cannot (and need not) vendor;
//! what Bombyx actually requires is an AST for the task-parallel kernel
//! functions. **Cilk-C** is a C subset with exactly the constructs the
//! paper's examples use:
//!
//! - scalar types `int` (i64), `float` (f32), `bool`, `void`
//! - `global <ty> name[size];` — shared memory arrays (the FPGA's HBM)
//! - functions, `if`/`else`, `while`, `for`, `return`, blocks
//! - `cilk_spawn f(args)` (value or void), `cilk_sync`
//! - `extern xla <ty> f(params);` — a task type whose body is the AOT
//!   XLA-compiled numeric PE datapath (see DESIGN.md §Hardware-Adaptation)
//! - `#pragma bombyx dae` — the paper's decoupled access-execute pragma
//! - statement-level builtins: `atomic_add(arr, idx, val)`,
//!   expression builtins: `min`, `max`, `abs`
//!
//! Pipeline: [`lexer`] → [`parser`] → [`sema`] → `crate::lower::ast_to_cfg`.

pub mod ast;
pub mod diag;
pub mod lexer;
pub mod parser;
pub mod sema;
pub mod token;

pub use ast::Program;
pub use diag::{Diagnostic, Source};

use anyhow::{bail, Result};

/// Parse and semantically check a Cilk-C compilation unit.
pub fn parse_and_check(name: &str, text: &str) -> Result<(Program, Source)> {
    let source = Source::new(name, text);
    let tokens = match lexer::lex(text) {
        Ok(t) => t,
        Err(d) => bail!("{}", d.render(&source)),
    };
    let program = match parser::parse(tokens) {
        Ok(p) => p,
        Err(d) => bail!("{}", d.render(&source)),
    };
    let diags = sema::check(&program);
    if !diags.is_empty() {
        let rendered: Vec<String> = diags.iter().map(|d| d.render(&source)).collect();
        bail!("{}", rendered.join("\n"));
    }
    Ok((program, source))
}
