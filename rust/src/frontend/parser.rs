//! Recursive-descent parser for Cilk-C.

use super::ast::*;
use super::diag::{Diagnostic, Span};
use super::token::{Tok, Token};

pub fn parse(tokens: Vec<Token>) -> Result<Program, Diagnostic> {
    Parser { tokens, pos: 0 }.program()
}

struct Parser {
    tokens: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.tokens[self.pos].tok
    }

    fn peek_at(&self, offset: usize) -> &Tok {
        let idx = (self.pos + offset).min(self.tokens.len() - 1);
        &self.tokens[idx].tok
    }

    fn span(&self) -> Span {
        self.tokens[self.pos].span
    }

    fn prev_span(&self) -> Span {
        self.tokens[self.pos.saturating_sub(1)].span
    }

    fn bump(&mut self) -> Tok {
        let t = self.tokens[self.pos].tok.clone();
        if self.pos + 1 < self.tokens.len() {
            self.pos += 1;
        }
        t
    }

    fn eat(&mut self, expected: &Tok) -> Result<(), Diagnostic> {
        if self.peek() == expected {
            self.bump();
            Ok(())
        } else {
            Err(Diagnostic::error(
                format!("expected {}, found {}", expected.describe(), self.peek().describe()),
                self.span(),
            ))
        }
    }

    fn eat_ident(&mut self) -> Result<(String, Span), Diagnostic> {
        let span = self.span();
        match self.bump() {
            Tok::Ident(name) => Ok((name, span)),
            other => Err(Diagnostic::error(
                format!("expected identifier, found {}", other.describe()),
                span,
            )),
        }
    }

    fn try_type(&mut self) -> Option<Type> {
        let ty = match self.peek() {
            Tok::KwInt => Type::Int,
            Tok::KwFloat => Type::Float,
            Tok::KwBool => Type::Bool,
            Tok::KwVoid => Type::Void,
            _ => return None,
        };
        self.bump();
        Some(ty)
    }

    fn eat_type(&mut self) -> Result<Type, Diagnostic> {
        let span = self.span();
        let found = self.peek().describe();
        self.try_type()
            .ok_or_else(|| Diagnostic::error(format!("expected a type, found {found}"), span))
    }

    // ---- items -----------------------------------------------------------

    fn program(&mut self) -> Result<Program, Diagnostic> {
        let mut program = Program { globals: Vec::new(), externs: Vec::new(), funcs: Vec::new() };
        while *self.peek() != Tok::Eof {
            match self.peek() {
                Tok::KwGlobal => program.globals.push(self.global_decl()?),
                Tok::KwExtern => program.externs.push(self.extern_decl()?),
                _ => program.funcs.push(self.func_def()?),
            }
        }
        Ok(program)
    }

    fn global_decl(&mut self) -> Result<GlobalDecl, Diagnostic> {
        let start = self.span();
        self.eat(&Tok::KwGlobal)?;
        let ty = self.eat_type()?;
        let (name, _) = self.eat_ident()?;
        self.eat(&Tok::LBracket)?;
        let size = match self.peek() {
            Tok::Int(v) => {
                let v = *v;
                if v < 0 {
                    return Err(Diagnostic::error("global array size must be non-negative", self.span()));
                }
                self.bump();
                Some(v as u64)
            }
            _ => None,
        };
        self.eat(&Tok::RBracket)?;
        self.eat(&Tok::Semi)?;
        Ok(GlobalDecl { name, ty, size, span: start.join(self.prev_span()) })
    }

    fn extern_decl(&mut self) -> Result<ExternDecl, Diagnostic> {
        let start = self.span();
        self.eat(&Tok::KwExtern)?;
        self.eat(&Tok::KwXla)?;
        let ret = self.eat_type()?;
        let (name, _) = self.eat_ident()?;
        let params = self.param_list()?;
        self.eat(&Tok::Semi)?;
        Ok(ExternDecl { name, ret, params, span: start.join(self.prev_span()) })
    }

    fn func_def(&mut self) -> Result<FuncDef, Diagnostic> {
        let start = self.span();
        let ret = self.eat_type().map_err(|_| {
            Diagnostic::error(
                format!(
                    "expected `global`, `extern`, or a function definition; found {}",
                    self.peek().describe()
                ),
                self.span(),
            )
        })?;
        let (name, _) = self.eat_ident()?;
        let params = self.param_list()?;
        let body = self.block()?;
        Ok(FuncDef { name, ret, params, body, span: start.join(self.prev_span()) })
    }

    fn param_list(&mut self) -> Result<Vec<Param>, Diagnostic> {
        self.eat(&Tok::LParen)?;
        let mut params = Vec::new();
        if *self.peek() != Tok::RParen {
            loop {
                let start = self.span();
                let ty = self.eat_type()?;
                let (name, _) = self.eat_ident()?;
                params.push(Param { name, ty, span: start.join(self.prev_span()) });
                if *self.peek() == Tok::Comma {
                    self.bump();
                } else {
                    break;
                }
            }
        }
        self.eat(&Tok::RParen)?;
        Ok(params)
    }

    // ---- statements ------------------------------------------------------

    fn block(&mut self) -> Result<Block, Diagnostic> {
        self.eat(&Tok::LBrace)?;
        let mut stmts = Vec::new();
        while *self.peek() != Tok::RBrace {
            if *self.peek() == Tok::Eof {
                return Err(Diagnostic::error("unterminated block (missing `}`)", self.span()));
            }
            stmts.push(self.stmt()?);
        }
        self.eat(&Tok::RBrace)?;
        Ok(Block { stmts })
    }

    fn stmt(&mut self) -> Result<Stmt, Diagnostic> {
        let mut dae = false;
        let start = self.span();
        while *self.peek() == Tok::PragmaDae {
            dae = true;
            self.bump();
        }
        let mut stmt = self.base_stmt()?;
        stmt.dae = dae;
        stmt.span = start.join(stmt.span);
        Ok(stmt)
    }

    fn base_stmt(&mut self) -> Result<Stmt, Diagnostic> {
        let start = self.span();
        let kind = match self.peek().clone() {
            Tok::LBrace => StmtKind::Block(self.block()?),
            Tok::KwSync => {
                self.bump();
                self.eat(&Tok::Semi)?;
                StmtKind::Sync
            }
            Tok::KwReturn => {
                self.bump();
                let value = if *self.peek() == Tok::Semi { None } else { Some(self.expr()?) };
                self.eat(&Tok::Semi)?;
                StmtKind::Return(value)
            }
            Tok::KwIf => {
                self.bump();
                self.eat(&Tok::LParen)?;
                let cond = self.expr()?;
                self.eat(&Tok::RParen)?;
                let then = Box::new(self.stmt()?);
                let els = if *self.peek() == Tok::KwElse {
                    self.bump();
                    Some(Box::new(self.stmt()?))
                } else {
                    None
                };
                StmtKind::If { cond, then, els }
            }
            Tok::KwWhile => {
                self.bump();
                self.eat(&Tok::LParen)?;
                let cond = self.expr()?;
                self.eat(&Tok::RParen)?;
                let body = Box::new(self.stmt()?);
                StmtKind::While { cond, body }
            }
            Tok::KwFor => {
                self.bump();
                self.eat(&Tok::LParen)?;
                let init = if *self.peek() == Tok::Semi {
                    self.bump();
                    None
                } else {
                    Some(Box::new(self.simple_stmt()?))
                };
                let cond = if *self.peek() == Tok::Semi { None } else { Some(self.expr()?) };
                self.eat(&Tok::Semi)?;
                let step = if *self.peek() == Tok::RParen {
                    None
                } else {
                    Some(Box::new(self.simple_stmt_no_semi()?))
                };
                self.eat(&Tok::RParen)?;
                let body = Box::new(self.stmt()?);
                StmtKind::For { init, cond, step, body }
            }
            Tok::KwSpawn => {
                self.bump();
                let call = self.call_after_name()?;
                self.eat(&Tok::Semi)?;
                StmtKind::VoidSpawn(call)
            }
            Tok::KwInt | Tok::KwFloat | Tok::KwBool => {
                let ty = self.try_type().unwrap();
                let (name, _) = self.eat_ident()?;
                let init = if *self.peek() == Tok::Assign {
                    self.bump();
                    Some(self.initializer()?)
                } else {
                    None
                };
                self.eat(&Tok::Semi)?;
                StmtKind::Decl { ty, name, init }
            }
            Tok::Ident(_) => {
                let kind = self.assign_or_call()?;
                self.eat(&Tok::Semi)?;
                kind
            }
            other => {
                return Err(Diagnostic::error(
                    format!("expected a statement, found {}", other.describe()),
                    start,
                ))
            }
        };
        Ok(Stmt { kind, dae: false, span: start.join(self.prev_span()) })
    }

    /// A statement allowed in `for` init position (declaration or
    /// assignment), consuming the trailing `;`.
    fn simple_stmt(&mut self) -> Result<Stmt, Diagnostic> {
        let start = self.span();
        let kind = match self.peek() {
            Tok::KwInt | Tok::KwFloat | Tok::KwBool => {
                let ty = self.try_type().unwrap();
                let (name, _) = self.eat_ident()?;
                self.eat(&Tok::Assign)?;
                let init = Some(self.initializer()?);
                StmtKind::Decl { ty, name, init }
            }
            _ => self.assign_or_call()?,
        };
        self.eat(&Tok::Semi)?;
        Ok(Stmt { kind, dae: false, span: start.join(self.prev_span()) })
    }

    /// `for` step position: assignment or call without `;`.
    fn simple_stmt_no_semi(&mut self) -> Result<Stmt, Diagnostic> {
        let start = self.span();
        let kind = self.assign_or_call()?;
        Ok(Stmt { kind, dae: false, span: start.join(self.prev_span()) })
    }

    /// Disambiguate `x = ...`, `arr[i] = ...`, `f(...)` after seeing an
    /// identifier.
    fn assign_or_call(&mut self) -> Result<StmtKind, Diagnostic> {
        let (name, name_span) = self.eat_ident()?;
        match self.peek() {
            Tok::Assign => {
                self.bump();
                let value = self.initializer()?;
                Ok(StmtKind::Assign { name, value })
            }
            Tok::LBracket => {
                self.bump();
                let index = self.expr()?;
                self.eat(&Tok::RBracket)?;
                self.eat(&Tok::Assign)?;
                let value = self.expr()?;
                Ok(StmtKind::Store { arr: name, index, value })
            }
            Tok::LParen => {
                let args = self.arg_list()?;
                Ok(StmtKind::ExprCall(Call {
                    name,
                    args,
                    span: name_span.join(self.prev_span()),
                }))
            }
            other => Err(Diagnostic::error(
                format!("expected `=`, `[`, or `(` after identifier, found {}", other.describe()),
                self.span(),
            )),
        }
    }

    fn initializer(&mut self) -> Result<Initializer, Diagnostic> {
        if *self.peek() == Tok::KwSpawn {
            self.bump();
            let call = self.call_after_name()?;
            return Ok(Initializer::Spawn(call));
        }
        // `x = f(a, b);` where f is a user function → Initializer::Call;
        // builtins stay in the expression grammar.
        if let Tok::Ident(name) = self.peek().clone() {
            if *self.peek_at(1) == Tok::LParen && !is_expr_builtin(&name) {
                let (name, name_span) = self.eat_ident()?;
                let args = self.arg_list()?;
                let call_span = name_span.join(self.prev_span());
                if self.peek_binop().is_some() {
                    return Err(Diagnostic::error(
                        format!(
                            "function call `{name}(...)` is not allowed inside an expression; \
                             only builtins {EXPR_BUILTINS:?} are. Assign it to a variable \
                             first (`int t = {name}(...);`)"
                        ),
                        call_span,
                    ));
                }
                return Ok(Initializer::Call(Call { name, args, span: call_span }));
            }
        }
        Ok(Initializer::Expr(self.expr()?))
    }

    fn call_after_name(&mut self) -> Result<Call, Diagnostic> {
        let (name, name_span) = self.eat_ident()?;
        let args = self.arg_list()?;
        Ok(Call { name, args, span: name_span.join(self.prev_span()) })
    }

    fn arg_list(&mut self) -> Result<Vec<Expr>, Diagnostic> {
        self.eat(&Tok::LParen)?;
        let mut args = Vec::new();
        if *self.peek() != Tok::RParen {
            loop {
                args.push(self.expr()?);
                if *self.peek() == Tok::Comma {
                    self.bump();
                } else {
                    break;
                }
            }
        }
        self.eat(&Tok::RParen)?;
        Ok(args)
    }

    // ---- expressions (precedence climbing) --------------------------------

    fn expr(&mut self) -> Result<Expr, Diagnostic> {
        self.binary_expr(0)
    }

    fn binary_expr(&mut self, min_prec: u8) -> Result<Expr, Diagnostic> {
        let mut lhs = self.unary_expr()?;
        loop {
            let Some((op, prec)) = self.peek_binop() else { break };
            if prec < min_prec {
                break;
            }
            self.bump();
            let rhs = self.binary_expr(prec + 1)?;
            let span = lhs.span.join(rhs.span);
            lhs = Expr {
                kind: ExprKind::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) },
                span,
            };
        }
        Ok(lhs)
    }

    fn peek_binop(&self) -> Option<(BinOp, u8)> {
        let (op, prec) = match self.peek() {
            Tok::OrOr => (BinOp::Or, 1),
            Tok::AndAnd => (BinOp::And, 2),
            Tok::Pipe => (BinOp::BitOr, 3),
            Tok::Caret => (BinOp::BitXor, 4),
            Tok::Amp => (BinOp::BitAnd, 5),
            Tok::EqEq => (BinOp::Eq, 6),
            Tok::NotEq => (BinOp::Ne, 6),
            Tok::Lt => (BinOp::Lt, 7),
            Tok::Le => (BinOp::Le, 7),
            Tok::Gt => (BinOp::Gt, 7),
            Tok::Ge => (BinOp::Ge, 7),
            Tok::Shl => (BinOp::Shl, 8),
            Tok::Shr => (BinOp::Shr, 8),
            Tok::Plus => (BinOp::Add, 9),
            Tok::Minus => (BinOp::Sub, 9),
            Tok::Star => (BinOp::Mul, 10),
            Tok::Slash => (BinOp::Div, 10),
            Tok::Percent => (BinOp::Rem, 10),
            _ => return None,
        };
        Some((op, prec))
    }

    fn unary_expr(&mut self) -> Result<Expr, Diagnostic> {
        let start = self.span();
        match self.peek() {
            Tok::Minus => {
                self.bump();
                let operand = self.unary_expr()?;
                let span = start.join(operand.span);
                Ok(Expr { kind: ExprKind::Unary { op: UnOp::Neg, operand: Box::new(operand) }, span })
            }
            Tok::Not => {
                self.bump();
                let operand = self.unary_expr()?;
                let span = start.join(operand.span);
                Ok(Expr { kind: ExprKind::Unary { op: UnOp::Not, operand: Box::new(operand) }, span })
            }
            _ => self.primary_expr(),
        }
    }

    fn primary_expr(&mut self) -> Result<Expr, Diagnostic> {
        let start = self.span();
        let kind = match self.bump() {
            Tok::Int(v) => ExprKind::IntLit(v),
            Tok::Float(v) => ExprKind::FloatLit(v),
            Tok::KwTrue => ExprKind::BoolLit(true),
            Tok::KwFalse => ExprKind::BoolLit(false),
            Tok::LParen => {
                let inner = self.expr()?;
                self.eat(&Tok::RParen)?;
                return Ok(Expr { kind: inner.kind, span: start.join(self.prev_span()) });
            }
            Tok::Ident(name) => match self.peek() {
                Tok::LBracket => {
                    self.bump();
                    let index = self.expr()?;
                    self.eat(&Tok::RBracket)?;
                    ExprKind::Load { arr: name, index: Box::new(index) }
                }
                Tok::LParen => {
                    if !is_expr_builtin(&name) {
                        return Err(Diagnostic::error(
                            format!(
                                "function call `{name}(...)` is not allowed inside an \
                                 expression; only builtins {EXPR_BUILTINS:?} are. Assign it \
                                 to a variable first (`int t = {name}(...);`)"
                            ),
                            start,
                        ));
                    }
                    let args = self.arg_list()?;
                    ExprKind::Builtin { name, args }
                }
                _ => ExprKind::Var(name),
            },
            other => {
                return Err(Diagnostic::error(
                    format!("expected an expression, found {}", other.describe()),
                    start,
                ))
            }
        };
        Ok(Expr { kind, span: start.join(self.prev_span()) })
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn parse_ok(text: &str) -> Program {
        parse(lex(text).unwrap()).unwrap_or_else(|d| panic!("{}", d.message))
    }

    fn parse_err(text: &str) -> Diagnostic {
        parse(lex(text).unwrap()).unwrap_err()
    }

    const FIB: &str = "
        int fib(int n) {
            if (n < 2)
                return n;
            int x = cilk_spawn fib(n - 1);
            int y = cilk_spawn fib(n - 2);
            cilk_sync;
            return x + y;
        }
    ";

    #[test]
    fn parses_paper_fig1_fib() {
        let p = parse_ok(FIB);
        assert_eq!(p.funcs.len(), 1);
        let f = &p.funcs[0];
        assert_eq!(f.name, "fib");
        assert_eq!(f.ret, Type::Int);
        assert_eq!(f.params.len(), 1);
        assert_eq!(f.body.stmts.len(), 5);
        assert!(matches!(f.body.stmts[0].kind, StmtKind::If { .. }));
        assert!(matches!(
            f.body.stmts[1].kind,
            StmtKind::Decl { init: Some(Initializer::Spawn(_)), .. }
        ));
        assert!(matches!(f.body.stmts[2].kind, StmtKind::Decl { .. }));
        assert!(matches!(f.body.stmts[3].kind, StmtKind::Sync));
        assert!(matches!(f.body.stmts[4].kind, StmtKind::Return(Some(_))));
    }

    #[test]
    fn parses_globals_and_externs() {
        let p = parse_ok(
            "global int adj[1024];
             global float feat[];
             extern xla int relax(int n);
             void f(int n) { return; }",
        );
        assert_eq!(p.globals.len(), 2);
        assert_eq!(p.globals[0].size, Some(1024));
        assert_eq!(p.globals[1].size, None);
        assert_eq!(p.externs.len(), 1);
        assert_eq!(p.externs[0].name, "relax");
    }

    #[test]
    fn parses_bfs_shape_with_pragma() {
        let p = parse_ok(
            "global int adj_off[];
             global int adj_edges[];
             global int visited[];
             void visit(int n) {
                 #pragma bombyx dae
                 int off = adj_off[n];
                 int end = adj_off[n + 1];
                 visited[n] = 1;
                 for (int i = off; i < end; i = i + 1) {
                     cilk_spawn visit(adj_edges[i]);
                 }
                 cilk_sync;
             }",
        );
        let f = &p.funcs[0];
        assert!(f.body.stmts[0].dae, "pragma attaches to following stmt");
        assert!(!f.body.stmts[1].dae);
        assert!(matches!(f.body.stmts[2].kind, StmtKind::Store { .. }));
        assert!(matches!(f.body.stmts[3].kind, StmtKind::For { .. }));
    }

    #[test]
    fn precedence() {
        let p = parse_ok("int f(int a, int b) { int x = a + b * 2 < 10 && a != 0; return x; }");
        let StmtKind::Decl { init: Some(Initializer::Expr(e)), .. } = &p.funcs[0].body.stmts[0].kind
        else {
            panic!(
                "expected first statement to be a declaration with an expression initializer, \
                 got {:?}",
                p.funcs[0].body.stmts[0].kind
            )
        };
        // Top-level should be `&&`.
        let ExprKind::Binary { op, .. } = &e.kind else {
            panic!("expected a binary expression at the top level, got {:?}", e.kind)
        };
        assert_eq!(*op, BinOp::And);
    }

    #[test]
    fn void_spawn_and_stmt_call() {
        let p = parse_ok(
            "void g(int n) { return; }
             void f(int n) { cilk_spawn g(n); atomic_add(counts, 0, 1); cilk_sync; }",
        );
        assert!(matches!(p.funcs[1].body.stmts[0].kind, StmtKind::VoidSpawn(_)));
        assert!(matches!(p.funcs[1].body.stmts[1].kind, StmtKind::ExprCall(_)));
    }

    #[test]
    fn user_call_in_expr_rejected() {
        let d = parse_err("int f(int n) { int x = g(n) + 1; return x; }");
        assert!(d.message.contains("not allowed inside an expression"));
    }

    #[test]
    fn leaf_call_initializer_allowed() {
        let p = parse_ok("int f(int n) { int x = helper(n); return x; }");
        assert!(matches!(
            p.funcs[0].body.stmts[0].kind,
            StmtKind::Decl { init: Some(Initializer::Call(_)), .. }
        ));
    }

    #[test]
    fn for_loop_forms() {
        parse_ok("void f(int n) { for (;;) { return; } }");
        parse_ok("void f(int n) { for (int i = 0; i < n; i = i + 1) { } }");
        parse_ok("void f(int n) { int i = 0; for (; i < n;) { i = i + 1; } }");
    }

    #[test]
    fn missing_semi_is_error() {
        let d = parse_err("int f(int n) { return n }");
        assert!(d.message.contains("expected `;`"), "{}", d.message);
    }

    #[test]
    fn min_max_builtins_parse() {
        parse_ok("int f(int a, int b) { int m = min(a, max(b, 0)); return m; }");
    }
}
