//! Semantic analysis for Cilk-C.
//!
//! Beyond ordinary name/type checking, sema enforces the restrictions that
//! keep the implicit→explicit conversion well-defined (DESIGN.md §6.3):
//!
//! 1. A value-producing `cilk_spawn` assigns to a scalar local and must not
//!    sit inside a loop (its closure slot must be static). Void spawns may be
//!    spawned in loops (dynamic join counters handle the arity).
//! 2. Sequential calls (`x = f(...)` / `f(...);`) may only target *leaf*
//!    functions — functions with no spawn/sync anywhere (HLS inlines them).
//! 3. `extern xla` tasks can only be spawned, never called sequentially.
//! 4. The DAE pragma must annotate a declaration/assignment whose RHS reads
//!    global memory (the access to decouple), inside a task function.
//! 5. Reading a spawn-assigned variable before `cilk_sync` is rejected
//!    (checked later on the CFG where flow is explicit; sema does the purely
//!    syntactic half: the variable exists, types match).

use std::collections::{HashMap, HashSet};

use super::ast::*;
use super::diag::{Diagnostic, Span};

/// Check the program; returns all diagnostics (empty = OK).
pub fn check(program: &Program) -> Vec<Diagnostic> {
    let mut cx = Checker::new(program);
    cx.check_program(program);
    cx.diags
}

struct FuncSig {
    ret: Type,
    params: Vec<Type>,
    is_xla: bool,
}

struct Checker {
    globals: HashMap<String, Type>,
    funcs: HashMap<String, FuncSig>,
    /// Functions containing spawn or sync (directly): not callable
    /// sequentially.
    spawning: HashSet<String>,
    diags: Vec<Diagnostic>,
}

impl Checker {
    fn new(program: &Program) -> Checker {
        let mut cx = Checker {
            globals: HashMap::new(),
            funcs: HashMap::new(),
            spawning: HashSet::new(),
            diags: Vec::new(),
        };
        for g in &program.globals {
            if g.ty == Type::Void {
                cx.error("global arrays cannot have element type `void`", g.span);
            }
            if cx.globals.insert(g.name.clone(), g.ty).is_some() {
                cx.error(format!("duplicate global `{}`", g.name), g.span);
            }
        }
        for e in &program.externs {
            let sig = FuncSig { ret: e.ret, params: e.params.iter().map(|p| p.ty).collect(), is_xla: true };
            if cx.funcs.insert(e.name.clone(), sig).is_some() {
                cx.error(format!("duplicate function `{}`", e.name), e.span);
            }
        }
        for f in &program.funcs {
            let sig = FuncSig { ret: f.ret, params: f.params.iter().map(|p| p.ty).collect(), is_xla: false };
            if cx.funcs.insert(f.name.clone(), sig).is_some() {
                cx.error(format!("duplicate function `{}`", f.name), f.span);
            }
            if func_spawns(&f.body) {
                cx.spawning.insert(f.name.clone());
            }
        }
        cx
    }

    fn error(&mut self, msg: impl Into<String>, span: Span) {
        self.diags.push(Diagnostic::error(msg, span));
    }

    fn check_program(&mut self, program: &Program) {
        for f in &program.funcs {
            self.check_func(f);
        }
    }

    fn check_func(&mut self, f: &FuncDef) {
        let mut scope = Scope::new();
        for p in &f.params {
            if p.ty == Type::Void {
                self.error(format!("parameter `{}` cannot be void", p.name), p.span);
            }
            if !scope.declare(&p.name, p.ty) {
                self.error(format!("duplicate parameter `{}`", p.name), p.span);
            }
        }
        let mut fx = FuncCx { ret: f.ret, in_loop: 0, func_name: f.name.clone() };
        self.check_block(&f.body, &mut scope, &mut fx);
    }

    fn check_block(&mut self, block: &Block, scope: &mut Scope, fx: &mut FuncCx) {
        scope.push();
        for stmt in &block.stmts {
            self.check_stmt(stmt, scope, fx);
        }
        scope.pop();
    }

    fn check_stmt(&mut self, stmt: &Stmt, scope: &mut Scope, fx: &mut FuncCx) {
        if stmt.dae {
            self.check_dae_target(stmt);
        }
        match &stmt.kind {
            StmtKind::Decl { ty, name, init } => {
                if *ty == Type::Void {
                    self.error(format!("variable `{name}` cannot be void"), stmt.span);
                }
                if let Some(init) = init {
                    self.check_initializer(init, *ty, stmt.span, scope, fx);
                }
                if !scope.declare(name, *ty) {
                    self.error(format!("`{name}` is already declared in this scope"), stmt.span);
                }
            }
            StmtKind::Assign { name, value } => {
                let Some(ty) = scope.lookup(name) else {
                    self.error(format!("assignment to undeclared variable `{name}`"), stmt.span);
                    return;
                };
                self.check_initializer(value, ty, stmt.span, scope, fx);
            }
            StmtKind::Store { arr, index, value } => {
                let elem = self.check_global(arr, stmt.span);
                self.expect_expr(index, Type::Int, scope, fx);
                if let Some(elem) = elem {
                    self.expect_expr(value, elem, scope, fx);
                }
            }
            StmtKind::VoidSpawn(call) => {
                self.check_spawn_call(call, scope, fx);
            }
            StmtKind::Sync => {
                if fx.in_loop > 0 {
                    // Allowed (sync-in-loop is a re-entrant continuation);
                    // nothing special here — explicitization handles it.
                }
            }
            StmtKind::If { cond, then, els } => {
                self.expect_expr(cond, Type::Bool, scope, fx);
                scope.push();
                self.check_stmt(then, scope, fx);
                scope.pop();
                if let Some(els) = els {
                    scope.push();
                    self.check_stmt(els, scope, fx);
                    scope.pop();
                }
            }
            StmtKind::While { cond, body } => {
                self.expect_expr(cond, Type::Bool, scope, fx);
                fx.in_loop += 1;
                scope.push();
                self.check_stmt(body, scope, fx);
                scope.pop();
                fx.in_loop -= 1;
            }
            StmtKind::For { init, cond, step, body } => {
                scope.push();
                if let Some(init) = init {
                    self.check_stmt(init, scope, fx);
                }
                if let Some(cond) = cond {
                    self.expect_expr(cond, Type::Bool, scope, fx);
                }
                fx.in_loop += 1;
                self.check_stmt(body, scope, fx);
                if let Some(step) = step {
                    self.check_stmt(step, scope, fx);
                }
                fx.in_loop -= 1;
                scope.pop();
            }
            StmtKind::Return(value) => match (fx.ret, value) {
                (Type::Void, None) => {}
                (Type::Void, Some(_)) => {
                    self.error(
                        format!("function `{}` returns void but `return` has a value", fx.func_name),
                        stmt.span,
                    );
                }
                (ret, None) => {
                    self.error(
                        format!("function `{}` must return a {}", fx.func_name, ret.name()),
                        stmt.span,
                    );
                }
                (ret, Some(e)) => self.expect_expr(e, ret, scope, fx),
            },
            StmtKind::ExprCall(call) => {
                if is_stmt_builtin(&call.name) {
                    self.check_stmt_builtin(call, scope, fx);
                } else {
                    self.check_seq_call(call, scope, fx);
                }
            }
            StmtKind::Block(block) => self.check_block(block, scope, fx),
        }
    }

    fn check_dae_target(&mut self, stmt: &Stmt) {
        let reads_memory = match &stmt.kind {
            StmtKind::Decl { init: Some(Initializer::Expr(e)), .. } => expr_reads_global(e),
            StmtKind::Assign { value: Initializer::Expr(e), .. } => expr_reads_global(e),
            StmtKind::Block(b) => b.stmts.iter().any(|s| match &s.kind {
                StmtKind::Decl { init: Some(Initializer::Expr(e)), .. } => expr_reads_global(e),
                StmtKind::Assign { value: Initializer::Expr(e), .. } => expr_reads_global(e),
                _ => false,
            }),
            _ => false,
        };
        if !reads_memory {
            self.error(
                "`#pragma bombyx dae` must annotate a declaration/assignment (or block of \
                 them) that reads global memory — there is no access to decouple here",
                stmt.span,
            );
        }
    }

    fn check_initializer(&mut self, init: &Initializer, expect: Type, span: Span, scope: &mut Scope, fx: &mut FuncCx) {
        match init {
            Initializer::Expr(e) => self.expect_expr(e, expect, scope, fx),
            Initializer::Spawn(call) => {
                if fx.in_loop > 0 {
                    self.error(
                        "a value-producing `cilk_spawn` may not appear inside a loop: its \
                         continuation closure slot must be static (void spawns are allowed \
                         in loops). Accumulate through memory with `atomic_add` instead",
                        span,
                    );
                }
                let ret = self.check_spawn_call(call, scope, fx);
                if let Some(ret) = ret {
                    if ret == Type::Void {
                        self.error(
                            format!("cannot assign result of void task `{}`", call.name),
                            call.span,
                        );
                    } else if !assignable(ret, expect) {
                        self.error(
                            format!(
                                "spawned task `{}` returns {} but target expects {}",
                                call.name,
                                ret.name(),
                                expect.name()
                            ),
                            call.span,
                        );
                    }
                }
            }
            Initializer::Call(call) => {
                let ret = self.check_seq_call(call, scope, fx);
                if let Some(ret) = ret {
                    if !assignable(ret, expect) {
                        self.error(
                            format!(
                                "call to `{}` returns {} but target expects {}",
                                call.name,
                                ret.name(),
                                expect.name()
                            ),
                            call.span,
                        );
                    }
                }
            }
        }
    }

    /// Check a spawned call; returns its return type if the callee resolves.
    fn check_spawn_call(&mut self, call: &Call, scope: &mut Scope, fx: &mut FuncCx) -> Option<Type> {
        let Some(sig_params) = self.func_params(&call.name) else {
            self.error(format!("spawn of unknown function `{}`", call.name), call.span);
            return None;
        };
        self.check_args(call, &sig_params, scope, fx);
        Some(self.funcs[&call.name].ret)
    }

    /// Check a sequential call; enforces leaf-ness and non-xla.
    fn check_seq_call(&mut self, call: &Call, scope: &mut Scope, fx: &mut FuncCx) -> Option<Type> {
        let Some(sig_params) = self.func_params(&call.name) else {
            self.error(format!("call to unknown function `{}`", call.name), call.span);
            return None;
        };
        if self.funcs[&call.name].is_xla {
            self.error(
                format!(
                    "`{}` is an `extern xla` task and can only be spawned (it runs on the \
                     batched XLA PE, not inline)",
                    call.name
                ),
                call.span,
            );
        }
        if self.spawning.contains(&call.name) {
            self.error(
                format!(
                    "`{}` contains cilk_spawn/cilk_sync and cannot be called sequentially; \
                     use `cilk_spawn {}(...)`",
                    call.name, call.name
                ),
                call.span,
            );
        }
        self.check_args(call, &sig_params, scope, fx);
        Some(self.funcs[&call.name].ret)
    }

    fn func_params(&self, name: &str) -> Option<Vec<Type>> {
        self.funcs.get(name).map(|s| s.params.clone())
    }

    fn check_args(&mut self, call: &Call, params: &[Type], scope: &mut Scope, fx: &mut FuncCx) {
        if call.args.len() != params.len() {
            self.error(
                format!(
                    "`{}` expects {} argument(s), got {}",
                    call.name,
                    params.len(),
                    call.args.len()
                ),
                call.span,
            );
            return;
        }
        for (arg, &ty) in call.args.iter().zip(params) {
            self.expect_expr(arg, ty, scope, fx);
        }
    }

    fn check_stmt_builtin(&mut self, call: &Call, scope: &mut Scope, fx: &mut FuncCx) {
        match call.name.as_str() {
            "atomic_add" => {
                if call.args.len() != 3 {
                    self.error("`atomic_add(arr, idx, val)` expects 3 arguments", call.span);
                    return;
                }
                let ExprKind::Var(arr) = &call.args[0].kind else {
                    self.error("first argument of `atomic_add` must name a global array", call.args[0].span);
                    return;
                };
                let elem = self.check_global(arr, call.args[0].span);
                self.expect_expr(&call.args[1], Type::Int, scope, fx);
                if let Some(elem) = elem {
                    self.expect_expr(&call.args[2], elem, scope, fx);
                }
            }
            other => self.error(format!("unknown builtin `{other}`"), call.span),
        }
    }

    fn check_global(&mut self, name: &str, span: Span) -> Option<Type> {
        match self.globals.get(name) {
            Some(&ty) => Some(ty),
            None => {
                self.error(format!("unknown global array `{name}`"), span);
                None
            }
        }
    }

    // ---- expression typing -------------------------------------------------

    fn expect_expr(&mut self, e: &Expr, expect: Type, scope: &mut Scope, fx: &mut FuncCx) {
        if let Some(actual) = self.type_expr(e, scope, fx) {
            if !assignable(actual, expect) {
                self.error(
                    format!("expected {}, found {}", expect.name(), actual.name()),
                    e.span,
                );
            }
        }
    }

    fn type_expr(&mut self, e: &Expr, scope: &mut Scope, fx: &mut FuncCx) -> Option<Type> {
        match &e.kind {
            ExprKind::IntLit(_) => Some(Type::Int),
            ExprKind::FloatLit(_) => Some(Type::Float),
            ExprKind::BoolLit(_) => Some(Type::Bool),
            ExprKind::Var(name) => {
                let ty = scope.lookup(name);
                if ty.is_none() {
                    self.error(format!("unknown variable `{name}`"), e.span);
                }
                ty
            }
            ExprKind::Load { arr, index } => {
                self.expect_expr(index, Type::Int, scope, fx);
                self.check_global(arr, e.span)
            }
            ExprKind::Builtin { name, args } => match name.as_str() {
                "min" | "max" => {
                    if args.len() != 2 {
                        self.error(format!("`{name}` expects 2 arguments"), e.span);
                        return None;
                    }
                    let a = self.type_expr(&args[0], scope, fx)?;
                    self.expect_expr(&args[1], a, scope, fx);
                    Some(a)
                }
                "abs" => {
                    if args.len() != 1 {
                        self.error("`abs` expects 1 argument", e.span);
                        return None;
                    }
                    self.type_expr(&args[0], scope, fx)
                }
                _ => unreachable!("parser only admits known builtins"),
            },
            ExprKind::Binary { op, lhs, rhs } => {
                let lt = self.type_expr(lhs, scope, fx)?;
                let rt = self.type_expr(rhs, scope, fx)?;
                if op.is_logical() {
                    if lt != Type::Bool || rt != Type::Bool {
                        self.error(
                            format!("`{}` requires bool operands, got {} and {}", op.symbol(), lt.name(), rt.name()),
                            e.span,
                        );
                    }
                    return Some(Type::Bool);
                }
                let unified = unify_arith(lt, rt);
                if unified.is_none() {
                    self.error(
                        format!(
                            "operands of `{}` have incompatible types {} and {}",
                            op.symbol(),
                            lt.name(),
                            rt.name()
                        ),
                        e.span,
                    );
                }
                if op.is_comparison() {
                    Some(Type::Bool)
                } else {
                    if matches!(op, BinOp::Shl | BinOp::Shr | BinOp::BitAnd | BinOp::BitOr | BinOp::BitXor | BinOp::Rem)
                        && unified == Some(Type::Float)
                    {
                        self.error(
                            format!("`{}` is not defined on float operands", op.symbol()),
                            e.span,
                        );
                    }
                    unified
                }
            }
            ExprKind::Unary { op, operand } => {
                let t = self.type_expr(operand, scope, fx)?;
                match op {
                    UnOp::Neg => {
                        if t == Type::Bool {
                            self.error("cannot negate a bool", e.span);
                        }
                        Some(t)
                    }
                    UnOp::Not => {
                        if t != Type::Bool {
                            self.error("`!` requires a bool operand", e.span);
                        }
                        Some(Type::Bool)
                    }
                }
            }
        }
    }
}

struct FuncCx {
    ret: Type,
    in_loop: u32,
    func_name: String,
}

/// Lexical scope stack.
struct Scope {
    frames: Vec<HashMap<String, Type>>,
}

impl Scope {
    fn new() -> Scope {
        Scope { frames: vec![HashMap::new()] }
    }

    fn push(&mut self) {
        self.frames.push(HashMap::new());
    }

    fn pop(&mut self) {
        self.frames.pop();
    }

    /// Returns false if already declared in the *current* frame.
    fn declare(&mut self, name: &str, ty: Type) -> bool {
        self.frames.last_mut().unwrap().insert(name.to_string(), ty).is_none()
    }

    fn lookup(&self, name: &str) -> Option<Type> {
        self.frames.iter().rev().find_map(|f| f.get(name).copied())
    }
}

/// Implicit conversions: int literals/values widen to float.
fn assignable(actual: Type, expect: Type) -> bool {
    actual == expect || (actual == Type::Int && expect == Type::Float)
}

fn unify_arith(a: Type, b: Type) -> Option<Type> {
    match (a, b) {
        (Type::Int, Type::Int) => Some(Type::Int),
        (Type::Float, Type::Float) | (Type::Int, Type::Float) | (Type::Float, Type::Int) => {
            Some(Type::Float)
        }
        (Type::Bool, Type::Bool) => Some(Type::Bool), // for == / !=
        _ => None,
    }
}

/// Does this function body contain spawn or sync (directly)?
pub fn func_spawns(block: &Block) -> bool {
    fn stmt_spawns(s: &Stmt) -> bool {
        match &s.kind {
            StmtKind::VoidSpawn(_) | StmtKind::Sync => true,
            StmtKind::Decl { init: Some(Initializer::Spawn(_)), .. } => true,
            StmtKind::Assign { value: Initializer::Spawn(_), .. } => true,
            StmtKind::If { then, els, .. } => {
                stmt_spawns(then) || els.as_deref().map(stmt_spawns).unwrap_or(false)
            }
            StmtKind::While { body, .. } => stmt_spawns(body),
            StmtKind::For { init, step, body, .. } => {
                stmt_spawns(body)
                    || init.as_deref().map(stmt_spawns).unwrap_or(false)
                    || step.as_deref().map(stmt_spawns).unwrap_or(false)
            }
            StmtKind::Block(b) => b.stmts.iter().any(stmt_spawns),
            _ => false,
        }
    }
    block.stmts.iter().any(stmt_spawns)
}

/// Does an expression read any global array?
pub fn expr_reads_global(e: &Expr) -> bool {
    match &e.kind {
        ExprKind::Load { .. } => true,
        ExprKind::Binary { lhs, rhs, .. } => expr_reads_global(lhs) || expr_reads_global(rhs),
        ExprKind::Unary { operand, .. } => expr_reads_global(operand),
        ExprKind::Builtin { args, .. } => args.iter().any(expr_reads_global),
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::super::parser::parse;
    use super::*;

    fn check_src(text: &str) -> Vec<Diagnostic> {
        check(&parse(lex(text).unwrap()).unwrap())
    }

    fn ok(text: &str) {
        let diags = check_src(text);
        assert!(diags.is_empty(), "unexpected diagnostics: {:?}", diags.iter().map(|d| &d.message).collect::<Vec<_>>());
    }

    fn err_containing(text: &str, needle: &str) {
        let diags = check_src(text);
        assert!(
            diags.iter().any(|d| d.message.contains(needle)),
            "expected a diagnostic containing {needle:?}, got {:?}",
            diags.iter().map(|d| &d.message).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fib_checks() {
        ok("int fib(int n) {
              if (n < 2) return n;
              int x = cilk_spawn fib(n - 1);
              int y = cilk_spawn fib(n - 2);
              cilk_sync;
              return x + y;
            }");
    }

    #[test]
    fn bfs_checks() {
        ok("global int adj_off[];
            global int adj_edges[];
            global int visited[];
            void visit(int n) {
                #pragma bombyx dae
                int off = adj_off[n];
                int end = adj_off[n + 1];
                visited[n] = 1;
                for (int i = off; i < end; i = i + 1) {
                    cilk_spawn visit(adj_edges[i]);
                }
                cilk_sync;
            }");
    }

    #[test]
    fn unknown_variable() {
        err_containing("int f(int n) { return m; }", "unknown variable `m`");
    }

    #[test]
    fn unknown_global() {
        err_containing("int f(int n) { return a[n]; }", "unknown global array `a`");
    }

    #[test]
    fn spawn_in_loop_with_value_rejected() {
        err_containing(
            "int g(int n) { return n; }
             int f(int n) {
                 int acc = 0;
                 for (int i = 0; i < n; i = i + 1) {
                     acc = cilk_spawn g(i);
                 }
                 cilk_sync;
                 return acc;
             }",
            "may not appear inside a loop",
        );
    }

    #[test]
    fn void_spawn_in_loop_ok() {
        ok("void g(int n) { return; }
            void f(int n) {
                for (int i = 0; i < n; i = i + 1) {
                    cilk_spawn g(i);
                }
                cilk_sync;
            }");
    }

    #[test]
    fn seq_call_of_spawning_function_rejected() {
        err_containing(
            "int fib(int n) {
                 if (n < 2) return n;
                 int x = cilk_spawn fib(n - 1);
                 cilk_sync;
                 return x;
             }
             int main(int n) { int r = fib(n); return r; }",
            "cannot be called sequentially",
        );
    }

    #[test]
    fn xla_seq_call_rejected() {
        err_containing(
            "extern xla int relax(int n);
             int f(int n) { int r = relax(n); return r; }",
            "can only be spawned",
        );
    }

    #[test]
    fn xla_spawn_ok() {
        ok("extern xla int relax(int n);
            int f(int n) {
                int r = cilk_spawn relax(n);
                cilk_sync;
                return r;
            }");
    }

    #[test]
    fn dae_on_non_memory_stmt_rejected() {
        err_containing(
            "global int a[];
             int f(int n) {
                 #pragma bombyx dae
                 int x = n + 1;
                 return x + a[0];
             }",
            "no access to decouple",
        );
    }

    #[test]
    fn type_mismatch() {
        err_containing("int f(int n) { bool b = n; return 0; }", "expected bool, found int");
        err_containing("int f(float x) { return x; }", "expected int, found float");
        // int widens to float.
        ok("float f(int n) { return n; }");
    }

    #[test]
    fn logical_ops_need_bools() {
        err_containing("int f(int n) { if (n && true) return 1; return 0; }", "requires bool operands");
    }

    #[test]
    fn float_modulo_rejected() {
        err_containing("float f(float x) { return x % 2.0; }", "not defined on float");
    }

    #[test]
    fn return_type_enforced() {
        err_containing("int f(int n) { return; }", "must return a int");
        err_containing("void f(int n) { return n; }", "returns void");
    }

    #[test]
    fn atomic_add_checked() {
        ok("global int counts[16];
            void f(int n) { atomic_add(counts, n, 1); }");
        err_containing("void f(int n) { atomic_add(nope, n, 1); }", "unknown global array");
    }

    #[test]
    fn duplicate_declarations() {
        err_containing("int f(int n) { int x = 0; int x = 1; return x; }", "already declared");
        err_containing("int f(int n, int n) { return n; }", "duplicate parameter");
    }

    #[test]
    fn shadowing_in_nested_scope_ok() {
        ok("int f(int n) { int x = 1; { int x = 2; n = x; } return x; }");
    }
}
