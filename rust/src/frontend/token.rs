//! Token definitions for Cilk-C.

use super::diag::Span;

#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    // Literals and identifiers
    Int(i64),
    Float(f32),
    Ident(String),

    // Keywords
    KwInt,
    KwFloat,
    KwBool,
    KwVoid,
    KwGlobal,
    KwExtern,
    KwXla,
    KwIf,
    KwElse,
    KwWhile,
    KwFor,
    KwReturn,
    KwTrue,
    KwFalse,
    KwSpawn, // cilk_spawn
    KwSync,  // cilk_sync

    // `#pragma bombyx dae` (lexed as one token)
    PragmaDae,

    // Punctuation
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Semi,
    Comma,
    Assign,

    // Operators
    Plus,
    Minus,
    Star,
    Slash,
    Percent,
    Shl,
    Shr,
    Lt,
    Le,
    Gt,
    Ge,
    EqEq,
    NotEq,
    AndAnd,
    OrOr,
    Amp,
    Pipe,
    Caret,
    Not,

    Eof,
}

impl Tok {
    /// Human-readable name for diagnostics.
    pub fn describe(&self) -> String {
        match self {
            Tok::Int(v) => format!("integer literal `{v}`"),
            Tok::Float(v) => format!("float literal `{v}`"),
            Tok::Ident(s) => format!("identifier `{s}`"),
            Tok::KwInt => "`int`".into(),
            Tok::KwFloat => "`float`".into(),
            Tok::KwBool => "`bool`".into(),
            Tok::KwVoid => "`void`".into(),
            Tok::KwGlobal => "`global`".into(),
            Tok::KwExtern => "`extern`".into(),
            Tok::KwXla => "`xla`".into(),
            Tok::KwIf => "`if`".into(),
            Tok::KwElse => "`else`".into(),
            Tok::KwWhile => "`while`".into(),
            Tok::KwFor => "`for`".into(),
            Tok::KwReturn => "`return`".into(),
            Tok::KwTrue => "`true`".into(),
            Tok::KwFalse => "`false`".into(),
            Tok::KwSpawn => "`cilk_spawn`".into(),
            Tok::KwSync => "`cilk_sync`".into(),
            Tok::PragmaDae => "`#pragma bombyx dae`".into(),
            Tok::LParen => "`(`".into(),
            Tok::RParen => "`)`".into(),
            Tok::LBrace => "`{`".into(),
            Tok::RBrace => "`}`".into(),
            Tok::LBracket => "`[`".into(),
            Tok::RBracket => "`]`".into(),
            Tok::Semi => "`;`".into(),
            Tok::Comma => "`,`".into(),
            Tok::Assign => "`=`".into(),
            Tok::Plus => "`+`".into(),
            Tok::Minus => "`-`".into(),
            Tok::Star => "`*`".into(),
            Tok::Slash => "`/`".into(),
            Tok::Percent => "`%`".into(),
            Tok::Shl => "`<<`".into(),
            Tok::Shr => "`>>`".into(),
            Tok::Lt => "`<`".into(),
            Tok::Le => "`<=`".into(),
            Tok::Gt => "`>`".into(),
            Tok::Ge => "`>=`".into(),
            Tok::EqEq => "`==`".into(),
            Tok::NotEq => "`!=`".into(),
            Tok::AndAnd => "`&&`".into(),
            Tok::OrOr => "`||`".into(),
            Tok::Amp => "`&`".into(),
            Tok::Pipe => "`|`".into(),
            Tok::Caret => "`^`".into(),
            Tok::Not => "`!`".into(),
            Tok::Eof => "end of file".into(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    pub span: Span,
}
