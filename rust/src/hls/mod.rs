//! HLS models: what Vitis would do with the generated PEs.
//!
//! Two models, both consuming the explicit IR (the same code the HLS
//! backend emits):
//!
//! - [`schedule`]: a statically-scheduled latency model. Its key property
//!   is the paper's §II-C limitation: a PE whose body mixes memory loads
//!   with data-dependent control flow cannot be task-pipelined (the tool
//!   cannot overlap stages whose latency it cannot bound), while a
//!   DAE-extracted access PE (straight-line load) pipelines at II≈1.
//! - [`resource`]: a LUT/FF/BRAM estimator calibrated against the paper's
//!   Fig. 6 synthesis results (Vivado 2024.1, xcu55c @ 300 MHz).

pub mod resource;
pub mod schedule;

pub use resource::{estimate, CostModel, ResourceEstimate};
pub use schedule::{classify, op_cycles, rtl_initiation_interval, PeClass, ScheduleModel};
