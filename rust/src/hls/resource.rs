//! FPGA resource estimation (LUT / FF / BRAM) for generated PEs.
//!
//! A linear model over static features of the explicit-IR task body:
//! datapath operator counts by class, AXI memory interfaces, stream ports,
//! closure and local register widths, and control complexity. The
//! coefficients are calibrated once against the paper's Fig. 6 (Vivado
//! 2024.1, xcu55c-fsvh2892-2L-e @ 300 MHz):
//!
//! | PE        | LUT  | FF   | BRAM |
//! |-----------|------|------|------|
//! | Non-DAE   | 2657 | 2305 | 2    |
//! | Spawner   | 133  | 387  | 0    |
//! | Executor  | 1999 | 1913 | 2    |
//! | Access    | 1764 | 1164 | 2    |
//!
//! The estimator is *not* a synthesis tool; EXPERIMENTS.md compares its
//! output against the paper's table and reports per-cell error. What must
//! hold is the paper's qualitative structure: spawner ≪ access < executor
//! < non-DAE; DAE total ≈ +47 % LUT / +50 % FF / 2× BRAM.

use crate::frontend::ast::{BinOp, Type};
use crate::ir::cfg::{Func, FuncKind, Module, Op};
use crate::ir::explicit::closure_layout;
use crate::ir::expr::Expr;

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResourceEstimate {
    pub lut: u32,
    pub ff: u32,
    pub bram: u32,
    pub dsp: u32,
}

impl std::fmt::Display for ResourceEstimate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "LUT={} FF={} BRAM={} DSP={}",
            self.lut, self.ff, self.bram, self.dsp
        )
    }
}

impl std::ops::Add for ResourceEstimate {
    type Output = ResourceEstimate;
    fn add(self, o: ResourceEstimate) -> ResourceEstimate {
        ResourceEstimate {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            bram: self.bram + o.bram,
            dsp: self.dsp + o.dsp,
        }
    }
}

/// Calibrated coefficients (see module docs).
#[derive(Clone, Debug)]
pub struct CostModel {
    // Control.
    pub ctrl_base_lut: u32,
    pub ctrl_per_block_lut: u32,
    pub ctrl_base_ff: u32,
    pub ctrl_per_block_ff: u32,
    // Datapath (per 64-bit operator).
    pub addsub_lut: u32,
    pub cmp_lut: u32,
    pub mul_lut: u32,
    pub mul_dsp: u32,
    pub divrem_lut: u32,
    pub shift_lut: u32,
    pub bit_lut: u32,
    pub fp_lut: u32,
    pub fp_dsp: u32,
    // Memory interfaces.
    pub axi_read_lut: u32,
    pub axi_read_ff: u32,
    pub axi_write_lut: u32,
    pub axi_write_ff: u32,
    pub extra_port_lut: u32,
    pub axi_bram: u32,
    /// Request muxing/reorder logic per load site beyond the first.
    pub load_extra_lut: u32,
    pub load_extra_ff: u32,
    // Stream ports.
    pub stream_port_lut: u32,
    pub stream_port_ff: u32,
    /// Per 64-bit word of spawn/send payload datapath.
    pub payload_word_lut: u32,
    // Registers.
    pub closure_bit_ff_milli: u32, // FF per closure bit, in 1/1000
    pub local_bit_ff_milli: u32,
    /// Sequential (non-pipelined) schedule keeps live values across many
    /// states → extra FF per local bit.
    pub seq_state_ff_milli: u32,
}

impl Default for CostModel {
    /// xcu55c calibration (see module docs and EXPERIMENTS.md §Fig6).
    fn default() -> Self {
        CostModel {
            ctrl_base_lut: 24,
            ctrl_per_block_lut: 12,
            ctrl_base_ff: 40,
            ctrl_per_block_ff: 12,
            addsub_lut: 32,
            cmp_lut: 20,
            mul_lut: 70,
            mul_dsp: 4,
            divrem_lut: 220,
            shift_lut: 40,
            bit_lut: 16,
            fp_lut: 110,
            fp_dsp: 2,
            axi_read_lut: 1650,
            axi_read_ff: 870,
            axi_write_lut: 120,
            axi_write_ff: 240,
            extra_port_lut: 150,
            axi_bram: 2,
            load_extra_lut: 180,
            load_extra_ff: 200,
            stream_port_lut: 10,
            stream_port_ff: 20,
            payload_word_lut: 8,
            closure_bit_ff_milli: 700,
            local_bit_ff_milli: 350,
            seq_state_ff_milli: 450,
        }
    }
}

/// Static features extracted from a task body.
#[derive(Clone, Debug, Default)]
pub struct Features {
    pub blocks: u32,
    pub addsub: u32,
    pub cmp: u32,
    pub mul: u32,
    pub divrem: u32,
    pub shift: u32,
    pub bit: u32,
    pub fp: u32,
    pub loads: u32,
    pub stores: u32,
    pub load_globals: u32,
    pub store_globals: u32,
    pub stream_ports: u32,
    pub payload_words: u32,
    pub closure_bits: u32,
    pub local_bits: u32,
    pub sequential: bool,
}

pub fn features(module: &Module, func: &Func) -> Features {
    let mut f = Features {
        closure_bits: closure_layout(func).padded_bits,
        sequential: matches!(super::schedule::classify(func), super::schedule::PeClass::Sequential),
        ..Default::default()
    };
    for (vid, v) in func.vars.iter() {
        if vid.index() >= func.params {
            f.local_bits += v.ty.bits().max(1);
        }
    }
    let Some(cfg) = func.body.as_ref() else {
        f.stream_ports = 2; // task_in + send_out for the xla blackbox shell
        return f;
    };
    let reachable = cfg.reachable();
    let mut load_arrs = Vec::new();
    let mut store_arrs = Vec::new();
    let mut has_spawn = false;
    let mut has_next = false;
    let mut has_send = false;
    for (bid, block) in cfg.blocks.iter() {
        if !reachable[bid.index()] {
            continue;
        }
        f.blocks += 1;
        let count_expr = |e: &Expr, f: &mut Features| count_ops(module, func, e, f);
        for op in &block.ops {
            match op {
                Op::Assign { src, .. } => count_expr(src, &mut f),
                Op::Load { arr, index, .. } => {
                    f.loads += 1;
                    if !load_arrs.contains(arr) {
                        load_arrs.push(*arr);
                    }
                    count_expr(index, &mut f);
                }
                Op::Store { arr, index, value } | Op::AtomicAdd { arr, index, value } => {
                    f.stores += 1;
                    if !store_arrs.contains(arr) {
                        store_arrs.push(*arr);
                    }
                    count_expr(index, &mut f);
                    count_expr(value, &mut f);
                }
                Op::Call { args, .. } => {
                    // Leaf bodies are inlined by HLS; fold their features
                    // in (callee counted once per call site, as inlining
                    // duplicates hardware).
                    for a in args {
                        count_expr(a, &mut f);
                    }
                    if let Op::Call { callee, .. } = op {
                        let leaf = &module.funcs[*callee];
                        if leaf.kind == FuncKind::Leaf {
                            let sub = features(module, leaf);
                            f.addsub += sub.addsub;
                            f.cmp += sub.cmp;
                            f.mul += sub.mul;
                            f.divrem += sub.divrem;
                            f.shift += sub.shift;
                            f.bit += sub.bit;
                            f.fp += sub.fp;
                            f.blocks += sub.blocks;
                            f.local_bits += sub.local_bits;
                        }
                    }
                }
                Op::Spawn { args, .. } => {
                    has_spawn = true;
                    f.payload_words += args.len() as u32;
                    for a in args {
                        count_expr(a, &mut f);
                    }
                }
                Op::MakeClosure { .. } => {
                    has_next = true;
                }
                Op::ClosureStore { value, .. } => {
                    has_send = true;
                    f.payload_words += 1;
                    count_expr(value, &mut f);
                }
                Op::SpawnChild { args, .. } => {
                    has_spawn = true;
                    f.payload_words += args.len() as u32;
                    for a in args {
                        count_expr(a, &mut f);
                    }
                }
                Op::CloseSpawns { .. } => has_send = true,
                Op::SendArgument { value } => {
                    has_send = true;
                    f.payload_words += 1;
                    if let Some(v) = value {
                        count_expr(v, &mut f);
                    }
                }
            }
        }
        if let crate::ir::cfg::Term::Branch { cond, .. } = &block.term {
            count_ops(module, func, cond, &mut f);
        }
    }
    f.load_globals = load_arrs.len() as u32;
    f.store_globals = store_arrs.len() as u32;
    // task_in is always present; others per use.
    f.stream_ports = 1
        + u32::from(has_spawn)
        + u32::from(has_send)
        + 2 * u32::from(has_next); // spawn_next_out + addr_in
    f
}

fn count_ops(module: &Module, func: &Func, e: &Expr, f: &mut Features) {
    let _ = module;
    e.for_each_node(&mut |n| match n {
        Expr::Binary(op, a, b) => {
            let float = expr_ty_is_float(func, a) || expr_ty_is_float(func, b);
            if float {
                f.fp += 1;
                return;
            }
            match op {
                BinOp::Add | BinOp::Sub => f.addsub += 1,
                BinOp::Mul => f.mul += 1,
                BinOp::Div | BinOp::Rem => f.divrem += 1,
                BinOp::Shl | BinOp::Shr => f.shift += 1,
                BinOp::BitAnd | BinOp::BitOr | BinOp::BitXor | BinOp::And | BinOp::Or => {
                    f.bit += 1
                }
                _ => f.cmp += 1,
            }
        }
        Expr::Unary(_, _) => f.addsub += 1,
        Expr::Builtin(_, _) => f.cmp += 2, // compare + mux
        Expr::IntToFloat(_) => f.fp += 1,
        _ => {}
    });
}

fn expr_ty_is_float(func: &Func, e: &Expr) -> bool {
    match e {
        Expr::ConstF(_) | Expr::IntToFloat(_) => true,
        Expr::Var(v) => func.vars[*v].ty == Type::Float,
        Expr::Binary(_, a, b) => expr_ty_is_float(func, a) || expr_ty_is_float(func, b),
        Expr::Unary(_, a) => expr_ty_is_float(func, a),
        Expr::Builtin(_, args) => args.iter().any(|a| expr_ty_is_float(func, a)),
        _ => false,
    }
}

/// Estimate one task's PE.
pub fn estimate(model: &CostModel, module: &Module, func: &Func) -> ResourceEstimate {
    let f = features(module, func);
    let mut lut = model.ctrl_base_lut + model.ctrl_per_block_lut * f.blocks;
    lut += model.addsub_lut * f.addsub
        + model.cmp_lut * f.cmp
        + model.mul_lut * f.mul
        + model.divrem_lut * f.divrem
        + model.shift_lut * f.shift
        + model.bit_lut * f.bit
        + model.fp_lut * f.fp;
    let mut bram = 0;
    let mut ff = model.ctrl_base_ff + model.ctrl_per_block_ff * f.blocks;
    if f.loads > 0 {
        lut += model.axi_read_lut + model.extra_port_lut * f.load_globals.saturating_sub(1);
        lut += model.load_extra_lut * f.loads.saturating_sub(1);
        ff += model.axi_read_ff + model.load_extra_ff * f.loads.saturating_sub(1);
        bram += model.axi_bram;
    }
    if f.stores > 0 {
        lut += model.axi_write_lut + model.extra_port_lut * f.store_globals.saturating_sub(1);
        ff += model.axi_write_ff;
        if f.loads == 0 {
            bram += model.axi_bram;
        }
    }
    lut += model.stream_port_lut * f.stream_ports + model.payload_word_lut * f.payload_words;
    ff += model.stream_port_ff * f.stream_ports;
    ff += (model.closure_bit_ff_milli * f.closure_bits) / 1000;
    ff += (model.local_bit_ff_milli * f.local_bits) / 1000;
    if f.sequential {
        ff += (model.seq_state_ff_milli * f.local_bits) / 1000;
    }
    let dsp = model.mul_dsp * f.mul + model.fp_dsp * f.fp;
    ResourceEstimate { lut, ff, bram, dsp }
}

/// Estimate every explicit task of a module; returns (name, role, est).
pub fn estimate_module(
    model: &CostModel,
    module: &Module,
) -> Vec<(String, &'static str, ResourceEstimate)> {
    crate::ir::explicit::explicit_tasks(module)
        .into_iter()
        .map(|fid| {
            let f = &module.funcs[fid];
            let role = f.task.as_ref().map(|t| t.role.name()).unwrap_or("task");
            (f.name.clone(), role, estimate(model, module, f))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::{compile, CompileOptions};
    use crate::workloads::bfs;

    /// Paper Fig. 6 ground truth.
    const PAPER: [(&str, u32, u32, u32); 4] = [
        ("non_dae", 2657, 2305, 2),
        ("spawner", 133, 387, 0),
        ("executor", 1999, 1913, 2),
        ("access", 1764, 1164, 2),
    ];

    fn fig6_estimates() -> Vec<(&'static str, ResourceEstimate)> {
        let model = CostModel::default();
        let non_dae = compile("t", bfs::BFS_SRC, &CompileOptions::no_dae()).unwrap();
        let dae = compile("t", bfs::BFS_DAE_SRC, &CompileOptions::standard()).unwrap();
        let m0 = &non_dae.explicit;
        let m1 = &dae.explicit;
        let get = |m: &crate::ir::Module, n: &str| {
            let f = &m.funcs[m.func_by_name(n).unwrap()];
            estimate(&model, m, f)
        };
        vec![
            ("non_dae", get(m0, "visit")),
            ("spawner", get(m1, "visit")),
            ("executor", get(m1, "visit__k1")),
            ("access", get(m1, "adj_off_access")),
        ]
    }

    #[test]
    fn fig6_shape_holds() {
        let est = fig6_estimates();
        let by = |n: &str| est.iter().find(|(m, _)| *m == n).unwrap().1;
        // Qualitative structure from the paper.
        assert!(by("spawner").lut < by("access").lut);
        assert!(by("access").lut < by("executor").lut || by("access").lut < by("non_dae").lut);
        assert!(by("executor").lut < by("non_dae").lut);
        assert_eq!(by("spawner").bram, 0);
        assert_eq!(by("access").bram, 2);
        assert_eq!(by("executor").bram, 2);
        assert_eq!(by("non_dae").bram, 2);
        // DAE total overhead ≈ +47 % LUT / +50 % FF (paper) — require the
        // same direction and rough magnitude (+25 %..+75 %).
        let dae_lut = by("spawner").lut + by("executor").lut + by("access").lut;
        let dae_ff = by("spawner").ff + by("executor").ff + by("access").ff;
        let rl = dae_lut as f64 / by("non_dae").lut as f64;
        let rf = dae_ff as f64 / by("non_dae").ff as f64;
        assert!((1.25..1.75).contains(&rl), "LUT ratio {rl:.2} (paper 1.47)");
        assert!((1.25..1.80).contains(&rf), "FF ratio {rf:.2} (paper 1.50)");
    }

    #[test]
    fn fig6_absolute_error_within_tolerance() {
        let est = fig6_estimates();
        for (name, paper_lut, paper_ff, paper_bram) in PAPER {
            let e = est.iter().find(|(m, _)| *m == name).unwrap().1;
            let lut_err = (e.lut as f64 - paper_lut as f64).abs() / paper_lut as f64;
            let ff_err = (e.ff as f64 - paper_ff as f64).abs() / paper_ff as f64;
            assert!(
                lut_err < 0.35,
                "{name}: LUT {} vs paper {paper_lut} ({:.0}% off)",
                e.lut,
                lut_err * 100.0
            );
            assert!(
                ff_err < 0.35,
                "{name}: FF {} vs paper {paper_ff} ({:.0}% off)",
                e.ff,
                ff_err * 100.0
            );
            assert_eq!(e.bram, paper_bram, "{name}: BRAM");
        }
    }
}

#[cfg(test)]
mod calib_dump {
    use super::*;
    use crate::lower::{compile, CompileOptions};
    use crate::workloads::bfs;

    #[test]
    fn dump_features() {
        let model = CostModel::default();
        let non_dae = compile("t", bfs::BFS_SRC, &CompileOptions::no_dae()).unwrap();
        let dae = compile("t", bfs::BFS_DAE_SRC, &CompileOptions::standard()).unwrap();
        for (label, m, name) in [
            ("non_dae", &non_dae.explicit, "visit"),
            ("spawner", &dae.explicit, "visit"),
            ("executor", &dae.explicit, "visit__k1"),
            ("access", &dae.explicit, "adj_off_access"),
        ] {
            let f = &m.funcs[m.func_by_name(name).unwrap()];
            let feat = features(m, f);
            let est = estimate(&model, m, f);
            eprintln!("{label}: {feat:?}\n  est={est:?}");
        }
    }
}
