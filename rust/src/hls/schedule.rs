//! Vitis-like static-schedule latency model for generated PEs.
//!
//! The paper's §II-C observation, operationalized:
//!
//! > "When the latency of operations in the PE cannot be determined
//! > statically, for example, a loop with a data dependent bound, the tool
//! > cannot fully pipeline the computation."
//!
//! We classify every task into:
//!
//! - [`PeClass::Pipelined`]: body is straight-line (no data-dependent
//!   back-edges) — Vitis pipelines the task loop; a new task enters every
//!   II cycles and memory latency is overlapped across tasks (bounded by
//!   the memory channel's outstanding-request capacity). DAE access tasks
//!   land here.
//! - [`PeClass::Sequential`]: body contains a data-dependent loop and/or
//!   mixes loads with control flow — the schedule serializes: every load
//!   stalls the PE for the full memory latency.
//!
//! The per-op cycle costs approximate a 300 MHz statically-scheduled
//! datapath (chaining ~4 simple ops per cycle; stream writes through the
//! write buffer cost a beat; `spawn_next` costs a scheduler round trip).

use crate::ir::cfg::{Func, Op};
use crate::ir::expr::Expr;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeClass {
    /// Task-pipelined with the given initiation interval.
    Pipelined { ii: u32 },
    /// One task at a time; loads stall.
    Sequential,
}

/// Cycle-cost constants (300 MHz, Vitis-style chaining).
#[derive(Clone, Debug)]
pub struct ScheduleModel {
    /// Simple 64-bit ALU ops chained per cycle.
    pub ops_per_cycle: u32,
    /// Stream write to the write buffer (spawn / send_argument).
    pub stream_write: u32,
    /// spawn_next: request + closure-address response round trip.
    pub spawn_next_rtt: u32,
    /// Closure/task ingress: reading the task from the scheduler stream.
    pub task_read: u32,
    /// Store issue (absorbed by the write buffer).
    pub store_issue: u32,
    /// Load issue (address phase; the wait is the memory model's business).
    pub load_issue: u32,
    /// Branch/loop-control overhead per executed terminator.
    pub branch: u32,
}

impl Default for ScheduleModel {
    fn default() -> Self {
        ScheduleModel {
            ops_per_cycle: 4,
            stream_write: 8,
            spawn_next_rtt: 50,
            task_read: 16,
            store_issue: 3,
            load_issue: 1,
            branch: 1,
        }
    }
}

/// Classify a task per the §II-C rule.
pub fn classify(func: &Func) -> PeClass {
    let Some(cfg) = func.body.as_ref() else {
        // extern xla: the blackbox datapath is pipelined by construction.
        return PeClass::Pipelined { ii: 1 };
    };
    // Any back edge (loop) → data-dependent latency → not pipelineable.
    let idom = crate::lower::analysis::dominators(cfg);
    let loops = crate::lower::analysis::natural_loops(cfg, &idom);
    if !loops.is_empty() {
        return PeClass::Sequential;
    }
    // Straight-line (possibly branching, but acyclic) body: pipelineable.
    // II = max beats demanded by any single stage resource; dominated by
    // the slower of (loads issued, stream writes) per task.
    let model = ScheduleModel::default();
    let mut loads = 0u32;
    let mut writes = 0u32;
    for block in cfg.blocks.values() {
        for op in &block.ops {
            match op {
                Op::Load { .. } => loads += 1,
                Op::SpawnChild { .. } | Op::SendArgument { .. } | Op::ClosureStore { .. } => {
                    writes += 1
                }
                _ => {}
            }
        }
    }
    let ii = (loads * model.load_issue).max(writes * model.stream_write).max(1);
    PeClass::Pipelined { ii }
}

/// Initiation interval a *direct-RTL* pipelined datapath achieves for a
/// [`PeClass::Pipelined`] task, or `None` for sequential tasks.
///
/// The HLS model's II ([`classify`]) charges every stream write a full
/// write-buffer beat (`stream_write` = 8 cycles), because Vitis schedules
/// the buffer handshake into the task loop. The RTL backend enqueues
/// stream messages in a single cycle through ready/valid FIFOs, so its II
/// is bounded by the load issue rate alone — a one-load DAE access task
/// pipelines at II=1 (paper §II-C made concrete in hardware).
pub fn rtl_initiation_interval(func: &Func) -> Option<u32> {
    match classify(func) {
        PeClass::Sequential => None,
        PeClass::Pipelined { .. } => {
            let model = ScheduleModel::default();
            let mut loads = 0u32;
            if let Some(cfg) = func.body.as_ref() {
                for block in cfg.blocks.values() {
                    for op in &block.ops {
                        if matches!(op, Op::Load { .. }) {
                            loads += 1;
                        }
                    }
                }
            }
            Some((loads * model.load_issue).max(1))
        }
    }
}

/// Cycles a sequential PE spends executing one op, *excluding* memory wait
/// (the simulator adds channel latency for loads).
pub fn op_cycles(model: &ScheduleModel, op: &Op) -> u32 {
    match op {
        Op::Assign { src, .. } => expr_cycles(model, src),
        Op::Load { index, .. } => model.load_issue + expr_cycles(model, index),
        Op::Store { index, value, .. } | Op::AtomicAdd { index, value, .. } => {
            model.store_issue + expr_cycles(model, index) + expr_cycles(model, value)
        }
        Op::Call { args, .. } => {
            // Inlined leaf: approximated by its argument datapath (callee
            // body is charged when interpreted — the simulator executes
            // leaf bodies op by op).
            args.iter().map(|a| expr_cycles(model, a)).sum()
        }
        Op::Spawn { .. } => model.stream_write,
        Op::MakeClosure { .. } => model.spawn_next_rtt,
        Op::ClosureStore { value, .. } => model.stream_write + expr_cycles(model, value),
        Op::SpawnChild { args, .. } => {
            model.stream_write + args.iter().map(|a| expr_cycles(model, a)).sum::<u32>()
        }
        Op::CloseSpawns { .. } => model.stream_write,
        Op::SendArgument { value } => {
            model.stream_write
                + value.as_ref().map(|v| expr_cycles(model, v)).unwrap_or(0)
        }
    }
}

/// Datapath cycles for an expression (ops chained `ops_per_cycle` per
/// cycle; constants and variable reads are free).
pub fn expr_cycles(model: &ScheduleModel, e: &Expr) -> u32 {
    let mut operators = 0u32;
    e.for_each_node(&mut |n| {
        if matches!(n, Expr::Binary(..) | Expr::Unary(..) | Expr::Builtin(..)) {
            operators += 1;
        }
    });
    operators.div_ceil(model.ops_per_cycle)
}

/// Static (memory-independent) latency of a whole task body along its
/// longest acyclic path — a reporting figure for DESIGN/EXPERIMENTS, not
/// used for simulation (the simulator charges ops as it executes them).
pub fn static_body_cycles(model: &ScheduleModel, func: &Func) -> u32 {
    let Some(cfg) = func.body.as_ref() else { return 1 };
    // Longest path over the DAG of blocks (back edges ignored).
    let rpo = cfg.reverse_postorder();
    let mut pos = vec![usize::MAX; cfg.blocks.len()];
    for (i, b) in rpo.iter().enumerate() {
        pos[b.index()] = i;
    }
    let mut dist = vec![0u32; cfg.blocks.len()];
    let mut best = 0;
    for &b in &rpo {
        let block = &cfg.blocks[b];
        let mut cost = model.branch;
        for op in &block.ops {
            cost += op_cycles(model, op);
        }
        let d = dist[b.index()] + cost;
        best = best.max(d);
        for s in block.term.successors() {
            // Forward edges only.
            if pos[s.index()] > pos[b.index()] {
                dist[s.index()] = dist[s.index()].max(d);
            }
        }
    }
    best + model.task_read
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::{compile, CompileOptions};

    const BFS_DAE: &str = "global int adj_off[];
        global int adj_edges[];
        global int visited[];
        void visit(int n) {
            #pragma bombyx dae
            int off = adj_off[n];
            #pragma bombyx dae
            int end = adj_off[n + 1];
            visited[n] = 1;
            for (int i = off; i < end; i = i + 1) {
                cilk_spawn visit(adj_edges[i]);
            }
            cilk_sync;
        }";

    #[test]
    fn access_pe_pipelines_executor_does_not() {
        let r = compile("t", BFS_DAE, &CompileOptions::standard()).unwrap();
        let m = &r.explicit;
        let access = &m.funcs[m.func_by_name("adj_off_access").unwrap()];
        assert!(matches!(classify(access), PeClass::Pipelined { .. }), "{:?}", classify(access));
        // The executor (continuation with the spawn loop) is sequential.
        let exec = &m.funcs[m.func_by_name("visit__k1").unwrap()];
        assert_eq!(classify(exec), PeClass::Sequential);
        // The spawner (entry) is straight-line → pipelineable.
        let spawner = &m.funcs[m.func_by_name("visit").unwrap()];
        assert!(matches!(classify(spawner), PeClass::Pipelined { .. }));
    }

    #[test]
    fn non_dae_visit_is_sequential() {
        let r = compile("t", BFS_DAE, &CompileOptions::no_dae()).unwrap();
        let m = &r.explicit;
        let visit = &m.funcs[m.func_by_name("visit").unwrap()];
        assert_eq!(classify(visit), PeClass::Sequential, "§II-C: loop prevents pipelining");
    }

    #[test]
    fn rtl_ii_is_one_for_single_load_access_pe() {
        let r = compile("t", BFS_DAE, &CompileOptions::standard()).unwrap();
        let m = &r.explicit;
        let access = &m.funcs[m.func_by_name("adj_off_access").unwrap()];
        assert_eq!(rtl_initiation_interval(access), Some(1));
        // Sequential tasks have no pipelined II at all.
        let exec = &m.funcs[m.func_by_name("visit__k1").unwrap()];
        assert_eq!(rtl_initiation_interval(exec), None);
    }

    #[test]
    fn op_costs_are_positive_and_bounded() {
        let r = compile("t", BFS_DAE, &CompileOptions::standard()).unwrap();
        let model = ScheduleModel::default();
        for (_, f) in r.explicit.funcs.iter() {
            let Some(cfg) = f.body.as_ref() else { continue };
            for block in cfg.blocks.values() {
                for op in &block.ops {
                    let c = op_cycles(&model, op);
                    assert!(c <= 64, "op too expensive: {op:?} = {c}");
                }
            }
            let total = static_body_cycles(&model, f);
            assert!(total >= 1 && total < 10_000, "{}: {total}", f.name);
        }
    }
}
