//! Single-threaded executor for the explicit IR — the Cilk-1 abstract
//! machine: a closure heap with join counters plus a ready queue.
//!
//! This is the semantic core shared with the multithreaded WS runtime
//! ([`crate::ws`]) and the HardCilk cycle simulator ([`crate::sim`]):
//! since the kernel rework, shared *by construction and by code* — all
//! three run the same compiled bytecode ([`crate::exec`]) through the
//! same interpreter loop, differing only in their [`Machine`] side
//! (this one: a local closure heap and a LIFO/FIFO ready queue).

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::exec::{
    self, run_kernel, ArgList, KStack, KernelMode, KernelProgram, KontRef, Machine,
};
use crate::ir::cfg::{FuncId, FuncKind, GlobalId, Module};
use crate::ir::expr::Value;

use super::{Memory, XlaHandler};

/// Where a task delivers its `send_argument`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cont {
    /// The external caller (result of the root task).
    Root,
    /// Fill `slot` of closure `clos`, decrement its counter.
    Slot { clos: usize, slot: u32 },
    /// Only decrement the counter of `clos`.
    Counter { clos: usize },
}

/// A pending continuation closure (paper §II: ready arguments, hole
/// placeholders, return pointer, join counter).
#[derive(Clone, Debug)]
pub struct Closure {
    pub task: FuncId,
    pub slots: Vec<Value>,
    pub cont: Cont,
    pub counter: u32,
    pub freed: bool,
}

/// A runnable task instance.
#[derive(Clone, Debug)]
pub struct TaskInst {
    pub task: FuncId,
    pub args: ArgList,
    pub cont: Cont,
}

/// Queue discipline for the ready queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Order {
    /// Depth-first-ish (stack). Bounds closure liveness like Cilk's
    /// work-first policy; the default.
    #[default]
    Lifo,
    /// Breadth-first (queue) — maximal exposed parallelism, worst-case
    /// closure footprint. Useful for stress tests.
    Fifo,
}

#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub tasks_run: u64,
    pub closures_made: u64,
    pub sends: u64,
    pub max_ready: usize,
    pub max_live_closures: usize,
    /// Kernel instructions retired (cumulative; a fused superinstruction
    /// retires as one dispatch).
    pub instrs: u64,
    /// Tasks run per role name (entry/continuation/join/access/xla).
    pub per_role: std::collections::BTreeMap<&'static str, u64>,
}

pub struct ExplicitExec<'m, X: XlaHandler> {
    pub module: &'m Module,
    pub memory: Memory,
    pub xla: X,
    pub order: Order,
    pub stats: ExecStats,
    kernels: Option<Arc<KernelProgram>>,
    closures: Vec<Closure>,
    free_closures: Vec<usize>,
    ready: VecDeque<TaskInst>,
    result: Option<Value>,
    live_closures: usize,
    stack: KStack,
    /// Continuation of the task instance currently executing (what
    /// `send_argument` / forwarded spawns target).
    cur_cont: Cont,
    /// Explicit JIT selection (`None` = process-environment default).
    jit_cfg: Option<exec::jit::JitConfig>,
    /// Native-tier handle, resolved once kernels exist.
    jit: Option<Arc<exec::jit::JitTier>>,
}

impl<'m, X: XlaHandler> ExplicitExec<'m, X> {
    pub fn new(module: &'m Module, memory: Memory, xla: X) -> Self {
        ExplicitExec {
            module,
            memory,
            xla,
            order: Order::default(),
            stats: ExecStats::default(),
            kernels: None,
            closures: Vec::new(),
            free_closures: Vec::new(),
            ready: VecDeque::new(),
            result: None,
            live_closures: 0,
            stack: KStack::new(),
            cur_cont: Cont::Root,
            jit_cfg: None,
            jit: None,
        }
    }

    /// Select the JIT configuration explicitly (overriding the
    /// `BOMBYX_JIT` environment default) — e.g.
    /// [`exec::jit::JitConfig::disabled`] pins a test to the interpreter.
    pub fn set_jit(&mut self, cfg: exec::jit::JitConfig) {
        self.jit_cfg = Some(cfg);
        self.resolve_jit();
    }

    fn resolve_jit(&mut self) {
        self.jit = match (&self.kernels, self.jit_cfg) {
            (Some(k), Some(cfg)) => exec::jit::tier_with(k, cfg),
            (Some(k), None) => exec::jit::tier_for(k),
            (None, _) => None,
        };
    }

    /// Reuse a session-cached kernel program instead of compiling on the
    /// first `run`.
    pub fn with_kernels(
        module: &'m Module,
        memory: Memory,
        xla: X,
        kernels: Arc<KernelProgram>,
    ) -> Self {
        let mut ex = ExplicitExec::new(module, memory, xla);
        ex.kernels = Some(kernels);
        ex.resolve_jit();
        ex
    }

    fn ensure_kernels(&mut self) -> Result<()> {
        if self.kernels.is_none() {
            self.kernels =
                Some(Arc::new(exec::compile_module(self.module, KernelMode::Explicit)?));
            self.resolve_jit();
        }
        Ok(())
    }

    /// Run task `name` to completion (drain the whole task graph) and
    /// return the value it sends to the root continuation (Unit for void).
    pub fn run(&mut self, name: &str, args: &[Value]) -> Result<Value> {
        let fid = self
            .module
            .func_by_name(name)
            .ok_or_else(|| anyhow!("no task named `{name}`"))?;
        self.ensure_kernels()?;
        self.ready.push_back(TaskInst {
            task: fid,
            args: ArgList::from_slice(args),
            cont: Cont::Root,
        });
        self.drain()?;
        self.result.take().ok_or_else(|| {
            anyhow!("task graph drained but no send_argument reached the root continuation")
        })
    }

    fn drain(&mut self) -> Result<()> {
        let mut steps: u64 = 0;
        while let Some(inst) = match self.order {
            Order::Lifo => self.ready.pop_back(),
            Order::Fifo => self.ready.pop_front(),
        } {
            steps += 1;
            if steps > 500_000_000 {
                bail!("explicit executor exceeded task budget");
            }
            self.run_task(inst)?;
            self.stats.max_ready = self.stats.max_ready.max(self.ready.len());
        }
        Ok(())
    }

    fn alloc_closure(&mut self, c: Closure) -> usize {
        self.stats.closures_made += 1;
        self.live_closures += 1;
        self.stats.max_live_closures = self.stats.max_live_closures.max(self.live_closures);
        match self.free_closures.pop() {
            Some(idx) => {
                self.closures[idx] = c;
                idx
            }
            None => {
                self.closures.push(c);
                self.closures.len() - 1
            }
        }
    }

    fn fire_if_ready(&mut self, clos: usize) {
        let c = &mut self.closures[clos];
        debug_assert!(!c.freed, "decrement on freed closure");
        if c.counter == 0 {
            let args = ArgList::from(std::mem::take(&mut c.slots));
            let inst = TaskInst { task: c.task, args, cont: c.cont };
            c.freed = true;
            self.live_closures -= 1;
            self.free_closures.push(clos);
            self.ready.push_back(inst);
        }
    }

    fn deliver(&mut self, cont: Cont, value: Value) -> Result<()> {
        self.stats.sends += 1;
        match cont {
            Cont::Root => {
                if self.result.is_some() {
                    bail!("root continuation received two results");
                }
                self.result = Some(value);
            }
            Cont::Slot { clos, slot } => {
                let (task, freed) = {
                    let c = &self.closures[clos];
                    (c.task, c.freed)
                };
                if freed {
                    bail!("send_argument into freed closure (join-counter bug)");
                }
                let ty = self
                    .kernels
                    .as_ref()
                    .expect("kernels compiled before execution")
                    .kernel(task)
                    .param_tys[slot as usize];
                {
                    let c = &mut self.closures[clos];
                    c.slots[slot as usize] = value.coerce(ty);
                    c.counter -= 1;
                }
                self.fire_if_ready(clos);
            }
            Cont::Counter { clos } => {
                {
                    let c = &mut self.closures[clos];
                    if c.freed {
                        bail!("counter decrement on freed closure (join-counter bug)");
                    }
                    c.counter -= 1;
                }
                self.fire_if_ready(clos);
            }
        }
        Ok(())
    }

    fn run_task(&mut self, inst: TaskInst) -> Result<()> {
        self.stats.tasks_run += 1;
        let prog = Arc::clone(self.kernels.as_ref().expect("kernels compiled in run()"));
        let kernel = prog.kernel(inst.task);
        *self.stats.per_role.entry(kernel.role).or_insert(0) += 1;

        // XLA tasks have no body: the scalar handler computes the datapath
        // and the result goes straight to the continuation.
        if kernel.kind == FuncKind::Xla {
            let out = self.xla.call(&kernel.name, inst.args.as_slice(), &mut self.memory)?;
            return self.deliver(inst.cont, out);
        }

        self.cur_cont = inst.cont;
        let mut stack = std::mem::take(&mut self.stack);
        let result =
            run_kernel(&prog, inst.task, inst.args.as_slice(), &mut stack, self, 100_000_000);
        self.stack = stack;
        self.stats.instrs = self.stack.retired();
        let value = result?;

        // A spawned *leaf* function (no spawns/syncs of its own) is a task
        // whose whole body is sequential: its return value is the send.
        if kernel.kind == FuncKind::Leaf {
            return self.deliver(inst.cont, value);
        }
        Ok(())
    }

    /// Live (unfreed) closures — must be zero after a clean drain.
    pub fn live_closures(&self) -> usize {
        self.live_closures
    }
}

impl<'m, X: XlaHandler> Machine for ExplicitExec<'m, X> {
    fn on_dispatch(&mut self, fid: FuncId, _depth: usize) -> Result<()> {
        // Hotness profile: once per frame entry, one relaxed load when off.
        if crate::obs::profile_enabled() {
            if let Some(k) = &self.kernels {
                crate::obs::profile::hit(&k.kernel(fid).name);
            }
        }
        Ok(())
    }

    fn jit(&mut self) -> Option<Arc<exec::jit::JitTier>> {
        self.jit.clone()
    }

    fn load(&mut self, arr: GlobalId, index: i64) -> Result<Value> {
        self.memory.load(arr, index)
    }

    fn store(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()> {
        self.memory.store(arr, index, value)
    }

    fn atomic_add(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()> {
        self.memory.atomic_add(arr, index, value)
    }

    fn xla_call(&mut self, fid: FuncId, args: &[Value]) -> Result<Value> {
        let prog = Arc::clone(self.kernels.as_ref().expect("kernels"));
        self.xla.call(&prog.kernel(fid).name, args, &mut self.memory)
    }

    fn make_closure(&mut self, task: FuncId) -> Result<Value> {
        let slots: Vec<Value> = {
            let prog = self.kernels.as_ref().expect("kernels");
            prog.kernel(task).param_tys.iter().map(|&t| Value::zero_of(t)).collect()
        };
        let c = Closure {
            task,
            slots,
            cont: self.cur_cont,
            counter: 1, // creator hold
            freed: false,
        };
        let handle = self.alloc_closure(c);
        Ok(Value::I64(handle as i64))
    }

    fn closure_store(&mut self, clos: Value, field: u32, value: Value) -> Result<()> {
        let h = clos.as_i64() as usize;
        let task = self.closures[h].task;
        let ty = self
            .kernels
            .as_ref()
            .expect("kernels")
            .kernel(task)
            .param_tys[field as usize];
        self.closures[h].slots[field as usize] = value.coerce(ty);
        Ok(())
    }

    fn spawn_child(&mut self, callee: FuncId, args: &[Value], ret: KontRef) -> Result<()> {
        let cont = match ret {
            KontRef::Slot { clos, field } => {
                let h = clos.as_i64() as usize;
                self.closures[h].counter += 1;
                Cont::Slot { clos: h, slot: field }
            }
            KontRef::Counter { clos } => {
                let h = clos.as_i64() as usize;
                self.closures[h].counter += 1;
                Cont::Counter { clos: h }
            }
            KontRef::Forward => self.cur_cont,
        };
        self.ready.push_back(TaskInst {
            task: callee,
            args: ArgList::from_slice(args),
            cont,
        });
        Ok(())
    }

    fn close_spawns(&mut self, clos: Value) -> Result<()> {
        let h = clos.as_i64() as usize;
        {
            let c = &mut self.closures[h];
            if c.freed {
                bail!("close_spawns on freed closure");
            }
            c.counter -= 1;
        }
        self.fire_if_ready(h);
        Ok(())
    }

    fn send_argument(&mut self, value: Value) -> Result<()> {
        self.deliver(self.cur_cont, value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::NoXla;
    use crate::lower::{compile, CompileOptions};

    fn run_both_orders(src: &str, name: &str, args: &[i64]) -> (i64, ExecStats) {
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let vals: Vec<Value> = args.iter().map(|&a| Value::I64(a)).collect();
        let mut results = Vec::new();
        let mut stats = None;
        for order in [Order::Lifo, Order::Fifo] {
            let mem = Memory::new(&r.explicit);
            let mut ex = ExplicitExec::new(&r.explicit, mem, NoXla);
            ex.order = order;
            let v = ex.run(name, &vals).unwrap();
            assert_eq!(ex.live_closures(), 0, "no leaked closures ({order:?})");
            results.push(v.as_i64());
            stats = Some(ex.stats.clone());
        }
        assert_eq!(results[0], results[1], "order-independent result");
        (results[0], stats.unwrap())
    }

    const FIB: &str = "int fib(int n) {
        if (n < 2) return n;
        int x = cilk_spawn fib(n - 1);
        int y = cilk_spawn fib(n - 2);
        cilk_sync;
        return x + y;
    }";

    #[test]
    fn fib_explicit_matches_reference() {
        for (n, expect) in [(0, 0), (1, 1), (5, 5), (10, 55), (15, 610)] {
            let (v, _) = run_both_orders(FIB, "fib", &[n]);
            assert_eq!(v, expect, "fib({n})");
        }
    }

    #[test]
    fn fib_task_counts() {
        let (_, stats) = run_both_orders(FIB, "fib", &[10]);
        // fib(10): 177 calls total; each non-leaf call runs entry +
        // continuation, each leaf (n<2) runs entry only.
        assert_eq!(stats.per_role["entry"], 177);
        assert_eq!(stats.per_role["continuation"], 88);
        assert_eq!(stats.closures_made, 88);
    }

    #[test]
    fn bfs_tree_explicit() {
        let src = "global int adj_off[6];
            global int adj_edges[4];
            global int visited[5];
            void visit(int n) {
                int off = adj_off[n];
                int end = adj_off[n + 1];
                visited[n] = 1;
                for (int i = off; i < end; i = i + 1) {
                    cilk_spawn visit(adj_edges[i]);
                }
                cilk_sync;
            }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let m = &r.explicit;
        let mut mem = Memory::new(m);
        mem.fill_i64(m.global_by_name("adj_off").unwrap(), &[0, 2, 4, 4, 4, 4]);
        mem.fill_i64(m.global_by_name("adj_edges").unwrap(), &[1, 2, 3, 4]);
        let mut ex = ExplicitExec::new(m, mem, NoXla);
        let v = ex.run("visit", &[Value::I64(0)]).unwrap();
        assert_eq!(v, Value::Unit);
        assert_eq!(
            ex.memory.dump_i64(m.global_by_name("visited").unwrap()),
            vec![1, 1, 1, 1, 1]
        );
        assert_eq!(ex.live_closures(), 0);
    }

    #[test]
    fn bfs_dae_same_result_more_tasks() {
        let src = "global int adj_off[6];
            global int adj_edges[4];
            global int visited[5];
            void visit(int n) {
                #pragma bombyx dae
                int off = adj_off[n];
                #pragma bombyx dae
                int end = adj_off[n + 1];
                visited[n] = 1;
                for (int i = off; i < end; i = i + 1) {
                    cilk_spawn visit(adj_edges[i]);
                }
                cilk_sync;
            }";
        let run_with = |dae: bool| {
            let opts = if dae { CompileOptions::standard() } else { CompileOptions::no_dae() };
            let r = compile("t", src, &opts).unwrap();
            let m = &r.explicit;
            let mut mem = Memory::new(m);
            mem.fill_i64(m.global_by_name("adj_off").unwrap(), &[0, 2, 4, 4, 4, 4]);
            mem.fill_i64(m.global_by_name("adj_edges").unwrap(), &[1, 2, 3, 4]);
            let mut ex = ExplicitExec::new(m, mem, NoXla);
            ex.run("visit", &[Value::I64(0)]).unwrap();
            assert_eq!(ex.live_closures(), 0);
            (
                ex.memory.dump_i64(m.global_by_name("visited").unwrap()),
                ex.stats.clone(),
            )
        };
        let (vis_plain, stats_plain) = run_with(false);
        let (vis_dae, stats_dae) = run_with(true);
        assert_eq!(vis_plain, vis_dae);
        // DAE adds access tasks.
        assert!(stats_dae.per_role.contains_key("access"), "{:?}", stats_dae.per_role);
        assert!(stats_dae.tasks_run > stats_plain.tasks_run);
    }

    #[test]
    fn sync_in_loop_iterates() {
        let src = "global int acc[1];
            void work(int n) { atomic_add(acc, 0, n); }
            void f(int n) {
                for (int i = 0; i < n; i = i + 1) {
                    cilk_spawn work(i);
                    cilk_sync;
                }
            }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let m = &r.explicit;
        let mem = Memory::new(m);
        let mut ex = ExplicitExec::new(m, mem, NoXla);
        ex.run("f", &[Value::I64(5)]).unwrap();
        assert_eq!(ex.memory.dump_i64(m.global_by_name("acc").unwrap()), vec![0 + 1 + 2 + 3 + 4]);
        assert_eq!(ex.live_closures(), 0);
    }

    #[test]
    fn nested_spawning_functions() {
        let src = "
            int leafv(int n) { return n * n; }
            int pair(int a, int b) {
                int x = cilk_spawn leaf2(a);
                int y = cilk_spawn leaf2(b);
                cilk_sync;
                return x + y;
            }
            int leaf2(int n) { return n + 1; }
            int top(int n) {
                int p = cilk_spawn pair(n, n * 2);
                int q = cilk_spawn pair(n + 1, 0);
                cilk_sync;
                int l = leafv(p);
                return l + q;
            }";
        let (v, _) = run_both_orders(src, "top", &[3]);
        // pair(3,6) = 4+7 = 11; pair(4,0) = 5+1 = 6; leafv(11)=121; 121+6.
        assert_eq!(v, 127);
    }
}
