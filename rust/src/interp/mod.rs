//! Interpreters over both IRs.
//!
//! - [`oracle`]: sequential depth-first execution of the *implicit* IR —
//!   the semantic reference every other execution engine (explicit
//!   executor, work-stealing runtime, HardCilk simulator) is tested
//!   against.
//! - [`explicit_exec`]: a single-threaded scheduler for the *explicit* IR
//!   (closures, join counters, send_argument) — the Cilk-1 abstract
//!   machine, and the functional core reused by the cycle simulator.

pub mod explicit_exec;
pub mod oracle;

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::frontend::ast::Type;
use crate::ir::cfg::{GlobalId, Module};
use crate::ir::expr::Value;

/// Simulated shared memory: one array per `global` declaration (the FPGA's
/// HBM in the paper's setting).
#[derive(Clone, Debug)]
pub struct Memory {
    arrays: Vec<Vec<Value>>,
    elems: Vec<Type>,
}

impl Memory {
    /// Allocate per the module's declared sizes (unsized globals start
    /// empty; use [`Memory::resize`] before running).
    pub fn new(module: &Module) -> Memory {
        let mut arrays = Vec::new();
        let mut elems = Vec::new();
        for (_, g) in module.globals.iter() {
            let len = g.size.unwrap_or(0) as usize;
            arrays.push(vec![Value::zero_of(g.elem); len]);
            elems.push(g.elem);
        }
        Memory { arrays, elems }
    }

    pub fn resize(&mut self, id: GlobalId, len: usize) {
        let z = Value::zero_of(self.elems[id.index()]);
        self.arrays[id.index()].resize(len, z);
    }

    pub fn resize_by_name(&mut self, module: &Module, name: &str, len: usize) -> Result<()> {
        let id = module
            .global_by_name(name)
            .ok_or_else(|| anyhow!("no global named `{name}`"))?;
        self.resize(id, len);
        Ok(())
    }

    pub fn len(&self, id: GlobalId) -> usize {
        self.arrays[id.index()].len()
    }

    pub fn is_empty(&self, id: GlobalId) -> bool {
        self.arrays[id.index()].is_empty()
    }

    #[inline]
    pub fn load(&self, id: GlobalId, index: i64) -> Result<Value> {
        self.arrays[id.index()]
            .get(index as usize)
            .copied()
            .ok_or_else(|| {
                anyhow!(
                    "out-of-bounds load: global #{} index {} (len {})",
                    id.index(),
                    index,
                    self.arrays[id.index()].len()
                )
            })
    }

    #[inline]
    pub fn store(&mut self, id: GlobalId, index: i64, value: Value) -> Result<()> {
        let elem = self.elems[id.index()];
        let arr = &mut self.arrays[id.index()];
        let len = arr.len();
        let slot = arr.get_mut(index as usize).ok_or_else(|| {
            anyhow!("out-of-bounds store: global #{} index {} (len {})", id.index(), index, len)
        })?;
        *slot = value.coerce(elem);
        Ok(())
    }

    #[inline]
    pub fn atomic_add(&mut self, id: GlobalId, index: i64, value: Value) -> Result<()> {
        let old = self.load(id, index)?;
        let elem = self.elems[id.index()];
        let new = match elem {
            Type::Float => Value::F32(old.as_f32() + value.as_f32()),
            _ => Value::I64(old.as_i64().wrapping_add(value.as_i64())),
        };
        self.store(id, index, new)
    }

    /// Snapshot an array as i64 (test helper).
    pub fn dump_i64(&self, id: GlobalId) -> Vec<i64> {
        self.arrays[id.index()].iter().map(|v| v.as_i64()).collect()
    }

    pub fn dump_f32(&self, id: GlobalId) -> Vec<f32> {
        self.arrays[id.index()].iter().map(|v| v.as_f32()).collect()
    }

    /// Fill an array from i64 values (coerced to the element type).
    pub fn fill_i64(&mut self, id: GlobalId, values: &[i64]) {
        let elem = self.elems[id.index()];
        self.arrays[id.index()] =
            values.iter().map(|&v| Value::I64(v).coerce(elem)).collect();
    }

    pub fn fill_f32(&mut self, id: GlobalId, values: &[f32]) {
        let elem = self.elems[id.index()];
        self.arrays[id.index()] =
            values.iter().map(|&v| Value::F32(v).coerce(elem)).collect();
    }
}

/// Handler for `extern xla` tasks in scalar execution contexts (the oracle,
/// the explicit executor, the WS runtime's reference mode). The production
/// path batches these through the AOT XLA executable instead
/// (`coordinator::batcher`); equivalence between the two is tested.
pub trait XlaHandler {
    fn call(&mut self, name: &str, args: &[Value], memory: &mut Memory) -> Result<Value>;
}

/// Rejects any xla call — for programs that don't use `extern xla`.
pub struct NoXla;

impl XlaHandler for NoXla {
    fn call(&mut self, name: &str, _args: &[Value], _memory: &mut Memory) -> Result<Value> {
        Err(anyhow!("program spawned `extern xla` task `{name}` but no XLA handler is installed"))
    }
}

/// Scalar handler built from a plain function map (used by workloads to
/// provide the reference datapath).
#[derive(Default)]
pub struct FnXla {
    #[allow(clippy::type_complexity)]
    pub fns: HashMap<String, Box<dyn FnMut(&[Value], &mut Memory) -> Result<Value>>>,
}

impl FnXla {
    pub fn register(
        &mut self,
        name: &str,
        f: impl FnMut(&[Value], &mut Memory) -> Result<Value> + 'static,
    ) {
        self.fns.insert(name.to_string(), Box::new(f));
    }
}

impl XlaHandler for FnXla {
    fn call(&mut self, name: &str, args: &[Value], memory: &mut Memory) -> Result<Value> {
        let f = self
            .fns
            .get_mut(name)
            .ok_or_else(|| anyhow!("no scalar implementation registered for xla task `{name}`"))?;
        f(args, memory)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::cfg::Global;

    fn memory_with(elem: Type, size: u64) -> (Module, Memory) {
        let mut m = Module::default();
        m.globals.push(Global { name: "a".into(), elem, size: Some(size) });
        let mem = Memory::new(&m);
        (m, mem)
    }

    #[test]
    fn load_store_roundtrip() {
        let (_m, mut mem) = memory_with(Type::Int, 4);
        let g = GlobalId::new(0);
        mem.store(g, 2, Value::I64(42)).unwrap();
        assert_eq!(mem.load(g, 2).unwrap(), Value::I64(42));
        assert_eq!(mem.load(g, 0).unwrap(), Value::I64(0));
    }

    #[test]
    fn oob_is_error_not_panic() {
        let (_m, mut mem) = memory_with(Type::Int, 4);
        let g = GlobalId::new(0);
        assert!(mem.load(g, 4).is_err());
        assert!(mem.load(g, -1).is_err());
        assert!(mem.store(g, 100, Value::I64(1)).is_err());
    }

    #[test]
    fn atomic_add_accumulates() {
        let (_m, mut mem) = memory_with(Type::Int, 1);
        let g = GlobalId::new(0);
        for _ in 0..5 {
            mem.atomic_add(g, 0, Value::I64(3)).unwrap();
        }
        assert_eq!(mem.load(g, 0).unwrap(), Value::I64(15));
    }

    #[test]
    fn float_memory_coerces() {
        let (_m, mut mem) = memory_with(Type::Float, 2);
        let g = GlobalId::new(0);
        mem.store(g, 0, Value::I64(3)).unwrap();
        assert_eq!(mem.load(g, 0).unwrap(), Value::F32(3.0));
        mem.atomic_add(g, 0, Value::F32(0.5)).unwrap();
        assert_eq!(mem.load(g, 0).unwrap(), Value::F32(3.5));
    }

    #[test]
    fn resize_zero_fills() {
        let (_m, mut mem) = memory_with(Type::Int, 0);
        let g = GlobalId::new(0);
        assert!(mem.is_empty(g));
        mem.resize(g, 8);
        assert_eq!(mem.len(g), 8);
        assert_eq!(mem.load(g, 7).unwrap(), Value::I64(0));
    }
}
