//! Sequential oracle: depth-first execution of the implicit IR.
//!
//! `cilk_spawn` runs the child immediately (serial elision — the C elision
//! of a Cilk program is a valid execution), `cilk_sync` is a no-op. This is
//! the ground truth for all parallel engines; any deterministic Cilk-C
//! program must produce identical results on every engine.
//!
//! Execution runs on the shared kernel layer ([`crate::exec`]): the
//! implicit module is compiled once into register bytecode (spawns become
//! sequential [`crate::exec::KOp::SpawnSeq`] calls) and the oracle is just
//! the [`Machine`] that supplies memory, the scalar XLA handler and the
//! call/spawn/load counters.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::exec::{self, run_kernel, KStack, KernelMode, KernelProgram, Machine};
use crate::ir::cfg::{FuncId, GlobalId, Module};
use crate::ir::expr::Value;

use super::{Memory, XlaHandler};

/// Execution statistics (used by tests and compile-time benches).
#[derive(Clone, Debug, Default)]
pub struct OracleStats {
    pub calls: u64,
    pub spawns: u64,
    pub loads: u64,
    pub stores: u64,
    pub max_depth: u64,
    /// Kernel instructions retired (cumulative over this oracle's runs; a
    /// fused superinstruction retires as one dispatch).
    pub instrs: u64,
}

pub struct Oracle<'m, X: XlaHandler> {
    pub module: &'m Module,
    pub memory: Memory,
    pub xla: X,
    pub stats: OracleStats,
    /// Recursion guard (serial elision is recursive; runaway programs
    /// should error, not blow the stack).
    pub max_depth_limit: u64,
    kernels: Option<Arc<KernelProgram>>,
    stack: KStack,
    /// Explicit JIT selection (`None` = process-environment default).
    jit_cfg: Option<exec::jit::JitConfig>,
    /// Native-tier handle, resolved once kernels exist.
    jit: Option<Arc<exec::jit::JitTier>>,
}

impl<'m, X: XlaHandler> Oracle<'m, X> {
    pub fn new(module: &'m Module, memory: Memory, xla: X) -> Self {
        Oracle {
            module,
            memory,
            xla,
            stats: OracleStats::default(),
            max_depth_limit: 1_000_000,
            kernels: None,
            stack: KStack::new(),
            jit_cfg: None,
            jit: None,
        }
    }

    /// Select the JIT configuration explicitly (overriding the
    /// `BOMBYX_JIT` environment default) — e.g.
    /// [`exec::jit::JitConfig::disabled`] pins a test to the interpreter.
    pub fn set_jit(&mut self, cfg: exec::jit::JitConfig) {
        self.jit_cfg = Some(cfg);
        self.resolve_jit();
    }

    fn resolve_jit(&mut self) {
        self.jit = match (&self.kernels, self.jit_cfg) {
            (Some(k), Some(cfg)) => exec::jit::tier_with(k, cfg),
            (Some(k), None) => exec::jit::tier_for(k),
            (None, _) => None,
        };
    }

    /// Reuse an already-compiled kernel program (the session-cached
    /// artifact) instead of compiling on first run.
    pub fn with_kernels(
        module: &'m Module,
        memory: Memory,
        xla: X,
        kernels: Arc<KernelProgram>,
    ) -> Self {
        let mut o = Oracle::new(module, memory, xla);
        o.kernels = Some(kernels);
        o.resolve_jit();
        o
    }

    fn ensure_kernels(&mut self) -> Result<Arc<KernelProgram>> {
        if self.kernels.is_none() {
            self.kernels =
                Some(Arc::new(exec::compile_module(self.module, KernelMode::Implicit)?));
            self.resolve_jit();
        }
        Ok(Arc::clone(self.kernels.as_ref().expect("kernels just compiled")))
    }

    /// Run a function by name with the given arguments.
    pub fn run(&mut self, name: &str, args: &[Value]) -> Result<Value> {
        let fid = self
            .module
            .func_by_name(name)
            .ok_or_else(|| anyhow!("no function named `{name}`"))?;
        self.call(fid, args)
    }

    pub fn call(&mut self, fid: FuncId, args: &[Value]) -> Result<Value> {
        let prog = self.ensure_kernels()?;
        if prog.kernel(fid).kind == crate::ir::FuncKind::Xla {
            return self.xla_call(fid, args);
        }
        let mut stack = std::mem::take(&mut self.stack);
        let result = run_kernel(&prog, fid, args, &mut stack, self, 100_000_000);
        self.stack = stack;
        self.stats.instrs = self.stack.retired();
        result
    }
}

impl<'m, X: XlaHandler> Machine for Oracle<'m, X> {
    fn on_dispatch(&mut self, fid: FuncId, depth: usize) -> Result<()> {
        self.stats.calls += 1;
        let d = depth as u64 + 1;
        self.stats.max_depth = self.stats.max_depth.max(d);
        if d > self.max_depth_limit {
            bail!("oracle recursion limit exceeded ({})", self.max_depth_limit);
        }
        // Hotness profile: once per frame entry, one relaxed load when off.
        if crate::obs::profile_enabled() {
            if let Some(k) = &self.kernels {
                crate::obs::profile::hit(&k.kernel(fid).name);
            }
        }
        Ok(())
    }

    fn on_spawn_seq(&mut self) {
        self.stats.spawns += 1;
    }

    fn jit(&mut self) -> Option<Arc<exec::jit::JitTier>> {
        self.jit.clone()
    }

    fn load(&mut self, arr: GlobalId, index: i64) -> Result<Value> {
        self.stats.loads += 1;
        self.memory.load(arr, index)
    }

    fn store(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()> {
        self.stats.stores += 1;
        self.memory.store(arr, index, value)
    }

    fn atomic_add(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()> {
        self.stats.stores += 1;
        self.memory.atomic_add(arr, index, value)
    }

    fn xla_call(&mut self, fid: FuncId, args: &[Value]) -> Result<Value> {
        self.stats.calls += 1;
        let module = self.module;
        self.xla.call(&module.funcs[fid].name, args, &mut self.memory)
    }
}

/// Convenience: run an implicit module function once. Note this compiles
/// the module's kernel program per call — repeated runs over one module
/// should go through [`crate::lower::CompileSession::run_oracle`] (cached
/// kernels) or hold an [`Oracle`] / use [`Oracle::with_kernels`].
pub fn run_oracle(
    module: &Module,
    memory: Memory,
    name: &str,
    args: &[Value],
) -> Result<(Value, Memory)> {
    let mut o = Oracle::new(module, memory, super::NoXla);
    let v = o.run(name, args)?;
    Ok((v, o.memory))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::{compile, CompileOptions};

    fn run(src: &str, name: &str, args: &[i64]) -> i64 {
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let mem = Memory::new(&r.implicit);
        let vals: Vec<Value> = args.iter().map(|&a| Value::I64(a)).collect();
        let (v, _) = run_oracle(&r.implicit, mem, name, &vals).unwrap();
        v.as_i64()
    }

    #[test]
    fn fib_reference_values() {
        let src = "int fib(int n) {
            if (n < 2) return n;
            int x = cilk_spawn fib(n - 1);
            int y = cilk_spawn fib(n - 2);
            cilk_sync;
            return x + y;
        }";
        for (n, expect) in [(0, 0), (1, 1), (2, 1), (5, 5), (10, 55), (15, 610), (20, 6765)] {
            assert_eq!(run(src, "fib", &[n]), expect, "fib({n})");
        }
    }

    #[test]
    fn loops_and_arithmetic() {
        let src = "int sumsq(int n) {
            int acc = 0;
            for (int i = 1; i <= n; i = i + 1) { acc = acc + i * i; }
            return acc;
        }";
        assert_eq!(run(src, "sumsq", &[5]), 55);
        assert_eq!(run(src, "sumsq", &[0]), 0);
    }

    #[test]
    fn leaf_calls() {
        let src = "int double_(int a) { return a * 2; }
                   int f(int n) { int d = double_(n); return d + 1; }";
        assert_eq!(run(src, "f", &[10]), 21);
    }

    #[test]
    fn memory_program() {
        let src = "global int a[8];
            void fill(int n) {
                for (int i = 0; i < n; i = i + 1) { a[i] = i * 3; }
            }
            int sum(int n) {
                int acc = 0;
                for (int i = 0; i < n; i = i + 1) { acc = acc + a[i]; }
                return acc;
            }
            int go(int n) { fill(n); int s = sum(n); return s; }";
        assert_eq!(run(src, "go", &[8]), 3 * (0 + 1 + 2 + 3 + 4 + 5 + 6 + 7));
    }

    #[test]
    fn bfs_tree_marks_all_nodes() {
        // Tiny tree: 0 -> 1,2 ; 1 -> 3,4 ; adjacency in CSR form.
        let src = "global int adj_off[6];
            global int adj_edges[4];
            global int visited[5];
            void visit(int n) {
                int off = adj_off[n];
                int end = adj_off[n + 1];
                visited[n] = 1;
                for (int i = off; i < end; i = i + 1) {
                    cilk_spawn visit(adj_edges[i]);
                }
                cilk_sync;
            }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let m = &r.implicit;
        let mut mem = Memory::new(m);
        mem.fill_i64(m.global_by_name("adj_off").unwrap(), &[0, 2, 4, 4, 4, 4]);
        mem.fill_i64(m.global_by_name("adj_edges").unwrap(), &[1, 2, 3, 4]);
        let (_, mem) = run_oracle(m, mem, "visit", &[Value::I64(0)]).unwrap();
        assert_eq!(mem.dump_i64(m.global_by_name("visited").unwrap()), vec![1, 1, 1, 1, 1]);
    }

    #[test]
    fn float_arithmetic() {
        let src = "float scale(float x, int n) {
            float acc = x;
            for (int i = 0; i < n; i = i + 1) { acc = acc * 1.5; }
            return acc;
        }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let mem = Memory::new(&r.implicit);
        let (v, _) =
            run_oracle(&r.implicit, mem, "scale", &[Value::F32(2.0), Value::I64(3)]).unwrap();
        assert_eq!(v, Value::F32(6.75));
    }

    #[test]
    fn infinite_loop_errors() {
        let src = "int f(int n) { while (true) { n = n + 1; } return n; }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let mem = Memory::new(&r.implicit);
        let err = run_oracle(&r.implicit, mem, "f", &[Value::I64(0)]).unwrap_err();
        assert!(err.to_string().contains("step limit"));
    }

    #[test]
    fn stats_count_calls_spawns_and_memory_ops() {
        let src = "global int acc[1];
            int fib(int n) {
                if (n < 2) return n;
                int x = cilk_spawn fib(n - 1);
                int y = cilk_spawn fib(n - 2);
                cilk_sync;
                atomic_add(acc, 0, 1);
                return x + y;
            }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let mut o = Oracle::new(&r.implicit, Memory::new(&r.implicit), crate::interp::NoXla);
        o.run("fib", &[Value::I64(10)]).unwrap();
        // fib(10): 177 calls, 176 spawns, 88 interior nodes do an atomic.
        assert_eq!(o.stats.calls, 177);
        assert_eq!(o.stats.spawns, 176);
        assert_eq!(o.stats.stores, 88);
        assert!(o.stats.max_depth >= 10);
    }
}
