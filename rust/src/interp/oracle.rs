//! Sequential oracle: depth-first execution of the implicit IR.
//!
//! `cilk_spawn` runs the child immediately (serial elision — the C elision
//! of a Cilk program is a valid execution), `cilk_sync` is a no-op. This is
//! the ground truth for all parallel engines; any deterministic Cilk-C
//! program must produce identical results on every engine.

use anyhow::{anyhow, bail, Result};

use crate::ir::cfg::{Func, FuncId, FuncKind, Module, Op, Term};
use crate::ir::expr::{self, Value, VarId};

use super::{Memory, XlaHandler};

/// Execution statistics (used by tests and compile-time benches).
#[derive(Clone, Debug, Default)]
pub struct OracleStats {
    pub calls: u64,
    pub spawns: u64,
    pub loads: u64,
    pub stores: u64,
    pub max_depth: u64,
}

pub struct Oracle<'m, X: XlaHandler> {
    pub module: &'m Module,
    pub memory: Memory,
    pub xla: X,
    pub stats: OracleStats,
    depth: u64,
    /// Recursion guard (the oracle is recursive; runaway programs should
    /// error, not blow the stack).
    pub max_depth_limit: u64,
}

impl<'m, X: XlaHandler> Oracle<'m, X> {
    pub fn new(module: &'m Module, memory: Memory, xla: X) -> Self {
        Oracle { module, memory, xla, stats: OracleStats::default(), depth: 0, max_depth_limit: 1_000_000 }
    }

    /// Run a function by name with the given arguments.
    pub fn run(&mut self, name: &str, args: &[Value]) -> Result<Value> {
        let fid = self
            .module
            .func_by_name(name)
            .ok_or_else(|| anyhow!("no function named `{name}`"))?;
        self.call(fid, args)
    }

    pub fn call(&mut self, fid: FuncId, args: &[Value]) -> Result<Value> {
        self.depth += 1;
        self.stats.max_depth = self.stats.max_depth.max(self.depth);
        if self.depth > self.max_depth_limit {
            bail!("oracle recursion limit exceeded ({})", self.max_depth_limit);
        }
        let result = self.call_inner(fid, args);
        self.depth -= 1;
        result
    }

    fn call_inner(&mut self, fid: FuncId, args: &[Value]) -> Result<Value> {
        self.stats.calls += 1;
        let func: &Func = &self.module.funcs[fid];
        if func.kind == FuncKind::Xla {
            let name = func.name.clone();
            return self.xla.call(&name, args, &mut self.memory);
        }
        let cfg = func.cfg();
        if args.len() != func.params {
            bail!("`{}` expects {} args, got {}", func.name, func.params, args.len());
        }
        let mut env: Vec<Value> = func
            .vars
            .values()
            .map(|v| Value::zero_of(v.ty))
            .collect();
        for (i, &a) in args.iter().enumerate() {
            env[i] = a.coerce(func.vars[VarId::new(i)].ty);
        }

        let mut block = cfg.entry;
        let mut steps: u64 = 0;
        loop {
            steps += 1;
            if steps > 100_000_000 {
                bail!("`{}` exceeded step limit (infinite loop?)", func.name);
            }
            let b = &cfg.blocks[block];
            for op in &b.ops {
                match op {
                    Op::Assign { dst, src } => {
                        let v = expr::eval(src, &|v| env[v.index()]);
                        env[dst.index()] = v.coerce(func.vars[*dst].ty);
                    }
                    Op::Load { dst, arr, index, .. } => {
                        self.stats.loads += 1;
                        let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                        env[dst.index()] = self.memory.load(*arr, idx)?;
                    }
                    Op::Store { arr, index, value } => {
                        self.stats.stores += 1;
                        let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                        let val = expr::eval(value, &|v| env[v.index()]);
                        self.memory.store(*arr, idx, val)?;
                    }
                    Op::AtomicAdd { arr, index, value } => {
                        self.stats.stores += 1;
                        let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                        let val = expr::eval(value, &|v| env[v.index()]);
                        self.memory.atomic_add(*arr, idx, val)?;
                    }
                    Op::Call { dst, callee, args } => {
                        let vals: Vec<Value> =
                            args.iter().map(|a| expr::eval(a, &|v| env[v.index()])).collect();
                        let r = self.call(*callee, &vals)?;
                        if let Some(d) = dst {
                            env[d.index()] = r.coerce(func.vars[*d].ty);
                        }
                    }
                    Op::Spawn { dst, callee, args } => {
                        self.stats.spawns += 1;
                        let vals: Vec<Value> =
                            args.iter().map(|a| expr::eval(a, &|v| env[v.index()])).collect();
                        let r = self.call(*callee, &vals)?;
                        if let Some(d) = dst {
                            env[d.index()] = r.coerce(func.vars[*d].ty);
                        }
                    }
                    other => bail!("oracle runs implicit IR only, found {other:?}"),
                }
            }
            match &b.term {
                Term::Jump(next) => block = *next,
                Term::Sync { next } => block = *next, // children already ran
                Term::Branch { cond, then_, else_ } => {
                    let c = expr::eval(cond, &|v| env[v.index()]).as_bool();
                    block = if c { *then_ } else { *else_ };
                }
                Term::Return(value) => {
                    return Ok(match value {
                        Some(e) => {
                            expr::eval(e, &|v| env[v.index()]).coerce(func.ret)
                        }
                        None => Value::Unit,
                    });
                }
                Term::Halt => bail!("oracle runs implicit IR only (Halt found)"),
            }
        }
    }
}

/// Convenience: compile nothing, just run an implicit module function.
pub fn run_oracle(
    module: &Module,
    memory: Memory,
    name: &str,
    args: &[Value],
) -> Result<(Value, Memory)> {
    let mut o = Oracle::new(module, memory, super::NoXla);
    let v = o.run(name, args)?;
    Ok((v, o.memory))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::{compile, CompileOptions};

    fn run(src: &str, name: &str, args: &[i64]) -> i64 {
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let mem = Memory::new(&r.implicit);
        let vals: Vec<Value> = args.iter().map(|&a| Value::I64(a)).collect();
        let (v, _) = run_oracle(&r.implicit, mem, name, &vals).unwrap();
        v.as_i64()
    }

    #[test]
    fn fib_reference_values() {
        let src = "int fib(int n) {
            if (n < 2) return n;
            int x = cilk_spawn fib(n - 1);
            int y = cilk_spawn fib(n - 2);
            cilk_sync;
            return x + y;
        }";
        for (n, expect) in [(0, 0), (1, 1), (2, 1), (5, 5), (10, 55), (15, 610), (20, 6765)] {
            assert_eq!(run(src, "fib", &[n]), expect, "fib({n})");
        }
    }

    #[test]
    fn loops_and_arithmetic() {
        let src = "int sumsq(int n) {
            int acc = 0;
            for (int i = 1; i <= n; i = i + 1) { acc = acc + i * i; }
            return acc;
        }";
        assert_eq!(run(src, "sumsq", &[5]), 55);
        assert_eq!(run(src, "sumsq", &[0]), 0);
    }

    #[test]
    fn leaf_calls() {
        let src = "int double_(int a) { return a * 2; }
                   int f(int n) { int d = double_(n); return d + 1; }";
        assert_eq!(run(src, "f", &[10]), 21);
    }

    #[test]
    fn memory_program() {
        let src = "global int a[8];
            void fill(int n) {
                for (int i = 0; i < n; i = i + 1) { a[i] = i * 3; }
            }
            int sum(int n) {
                int acc = 0;
                for (int i = 0; i < n; i = i + 1) { acc = acc + a[i]; }
                return acc;
            }
            int go(int n) { fill(n); int s = sum(n); return s; }";
        assert_eq!(run(src, "go", &[8]), 3 * (0 + 1 + 2 + 3 + 4 + 5 + 6 + 7));
    }

    #[test]
    fn bfs_tree_marks_all_nodes() {
        // Tiny tree: 0 -> 1,2 ; 1 -> 3,4 ; adjacency in CSR form.
        let src = "global int adj_off[6];
            global int adj_edges[4];
            global int visited[5];
            void visit(int n) {
                int off = adj_off[n];
                int end = adj_off[n + 1];
                visited[n] = 1;
                for (int i = off; i < end; i = i + 1) {
                    cilk_spawn visit(adj_edges[i]);
                }
                cilk_sync;
            }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let m = &r.implicit;
        let mut mem = Memory::new(m);
        mem.fill_i64(m.global_by_name("adj_off").unwrap(), &[0, 2, 4, 4, 4, 4]);
        mem.fill_i64(m.global_by_name("adj_edges").unwrap(), &[1, 2, 3, 4]);
        let (_, mem) = run_oracle(m, mem, "visit", &[Value::I64(0)]).unwrap();
        assert_eq!(mem.dump_i64(m.global_by_name("visited").unwrap()), vec![1, 1, 1, 1, 1]);
    }

    #[test]
    fn float_arithmetic() {
        let src = "float scale(float x, int n) {
            float acc = x;
            for (int i = 0; i < n; i = i + 1) { acc = acc * 1.5; }
            return acc;
        }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let mem = Memory::new(&r.implicit);
        let (v, _) =
            run_oracle(&r.implicit, mem, "scale", &[Value::F32(2.0), Value::I64(3)]).unwrap();
        assert_eq!(v, Value::F32(6.75));
    }

    #[test]
    fn infinite_loop_errors() {
        let src = "int f(int n) { while (true) { n = n + 1; } return n; }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let mem = Memory::new(&r.implicit);
        let err = run_oracle(&r.implicit, mem, "f", &[Value::I64(0)]).unwrap_err();
        assert!(err.to_string().contains("step limit"));
    }
}
