//! The implicit IR: per-function control-flow graphs with `sync` as a
//! block terminator (paper Fig. 4(b)).

use crate::frontend::ast::Type;
use crate::util::idvec::{Id, IdVec};

use super::expr::{Expr, Var, VarId};

/// A shared-memory array (models device HBM; the FPGA's off-chip memory).
#[derive(Clone, Debug)]
pub struct Global {
    pub name: String,
    pub elem: Type,
    /// Declared element count (`None` = sized by the driver at load time).
    pub size: Option<u64>,
}

pub type GlobalId = Id<Global>;

/// A compilation unit after AST lowering.
#[derive(Clone, Debug, Default)]
pub struct Module {
    pub globals: IdVec<Global>,
    pub funcs: IdVec<Func>,
}

impl Module {
    pub fn func_by_name(&self, name: &str) -> Option<FuncId> {
        self.funcs.iter().find(|(_, f)| f.name == name).map(|(id, _)| id)
    }

    pub fn global_by_name(&self, name: &str) -> Option<GlobalId> {
        self.globals.iter().find(|(_, g)| g.name == name).map(|(id, _)| id)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FuncKind {
    /// Ordinary Cilk-C function (may spawn).
    Task,
    /// Spawn-free function callable sequentially (HLS would inline it).
    Leaf,
    /// `extern xla` — body is the AOT-compiled XLA PE datapath.
    Xla,
}

/// Role of an explicit task within its source function (paper §III's PE
/// taxonomy: spawner / executor / access).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskRole {
    /// The path starting at the source function's entry.
    Entry,
    /// A continuation task (entered through `spawn_next` at a sync).
    Continuation,
    /// A re-entered join block (loop header crossing task boundaries).
    Join,
    /// A DAE-extracted memory access task.
    Access,
    /// An `extern xla` task (batched XLA PE datapath).
    Xla,
}

impl TaskRole {
    pub fn name(self) -> &'static str {
        match self {
            TaskRole::Entry => "entry",
            TaskRole::Continuation => "continuation",
            TaskRole::Join => "join",
            TaskRole::Access => "access",
            TaskRole::Xla => "xla",
        }
    }
}

/// Metadata attached to a function once it has been explicitized into a
/// Cilk-1 task.
#[derive(Clone, Debug)]
pub struct TaskMeta {
    pub role: TaskRole,
    /// Type of the value this task eventually `send_argument`s to its
    /// continuation (`Void` = pure completion notification).
    pub cont_ty: Type,
    /// Name of the originating Cilk-C function.
    pub source: String,
}

#[derive(Clone, Debug)]
pub struct Func {
    pub name: String,
    pub ret: Type,
    /// The first `params` entries of `vars` are the parameters, in order.
    pub params: usize,
    pub vars: IdVec<Var>,
    /// `None` for `extern xla` declarations.
    pub body: Option<Cfg>,
    pub kind: FuncKind,
    /// `Some` once this function is an explicit Cilk-1 task.
    pub task: Option<TaskMeta>,
}

pub type FuncId = Id<Func>;

impl Func {
    pub fn param_ids(&self) -> impl Iterator<Item = VarId> + '_ {
        (0..self.params).map(VarId::new)
    }

    pub fn cfg(&self) -> &Cfg {
        self.body.as_ref().expect("function has no body")
    }

    pub fn cfg_mut(&mut self) -> &mut Cfg {
        self.body.as_mut().expect("function has no body")
    }

    /// Does any block contain a spawn?
    pub fn has_spawns(&self) -> bool {
        self.body
            .as_ref()
            .map(|cfg| {
                cfg.blocks
                    .values()
                    .any(|b| b.ops.iter().any(|op| matches!(op, Op::Spawn { .. })))
            })
            .unwrap_or(false)
    }

    /// Does any block end in a sync?
    pub fn has_syncs(&self) -> bool {
        self.body
            .as_ref()
            .map(|cfg| cfg.blocks.values().any(|b| matches!(b.term, Term::Sync { .. })))
            .unwrap_or(false)
    }
}

#[derive(Clone, Debug, Default)]
pub struct Cfg {
    pub blocks: IdVec<Block>,
    pub entry: BlockId,
}

pub type BlockId = Id<Block>;

#[derive(Clone, Debug, Default)]
pub struct Block {
    pub ops: Vec<Op>,
    pub term: Term,
}

pub type FieldIdx = u32;

/// Where a spawned child delivers its result (explicit IR only).
#[derive(Clone, Debug, PartialEq)]
pub enum RetTarget {
    /// Fill field `field` of the closure held in `clos`, then decrement its
    /// join counter. (`send_argument` into a hole.)
    Slot { clos: VarId, field: FieldIdx },
    /// Void child: only decrement the closure's join counter.
    Counter { clos: VarId },
    /// Tail transition: the child inherits this task's own continuation.
    Forward,
}

/// Straight-line operations. The first group exists in both IRs; the
/// `--- explicit IR only ---` group is introduced by explicitization
/// (Cilk-1's `spawn_next` / `send_argument`, paper Fig. 2).
#[derive(Clone, Debug)]
pub enum Op {
    /// `dst = expr`
    Assign { dst: VarId, src: Expr },
    /// `dst = arr[index]` — the memory-access primitive. `dae` marks it as
    /// annotated by `#pragma bombyx dae`.
    Load { dst: VarId, arr: GlobalId, index: Expr, dae: bool },
    /// `arr[index] = value`
    Store { arr: GlobalId, index: Expr, value: Expr },
    /// `atomic_add(arr, index, value)`
    AtomicAdd { arr: GlobalId, index: Expr, value: Expr },
    /// Sequential call to a leaf function.
    Call { dst: Option<VarId>, callee: FuncId, args: Vec<Expr> },
    /// `cilk_spawn` — `dst` is `None` for void spawns. (Implicit IR only.)
    Spawn { dst: Option<VarId>, callee: FuncId, args: Vec<Expr> },

    // --- explicit IR only -------------------------------------------------
    /// `spawn_next`: allocate a closure for continuation task `task` with
    /// join counter 1 (the creator's hold — see DESIGN.md §6.2) and bind the
    /// handle to `dst`. The current task's continuation is forwarded into
    /// the closure's cont slot.
    MakeClosure { dst: VarId, task: FuncId },
    /// Write a ready argument into closure param slot `field`.
    ClosureStore { clos: VarId, field: FieldIdx, value: Expr },
    /// `spawn`: enqueue child task. Increments the target closure's join
    /// counter *before* the child becomes runnable (race-free dynamic join).
    SpawnChild { callee: FuncId, args: Vec<Expr>, ret: RetTarget },
    /// Drop the creator's hold on the closure; it fires when the counter
    /// reaches zero.
    CloseSpawns { clos: VarId },
    /// `send_argument(k, value)`: deliver to this task's continuation.
    SendArgument { value: Option<Expr> },
}

impl Op {
    /// Variable defined by this op, if any.
    pub fn def(&self) -> Option<VarId> {
        match self {
            Op::Assign { dst, .. } | Op::Load { dst, .. } | Op::MakeClosure { dst, .. } => {
                Some(*dst)
            }
            Op::Call { dst, .. } | Op::Spawn { dst, .. } => *dst,
            Op::Store { .. }
            | Op::AtomicAdd { .. }
            | Op::ClosureStore { .. }
            | Op::SpawnChild { .. }
            | Op::CloseSpawns { .. }
            | Op::SendArgument { .. } => None,
        }
    }

    /// Visit every variable *used* by this op.
    pub fn for_each_use(&self, f: &mut impl FnMut(VarId)) {
        match self {
            Op::Assign { src, .. } => src.for_each_var(f),
            Op::Load { index, .. } => index.for_each_var(f),
            Op::Store { index, value, .. } | Op::AtomicAdd { index, value, .. } => {
                index.for_each_var(f);
                value.for_each_var(f);
            }
            Op::Call { args, .. } | Op::Spawn { args, .. } => {
                args.iter().for_each(|a| a.for_each_var(f))
            }
            Op::MakeClosure { .. } => {}
            Op::ClosureStore { clos, value, .. } => {
                f(*clos);
                value.for_each_var(f);
            }
            Op::SpawnChild { args, ret, .. } => {
                args.iter().for_each(|a| a.for_each_var(f));
                match ret {
                    RetTarget::Slot { clos, .. } | RetTarget::Counter { clos } => f(*clos),
                    RetTarget::Forward => {}
                }
            }
            Op::CloseSpawns { clos } => f(*clos),
            Op::SendArgument { value } => {
                if let Some(v) = value {
                    v.for_each_var(f)
                }
            }
        }
    }

    /// Is this op only valid in the explicit IR?
    pub fn is_explicit_only(&self) -> bool {
        matches!(
            self,
            Op::MakeClosure { .. }
                | Op::ClosureStore { .. }
                | Op::SpawnChild { .. }
                | Op::CloseSpawns { .. }
                | Op::SendArgument { .. }
        )
    }
}

/// Block terminators. `Sync` is a terminator by design — see module docs.
#[derive(Clone, Debug)]
pub enum Term {
    Jump(BlockId),
    Branch { cond: Expr, then_: BlockId, else_: BlockId },
    /// Implicit IR only: return from the function.
    Return(Option<Expr>),
    /// `cilk_sync;` — wait for all children spawned so far, then continue at
    /// `next`. Explicitization cuts the function here. (Implicit IR only.)
    Sync { next: BlockId },
    /// Explicit IR only: the task terminates (it has already delivered its
    /// effects via SendArgument / CloseSpawns / SpawnChild).
    Halt,
}

impl Default for Term {
    fn default() -> Term {
        Term::Return(None)
    }
}

impl Term {
    pub fn successors(&self) -> Vec<BlockId> {
        match self {
            Term::Jump(b) => vec![*b],
            Term::Branch { then_, else_, .. } => vec![*then_, *else_],
            Term::Sync { next } => vec![*next],
            Term::Return(_) | Term::Halt => vec![],
        }
    }

    pub fn for_each_use(&self, f: &mut impl FnMut(VarId)) {
        match self {
            Term::Branch { cond, .. } => cond.for_each_var(f),
            Term::Return(Some(e)) => e.for_each_var(f),
            _ => {}
        }
    }

    /// Rewrite successor block ids through `map`.
    pub fn map_blocks(&self, map: &impl Fn(BlockId) -> BlockId) -> Term {
        match self {
            Term::Jump(b) => Term::Jump(map(*b)),
            Term::Branch { cond, then_, else_ } => {
                Term::Branch { cond: cond.clone(), then_: map(*then_), else_: map(*else_) }
            }
            Term::Sync { next } => Term::Sync { next: map(*next) },
            Term::Return(e) => Term::Return(e.clone()),
            Term::Halt => Term::Halt,
        }
    }
}

impl Cfg {
    /// Predecessor lists, indexed by block. Out-of-range successor ids
    /// (a malformed CFG — the verifier reports them) are skipped rather
    /// than panicking.
    pub fn predecessors(&self) -> Vec<Vec<BlockId>> {
        let mut preds = vec![Vec::new(); self.blocks.len()];
        for (id, block) in self.blocks.iter() {
            for succ in block.term.successors() {
                if succ.index() < self.blocks.len() {
                    preds[succ.index()].push(id);
                }
            }
        }
        preds
    }

    /// Blocks reachable from entry, in reverse post-order.
    pub fn reverse_postorder(&self) -> Vec<BlockId> {
        let mut visited = vec![false; self.blocks.len()];
        let mut order = Vec::new();
        // Iterative DFS with an explicit "post" marker stack.
        let mut stack = vec![(self.entry, false)];
        while let Some((b, post)) = stack.pop() {
            if post {
                order.push(b);
                continue;
            }
            if visited[b.index()] {
                continue;
            }
            visited[b.index()] = true;
            stack.push((b, true));
            for succ in self.blocks[b].term.successors() {
                if succ.index() < self.blocks.len() && !visited[succ.index()] {
                    stack.push((succ, false));
                }
            }
        }
        order.reverse();
        order
    }

    /// Set of blocks reachable from entry.
    pub fn reachable(&self) -> Vec<bool> {
        let mut seen = vec![false; self.blocks.len()];
        let mut stack = vec![self.entry];
        while let Some(b) = stack.pop() {
            if std::mem::replace(&mut seen[b.index()], true) {
                continue;
            }
            for succ in self.blocks[b].term.successors() {
                if succ.index() < self.blocks.len() && !seen[succ.index()] {
                    stack.push(succ);
                }
            }
        }
        seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::ast::BinOp;

    fn var(name: &str) -> Var {
        Var { name: name.into(), ty: Type::Int, is_param: false, is_temp: false }
    }

    /// Build the fib-like diamond: entry -> (ret | spawn-block -> sync -> join)
    fn diamond() -> Cfg {
        let mut cfg = Cfg::default();
        let entry = cfg.blocks.push(Block::default());
        let ret_n = cfg.blocks.push(Block { ops: vec![], term: Term::Return(Some(Expr::ConstI(1))) });
        let spawns = cfg.blocks.push(Block::default());
        let join = cfg.blocks.push(Block { ops: vec![], term: Term::Return(Some(Expr::ConstI(2))) });
        cfg.blocks[entry].term = Term::Branch {
            cond: Expr::Binary(BinOp::Lt, Box::new(Expr::ConstI(0)), Box::new(Expr::ConstI(2))),
            then_: ret_n,
            else_: spawns,
        };
        cfg.blocks[spawns].term = Term::Sync { next: join };
        cfg.entry = entry;
        cfg
    }

    #[test]
    fn predecessors_and_rpo() {
        let cfg = diamond();
        let preds = cfg.predecessors();
        assert_eq!(preds[0], vec![]);
        assert_eq!(preds[1].len(), 1);
        assert_eq!(preds[3].len(), 1);
        let rpo = cfg.reverse_postorder();
        assert_eq!(rpo.len(), 4);
        assert_eq!(rpo[0], cfg.entry);
        // entry precedes all its successors in RPO.
        let pos = |b: BlockId| rpo.iter().position(|x| *x == b).unwrap();
        assert!(pos(BlockId::new(0)) < pos(BlockId::new(2)));
        assert!(pos(BlockId::new(2)) < pos(BlockId::new(3)));
    }

    #[test]
    fn reachable_excludes_orphans() {
        let mut cfg = diamond();
        let orphan = cfg.blocks.push(Block::default());
        let seen = cfg.reachable();
        assert!(seen[0] && seen[1] && seen[2] && seen[3]);
        assert!(!seen[orphan.index()]);
    }

    #[test]
    fn op_def_use() {
        let mut vars: IdVec<Var> = IdVec::new();
        let a = vars.push(var("a"));
        let b = vars.push(var("b"));
        let op = Op::Assign {
            dst: a,
            src: Expr::Binary(BinOp::Add, Box::new(Expr::Var(b)), Box::new(Expr::ConstI(1))),
        };
        assert_eq!(op.def(), Some(a));
        let mut uses = Vec::new();
        op.for_each_use(&mut |v| uses.push(v));
        assert_eq!(uses, vec![b]);
    }
}
