//! Explicit-IR-level metadata: closure layouts and task-graph queries.
//!
//! A *closure* (paper §II, Fig. 2) is the in-memory record created by
//! `spawn_next`: ready arguments, placeholders ("holes") for anticipated
//! dependencies, a return continuation, and a join counter. HardCilk
//! requires each closure padded to a hardware-friendly power-of-two width
//! (§II-B); this module computes those layouts from task signatures.

use crate::frontend::ast::Type;
use crate::util::align::{pow2_bucket, round_up};

use super::cfg::{Func, FuncId, FuncKind, Module, Op, RetTarget};

/// Field offsets/widths of one task's closure.
#[derive(Clone, Debug, PartialEq)]
pub struct ClosureLayout {
    pub task_name: String,
    /// (param name, type, bit offset, bit width) per data parameter, in
    /// parameter order.
    pub fields: Vec<ClosureField>,
    /// Continuation slot offset (every closure carries one: closure address
    /// + slot index of the parent, 64 bits).
    pub cont_offset_bits: u32,
    /// Join-counter offset (32 bits).
    pub counter_offset_bits: u32,
    /// Sum of field widths + cont + counter, before padding.
    pub payload_bits: u32,
    /// Power-of-two padded width (what the queues/memory interface see).
    pub padded_bits: u32,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ClosureField {
    pub name: String,
    pub ty: Type,
    pub offset_bits: u32,
    pub width_bits: u32,
}

/// HardCilk closure width rules (paper §II-B mentions 128/256-bit
/// alignment; HardCilk's generator uses power-of-two buckets).
pub const MIN_CLOSURE_BITS: u32 = 128;
pub const MAX_CLOSURE_BITS: u32 = 1024;
/// Each field is aligned to this boundary so the write buffer can update a
/// hole with a single beat.
pub const FIELD_ALIGN_BITS: u32 = 32;
pub const CONT_SLOT_BITS: u32 = 64;
pub const COUNTER_BITS: u32 = 32;

/// Compute the closure layout for a task function.
pub fn closure_layout(func: &Func) -> ClosureLayout {
    let mut offset = 0u32;
    let mut fields = Vec::new();
    for vid in func.param_ids() {
        let var = &func.vars[vid];
        let width = round_up(var.ty.bits().max(1), FIELD_ALIGN_BITS);
        fields.push(ClosureField {
            name: var.name.clone(),
            ty: var.ty,
            offset_bits: offset,
            width_bits: width,
        });
        offset += width;
    }
    let cont_offset_bits = round_up(offset, CONT_SLOT_BITS);
    offset = cont_offset_bits + CONT_SLOT_BITS;
    let counter_offset_bits = offset;
    offset += COUNTER_BITS;
    ClosureLayout {
        task_name: func.name.clone(),
        fields,
        cont_offset_bits,
        counter_offset_bits,
        payload_bits: offset,
        padded_bits: pow2_bucket(offset, MIN_CLOSURE_BITS, MAX_CLOSURE_BITS),
    }
}

impl ClosureLayout {
    /// Padding overhead the paper's §II-B says users add by hand.
    pub fn padding_bits(&self) -> u32 {
        self.padded_bits - self.payload_bits
    }

    pub fn padded_bytes(&self) -> u32 {
        self.padded_bits / 8
    }
}

/// Task-graph edges for the HardCilk JSON descriptor: which tasks a task may
/// `spawn`, `spawn_next`, or `send_argument` to (paper §II-B).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TaskRelations {
    pub spawns: Vec<FuncId>,
    pub spawn_nexts: Vec<FuncId>,
    /// Tasks whose closures this task fills via child-return or tail
    /// forwarding (conservatively: every continuation it may target).
    pub sends_to: Vec<FuncId>,
}

/// Compute relations for every explicit task in the module.
pub fn task_relations(module: &Module, func: FuncId) -> TaskRelations {
    let mut rel = TaskRelations::default();
    let f = &module.funcs[func];
    let Some(cfg) = f.body.as_ref() else {
        return rel;
    };
    let push_unique = |list: &mut Vec<FuncId>, id: FuncId| {
        if !list.contains(&id) {
            list.push(id);
        }
    };
    for block in cfg.blocks.values() {
        for op in &block.ops {
            match op {
                Op::MakeClosure { task, .. } => push_unique(&mut rel.spawn_nexts, *task),
                Op::SpawnChild { callee, ret, .. } => {
                    push_unique(&mut rel.spawns, *callee);
                    if let RetTarget::Slot { .. } | RetTarget::Counter { .. } = ret {
                        // The child sends into a closure this task created;
                        // recorded on the child's side below.
                    }
                }
                Op::SendArgument { .. } => {
                    // Recorded at module level (see `send_targets`).
                }
                _ => {}
            }
        }
    }
    rel.sends_to = send_targets(module, func);
    rel
}

/// Conservative send-targets: any task that creates a closure whose children
/// include `func` may receive a send_argument from it; plus tail-forward
/// chains. For the descriptor we report the continuation tasks `func`'s
/// sends can land in: every task T such that some task makes a closure for T
/// and spawns `func` against it.
fn send_targets(module: &Module, func: FuncId) -> Vec<FuncId> {
    let mut out = Vec::new();
    for (_, creator) in module.funcs.iter() {
        let Some(cfg) = creator.body.as_ref() else { continue };
        for block in cfg.blocks.values() {
            // Map closure var -> continuation task within this block scan.
            let mut clos_task: Vec<(super::VarId, FuncId)> = Vec::new();
            for op in &block.ops {
                match op {
                    Op::MakeClosure { dst, task } => clos_task.push((*dst, *task)),
                    Op::SpawnChild { callee, ret, .. } if *callee == func => {
                        if let RetTarget::Slot { clos, .. } | RetTarget::Counter { clos } = ret {
                            if let Some((_, t)) =
                                clos_task.iter().find(|(c, _)| c == clos).copied().map(|x| (x.0, x.1)).map(Some).unwrap_or(None)
                            {
                                if !out.contains(&t) {
                                    out.push(t);
                                }
                            }
                        }
                    }
                    _ => {}
                }
            }
        }
    }
    out
}

/// All explicit tasks of a module (functions carrying task metadata).
pub fn explicit_tasks(module: &Module) -> Vec<FuncId> {
    module
        .funcs
        .iter()
        .filter(|(_, f)| f.task.is_some() && f.kind != FuncKind::Leaf)
        .map(|(id, _)| id)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Var;
    use crate::util::idvec::IdVec;

    fn mk_func(name: &str, param_tys: &[Type]) -> Func {
        let mut vars = IdVec::new();
        for (i, &ty) in param_tys.iter().enumerate() {
            vars.push(Var { name: format!("p{i}"), ty, is_param: true, is_temp: false });
        }
        Func {
            name: name.into(),
            ret: Type::Int,
            params: param_tys.len(),
            vars,
            body: None,
            kind: FuncKind::Task,
            task: None,
        }
    }

    #[test]
    fn fib_closure_is_256_bits() {
        // fib continuation: (x: int, y: int) + cont(64) + counter(32)
        // = 64 + 64 + 64 + 32 = 224 -> padded 256. Matches HardCilk's
        // "closures aligned to 128/256 bits".
        let f = mk_func("fib_sync0", &[Type::Int, Type::Int]);
        let layout = closure_layout(&f);
        assert_eq!(layout.payload_bits, 224);
        assert_eq!(layout.padded_bits, 256);
        assert_eq!(layout.padding_bits(), 32);
        assert_eq!(layout.fields.len(), 2);
        assert_eq!(layout.fields[1].offset_bits, 64);
    }

    #[test]
    fn empty_closure_is_min_width() {
        let f = mk_func("t", &[]);
        let layout = closure_layout(&f);
        assert_eq!(layout.payload_bits, CONT_SLOT_BITS + COUNTER_BITS);
        assert_eq!(layout.padded_bits, MIN_CLOSURE_BITS);
    }

    #[test]
    fn float_fields_align_to_32() {
        let f = mk_func("t", &[Type::Float, Type::Bool, Type::Int]);
        let layout = closure_layout(&f);
        assert_eq!(layout.fields[0].width_bits, 32);
        assert_eq!(layout.fields[1].width_bits, 32); // bool padded to a beat
        assert_eq!(layout.fields[2].offset_bits, 64);
        // 32+32+64 = 128 data; cont at 128; counter at 192 -> 224 -> 256.
        assert_eq!(layout.padded_bits, 256);
    }

    #[test]
    fn wide_closures_bucket_up() {
        let f = mk_func("t", &[Type::Int; 8]);
        let layout = closure_layout(&f);
        // 8*64 = 512 data + 64 + 32 = 608 -> 1024.
        assert_eq!(layout.padded_bits, 1024);
    }
}
