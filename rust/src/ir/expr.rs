//! Side-effect-free IR expressions and runtime values.
//!
//! Memory reads are *not* expressions — `ast_to_cfg` hoists every global
//! array read into an [`crate::ir::cfg::Op::Load`], so expressions evaluate
//! purely over local variables. This is what makes liveness, the DAE
//! transform and the HLS latency model straightforward.

use crate::frontend::ast::{BinOp, Type, UnOp};
use crate::util::idvec::Id;

/// A function-local variable (parameter or local/temp).
#[derive(Clone, Debug, PartialEq)]
pub struct Var {
    pub name: String,
    pub ty: Type,
    pub is_param: bool,
    /// True for compiler-introduced temporaries (hoisted loads etc.).
    pub is_temp: bool,
}

pub type VarId = Id<Var>;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Builtin {
    Min,
    Max,
    Abs,
}

impl Builtin {
    pub fn name(self) -> &'static str {
        match self {
            Builtin::Min => "min",
            Builtin::Max => "max",
            Builtin::Abs => "abs",
        }
    }

    pub fn from_name(name: &str) -> Option<Builtin> {
        match name {
            "min" => Some(Builtin::Min),
            "max" => Some(Builtin::Max),
            "abs" => Some(Builtin::Abs),
            _ => None,
        }
    }
}

/// Pure expression tree over local variables.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    ConstI(i64),
    ConstF(f32),
    ConstB(bool),
    Var(VarId),
    Binary(BinOp, Box<Expr>, Box<Expr>),
    Unary(UnOp, Box<Expr>),
    Builtin(Builtin, Vec<Expr>),
    /// Implicit int → float widening inserted during lowering.
    IntToFloat(Box<Expr>),
}

impl Expr {
    pub fn var(id: VarId) -> Expr {
        Expr::Var(id)
    }

    /// Visit every variable referenced by this expression.
    pub fn for_each_var(&self, f: &mut impl FnMut(VarId)) {
        match self {
            Expr::Var(v) => f(*v),
            Expr::Binary(_, a, b) => {
                a.for_each_var(f);
                b.for_each_var(f);
            }
            Expr::Unary(_, e) | Expr::IntToFloat(e) => e.for_each_var(f),
            Expr::Builtin(_, args) => args.iter().for_each(|a| a.for_each_var(f)),
            Expr::ConstI(_) | Expr::ConstF(_) | Expr::ConstB(_) => {}
        }
    }

    /// Rewrite every variable reference through `map` (used when splicing
    /// code into a new function with a fresh variable table).
    pub fn map_vars(&self, map: &impl Fn(VarId) -> VarId) -> Expr {
        match self {
            Expr::Var(v) => Expr::Var(map(*v)),
            Expr::Binary(op, a, b) => {
                Expr::Binary(*op, Box::new(a.map_vars(map)), Box::new(b.map_vars(map)))
            }
            Expr::Unary(op, e) => Expr::Unary(*op, Box::new(e.map_vars(map))),
            Expr::IntToFloat(e) => Expr::IntToFloat(Box::new(e.map_vars(map))),
            Expr::Builtin(b, args) => {
                Expr::Builtin(*b, args.iter().map(|a| a.map_vars(map)).collect())
            }
            Expr::ConstI(v) => Expr::ConstI(*v),
            Expr::ConstF(v) => Expr::ConstF(*v),
            Expr::ConstB(v) => Expr::ConstB(*v),
        }
    }

    /// Number of nodes — used by the HLS resource/latency models as the
    /// datapath operator count.
    pub fn size(&self) -> usize {
        match self {
            Expr::Binary(_, a, b) => 1 + a.size() + b.size(),
            Expr::Unary(_, e) | Expr::IntToFloat(e) => 1 + e.size(),
            Expr::Builtin(_, args) => 1 + args.iter().map(Expr::size).sum::<usize>(),
            _ => 1,
        }
    }

    /// Count binary/unary/builtin operator nodes by a classifier (see
    /// `hls::resource`).
    pub fn for_each_node(&self, f: &mut impl FnMut(&Expr)) {
        f(self);
        match self {
            Expr::Binary(_, a, b) => {
                a.for_each_node(f);
                b.for_each_node(f);
            }
            Expr::Unary(_, e) | Expr::IntToFloat(e) => e.for_each_node(f),
            Expr::Builtin(_, args) => args.iter().for_each(|a| a.for_each_node(f)),
            _ => {}
        }
    }
}

/// A runtime value (shared by the oracle interpreter, the explicit-IR
/// executor, the work-stealing runtime and the simulator).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Value {
    I64(i64),
    F32(f32),
    Bool(bool),
    /// The value of an untaken conditional spawn's slot / an uninitialized
    /// local. Reading it through arithmetic is defined as zero of the
    /// context type (locals are zero-initialized, matching hardware
    /// registers reset to 0).
    Unit,
}

impl Value {
    pub fn zero_of(ty: Type) -> Value {
        match ty {
            Type::Int => Value::I64(0),
            Type::Float => Value::F32(0.0),
            Type::Bool => Value::Bool(false),
            Type::Void => Value::Unit,
        }
    }

    pub fn as_i64(self) -> i64 {
        match self {
            Value::I64(v) => v,
            Value::Bool(b) => b as i64,
            Value::F32(v) => v as i64,
            Value::Unit => 0,
        }
    }

    pub fn as_f32(self) -> f32 {
        match self {
            Value::F32(v) => v,
            Value::I64(v) => v as f32,
            Value::Bool(b) => b as i64 as f32,
            Value::Unit => 0.0,
        }
    }

    pub fn as_bool(self) -> bool {
        match self {
            Value::Bool(b) => b,
            Value::I64(v) => v != 0,
            Value::F32(v) => v != 0.0,
            Value::Unit => false,
        }
    }

    /// Coerce to the representation of `ty` (used when writing closure
    /// slots / memory of a known element type).
    pub fn coerce(self, ty: Type) -> Value {
        match ty {
            Type::Int => Value::I64(self.as_i64()),
            Type::Float => Value::F32(self.as_f32()),
            Type::Bool => Value::Bool(self.as_bool()),
            Type::Void => Value::Unit,
        }
    }

    /// Bit pattern for closure packing (64-bit field max).
    pub fn to_bits(self) -> u64 {
        match self {
            Value::I64(v) => v as u64,
            Value::F32(v) => v.to_bits() as u64,
            Value::Bool(b) => b as u64,
            Value::Unit => 0,
        }
    }

    pub fn from_bits(ty: Type, bits: u64) -> Value {
        match ty {
            Type::Int => Value::I64(bits as i64),
            Type::Float => Value::F32(f32::from_bits(bits as u32)),
            Type::Bool => Value::Bool(bits != 0),
            Type::Void => Value::Unit,
        }
    }
}

impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Value::I64(v) => write!(f, "{v}"),
            Value::F32(v) => write!(f, "{v}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Unit => write!(f, "unit"),
        }
    }
}

/// Evaluate a pure expression against an environment of local values.
/// Generic over the environment lookup so the hot interpreters
/// monomorphize and inline it (perf: see EXPERIMENTS.md §Perf).
pub fn eval<F: Fn(VarId) -> Value>(expr: &Expr, env: &F) -> Value {
    match expr {
        Expr::ConstI(v) => Value::I64(*v),
        Expr::ConstF(v) => Value::F32(*v),
        Expr::ConstB(v) => Value::Bool(*v),
        Expr::Var(v) => env(*v),
        Expr::IntToFloat(e) => Value::F32(eval(e, env).as_f32()),
        Expr::Unary(op, e) => {
            let v = eval(e, env);
            match op {
                UnOp::Neg => match v {
                    Value::F32(f) => Value::F32(-f),
                    other => Value::I64(-other.as_i64()),
                },
                UnOp::Not => Value::Bool(!v.as_bool()),
            }
        }
        Expr::Builtin(b, args) => {
            let vals: Vec<Value> = args.iter().map(|a| eval(a, env)).collect();
            let float = vals.iter().any(|v| matches!(v, Value::F32(_)));
            match (b, float) {
                (Builtin::Min, false) => Value::I64(vals[0].as_i64().min(vals[1].as_i64())),
                (Builtin::Max, false) => Value::I64(vals[0].as_i64().max(vals[1].as_i64())),
                (Builtin::Abs, false) => Value::I64(vals[0].as_i64().abs()),
                (Builtin::Min, true) => Value::F32(vals[0].as_f32().min(vals[1].as_f32())),
                (Builtin::Max, true) => Value::F32(vals[0].as_f32().max(vals[1].as_f32())),
                (Builtin::Abs, true) => Value::F32(vals[0].as_f32().abs()),
            }
        }
        Expr::Binary(op, a, b) => {
            let va = eval(a, env);
            let vb = eval(b, env);
            let float = matches!(va, Value::F32(_)) || matches!(vb, Value::F32(_));
            use BinOp::*;
            match op {
                Add | Sub | Mul | Div if float => {
                    let (x, y) = (va.as_f32(), vb.as_f32());
                    Value::F32(match op {
                        Add => x + y,
                        Sub => x - y,
                        Mul => x * y,
                        Div => x / y,
                        _ => unreachable!(),
                    })
                }
                Add => Value::I64(va.as_i64().wrapping_add(vb.as_i64())),
                Sub => Value::I64(va.as_i64().wrapping_sub(vb.as_i64())),
                Mul => Value::I64(va.as_i64().wrapping_mul(vb.as_i64())),
                Div => {
                    let d = vb.as_i64();
                    Value::I64(if d == 0 { 0 } else { va.as_i64().wrapping_div(d) })
                }
                Rem => {
                    let d = vb.as_i64();
                    Value::I64(if d == 0 { 0 } else { va.as_i64().wrapping_rem(d) })
                }
                Shl => Value::I64(va.as_i64().wrapping_shl(vb.as_i64() as u32 & 63)),
                Shr => Value::I64(va.as_i64().wrapping_shr(vb.as_i64() as u32 & 63)),
                BitAnd => Value::I64(va.as_i64() & vb.as_i64()),
                BitOr => Value::I64(va.as_i64() | vb.as_i64()),
                BitXor => Value::I64(va.as_i64() ^ vb.as_i64()),
                And => Value::Bool(va.as_bool() && vb.as_bool()),
                Or => Value::Bool(va.as_bool() || vb.as_bool()),
                Lt | Le | Gt | Ge | Eq | Ne => {
                    let r = if float {
                        let (x, y) = (va.as_f32(), vb.as_f32());
                        match op {
                            Lt => x < y,
                            Le => x <= y,
                            Gt => x > y,
                            Ge => x >= y,
                            Eq => x == y,
                            Ne => x != y,
                            _ => unreachable!(),
                        }
                    } else {
                        let (x, y) = (va.as_i64(), vb.as_i64());
                        match op {
                            Lt => x < y,
                            Le => x <= y,
                            Gt => x > y,
                            Ge => x >= y,
                            Eq => x == y,
                            Ne => x != y,
                            _ => unreachable!(),
                        }
                    };
                    Value::Bool(r)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(vals: Vec<Value>) -> impl Fn(VarId) -> Value {
        move |v: VarId| vals[v.index()]
    }

    #[test]
    fn arithmetic() {
        let e = Expr::Binary(
            BinOp::Add,
            Box::new(Expr::Var(VarId::new(0))),
            Box::new(Expr::Binary(
                BinOp::Mul,
                Box::new(Expr::ConstI(3)),
                Box::new(Expr::Var(VarId::new(1))),
            )),
        );
        let v = eval(&e, &env(vec![Value::I64(1), Value::I64(4)]));
        assert_eq!(v, Value::I64(13));
    }

    #[test]
    fn float_promotion() {
        let e = Expr::Binary(
            BinOp::Add,
            Box::new(Expr::ConstF(1.5)),
            Box::new(Expr::ConstI(2)),
        );
        assert_eq!(eval(&e, &env(vec![])), Value::F32(3.5));
    }

    #[test]
    fn division_by_zero_is_zero() {
        // Matches the hardware datapath convention (no trap lines on PEs).
        let e = Expr::Binary(BinOp::Div, Box::new(Expr::ConstI(7)), Box::new(Expr::ConstI(0)));
        assert_eq!(eval(&e, &env(vec![])), Value::I64(0));
    }

    #[test]
    fn comparisons_and_logic() {
        let e = Expr::Binary(
            BinOp::And,
            Box::new(Expr::Binary(BinOp::Lt, Box::new(Expr::ConstI(1)), Box::new(Expr::ConstI(2)))),
            Box::new(Expr::Binary(BinOp::Ne, Box::new(Expr::ConstI(3)), Box::new(Expr::ConstI(3)))),
        );
        assert_eq!(eval(&e, &env(vec![])), Value::Bool(false));
    }

    #[test]
    fn builtins() {
        let m = Expr::Builtin(Builtin::Min, vec![Expr::ConstI(3), Expr::ConstI(-2)]);
        assert_eq!(eval(&m, &env(vec![])), Value::I64(-2));
        let a = Expr::Builtin(Builtin::Abs, vec![Expr::ConstF(-2.5)]);
        assert_eq!(eval(&a, &env(vec![])), Value::F32(2.5));
    }

    #[test]
    fn value_bits_roundtrip() {
        use crate::frontend::ast::Type;
        for v in [Value::I64(-7), Value::F32(3.25), Value::Bool(true)] {
            let ty = match v {
                Value::I64(_) => Type::Int,
                Value::F32(_) => Type::Float,
                Value::Bool(_) => Type::Bool,
                Value::Unit => Type::Void,
            };
            assert_eq!(Value::from_bits(ty, v.to_bits()), v);
        }
    }

    #[test]
    fn for_each_var_collects() {
        let e = Expr::Binary(
            BinOp::Add,
            Box::new(Expr::Var(VarId::new(2))),
            Box::new(Expr::Unary(UnOp::Neg, Box::new(Expr::Var(VarId::new(5))))),
        );
        let mut vars = Vec::new();
        e.for_each_var(&mut |v| vars.push(v.index()));
        assert_eq!(vars, vec![2, 5]);
    }

    #[test]
    fn map_vars_rewrites() {
        let e = Expr::Var(VarId::new(3));
        let m = e.map_vars(&|v| VarId::new(v.index() + 10));
        assert_eq!(m, Expr::Var(VarId::new(13)));
    }
}
