//! Bombyx intermediate representations.
//!
//! Two IRs, exactly as the paper describes (Fig. 3 / Fig. 4):
//!
//! - the **implicit IR** ([`cfg`]): a control-flow graph of basic blocks per
//!   function, with `cilk_sync` kept as a *terminator* (it affects control
//!   flow — it ends the terminating function that will be carved out by
//!   explicitization). Memory reads are hoisted into explicit [`cfg::Op::Load`]
//!   ops so every memory access is visible to the DAE transform, the HLS
//!   latency model, and the simulator.
//! - the **explicit IR** ([`explicit`]): Cilk-1-style terminating tasks using
//!   `spawn`, `spawn_next` (closure creation) and `send_argument`.
//!
//! Both IRs share [`expr::Expr`] (side-effect-free expressions over
//! function-local variables) and are printable ([`print`]) and verifiable
//! ([`verify`]).

pub mod cfg;
pub mod explicit;
pub mod expr;
pub mod print;
pub mod verify;

pub use cfg::{
    Block, BlockId, Cfg, FieldIdx, Func, FuncId, FuncKind, Global, GlobalId, Module, Op,
    RetTarget, TaskMeta, TaskRole, Term,
};
pub use expr::{Builtin, Expr, Value, Var, VarId};
