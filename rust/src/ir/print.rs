//! Text printers for both IRs.
//!
//! Two formats:
//! - [`print_func`] / [`print_module`]: block-structured CFG dump (the
//!   Fig. 4(b)/(c) view), stable for golden tests.
//! - [`print_cilk1`]: Cilk-1 concrete syntax for explicit tasks (the Fig. 2
//!   view: `task f(cont int k, ...)`, `spawn_next`, `send_argument`).

use std::fmt::Write as _;

use crate::frontend::ast::Type;

use super::cfg::{Func, FuncKind, Module, Op, RetTarget, Term};
use super::expr::{Expr, VarId};

pub fn print_module(module: &Module) -> String {
    let mut out = String::new();
    for (_, g) in module.globals.iter() {
        let size = g.size.map(|s| s.to_string()).unwrap_or_default();
        let _ = writeln!(out, "global {} {}[{}]", g.elem.name(), g.name, size);
    }
    if !module.globals.is_empty() {
        out.push('\n');
    }
    for (_, f) in module.funcs.iter() {
        out.push_str(&print_func(module, f));
        out.push('\n');
    }
    out
}

pub fn print_func(module: &Module, func: &Func) -> String {
    let mut out = String::new();
    let kind = match func.kind {
        FuncKind::Task => "func",
        FuncKind::Leaf => "leaf",
        FuncKind::Xla => "xla",
    };
    let params: Vec<String> = func
        .param_ids()
        .map(|v| format!("{}: {}", func.vars[v].name, func.vars[v].ty.name()))
        .collect();
    let role = func
        .task
        .as_ref()
        .map(|t| format!(" [{} of {}]", t.role.name(), t.source))
        .unwrap_or_default();
    let _ = writeln!(
        out,
        "{kind} {}({}) -> {}{role} {{",
        func.name,
        params.join(", "),
        func.ret.name()
    );
    if let Some(cfg) = func.body.as_ref() {
        for (id, block) in cfg.blocks.iter() {
            let marker = if id == cfg.entry { " (entry)" } else { "" };
            let _ = writeln!(out, "bb{}{marker}:", id.index());
            for op in &block.ops {
                let _ = writeln!(out, "  {}", fmt_op(module, func, op));
            }
            let _ = writeln!(out, "  {}", fmt_term(func, &block.term));
        }
    } else {
        let _ = writeln!(out, "  <extern>");
    }
    out.push_str("}\n");
    out
}

pub fn fmt_op(module: &Module, func: &Func, op: &Op) -> String {
    let v = |id: VarId| func.vars[id].name.clone();
    let e = |expr: &Expr| fmt_expr(func, expr);
    match op {
        Op::Assign { dst, src } => format!("{} = {}", v(*dst), e(src)),
        Op::Load { dst, arr, index, dae } => format!(
            "{} = load {}[{}]{}",
            v(*dst),
            module.globals[*arr].name,
            e(index),
            if *dae { "  ; #pragma bombyx dae" } else { "" }
        ),
        Op::Store { arr, index, value } => {
            format!("store {}[{}] = {}", module.globals[*arr].name, e(index), e(value))
        }
        Op::AtomicAdd { arr, index, value } => {
            format!("atomic_add {}[{}], {}", module.globals[*arr].name, e(index), e(value))
        }
        Op::Call { dst, callee, args } => {
            let args: Vec<String> = args.iter().map(|a| e(a)).collect();
            let call = format!("call {}({})", module.funcs[*callee].name, args.join(", "));
            match dst {
                Some(d) => format!("{} = {}", v(*d), call),
                None => call,
            }
        }
        Op::Spawn { dst, callee, args } => {
            let args: Vec<String> = args.iter().map(|a| e(a)).collect();
            let call = format!("spawn {}({})", module.funcs[*callee].name, args.join(", "));
            match dst {
                Some(d) => format!("{} = {}", v(*d), call),
                None => call,
            }
        }
        Op::MakeClosure { dst, task } => {
            format!("{} = spawn_next {}", v(*dst), module.funcs[*task].name)
        }
        Op::ClosureStore { clos, field, value } => {
            format!("{}.arg{} = {}", v(*clos), field, e(value))
        }
        Op::SpawnChild { callee, args, ret } => {
            let args: Vec<String> = args.iter().map(|a| e(a)).collect();
            let ret = match ret {
                RetTarget::Slot { clos, field } => format!(" -> {}.arg{}", v(*clos), field),
                RetTarget::Counter { clos } => format!(" -> {}.count", v(*clos)),
                RetTarget::Forward => " -> k".to_string(),
            };
            format!("spawn {}({}){}", module.funcs[*callee].name, args.join(", "), ret)
        }
        Op::CloseSpawns { clos } => format!("close {}", v(*clos)),
        Op::SendArgument { value } => match value {
            Some(value) => format!("send_argument(k, {})", e(value)),
            None => "send_argument(k)".to_string(),
        },
    }
}

pub fn fmt_term(func: &Func, term: &Term) -> String {
    let e = |expr: &Expr| fmt_expr(func, expr);
    match term {
        Term::Jump(b) => format!("jump bb{}", b.index()),
        Term::Branch { cond, then_, else_ } => {
            format!("br {}, bb{}, bb{}", e(cond), then_.index(), else_.index())
        }
        Term::Return(Some(v)) => format!("T: return {}", e(v)),
        Term::Return(None) => "T: return".to_string(),
        Term::Sync { next } => format!("T: sync -> bb{}", next.index()),
        Term::Halt => "halt".to_string(),
    }
}

pub fn fmt_expr(func: &Func, expr: &Expr) -> String {
    fmt_expr_prec(func, expr, 0)
}

fn fmt_expr_prec(func: &Func, expr: &Expr, parent_prec: u8) -> String {
    match expr {
        Expr::ConstI(v) => v.to_string(),
        Expr::ConstF(v) => {
            if v.fract() == 0.0 {
                format!("{v:.1}")
            } else {
                v.to_string()
            }
        }
        Expr::ConstB(v) => v.to_string(),
        Expr::Var(v) => func.vars[*v].name.clone(),
        Expr::IntToFloat(e) => format!("(float){}", fmt_expr_prec(func, e, 11)),
        Expr::Unary(op, e) => {
            let sym = match op {
                crate::frontend::ast::UnOp::Neg => "-",
                crate::frontend::ast::UnOp::Not => "!",
            };
            format!("{sym}{}", fmt_expr_prec(func, e, 11))
        }
        Expr::Builtin(b, args) => {
            let args: Vec<String> = args.iter().map(|a| fmt_expr_prec(func, a, 0)).collect();
            format!("{}({})", b.name(), args.join(", "))
        }
        Expr::Binary(op, a, b) => {
            let prec = binop_prec(*op);
            let s = format!(
                "{} {} {}",
                fmt_expr_prec(func, a, prec),
                op.symbol(),
                fmt_expr_prec(func, b, prec + 1)
            );
            if prec < parent_prec {
                format!("({s})")
            } else {
                s
            }
        }
    }
}

fn binop_prec(op: crate::frontend::ast::BinOp) -> u8 {
    use crate::frontend::ast::BinOp::*;
    match op {
        Or => 1,
        And => 2,
        BitOr => 3,
        BitXor => 4,
        BitAnd => 5,
        Eq | Ne => 6,
        Lt | Le | Gt | Ge => 7,
        Shl | Shr => 8,
        Add | Sub => 9,
        Mul | Div | Rem => 10,
    }
}

/// Render an explicit task in Cilk-1 concrete syntax (paper Fig. 2 style).
/// Control flow is rendered as labeled blocks with gotos (tasks are small;
/// the HLS backend does proper structural reconstruction).
pub fn print_cilk1(module: &Module, func: &Func) -> String {
    let mut out = String::new();
    let cont = match func.task.as_ref() {
        Some(meta) if meta.cont_ty != Type::Void => format!("cont {} k", meta.cont_ty.name()),
        _ => "cont void k".to_string(),
    };
    let mut params = vec![cont];
    params.extend(
        func.param_ids()
            .map(|v| format!("{} {}", func.vars[v].ty.name(), func.vars[v].name)),
    );
    let _ = writeln!(out, "task {} ({}) {{", func.name, params.join(", "));
    if let Some(cfg) = func.body.as_ref() {
        let multi = cfg.blocks.len() > 1;
        for (id, block) in cfg.blocks.iter() {
            if multi {
                let _ = writeln!(out, "L{}:", id.index());
            }
            for op in &block.ops {
                let _ = writeln!(out, "  {};", fmt_cilk1_op(module, func, op));
            }
            match &block.term {
                Term::Jump(b) => {
                    let _ = writeln!(out, "  goto L{};", b.index());
                }
                Term::Branch { cond, then_, else_ } => {
                    let _ = writeln!(
                        out,
                        "  if ({}) goto L{}; else goto L{};",
                        fmt_expr(func, cond),
                        then_.index(),
                        else_.index()
                    );
                }
                Term::Halt => {
                    if multi {
                        let _ = writeln!(out, "  return;");
                    }
                }
                other => {
                    let _ = writeln!(out, "  /* non-explicit terminator: {} */", fmt_term(func, other));
                }
            }
        }
    }
    out.push_str("}\n");
    out
}

fn fmt_cilk1_op(module: &Module, func: &Func, op: &Op) -> String {
    let v = |id: VarId| func.vars[id].name.clone();
    match op {
        Op::MakeClosure { dst, task } => {
            let t = &module.funcs[*task];
            let holes: Vec<String> = t.param_ids().map(|p| format!("?{}", t.vars[p].name)).collect();
            format!("cont {} = spawn_next {}(k{}{})", v(*dst), t.name, if holes.is_empty() { "" } else { ", " }, holes.join(", "))
        }
        Op::ClosureStore { clos, field, value } => {
            format!("{}.{} = {}", v(*clos), field_name(module, func, *clos, *field).unwrap_or(format!("arg{field}")), fmt_expr(func, value))
        }
        Op::SpawnChild { callee, args, ret } => {
            let t = &module.funcs[*callee];
            let args: Vec<String> = args.iter().map(|a| fmt_expr(func, a)).collect();
            let k = match ret {
                RetTarget::Slot { clos, field } => format!(
                    "{}.{}",
                    v(*clos),
                    field_name(module, func, *clos, *field).unwrap_or(format!("arg{field}"))
                ),
                RetTarget::Counter { clos } => format!("{}.join", v(*clos)),
                RetTarget::Forward => "k".to_string(),
            };
            format!("spawn {}({k}{}{})", t.name, if args.is_empty() { "" } else { ", " }, args.join(", "))
        }
        Op::CloseSpawns { clos } => format!("close_spawns({})", v(*clos)),
        Op::SendArgument { value } => match value {
            Some(value) => format!("send_argument(k, {})", fmt_expr(func, value)),
            None => "send_argument(k)".to_string(),
        },
        other => fmt_op(module, func, other),
    }
}

/// Resolve a closure field index to the continuation task's parameter name,
/// by finding which task this closure var was created for.
fn field_name(module: &Module, func: &Func, clos: VarId, field: u32) -> Option<String> {
    let cfg = func.body.as_ref()?;
    for block in cfg.blocks.values() {
        for op in &block.ops {
            if let Op::MakeClosure { dst, task } = op {
                if *dst == clos {
                    let t = &module.funcs[*task];
                    let vid = VarId::new(field as usize);
                    if (field as usize) < t.params {
                        return Some(t.vars[vid].name.clone());
                    }
                }
            }
        }
    }
    None
}
