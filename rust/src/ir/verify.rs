//! IR verifiers. Run after every lowering stage; a verifier failure is a
//! compiler bug, reported with the offending function and block.

use std::collections::HashSet;

use super::cfg::{Func, FuncKind, Module, Op, Term};
use super::expr::VarId;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    Implicit,
    Explicit,
}

/// Verify every function of a module for the given stage. Returns the list
/// of violations (empty = OK).
pub fn verify_module(module: &Module, stage: Stage) -> Vec<String> {
    let mut errors = Vec::new();
    for (id, func) in module.funcs.iter() {
        if func.kind == FuncKind::Xla {
            if func.body.is_some() {
                errors.push(format!("xla task `{}` must not have a body", func.name));
            }
            continue;
        }
        let Some(cfg) = func.body.as_ref() else {
            errors.push(format!("function `{}` (#{}) has no body", func.name, id.index()));
            continue;
        };
        let fname = &func.name;

        // Structural checks.
        if cfg.blocks.is_empty() {
            errors.push(format!("`{fname}`: empty CFG"));
            continue;
        }
        if cfg.entry.index() >= cfg.blocks.len() {
            errors.push(format!("`{fname}`: entry block out of range"));
            continue;
        }
        let preds = cfg.predecessors();
        if !preds[cfg.entry.index()].is_empty() {
            errors.push(format!(
                "`{fname}`: entry block bb{} has {} predecessor(s); paper requires the \
                 entry block to have no incoming edges",
                cfg.entry.index(),
                preds[cfg.entry.index()].len()
            ));
        }
        let reachable = cfg.reachable();
        let mut has_exit = false;

        for (bid, block) in cfg.blocks.iter() {
            if !reachable[bid.index()] {
                continue;
            }
            for succ in block.term.successors() {
                if succ.index() >= cfg.blocks.len() {
                    errors.push(format!(
                        "`{fname}` bb{}: terminator targets nonexistent bb{}",
                        bid.index(),
                        succ.index()
                    ));
                }
            }
            if block.term.successors().is_empty() {
                has_exit = true;
            }

            // Variable sanity: every referenced var exists.
            let check_var = |v: VarId, errors: &mut Vec<String>, what: &str| {
                if v.index() >= func.vars.len() {
                    errors.push(format!(
                        "`{fname}` bb{}: {what} references out-of-range var #{}",
                        bid.index(),
                        v.index()
                    ));
                }
            };
            for op in &block.ops {
                if let Some(d) = op.def() {
                    check_var(d, &mut errors, "op def");
                }
                op.for_each_use(&mut |v| check_var(v, &mut errors, "op use"));
                for (gid, what) in op_global_refs(op) {
                    if gid >= module.globals.len() {
                        errors.push(format!(
                            "`{fname}` bb{}: {what} references out-of-range global #{gid}",
                            bid.index()
                        ));
                    }
                }
                for (fid, what) in op_func_refs(op) {
                    if fid >= module.funcs.len() {
                        errors.push(format!(
                            "`{fname}` bb{}: {what} references out-of-range function #{fid}",
                            bid.index()
                        ));
                    }
                }
            }
            block.term.for_each_use(&mut |v| check_var(v, &mut errors, "terminator use"));

            // Stage-specific op/term restrictions.
            match stage {
                Stage::Implicit => {
                    for op in &block.ops {
                        if op.is_explicit_only() {
                            errors.push(format!(
                                "`{fname}` bb{}: explicit-only op in implicit IR: {op:?}",
                                bid.index()
                            ));
                        }
                    }
                    if matches!(block.term, Term::Halt) {
                        errors.push(format!(
                            "`{fname}` bb{}: Halt terminator in implicit IR",
                            bid.index()
                        ));
                    }
                }
                Stage::Explicit => {
                    for op in &block.ops {
                        if let Op::Spawn { .. } = op {
                            errors.push(format!(
                                "`{fname}` bb{}: implicit Spawn survives in explicit IR",
                                bid.index()
                            ));
                        }
                    }
                    match block.term {
                        Term::Sync { .. } => errors.push(format!(
                            "`{fname}` bb{}: sync terminator survives in explicit IR",
                            bid.index()
                        )),
                        Term::Return(_) if func.kind != FuncKind::Leaf => errors.push(format!(
                            "`{fname}` bb{}: Return in explicit task (must be SendArgument + \
                             Halt)",
                            bid.index()
                        )),
                        _ => {}
                    }
                }
            }

            // Leaf functions never spawn or sync.
            if func.kind == FuncKind::Leaf {
                for op in &block.ops {
                    if matches!(op, Op::Spawn { .. } | Op::SpawnChild { .. }) {
                        errors.push(format!("leaf `{fname}` bb{}: contains a spawn", bid.index()));
                    }
                }
                if matches!(block.term, Term::Sync { .. }) {
                    errors.push(format!("leaf `{fname}` bb{}: contains a sync", bid.index()));
                }
            }
        }
        if !has_exit {
            errors.push(format!("`{fname}`: no exit block (return/halt) is reachable"));
        }

        // Implicit stage: every spawn-reaching return must be preceded by a
        // sync (the "implicit sync" OpenCilk semantics). Verified via the
        // pending-spawn dataflow.
        if stage == Stage::Implicit && func.kind == FuncKind::Task {
            errors.extend(check_no_pending_spawn_at_return(func).into_iter().map(|b| {
                format!(
                    "`{fname}` bb{b}: return with pending spawns (missing implicit sync \
                     insertion)"
                )
            }));
        }
    }
    errors
}

/// Blocks whose Return terminator may execute with children outstanding.
fn check_no_pending_spawn_at_return(func: &Func) -> Vec<usize> {
    let cfg = func.cfg();
    let n = cfg.blocks.len();
    // pending[b] = may there be un-synced spawns at entry of b?
    let mut pending_in = vec![false; n];
    let mut changed = true;
    while changed {
        changed = false;
        for (bid, block) in cfg.blocks.iter() {
            let mut pending = pending_in[bid.index()];
            for op in &block.ops {
                if matches!(op, Op::Spawn { .. }) {
                    pending = true;
                }
            }
            let out = match block.term {
                Term::Sync { .. } => false,
                _ => pending,
            };
            for succ in block.term.successors() {
                if succ.index() >= n {
                    continue; // malformed edge; reported by the structural checks
                }
                if out && !pending_in[succ.index()] {
                    pending_in[succ.index()] = true;
                    changed = true;
                }
            }
        }
    }
    let mut bad = Vec::new();
    let reachable = cfg.reachable();
    for (bid, block) in cfg.blocks.iter() {
        if !reachable[bid.index()] {
            continue;
        }
        if let Term::Return(_) = block.term {
            let mut pending = pending_in[bid.index()];
            for op in &block.ops {
                if matches!(op, Op::Spawn { .. }) {
                    pending = true;
                }
            }
            if pending {
                bad.push(bid.index());
            }
        }
    }
    bad
}

fn op_global_refs(op: &Op) -> Vec<(usize, &'static str)> {
    match op {
        Op::Load { arr, .. } => vec![(arr.index(), "load")],
        Op::Store { arr, .. } => vec![(arr.index(), "store")],
        Op::AtomicAdd { arr, .. } => vec![(arr.index(), "atomic_add")],
        _ => vec![],
    }
}

fn op_func_refs(op: &Op) -> Vec<(usize, &'static str)> {
    match op {
        Op::Call { callee, .. } => vec![(callee.index(), "call")],
        Op::Spawn { callee, .. } => vec![(callee.index(), "spawn")],
        Op::SpawnChild { callee, .. } => vec![(callee.index(), "spawn_child")],
        Op::MakeClosure { task, .. } => vec![(task.index(), "make_closure")],
        _ => vec![],
    }
}

/// Check that variable names within a function are unique enough for the
/// printers (duplicates get a numeric suffix during lowering; this guards
/// against regressions that would make goldens ambiguous).
pub fn check_unique_var_names(func: &Func) -> Result<(), String> {
    let mut seen = HashSet::new();
    for (_, var) in func.vars.iter() {
        if !seen.insert(var.name.clone()) {
            return Err(format!("duplicate variable name `{}` in `{}`", var.name, func.name));
        }
    }
    Ok(())
}
