//! # Bombyx
//!
//! A production-grade reproduction of *Bombyx: OpenCilk Compilation for FPGA
//! Hardware Acceleration* (Shahawy, de Castelnau, Ienne — CS.AR 2025).
//!
//! Bombyx lowers fork–join (implicit) task-parallel programs into a
//! Cilk-1-style *explicit continuation-passing* IR and generates, from one
//! source program:
//!
//! - **HardCilk PEs**: synthesizable HLS C++ processing elements plus the
//!   JSON system descriptor HardCilk's architecture generator consumes
//!   ([`backend::hardcilk`]);
//! - **an emulation program** executed by a software work-stealing runtime
//!   for verification ([`backend::emu`], [`ws`]);
//! - inputs to a **cycle-level HardCilk system simulator** ([`sim`]) and an
//!   **HLS resource estimator** ([`hls`]) that together regenerate the
//!   paper's evaluation (the 26.5 % DAE runtime reduction and the Fig. 6
//!   synthesis table).
//!
//! The numeric PE datapath (graph-relaxation workload) is AOT-compiled from
//! JAX/Pallas to an XLA executable loaded by [`runtime`]; Python never runs
//! on the request path. See DESIGN.md for the full system inventory and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod backend;
pub mod coordinator;
pub mod exec;
pub mod frontend;
pub mod hls;
pub mod interp;
pub mod ir;
pub mod lower;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;
pub mod workloads;
pub mod ws;
