//! CFG analyses used by DAE and explicitization: liveness, dominators,
//! pending-spawn mapping, and path (task) partitioning.

use std::collections::{HashMap, HashSet};

use anyhow::{bail, Result};

use crate::ir::cfg::{BlockId, Cfg, Func, Op, Term};
use crate::ir::expr::VarId;

/// Per-block liveness sets (bitsets over variables, packed in u64 words).
#[derive(Clone, Debug)]
pub struct Liveness {
    pub live_in: Vec<Vec<u64>>,
    pub live_out: Vec<Vec<u64>>,
}

impl Liveness {
    pub fn live_in_vars(&self, block: BlockId) -> Vec<VarId> {
        bits_to_vars(&self.live_in[block.index()])
    }

    pub fn live_out_vars(&self, block: BlockId) -> Vec<VarId> {
        bits_to_vars(&self.live_out[block.index()])
    }

    pub fn is_live_in(&self, block: BlockId, var: VarId) -> bool {
        let (w, b) = (var.index() / 64, var.index() % 64);
        self.live_in[block.index()][w] & (1u64 << b) != 0
    }
}

fn bits_to_vars(bits: &[u64]) -> Vec<VarId> {
    let mut out = Vec::new();
    for (w, &word) in bits.iter().enumerate() {
        let mut word = word;
        while word != 0 {
            let b = word.trailing_zeros() as usize;
            out.push(VarId::new(w * 64 + b));
            word &= word - 1;
        }
    }
    out
}

/// Classic backward iterative liveness on the block level.
pub fn liveness(func: &Func) -> Liveness {
    let cfg = func.cfg();
    let nvars = func.vars.len();
    let words = nvars.div_ceil(64);
    let nblocks = cfg.blocks.len();

    // use/def per block.
    let mut use_bits = vec![vec![0u64; words]; nblocks];
    let mut def_bits = vec![vec![0u64; words]; nblocks];
    for (bid, block) in cfg.blocks.iter() {
        let bi = bid.index();
        let mut defined = vec![0u64; words];
        let add_use = |v: VarId, defined: &[u64], use_bits: &mut Vec<Vec<u64>>| {
            let (w, b) = (v.index() / 64, v.index() % 64);
            if defined[w] & (1 << b) == 0 {
                use_bits[bi][w] |= 1 << b;
            }
        };
        for op in &block.ops {
            op.for_each_use(&mut |v| add_use(v, &defined, &mut use_bits));
            if let Some(d) = op.def() {
                let (w, b) = (d.index() / 64, d.index() % 64);
                defined[w] |= 1 << b;
                def_bits[bi][w] |= 1 << b;
            }
        }
        block.term.for_each_use(&mut |v| add_use(v, &defined, &mut use_bits));
    }

    let mut live_in = vec![vec![0u64; words]; nblocks];
    let mut live_out = vec![vec![0u64; words]; nblocks];
    let mut changed = true;
    while changed {
        changed = false;
        // Reverse iteration converges faster on reducible CFGs.
        for bi in (0..nblocks).rev() {
            let block = &cfg.blocks[BlockId::new(bi)];
            let mut out = vec![0u64; words];
            for succ in block.term.successors() {
                for w in 0..words {
                    out[w] |= live_in[succ.index()][w];
                }
            }
            let mut inp = vec![0u64; words];
            for w in 0..words {
                inp[w] = use_bits[bi][w] | (out[w] & !def_bits[bi][w]);
            }
            if inp != live_in[bi] || out != live_out[bi] {
                live_in[bi] = inp;
                live_out[bi] = out;
                changed = true;
            }
        }
    }
    Liveness { live_in, live_out }
}

/// Immediate dominators (Cooper–Harvey–Kennedy iterative algorithm).
/// `idom[entry] == entry`; unreachable blocks get `None`.
pub fn dominators(cfg: &Cfg) -> Vec<Option<BlockId>> {
    let rpo = cfg.reverse_postorder();
    let mut rpo_index = vec![usize::MAX; cfg.blocks.len()];
    for (i, b) in rpo.iter().enumerate() {
        rpo_index[b.index()] = i;
    }
    let preds = cfg.predecessors();
    let mut idom: Vec<Option<BlockId>> = vec![None; cfg.blocks.len()];
    idom[cfg.entry.index()] = Some(cfg.entry);

    let intersect = |idom: &[Option<BlockId>], rpo_index: &[usize], mut a: BlockId, mut b: BlockId| {
        while a != b {
            while rpo_index[a.index()] > rpo_index[b.index()] {
                a = idom[a.index()].unwrap();
            }
            while rpo_index[b.index()] > rpo_index[a.index()] {
                b = idom[b.index()].unwrap();
            }
        }
        a
    };

    let mut changed = true;
    while changed {
        changed = false;
        for &b in rpo.iter().skip(1) {
            let mut new_idom: Option<BlockId> = None;
            for &p in &preds[b.index()] {
                if idom[p.index()].is_none() {
                    continue;
                }
                new_idom = Some(match new_idom {
                    None => p,
                    Some(cur) => intersect(&idom, &rpo_index, cur, p),
                });
            }
            if let Some(ni) = new_idom {
                if idom[b.index()] != Some(ni) {
                    idom[b.index()] = Some(ni);
                    changed = true;
                }
            }
        }
    }
    idom
}

/// Nearest common dominator of a non-empty set of blocks.
pub fn common_dominator(cfg: &Cfg, idom: &[Option<BlockId>], blocks: &[BlockId]) -> BlockId {
    let rpo = cfg.reverse_postorder();
    let mut rpo_index = vec![usize::MAX; cfg.blocks.len()];
    for (i, b) in rpo.iter().enumerate() {
        rpo_index[b.index()] = i;
    }
    let mut cur = blocks[0];
    for &b in &blocks[1..] {
        let mut a = cur;
        let mut c = b;
        while a != c {
            while rpo_index[a.index()] > rpo_index[c.index()] {
                a = idom[a.index()].unwrap();
            }
            while rpo_index[c.index()] > rpo_index[a.index()] {
                c = idom[c.index()].unwrap();
            }
        }
        cur = a;
    }
    cur
}

/// Does `a` dominate `b`? (walks the idom chain; CFGs here are small)
pub fn dominates(idom: &[Option<BlockId>], a: BlockId, b: BlockId) -> bool {
    let mut cur = b;
    loop {
        if cur == a {
            return true;
        }
        match idom[cur.index()] {
            Some(d) if d != cur => cur = d,
            _ => return false,
        }
    }
}

/// Natural loops: for each back edge `u -> v` (where `v` dominates `u`),
/// the loop body is `v` plus everything that reaches `u` without passing
/// through `v`. Returns `(header, body)` pairs; nested loops appear
/// separately.
pub fn natural_loops(cfg: &Cfg, idom: &[Option<BlockId>]) -> Vec<(BlockId, HashSet<BlockId>)> {
    let mut loops: Vec<(BlockId, HashSet<BlockId>)> = Vec::new();
    let preds = cfg.predecessors();
    for (u, block) in cfg.blocks.iter() {
        for v in block.term.successors() {
            if idom[u.index()].is_some() && dominates(idom, v, u) {
                // Back edge u -> v.
                let mut body: HashSet<BlockId> = HashSet::new();
                body.insert(v);
                let mut stack = vec![u];
                while let Some(b) = stack.pop() {
                    if !body.insert(b) {
                        continue;
                    }
                    for &p in &preds[b.index()] {
                        if !body.contains(&p) {
                            stack.push(p);
                        }
                    }
                }
                // Merge with an existing loop sharing the header.
                if let Some(existing) = loops.iter_mut().find(|(h, _)| *h == v) {
                    existing.1.extend(body);
                } else {
                    loops.push((v, body));
                }
            }
        }
    }
    loops
}

/// Partition of a function CFG into *paths* (paper §II-A): maximal subgraphs
/// entered only at their entry block. Entries are the function entry, every
/// sync successor, and any block reachable from two or more entries (joins
/// get promoted to entries until fixpoint).
#[derive(Clone, Debug)]
pub struct Paths {
    /// Entry block of each path, in discovery order (function entry first).
    pub entries: Vec<BlockId>,
    /// For each block: which path owns it (index into `entries`);
    /// unreachable blocks map to `usize::MAX`.
    pub owner: Vec<usize>,
}

impl Paths {
    pub fn path_of(&self, block: BlockId) -> usize {
        self.owner[block.index()]
    }

    pub fn entry_of_path(&self, path: usize) -> BlockId {
        self.entries[path]
    }

    /// Blocks owned by a path, ascending.
    pub fn blocks_of(&self, path: usize, cfg: &Cfg) -> Vec<BlockId> {
        cfg.blocks
            .ids()
            .filter(|b| self.owner[b.index()] == path)
            .collect()
    }
}

pub fn partition_paths(cfg: &Cfg) -> Paths {
    let nblocks = cfg.blocks.len();
    let mut entries: Vec<BlockId> = vec![cfg.entry];
    let mut entry_set: HashSet<BlockId> = entries.iter().copied().collect();
    for (bid, block) in cfg.blocks.iter() {
        let _ = bid;
        if let Term::Sync { next } = block.term {
            if entry_set.insert(next) {
                entries.push(next);
            }
        }
    }
    // Fixpoint: a block reachable (without passing through an entry) from
    // more than one entry becomes an entry itself.
    loop {
        let mut owner = vec![usize::MAX; nblocks];
        let mut conflict: Option<BlockId> = None;
        'outer: for (pi, &entry) in entries.iter().enumerate() {
            let mut stack = vec![entry];
            let mut seen = HashSet::new();
            while let Some(b) = stack.pop() {
                if !seen.insert(b) {
                    continue;
                }
                if owner[b.index()] != usize::MAX && owner[b.index()] != pi {
                    conflict = Some(b);
                    break 'outer;
                }
                owner[b.index()] = pi;
                for succ in cfg.blocks[b].term.successors() {
                    if !entry_set.contains(&succ) {
                        stack.push(succ);
                    }
                }
            }
        }
        match conflict {
            Some(b) => {
                entry_set.insert(b);
                entries.push(b);
            }
            None => {
                return Paths { entries, owner };
            }
        }
    }
}

/// Map each `Spawn` op to the sync block it joins at, or an error if a spawn
/// can reach two different syncs / no sync (the restriction of DESIGN.md
/// §6.1 that keeps closures static).
///
/// Returned as: for each sync block, the list of (block, op index) spawn
/// sites joining there.
pub fn spawn_sync_map(func: &Func) -> Result<HashMap<BlockId, Vec<(BlockId, usize)>>> {
    let cfg = func.cfg();
    let mut result: HashMap<BlockId, Vec<(BlockId, usize)>> = HashMap::new();

    // For each spawn site, forward-walk to find reachable syncs without
    // crossing another sync.
    for (bid, block) in cfg.blocks.iter() {
        for (oi, op) in block.ops.iter().enumerate() {
            if !matches!(op, Op::Spawn { .. }) {
                continue;
            }
            let mut syncs = HashSet::new();
            // Walk from this point: remainder of this block then successors.
            let mut stack: Vec<BlockId> = Vec::new();
            let mut seen = HashSet::new();
            match block.term {
                Term::Sync { .. } => {
                    syncs.insert(bid);
                }
                _ => {
                    for s in block.term.successors() {
                        stack.push(s);
                    }
                }
            }
            while let Some(b) = stack.pop() {
                if !seen.insert(b) {
                    continue;
                }
                let blk = &cfg.blocks[b];
                match blk.term {
                    Term::Sync { .. } => {
                        syncs.insert(b);
                    }
                    _ => {
                        for s in blk.term.successors() {
                            stack.push(s);
                        }
                    }
                }
            }
            if syncs.is_empty() {
                bail!(
                    "function `{}`: spawn in bb{} never reaches a cilk_sync (and is not \
                     followed by an implicit one) — unsupported",
                    func.name,
                    bid.index()
                );
            }
            if syncs.len() > 1 {
                let mut list: Vec<usize> = syncs.iter().map(|b| b.index()).collect();
                list.sort();
                bail!(
                    "function `{}`: spawn in bb{} may join at multiple syncs ({:?}); Bombyx \
                     requires each spawn region to be post-dominated by a single sync",
                    func.name,
                    bid.index(),
                    list
                );
            }
            let sync = syncs.into_iter().next().unwrap();
            result.entry(sync).or_default().push((bid, oi));
        }
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_check;
    use crate::lower::ast_to_cfg::lower_program;
    use crate::ir::Module;

    fn lower(src: &str) -> Module {
        let (p, _) = parse_and_check("t", src).unwrap();
        lower_program(&p).unwrap()
    }

    const FIB: &str = "int fib(int n) {
        if (n < 2) return n;
        int x = cilk_spawn fib(n - 1);
        int y = cilk_spawn fib(n - 2);
        cilk_sync;
        return x + y;
    }";

    #[test]
    fn fib_liveness_at_join() {
        let m = lower(FIB);
        let f = &m.funcs[m.func_by_name("fib").unwrap()];
        let live = liveness(f);
        // The sync successor (join block) must have x and y live-in.
        let cfg = f.cfg();
        let sync_next = cfg
            .blocks
            .values()
            .find_map(|b| match b.term {
                Term::Sync { next } => Some(next),
                _ => None,
            })
            .unwrap();
        let names: Vec<String> = live
            .live_in_vars(sync_next)
            .into_iter()
            .map(|v| f.vars[v].name.clone())
            .collect();
        assert!(names.contains(&"x".to_string()) && names.contains(&"y".to_string()), "{names:?}");
        assert!(!names.contains(&"n".to_string()), "n dead after spawns: {names:?}");
    }

    #[test]
    fn dominators_entry_dominates_all() {
        let m = lower(FIB);
        let f = &m.funcs[m.func_by_name("fib").unwrap()];
        let cfg = f.cfg();
        let idom = dominators(cfg);
        let reachable = cfg.reachable();
        for (bid, _) in cfg.blocks.iter() {
            if reachable[bid.index()] && bid != cfg.entry {
                // Walking idoms reaches entry.
                let mut cur = bid;
                let mut steps = 0;
                while cur != cfg.entry {
                    cur = idom[cur.index()].expect("reachable block has idom");
                    steps += 1;
                    assert!(steps < 100);
                }
            }
        }
    }

    #[test]
    fn fib_partitions_into_two_paths() {
        let m = lower(FIB);
        let f = &m.funcs[m.func_by_name("fib").unwrap()];
        let paths = partition_paths(f.cfg());
        // Path 0: entry/branch/spawns; path 1: after sync. (Unreachable
        // dead blocks don't create paths.)
        assert_eq!(paths.entries.len(), 2, "expected 2 paths, got {:?}", paths.entries);
    }

    #[test]
    fn loop_with_sync_promotes_header() {
        let m = lower(
            "global int acc[1];
             void work(int n) { atomic_add(acc, 0, n); }
             void f(int n) {
                for (int i = 0; i < n; i = i + 1) {
                    cilk_spawn work(i);
                    cilk_sync;
                }
             }",
        );
        let f = &m.funcs[m.func_by_name("f").unwrap()];
        let paths = partition_paths(f.cfg());
        // entry, sync-successor, and the loop header join → ≥3 paths.
        assert!(paths.entries.len() >= 3, "paths: {:?}", paths.entries);
    }

    #[test]
    fn spawn_sync_map_fib() {
        let m = lower(FIB);
        let f = &m.funcs[m.func_by_name("fib").unwrap()];
        let map = spawn_sync_map(f).unwrap();
        assert_eq!(map.len(), 1);
        let sites = map.values().next().unwrap();
        assert_eq!(sites.len(), 2);
    }

    #[test]
    fn bfs_loop_spawns_map_to_following_sync() {
        let m = lower(
            "global int adj_off[];
             global int adj_edges[];
             global int visited[];
             void visit(int n) {
                 int off = adj_off[n];
                 int end = adj_off[n + 1];
                 visited[n] = 1;
                 for (int i = off; i < end; i = i + 1) {
                     cilk_spawn visit(adj_edges[i]);
                 }
                 cilk_sync;
             }",
        );
        let f = &m.funcs[m.func_by_name("visit").unwrap()];
        let map = spawn_sync_map(f).unwrap();
        assert_eq!(map.len(), 1);
        assert_eq!(map.values().next().unwrap().len(), 1);
    }
}
