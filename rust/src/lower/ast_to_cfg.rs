//! AST → implicit IR (CFG) lowering.
//!
//! Responsibilities beyond plain CFG construction:
//! - hoist every global-array read into an [`Op::Load`] temp (memory
//!   accesses must be first-class for DAE / HLS modelling);
//! - desugar `for` into `while`-shaped blocks;
//! - propagate `#pragma bombyx dae` onto the hoisted loads;
//! - insert OpenCilk's *implicit sync*: a `sync` before every `return` that
//!   may execute with outstanding children;
//! - uniquify variable names (scope-aware) so printers stay unambiguous.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::frontend::ast::{self, Type};
use crate::ir::cfg::{Block, BlockId, Cfg, Func, FuncId, FuncKind, Global, Module, Op, Term};
use crate::ir::expr::{Builtin, Expr, Var, VarId};

/// Lower a checked program to the implicit IR.
pub fn lower_program(program: &ast::Program) -> Result<Module> {
    let mut module = Module::default();
    let mut global_ids = HashMap::new();
    for g in &program.globals {
        let id = module.globals.push(Global { name: g.name.clone(), elem: g.ty, size: g.size });
        global_ids.insert(g.name.clone(), id);
    }

    // Pre-register all functions so bodies can reference each other.
    let mut func_ids = HashMap::new();
    for f in &program.funcs {
        let kind = if crate::frontend::sema::func_spawns(&f.body) {
            FuncKind::Task
        } else {
            FuncKind::Leaf
        };
        let mut vars = crate::util::idvec::IdVec::new();
        for p in &f.params {
            vars.push(Var { name: p.name.clone(), ty: p.ty, is_param: true, is_temp: false });
        }
        let id = module.funcs.push(Func {
            name: f.name.clone(),
            ret: f.ret,
            params: f.params.len(),
            vars,
            body: None,
            kind,
            task: None,
        });
        func_ids.insert(f.name.clone(), id);
    }
    for e in &program.externs {
        let mut vars = crate::util::idvec::IdVec::new();
        for p in &e.params {
            vars.push(Var { name: p.name.clone(), ty: p.ty, is_param: true, is_temp: false });
        }
        let id = module.funcs.push(Func {
            name: e.name.clone(),
            ret: e.ret,
            params: e.params.len(),
            vars,
            body: None,
            kind: FuncKind::Xla,
            task: None,
        });
        func_ids.insert(e.name.clone(), id);
    }

    // Lower bodies.
    for f in &program.funcs {
        let fid = func_ids[&f.name];
        let (cfg, vars) = FuncLowerer::new(&module, &global_ids, &func_ids, f).lower()?;
        let func = &mut module.funcs[fid];
        func.vars = vars;
        func.body = Some(cfg);
    }

    // Insert implicit syncs before spawn-pending returns.
    for (_, func) in module.funcs.iter_mut() {
        if func.kind == FuncKind::Task && func.body.is_some() {
            insert_implicit_syncs(func);
        }
    }
    Ok(module)
}

/// Re-lower a single function of an already-lowered module from its
/// (edited) AST definition, in place: every other function and all ids
/// stay untouched. Mirrors `lower_program`'s per-function steps — kind
/// classification, body lowering, implicit-sync insertion — so splicing
/// the result produces the same module a cold lowering of the edited
/// source would. (Function-at-a-time support for incremental
/// recompilation; see `lower::pass::Pass::run_on_function`.)
pub fn relower_function(module: &mut Module, def: &ast::FuncDef, fid: FuncId) -> Result<()> {
    let global_ids: HashMap<String, crate::ir::GlobalId> = module
        .globals
        .iter()
        .map(|(id, g)| (g.name.clone(), id))
        .collect();
    let func_ids: HashMap<String, FuncId> = module
        .funcs
        .iter()
        .map(|(id, f)| (f.name.clone(), id))
        .collect();
    let kind = if crate::frontend::sema::func_spawns(&def.body) {
        FuncKind::Task
    } else {
        FuncKind::Leaf
    };
    {
        let func = &mut module.funcs[fid];
        func.kind = kind;
        func.ret = def.ret;
        func.params = def.params.len();
    }
    let (cfg, vars) = FuncLowerer::new(module, &global_ids, &func_ids, def).lower()?;
    let func = &mut module.funcs[fid];
    func.vars = vars;
    func.body = Some(cfg);
    func.task = None;
    if func.kind == FuncKind::Task {
        insert_implicit_syncs(func);
    }
    Ok(())
}

struct FuncLowerer<'a> {
    module: &'a Module,
    globals: &'a HashMap<String, crate::ir::GlobalId>,
    funcs: &'a HashMap<String, FuncId>,
    src: &'a ast::FuncDef,
    vars: crate::util::idvec::IdVec<Var>,
    /// Scope stack: name → var.
    scopes: Vec<HashMap<String, VarId>>,
    /// Per-name occurrence counter for uniquified printing names.
    name_counts: HashMap<String, u32>,
    cfg: Cfg,
    cur: BlockId,
    /// Blocks whose terminator has been set (an op emitted into a
    /// terminated block would be lost; `emit` guards on this).
    terminated: HashSet<BlockId>,
    temp_count: u32,
}

impl<'a> FuncLowerer<'a> {
    fn new(
        module: &'a Module,
        globals: &'a HashMap<String, crate::ir::GlobalId>,
        funcs: &'a HashMap<String, FuncId>,
        src: &'a ast::FuncDef,
    ) -> Self {
        let mut cfg = Cfg::default();
        let entry = cfg.blocks.push(Block::default());
        cfg.entry = entry;
        let mut this = FuncLowerer {
            module,
            globals,
            funcs,
            src,
            vars: crate::util::idvec::IdVec::new(),
            scopes: vec![HashMap::new()],
            name_counts: HashMap::new(),
            cfg,
            cur: entry,
            terminated: HashSet::new(),
            temp_count: 0,
        };
        for p in &src.params {
            let id = this.vars.push(Var {
                name: p.name.clone(),
                ty: p.ty,
                is_param: true,
                is_temp: false,
            });
            this.name_counts.insert(p.name.clone(), 1);
            this.scopes[0].insert(p.name.clone(), id);
        }
        this
    }

    fn lower(mut self) -> Result<(Cfg, crate::util::idvec::IdVec<Var>)> {
        self.lower_block_stmts(&self.src.body.clone())?;
        // Fall-through exit.
        if !self.block_terminated() {
            self.set_term(Term::Return(None));
        }
        Ok((self.cfg, self.vars))
    }

    // ---- var/scope helpers -------------------------------------------------

    fn declare(&mut self, name: &str, ty: Type) -> VarId {
        let count = self.name_counts.entry(name.to_string()).or_insert(0);
        *count += 1;
        let unique = if *count == 1 { name.to_string() } else { format!("{name}_{count}") };
        let id = self.vars.push(Var { name: unique, ty, is_param: false, is_temp: false });
        self.scopes.last_mut().unwrap().insert(name.to_string(), id);
        id
    }

    fn fresh_temp(&mut self, ty: Type) -> VarId {
        let id = self.vars.push(Var {
            name: format!("t{}", self.temp_count),
            ty,
            is_param: false,
            is_temp: true,
        });
        self.temp_count += 1;
        id
    }

    fn lookup(&self, name: &str) -> Result<VarId> {
        self.scopes
            .iter()
            .rev()
            .find_map(|s| s.get(name).copied())
            .ok_or_else(|| anyhow!("unknown variable `{name}` (sema should have caught this)"))
    }

    fn global(&self, name: &str) -> Result<crate::ir::GlobalId> {
        self.globals
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("unknown global `{name}`"))
    }

    fn func(&self, name: &str) -> Result<FuncId> {
        self.funcs
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("unknown function `{name}`"))
    }

    // ---- block helpers -----------------------------------------------------

    fn new_block(&mut self) -> BlockId {
        self.cfg.blocks.push(Block::default())
    }

    fn block_terminated(&self) -> bool {
        self.terminated.contains(&self.cur)
    }

    fn emit(&mut self, op: Op) {
        if !self.block_terminated() {
            self.cfg.blocks[self.cur].ops.push(op);
        }
    }

    fn set_term(&mut self, term: Term) {
        if !self.block_terminated() {
            self.cfg.blocks[self.cur].term = term;
            self.terminated.insert(self.cur);
        }
    }

    fn switch_to(&mut self, block: BlockId) {
        self.cur = block;
    }

    // ---- statements --------------------------------------------------------

    fn lower_block_stmts(&mut self, block: &ast::Block) -> Result<()> {
        self.scopes.push(HashMap::new());
        for stmt in &block.stmts {
            self.lower_stmt(stmt)?;
        }
        self.scopes.pop();
        Ok(())
    }

    fn lower_stmt(&mut self, stmt: &ast::Stmt) -> Result<()> {
        match &stmt.kind {
            ast::StmtKind::Decl { ty, name, init } => {
                // Evaluate the initializer *before* declaring (C scoping:
                // `int x = x;` refers to the outer x).
                let rhs = match init {
                    Some(init) => Some(self.lower_initializer(init, *ty, stmt.dae)?),
                    None => None,
                };
                let dst = self.declare(name, *ty);
                match rhs {
                    Some(Rhs::Expr(e)) => self.emit(Op::Assign { dst, src: e }),
                    Some(Rhs::Spawn { callee, args }) => {
                        self.emit(Op::Spawn { dst: Some(dst), callee, args })
                    }
                    Some(Rhs::Call { callee, args }) => {
                        self.emit(Op::Call { dst: Some(dst), callee, args })
                    }
                    None => self.emit(Op::Assign {
                        dst,
                        src: match ty {
                            Type::Float => Expr::ConstF(0.0),
                            Type::Bool => Expr::ConstB(false),
                            _ => Expr::ConstI(0),
                        },
                    }),
                }
            }
            ast::StmtKind::Assign { name, value } => {
                let dst = self.lookup(name)?;
                let ty = self.vars[dst].ty;
                match self.lower_initializer(value, ty, stmt.dae)? {
                    Rhs::Expr(e) => self.emit(Op::Assign { dst, src: e }),
                    Rhs::Spawn { callee, args } => {
                        self.emit(Op::Spawn { dst: Some(dst), callee, args })
                    }
                    Rhs::Call { callee, args } => {
                        self.emit(Op::Call { dst: Some(dst), callee, args })
                    }
                }
            }
            ast::StmtKind::Store { arr, index, value } => {
                let arr = self.global(arr)?;
                let index = self.lower_expr(index, false)?;
                let value = self.lower_expr(value, false)?;
                self.emit(Op::Store { arr, index, value });
            }
            ast::StmtKind::VoidSpawn(call) => {
                let callee = self.func(&call.name)?;
                let args = self.lower_args(&call.args)?;
                self.emit(Op::Spawn { dst: None, callee, args });
            }
            ast::StmtKind::Sync => {
                let next = self.new_block();
                self.set_term(Term::Sync { next });
                self.switch_to(next);
            }
            ast::StmtKind::If { cond, then, els } => {
                let cond = self.lower_expr(cond, false)?;
                let then_bb = self.new_block();
                let join_bb = self.new_block();
                let else_bb = if els.is_some() { self.new_block() } else { join_bb };
                self.set_term(Term::Branch { cond, then_: then_bb, else_: else_bb });

                self.switch_to(then_bb);
                self.scopes.push(HashMap::new());
                self.lower_stmt(then)?;
                self.scopes.pop();
                self.set_term(Term::Jump(join_bb));

                if let Some(els) = els {
                    self.switch_to(else_bb);
                    self.scopes.push(HashMap::new());
                    self.lower_stmt(els)?;
                    self.scopes.pop();
                    self.set_term(Term::Jump(join_bb));
                }
                self.switch_to(join_bb);
            }
            ast::StmtKind::While { cond, body } => {
                let header = self.new_block();
                let body_bb = self.new_block();
                let exit_bb = self.new_block();
                self.set_term(Term::Jump(header));

                self.switch_to(header);
                let cond = self.lower_expr(cond, false)?;
                self.set_term(Term::Branch { cond, then_: body_bb, else_: exit_bb });

                self.switch_to(body_bb);
                self.scopes.push(HashMap::new());
                self.lower_stmt(body)?;
                self.scopes.pop();
                self.set_term(Term::Jump(header));

                self.switch_to(exit_bb);
            }
            ast::StmtKind::For { init, cond, step, body } => {
                self.scopes.push(HashMap::new());
                if let Some(init) = init {
                    self.lower_stmt(init)?;
                }
                let header = self.new_block();
                let body_bb = self.new_block();
                let exit_bb = self.new_block();
                self.set_term(Term::Jump(header));

                self.switch_to(header);
                let cond = match cond {
                    Some(c) => self.lower_expr(c, false)?,
                    None => Expr::ConstB(true),
                };
                self.set_term(Term::Branch { cond, then_: body_bb, else_: exit_bb });

                self.switch_to(body_bb);
                self.scopes.push(HashMap::new());
                self.lower_stmt(body)?;
                self.scopes.pop();
                if let Some(step) = step {
                    self.lower_stmt(step)?;
                }
                self.set_term(Term::Jump(header));

                self.scopes.pop();
                self.switch_to(exit_bb);
            }
            ast::StmtKind::Return(value) => {
                let value = match value {
                    Some(v) => Some(self.lower_expr(v, false)?),
                    None => None,
                };
                self.set_term(Term::Return(value));
                // Subsequent statements in this block are dead; give them a
                // fresh unreachable block.
                let dead = self.new_block();
                self.switch_to(dead);
            }
            ast::StmtKind::ExprCall(call) => {
                if ast::is_stmt_builtin(&call.name) {
                    match call.name.as_str() {
                        "atomic_add" => {
                            let ast::ExprKind::Var(arr) = &call.args[0].kind else {
                                bail!("atomic_add first arg must be a global name");
                            };
                            let arr = self.global(arr)?;
                            let index = self.lower_expr(&call.args[1], false)?;
                            let value = self.lower_expr(&call.args[2], false)?;
                            self.emit(Op::AtomicAdd { arr, index, value });
                        }
                        other => bail!("unknown builtin `{other}`"),
                    }
                } else {
                    let callee = self.func(&call.name)?;
                    let args = self.lower_args(&call.args)?;
                    self.emit(Op::Call { dst: None, callee, args });
                }
            }
            ast::StmtKind::Block(block) => self.lower_block_stmts(block)?,
        }
        Ok(())
    }

    // ---- initializers / expressions ---------------------------------------

    fn lower_initializer(
        &mut self,
        init: &ast::Initializer,
        _target_ty: Type,
        dae: bool,
    ) -> Result<Rhs> {
        match init {
            ast::Initializer::Expr(e) => Ok(Rhs::Expr(self.lower_expr(e, dae)?)),
            ast::Initializer::Spawn(call) => {
                let callee = self.func(&call.name)?;
                let args = self.lower_args(&call.args)?;
                Ok(Rhs::Spawn { callee, args })
            }
            ast::Initializer::Call(call) => {
                let callee = self.func(&call.name)?;
                let args = self.lower_args(&call.args)?;
                Ok(Rhs::Call { callee, args })
            }
        }
    }

    fn lower_args(&mut self, args: &[ast::Expr]) -> Result<Vec<Expr>> {
        args.iter().map(|a| self.lower_expr(a, false)).collect()
    }

    /// Lower an expression, hoisting global loads into temps. `dae` marks
    /// hoisted loads as DAE-annotated.
    fn lower_expr(&mut self, e: &ast::Expr, dae: bool) -> Result<Expr> {
        Ok(match &e.kind {
            ast::ExprKind::IntLit(v) => Expr::ConstI(*v),
            ast::ExprKind::FloatLit(v) => Expr::ConstF(*v),
            ast::ExprKind::BoolLit(v) => Expr::ConstB(*v),
            ast::ExprKind::Var(name) => Expr::Var(self.lookup(name)?),
            ast::ExprKind::Load { arr, index } => {
                let gid = self.global(arr)?;
                let index = self.lower_expr(index, dae)?;
                let elem = self.module.globals[gid].elem;
                let dst = self.fresh_temp(elem);
                self.emit(Op::Load { dst, arr: gid, index, dae });
                Expr::Var(dst)
            }
            ast::ExprKind::Builtin { name, args } => {
                let b = Builtin::from_name(name)
                    .ok_or_else(|| anyhow!("unknown expression builtin `{name}`"))?;
                let args = args
                    .iter()
                    .map(|a| self.lower_expr(a, dae))
                    .collect::<Result<Vec<_>>>()?;
                Expr::Builtin(b, args)
            }
            ast::ExprKind::Binary { op, lhs, rhs } => {
                let l = self.lower_expr(lhs, dae)?;
                let r = self.lower_expr(rhs, dae)?;
                Expr::Binary(*op, Box::new(l), Box::new(r))
            }
            ast::ExprKind::Unary { op, operand } => {
                let inner = self.lower_expr(operand, dae)?;
                Expr::Unary(*op, Box::new(inner))
            }
        })
    }
}

enum Rhs {
    Expr(Expr),
    Spawn { callee: FuncId, args: Vec<Expr> },
    Call { callee: FuncId, args: Vec<Expr> },
}

/// OpenCilk's implicit sync: rewrite every reachable `return` that may have
/// outstanding children into `sync; return`.
fn insert_implicit_syncs(func: &mut Func) {
    let cfg = func.cfg();
    let n = cfg.blocks.len();
    let mut pending_in = vec![false; n];
    let mut changed = true;
    while changed {
        changed = false;
        for (bid, block) in cfg.blocks.iter() {
            let mut pending = pending_in[bid.index()];
            for op in &block.ops {
                if matches!(op, Op::Spawn { .. }) {
                    pending = true;
                }
            }
            let out = !matches!(block.term, Term::Sync { .. }) && pending;
            for succ in block.term.successors() {
                if out && !pending_in[succ.index()] {
                    pending_in[succ.index()] = true;
                    changed = true;
                }
            }
        }
    }
    let reachable = cfg.reachable();
    let mut to_split = Vec::new();
    for (bid, block) in cfg.blocks.iter() {
        if !reachable[bid.index()] {
            continue;
        }
        if let Term::Return(v) = &block.term {
            let mut pending = pending_in[bid.index()];
            for op in &block.ops {
                if matches!(op, Op::Spawn { .. }) {
                    pending = true;
                }
            }
            if pending {
                to_split.push((bid, v.clone()));
            }
        }
    }
    let cfg = func.cfg_mut();
    for (bid, ret) in to_split {
        let ret_block = cfg.blocks.push(Block { ops: vec![], term: Term::Return(ret) });
        cfg.blocks[bid].term = Term::Sync { next: ret_block };
    }
}

use std::collections::HashSet;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_check;
    use crate::ir::print::print_func;
    use crate::ir::verify::{verify_module, Stage};

    fn lower(src: &str) -> Module {
        let (program, _) = parse_and_check("test.cilk", src).unwrap();
        let module = lower_program(&program).unwrap();
        let errors = verify_module(&module, Stage::Implicit);
        assert!(errors.is_empty(), "verifier: {errors:?}");
        module
    }

    #[test]
    fn fib_cfg_shape() {
        let module = lower(
            "int fib(int n) {
                if (n < 2) return n;
                int x = cilk_spawn fib(n - 1);
                int y = cilk_spawn fib(n - 2);
                cilk_sync;
                return x + y;
            }",
        );
        let fib = &module.funcs[module.func_by_name("fib").unwrap()];
        let cfg = fib.cfg();
        // One sync terminator, two spawns.
        let syncs = cfg.blocks.values().filter(|b| matches!(b.term, Term::Sync { .. })).count();
        assert_eq!(syncs, 1);
        let spawns: usize = cfg
            .blocks
            .values()
            .map(|b| b.ops.iter().filter(|o| matches!(o, Op::Spawn { .. })).count())
            .sum();
        assert_eq!(spawns, 2);
        assert_eq!(fib.kind, FuncKind::Task);
    }

    #[test]
    fn loads_are_hoisted() {
        let module = lower(
            "global int a[16];
             int f(int i) { return a[i] + a[i + 1]; }",
        );
        let f = &module.funcs[module.func_by_name("f").unwrap()];
        let loads: usize = f
            .cfg()
            .blocks
            .values()
            .map(|b| b.ops.iter().filter(|o| matches!(o, Op::Load { .. })).count())
            .sum();
        assert_eq!(loads, 2);
        assert_eq!(f.kind, FuncKind::Leaf);
    }

    #[test]
    fn dae_pragma_marks_loads() {
        let module = lower(
            "global int a[16];
             void f(int i) {
                #pragma bombyx dae
                int x = a[i];
                int y = a[i + 1];
                atomic_add(a, 0, x + y);
             }",
        );
        let f = &module.funcs[module.func_by_name("f").unwrap()];
        let flags: Vec<bool> = f
            .cfg()
            .blocks
            .values()
            .flat_map(|b| b.ops.iter())
            .filter_map(|o| match o {
                Op::Load { dae, .. } => Some(*dae),
                _ => None,
            })
            .collect();
        assert_eq!(flags, vec![true, false]);
    }

    #[test]
    fn implicit_sync_inserted_before_pending_return() {
        // `return 0` after a spawn without a sync — OpenCilk syncs
        // implicitly at function exit.
        let module = lower(
            "void g(int n) { }
             int f(int n) {
                cilk_spawn g(n);
                return 0;
             }",
        );
        let f = &module.funcs[module.func_by_name("f").unwrap()];
        assert!(f.has_syncs(), "implicit sync must be inserted:\n{}", print_func(&module, f));
    }

    #[test]
    fn no_spurious_sync_on_pre_spawn_return() {
        let module = lower(
            "void g(int n) { }
             int f(int n) {
                if (n < 2) return n;
                cilk_spawn g(n);
                cilk_sync;
                return 0;
             }",
        );
        let f = &module.funcs[module.func_by_name("f").unwrap()];
        let syncs = f
            .cfg()
            .blocks
            .values()
            .filter(|b| matches!(b.term, Term::Sync { .. }))
            .count();
        assert_eq!(syncs, 1, "{}", print_func(&module, f));
    }

    #[test]
    fn while_loop_shape() {
        let module = lower(
            "int f(int n) {
                int i = 0;
                while (i < n) { i = i + 1; }
                return i;
             }",
        );
        let f = &module.funcs[module.func_by_name("f").unwrap()];
        // entry, header, body, exit (+possibly dead) — header has 2 preds.
        let cfg = f.cfg();
        let preds = cfg.predecessors();
        assert!(preds.iter().any(|p| p.len() == 2), "loop header with 2 preds expected");
    }

    #[test]
    fn shadowed_names_are_uniquified() {
        let module = lower("int f(int n) { int x = 1; { int x = 2; n = x; } return x; }");
        let f = &module.funcs[module.func_by_name("f").unwrap()];
        crate::ir::verify::check_unique_var_names(f).unwrap();
        let names: Vec<&str> = f.vars.values().map(|v| v.name.as_str()).collect();
        assert!(names.contains(&"x") && names.contains(&"x_2"), "{names:?}");
    }

    #[test]
    fn entry_block_has_no_preds_even_with_leading_loop() {
        let module = lower("int f(int n) { while (n > 0) { n = n - 1; } return n; }");
        let f = &module.funcs[module.func_by_name("f").unwrap()];
        let preds = f.cfg().predecessors();
        assert!(preds[f.cfg().entry.index()].is_empty());
    }

    #[test]
    fn xla_extern_registered() {
        let module = lower(
            "extern xla int relax(int n);
             int f(int n) { int r = cilk_spawn relax(n); cilk_sync; return r; }",
        );
        let relax = &module.funcs[module.func_by_name("relax").unwrap()];
        assert_eq!(relax.kind, FuncKind::Xla);
        assert!(relax.body.is_none());
    }
}
