//! Batch + incremental compilation on top of the pass manager.
//!
//! Two throughput layers over the per-module pipeline (ROADMAP's first
//! scaling follow-ups to the session/pass foundation):
//!
//! - [`compile_batch`]: lower many sources across a scoped thread pool
//!   ([`crate::util::parallel::shard_map`] — the same sharding idiom the
//!   sweep benches use). Per-source errors are aggregated instead of
//!   failing the whole batch; sessions come back in input order with
//!   merged per-pass timing totals.
//! - the **incremental recompilation engine** behind
//!   [`super::CompileSession::recompile`]: every source function is
//!   fingerprinted (a span-insensitive hash of its checked AST subtree),
//!   and an edit re-runs the pipeline only for functions whose
//!   fingerprint changed — each pass executed function-at-a-time
//!   ([`super::pass::Pass::run_on_function`]) and spliced into the cached per-stage
//!   modules. An edit that changes the needed DAE access-function set is
//!   still spliced — clean functions keep their cached post-DAE bodies
//!   with access callee ids remapped to the cold assignment. Structural
//!   edits (changed signatures or globals) fall back to a full pipeline
//!   run, and a shifted explicit-task layout re-runs explicitize only;
//!   either way the result is byte-for-byte the module a cold compile of
//!   the edited source produces — which the test suite asserts via
//!   printed IR.
//!
//! Both are possible because the Fig. 3 pipeline is per-function at every
//! stage: batching parallelizes across modules, incrementality memoizes
//! within one.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::frontend::ast::{
    self, Block, Call, Expr, ExprKind, FuncDef, Initializer, Program, Stmt, StmtKind,
};
use crate::ir::cfg::{FuncKind, Op};
use crate::ir::verify::{verify_module, Stage};
use crate::ir::{FuncId, GlobalId, Module};
use crate::util::parallel;

use super::analysis::{partition_paths, Paths};
use super::pass::{FuncCtx, PassManager, PassTiming, PipelineStage};
use super::{compile_ast, dae, explicitize, CompileOptions, CompileResult, CompileSession};

// ---------------------------------------------------------------------------
// Parallel batch compilation
// ---------------------------------------------------------------------------

/// Outcome of [`compile_batch`]: per-source sessions (or errors) in input
/// order plus merged pass-timing totals across the successful ones.
#[derive(Debug)]
pub struct BatchResult {
    /// One entry per input source, in input order.
    pub outcomes: Vec<(String, Result<CompileSession>)>,
    /// Per-pass totals summed over every successful session (durations
    /// and function counts add; `ran` is true if the pass ran anywhere).
    pub timings: Vec<PassTiming>,
    /// Worker threads actually used.
    pub workers: usize,
}

impl BatchResult {
    /// The successfully compiled sessions, in input order.
    pub fn sessions(&self) -> Vec<&CompileSession> {
        self.outcomes.iter().filter_map(|(_, r)| r.as_ref().ok()).collect()
    }

    /// `(source name, rendered error)` for every failed source.
    pub fn errors(&self) -> Vec<(&str, String)> {
        self.outcomes
            .iter()
            .filter_map(|(n, r)| r.as_ref().err().map(|e| (n.as_str(), format!("{e:#}"))))
            .collect()
    }

    /// Unwrap into owned sessions, or the aggregated error report if any
    /// source failed.
    pub fn into_sessions(self) -> Result<Vec<CompileSession>> {
        let n_err = self.outcomes.iter().filter(|(_, r)| r.is_err()).count();
        if n_err > 0 {
            let rendered: Vec<String> = self
                .errors()
                .iter()
                .map(|(n, e)| format!("{n}: {e}"))
                .collect();
            bail!("{n_err} of {} sources failed to compile:\n{}", self.outcomes.len(), rendered.join("\n"));
        }
        Ok(self.outcomes.into_iter().map(|(_, r)| r.expect("no errors")).collect())
    }
}

/// Parse and lower many sources across `jobs` OS threads (`0` = one per
/// available core). Each source becomes its own [`CompileSession`];
/// per-source failures are captured, not propagated, so one bad file
/// cannot sink the batch. Results preserve input order regardless of the
/// thread count, and the merged [`BatchResult::timings`] give the
/// batch-wide per-pass cost.
pub fn compile_batch<N, S>(
    sources: &[(N, S)],
    opts: &CompileOptions,
    jobs: usize,
) -> BatchResult
where
    N: AsRef<str> + Sync,
    S: AsRef<str> + Sync,
{
    let workers = if jobs == 0 {
        parallel::default_workers(sources.len())
    } else {
        jobs.min(sources.len().max(1))
    };
    let _span = crate::obs::Span::enter(
        format!("compile_batch x{}", sources.len()),
        "session",
    );
    crate::obs::metrics::counter_add("compile.batches", 1);
    crate::obs::metrics::counter_add("compile.batch_sources", sources.len() as u64);
    let results = parallel::shard_map(sources, workers, |(name, src)| {
        CompileSession::new(name.as_ref(), src.as_ref(), opts)
    });
    let mut timings: Vec<PassTiming> = Vec::new();
    let mut outcomes = Vec::with_capacity(results.len());
    for ((name, _), result) in sources.iter().zip(results) {
        if let Ok(session) = &result {
            merge_timings(&mut timings, session.timings());
        }
        outcomes.push((name.as_ref().to_string(), result));
    }
    BatchResult { outcomes, timings, workers }
}

/// Accumulate `add` into `acc` by pass name (durations and function
/// counts sum; a pass that ran anywhere counts as ran).
pub fn merge_timings(acc: &mut Vec<PassTiming>, add: &[PassTiming]) {
    for t in add {
        match acc.iter_mut().find(|a| a.pass == t.pass) {
            Some(a) => {
                a.duration += t.duration;
                a.funcs += t.funcs;
                a.ran |= t.ran;
            }
            None => acc.push(t.clone()),
        }
    }
}

// ---------------------------------------------------------------------------
// AST fingerprints (span-insensitive)
// ---------------------------------------------------------------------------

/// FNV-1a over a structural walk of the AST. Spans are deliberately
/// excluded: editing one function must not dirty the functions below it
/// just because their source positions shifted.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.byte(b);
        }
    }

    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }
}

fn hash_type(h: &mut Fnv, ty: ast::Type) {
    h.byte(match ty {
        ast::Type::Int => 0,
        ast::Type::Float => 1,
        ast::Type::Bool => 2,
        ast::Type::Void => 3,
    });
}

fn hash_params(h: &mut Fnv, params: &[ast::Param]) {
    h.u64(params.len() as u64);
    for p in params {
        h.str(&p.name);
        hash_type(h, p.ty);
    }
}

fn hash_expr(h: &mut Fnv, e: &Expr) {
    match &e.kind {
        ExprKind::IntLit(v) => {
            h.byte(0);
            h.u64(*v as u64);
        }
        ExprKind::FloatLit(v) => {
            h.byte(1);
            h.u64(v.to_bits() as u64);
        }
        ExprKind::BoolLit(v) => {
            h.byte(2);
            h.byte(*v as u8);
        }
        ExprKind::Var(name) => {
            h.byte(3);
            h.str(name);
        }
        ExprKind::Load { arr, index } => {
            h.byte(4);
            h.str(arr);
            hash_expr(h, index);
        }
        ExprKind::Builtin { name, args } => {
            h.byte(5);
            h.str(name);
            h.u64(args.len() as u64);
            for a in args {
                hash_expr(h, a);
            }
        }
        ExprKind::Binary { op, lhs, rhs } => {
            h.byte(6);
            h.byte(*op as u8);
            hash_expr(h, lhs);
            hash_expr(h, rhs);
        }
        ExprKind::Unary { op, operand } => {
            h.byte(7);
            h.byte(*op as u8);
            hash_expr(h, operand);
        }
    }
}

fn hash_call(h: &mut Fnv, c: &Call) {
    h.str(&c.name);
    h.u64(c.args.len() as u64);
    for a in &c.args {
        hash_expr(h, a);
    }
}

fn hash_initializer(h: &mut Fnv, init: &Initializer) {
    match init {
        Initializer::Expr(e) => {
            h.byte(0);
            hash_expr(h, e);
        }
        Initializer::Spawn(c) => {
            h.byte(1);
            hash_call(h, c);
        }
        Initializer::Call(c) => {
            h.byte(2);
            hash_call(h, c);
        }
    }
}

fn hash_block(h: &mut Fnv, b: &Block) {
    h.u64(b.stmts.len() as u64);
    for s in &b.stmts {
        hash_stmt(h, s);
    }
}

fn hash_stmt(h: &mut Fnv, s: &Stmt) {
    h.byte(s.dae as u8);
    match &s.kind {
        StmtKind::Decl { ty, name, init } => {
            h.byte(0);
            hash_type(h, *ty);
            h.str(name);
            h.byte(init.is_some() as u8);
            if let Some(init) = init {
                hash_initializer(h, init);
            }
        }
        StmtKind::Assign { name, value } => {
            h.byte(1);
            h.str(name);
            hash_initializer(h, value);
        }
        StmtKind::Store { arr, index, value } => {
            h.byte(2);
            h.str(arr);
            hash_expr(h, index);
            hash_expr(h, value);
        }
        StmtKind::VoidSpawn(c) => {
            h.byte(3);
            hash_call(h, c);
        }
        StmtKind::Sync => h.byte(4),
        StmtKind::If { cond, then, els } => {
            h.byte(5);
            hash_expr(h, cond);
            hash_stmt(h, then);
            h.byte(els.is_some() as u8);
            if let Some(els) = els {
                hash_stmt(h, els);
            }
        }
        StmtKind::While { cond, body } => {
            h.byte(6);
            hash_expr(h, cond);
            hash_stmt(h, body);
        }
        StmtKind::For { init, cond, step, body } => {
            h.byte(7);
            h.byte(init.is_some() as u8);
            if let Some(init) = init {
                hash_stmt(h, init);
            }
            h.byte(cond.is_some() as u8);
            if let Some(cond) = cond {
                hash_expr(h, cond);
            }
            h.byte(step.is_some() as u8);
            if let Some(step) = step {
                hash_stmt(h, step);
            }
            hash_stmt(h, body);
        }
        StmtKind::Return(value) => {
            h.byte(8);
            h.byte(value.is_some() as u8);
            if let Some(v) = value {
                hash_expr(h, v);
            }
        }
        StmtKind::ExprCall(c) => {
            h.byte(9);
            hash_call(h, c);
        }
        StmtKind::Block(b) => {
            h.byte(10);
            hash_block(h, b);
        }
    }
}

/// Fingerprint of one function definition (signature + body, no spans).
pub fn func_fingerprint(def: &FuncDef) -> u64 {
    let mut h = Fnv::new();
    h.str(&def.name);
    hash_type(&mut h, def.ret);
    hash_params(&mut h, &def.params);
    hash_block(&mut h, &def.body);
    h.0
}

/// Fingerprint of everything *around* function bodies: globals, externs
/// and every function signature, in declaration order. If this changes,
/// `FuncId` assignments (or cross-function lowering inputs) may shift and
/// incremental splicing is unsound — the driver recompiles from scratch.
pub fn structure_fingerprint(program: &Program) -> u64 {
    let mut h = Fnv::new();
    h.u64(program.globals.len() as u64);
    for g in &program.globals {
        h.str(&g.name);
        hash_type(&mut h, g.ty);
        h.byte(g.size.is_some() as u8);
        h.u64(g.size.unwrap_or(0));
    }
    h.u64(program.externs.len() as u64);
    for e in &program.externs {
        h.str(&e.name);
        hash_type(&mut h, e.ret);
        hash_params(&mut h, &e.params);
    }
    h.u64(program.funcs.len() as u64);
    for f in &program.funcs {
        h.str(&f.name);
        hash_type(&mut h, f.ret);
        hash_params(&mut h, &f.params);
    }
    h.0
}

// ---------------------------------------------------------------------------
// Incremental recompilation
// ---------------------------------------------------------------------------

/// Cached per-function compilation identity of a session, against which
/// the next `recompile` diffs.
#[derive(Clone, Debug)]
pub(crate) struct IncrState {
    structure_fp: u64,
    /// Fingerprint per `program.funcs` entry, in order (and ids: source
    /// function `i` is `FuncId(i)` in the implicit modules).
    body_fps: Vec<u64>,
    /// Program funcs + externs: ids below this are source functions, ids
    /// at or above are DAE-generated access functions.
    n_source: usize,
    /// Cached path partitions over the post-DAE implicit module. `None`
    /// until the first recompile computes them — cold compiles never pay
    /// a second partition analysis on top of the one explicitize ran.
    partitions: Option<HashMap<FuncId, Paths>>,
}

impl IncrState {
    /// Structure fingerprint of the program this state was built from
    /// (globals + extern/function signatures). Exposed so session callers
    /// — e.g. the serve daemon's stats — can report compilation identity
    /// without re-parsing.
    pub(crate) fn structure_fp(&self) -> u64 {
        self.structure_fp
    }
}

pub(crate) fn build_incr_state(program: &Program, _result: &CompileResult) -> IncrState {
    IncrState {
        structure_fp: structure_fingerprint(program),
        body_fps: program.funcs.iter().map(func_fingerprint).collect(),
        n_source: program.funcs.len() + program.externs.len(),
        partitions: None,
    }
}

/// What `recompile` decided to do.
pub(crate) enum Recompiled {
    /// No fingerprint changed: the cached result (and every memoized
    /// backend artifact) stays valid. Zero pass work.
    Unchanged,
    /// Only the named functions were re-lowered; everything else was
    /// spliced from the cached stage modules.
    Incremental { result: CompileResult, state: IncrState, dirty: Vec<String> },
    /// A structural change forced a full pipeline run.
    Full { result: CompileResult, state: IncrState },
}

fn full_recompile(program: &Program, opts: &CompileOptions) -> Result<Recompiled> {
    let result = compile_ast(program, opts)?;
    let state = build_incr_state(program, &result);
    Ok(Recompiled::Full { result, state })
}

/// Diff `program` against the cached compilation and re-run the pipeline
/// for changed functions only (see module docs for the fallback rules).
pub(crate) fn recompile(
    program: &Program,
    opts: &CompileOptions,
    cached: &CompileResult,
    state: &IncrState,
) -> Result<Recompiled> {
    // The structure fingerprint hashes the function count and every
    // signature, so a fingerprint match guarantees `body_fps` lines up
    // index-for-index with `program.funcs`.
    if structure_fingerprint(program) != state.structure_fp {
        return full_recompile(program, opts);
    }
    let dirty_ids: Vec<FuncId> = program
        .funcs
        .iter()
        .enumerate()
        .filter(|&(i, f)| func_fingerprint(f) != state.body_fps[i])
        .map(|(i, _)| FuncId::new(i))
        .collect();
    if dirty_ids.is_empty() {
        return Ok(Recompiled::Unchanged);
    }

    // ---- stage A: ast_to_cfg + simplify, dirty functions only -------------
    let mut module_a = (*cached.implicit).clone();
    let mut report = {
        let mut ctx = FuncCtx { program, module: &mut module_a };
        PassManager::incremental_frontend().run_on_functions(
            &mut ctx,
            &dirty_ids,
            PipelineStage::Implicit,
            opts,
        )?
    };

    // ---- stage B: dae + simplify_post_dae, dirty functions only -----------
    let implicit_dae: Arc<Module>;
    let implicit: Arc<Module>;
    // Set when the edit changed the *set* of DAE access functions the
    // module needs, so every cached id at or above `n_source` refers to
    // an access function that moved or no longer exists.
    let mut access_remapped = false;
    if opts.dae {
        // The cached access functions, in creation order, recognized by
        // shape. An unrecognizable trailing function means the cached
        // module was not produced by the DAE pass we know — never splice
        // on a guess.
        let mut cached_access: Vec<GlobalId> = Vec::new();
        let mut recognizable = true;
        for (id, f) in cached.implicit_dae.funcs.iter() {
            if id.index() < state.n_source {
                continue;
            }
            match dae::access_func_target(f) {
                Some(arr) => cached_access.push(arr),
                None => {
                    recognizable = false;
                    break;
                }
            }
        }
        let new_needed = dae::module_dae_globals(&module_a);
        if !recognizable {
            return full_recompile(program, opts);
        }
        access_remapped = cached_access != new_needed;
        implicit = Arc::new(module_a);
        if new_needed.is_empty() {
            // No annotated loads anywhere — either the common no-pragma
            // source under standard options, or the edit removed the
            // last DAE load: the post-DAE module IS the pre-DAE module —
            // cold compiles share one Arc here, and so do we, instead of
            // deep-copying the cached module for a guaranteed no-op
            // segment. The report still mirrors the cold shape.
            implicit_dae = Arc::clone(&implicit);
            report.timings.push(PassTiming {
                pass: "dae",
                duration: Duration::ZERO,
                ran: true,
                funcs: dirty_ids.len(),
            });
            let spd_ran = opts.simplify;
            report.timings.push(PassTiming {
                pass: "simplify_post_dae",
                duration: Duration::ZERO,
                ran: spd_ran,
                funcs: if spd_ran { dirty_ids.len() } else { 0 },
            });
        } else if !access_remapped {
            // The edited module needs exactly the access functions the
            // cached module already has, in the same creation order:
            // ids line up, splice dirty bodies straight in.
            let mut module_b = (*cached.implicit_dae).clone();
            for &fid in &dirty_ids {
                module_b.funcs[fid] = implicit.funcs[fid].clone();
            }
            let mut ctx = FuncCtx { program, module: &mut module_b };
            let dae_report = PassManager::incremental_dae().run_on_functions(
                &mut ctx,
                &dirty_ids,
                PipelineStage::Implicit,
                opts,
            )?;
            report.timings.extend(dae_report.timings);
            implicit_dae = Arc::new(module_b);
        } else {
            // The needed set changed — a dirty edit added the first DAE
            // load of a new global and/or dropped the last load of an
            // old one — so cached access-function ids no longer line up
            // with what a cold compile would assign. Rebuild the
            // post-DAE module in cold creation order: dirty functions
            // start from their freshly re-lowered pre-DAE bodies, clean
            // functions keep their cached post-DAE bodies with
            // access-spawn callees remapped old-id → new-id, and the
            // access functions themselves are regenerated per
            // `new_needed` (the order a cold DAE pass creates them in).
            let mut remap: HashMap<FuncId, FuncId> = HashMap::new();
            for (old_pos, g) in cached_access.iter().enumerate() {
                if let Some(new_pos) = new_needed.iter().position(|n| n == g) {
                    remap.insert(
                        FuncId::new(state.n_source + old_pos),
                        FuncId::new(state.n_source + new_pos),
                    );
                }
            }
            // `implicit` has exactly the source+extern functions — the
            // clone drops the stale access functions for free.
            let mut module_b = (*implicit).clone();
            for i in 0..state.n_source {
                let fid = FuncId::new(i);
                if dirty_ids.contains(&fid) {
                    continue;
                }
                let mut func = cached.implicit_dae.funcs[fid].clone();
                if let Some(cfg) = func.body.as_mut() {
                    for (_, block) in cfg.blocks.iter_mut() {
                        for op in &mut block.ops {
                            let callee = match op {
                                Op::Call { callee, .. } | Op::Spawn { callee, .. } => callee,
                                _ => continue,
                            };
                            if callee.index() >= state.n_source {
                                match remap.get(callee) {
                                    Some(&nid) => *callee = nid,
                                    // A clean function spawning an access
                                    // function whose global left the
                                    // needed set cannot happen (its
                                    // annotated loads are in `module_a`),
                                    // but never splice on a guess.
                                    None => return full_recompile(program, opts),
                                }
                            }
                        }
                    }
                }
                module_b.funcs[fid] = func;
            }
            // Append the new access functions, then run the DAE segment
            // over dirty + access functions: `apply_dae_func` rewrites
            // the dirty bodies against the rebuilt set (a no-op on the
            // access functions themselves), and `simplify_post_dae`
            // touches the fresh access functions exactly as a cold
            // module-wide run would.
            let mut run_ids = dirty_ids.clone();
            for &arr in &new_needed {
                let (gname, elem) = {
                    let g = &module_b.globals[arr];
                    (g.name.clone(), g.elem)
                };
                run_ids.push(module_b.funcs.push(dae::make_access_func(&gname, elem, arr)));
            }
            let mut ctx = FuncCtx { program, module: &mut module_b };
            let dae_report = PassManager::incremental_dae().run_on_functions(
                &mut ctx,
                &run_ids,
                PipelineStage::Implicit,
                opts,
            )?;
            report.timings.extend(dae_report.timings);
            implicit_dae = Arc::new(module_b);
        }
    } else {
        implicit = Arc::new(module_a);
        implicit_dae = Arc::clone(&implicit);
        // Mirror the cold pipeline's report shape: both DAE-segment
        // passes are disabled under these options.
        for pass in ["dae", "simplify_post_dae"] {
            report.timings.push(PassTiming {
                pass,
                duration: Duration::ZERO,
                ran: false,
                funcs: 0,
            });
        }
    }

    // ---- stage C: explicitize, spliced where the task layout allows -------
    let mut partitions = match &state.partitions {
        Some(p) => p.clone(),
        // First recompile of this session: derive the clean functions'
        // partitions from the cached post-DAE module (their CFGs are
        // unchanged); later recompiles reuse the cache built here.
        None => explicitize::compute_partitions(&cached.implicit_dae),
    };
    if access_remapped {
        // The access-function id space shifted: every cached partition
        // entry at or above `n_source` describes an old access function
        // (possibly one that no longer exists). Rebuild that tail from
        // the freshly assembled post-DAE module; source-function entries
        // stay valid (clean CFG structure is untouched — only callee ids
        // inside ops moved, which path partitioning never looks at).
        partitions.retain(|fid, _| fid.index() < state.n_source);
        for (fid, f) in implicit_dae.funcs.iter() {
            if fid.index() >= state.n_source && f.kind == FuncKind::Task && f.body.is_some() {
                partitions.insert(fid, partition_paths(f.cfg()));
            }
        }
    }
    for &fid in &dirty_ids {
        let f = &implicit_dae.funcs[fid];
        if f.kind == FuncKind::Task && f.body.is_some() {
            partitions.insert(fid, partition_paths(f.cfg()));
        } else {
            partitions.remove(&fid);
        }
    }
    let t0 = Instant::now();
    let reservation = explicitize::reserve(&implicit_dae, &partitions);
    let (explicit, converted) = if explicitize::layout_of(&reservation.out)
        == explicitize::layout_of(&cached.explicit)
    {
        let mut out = (*cached.explicit).clone();
        for &fid in &dirty_ids {
            let func = &implicit_dae.funcs[fid];
            match func.kind {
                FuncKind::Leaf | FuncKind::Xla => {
                    let nid = reservation.entry_map[&fid];
                    out.funcs[nid] = reservation.out.funcs[nid].clone();
                }
                FuncKind::Task => {
                    let paths = &partitions[&fid];
                    for pi in 0..paths.entries.len() {
                        let nid = reservation.path_map[&(fid, pi)];
                        out.funcs[nid] = reservation.out.funcs[nid].clone();
                    }
                    explicitize::convert_task_func(
                        &implicit_dae,
                        &mut out,
                        fid,
                        func,
                        paths,
                        &reservation.entry_map,
                        &reservation.path_map,
                    )?;
                }
            }
        }
        (out, dirty_ids.len())
    } else {
        // Path structure shifted: explicit ids moved, so every function
        // is re-converted (the per-function work of stages A/B is still
        // saved for the clean functions).
        (explicitize::explicitize_with(&implicit_dae, &partitions)?, implicit_dae.funcs.len())
    };
    let errors = verify_module(&explicit, Stage::Explicit);
    if !errors.is_empty() {
        bail!(
            "incremental explicitize splice broke the explicit IR invariants:\n  {}",
            errors.join("\n  ")
        );
    }
    report.timings.push(PassTiming {
        pass: "explicitize",
        duration: t0.elapsed(),
        ran: true,
        funcs: converted,
    });

    let dirty_names: Vec<String> =
        dirty_ids.iter().map(|&fid| implicit.funcs[fid].name.clone()).collect();
    let result = CompileResult {
        implicit,
        implicit_dae,
        explicit: Arc::new(explicit),
        timings: report.timings.clone(),
    };
    let new_state = IncrState {
        structure_fp: state.structure_fp,
        body_fps: program.funcs.iter().map(func_fingerprint).collect(),
        n_source: state.n_source,
        partitions: Some(partitions),
    };
    Ok(Recompiled::Incremental { result, state: new_state, dirty: dirty_names })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_check;

    const TWO: &str = "int leaf(int a) { return a + 1; }
        int top(int n) {
            if (n < 2) return n;
            int x = cilk_spawn top(n - 1);
            cilk_sync;
            int r = leaf(x);
            return r;
        }";

    #[test]
    fn fingerprints_ignore_spans() {
        let (a, _) = parse_and_check("t", TWO).unwrap();
        // Same program with extra leading whitespace/newlines: every span
        // shifts, no fingerprint may change.
        let shifted = format!("\n\n   \n{TWO}");
        let (b, _) = parse_and_check("t", &shifted).unwrap();
        assert_eq!(structure_fingerprint(&a), structure_fingerprint(&b));
        for (fa, fb) in a.funcs.iter().zip(&b.funcs) {
            assert_eq!(func_fingerprint(fa), func_fingerprint(fb), "{}", fa.name);
        }
    }

    #[test]
    fn body_edit_changes_only_that_fingerprint() {
        let (a, _) = parse_and_check("t", TWO).unwrap();
        let edited = TWO.replace("a + 1", "a + 2");
        let (b, _) = parse_and_check("t", &edited).unwrap();
        assert_eq!(structure_fingerprint(&a), structure_fingerprint(&b));
        assert_ne!(func_fingerprint(&a.funcs[0]), func_fingerprint(&b.funcs[0]));
        assert_eq!(func_fingerprint(&a.funcs[1]), func_fingerprint(&b.funcs[1]));
    }

    #[test]
    fn signature_edit_changes_structure() {
        let (a, _) = parse_and_check("t", TWO).unwrap();
        let edited = TWO.replace("int leaf(int a)", "int leaf(int b)").replace("a + 1", "b + 1");
        let (b, _) = parse_and_check("t", &edited).unwrap();
        assert_ne!(structure_fingerprint(&a), structure_fingerprint(&b));
    }

    #[test]
    fn batch_preserves_order_and_captures_errors() {
        let sources = [
            ("ok1", TWO),
            ("bad", "int broken( {"),
            ("ok2", "int f(int n) { return n; }"),
        ];
        let batch = compile_batch(&sources, &CompileOptions::standard(), 2);
        assert_eq!(batch.outcomes.len(), 3);
        assert_eq!(batch.outcomes[0].0, "ok1");
        assert!(batch.outcomes[0].1.is_ok());
        assert!(batch.outcomes[1].1.is_err());
        assert!(batch.outcomes[2].1.is_ok());
        assert_eq!(batch.errors().len(), 1);
        assert_eq!(batch.sessions().len(), 2);
        assert!(batch.into_sessions().is_err());
    }

    #[test]
    fn merge_timings_sums_by_pass() {
        let mut acc = Vec::new();
        let rows = [
            PassTiming { pass: "ast_to_cfg", duration: Duration::from_micros(5), ran: true, funcs: 2 },
            PassTiming { pass: "dae", duration: Duration::ZERO, ran: false, funcs: 0 },
        ];
        merge_timings(&mut acc, &rows);
        merge_timings(&mut acc, &rows);
        assert_eq!(acc.len(), 2);
        assert_eq!(acc[0].funcs, 4);
        assert_eq!(acc[0].duration, Duration::from_micros(10));
        assert!(!acc[1].ran);
    }
}
