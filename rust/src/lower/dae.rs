//! Decoupled access–execute transform (paper §II-C).
//!
//! For each `#pragma bombyx dae`-marked [`Op::Load`], Bombyx:
//!
//! 1. creates (or reuses) an *access function* `<global>_access(idx)` whose
//!    whole body is `return <global>[idx];`;
//! 2. replaces the load with `dst = cilk_spawn <global>_access(index)`;
//! 3. inserts a `cilk_sync` immediately after the (consecutive run of)
//!    converted loads, splitting the containing block — "the compiler will
//!    split that operation and the code after it into separate tasks".
//!
//! After explicitization this yields exactly the paper's PE trio: the
//! original task becomes the *spawner*, the access function becomes the
//! *access* PE, and the post-sync continuation becomes the *executor* PE.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::frontend::ast::Type;
use crate::ir::cfg::{Block, Cfg, Func, FuncKind, GlobalId, Module, Op, Term};
use crate::ir::expr::{Expr, Var};
use crate::util::idvec::IdVec;

/// Does any function of the module carry an annotated load? A cheap
/// read-only scan the pass manager uses to keep the no-pragma path
/// copy-free (no `Arc::make_mut` when nothing would change).
pub fn module_has_dae_loads(module: &Module) -> bool {
    module.funcs.values().any(|f| !func_dae_globals(f).is_empty())
}

/// Globals a single function's annotated loads target, in first-use order
/// (block id order, then op order) — the per-function slice of the
/// access-function creation order.
pub fn func_dae_globals(func: &crate::ir::cfg::Func) -> Vec<GlobalId> {
    let mut needed = Vec::new();
    let Some(cfg) = func.body.as_ref() else { return needed };
    for block in cfg.blocks.values() {
        for op in &block.ops {
            if let Op::Load { dae: true, arr, .. } = op {
                if !needed.contains(arr) {
                    needed.push(*arr);
                }
            }
        }
    }
    needed
}

/// Globals needing access functions, in the order a cold [`apply_dae`]
/// creates them (function id order, first use wins). The incremental
/// driver compares this against the cached module's access functions to
/// decide whether per-function splicing stays id-compatible.
pub fn module_dae_globals(module: &Module) -> Vec<GlobalId> {
    let mut needed: Vec<GlobalId> = Vec::new();
    for (_, func) in module.funcs.iter() {
        for arr in func_dae_globals(func) {
            if !needed.contains(&arr) {
                needed.push(arr);
            }
        }
    }
    needed
}

/// If `func` is a generated access function, the global it serves.
/// Recognized by shape: the single-block `load idx; return` body created
/// by [`make_access_func`] (plus the `_access` name suffix).
pub fn access_func_target(func: &crate::ir::cfg::Func) -> Option<GlobalId> {
    if func.kind != FuncKind::Task || func.params != 1 || !func.name.ends_with("_access") {
        return None;
    }
    let cfg = func.body.as_ref()?;
    if cfg.blocks.len() != 1 {
        return None;
    }
    let block = &cfg.blocks[cfg.entry];
    match (block.ops.as_slice(), &block.term) {
        ([Op::Load { arr, .. }], Term::Return(Some(_))) => Some(*arr),
        _ => None,
    }
}

/// Apply the DAE transform to every annotated load in the module.
/// Returns the number of loads converted.
pub fn apply_dae(module: &mut Module) -> Result<usize> {
    // First collect the set of globals needing access functions, then
    // create them (stable ids), then rewrite bodies.
    let mut needed: Vec<GlobalId> = Vec::new();
    for (_, func) in module.funcs.iter() {
        let globals = func_dae_globals(func);
        if !globals.is_empty() && func.kind != FuncKind::Task {
            bail!(
                "`#pragma bombyx dae` in leaf function `{}`: DAE requires a task \
                 context (the access becomes a spawned task)",
                func.name
            );
        }
        for arr in globals {
            if !needed.contains(&arr) {
                needed.push(arr);
            }
        }
    }
    if needed.is_empty() {
        return Ok(0);
    }

    let mut access_funcs: HashMap<GlobalId, crate::ir::FuncId> = HashMap::new();
    for arr in needed {
        let g = &module.globals[arr];
        let fid = module.funcs.push(make_access_func(&g.name, g.elem, arr));
        access_funcs.insert(arr, fid);
    }

    let mut converted = 0;
    for (_, func) in module.funcs.iter_mut() {
        if func.kind != FuncKind::Task || func.body.is_none() {
            continue;
        }
        converted += rewrite_func(func, &access_funcs)?;
    }
    Ok(converted)
}

/// Function-at-a-time DAE (incremental recompilation): rewrite only
/// `fid`'s annotated loads against the module's *existing* access
/// functions. The incremental driver guarantees up front that the
/// access-function set already matches what a cold [`apply_dae`] of the
/// edited module would create (falling back to a full compile otherwise);
/// a missing access function here is therefore an internal error, not a
/// fallback signal.
pub fn apply_dae_func(module: &mut Module, fid: crate::ir::FuncId) -> Result<usize> {
    let needed = func_dae_globals(&module.funcs[fid]);
    if needed.is_empty() {
        return Ok(0);
    }
    if module.funcs[fid].kind != FuncKind::Task {
        bail!(
            "`#pragma bombyx dae` in leaf function `{}`: DAE requires a task \
             context (the access becomes a spawned task)",
            module.funcs[fid].name
        );
    }
    let mut access_funcs: HashMap<GlobalId, crate::ir::FuncId> = HashMap::new();
    for (id, f) in module.funcs.iter() {
        if let Some(arr) = access_func_target(f) {
            access_funcs.insert(arr, id);
        }
    }
    for arr in &needed {
        if !access_funcs.contains_key(arr) {
            bail!(
                "incremental DAE: no access function for global `{}` in the cached module \
                 (structure changed — the driver should have fallen back to a full compile)",
                module.globals[*arr].name
            );
        }
    }
    rewrite_func(&mut module.funcs[fid], &access_funcs)
}

/// `int <name>_access(int idx) { return <name>[idx]; }` — a *task* (it is
/// spawned; in hardware it becomes the access PE). `pub(crate)` so the
/// incremental engine can append the same access functions when a dirty
/// edit changes the needed set (`lower/batch.rs` remap splice).
pub(crate) fn make_access_func(global_name: &str, elem: Type, arr: GlobalId) -> Func {
    let mut vars = IdVec::new();
    let idx = vars.push(Var { name: "idx".into(), ty: Type::Int, is_param: true, is_temp: false });
    let tmp = vars.push(Var { name: "t0".into(), ty: elem, is_param: false, is_temp: true });
    let mut cfg = Cfg::default();
    let entry = cfg.blocks.push(Block {
        ops: vec![Op::Load { dst: tmp, arr, index: Expr::Var(idx), dae: false }],
        term: Term::Return(Some(Expr::Var(tmp))),
    });
    cfg.entry = entry;
    Func {
        name: format!("{global_name}_access"),
        ret: elem,
        params: 1,
        vars,
        body: Some(cfg),
        kind: FuncKind::Task,
        task: None,
    }
}

/// Rewrite one function; returns number of converted loads.
fn rewrite_func(
    func: &mut Func,
    access_funcs: &HashMap<GlobalId, crate::ir::FuncId>,
) -> Result<usize> {
    let mut converted = 0;
    let cfg = func.cfg_mut();
    // Iterate blocks by index; rewriting appends new blocks.
    let mut bi = 0;
    while bi < cfg.blocks.len() {
        let bid = crate::ir::BlockId::new(bi);
        // Find the first DAE load in this block.
        let pos = cfg.blocks[bid]
            .ops
            .iter()
            .position(|op| matches!(op, Op::Load { dae: true, .. }));
        let Some(pos) = pos else {
            bi += 1;
            continue;
        };
        // Everything from `pos` on is partitioned into the spawn group
        // (DAE loads whose indices only use values defined before `pos`)
        // and the continuation tail (everything else — including the
        // assigns that consume the loaded values, which may only run after
        // the sync anyway). A DAE load depending on a tail-defined value
        // keeps its flag and is converted when its (new) block is visited,
        // yielding a chained access→sync→access pipeline.
        let rest: Vec<Op> = cfg.blocks[bid].ops.split_off(pos);
        let old_term = std::mem::take(&mut cfg.blocks[bid].term);

        let mut tail_ops: Vec<Op> = Vec::new();
        let mut tail_defs: Vec<crate::ir::VarId> = Vec::new();
        // Results of loads already converted in this block's spawn group:
        // a later DAE load whose index reads one of them must wait for the
        // inserted sync, i.e. it belongs to the continuation (where it is
        // converted in a later iteration — a chained access→sync→access
        // pipeline).
        let mut group_defs: Vec<crate::ir::VarId> = Vec::new();
        for op in rest {
            let convertible = match &op {
                Op::Load { dae: true, index, .. } => {
                    let mut independent = true;
                    index.for_each_var(&mut |v| {
                        if tail_defs.contains(&v) || group_defs.contains(&v) {
                            independent = false;
                        }
                    });
                    independent
                }
                _ => false,
            };
            if convertible {
                let Op::Load { dst, arr, index, .. } = op else { unreachable!() };
                let callee = access_funcs[&arr];
                cfg.blocks[bid].ops.push(Op::Spawn {
                    dst: Some(dst),
                    callee,
                    args: vec![index],
                });
                group_defs.push(dst);
                converted += 1;
            } else {
                if let Some(d) = op.def() {
                    tail_defs.push(d);
                }
                tail_ops.push(op);
            }
        }
        if tail_ops.is_empty() && matches!(old_term, Term::Sync { .. }) {
            // Empty continuation: nothing runs between the converted loads
            // and the user's own sync, so the spawned accesses join there
            // directly — splitting would only create an empty block and a
            // redundant back-to-back sync (and, after explicitization, an
            // empty continuation task).
            cfg.blocks[bid].term = old_term;
        } else {
            let cont = cfg.blocks.push(Block { ops: tail_ops, term: old_term });
            cfg.blocks[bid].term = Term::Sync { next: cont };
        }
        bi += 1;
    }
    Ok(converted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_check;
    use crate::ir::print::print_module;
    use crate::ir::verify::{verify_module, Stage};
    use crate::lower::ast_to_cfg::lower_program;

    fn lower_with_dae(src: &str) -> (Module, usize) {
        let (p, _) = parse_and_check("t", src).unwrap();
        let mut m = lower_program(&p).unwrap();
        let n = apply_dae(&mut m).unwrap();
        let errors = verify_module(&m, Stage::Implicit);
        assert!(errors.is_empty(), "verify: {errors:?}\n{}", print_module(&m));
        (m, n)
    }

    const BFS_DAE_FLAT: &str = "
        global int adj_off[];
        global int adj_edges[];
        global int visited[];
        void visit(int n) {
            #pragma bombyx dae
            int off = adj_off[n];
            #pragma bombyx dae
            int end = adj_off[n + 1];
            visited[n] = 1;
            for (int i = off; i < end; i = i + 1) {
                cilk_spawn visit(adj_edges[i]);
            }
            cilk_sync;
        }";

    #[test]
    fn bfs_dae_creates_access_task_and_sync() {
        let (m, n) = lower_with_dae(BFS_DAE_FLAT);
        assert_eq!(n, 2, "two annotated loads converted");
        let access = m.func_by_name("adj_off_access").expect("access function created");
        assert_eq!(m.funcs[access].kind, FuncKind::Task);
        let visit = &m.funcs[m.func_by_name("visit").unwrap()];
        // Consecutive DAE loads share one inserted sync; the loop sync is
        // the second.
        let syncs = visit
            .cfg()
            .blocks
            .values()
            .filter(|b| matches!(b.term, Term::Sync { .. }))
            .count();
        assert_eq!(syncs, 2, "{}", print_module(&m));
        let spawns_of_access: usize = visit
            .cfg()
            .blocks
            .values()
            .flat_map(|b| b.ops.iter())
            .filter(|op| matches!(op, Op::Spawn { callee, .. } if *callee == access))
            .count();
        assert_eq!(spawns_of_access, 2);
    }

    #[test]
    fn single_dae_with_user_sync() {
        let (m, n) = lower_with_dae(
            "global int a[];
             void g(int v) { atomic_add(a, 0, v); }
             void f(int i) {
                #pragma bombyx dae
                int x = a[i];
                cilk_spawn g(x);
                cilk_sync;
             }",
        );
        assert_eq!(n, 1);
        let f = &m.funcs[m.func_by_name("f").unwrap()];
        let syncs = f
            .cfg()
            .blocks
            .values()
            .filter(|b| matches!(b.term, Term::Sync { .. }))
            .count();
        assert_eq!(syncs, 2, "DAE sync + user sync:\n{}", print_module(&m));
    }

    #[test]
    fn no_pragma_no_change() {
        let (p, _) = parse_and_check(
            "t",
            "global int a[];
             void g(int v) { atomic_add(a, 0, v); }
             void f(int i) { int x = a[i]; cilk_spawn g(x); cilk_sync; }",
        )
        .unwrap();
        let mut m = lower_program(&p).unwrap();
        let before = print_module(&m);
        let n = apply_dae(&mut m).unwrap();
        assert_eq!(n, 0);
        assert_eq!(print_module(&m), before);
    }

    #[test]
    fn access_task_reused_across_functions() {
        let (m, n) = lower_with_dae(
            "global int a[];
             void h(int v) { atomic_add(a, 0, v); }
             void f(int i) {
                #pragma bombyx dae
                int x = a[i];
                cilk_spawn h(x);
                cilk_sync;
             }
             void g(int i) {
                #pragma bombyx dae
                int y = a[i + 1];
                cilk_spawn h(y);
                cilk_sync;
             }",
        );
        assert_eq!(n, 2);
        let count = m.funcs.values().filter(|f| f.name == "a_access").count();
        assert_eq!(count, 1, "one access task per global");
    }

    #[test]
    fn dae_load_with_empty_continuation_does_not_split() {
        // The annotated load is the last op before the user's own sync and
        // its result is never read afterwards: the rewrite must let the
        // access task join at that sync instead of splitting off an empty
        // continuation block behind a second, back-to-back sync.
        let (m, n) = lower_with_dae(
            "global int a[];
             void g(int v) { atomic_add(a, 0, v); }
             void f(int i) {
                cilk_spawn g(i);
                #pragma bombyx dae
                int x = a[i];
                cilk_sync;
             }",
        );
        assert_eq!(n, 1);
        let f = &m.funcs[m.func_by_name("f").unwrap()];
        let syncs = f
            .cfg()
            .blocks
            .values()
            .filter(|b| matches!(b.term, Term::Sync { .. }))
            .count();
        assert_eq!(syncs, 1, "no extra sync for an empty continuation:\n{}", print_module(&m));
        let empty_sync_blocks = f
            .cfg()
            .blocks
            .values()
            .filter(|b| b.ops.is_empty() && matches!(b.term, Term::Sync { .. }))
            .count();
        assert_eq!(empty_sync_blocks, 0, "{}", print_module(&m));
    }

    #[test]
    fn chained_dependent_dae_loads_get_separate_syncs() {
        // y's index reads x, itself the result of a converted access: y
        // must not join x's spawn group (its index would be evaluated
        // before x arrives). It lands in the continuation and is converted
        // there — two access/sync rounds plus the user's sync.
        let (m, n) = lower_with_dae(
            "global int a[];
             void g(int v) { atomic_add(a, 0, v); }
             void f(int i) {
                #pragma bombyx dae
                int x = a[i];
                #pragma bombyx dae
                int y = a[x];
                cilk_spawn g(y);
                cilk_sync;
             }",
        );
        assert_eq!(n, 2, "both loads eventually converted");
        let f = &m.funcs[m.func_by_name("f").unwrap()];
        let syncs = f
            .cfg()
            .blocks
            .values()
            .filter(|b| matches!(b.term, Term::Sync { .. }))
            .count();
        assert_eq!(syncs, 3, "access(x) | access(y) | user sync:\n{}", print_module(&m));
    }

    #[test]
    fn dae_in_leaf_rejected() {
        let (p, _) = parse_and_check(
            "t",
            "global int a[];
             int f(int i) {
                #pragma bombyx dae
                int x = a[i];
                return x;
             }",
        )
        .unwrap();
        let mut m = lower_program(&p).unwrap();
        let err = apply_dae(&mut m).unwrap_err();
        assert!(err.to_string().contains("leaf function"), "{err}");
    }
}
