//! Implicit → explicit IR conversion (paper §II-A, Fig. 4(b) → Fig. 4(c)).
//!
//! The CFG of every task function is partitioned into *paths* — each path a
//! self-contained terminating function. Conversion steps per function:
//!
//! 1. **Partition** ([`analysis::partition_paths`]): entries are the
//!    function entry, every sync successor, and join blocks promoted to
//!    entries (fixpoint).
//! 2. **Closure construction**: for each sync block `s` with continuation
//!    entry `t`, the continuation task's parameters are `live-in(t)`;
//!    parameters assigned by spawns joining at `s` become *holes*, the rest
//!    are *ready arguments*. A `spawn_next` ([`Op::MakeClosure`]) is placed
//!    at the nearest common dominator of the spawn sites and `s`, hoisted
//!    out of any loop not containing `s` (a loop-carried closure handle is
//!    just a value that flows through the loop task's parameters — this is
//!    how the BFS executor of the paper keeps one closure alive across its
//!    spawn loop).
//! 3. **Spawn conversion**: `x = cilk_spawn f(...)` becomes
//!    `spawn f_entry(...) -> c.arg<i>` ([`Op::SpawnChild`] with a
//!    [`RetTarget::Slot`]), void spawns decrement only the join counter
//!    ([`RetTarget::Counter`]).
//! 4. **Split**: each path becomes a task; `sync` becomes
//!    `close_spawns + halt`, `return` becomes `send_argument(k) + halt`,
//!    and inter-path control edges become tail spawns with
//!    [`RetTarget::Forward`].
//!
//! Join counters are dynamic (created at 1 = creator hold, incremented per
//! spawn, hold dropped by `close_spawns`) which supports data-dependent
//! spawn counts with no races — see DESIGN.md §6.2.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::ir::cfg::{
    Block, BlockId, Cfg, Func, FuncId, FuncKind, Module, Op, RetTarget, TaskMeta, TaskRole, Term,
};
use crate::ir::expr::{Expr, Var, VarId};
use crate::util::idvec::IdVec;

use super::analysis::{
    common_dominator, dominators, liveness, natural_loops, partition_paths, spawn_sync_map, Paths,
};

/// Path partitions of every task function, keyed by source `FuncId`.
/// A pure per-function analysis — the incremental driver caches entries
/// for unchanged functions and recomputes only dirty ones.
pub fn compute_partitions(module: &Module) -> HashMap<FuncId, Paths> {
    module
        .funcs
        .iter()
        .filter(|(_, f)| f.kind == FuncKind::Task && f.body.is_some())
        .map(|(fid, f)| (fid, partition_paths(f.cfg())))
        .collect()
}

/// The reservation (pass 1) result: the skeleton output module with every
/// explicit `FuncId` assigned, leaf/xla functions copied (call targets
/// remapped), and task slots reserved with their names and metadata. The
/// id assignment is a pure function of each source function's kind, name
/// and partition shape — which is what makes incremental splicing sound.
pub(crate) struct Reservation {
    pub out: Module,
    /// old FuncId -> new entry FuncId (for leaf/xla: the copy).
    pub entry_map: HashMap<FuncId, FuncId>,
    /// (old FuncId, path index) -> new FuncId.
    pub path_map: HashMap<(FuncId, usize), FuncId>,
}

pub(crate) fn reserve(module: &Module, partitions: &HashMap<FuncId, Paths>) -> Reservation {
    let mut out = Module { globals: module.globals.clone(), funcs: IdVec::new() };
    let mut entry_map: HashMap<FuncId, FuncId> = HashMap::new();
    let mut path_map: HashMap<(FuncId, usize), FuncId> = HashMap::new();

    for (fid, func) in module.funcs.iter() {
        match func.kind {
            FuncKind::Leaf => {
                let new_id = out.funcs.push(func.clone());
                entry_map.insert(fid, new_id);
            }
            FuncKind::Xla => {
                let mut f = func.clone();
                f.task = Some(TaskMeta {
                    role: TaskRole::Xla,
                    cont_ty: f.ret,
                    source: f.name.clone(),
                });
                let new_id = out.funcs.push(f);
                entry_map.insert(fid, new_id);
            }
            FuncKind::Task => {
                let paths = &partitions[&fid];
                let cfg = func.cfg();
                let mut cont_n = 0;
                let mut join_n = 0;
                for (pi, &entry) in paths.entries.iter().enumerate() {
                    let is_sync_target = cfg.blocks.values().any(
                        |b| matches!(b.term, Term::Sync { next } if next == entry),
                    );
                    let (name, role) = if pi == 0 {
                        let role = if func.name.ends_with("_access") {
                            TaskRole::Access
                        } else {
                            TaskRole::Entry
                        };
                        (func.name.clone(), role)
                    } else if is_sync_target {
                        cont_n += 1;
                        (format!("{}__k{}", func.name, cont_n), TaskRole::Continuation)
                    } else {
                        join_n += 1;
                        (format!("{}__j{}", func.name, join_n), TaskRole::Join)
                    };
                    let new_id = out.funcs.push(Func {
                        name,
                        ret: func.ret,
                        params: 0,
                        vars: IdVec::new(),
                        body: None,
                        kind: FuncKind::Task,
                        task: Some(TaskMeta {
                            role,
                            cont_ty: func.ret,
                            source: func.name.clone(),
                        }),
                    });
                    path_map.insert((fid, pi), new_id);
                    if pi == 0 {
                        entry_map.insert(fid, new_id);
                    }
                }
            }
        }
    }

    // Rewrite leaf Call targets inside copied leaf functions.
    for (_, func) in out.funcs.iter_mut() {
        if func.kind == FuncKind::Leaf {
            if let Some(cfg) = func.body.as_mut() {
                for (_, block) in cfg.blocks.iter_mut() {
                    for op in &mut block.ops {
                        if let Op::Call { callee, .. } = op {
                            *callee = entry_map[callee];
                        }
                    }
                }
            }
        }
    }

    Reservation { out, entry_map, path_map }
}

/// The identity of an explicit module's function table: per function, its
/// name, kind and task role. Two source modules whose reservations have
/// equal layouts assign identical explicit `FuncId`s, so functions
/// converted against one layout splice soundly into the other.
pub(crate) fn layout_of(module: &Module) -> Vec<(String, FuncKind, Option<TaskRole>)> {
    module
        .funcs
        .values()
        .map(|f| (f.name.clone(), f.kind, f.task.as_ref().map(|t| t.role)))
        .collect()
}

/// Explicitize every task function of a module. Leaf functions are copied;
/// `extern xla` declarations become XLA tasks.
pub fn explicitize_module(module: &Module) -> Result<Module> {
    explicitize_with(module, &compute_partitions(module))
}

/// [`explicitize_module`] over pre-computed partitions (the incremental
/// driver reuses cached partitions for unchanged functions).
pub(crate) fn explicitize_with(
    module: &Module,
    partitions: &HashMap<FuncId, Paths>,
) -> Result<Module> {
    let Reservation { mut out, entry_map, path_map } = reserve(module, partitions);
    for (fid, func) in module.funcs.iter() {
        if func.kind != FuncKind::Task {
            continue;
        }
        convert_task_func(module, &mut out, fid, func, &partitions[&fid], &entry_map, &path_map)?;
    }
    Ok(out)
}

pub(crate) fn convert_task_func(
    module: &Module,
    out: &mut Module,
    fid: FuncId,
    func: &Func,
    paths: &Paths,
    entry_map: &HashMap<FuncId, FuncId>,
    path_map: &HashMap<(FuncId, usize), FuncId>,
) -> Result<()> {
    // ---- phase A: analyses on the original CFG -----------------------------
    let orig_live = liveness(func);
    let cfg0 = func.cfg();
    let idom = dominators(cfg0);
    let loops = natural_loops(cfg0, &idom);
    let sync_spawns = spawn_sync_map(func)?;

    // Continuation parameter lists (sorted live-in of each sync target),
    // shared between closure construction here and task construction below.
    // Keyed by path entry block.
    let mut path_params: HashMap<BlockId, Vec<VarId>> = HashMap::new();
    for (pi, &entry) in paths.entries.iter().enumerate() {
        if pi == 0 {
            path_params.insert(entry, func.param_ids().collect());
        } else {
            let mut vars = orig_live.live_in_vars(entry);
            vars.sort();
            path_params.insert(entry, vars);
        }
    }

    // ---- phase B: instrument a working copy ---------------------------------
    let mut work = func.clone();
    let sync_blocks: Vec<(BlockId, BlockId)> = cfg0
        .blocks
        .iter()
        .filter_map(|(bid, b)| match b.term {
            Term::Sync { next } => Some((bid, next)),
            _ => None,
        })
        .collect();

    // Allocate one closure var per sync and plan every mutation before
    // touching the CFG (op indices stay valid only while nothing shifts).
    struct SyncPlan {
        s: BlockId,
        clos: VarId,
        cont_task: FuncId,
        cont_params: Vec<VarId>,
        insert_at: BlockId,
        spawn_sites: Vec<(BlockId, usize)>,
    }
    let mut plans: Vec<SyncPlan> = Vec::new();
    for (s, target) in &sync_blocks {
        let (s, target) = (*s, *target);
        let clos = work.vars.push(Var {
            name: format!("c{}", s.index()),
            ty: crate::frontend::ast::Type::Int,
            is_param: false,
            is_temp: true,
        });
        // Where to create the closure: NCD of spawn sites and the sync,
        // hoisted out of loops that don't contain the sync.
        let spawn_sites = sync_spawns.get(&s).cloned().unwrap_or_default();
        let mut ncd_blocks: Vec<BlockId> = spawn_sites.iter().map(|(b, _)| *b).collect();
        ncd_blocks.push(s);
        let mut insert_at = common_dominator(cfg0, &idom, &ncd_blocks);
        loop {
            let Some((header, _)) = loops
                .iter()
                .find(|(_, body)| body.contains(&insert_at) && !body.contains(&s))
            else {
                break;
            };
            let Some(up) = idom[header.index()] else { break };
            if up == *header {
                bail!("cannot hoist spawn_next out of irreducible loop in `{}`", func.name);
            }
            insert_at = up;
        }
        plans.push(SyncPlan {
            s,
            clos,
            cont_task: path_map[&(fid, paths.path_of(target))],
            cont_params: path_params[&target].clone(),
            insert_at,
            spawn_sites,
        });
    }

    // Step 1: convert every spawn in place (indices untouched).
    for plan in &plans {
        let work_cfg = work.cfg_mut();
        for (bid, oi) in &plan.spawn_sites {
            let op = &mut work_cfg.blocks[*bid].ops[*oi];
            let Op::Spawn { dst, callee, args } = op.clone() else {
                bail!("spawn site moved during instrumentation (compiler bug)");
            };
            let ret = match dst {
                Some(d) => match plan.cont_params.iter().position(|&p| p == d) {
                    Some(field) => RetTarget::Slot { clos: plan.clos, field: field as u32 },
                    None => RetTarget::Counter { clos: plan.clos }, // result dead after sync
                },
                None => RetTarget::Counter { clos: plan.clos },
            };
            *op = Op::SpawnChild { callee: entry_map[&callee], args, ret };
        }
    }

    // Step 2: ready-argument stores + close at each sync block (appends —
    // no index shifts for other plans' spawn sites, which precede syncs).
    for plan in &plans {
        let holes: Vec<VarId> = plan
            .spawn_sites
            .iter()
            .filter_map(|(b, oi)| match &work.cfg().blocks[*b].ops[*oi] {
                Op::SpawnChild { ret: RetTarget::Slot { field, .. }, .. } => {
                    Some(plan.cont_params[*field as usize])
                }
                _ => None,
            })
            .collect();
        let work_cfg = work.cfg_mut();
        for (field, &p) in plan.cont_params.iter().enumerate() {
            if !holes.contains(&p) {
                work_cfg.blocks[plan.s].ops.push(Op::ClosureStore {
                    clos: plan.clos,
                    field: field as u32,
                    value: Expr::Var(p),
                });
            }
        }
        work_cfg.blocks[plan.s].ops.push(Op::CloseSpawns { clos: plan.clos });
    }

    // Step 3: MakeClosure insertions at block starts (done last — they
    // shift op indices, which no later step consults).
    for plan in &plans {
        let work_cfg = work.cfg_mut();
        work_cfg.blocks[plan.insert_at]
            .ops
            .insert(0, Op::MakeClosure { dst: plan.clos, task: plan.cont_task });
    }

    // Rewrite spawn callee ids for any spawns NOT attached to a sync —
    // there are none (spawn_sync_map guarantees), but Call targets must be
    // remapped to the new module's leaf ids.
    let work_cfg = work.cfg_mut();
    for (_, block) in work_cfg.blocks.iter_mut() {
        for op in &mut block.ops {
            if let Op::Call { callee, .. } = op {
                *callee = entry_map[callee];
            }
        }
    }

    // ---- phase C: recompute liveness, split into tasks ----------------------
    let live = liveness(&work);
    // Updated parameter lists including threaded closure handles.
    let mut final_params: HashMap<BlockId, Vec<VarId>> = HashMap::new();
    for (pi, &entry) in paths.entries.iter().enumerate() {
        if pi == 0 {
            final_params.insert(entry, func.param_ids().collect());
        } else {
            let mut vars = live.live_in_vars(entry);
            vars.sort();
            // Closure fields must match phase-B hole indices: the original
            // params prefix must be exactly path_params (hole fields were
            // indexed against it). Threaded extras (closure handles) go
            // after.
            let base = &path_params[&entry];
            let extras: Vec<VarId> = vars.iter().copied().filter(|v| !base.contains(v)).collect();
            let mut ordered = base.clone();
            ordered.extend(extras);
            final_params.insert(entry, ordered);
        }
    }

    for (pi, &entry) in paths.entries.iter().enumerate() {
        let new_fid = path_map[&(fid, pi)];
        let task = build_task(
            module,
            &work,
            paths,
            pi,
            entry,
            &final_params,
            fid,
            path_map,
        )?;
        let name = out.funcs[new_fid].name.clone();
        let meta = out.funcs[new_fid].task.clone();
        out.funcs[new_fid] = task;
        out.funcs[new_fid].name = name;
        out.funcs[new_fid].task = meta;
    }
    Ok(())
}

/// Construct one explicit task from a path of the instrumented CFG.
#[allow(clippy::too_many_arguments)]
fn build_task(
    _module: &Module,
    work: &Func,
    paths: &Paths,
    path_index: usize,
    entry: BlockId,
    final_params: &HashMap<BlockId, Vec<VarId>>,
    fid: FuncId,
    path_map: &HashMap<(FuncId, usize), FuncId>,
) -> Result<Func> {
    let work_cfg = work.cfg();
    let params = &final_params[&entry];
    let owned: Vec<BlockId> = paths.blocks_of(path_index, work_cfg);

    // Pre-collect every variable the path touches: params first (fixed
    // order — closure field indices depend on it), then defs/uses in block
    // order.
    let mut vars: IdVec<Var> = IdVec::new();
    let mut var_map: HashMap<VarId, VarId> = HashMap::new();
    for &p in params {
        let mut v = work.vars[p].clone();
        v.is_param = true;
        var_map.insert(p, vars.push(v));
    }
    {
        let mut add = |v: VarId| {
            if !var_map.contains_key(&v) {
                let mut nv = work.vars[v].clone();
                nv.is_param = false;
                var_map.insert(v, vars.push(nv));
            }
        };
        for &b in &owned {
            let src = &work_cfg.blocks[b];
            for op in &src.ops {
                if let Some(d) = op.def() {
                    add(d);
                }
                op.for_each_use(&mut add);
            }
            src.term.for_each_use(&mut add);
            // Tail-spawn args use the target's params.
            for t in src.term.successors() {
                if paths.path_of(t) != path_index {
                    for &p in &final_params[&t] {
                        add(p);
                    }
                }
            }
        }
    }
    let mv = |v: VarId| -> VarId {
        *var_map.get(&v).unwrap_or_else(|| {
            panic!(
                "variable `{}` used but not collected in path (liveness bug)",
                work.vars[v].name
            )
        })
    };

    let mut block_map: HashMap<BlockId, BlockId> = HashMap::new();
    let mut blocks: IdVec<Block> = IdVec::new();
    for &b in &owned {
        block_map.insert(b, blocks.push(Block::default()));
    }

    // Tail-spawn trampolines per target entry, created lazily.
    let mut trampolines: HashMap<BlockId, BlockId> = HashMap::new();
    let mut resolve_target = |t: BlockId, blocks: &mut IdVec<Block>| -> BlockId {
        if paths.path_of(t) == path_index {
            block_map[&t]
        } else {
            *trampolines.entry(t).or_insert_with(|| {
                let callee = path_map[&(fid, paths.path_of(t))];
                let args: Vec<Expr> =
                    final_params[&t].iter().map(|&p| Expr::Var(mv(p))).collect();
                blocks.push(Block {
                    ops: vec![Op::SpawnChild { callee, args, ret: RetTarget::Forward }],
                    term: Term::Halt,
                })
            })
        }
    };

    let mut out_blocks: Vec<(BlockId, Vec<Op>, Term)> = Vec::new();
    for &b in &owned {
        let src = &work_cfg.blocks[b];
        let mut ops: Vec<Op> = src.ops.iter().map(|op| remap_op(op, &mv)).collect();
        let term = match &src.term {
            Term::Sync { .. } => Term::Halt,
            Term::Return(v) => {
                let value = v.as_ref().map(|e| e.map_vars(&mv));
                ops.push(Op::SendArgument { value });
                Term::Halt
            }
            Term::Jump(t) => Term::Jump(resolve_target(*t, &mut blocks)),
            Term::Branch { cond, then_, else_ } => Term::Branch {
                cond: cond.map_vars(&mv),
                then_: resolve_target(*then_, &mut blocks),
                else_: resolve_target(*else_, &mut blocks),
            },
            Term::Halt => Term::Halt,
        };
        out_blocks.push((block_map[&b], ops, term));
    }
    for (nb, ops, term) in out_blocks {
        blocks[nb].ops = ops;
        blocks[nb].term = term;
    }

    Ok(Func {
        name: String::new(), // caller preserves the reserved name
        ret: work.ret,
        params: params.len(),
        vars,
        body: Some(Cfg { blocks, entry: block_map[&entry] }),
        kind: FuncKind::Task,
        task: None, // caller preserves meta
    })
}

fn remap_op(op: &Op, mv: &impl Fn(VarId) -> VarId) -> Op {
    match op {
        Op::Assign { dst, src } => Op::Assign { dst: mv(*dst), src: src.map_vars(mv) },
        Op::Load { dst, arr, index, dae } => {
            Op::Load { dst: mv(*dst), arr: *arr, index: index.map_vars(mv), dae: *dae }
        }
        Op::Store { arr, index, value } => {
            Op::Store { arr: *arr, index: index.map_vars(mv), value: value.map_vars(mv) }
        }
        Op::AtomicAdd { arr, index, value } => {
            Op::AtomicAdd { arr: *arr, index: index.map_vars(mv), value: value.map_vars(mv) }
        }
        Op::Call { dst, callee, args } => Op::Call {
            dst: dst.map(&mv),
            callee: *callee,
            args: args.iter().map(|a| a.map_vars(mv)).collect(),
        },
        Op::Spawn { .. } => {
            unreachable!("bare Spawn must have been converted to SpawnChild")
        }
        Op::MakeClosure { dst, task } => Op::MakeClosure { dst: mv(*dst), task: *task },
        Op::ClosureStore { clos, field, value } => {
            Op::ClosureStore { clos: mv(*clos), field: *field, value: value.map_vars(mv) }
        }
        Op::SpawnChild { callee, args, ret } => Op::SpawnChild {
            callee: *callee,
            args: args.iter().map(|a| a.map_vars(mv)).collect(),
            ret: match ret {
                RetTarget::Slot { clos, field } => {
                    RetTarget::Slot { clos: mv(*clos), field: *field }
                }
                RetTarget::Counter { clos } => RetTarget::Counter { clos: mv(*clos) },
                RetTarget::Forward => RetTarget::Forward,
            },
        },
        Op::CloseSpawns { clos } => Op::CloseSpawns { clos: mv(*clos) },
        Op::SendArgument { value } => {
            Op::SendArgument { value: value.as_ref().map(|e| e.map_vars(mv)) }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_check;
    use crate::ir::print::{print_cilk1, print_module};
    use crate::ir::verify::{verify_module, Stage};
    use crate::lower::ast_to_cfg::lower_program;
    use crate::lower::simplify::simplify_module;

    fn explicitize(src: &str) -> Module {
        let (p, _) = parse_and_check("t", src).unwrap();
        let mut m = lower_program(&p).unwrap();
        simplify_module(&mut m);
        let e = explicitize_module(&m).unwrap();
        let errors = verify_module(&e, Stage::Explicit);
        assert!(errors.is_empty(), "verify: {errors:?}\n{}", print_module(&e));
        e
    }

    const FIB: &str = "int fib(int n) {
        if (n < 2) return n;
        int x = cilk_spawn fib(n - 1);
        int y = cilk_spawn fib(n - 2);
        cilk_sync;
        return x + y;
    }";

    #[test]
    fn fib_becomes_two_tasks() {
        let e = explicitize(FIB);
        let names: Vec<&str> = e.funcs.values().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["fib", "fib__k1"], "{names:?}");
        let fib = &e.funcs[e.func_by_name("fib").unwrap()];
        let cont = &e.funcs[e.func_by_name("fib__k1").unwrap()];
        assert_eq!(cont.params, 2, "continuation takes x, y");
        assert_eq!(cont.task.as_ref().unwrap().role, TaskRole::Continuation);
        assert_eq!(fib.task.as_ref().unwrap().role, TaskRole::Entry);

        // fib: a MakeClosure, two SpawnChild with Slot targets, one Close,
        // one SendArgument (base case).
        let ops: Vec<&Op> = fib.cfg().blocks.values().flat_map(|b| b.ops.iter()).collect();
        assert_eq!(ops.iter().filter(|o| matches!(o, Op::MakeClosure { .. })).count(), 1);
        assert_eq!(
            ops.iter()
                .filter(|o| matches!(o, Op::SpawnChild { ret: RetTarget::Slot { .. }, .. }))
                .count(),
            2
        );
        assert_eq!(ops.iter().filter(|o| matches!(o, Op::CloseSpawns { .. })).count(), 1);
        assert_eq!(ops.iter().filter(|o| matches!(o, Op::SendArgument { .. })).count(), 1);

        // Continuation: just send_argument(k, x + y).
        let cont_ops: Vec<&Op> = cont.cfg().blocks.values().flat_map(|b| b.ops.iter()).collect();
        assert_eq!(cont_ops.len(), 1);
        assert!(matches!(cont_ops[0], Op::SendArgument { value: Some(_) }));
    }

    #[test]
    fn fib_cilk1_rendering_matches_paper_shape() {
        let e = explicitize(FIB);
        let fib = &e.funcs[e.func_by_name("fib").unwrap()];
        let text = print_cilk1(&e, fib);
        assert!(text.contains("task fib (cont int k, int n)"), "{text}");
        assert!(text.contains("spawn_next fib__k1(k, ?x, ?y)"), "{text}");
        assert!(text.contains("send_argument(k, n)"), "{text}");
        let cont = &e.funcs[e.func_by_name("fib__k1").unwrap()];
        let ct = print_cilk1(&e, cont);
        assert!(ct.contains("send_argument(k, x + y)"), "{ct}");
    }

    #[test]
    fn bfs_loop_keeps_single_closure() {
        let e = explicitize(
            "global int adj_off[];
             global int adj_edges[];
             global int visited[];
             void visit(int n) {
                 int off = adj_off[n];
                 int end = adj_off[n + 1];
                 visited[n] = 1;
                 for (int i = off; i < end; i = i + 1) {
                     cilk_spawn visit(adj_edges[i]);
                 }
                 cilk_sync;
             }",
        );
        // The whole spawn loop stays inside the `visit` entry task (the
        // paper's executor PE contains the loop — that is exactly why Vitis
        // cannot pipeline it, §II-C), with ONE closure created at task
        // entry (hoisted out of the loop) and closed at the loop exit.
        let visit = &e.funcs[e.func_by_name("visit").unwrap()];
        let ops: Vec<&Op> = visit.cfg().blocks.values().flat_map(|b| b.ops.iter()).collect();
        assert_eq!(
            ops.iter().filter(|o| matches!(o, Op::MakeClosure { .. })).count(),
            1,
            "{}",
            print_module(&e)
        );
        // The MakeClosure is in the entry block (outside the loop).
        let entry_ops = &visit.cfg().blocks[visit.cfg().entry].ops;
        assert!(
            entry_ops.iter().any(|o| matches!(o, Op::MakeClosure { .. })),
            "closure hoisted to entry block:\n{}",
            print_module(&e)
        );
        // Dynamic joins: the recursive child spawns use Counter targets.
        assert!(ops
            .iter()
            .any(|o| matches!(o, Op::SpawnChild { ret: RetTarget::Counter { .. }, .. })));
        // Continuation task is a trivial completion notifier.
        let cont = &e.funcs[e.func_by_name("visit__k1").unwrap()];
        let cont_ops: Vec<&Op> = cont.cfg().blocks.values().flat_map(|b| b.ops.iter()).collect();
        assert!(matches!(cont_ops.last(), Some(Op::SendArgument { value: None })));
    }

    #[test]
    fn sync_inside_loop_promotes_header_to_join_task() {
        let e = explicitize(
            "global int acc[1];
             void work(int n) { atomic_add(acc, 0, n); }
             void f(int n) {
                for (int i = 0; i < n; i = i + 1) {
                    cilk_spawn work(i);
                    cilk_sync;
                }
             }",
        );
        // The loop header is re-entered from the post-sync continuation →
        // it becomes its own Join task; each iteration creates a fresh
        // closure (per-iteration sync semantics).
        let join = e
            .funcs
            .values()
            .find(|f| f.task.as_ref().map(|t| t.role == TaskRole::Join).unwrap_or(false))
            .unwrap_or_else(|| panic!("join task expected:\n{}", print_module(&e)));
        let join_ops: Vec<&Op> = join.cfg().blocks.values().flat_map(|b| b.ops.iter()).collect();
        assert!(
            join_ops.iter().any(|o| matches!(o, Op::MakeClosure { .. })),
            "per-iteration closure in join task:\n{}",
            print_module(&e)
        );
        // The continuation tail-spawns back to the join task.
        let cont = e
            .funcs
            .values()
            .find(|f| {
                f.task.as_ref().map(|t| t.role == TaskRole::Continuation).unwrap_or(false)
                    && f.task.as_ref().unwrap().source == "f"
            })
            .unwrap();
        let cont_ops: Vec<&Op> = cont.cfg().blocks.values().flat_map(|b| b.ops.iter()).collect();
        assert!(
            cont_ops
                .iter()
                .any(|o| matches!(o, Op::SpawnChild { ret: RetTarget::Forward, .. })),
            "tail re-entry expected:\n{}",
            print_module(&e)
        );
    }

    #[test]
    fn void_spawns_use_counter_target() {
        let e = explicitize(
            "void g(int n) { }
             void f(int n) {
                cilk_spawn g(n);
                cilk_spawn g(n + 1);
                cilk_sync;
             }",
        );
        let f = &e.funcs[e.func_by_name("f").unwrap()];
        let counters = f
            .cfg()
            .blocks
            .values()
            .flat_map(|b| b.ops.iter())
            .filter(|o| matches!(o, Op::SpawnChild { ret: RetTarget::Counter { .. }, .. }))
            .count();
        assert_eq!(counters, 2);
    }

    #[test]
    fn dead_spawn_result_becomes_counter() {
        let e = explicitize(
            "int g(int n) { return n; }
             void f(int n) {
                int x = cilk_spawn g(n);
                cilk_sync;
             }",
        );
        let f = &e.funcs[e.func_by_name("f").unwrap()];
        let ops: Vec<&Op> = f.cfg().blocks.values().flat_map(|b| b.ops.iter()).collect();
        assert!(
            ops.iter()
                .any(|o| matches!(o, Op::SpawnChild { ret: RetTarget::Counter { .. }, .. })),
            "unused spawn result needs no slot: {ops:?}"
        );
    }

    #[test]
    fn sequential_syncs_chain_continuations() {
        let e = explicitize(
            "int g(int n) { return n; }
             int f(int n) {
                int a = cilk_spawn g(n);
                cilk_sync;
                int b = cilk_spawn g(a + 1);
                cilk_sync;
                return b;
             }",
        );
        let names: Vec<&str> = e.funcs.values().map(|f| f.name.as_str()).collect();
        assert!(names.contains(&"f") && names.contains(&"f__k1") && names.contains(&"f__k2"), "{names:?}");
        // k1 spawns g and spawn_nexts k2.
        let k1 = &e.funcs[e.func_by_name("f__k1").unwrap()];
        let ops: Vec<&Op> = k1.cfg().blocks.values().flat_map(|b| b.ops.iter()).collect();
        assert!(ops.iter().any(|o| matches!(o, Op::MakeClosure { .. })));
        assert!(ops.iter().any(|o| matches!(o, Op::SpawnChild { .. })));
    }

    #[test]
    fn leaf_functions_copied_verbatim() {
        let e = explicitize(
            "int helper(int a) { return a * 2; }
             int f(int n) {
                int h = helper(n);
                int x = cilk_spawn f(h);
                cilk_sync;
                return x;
             }",
        );
        let h = &e.funcs[e.func_by_name("helper").unwrap()];
        assert_eq!(h.kind, FuncKind::Leaf);
        assert!(h.cfg().blocks.values().any(|b| matches!(b.term, Term::Return(_))));
    }

    #[test]
    fn xla_decl_becomes_xla_task() {
        let e = explicitize(
            "extern xla int relax(int n);
             int f(int n) {
                int r = cilk_spawn relax(n);
                cilk_sync;
                return r;
             }",
        );
        let relax = &e.funcs[e.func_by_name("relax").unwrap()];
        assert_eq!(relax.kind, FuncKind::Xla);
        assert_eq!(relax.task.as_ref().unwrap().role, TaskRole::Xla);
    }
}
