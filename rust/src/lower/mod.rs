//! Lowering pipeline: AST → implicit IR → (DAE) → explicit Cilk-1 IR.
//!
//! Mirrors paper Fig. 3: the AST from the frontend becomes the implicit IR
//! ([`ast_to_cfg`]); the DAE optimization rewrites annotated memory
//! accesses into access tasks ([`dae`]); explicitization partitions each
//! function into *paths* and emits Cilk-1 tasks ([`explicitize`]).
//!
//! # Pass manager
//!
//! The stages are not hardcoded: they are [`pass::Pass`]es run by a
//! [`pass::PassManager`] (see [`PassManager::standard`] for the Fig. 3
//! order). The manager enforces stage ordering, checks [`verify_module`]
//! invariants before and after every pass, records per-pass wall-clock
//! timings ([`PassTiming`], surfaced on [`CompileResult::timings`] and the
//! `compile_time` bench), and exposes a snapshot hook that can dump the IR
//! after any pass.
//!
//! [`verify_module`]: crate::ir::verify::verify_module
//!
//! # Compile sessions
//!
//! [`CompileSession`] lowers a source **once** and memoizes per-target
//! artifacts, so the emu runtime ([`crate::backend::emu`]), HardCilk
//! codegen ([`crate::backend::hardcilk`]), the cycle simulator
//! ([`crate::sim`]) and the interpreters ([`crate::interp`]) all consume
//! the same cached explicit module instead of each re-running the
//! pipeline. The per-stage modules live behind [`std::sync::Arc`], so
//! snapshots, goldens and backend emission *share* the modules instead of
//! deep-copying them (a pass that mutates takes a copy-on-write handle).
//!
//! ```ignore
//! let mut session = CompileSession::new("fib", FIB_SRC, &CompileOptions::standard())?;
//! let (v, _, _) = session.simulate(session.memory(), "fib", &args, &cfg, &mut NoSimXla)?;
//! let system = session.hardcilk_system("fib_system")?; // cached per name
//! let emu = session.emu_program();                     // compiled once
//! ```
//!
//! # Batch + incremental compilation
//!
//! [`batch::compile_batch`] lowers many sources across a scoped thread
//! pool; [`CompileSession::recompile`] diffs an edited source against
//! per-function AST fingerprints and re-runs the pipeline only for the
//! functions that changed, splicing everything else from the cached stage
//! modules (see [`batch`]).

pub mod analysis;
pub mod ast_to_cfg;
pub mod batch;
pub mod dae;
pub mod explicitize;
pub mod pass;
pub mod simplify;

use std::sync::{Arc, OnceLock};

use anyhow::Result;

use crate::exec::{KernelMode, KernelProgram};
use crate::frontend;
use crate::interp::explicit_exec::ExplicitExec;
use crate::interp::{Memory, NoXla};
use crate::ir::expr::Value;
use crate::ir::Module;
use crate::obs;

pub use batch::{compile_batch, BatchResult};
pub use pass::{
    pass_work, Artifact, KernelCompile, Pass, PassManager, PassReport, PassTiming,
    PipelineStage,
};

/// Options controlling the pipeline. `PartialEq` so callers that share
/// artifacts across sessions (the serve daemon's dedup map) can require
/// option-identical donors.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CompileOptions {
    /// Apply `#pragma bombyx dae` transformations (when false, pragmas are
    /// ignored — the paper's non-DAE baseline).
    pub dae: bool,
    /// Run CFG simplification between stages.
    pub simplify: bool,
}

impl CompileOptions {
    pub fn standard() -> Self {
        CompileOptions { dae: true, simplify: true }
    }

    pub fn no_dae() -> Self {
        CompileOptions { dae: false, simplify: true }
    }
}

/// Stage-by-stage artifacts of one compilation, for `--trace-stages`,
/// goldens and the figure benches. The modules are shared handles into
/// the pipeline's artifacts — cloning a `CompileResult` bumps refcounts,
/// it does not copy IR.
#[derive(Clone, Debug)]
pub struct CompileResult {
    /// The implicit IR before DAE.
    pub implicit: Arc<Module>,
    /// The implicit IR after DAE (the same module as `implicit` when DAE
    /// is off — shared, not copied).
    pub implicit_dae: Arc<Module>,
    /// The explicit (Cilk-1) IR.
    pub explicit: Arc<Module>,
    /// Per-pass wall-clock timings of the pipeline run that produced this
    /// result (skipped passes appear with `ran == false`).
    pub timings: Vec<PassTiming>,
}

/// Full pipeline from source text.
pub fn compile(name: &str, source: &str, opts: &CompileOptions) -> Result<CompileResult> {
    let (program, _src) = frontend::parse_and_check(name, source)?;
    compile_ast(&program, opts)
}

/// Pipeline from a checked AST, via the standard pass manager. The
/// per-stage modules of [`CompileResult`] are captured through the
/// manager's snapshot hook — a refcount bump per kept stage, with the one
/// unavoidable copy happening inside the first pass that mutates a
/// snapshotted module (copy-on-write via `Arc::make_mut`).
pub fn compile_ast(
    program: &frontend::ast::Program,
    opts: &CompileOptions,
) -> Result<CompileResult> {
    let manager = PassManager::standard();
    // Which pass produces each snapshot we keep is decidable up front, so
    // the hook retains exactly the modules that end up in the result.
    let implicit_pass = if opts.simplify { "simplify" } else { "ast_to_cfg" };
    let implicit_dae_pass = match (opts.dae, opts.simplify) {
        (true, true) => "simplify_post_dae",
        (true, false) => "dae",
        (false, _) => "",
    };
    let mut implicit: Option<Arc<Module>> = None;
    let mut implicit_dae: Option<Arc<Module>> = None;
    let (artifact, report) =
        manager.run(Artifact::Ast(program.clone()), opts, |pass, artifact| {
            let Some(module) = artifact.as_module_arc() else { return };
            if pass == implicit_pass {
                implicit = Some(Arc::clone(module));
            } else if pass == implicit_dae_pass {
                implicit_dae = Some(Arc::clone(module));
            }
        })?;
    let explicit = artifact.into_module()?;
    let implicit = implicit.expect("the standard pipeline always lowers the AST");
    let implicit_dae = implicit_dae.unwrap_or_else(|| Arc::clone(&implicit));
    Ok(CompileResult { implicit, implicit_dae, explicit, timings: report.timings })
}

/// How [`CompileSession::recompile`] handled an edited source.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecompileMode {
    /// No function fingerprint changed; the cached result and every
    /// memoized backend artifact remain valid. Zero pass work.
    Unchanged,
    /// Only the dirty functions were re-lowered (function-at-a-time
    /// passes + splice).
    Incremental,
    /// A structural change (signatures, globals, DAE access set, task
    /// layout) forced a full pipeline run.
    Full,
}

/// Report of one [`CompileSession::recompile`] call.
#[derive(Clone, Debug)]
pub struct RecompileOutcome {
    pub mode: RecompileMode,
    /// Names of the re-lowered source functions (empty for `Unchanged`).
    pub dirty: Vec<String>,
    /// Per-pass timings of this recompile, with `funcs` counting only the
    /// functions each pass actually processed.
    pub timings: Vec<PassTiming>,
}

/// How [`CompileSession::new_seeded`] produced its session.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SessionSeed {
    /// No donor (or an unusable one — different options, no fingerprint
    /// state, or a structural mismatch): full cold pipeline.
    Cold,
    /// The donor's compilation was reused wholesale — the new source is
    /// fingerprint-identical, so every stage module is shared by `Arc`
    /// with zero pass work.
    Identical,
    /// Only the named functions were re-lowered; everything else was
    /// spliced from the donor's cached stage modules.
    Spliced { dirty: Vec<String> },
}

/// One compilation, many targets: lowers the source once and hands the
/// cached modules to every backend/runtime (see module docs).
#[derive(Debug)]
pub struct CompileSession {
    name: String,
    options: CompileOptions,
    result: CompileResult,
    emu: Option<crate::backend::emu::EmuProgram>,
    hardcilk: Vec<(String, crate::backend::hardcilk::HardCilkSystem)>,
    rtl: Vec<(String, crate::backend::rtl::RtlSystem)>,
    /// Memoized execution-kernel programs (the bytecode all four
    /// executors run): compiled at most once per module, shared by
    /// `Arc`, invalidated on recompile like every other artifact.
    kernels_explicit: OnceLock<Arc<KernelProgram>>,
    kernels_implicit: OnceLock<Arc<KernelProgram>>,
    /// Per-function fingerprints + cached analyses for incremental
    /// recompilation (`None` for sessions wrapped around a bare
    /// `CompileResult`, which then always recompile fully).
    incr: Option<batch::IncrState>,
}

impl CompileSession {
    /// Parse, check and lower `source` through the standard pass manager.
    pub fn new(name: &str, source: &str, opts: &CompileOptions) -> Result<CompileSession> {
        let _span = obs::Span::enter(format!("compile {name}"), "session");
        obs::metrics::counter_add("compile.sessions", 1);
        let (program, _src) = frontend::parse_and_check(name, source)?;
        let result = compile_ast(&program, opts)?;
        let incr = batch::build_incr_state(&program, &result);
        let mut session = CompileSession::from_result(name, opts.clone(), result);
        session.incr = Some(incr);
        Ok(session)
    }

    /// Like [`CompileSession::new`], but seeded from a *donor* session
    /// compiled with the same options. The donor's per-function
    /// fingerprints decide how much work the new source actually needs:
    /// an identical source shares every stage module by `Arc`
    /// ([`SessionSeed::Identical`]), a near-identical template source
    /// re-lowers only the differing functions and splices the rest
    /// ([`SessionSeed::Spliced`]), and anything structurally different
    /// falls back to a cold pipeline. The donor is never mutated; the
    /// produced modules are byte-for-byte what a cold compile of
    /// `source` yields. This is the dedup primitive behind the serve
    /// daemon's content-fingerprint map.
    pub fn new_seeded(
        name: &str,
        source: &str,
        opts: &CompileOptions,
        donor: Option<&CompileSession>,
    ) -> Result<(CompileSession, SessionSeed)> {
        let _span = obs::Span::enter(format!("compile {name}"), "session");
        obs::metrics::counter_add("compile.sessions", 1);
        let (program, _src) = frontend::parse_and_check(name, source)?;
        if let Some(d) = donor {
            if d.options == *opts {
                if let Some(state) = d.incr.as_ref() {
                    match batch::recompile(&program, opts, &d.result, state)? {
                        batch::Recompiled::Unchanged => {
                            return Ok((d.clone_shared(name), SessionSeed::Identical));
                        }
                        batch::Recompiled::Incremental { result, state, dirty } => {
                            let mut s =
                                CompileSession::from_result(name, opts.clone(), result);
                            s.incr = Some(state);
                            return Ok((s, SessionSeed::Spliced { dirty }));
                        }
                        batch::Recompiled::Full { result, state } => {
                            let mut s =
                                CompileSession::from_result(name, opts.clone(), result);
                            s.incr = Some(state);
                            return Ok((s, SessionSeed::Cold));
                        }
                    }
                }
            }
        }
        let result = compile_ast(&program, opts)?;
        let incr = batch::build_incr_state(&program, &result);
        let mut session = CompileSession::from_result(name, opts.clone(), result);
        session.incr = Some(incr);
        Ok((session, SessionSeed::Cold))
    }

    /// A new session over the *same* compilation: stage modules, kernel
    /// programs and fingerprint state are shared (`Arc` bumps / clones),
    /// per-name backend artifacts start empty. Cheap — no IR is copied.
    pub fn clone_shared(&self, name: &str) -> CompileSession {
        let session = CompileSession {
            name: name.to_string(),
            options: self.options.clone(),
            result: self.result.clone(),
            emu: None,
            hardcilk: Vec::new(),
            rtl: Vec::new(),
            kernels_explicit: OnceLock::new(),
            kernels_implicit: OnceLock::new(),
            incr: self.incr.clone(),
        };
        if let Some(k) = self.kernels_explicit.get() {
            let _ = session.kernels_explicit.set(Arc::clone(k));
        }
        if let Some(k) = self.kernels_implicit.get() {
            let _ = session.kernels_implicit.set(Arc::clone(k));
        }
        session
    }

    /// Wrap an existing compilation (e.g. from [`compile_ast`]).
    pub fn from_result(
        name: &str,
        options: CompileOptions,
        result: CompileResult,
    ) -> CompileSession {
        CompileSession {
            name: name.to_string(),
            options,
            result,
            emu: None,
            hardcilk: Vec::new(),
            rtl: Vec::new(),
            kernels_explicit: OnceLock::new(),
            kernels_implicit: OnceLock::new(),
            incr: None,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn options(&self) -> &CompileOptions {
        &self.options
    }

    pub fn result(&self) -> &CompileResult {
        &self.result
    }

    /// The implicit IR before DAE (what the sequential oracle runs).
    pub fn implicit(&self) -> &Module {
        &self.result.implicit
    }

    pub fn implicit_dae(&self) -> &Module {
        &self.result.implicit_dae
    }

    /// The shared explicit module every target consumes.
    pub fn explicit(&self) -> &Module {
        &self.result.explicit
    }

    /// Per-pass timings of the most recent lowering (initial compile or
    /// last [`CompileSession::recompile`]), plus any timed backend
    /// emission passes appended since.
    pub fn timings(&self) -> &[PassTiming] {
        &self.result.timings
    }

    /// Recompile the session against an edited `source`.
    ///
    /// Every source function is fingerprinted (span-insensitive hash of
    /// its checked AST subtree); only functions whose fingerprint changed
    /// are re-lowered, function-at-a-time, and spliced into the cached
    /// per-stage modules. Structural edits fall back to a full pipeline
    /// run. Memoized backend artifacts (emu / hardcilk / rtl) are
    /// invalidated only when the compilation actually changed — an
    /// untouched source keeps them all.
    ///
    /// The produced modules are byte-for-byte identical to a cold
    /// [`CompileSession::new`] of the edited source (asserted by the
    /// integration tests via printed IR).
    pub fn recompile(&mut self, source: &str) -> Result<RecompileOutcome> {
        let _span = obs::Span::enter(format!("recompile {}", self.name), "session");
        obs::metrics::counter_add("compile.recompiles", 1);
        let (program, _src) = frontend::parse_and_check(&self.name, source)?;
        let Some(state) = self.incr.as_ref() else {
            // No fingerprints to diff against: full run.
            let result = compile_ast(&program, &self.options)?;
            let state = batch::build_incr_state(&program, &result);
            let timings = result.timings.clone();
            let dirty = program.funcs.iter().map(|f| f.name.clone()).collect();
            self.install(result, state);
            return Ok(RecompileOutcome { mode: RecompileMode::Full, dirty, timings });
        };
        match batch::recompile(&program, &self.options, &self.result, state)? {
            batch::Recompiled::Unchanged => {
                let timings: Vec<PassTiming> = PassManager::standard()
                    .pass_names()
                    .into_iter()
                    .map(|pass| PassTiming {
                        pass,
                        duration: std::time::Duration::ZERO,
                        ran: false,
                        funcs: 0,
                    })
                    .collect();
                Ok(RecompileOutcome {
                    mode: RecompileMode::Unchanged,
                    dirty: Vec::new(),
                    timings,
                })
            }
            batch::Recompiled::Incremental { result, state, dirty } => {
                let timings = result.timings.clone();
                self.install(result, state);
                Ok(RecompileOutcome { mode: RecompileMode::Incremental, dirty, timings })
            }
            batch::Recompiled::Full { result, state } => {
                let timings = result.timings.clone();
                let dirty = program.funcs.iter().map(|f| f.name.clone()).collect();
                self.install(result, state);
                Ok(RecompileOutcome { mode: RecompileMode::Full, dirty, timings })
            }
        }
    }

    /// Swap in a new compilation and drop every memoized artifact that
    /// depended on the old explicit module.
    fn install(&mut self, result: CompileResult, state: batch::IncrState) {
        self.result = result;
        self.incr = Some(state);
        self.emu = None;
        self.hardcilk.clear();
        self.rtl.clear();
        self.kernels_explicit = OnceLock::new();
        self.kernels_implicit = OnceLock::new();
    }

    /// Structure fingerprint (globals + extern/function signatures) of
    /// the compiled program — `None` for sessions wrapped around a bare
    /// [`CompileResult`] with no fingerprint state.
    pub fn structure_fp(&self) -> Option<u64> {
        self.incr.as_ref().map(|s| s.structure_fp())
    }

    /// Rough resident-size estimate of this session's IR artifacts, for
    /// byte-budget accounting (the serve LRU). Structural, not measured:
    /// weighted op/block/var/function counts per module, counting each
    /// distinct module once (DAE-off sessions share one `Arc` between
    /// the two implicit stages).
    pub fn approx_bytes(&self) -> usize {
        fn module_bytes(m: &Module) -> usize {
            let mut bytes = 128 * m.funcs.len() + 64 * m.globals.len();
            for (_, f) in m.funcs.iter() {
                bytes += 48 * f.vars.len();
                if let Some(cfg) = &f.body {
                    bytes += 64 * cfg.blocks.len();
                    for (_, b) in cfg.blocks.iter() {
                        bytes += 96 * b.ops.len();
                    }
                }
            }
            bytes
        }
        let r = &self.result;
        let mut total = module_bytes(&r.explicit) + module_bytes(&r.implicit);
        if !Arc::ptr_eq(&r.implicit, &r.implicit_dae) {
            total += module_bytes(&r.implicit_dae);
        }
        total
    }

    /// A fresh memory image over the cached explicit module.
    pub fn memory(&self) -> Memory {
        Memory::new(&self.result.explicit)
    }

    /// A fresh memory image over the implicit module (for the oracle).
    pub fn implicit_memory(&self) -> Memory {
        Memory::new(&self.result.implicit)
    }

    /// A fresh shared (word-atomic) memory image for the WS runtime.
    pub fn shared_memory(&self) -> crate::ws::SharedMemory {
        crate::ws::SharedMemory::new(&self.result.explicit)
    }

    /// The emulation-backend packaging of this compilation, built once.
    /// The packaged program shares the session's explicit module (an
    /// `Arc` handle, not a copy).
    pub fn emu_program(&mut self) -> &crate::backend::emu::EmuProgram {
        if self.emu.is_none() {
            self.emu = Some(crate::backend::emu::package(&self.result));
        }
        self.emu.as_ref().expect("emu program just populated")
    }

    /// The generated HardCilk system, memoized per system name.
    pub fn hardcilk_system(
        &mut self,
        system_name: &str,
    ) -> Result<&crate::backend::hardcilk::HardCilkSystem> {
        if let Some(i) = self.hardcilk.iter().position(|(n, _)| n == system_name) {
            return Ok(&self.hardcilk[i].1);
        }
        let system = crate::backend::hardcilk::generate(&self.result.explicit, system_name)?;
        self.hardcilk.push((system_name.to_string(), system));
        Ok(&self.hardcilk.last().expect("system just pushed").1)
    }

    /// The generated Verilog system, memoized per system name. Emission
    /// runs through a one-pass [`PassManager`] so the `rtl_emit` pass is
    /// timed (appended to [`CompileSession::timings`]) and the produced
    /// system is verified by the structural lint at the pass boundary.
    /// The emission pass *borrows* the session's explicit module (a
    /// shared `Arc` handle — no per-emission module clone), and a second
    /// request for the same name returns the cached system without
    /// re-lowering or re-emitting.
    pub fn rtl_system(
        &mut self,
        system_name: &str,
    ) -> Result<&crate::backend::rtl::RtlSystem> {
        if let Some(i) = self.rtl.iter().position(|(n, _)| n == system_name) {
            return Ok(&self.rtl[i].1);
        }
        let manager = PassManager::new()
            .add(crate::backend::rtl::RtlEmit { system_name: system_name.to_string() });
        let (artifact, report) = manager.run_from(
            Artifact::Module(Arc::clone(&self.result.explicit)),
            PipelineStage::Explicit,
            &self.options,
            |_, _| {},
        )?;
        self.result.timings.extend(report.timings);
        let system = artifact.into_rtl()?;
        self.rtl.push((system_name.to_string(), system));
        Ok(&self.rtl.last().expect("system just pushed").1)
    }

    /// The compiled execution kernels of the explicit module — the
    /// bytecode the explicit machine, WS runtime and simulator all run.
    /// Compiled on first request, then shared (`Arc`) until the next
    /// recompile invalidates it.
    pub fn explicit_kernels(&self) -> Result<Arc<KernelProgram>> {
        crate::exec::memo_kernels(&self.kernels_explicit, || {
            crate::exec::compile_module(&self.result.explicit, KernelMode::Explicit)
        })
    }

    /// The compiled kernels of the (pre-DAE) implicit module — what the
    /// sequential oracle runs.
    pub fn implicit_kernels(&self) -> Result<Arc<KernelProgram>> {
        crate::exec::memo_kernels(&self.kernels_implicit, || {
            crate::exec::compile_module(&self.result.implicit, KernelMode::Implicit)
        })
    }

    /// [`CompileSession::explicit_kernels`] through a one-pass
    /// [`PassManager`] run, so `kernel_compile` is timed (appended to
    /// [`CompileSession::timings`]) and verified by the bytecode
    /// validator at the pass boundary — the same pattern as
    /// [`CompileSession::rtl_system`]. A second call returns the cached
    /// program with zero pass work.
    pub fn kernels_timed(&mut self) -> Result<Arc<KernelProgram>> {
        if let Some(k) = self.kernels_explicit.get() {
            return Ok(Arc::clone(k));
        }
        let manager =
            PassManager::new().add(pass::KernelCompile { mode: KernelMode::Explicit });
        let (artifact, report) = manager.run_from(
            Artifact::Module(Arc::clone(&self.result.explicit)),
            PipelineStage::Explicit,
            &self.options,
            |_, _| {},
        )?;
        self.result.timings.extend(report.timings);
        let k = artifact.into_kernels()?;
        Ok(Arc::clone(self.kernels_explicit.get_or_init(|| k)))
    }

    /// Sequential oracle over the cached implicit module (and its cached
    /// kernel program).
    pub fn run_oracle(
        &self,
        memory: Memory,
        entry: &str,
        args: &[Value],
    ) -> Result<(Value, Memory)> {
        let kernels = self.implicit_kernels()?;
        let mut o = crate::interp::oracle::Oracle::with_kernels(
            &self.result.implicit,
            memory,
            NoXla,
            kernels,
        );
        let v = o.run(entry, args)?;
        Ok((v, o.memory))
    }

    /// Single-threaded explicit-IR machine over the cached explicit
    /// module (and its cached kernel program).
    pub fn run_explicit(
        &self,
        memory: Memory,
        entry: &str,
        args: &[Value],
    ) -> Result<(Value, Memory)> {
        let kernels = self.explicit_kernels()?;
        let mut exec =
            ExplicitExec::with_kernels(&self.result.explicit, memory, NoXla, kernels);
        let value = exec.run(entry, args)?;
        Ok((value, exec.memory))
    }

    /// Cycle simulation over the cached explicit module (and its cached
    /// kernel program).
    pub fn simulate(
        &self,
        memory: Memory,
        entry: &str,
        args: &[Value],
        config: &crate::sim::SimConfig,
        xla: &mut dyn crate::sim::SimXla,
    ) -> Result<(Value, Memory, crate::sim::SimStats)> {
        let kernels = self.explicit_kernels()?;
        crate::sim::simulate_with_kernels(
            &self.result.explicit,
            kernels,
            memory,
            entry,
            args,
            config,
            xla,
        )
    }

    /// Multithreaded WS run over the cached explicit module (and its
    /// cached kernel program).
    pub fn run_ws(
        &self,
        memory: crate::ws::SharedMemory,
        entry: &str,
        args: &[Value],
        config: &crate::ws::WsConfig,
        sink: Box<dyn crate::ws::XlaSink>,
    ) -> Result<(Value, crate::ws::SharedMemory, crate::ws::WsStats)> {
        let kernels = self.explicit_kernels()?;
        crate::ws::run_with_kernels(kernels, memory, entry, args, config, sink)
    }

    /// Package a resident-executor job over this session's cached kernel
    /// program and a fresh shared-memory image. Callers seed globals
    /// through the returned job's `memory` field (and may swap
    /// `xla_sink`) before [`crate::ws::Executor::submit`]ting it.
    pub fn ws_job(&self, entry: &str, args: &[Value]) -> Result<crate::ws::Job> {
        Ok(crate::ws::Job::new(
            self.explicit_kernels()?,
            self.shared_memory(),
            entry,
            args,
        ))
    }

    /// Per-kernel native-tier (JIT) statistics for this session's cached
    /// explicit kernel program: dispatch/entry/bail counts, compile time
    /// and code size per kernel. Empty when no tier has been created for
    /// the program (JIT disabled or unavailable) or the kernels haven't
    /// been compiled yet.
    pub fn jit_stats(&self) -> Vec<crate::exec::jit::JitKernelStats> {
        match self.kernels_explicit.get() {
            Some(k) => crate::exec::jit::stats_for(k),
            None => Vec::new(),
        }
    }
}
