//! Lowering pipeline: AST → implicit IR → (DAE) → explicit IR.
//!
//! Mirrors paper Fig. 3: the AST from the frontend becomes the implicit IR
//! ([`ast_to_cfg`]); the DAE optimization rewrites annotated memory accesses
//! into access tasks ([`dae`]); explicitization partitions each function
//! into *paths* and emits Cilk-1 tasks ([`explicitize`]).

pub mod analysis;
pub mod ast_to_cfg;
pub mod dae;
pub mod explicitize;
pub mod simplify;

use anyhow::{bail, Result};

use crate::frontend;
use crate::ir::verify::{verify_module, Stage};
use crate::ir::Module;

/// Options controlling the pipeline.
#[derive(Clone, Debug, Default)]
pub struct CompileOptions {
    /// Apply `#pragma bombyx dae` transformations (when false, pragmas are
    /// ignored — the paper's non-DAE baseline).
    pub dae: bool,
    /// Run CFG simplification between stages.
    pub simplify: bool,
}

impl CompileOptions {
    pub fn standard() -> Self {
        CompileOptions { dae: true, simplify: true }
    }

    pub fn no_dae() -> Self {
        CompileOptions { dae: false, simplify: true }
    }
}

/// Stage-by-stage artifacts of one compilation, for `--trace-stages`,
/// goldens and the figure benches.
#[derive(Clone, Debug)]
pub struct CompileResult {
    /// The implicit IR before DAE.
    pub implicit: Module,
    /// The implicit IR after DAE (equal to `implicit` when DAE is off or no
    /// pragmas are present).
    pub implicit_dae: Module,
    /// The explicit (Cilk-1) IR.
    pub explicit: Module,
}

/// Full pipeline from source text.
pub fn compile(name: &str, source: &str, opts: &CompileOptions) -> Result<CompileResult> {
    let (program, _src) = frontend::parse_and_check(name, source)?;
    compile_ast(&program, opts)
}

/// Pipeline from a checked AST.
pub fn compile_ast(
    program: &frontend::ast::Program,
    opts: &CompileOptions,
) -> Result<CompileResult> {
    let mut implicit = ast_to_cfg::lower_program(program)?;
    if opts.simplify {
        simplify::simplify_module(&mut implicit);
    }
    let errors = verify_module(&implicit, Stage::Implicit);
    if !errors.is_empty() {
        bail!("implicit IR verification failed:\n  {}", errors.join("\n  "));
    }

    let mut implicit_dae = implicit.clone();
    if opts.dae {
        dae::apply_dae(&mut implicit_dae)?;
        if opts.simplify {
            simplify::simplify_module(&mut implicit_dae);
        }
        let errors = verify_module(&implicit_dae, Stage::Implicit);
        if !errors.is_empty() {
            bail!("post-DAE IR verification failed:\n  {}", errors.join("\n  "));
        }
    }

    let explicit = explicitize::explicitize_module(&implicit_dae)?;
    let errors = verify_module(&explicit, Stage::Explicit);
    if !errors.is_empty() {
        bail!("explicit IR verification failed:\n  {}", errors.join("\n  "));
    }
    Ok(CompileResult { implicit, implicit_dae, explicit })
}
