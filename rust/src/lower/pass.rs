//! The lowering pass manager.
//!
//! The paper's Fig. 3 pipeline (AST → implicit IR → DAE → explicit Cilk-1
//! IR) is expressed as a sequence of named [`Pass`]es over an [`Artifact`]
//! (the AST, then the module at a known [`PipelineStage`]). The
//! [`PassManager`]:
//!
//! - **enforces ordering**: each pass declares the stage it consumes and
//!   the stage it produces; feeding a pass the wrong stage (e.g.
//!   explicitize on an un-lowered AST) is an error, not a crash later;
//! - **verifies invariants between passes**: before and after every
//!   executed pass the module is checked with [`verify_module`] against the
//!   declared stage, so a pass that corrupts the CFG is caught at the pass
//!   boundary with its name in the error;
//! - **times every pass**: the returned [`PassReport`] carries wall-clock
//!   durations and processed-function counts per pass (rendered by
//!   `util::bench::timing_table`, consumed by the `compile_time` bench and
//!   `bombyx compile --timings`);
//! - **snapshots**: a hook is invoked after every executed pass with the
//!   pass name and the produced artifact, which is how `CompileResult`
//!   captures its per-stage modules and how `--trace-stages`-style dumps
//!   are implemented without hardcoding the stage list.
//!
//! # Sharing and copy-on-write
//!
//! Modules flow through the pipeline behind [`Arc`]: a pass that only
//! reads (explicitize, rtl emission) never copies its input, and a pass
//! that mutates calls [`Arc::make_mut`] — free while the pipeline holds
//! the only reference, one copy when a snapshot keeps the previous stage
//! alive. This is what makes per-stage snapshots, golden captures and
//! repeated backend emission clone-free.
//!
//! # Function-at-a-time execution
//!
//! Every standard lowering pass also implements
//! [`Pass::run_on_function`], which re-runs the pass for a single
//! function and splices the result into the module in place. The
//! incremental recompilation driver ([`super::CompileSession::recompile`])
//! uses [`PassManager::run_on_functions`] to re-lower only the functions
//! whose AST actually changed.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::frontend::ast::Program;
use crate::obs;
use crate::ir::verify::{verify_module, Stage};
use crate::ir::{FuncId, Module};

use super::{ast_to_cfg, dae, explicitize, simplify, CompileOptions};

/// Stage of the artifact flowing through the pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineStage {
    /// Checked AST, not yet lowered.
    Ast,
    /// Implicit IR (CFG with `sync` terminators, paper Fig. 4(b)).
    Implicit,
    /// Explicit Cilk-1 IR (terminating tasks, paper Fig. 4(c)).
    Explicit,
    /// Emitted Verilog system ([`crate::backend::rtl`]).
    Rtl,
    /// Compiled execution kernels ([`crate::exec`]).
    Kernels,
}

impl PipelineStage {
    pub fn name(self) -> &'static str {
        match self {
            PipelineStage::Ast => "ast",
            PipelineStage::Implicit => "implicit IR",
            PipelineStage::Explicit => "explicit IR",
            PipelineStage::Rtl => "rtl",
            PipelineStage::Kernels => "kernels",
        }
    }

    /// The `ir::verify` stage used for inter-pass checks (`None` for AST,
    /// which has no module-level verifier; the `rtl` and `kernels` stages
    /// are verified by their own structural validators instead).
    pub fn verify_stage(self) -> Option<Stage> {
        match self {
            PipelineStage::Ast | PipelineStage::Rtl | PipelineStage::Kernels => None,
            PipelineStage::Implicit => Some(Stage::Implicit),
            PipelineStage::Explicit => Some(Stage::Explicit),
        }
    }
}

/// The value a pass consumes and produces. Modules are reference-counted
/// so snapshots and backend emission share instead of deep-copying; a
/// mutating pass takes a unique handle via [`Arc::make_mut`].
#[derive(Clone, Debug)]
pub enum Artifact {
    Ast(Program),
    Module(Arc<Module>),
    Rtl(crate::backend::rtl::RtlSystem),
    Kernels(Arc<crate::exec::KernelProgram>),
}

impl Artifact {
    pub fn as_module(&self) -> Option<&Module> {
        match self {
            Artifact::Module(m) => Some(m),
            Artifact::Ast(_) | Artifact::Rtl(_) | Artifact::Kernels(_) => None,
        }
    }

    /// The shared handle to the module, if this artifact is one (what
    /// snapshot hooks clone — a refcount bump, not a module copy).
    pub fn as_module_arc(&self) -> Option<&Arc<Module>> {
        match self {
            Artifact::Module(m) => Some(m),
            Artifact::Ast(_) | Artifact::Rtl(_) | Artifact::Kernels(_) => None,
        }
    }

    pub fn into_module(self) -> Result<Arc<Module>> {
        match self {
            Artifact::Module(m) => Ok(m),
            Artifact::Ast(_) => bail!("pipeline ended before AST lowering produced a module"),
            Artifact::Rtl(_) => bail!("pipeline ended at the rtl stage, not a module"),
            Artifact::Kernels(_) => bail!("pipeline ended at the kernels stage, not a module"),
        }
    }

    pub fn into_rtl(self) -> Result<crate::backend::rtl::RtlSystem> {
        match self {
            Artifact::Rtl(system) => Ok(system),
            Artifact::Ast(_) | Artifact::Module(_) | Artifact::Kernels(_) => {
                bail!("pipeline did not end with an rtl emission pass")
            }
        }
    }

    pub fn into_kernels(self) -> Result<Arc<crate::exec::KernelProgram>> {
        match self {
            Artifact::Kernels(k) => Ok(k),
            Artifact::Ast(_) | Artifact::Module(_) | Artifact::Rtl(_) => {
                bail!("pipeline did not end with a kernel compilation pass")
            }
        }
    }
}

fn require_module(pass: &str, artifact: Artifact) -> Result<Arc<Module>> {
    match artifact {
        Artifact::Module(m) => Ok(m),
        Artifact::Ast(_) => {
            bail!("pass `{pass}` requires lowered (implicit IR) input, got an unlowered AST")
        }
        Artifact::Rtl(_) => {
            bail!("pass `{pass}` requires an IR module, got an emitted rtl system")
        }
        Artifact::Kernels(_) => {
            bail!("pass `{pass}` requires an IR module, got compiled kernels")
        }
    }
}

/// Context handed to function-at-a-time pass execution: the checked
/// program (consumed by the AST-level pass) and the module being rebuilt
/// in place.
pub struct FuncCtx<'a> {
    pub program: &'a Program,
    pub module: &'a mut Module,
}

/// One named stage of the lowering pipeline.
pub trait Pass {
    fn name(&self) -> &'static str;
    /// Stage this pass consumes; checked by the manager before `run`.
    fn input_stage(&self) -> PipelineStage;
    /// Stage this pass produces; verified by the manager after `run`.
    fn output_stage(&self) -> PipelineStage;
    /// Disabled passes are skipped (recorded in the report with
    /// `ran == false`); only stage-preserving passes may be disabled.
    fn enabled(&self, _opts: &CompileOptions) -> bool {
        true
    }
    fn run(&self, artifact: Artifact, opts: &CompileOptions) -> Result<Artifact>;

    /// Function-at-a-time execution (incremental recompilation): re-run
    /// this pass for `func` only, splicing the result into `ctx.module`
    /// in place and leaving every other function untouched. Passes whose
    /// output cannot be spliced per function decline.
    fn run_on_function(
        &self,
        _ctx: &mut FuncCtx<'_>,
        _func: FuncId,
        _opts: &CompileOptions,
    ) -> Result<()> {
        bail!(
            "pass `{}` does not support function-at-a-time execution",
            self.name()
        )
    }
}

/// AST → implicit IR (`lower::ast_to_cfg`).
pub struct AstToCfg;

impl Pass for AstToCfg {
    fn name(&self) -> &'static str {
        "ast_to_cfg"
    }

    fn input_stage(&self) -> PipelineStage {
        PipelineStage::Ast
    }

    fn output_stage(&self) -> PipelineStage {
        PipelineStage::Implicit
    }

    fn run(&self, artifact: Artifact, _opts: &CompileOptions) -> Result<Artifact> {
        match artifact {
            Artifact::Ast(program) => {
                Ok(Artifact::Module(Arc::new(ast_to_cfg::lower_program(&program)?)))
            }
            Artifact::Module(_) => {
                bail!("pass `ast_to_cfg` expects an AST input, got an already-lowered module")
            }
            Artifact::Rtl(_) => {
                bail!("pass `ast_to_cfg` expects an AST input, got an emitted rtl system")
            }
            Artifact::Kernels(_) => {
                bail!("pass `ast_to_cfg` expects an AST input, got compiled kernels")
            }
        }
    }

    fn run_on_function(
        &self,
        ctx: &mut FuncCtx<'_>,
        func: FuncId,
        _opts: &CompileOptions,
    ) -> Result<()> {
        let name = ctx.module.funcs[func].name.clone();
        let Some(def) = ctx.program.funcs.iter().find(|f| f.name == name) else {
            bail!("incremental ast_to_cfg: no AST definition for function `{name}`");
        };
        ast_to_cfg::relower_function(ctx.module, def, func)
    }
}

/// CFG cleanup (`lower::simplify`). Appears twice in the standard pipeline
/// under distinct names; the post-DAE instance only runs when DAE ran.
pub struct Simplify {
    pub name: &'static str,
    pub requires_dae: bool,
}

impl Pass for Simplify {
    fn name(&self) -> &'static str {
        self.name
    }

    fn input_stage(&self) -> PipelineStage {
        PipelineStage::Implicit
    }

    fn output_stage(&self) -> PipelineStage {
        PipelineStage::Implicit
    }

    fn enabled(&self, opts: &CompileOptions) -> bool {
        opts.simplify && (!self.requires_dae || opts.dae)
    }

    fn run(&self, artifact: Artifact, _opts: &CompileOptions) -> Result<Artifact> {
        let mut module = require_module(self.name, artifact)?;
        // Copy-on-write discipline: when a snapshot shares the module and
        // every CFG is already at the simplify fixpoint (the common
        // `simplify_post_dae` case for pragma-free sources, where the DAE
        // pass changed nothing), running would be a no-op — skip the deep
        // copy entirely. When the handle is unique, `make_mut` is free.
        if Arc::get_mut(&mut module).is_none() && simplify::module_at_fixpoint(&module) {
            return Ok(Artifact::Module(module));
        }
        simplify::simplify_module(Arc::make_mut(&mut module));
        Ok(Artifact::Module(module))
    }

    fn run_on_function(
        &self,
        ctx: &mut FuncCtx<'_>,
        func: FuncId,
        _opts: &CompileOptions,
    ) -> Result<()> {
        if let Some(cfg) = ctx.module.funcs[func].body.as_mut() {
            simplify::simplify_cfg(cfg);
        }
        Ok(())
    }
}

/// Decoupled access–execute rewrite (`lower::dae`).
pub struct Dae;

impl Pass for Dae {
    fn name(&self) -> &'static str {
        "dae"
    }

    fn input_stage(&self) -> PipelineStage {
        PipelineStage::Implicit
    }

    fn output_stage(&self) -> PipelineStage {
        PipelineStage::Implicit
    }

    fn enabled(&self, opts: &CompileOptions) -> bool {
        opts.dae
    }

    fn run(&self, artifact: Artifact, _opts: &CompileOptions) -> Result<Artifact> {
        let mut module = require_module("dae", artifact)?;
        // A module with no annotated loads is returned untouched: gating
        // the copy-on-write handle on the scan keeps the no-pragma path
        // (and the snapshot taken just before this pass) clone-free.
        if dae::module_has_dae_loads(&module) {
            dae::apply_dae(Arc::make_mut(&mut module))?;
        }
        Ok(Artifact::Module(module))
    }

    fn run_on_function(
        &self,
        ctx: &mut FuncCtx<'_>,
        func: FuncId,
        _opts: &CompileOptions,
    ) -> Result<()> {
        dae::apply_dae_func(ctx.module, func)?;
        Ok(())
    }
}

/// Implicit → explicit conversion (`lower::explicitize`).
pub struct Explicitize;

impl Pass for Explicitize {
    fn name(&self) -> &'static str {
        "explicitize"
    }

    fn input_stage(&self) -> PipelineStage {
        PipelineStage::Implicit
    }

    fn output_stage(&self) -> PipelineStage {
        PipelineStage::Explicit
    }

    fn run(&self, artifact: Artifact, _opts: &CompileOptions) -> Result<Artifact> {
        let module = require_module("explicitize", artifact)?;
        Ok(Artifact::Module(Arc::new(explicitize::explicitize_module(&module)?)))
    }
}

/// Explicit/implicit IR → execution-kernel bytecode
/// (`exec::compile_module`). Post-verification is the kernel program's
/// structural validator, run like the RTL lint at the pass boundary.
pub struct KernelCompile {
    pub mode: crate::exec::KernelMode,
}

impl Pass for KernelCompile {
    fn name(&self) -> &'static str {
        match self.mode {
            crate::exec::KernelMode::Implicit => "kernel_compile_implicit",
            crate::exec::KernelMode::Explicit => "kernel_compile",
        }
    }

    fn input_stage(&self) -> PipelineStage {
        match self.mode {
            crate::exec::KernelMode::Implicit => PipelineStage::Implicit,
            crate::exec::KernelMode::Explicit => PipelineStage::Explicit,
        }
    }

    fn output_stage(&self) -> PipelineStage {
        PipelineStage::Kernels
    }

    fn run(&self, artifact: Artifact, _opts: &CompileOptions) -> Result<Artifact> {
        let module = require_module(self.name(), artifact)?;
        // Unvalidated entry point: the manager's post-verification runs
        // `KernelProgram::validate` at the pass boundary, so validating
        // here too would walk every instruction twice.
        Ok(Artifact::Kernels(Arc::new(crate::exec::compile::compile_module_unvalidated(
            &module, self.mode,
        )?)))
    }
}

/// Wall-clock record of one pipeline pass.
#[derive(Clone, Debug)]
pub struct PassTiming {
    pub pass: &'static str,
    pub duration: Duration,
    /// False when the pass was disabled by the compile options.
    pub ran: bool,
    /// Number of input functions the pass consumed (the whole module for
    /// a full run, only the dirty set for an incremental one, 0 when
    /// skipped) — always measured on the pass *input*, so full and
    /// incremental runs report in comparable units. `Σ funcs` over
    /// executed passes is the "pass work" figure the compile-time bench
    /// tracks.
    pub funcs: usize,
}

/// What one `PassManager::run` did.
#[derive(Clone, Debug, Default)]
pub struct PassReport {
    pub timings: Vec<PassTiming>,
}

impl PassReport {
    /// Total time spent in executed passes.
    pub fn total(&self) -> Duration {
        self.timings.iter().map(|t| t.duration).sum()
    }

    /// Total function-pass executions ("pass work"): the per-function
    /// cost model the incremental-recompile acceptance bar is measured
    /// against.
    pub fn work(&self) -> usize {
        pass_work(&self.timings)
    }
}

/// Sum of function-pass executions over a timing slice (see
/// [`PassReport::work`]).
pub fn pass_work(timings: &[PassTiming]) -> usize {
    timings.iter().filter(|t| t.ran).map(|t| t.funcs).sum()
}

/// Ordered, verified, instrumented pipeline of lowering passes.
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
    verify: bool,
}

impl Default for PassManager {
    fn default() -> Self {
        PassManager::new()
    }
}

impl PassManager {
    pub fn new() -> PassManager {
        PassManager { passes: Vec::new(), verify: true }
    }

    /// Append a pass (builder style).
    pub fn add(mut self, pass: impl Pass + 'static) -> PassManager {
        self.passes.push(Box::new(pass));
        self
    }

    /// Disable the inter-pass `verify_module` checks (bench-only escape
    /// hatch; the standard pipeline keeps them on).
    pub fn without_verify(mut self) -> PassManager {
        self.verify = false;
        self
    }

    /// The standard Fig. 3 pipeline:
    /// `ast_to_cfg → simplify → dae → simplify_post_dae → explicitize`.
    pub fn standard() -> PassManager {
        PassManager::new()
            .add(AstToCfg)
            .add(Simplify { name: "simplify", requires_dae: false })
            .add(Dae)
            .add(Simplify { name: "simplify_post_dae", requires_dae: true })
            .add(Explicitize)
    }

    /// The function-at-a-time prefix of the standard pipeline
    /// (`ast_to_cfg → simplify`): what re-lowers a dirty function into
    /// the cached pre-DAE implicit module.
    pub fn incremental_frontend() -> PassManager {
        PassManager::new()
            .add(AstToCfg)
            .add(Simplify { name: "simplify", requires_dae: false })
    }

    /// The function-at-a-time DAE segment of the standard pipeline
    /// (`dae → simplify_post_dae`): what rewrites a dirty function inside
    /// the cached post-DAE implicit module.
    pub fn incremental_dae() -> PassManager {
        PassManager::new()
            .add(Dae)
            .add(Simplify { name: "simplify_post_dae", requires_dae: true })
    }

    /// Names of the registered passes, in order.
    pub fn pass_names(&self) -> Vec<&'static str> {
        self.passes.iter().map(|p| p.name()).collect()
    }

    /// Run the pipeline over `artifact`. `snapshot` is invoked after every
    /// *executed* pass with the pass name and the artifact it produced —
    /// this is the hook `CompileResult` capture and IR dumps are built on.
    ///
    /// The initial stage is inferred: an AST is [`PipelineStage::Ast`], a
    /// module is assumed implicit. Use [`PassManager::run_from`] to feed an
    /// explicit-IR module to a pipeline of explicit-stage passes.
    pub fn run(
        &self,
        artifact: Artifact,
        opts: &CompileOptions,
        snapshot: impl FnMut(&'static str, &Artifact),
    ) -> Result<(Artifact, PassReport)> {
        let stage = match &artifact {
            Artifact::Ast(_) => PipelineStage::Ast,
            Artifact::Module(_) => PipelineStage::Implicit,
            Artifact::Rtl(_) => PipelineStage::Rtl,
            Artifact::Kernels(_) => PipelineStage::Kernels,
        };
        self.run_from(artifact, stage, opts, snapshot)
    }

    /// [`PassManager::run`] with an explicitly declared input stage.
    pub fn run_from(
        &self,
        mut artifact: Artifact,
        mut stage: PipelineStage,
        opts: &CompileOptions,
        mut snapshot: impl FnMut(&'static str, &Artifact),
    ) -> Result<(Artifact, PassReport)> {
        let mut report = PassReport::default();
        // Verification of the artifact entering each pass: the caller's
        // input is checked once up front; after that, each executed pass's
        // post-check doubles as the next pass's pre-check (nothing mutates
        // the artifact between passes).
        let mut verified = false;
        for pass in &self.passes {
            if pass.input_stage() != stage {
                bail!(
                    "pass ordering violation: `{}` expects {} input but the pipeline is at {} \
                     (did you skip a lowering stage?)",
                    pass.name(),
                    pass.input_stage().name(),
                    stage.name()
                );
            }
            if !pass.enabled(opts) {
                if pass.output_stage() != pass.input_stage() {
                    bail!(
                        "pass `{}` cannot be disabled: it advances the pipeline stage",
                        pass.name()
                    );
                }
                report.timings.push(PassTiming {
                    pass: pass.name(),
                    duration: Duration::ZERO,
                    ran: false,
                    funcs: 0,
                });
                continue;
            }
            if self.verify && !verified {
                verify_artifact(pass.name(), "pre", &artifact, stage)?;
            }
            // Function count is measured on the pass *input* — the work
            // the pass consumed — so full and incremental runs report in
            // the same units (source functions processed).
            let funcs = match &artifact {
                Artifact::Ast(p) => p.funcs.len() + p.externs.len(),
                Artifact::Module(m) => m.funcs.len(),
                Artifact::Rtl(_) | Artifact::Kernels(_) => 0,
            };
            // The pass span is the timing: `PassTiming.duration` is read
            // back from the same `obs::Span` that emits the trace events,
            // so `--timings` tables and Perfetto pass tracks agree.
            let span = obs::Span::enter(pass.name(), "pass");
            artifact = pass.run(artifact, opts)?;
            let duration = span.finish();
            obs::metrics::counter_add("compile.passes_run", 1);
            obs::metrics::observe_ms(&format!("compile.pass.{}_ms", pass.name()), duration);
            stage = pass.output_stage();
            if self.verify {
                verify_artifact(pass.name(), "post", &artifact, stage)?;
                verified = true;
            }
            report.timings.push(PassTiming { pass: pass.name(), duration, ran: true, funcs });
            snapshot(pass.name(), &artifact);
        }
        Ok((artifact, report))
    }

    /// Function-at-a-time execution: re-run every registered pass for only
    /// the functions in `funcs`, splicing results into `ctx.module` in
    /// place (see [`Pass::run_on_function`]). The module is verified once
    /// against `stage` after all passes ran — per-pass whole-module
    /// verification would cost more than the skipped functions save.
    pub fn run_on_functions(
        &self,
        ctx: &mut FuncCtx<'_>,
        funcs: &[FuncId],
        stage: PipelineStage,
        opts: &CompileOptions,
    ) -> Result<PassReport> {
        let mut report = PassReport::default();
        for pass in &self.passes {
            if !pass.enabled(opts) {
                report.timings.push(PassTiming {
                    pass: pass.name(),
                    duration: Duration::ZERO,
                    ran: false,
                    funcs: 0,
                });
                continue;
            }
            let span = obs::Span::enter(pass.name(), "pass");
            for &f in funcs {
                pass.run_on_function(ctx, f, opts)?;
            }
            let duration = span.finish();
            obs::metrics::counter_add("compile.passes_run", 1);
            obs::metrics::observe_ms(&format!("compile.pass.{}_ms", pass.name()), duration);
            report.timings.push(PassTiming {
                pass: pass.name(),
                duration,
                ran: true,
                funcs: funcs.len(),
            });
        }
        if self.verify {
            if let Some(vstage) = stage.verify_stage() {
                let errors = verify_module(ctx.module, vstage);
                if !errors.is_empty() {
                    bail!(
                        "function-at-a-time splice broke the {} invariants:\n  {}",
                        stage.name(),
                        errors.join("\n  ")
                    );
                }
            }
        }
        Ok(report)
    }
}

fn verify_artifact(
    pass: &str,
    when: &str,
    artifact: &Artifact,
    stage: PipelineStage,
) -> Result<()> {
    // The rtl stage has no IR verifier; its invariant check is the
    // structural Verilog lint.
    if let Artifact::Rtl(system) = artifact {
        let errors = system.lint();
        if !errors.is_empty() {
            bail!(
                "pass `{pass}`: {when}-verification (structural Verilog lint) failed:\n  {}",
                errors.join("\n  ")
            );
        }
        return Ok(());
    }
    // Likewise the kernels stage: its invariant check is the bytecode
    // validator (slot/target/cost ranges, mode-legal ops).
    if let Artifact::Kernels(prog) = artifact {
        let errors = prog.validate();
        if !errors.is_empty() {
            bail!(
                "pass `{pass}`: {when}-verification (kernel bytecode validator) failed:\n  {}",
                errors.join("\n  ")
            );
        }
        return Ok(());
    }
    let (Some(module), Some(vstage)) = (artifact.as_module(), stage.verify_stage()) else {
        return Ok(());
    };
    let errors = verify_module(module, vstage);
    if !errors.is_empty() {
        bail!(
            "pass `{pass}`: {when}-verification against the {} invariants failed:\n  {}",
            stage.name(),
            errors.join("\n  ")
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_check;

    const FIB: &str = "int fib(int n) {
        if (n < 2) return n;
        int x = cilk_spawn fib(n - 1);
        int y = cilk_spawn fib(n - 2);
        cilk_sync;
        return x + y;
    }";

    fn fib_ast() -> Program {
        parse_and_check("t", FIB).unwrap().0
    }

    // (Ordering enforcement, skip reporting and corruption detection are
    // covered by rust/tests/pass_manager_tests.rs; the tests here exercise
    // only what the integration suite cannot see from outside.)

    #[test]
    fn snapshot_hook_sees_each_executed_pass() {
        let pm = PassManager::standard();
        let opts = CompileOptions::standard();
        let mut seen = Vec::new();
        pm.run(Artifact::Ast(fib_ast()), &opts, |pass, artifact| {
            seen.push((pass, artifact.as_module().is_some()));
        })
        .unwrap();
        assert_eq!(seen.len(), 5);
        assert!(seen.iter().all(|(_, is_module)| *is_module));
    }

    #[test]
    fn run_from_accepts_an_explicit_stage_module() {
        // An explicit-IR module fed to an empty manager round-trips; the
        // inferred-stage entry point would have misclassified it.
        let pm = PassManager::standard();
        let opts = CompileOptions::no_dae();
        let (artifact, _) = pm.run(Artifact::Ast(fib_ast()), &opts, |_, _| {}).unwrap();
        let module = artifact.into_module().unwrap();
        let empty = PassManager::new();
        let (out, report) = empty
            .run_from(Artifact::Module(module), PipelineStage::Explicit, &opts, |_, _| {})
            .unwrap();
        assert!(matches!(out, Artifact::Module(_)));
        assert!(report.timings.is_empty());
    }

    #[test]
    fn read_only_passes_share_the_module() {
        // The module entering explicitize must come out of the snapshot
        // hook as the same allocation the pipeline continues with: the
        // clone-free invariant of the Arc'd artifact design.
        let pm = PassManager::standard();
        let opts = CompileOptions::no_dae();
        let mut last_implicit: Option<Arc<Module>> = None;
        pm.run(Artifact::Ast(fib_ast()), &opts, |pass, artifact| {
            if pass == "simplify" {
                last_implicit = artifact.as_module_arc().cloned();
            }
        })
        .unwrap();
        // The snapshot holds a live reference even after the pipeline has
        // moved on: it was shared, not copied.
        let snap = last_implicit.expect("simplify snapshot captured");
        assert!(snap.funcs.len() >= 1);
    }

    #[test]
    fn timings_carry_function_counts() {
        let pm = PassManager::standard();
        let opts = CompileOptions::standard();
        let (_, report) = pm.run(Artifact::Ast(fib_ast()), &opts, |_, _| {}).unwrap();
        assert!(report.timings.iter().all(|t| !t.ran || t.funcs > 0), "{:?}", report.timings);
        assert!(report.work() > 0);
    }
}
