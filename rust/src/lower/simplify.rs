//! CFG cleanup passes: unreachable-block elimination, jump threading
//! through empty blocks, and straight-line block merging. Keeps both the
//! printers' output and the generated HLS code close to what a human would
//! write (the paper's stated reason for avoiding TAPIR, Fig. 4(a)).

use std::collections::HashMap;

use crate::ir::cfg::{BlockId, Cfg, Module, Term};

pub fn simplify_module(module: &mut Module) {
    for (_, func) in module.funcs.iter_mut() {
        if let Some(cfg) = func.body.as_mut() {
            simplify_cfg(cfg);
        }
    }
}

/// Would [`simplify_cfg`] leave this CFG untouched? A read-only mirror of
/// one fixpoint round (`thread_jumps` / `merge_straightline` /
/// `remove_unreachable` change conditions), used by the pass manager to
/// skip the copy-on-write module clone when a shared module is already
/// fully simplified — e.g. `simplify_post_dae` after a no-op DAE pass.
pub fn cfg_at_fixpoint(cfg: &Cfg) -> bool {
    // thread_jumps would change: some terminator (or the entry) retargets
    // through an empty forwarding block.
    let mut forward: HashMap<BlockId, BlockId> = HashMap::new();
    for (bid, block) in cfg.blocks.iter() {
        if block.ops.is_empty() && bid != cfg.entry {
            if let Term::Jump(next) = block.term {
                if next != bid {
                    forward.insert(bid, next);
                }
            }
        }
    }
    if !forward.is_empty() {
        let resolve = |mut b: BlockId| {
            let mut hops = 0;
            while let Some(&next) = forward.get(&b) {
                b = next;
                hops += 1;
                if hops > forward.len() {
                    break; // cycle of empty blocks (infinite loop in source)
                }
            }
            b
        };
        for (_, block) in cfg.blocks.iter() {
            let new_term = block.term.map_blocks(&resolve);
            if !same_targets(&block.term, &new_term) {
                return false;
            }
        }
        if resolve(cfg.entry) != cfg.entry {
            return false;
        }
    }
    // merge_straightline would change: a `jump`-terminated block feeds a
    // non-entry block with exactly one predecessor.
    let preds = cfg.predecessors();
    for (a, block) in cfg.blocks.iter() {
        if let Term::Jump(b) = block.term {
            if b != a && b != cfg.entry && preds[b.index()].len() == 1 {
                return false;
            }
        }
    }
    // remove_unreachable would change: any block is unreachable.
    cfg.reachable().iter().all(|&r| r)
}

/// [`cfg_at_fixpoint`] over every function body of a module.
pub fn module_at_fixpoint(module: &Module) -> bool {
    module
        .funcs
        .values()
        .all(|f| f.body.as_ref().map(cfg_at_fixpoint).unwrap_or(true))
}

pub fn simplify_cfg(cfg: &mut Cfg) {
    loop {
        let mut changed = false;
        changed |= thread_jumps(cfg);
        changed |= merge_straightline(cfg);
        changed |= remove_unreachable(cfg);
        if !changed {
            break;
        }
    }
}

/// Retarget edges that point at an empty block whose only content is
/// `jump next`. Sync targets are threaded as well (a sync continuing into an
/// empty forwarding block continues at its target).
fn thread_jumps(cfg: &mut Cfg) -> bool {
    // Resolve forwarding chains with path compression. The entry block is
    // never forwarded: retargeting the entry into a loop header would give
    // the entry block predecessors, which the paper's IR forbids (and the
    // verifier checks). `merge_straightline` handles entry→single-pred
    // chains instead.
    let mut forward: HashMap<BlockId, BlockId> = HashMap::new();
    for (bid, block) in cfg.blocks.iter() {
        if block.ops.is_empty() && bid != cfg.entry {
            if let Term::Jump(next) = block.term {
                if next != bid {
                    forward.insert(bid, next);
                }
            }
        }
    }
    if forward.is_empty() {
        return false;
    }
    let resolve = |mut b: BlockId| {
        let mut hops = 0;
        while let Some(&next) = forward.get(&b) {
            b = next;
            hops += 1;
            if hops > forward.len() {
                break; // cycle of empty blocks (infinite loop in source)
            }
        }
        b
    };
    let mut changed = false;
    let ids: Vec<BlockId> = cfg.blocks.ids().collect();
    for bid in ids {
        let term = cfg.blocks[bid].term.clone();
        let new_term = term.map_blocks(&|b| resolve(b));
        if !same_targets(&term, &new_term) {
            cfg.blocks[bid].term = new_term;
            changed = true;
        }
    }
    let new_entry = resolve(cfg.entry);
    if new_entry != cfg.entry {
        cfg.entry = new_entry;
        changed = true;
    }
    changed
}

fn same_targets(a: &Term, b: &Term) -> bool {
    a.successors() == b.successors()
}

/// Merge `a -> jump b` when `b` has exactly one predecessor and `a`'s
/// terminator is the jump. Sync edges are never merged (the cut point is
/// semantic).
fn merge_straightline(cfg: &mut Cfg) -> bool {
    let preds = cfg.predecessors();
    for a in cfg.blocks.ids().collect::<Vec<_>>() {
        let Term::Jump(b) = cfg.blocks[a].term else { continue };
        if b == a || b == cfg.entry {
            continue;
        }
        if preds[b.index()].len() != 1 {
            continue;
        }
        // Move b's contents into a.
        let b_block = std::mem::take(&mut cfg.blocks[b]);
        let a_block = &mut cfg.blocks[a];
        a_block.ops.extend(b_block.ops);
        a_block.term = b_block.term;
        // b becomes an empty unreachable stub (removed below).
        cfg.blocks[b].term = Term::Halt;
        // Only one merge per iteration round to keep preds fresh.
        return true;
    }
    false
}

/// Drop unreachable blocks by compacting the block list.
fn remove_unreachable(cfg: &mut Cfg) -> bool {
    let reachable = cfg.reachable();
    if reachable.iter().all(|&r| r) {
        return false;
    }
    let mut remap: Vec<Option<BlockId>> = vec![None; cfg.blocks.len()];
    let mut new_blocks = crate::util::idvec::IdVec::new();
    for (bid, block) in cfg.blocks.iter() {
        if reachable[bid.index()] {
            remap[bid.index()] = Some(new_blocks.push(block.clone()));
        }
    }
    for slot in new_blocks.iter_mut() {
        let (_, block) = slot;
        block.term = block.term.map_blocks(&|b| remap[b.index()].expect("edge to unreachable"));
    }
    cfg.entry = remap[cfg.entry.index()].expect("entry always reachable");
    cfg.blocks = new_blocks;
    true
}

/// Count reachable blocks (test/bench helper).
pub fn block_count(cfg: &Cfg) -> usize {
    cfg.reachable().iter().filter(|&&r| r).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::cfg::{Block, Op};
    use crate::ir::expr::Expr;

    fn jump_block(to: BlockId) -> Block {
        Block { ops: vec![], term: Term::Jump(to) }
    }

    #[test]
    fn threads_empty_chain() {
        let mut cfg = Cfg::default();
        let a = cfg.blocks.push(Block::default());
        let b = cfg.blocks.push(Block::default());
        let c = cfg.blocks.push(Block::default());
        let d = cfg.blocks.push(Block { ops: vec![], term: Term::Return(None) });
        cfg.blocks[a].term = Term::Jump(b);
        cfg.blocks[b].term = Term::Jump(c);
        cfg.blocks[c].term = Term::Jump(d);
        cfg.entry = a;
        simplify_cfg(&mut cfg);
        assert_eq!(cfg.blocks.len(), 1);
        assert!(matches!(cfg.blocks[cfg.entry].term, Term::Return(None)));
    }

    #[test]
    fn keeps_sync_blocks_separate() {
        let mut cfg = Cfg::default();
        let a = cfg.blocks.push(Block::default());
        let b = cfg.blocks.push(Block {
            ops: vec![Op::Assign { dst: crate::ir::VarId::new(0), src: Expr::ConstI(1) }],
            term: Term::Return(None),
        });
        cfg.blocks[a].term = Term::Sync { next: b };
        cfg.entry = a;
        simplify_cfg(&mut cfg);
        // Sync edge must survive: 2 blocks.
        assert_eq!(cfg.blocks.len(), 2);
        assert!(matches!(cfg.blocks[cfg.entry].term, Term::Sync { .. }));
    }

    #[test]
    fn removes_unreachable() {
        let mut cfg = Cfg::default();
        let a = cfg.blocks.push(Block { ops: vec![], term: Term::Return(None) });
        let _orphan = cfg.blocks.push(jump_block(a));
        cfg.entry = a;
        simplify_cfg(&mut cfg);
        assert_eq!(cfg.blocks.len(), 1);
    }

    #[test]
    fn fixpoint_probe_agrees_with_simplify() {
        // Each sub-pass's trigger flips the probe; a simplified CFG is
        // always reported at fixpoint (the pass manager relies on this
        // equivalence to skip copy-on-write clones).
        let mut chain = Cfg::default();
        let a = chain.blocks.push(Block::default());
        let b = chain.blocks.push(Block::default());
        let c = chain.blocks.push(Block { ops: vec![], term: Term::Return(None) });
        chain.blocks[a].term = Term::Jump(b);
        chain.blocks[b].term = Term::Jump(c);
        chain.entry = a;
        assert!(!cfg_at_fixpoint(&chain));
        simplify_cfg(&mut chain);
        assert!(cfg_at_fixpoint(&chain));

        let mut orphaned = Cfg::default();
        let e = orphaned.blocks.push(Block { ops: vec![], term: Term::Return(None) });
        let _orphan = orphaned.blocks.push(jump_block(e));
        orphaned.entry = e;
        assert!(!cfg_at_fixpoint(&orphaned));
        simplify_cfg(&mut orphaned);
        assert!(cfg_at_fixpoint(&orphaned));

        // A semantic sync cut stays split and is already at fixpoint.
        let mut sync = Cfg::default();
        let s = sync.blocks.push(Block::default());
        let k = sync.blocks.push(Block {
            ops: vec![Op::Assign { dst: crate::ir::VarId::new(0), src: Expr::ConstI(1) }],
            term: Term::Return(None),
        });
        sync.blocks[s].term = Term::Sync { next: k };
        sync.entry = s;
        assert!(cfg_at_fixpoint(&sync));
    }

    #[test]
    fn merges_single_pred_chain_with_ops() {
        let mut cfg = Cfg::default();
        let v = crate::ir::VarId::new(0);
        let a = cfg.blocks.push(Block {
            ops: vec![Op::Assign { dst: v, src: Expr::ConstI(1) }],
            term: Term::Return(None),
        });
        let b = cfg.blocks.push(Block {
            ops: vec![Op::Assign { dst: v, src: Expr::ConstI(2) }],
            term: Term::Return(None),
        });
        cfg.blocks[a].term = Term::Jump(b);
        cfg.entry = a;
        simplify_cfg(&mut cfg);
        assert_eq!(cfg.blocks.len(), 1);
        assert_eq!(cfg.blocks[cfg.entry].ops.len(), 2);
    }
}
