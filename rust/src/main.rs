//! `bombyx` — the command-line driver.
//!
//! ```text
//! bombyx compile  <file.cilk> [--dae] [--dump implicit|explicit|cilk1] [--trace-stages]
//! bombyx serve    --socket <path> [--capacity N] [--bytes N] [--log]   # resident compile daemon
//! bombyx client   --socket <path> <op> [file.cilk] [--id ID] [--target T]
//! bombyx codegen  <file.cilk> [--dae] --out <dir> [--system <name>]
//! bombyx estimate <file.cilk> [--dae]
//! bombyx kernels  <file.cilk> [--mode implicit|explicit] [--dump]
//! bombyx run      <file.cilk> <entry> [args...] [--dae] [--engine E] [--workers N] [--stats]
//!                 [--deadline-ms N] [--fuel N]                  # per-job budgets (ws engine)
//!                 [--jit-threshold N] [--profile-sample N]      # native tier / profiler knobs
//! bombyx run      --engine ws --jobs N [--repeat K] [--workers N] [--chaos SEED] [--stats]
//! bombyx sim      <file.cilk> <entry> [args...] [--dae] [--pes N] [--mem-latency N]
//! bombyx bfs      [--depth D] [--branch B] [--pes N]     # paper §III experiment
//! bombyx trace    summarize <trace.json> [--top N]       # aggregate a --trace file
//! ```
//!
//! `run`, `compile` and `compile-batch` additionally accept
//! `--trace <file>` (Chrome trace-event / Perfetto JSON) and
//! `--metrics-json <file>` (the `bombyx-metrics-v1` document) — see
//! `src/obs/README.md`.
//!
//! (Argument parsing is hand-rolled: clap is not in the offline vendor
//! set — see DESIGN.md §6.6.)

use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};

use bombyx::hls::{estimate, CostModel};
use bombyx::ir::expr::Value;
use bombyx::ir::print::{print_cilk1, print_module};
use bombyx::lower::{CompileOptions, CompileSession};
use bombyx::sim::{NoSimXla, SimConfig};
use bombyx::util::bench::timing_table;
use bombyx::util::table::{commas, Table};
use bombyx::workloads::graphgen;
use bombyx::ws::{self, WsConfig};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

struct Flags {
    positional: Vec<String>,
    options: std::collections::HashMap<String, String>,
    switches: std::collections::HashSet<String>,
}

fn parse_flags(args: &[String], value_opts: &[&str]) -> Result<Flags> {
    let mut flags = Flags {
        positional: Vec::new(),
        options: std::collections::HashMap::new(),
        switches: std::collections::HashSet::new(),
    };
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if value_opts.contains(&name) {
                let value = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("--{name} requires a value"))?;
                flags.options.insert(name.to_string(), value.clone());
                i += 2;
            } else {
                flags.switches.insert(name.to_string());
                i += 1;
            }
        } else {
            flags.positional.push(a.clone());
            i += 1;
        }
    }
    Ok(flags)
}

/// Telemetry lifecycle for one command: arm the `obs` layer from
/// `--trace <file>` / `--metrics-json <file>` (and the hotness profiler
/// for `run --stats`), run the command body, then write the export
/// files. Everything stays disabled — one relaxed load per
/// instrumentation point — when neither flag is given.
struct Telemetry {
    trace_path: Option<String>,
    metrics_path: Option<String>,
}

impl Telemetry {
    fn arm(flags: &Flags, profile: bool) -> Telemetry {
        let trace_path = flags.options.get("trace").cloned();
        let metrics_path = flags.options.get("metrics-json").cloned();
        bombyx::obs::set_trace(trace_path.is_some());
        bombyx::obs::set_metrics(metrics_path.is_some());
        bombyx::obs::set_profile(profile);
        if trace_path.is_some() {
            bombyx::obs::trace::set_thread_name("main");
        }
        Telemetry { trace_path, metrics_path }
    }

    /// Write the export files (call once, after the command's work).
    fn finish(&self) -> Result<()> {
        if let Some(path) = &self.trace_path {
            let events = bombyx::obs::trace::drain();
            let doc = bombyx::obs::trace::export_json(&events);
            std::fs::write(path, doc.pretty()).with_context(|| format!("writing {path}"))?;
            let dropped = bombyx::obs::trace::dropped();
            if dropped > 0 {
                eprintln!("warning: trace ring overflow, {dropped} event(s) dropped");
            }
            println!("wrote {} trace event(s) to {path}", events.len());
        }
        if let Some(path) = &self.metrics_path {
            let doc = bombyx::obs::metrics::export_json();
            std::fs::write(path, doc.pretty()).with_context(|| format!("writing {path}"))?;
            println!("wrote metrics to {path}");
        }
        Ok(())
    }
}

/// Print the sampled per-kernel hotness profile (`run --stats`):
/// dispatch counts from [`bombyx::obs::profile`], weighted by each
/// kernel's static cycle estimate under the default schedule model when
/// a kernel program is at hand. Also published as `profile.*` counters
/// when metrics are armed.
fn print_profile(kernels: Option<&bombyx::exec::KernelProgram>, top: usize) {
    let counts = bombyx::obs::profile::snapshot();
    if counts.is_empty() {
        return;
    }
    let model = bombyx::hls::ScheduleModel::default();
    let mut rows: Vec<(String, u64, u64)> = counts
        .into_iter()
        .map(|(name, n)| {
            let static_cycles: u64 = kernels
                .and_then(|p| p.funcs.iter().find(|k| k.name == name))
                .map(|k| k.costs.iter().map(|c| c.cycles(&model) as u64).sum())
                .unwrap_or(0);
            (name, n, n * static_cycles)
        })
        .collect();
    rows.sort_by(|a, b| b.2.cmp(&a.2).then(b.1.cmp(&a.1)));
    for (name, n, cyc) in &rows {
        bombyx::obs::metrics::counter_set(&format!("profile.dispatches.{name}"), *n);
        bombyx::obs::metrics::counter_set(&format!("profile.cycles.{name}"), *cyc);
    }
    println!("hotness profile (top {} of {} kernels, by est. cycles):", top.min(rows.len()), rows.len());
    let mut table = Table::new(["kernel", "dispatches", "est. cycles"]);
    for (name, n, cyc) in rows.iter().take(top) {
        table.row([name.clone(), commas(*n), commas(*cyc)]);
    }
    print!("{}", table.render());
}

/// `bombyx trace summarize <file> [--top N]` — aggregate a trace written
/// by `--trace`: hottest span names by total time, plus per-job latency
/// breakdowns with lifecycle milestones.
fn cmd_trace(args: &[String]) -> Result<()> {
    if args.first().map(String::as_str) != Some("summarize") {
        bail!("usage: bombyx trace summarize <trace.json> [--top N]");
    }
    let flags = parse_flags(&args[1..], &["top"])?;
    let path = flags
        .positional
        .first()
        .ok_or_else(|| anyhow!("expected a trace file (written by --trace)"))?;
    let top = flags.options.get("top").map(|v| v.parse::<usize>()).transpose()?.unwrap_or(10);
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = bombyx::util::json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
    let summary =
        bombyx::obs::trace::summarize(&doc).map_err(|e| anyhow!("summarizing {path}: {e}"))?;
    if !summary.spans.is_empty() {
        println!("hot spans (top {} of {}, by total time):", top.min(summary.spans.len()), summary.spans.len());
        let mut table = Table::new(["span", "count", "total ms", "max ms"]);
        for (name, count, total_ms, max_ms) in summary.spans.iter().take(top) {
            table.row([
                name.clone(),
                commas(*count),
                format!("{total_ms:.3}"),
                format!("{max_ms:.3}"),
            ]);
        }
        print!("{}", table.render());
    }
    if !summary.jobs.is_empty() {
        let mut jobs = summary.jobs.clone();
        jobs.sort_by(|a, b| b.2.total_cmp(&a.2));
        println!("jobs (top {} of {}, by latency):", top.min(jobs.len()), jobs.len());
        let mut table = Table::new(["job", "id", "latency ms", "milestones"]);
        for (name, id, latency_ms, marks) in jobs.iter().take(top) {
            table.row([
                name.clone(),
                id.to_string(),
                format!("{latency_ms:.3}"),
                marks.join(" -> "),
            ]);
        }
        print!("{}", table.render());
        let lat: Vec<f64> = jobs.iter().map(|j| j.2).collect();
        let h = bombyx::obs::metrics::Histogram::from_samples(&lat);
        println!(
            "job latency: n {}  p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms  max {:.3} ms",
            h.count(),
            h.percentile(0.50),
            h.percentile(0.95),
            h.percentile(0.99),
            h.max()
        );
    }
    if summary.unbalanced > 0 {
        eprintln!("warning: {} unbalanced begin/end event(s)", summary.unbalanced);
    }
    Ok(())
}

/// `bombyx serve --socket <path>` — run the resident compile daemon
/// until a client sends `shutdown` (see `rust/src/serve/`).
fn cmd_serve(args: &[String]) -> Result<()> {
    let flags = parse_flags(args, &["socket", "capacity", "bytes", "trace", "metrics-json"])?;
    let socket = flags
        .options
        .get("socket")
        .ok_or_else(|| anyhow!("serve requires --socket <path>"))?;
    let telemetry = Telemetry::arm(&flags, false);
    let mut config = bombyx::serve::ServeConfig::new(socket);
    if let Some(v) = flags.options.get("capacity") {
        config.capacity = v.parse().context("--capacity must be an integer")?;
    }
    if let Some(v) = flags.options.get("bytes") {
        config.byte_budget = v.parse().context("--bytes must be an integer")?;
    }
    config.log = flags.switches.contains("log");
    let server = bombyx::serve::Server::start(config)?;
    println!("bombyx serve: listening on {}", server.socket().display());
    let stats = server.join()?;
    println!(
        "bombyx serve: shut down after {} request(s) ({} compile(s), {} warm hit(s), \
         {} dedup hit(s), {} eviction(s), {} error(s))",
        stats.requests,
        stats.compiles,
        stats.cache_hits,
        stats.dedup_hits + stats.dedup_spliced,
        stats.evictions,
        stats.errors
    );
    telemetry.finish()
}

/// `bombyx client --socket <path> <op> [...]` — one scripted request
/// against a running daemon; prints the response JSON.
fn cmd_client(args: &[String]) -> Result<()> {
    let flags = parse_flags(args, &["socket", "id", "target", "system", "jobs"])?;
    let socket = flags
        .options
        .get("socket")
        .ok_or_else(|| anyhow!("client requires --socket <path>"))?;
    let op = flags
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow!("client requires an op: compile|recompile|codegen|stats|shutdown"))?;
    let mut client = bombyx::serve::Client::connect(socket)?;
    let read_source = |idx: usize| -> Result<(String, String)> {
        let path = flags
            .positional
            .get(idx)
            .ok_or_else(|| anyhow!("`{op}` needs a .cilk source file argument"))?;
        let source =
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let id = flags.options.get("id").cloned().unwrap_or_else(|| path.clone());
        Ok((id, source))
    };
    let extend = |msg: &mut bombyx::util::json::Json| {
        if flags.switches.contains("no-dae") {
            msg.set("no_dae", true);
        }
        if flags.switches.contains("dae") {
            msg.set("dae", true);
        }
        if flags.switches.contains("echo") {
            msg.set("echo", true);
        }
    };
    let resp = match op {
        "compile" => {
            let (id, source) = read_source(1)?;
            client.compile_with(&id, &source, extend)?
        }
        "recompile" => {
            let (id, source) = read_source(1)?;
            client.recompile_with(&id, &source, extend)?
        }
        "codegen" => {
            let target = flags.options.get("target").map(String::as_str).unwrap_or("emu");
            let (id, source) = match read_source(1) {
                Ok((id, source)) => (id, Some(source)),
                Err(_) => {
                    let id = flags
                        .options
                        .get("id")
                        .cloned()
                        .ok_or_else(|| anyhow!("codegen needs a source file or --id"))?;
                    (id, None)
                }
            };
            client.codegen(&id, target, source.as_deref())?
        }
        "stats" => client.stats()?,
        "shutdown" => client.shutdown()?,
        other => bail!("unknown client op `{other}` (compile|recompile|codegen|stats|shutdown)"),
    };
    println!("{}", resp.pretty());
    if resp.get("ok") != Some(&bombyx::util::json::Json::Bool(true)) {
        bail!("request failed");
    }
    Ok(())
}

fn run(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "compile" => cmd_compile(rest),
        "compile-batch" => cmd_compile_batch(rest),
        "codegen" => cmd_codegen(rest),
        "serve" => cmd_serve(rest),
        "client" => cmd_client(rest),
        "estimate" => cmd_estimate(rest),
        "kernels" => cmd_kernels(rest),
        "run" => cmd_run(rest),
        "sim" => cmd_sim(rest),
        "bfs" => cmd_bfs(rest),
        "trace" => cmd_trace(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `bombyx help`)"),
    }
}

fn print_usage() {
    println!(
        "bombyx — OpenCilk-style task parallelism compiled for FPGA TLP systems\n\n\
         USAGE:\n  \
         bombyx compile  <file.cilk> [--target rtl|hardcilk] [--dae|--no-dae] [--dump implicit|explicit|cilk1] [--trace-stages] [--timings]\n  \
         bombyx compile-batch [files|dirs...] [--jobs N] [--no-dae] [--timings]   # default corpus: examples/cilk\n  \
         bombyx serve    --socket <path> [--capacity N] [--bytes N] [--log]   # resident compile daemon (LRU session cache)\n  \
         bombyx client   --socket <path> compile|recompile|codegen|stats|shutdown [file.cilk] [--id ID] [--target emu|hardcilk|rtl] [--echo]\n  \
         bombyx codegen  <file.cilk> [--target rtl|hardcilk] [--dae|--no-dae] --out <dir> [--system <name>]\n  \
         bombyx estimate <file.cilk> [--dae|--no-dae]\n  \
         bombyx kernels  <file.cilk> [--mode implicit|explicit] [--dae|--no-dae] [--dump]\n  \
         bombyx run      <file.cilk> <entry> [int args...] [--engine oracle|explicit|ws|sim] [--dae|--no-dae] [--workers N] [--stats]\n                  \
         [--deadline-ms N] [--fuel N]   # per-job wall-clock / dispatch budgets (ws engine)\n  \
         bombyx run      --engine ws --jobs N [--repeat K] [--workers N] [--chaos SEED] [--stats]   # flood the resident executor\n  \
         bombyx sim      <file.cilk> <entry> [int args...] [--dae|--no-dae] [--pes N] [--mem-latency N]\n  \
         bombyx bfs      [--depth D] [--branch B] [--pes N]\n  \
         bombyx trace    summarize <trace.json> [--top N]\n\n\
         Sources containing `#pragma bombyx dae` compile with DAE enabled\n\
         automatically; `--no-dae` forces the non-DAE baseline.\n\n\
         Observability (run / compile / compile-batch):\n  \
         --trace <file>          write a Chrome trace-event / Perfetto JSON trace\n  \
         --metrics-json <file>   write the bombyx-metrics-v1 counters/gauges/histograms\n\
         `run --stats` also samples a per-kernel hotness profile (top-N dispatches).\n\n\
         Fault tolerance: `run --engine ws --jobs N --chaos SEED` replays the flood with\n\
         deterministic fault injection (panics, transients, delays) and retry enabled;\n\
         BOMBYX_CHAOS=<seed> arms the same plan on any resident-executor run."
    );
}

/// Build a compile session (one lowering, shared by every target the
/// command touches). DAE is enabled by `--dae` or by the presence of
/// `#pragma bombyx dae` in the source (the pragma states intent);
/// `--no-dae` wins over both.
fn load_session(flags: &Flags) -> Result<CompileSession> {
    let path = flags
        .positional
        .first()
        .ok_or_else(|| anyhow!("expected a .cilk source file"))?;
    let source = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    // Comment-stripped pragma scan: `// #pragma bombyx dae` must not flip
    // the mode (the parser ignores it too).
    let has_pragma = source
        .lines()
        .any(|l| l.split("//").next().unwrap_or("").contains("#pragma bombyx dae"));
    let dae = !flags.switches.contains("no-dae")
        && (flags.switches.contains("dae") || has_pragma);
    let opts = if dae { CompileOptions::standard() } else { CompileOptions::no_dae() };
    CompileSession::new(path, &source, &opts)
}

fn cmd_compile(args: &[String]) -> Result<()> {
    let flags = parse_flags(args, &["dump", "target", "trace", "metrics-json"])?;
    let telemetry = Telemetry::arm(&flags, false);
    let mut session = load_session(&flags)?;
    let target = flags.options.get("target").map(String::as_str);
    if !matches!(target, None | Some("explicit"))
        && (flags.options.contains_key("dump") || flags.switches.contains("trace-stages"))
    {
        bail!("--dump/--trace-stages only apply to the default IR target");
    }
    match target {
        None | Some("explicit") => {}
        Some("rtl") => {
            let system = session.rtl_system("bombyx_system")?;
            print!("{}", system.report());
            print!("{}", system.concatenated());
            if flags.switches.contains("timings") {
                println!("{}", timing_table(session.timings()));
            }
            return telemetry.finish();
        }
        Some("hardcilk") => {
            let system = session.hardcilk_system("bombyx_system")?;
            println!("{}", system.header);
            for (_, file, src) in &system.pes {
                println!("// ==== {file} ====\n{src}");
            }
            println!("// ==== bombyx_system.json ====\n{}", system.descriptor.pretty());
            if flags.switches.contains("timings") {
                println!("{}", timing_table(session.timings()));
            }
            return telemetry.finish();
        }
        Some(other) => {
            bail!("unknown --target `{other}` (expected `rtl`, `hardcilk` or `explicit`)")
        }
    }
    let result = session.result();
    if flags.switches.contains("timings") {
        println!("{}", timing_table(session.timings()));
    }
    if flags.switches.contains("trace-stages") {
        println!("=== stage 1: implicit IR ===\n{}", print_module(&result.implicit));
        println!("=== stage 2: implicit IR after DAE ===\n{}", print_module(&result.implicit_dae));
        println!("=== stage 3: explicit IR ===\n{}", print_module(&result.explicit));
        return telemetry.finish();
    }
    match flags.options.get("dump").map(String::as_str) {
        Some("implicit") => print!("{}", print_module(&result.implicit_dae)),
        Some("cilk1") => {
            for (_, f) in result.explicit.funcs.iter() {
                if f.task.is_some() && f.body.is_some() {
                    println!("{}", print_cilk1(&result.explicit, f));
                }
            }
        }
        _ => print!("{}", print_module(&result.explicit)),
    }
    telemetry.finish()
}

/// Compile many sources across a thread pool (`lower::compile_batch`).
/// Inputs are `.cilk` files and/or directories (every `*.cilk` inside,
/// sorted); with no inputs the `examples/cilk` corpus is used. Per-source
/// errors are reported individually and the batch continues — the exit
/// status reflects whether everything compiled.
fn cmd_compile_batch(args: &[String]) -> Result<()> {
    let flags = parse_flags(args, &["jobs", "trace", "metrics-json"])?;
    let telemetry = Telemetry::arm(&flags, false);
    let jobs = flags
        .options
        .get("jobs")
        .map(|v| v.parse::<usize>())
        .transpose()
        .map_err(|e| anyhow!("bad --jobs value: {e}"))?
        .unwrap_or(0);
    let inputs: Vec<String> = if flags.positional.is_empty() {
        vec!["examples/cilk".to_string()]
    } else {
        flags.positional.clone()
    };
    let mut paths: Vec<std::path::PathBuf> = Vec::new();
    for input in &inputs {
        let p = std::path::Path::new(input);
        if p.is_dir() {
            let mut entries: Vec<std::path::PathBuf> = std::fs::read_dir(p)
                .with_context(|| format!("reading directory {input}"))?
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("cilk"))
                .collect();
            entries.sort();
            paths.extend(entries);
        } else {
            paths.push(p.to_path_buf());
        }
    }
    if paths.is_empty() {
        bail!("no .cilk sources found under {inputs:?}");
    }
    // Read failures are aggregated like compile failures — one unreadable
    // file must not sink the rest of the batch.
    let mut sources: Vec<(String, String)> = Vec::new();
    let mut read_errors: Vec<(String, String)> = Vec::new();
    for p in &paths {
        let name = p.display().to_string();
        match std::fs::read_to_string(p) {
            Ok(text) => sources.push((name, text)),
            Err(e) => read_errors.push((name, format!("reading {}: {e}", p.display()))),
        }
    }
    let opts = if flags.switches.contains("no-dae") {
        CompileOptions::no_dae()
    } else {
        // Sources without `#pragma bombyx dae` compile identically under
        // the standard options (the DAE pass converts nothing), so one
        // option set serves a mixed corpus.
        CompileOptions::standard()
    };
    let t0 = std::time::Instant::now();
    let batch = bombyx::lower::compile_batch(&sources, &opts, jobs);
    let wall = t0.elapsed();
    let mut table = Table::new(["source", "status", "tasks", "lowering"]);
    for (name, err) in &read_errors {
        table.row([name.clone(), "ERROR".to_string(), "-".to_string(), "-".to_string()]);
        eprintln!("error: {name}: {err}");
    }
    for (name, outcome) in &batch.outcomes {
        match outcome {
            Ok(session) => {
                let tasks = bombyx::ir::explicit::explicit_tasks(session.explicit()).len();
                let total: std::time::Duration =
                    session.timings().iter().map(|t| t.duration).sum();
                table.row([
                    name.clone(),
                    "ok".to_string(),
                    tasks.to_string(),
                    bombyx::util::bench::fmt_duration(total),
                ]);
            }
            Err(e) => {
                table.row([name.clone(), "ERROR".to_string(), "-".to_string(), "-".to_string()]);
                eprintln!("error: {name}: {e:#}");
            }
        }
    }
    print!("{}", table.render());
    println!(
        "{} sources on {} worker thread(s), wall {}",
        paths.len(),
        batch.workers,
        bombyx::util::bench::fmt_duration(wall)
    );
    if flags.switches.contains("timings") {
        println!("{}", timing_table(&batch.timings));
    }
    let n_err = batch.errors().len() + read_errors.len();
    if n_err > 0 {
        telemetry.finish()?;
        bail!("{n_err} of {} sources failed to compile", paths.len());
    }
    telemetry.finish()
}

fn cmd_codegen(args: &[String]) -> Result<()> {
    let flags = parse_flags(args, &["out", "system", "target"])?;
    let mut session = load_session(&flags)?;
    let name =
        flags.options.get("system").map(String::as_str).unwrap_or("bombyx_system").to_string();
    if flags.options.get("target").map(String::as_str) == Some("rtl") {
        let system = session.rtl_system(&name)?;
        match flags.options.get("out") {
            Some(dir) => {
                system.write_to(std::path::Path::new(dir))?;
                println!(
                    "wrote {} PE modules + package + {}_top.v to {dir} ({} LoC)",
                    system.pes.len(),
                    name,
                    system.total_loc()
                );
            }
            None => print!("{}", system.concatenated()),
        }
        return Ok(());
    }
    let system = session.hardcilk_system(&name)?;
    match flags.options.get("out") {
        Some(dir) => {
            system.write_to(std::path::Path::new(dir))?;
            println!(
                "wrote {} PE kernels + header + {}.json to {dir} ({} LoC)",
                system.pes.len(),
                name,
                system.total_loc()
            );
        }
        None => {
            println!("{}", system.header);
            for (_, file, src) in &system.pes {
                println!("// ==== {file} ====\n{src}");
            }
            println!("// ==== {name}.json ====\n{}", system.descriptor.pretty());
        }
    }
    Ok(())
}

fn cmd_estimate(args: &[String]) -> Result<()> {
    let flags = parse_flags(args, &[])?;
    let session = load_session(&flags)?;
    let explicit = session.explicit();
    let model = CostModel::default();
    let mut table = Table::new(["task", "role", "LUT", "FF", "BRAM", "DSP"]);
    let mut total = bombyx::hls::ResourceEstimate::default();
    for fid in bombyx::ir::explicit::explicit_tasks(explicit) {
        let f = &explicit.funcs[fid];
        let est = estimate(&model, explicit, f);
        total = total + est;
        table.row([
            f.name.clone(),
            f.task.as_ref().unwrap().role.name().to_string(),
            est.lut.to_string(),
            est.ff.to_string(),
            est.bram.to_string(),
            est.dsp.to_string(),
        ]);
    }
    table.row([
        "TOTAL".to_string(),
        String::new(),
        total.lut.to_string(),
        total.ff.to_string(),
        total.bram.to_string(),
        total.dsp.to_string(),
    ]);
    print!("{}", table.render());
    Ok(())
}

/// `bombyx kernels <file> [--mode implicit|explicit] [--dump]` — per-task
/// summary of the compiled execution kernels (instruction counts, fused
/// superinstruction pairs, frame sizes), plus the full disassembly with
/// fused superinstructions and `KCost` annotations under `--dump`.
fn cmd_kernels(args: &[String]) -> Result<()> {
    let flags = parse_flags(args, &["mode"])?;
    let mut session = load_session(&flags)?;
    let mode = flags.options.get("mode").map(String::as_str).unwrap_or("explicit");
    let prog = match mode {
        "explicit" => session.kernels_timed()?,
        "implicit" => session.implicit_kernels()?,
        other => bail!("unknown --mode `{other}` (expected implicit or explicit)"),
    };
    let mut table = Table::new(["kernel", "role", "instrs", "fused pairs", "frame", "params"]);
    for k in &prog.funcs {
        table.row([
            k.name.clone(),
            k.role.to_string(),
            k.code.len().to_string(),
            k.fused.to_string(),
            k.frame.len().to_string(),
            k.params.to_string(),
        ]);
    }
    print!("{}", table.render());
    let (pairs, before) = prog.fusion();
    println!(
        "{} kernels ({mode} IR), {} instrs ({} before fusion), {} fused pairs, fused_ratio {:.3}{}",
        prog.funcs.len(),
        prog.instr_count(),
        before,
        pairs,
        prog.fused_ratio(),
        if bombyx::exec::fuse_enabled() { "" } else { "  [BOMBYX_KERNEL_FUSE=0]" }
    );
    print_role_fusion(&prog);
    if flags.switches.contains("dump") {
        print!("{}", prog.disasm());
    }
    Ok(())
}

/// One line per task role under the global fusion summary — fusion
/// coverage varies sharply by kernel shape, and the global ratio
/// averages that away.
fn print_role_fusion(prog: &bombyx::exec::KernelProgram) {
    for (role, pairs, before) in prog.fusion_by_role() {
        let ratio = if before == 0 { 0.0 } else { 2.0 * pairs as f64 / before as f64 };
        println!(
            "  role {role:<12} fused pairs {:>6} / {:>8} instrs (fused_ratio {ratio:.3})",
            commas(pairs),
            commas(before)
        );
    }
}

/// `bombyx run --engine ws --jobs N [--repeat K]` — flood the resident
/// executor with interleaved mixed-corpus jobs (every result verified
/// against its reference) and report steady-state throughput plus
/// per-job latency percentiles.
fn run_flood(
    workers: usize,
    jobs: usize,
    repeat: usize,
    want_stats: bool,
    chaos: Option<u64>,
) -> Result<()> {
    use bombyx::util::bench::fmt_duration;
    let exp = bombyx::coordinator::WsServeExperiment::new()?;
    println!(
        "flooding resident ws executor: {jobs} job(s) x {repeat} wave(s) on {workers} worker(s), corpus [{}]",
        exp.corpus_names().join(", ")
    );
    let report = exp.flood(workers, jobs, repeat)?;
    println!(
        "jobs: {} completed, {} verified   wall {}   throughput {:.1} jobs/s",
        report.jobs,
        report.verified,
        fmt_duration(report.wall),
        report.jobs_per_s
    );
    println!(
        "latency: p50 {}   p95 {}   p99 {}",
        fmt_duration(report.p50),
        fmt_duration(report.p95),
        fmt_duration(report.p99)
    );
    if want_stats {
        print_flood_stats(&report);
    }
    let Some(seed) = chaos else { return Ok(()) };
    // Degraded pass: same corpus and load, but with the standard chaos
    // mix armed (injected panics, transient faults and delays) and a
    // retry-friendly default spec — every non-shed job must still verify.
    println!("chaos flood: re-running the same load with fault injection armed (seed {seed})");
    let degraded = exp.flood_chaos(workers, jobs, repeat, seed)?;
    let retained = if report.jobs_per_s > 0.0 {
        degraded.jobs_per_s / report.jobs_per_s * 100.0
    } else {
        0.0
    };
    println!(
        "chaos: {} of {} job(s) verified, {} failed   wall {}   throughput {:.1} jobs/s ({retained:.0}% of clean)",
        degraded.verified,
        degraded.jobs,
        degraded.failed,
        fmt_duration(degraded.wall),
        degraded.jobs_per_s
    );
    let breakdown: Vec<String> = degraded
        .outcome_breakdown()
        .into_iter()
        .map(|(tag, n)| format!("{tag} {n}"))
        .collect();
    println!("chaos outcomes: {}", breakdown.join("   "));
    if want_stats {
        print_flood_stats(&degraded);
    }
    Ok(())
}

/// The `--stats` executor-counter block shared by the clean and chaos
/// flood reports, including the fault-tolerance counters and the
/// terminal-outcome breakdown by [`bombyx::ws::JobErrorKind`] tag.
fn print_flood_stats(report: &bombyx::coordinator::FloodReport) {
    let s = &report.stats;
    println!(
        "executor: submitted {}  completed {}  failed {}  cancelled {}  retried {}  shed {}  workers respawned {}",
        s.jobs_submitted,
        s.jobs_completed,
        s.jobs_failed,
        s.jobs_cancelled,
        s.jobs_retried,
        s.jobs_shed,
        s.workers_respawned
    );
    println!(
        "executor: tasks {}  steals {}  closures {}  xla batches {}  instrs {}",
        commas(s.tasks_run),
        commas(s.steals),
        commas(s.closures_made),
        commas(s.xla_batches),
        commas(s.instrs)
    );
    if s.jobs_failed > 0 || s.jobs_shed > 0 {
        let breakdown: Vec<String> = report
            .outcome_breakdown()
            .into_iter()
            .map(|(tag, n)| format!("{tag} {n}"))
            .collect();
        println!("executor: outcomes {}", breakdown.join("   "));
    }
}

fn parse_task_args(flags: &Flags) -> Result<(String, Vec<Value>)> {
    let entry = flags
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("expected an entry task name"))?
        .clone();
    let args: Vec<Value> = flags.positional[2..]
        .iter()
        .map(|a| a.parse::<i64>().map(Value::I64).map_err(|e| anyhow!("bad int arg `{a}`: {e}")))
        .collect::<Result<_>>()?;
    Ok((entry, args))
}

/// `bombyx run <file> <entry> [args...] [--engine oracle|explicit|ws|sim]
/// [--workers N] [--stats]` — one entry point over all four execution
/// engines, all running the session's cached kernel program. With
/// `--jobs N` (ws engine only) no source file is read: the built-in
/// mixed corpus floods the resident executor instead.
fn cmd_run(args: &[String]) -> Result<()> {
    let flags = parse_flags(
        args,
        &["workers", "engine", "jobs", "repeat", "deadline-ms", "fuel", "chaos", "trace", "metrics-json", "jit-threshold", "profile-sample"],
    )?;
    let engine = flags
        .options
        .get("engine")
        .map(String::as_str)
        .unwrap_or("ws")
        .to_string();
    let want_stats = flags.switches.contains("stats");
    if let Some(t) = flags.options.get("jit-threshold") {
        let t = t.parse::<u64>().map_err(|e| anyhow!("bad --jit-threshold value: {e}"))?;
        bombyx::exec::jit::set_threshold_override(t);
    }
    if let Some(n) = flags.options.get("profile-sample") {
        let n = n.parse::<u64>().map_err(|e| anyhow!("bad --profile-sample value: {e}"))?;
        bombyx::obs::profile::set_sample_every(n);
    }
    // The hotness profiler rides on --stats (sampled at frame entry via
    // `Machine::on_dispatch` — never the retired fast path).
    let telemetry = Telemetry::arm(&flags, want_stats);
    let deadline_ms = flags
        .options
        .get("deadline-ms")
        .map(|v| v.parse::<u64>())
        .transpose()
        .map_err(|e| anyhow!("bad --deadline-ms value: {e}"))?;
    let fuel = flags
        .options
        .get("fuel")
        .map(|v| v.parse::<u64>())
        .transpose()
        .map_err(|e| anyhow!("bad --fuel value: {e}"))?;
    let chaos = flags
        .options
        .get("chaos")
        .map(|v| v.parse::<u64>())
        .transpose()
        .map_err(|e| anyhow!("bad --chaos value (expected a u64 seed): {e}"))?;
    if flags.options.contains_key("jobs") || flags.options.contains_key("repeat") {
        if engine != "ws" {
            bail!("--jobs/--repeat need the resident executor (use --engine ws)");
        }
        if deadline_ms.is_some() || fuel.is_some() {
            bail!("--deadline-ms/--fuel apply to a single-job run, not a --jobs flood");
        }
        let jobs = flags
            .options
            .get("jobs")
            .ok_or_else(|| anyhow!("--repeat requires --jobs"))?
            .parse::<usize>()
            .map_err(|e| anyhow!("bad --jobs value: {e}"))?;
        let repeat = flags
            .options
            .get("repeat")
            .map(|v| v.parse::<usize>())
            .transpose()
            .map_err(|e| anyhow!("bad --repeat value: {e}"))?
            .unwrap_or(1);
        if jobs == 0 {
            bail!("--jobs must be >= 1");
        }
        let workers =
            flags.options.get("workers").map(|w| w.parse::<usize>()).transpose()?.unwrap_or(4);
        run_flood(workers, jobs, repeat, want_stats, chaos)?;
        if want_stats {
            print_profile(None, 10);
        }
        return telemetry.finish();
    }
    if chaos.is_some() {
        bail!("--chaos drives the flood mode (add --jobs N); set BOMBYX_CHAOS=<seed> to arm single runs");
    }
    if (deadline_ms.is_some() || fuel.is_some()) && engine != "ws" {
        bail!("--deadline-ms/--fuel need the resident executor (use --engine ws)");
    }
    let mut session = load_session(&flags)?;
    let (entry, task_args) = parse_task_args(&flags)?;
    let workers = flags
        .options
        .get("workers")
        .map(|w| w.parse::<usize>())
        .transpose()?
        .unwrap_or_else(|| WsConfig::default().workers);

    // Kernel compilation, session-cached: the oracle runs implicit-IR
    // kernels, every other engine shares the explicit ones (timed via
    // the `kernel_compile` pass).
    let t0 = std::time::Instant::now();
    if engine == "oracle" {
        session.implicit_kernels()?;
    } else {
        session.kernels_timed()?;
    }
    let kernel_time = t0.elapsed();

    // The engines drop their tiers before the --stats block below reads
    // the tier table, and the interned JitProgram (with its counters)
    // only lives as long as some tier over it — hold one across the run.
    let _jit_pin = if want_stats {
        let kernels = if engine == "oracle" {
            session.implicit_kernels()?
        } else {
            session.explicit_kernels()?
        };
        bombyx::exec::jit::tier_for(&kernels)
    } else {
        None
    };

    let wall = std::time::Instant::now();
    let (value, tasks, retired) = match engine.as_str() {
        "oracle" => {
            let kernels = session.implicit_kernels()?;
            let mut o = bombyx::interp::oracle::Oracle::with_kernels(
                session.implicit(),
                session.implicit_memory(),
                bombyx::interp::NoXla,
                kernels,
            );
            let value = o.run(&entry, &task_args)?;
            if want_stats {
                println!(
                    "oracle: calls {}  spawns {}  loads {}  stores {}  max depth {}",
                    commas(o.stats.calls),
                    commas(o.stats.spawns),
                    commas(o.stats.loads),
                    commas(o.stats.stores),
                    o.stats.max_depth
                );
            }
            (value, o.stats.calls, o.stats.instrs)
        }
        "explicit" => {
            let kernels = session.explicit_kernels()?;
            let mut ex = bombyx::interp::explicit_exec::ExplicitExec::with_kernels(
                session.explicit(),
                session.memory(),
                bombyx::interp::NoXla,
                kernels,
            );
            let value = ex.run(&entry, &task_args)?;
            if want_stats {
                println!(
                    "explicit: tasks {}  closures {}  sends {}  max ready {}  max live closures {}",
                    commas(ex.stats.tasks_run),
                    commas(ex.stats.closures_made),
                    commas(ex.stats.sends),
                    ex.stats.max_ready,
                    ex.stats.max_live_closures
                );
            }
            (value, ex.stats.tasks_run, ex.stats.instrs)
        }
        "ws" => {
            let (value, stats) = if deadline_ms.is_some() || fuel.is_some() {
                // Budgeted run: route through the resident executor so
                // the JobSpec's deadline and fuel budget are enforced at
                // dispatch boundaries.
                let spec = ws::JobSpec {
                    deadline: deadline_ms.map(std::time::Duration::from_millis),
                    fuel_budget: fuel,
                    ..ws::JobSpec::default()
                };
                let config = ws::ExecutorConfig {
                    ws: WsConfig { workers, steal_tries: 4 },
                    ..ws::ExecutorConfig::default()
                };
                let executor = ws::Executor::new(config)?;
                let job = session.ws_job(&entry, &task_args)?.with_spec(spec);
                let handle = executor.submit(job)?;
                let (value, _, stats) = handle.join()?;
                (value, stats)
            } else {
                let cfg = WsConfig { workers, steal_tries: 4 };
                let (value, _, stats) = session.run_ws(
                    session.shared_memory(),
                    &entry,
                    &task_args,
                    &cfg,
                    Box::new(ws::NoXlaSink),
                )?;
                (value, stats)
            };
            println!(
                "tasks: {}  closures: {}  workers: {workers}",
                commas(stats.tasks_run),
                commas(stats.closures_made)
            );
            if want_stats {
                println!(
                    "ws: steals {}  peak live closures {}  xla batches {}",
                    commas(stats.steals),
                    commas(stats.max_live_closures),
                    commas(stats.xla_batches)
                );
            }
            (value, stats.tasks_run, stats.instrs)
        }
        "sim" => {
            let cfg = SimConfig::default();
            let (value, _, stats) =
                session.simulate(session.memory(), &entry, &task_args, &cfg, &mut NoSimXla)?;
            println!(
                "cycles: {} ({:.1} us @ {} MHz)   tasks: {}",
                commas(stats.cycles),
                cfg.cycles_to_us(stats.cycles),
                cfg.freq_mhz,
                commas(stats.tasks_run)
            );
            (value, stats.tasks_run, stats.instrs)
        }
        other => bail!("unknown --engine `{other}` (expected oracle, explicit, ws or sim)"),
    };
    let wall = wall.elapsed();
    println!("result: {value}");
    if want_stats {
        let per_sec = if wall.as_secs_f64() > 0.0 {
            tasks as f64 / wall.as_secs_f64()
        } else {
            0.0
        };
        println!(
            "engine {engine}: wall {}  throughput {:.0} tasks/s  kernel compile {} (cached in session)",
            bombyx::util::bench::fmt_duration(wall),
            per_sec,
            bombyx::util::bench::fmt_duration(kernel_time)
        );
        // Fusion/dispatch stats: static program coverage + dynamic
        // dispatch count (one retirement per fused pair).
        let kernels = if engine == "oracle" {
            session.implicit_kernels()?
        } else {
            session.explicit_kernels()?
        };
        let (pairs, before) = kernels.fusion();
        println!(
            "dispatch: retired {}  fused pairs {} / {} instrs (fused_ratio {:.3}){}",
            commas(retired),
            commas(pairs),
            commas(before),
            kernels.fused_ratio(),
            if bombyx::exec::fuse_enabled() { "" } else { "  [BOMBYX_KERNEL_FUSE=0]" }
        );
        print_role_fusion(&kernels);
        print_jit_tiers(&kernels);
        print_profile(Some(kernels.as_ref()), 10);
    }
    telemetry.finish()
}

/// Print the native-tier (JIT) table for `run --stats`: per-kernel tier
/// activity from the process-wide intern table. Silent when no tier was
/// ever created for the program (JIT disabled via `BOMBYX_JIT=0`, or
/// this engine doesn't tier); one line when the platform probe failed.
fn print_jit_tiers(kernels: &std::sync::Arc<bombyx::exec::KernelProgram>) {
    if let Some(reason) = bombyx::exec::jit::disabled_reason() {
        println!("jit: unavailable ({reason})");
        return;
    }
    let stats = bombyx::exec::jit::stats_for(kernels);
    if stats.is_empty() || stats.iter().all(|s| s.dispatches == 0) {
        return;
    }
    println!("execution tiers (threshold {} dispatches):", bombyx::exec::jit::JitConfig::from_env().threshold);
    let mut table = Table::new(["kernel", "dispatches", "jit entries", "bails", "compile", "code"]);
    for s in &stats {
        let compile = match s.uncompilable {
            Some(reason) => reason.to_string(),
            None if s.entries > 0 => format!("{:.2} ms", s.compile_ms),
            None => "-".to_string(),
        };
        let code = if s.code_bytes > 0 { format!("{} B", commas(s.code_bytes as u64)) } else { "-".to_string() };
        table.row([
            s.name.clone(),
            commas(s.dispatches),
            commas(s.entries),
            commas(s.bails),
            compile,
            code,
        ]);
    }
    print!("{}", table.render());
}

fn cmd_sim(args: &[String]) -> Result<()> {
    let flags = parse_flags(args, &["pes", "mem-latency"])?;
    let session = load_session(&flags)?;
    let (entry, task_args) = parse_task_args(&flags)?;
    let mut cfg = SimConfig::default();
    if let Some(p) = flags.options.get("pes") {
        cfg.default_pes = p.parse()?;
    }
    if let Some(l) = flags.options.get("mem-latency") {
        cfg.mem_latency = l.parse()?;
    }
    let (value, _, stats) =
        session.simulate(session.memory(), &entry, &task_args, &cfg, &mut NoSimXla)?;
    println!("result: {value}");
    println!(
        "cycles: {} ({:.1} us @ {} MHz)   tasks: {}",
        commas(stats.cycles),
        cfg.cycles_to_us(stats.cycles),
        cfg.freq_mhz,
        commas(stats.tasks_run)
    );
    let mut table = Table::new(["task", "executed", "PEs", "utilization"]);
    for (name, t) in &stats.per_task {
        table.row([
            name.clone(),
            commas(t.executed),
            t.pes.to_string(),
            format!("{:.1}%", t.utilization * 100.0),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

fn cmd_bfs(args: &[String]) -> Result<()> {
    let flags = parse_flags(args, &["depth", "branch", "pes"])?;
    let depth: u32 = flags.options.get("depth").map(|v| v.parse()).transpose()?.unwrap_or(7);
    let branch: u64 = flags.options.get("branch").map(|v| v.parse()).transpose()?.unwrap_or(4);
    let mut cfg = SimConfig::paper();
    if let Some(p) = flags.options.get("pes") {
        cfg.default_pes = p.parse()?;
    }
    let graph = graphgen::tree(branch, depth);
    println!(
        "graph: B={branch} D={depth} -> {} nodes (paper III: B=4, D in {{7,9}})",
        commas(graph.nodes() as u64)
    );
    let cmp = bombyx::coordinator::run_bfs_comparison(&graph, &cfg)?;
    println!(
        "non-DAE: {} cycles ({:.1} us)",
        commas(cmp.plain_cycles),
        cfg.cycles_to_us(cmp.plain_cycles)
    );
    println!(
        "DAE:     {} cycles ({:.1} us)",
        commas(cmp.dae_cycles),
        cfg.cycles_to_us(cmp.dae_cycles)
    );
    println!("runtime reduction: {:.1}% (paper: 26.5%)", cmp.reduction() * 100.0);
    Ok(())
}
