//! Metrics registry: named counters, gauges, and log2-bucketed
//! histograms with a stable JSON schema (`bombyx-metrics-v1`).
//!
//! The registry is the machine-readable export layer over the runtime's
//! hand-rolled aggregates: the WS executor's lifetime totals, the flood
//! latency percentiles, sim queue/PE gauges and the kernel hotness
//! profile all publish here, and `--metrics-json <file>` serializes the
//! lot. Recording through the free functions is a no-op unless
//! [`crate::obs::metrics_enabled`] — call sites pay one relaxed load.
//!
//! [`Histogram`] is also usable standalone (no global state): it is the
//! one percentile implementation in the tree, with clamped nearest-rank
//! math that is exact up to a bounded reservoir and never emits NaN/Inf
//! (empty histogram → 0.0 everywhere).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Json;

/// Version tag stamped into every metrics export.
pub const SCHEMA: &str = "bombyx-metrics-v1";

/// Exact-percentile reservoir: histograms keep the first `RESERVOIR`
/// raw samples; past that, percentiles fall back to log2-bucket upper
/// bounds (clamped to the observed min/max).
const RESERVOIR: usize = 4096;

const BUCKETS: usize = 64;

/// log2-bucketed histogram over non-negative finite samples.
#[derive(Clone, Debug)]
pub struct Histogram {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    buckets: [u64; BUCKETS],
    samples: Vec<f64>,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// Bucket 0 holds `v < 1`; bucket `b >= 1` holds `2^(b-1) <= v < 2^b`.
fn bucket_of(v: f64) -> usize {
    if v < 1.0 {
        return 0;
    }
    let b = 64 - (v as u64).leading_zeros() as usize;
    b.min(BUCKETS - 1)
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            count: 0,
            sum: 0.0,
            min: 0.0,
            max: 0.0,
            buckets: [0; BUCKETS],
            samples: Vec::new(),
        }
    }

    /// Build from a sample slice (the bench/flood percentile path).
    pub fn from_samples(samples: &[f64]) -> Histogram {
        let mut h = Histogram::new();
        for &v in samples {
            h.record(v);
        }
        h
    }

    /// Record one sample. Non-finite and negative values are dropped —
    /// the histogram's exports are guaranteed finite.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            return;
        }
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
        self.buckets[bucket_of(v)] += 1;
        if self.samples.len() < RESERVOIR {
            self.samples.push(v);
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Arithmetic mean; 0.0 on an empty histogram.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Clamped nearest-rank percentile: `rank = ceil(q·n)` clamped to
    /// `[1, n]`, so q=0.99 of a single sample returns that sample and an
    /// empty histogram returns 0.0 — never an out-of-range index, never
    /// NaN. Exact while the reservoir holds every sample; bucket upper
    /// bounds (clamped to [min, max]) past that.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        if self.samples.len() as u64 == self.count {
            let mut sorted = self.samples.clone();
            sorted.sort_by(f64::total_cmp);
            return sorted[(rank - 1) as usize];
        }
        let mut seen = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let hi = if b == 0 { 1.0 } else { (1u128 << b) as f64 };
                return hi.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Stable JSON shape (all values finite):
    /// `{count, sum, min, max, mean, p50, p95, p99}`.
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("count", self.count as i64)
            .set("sum", self.sum)
            .set("min", self.min)
            .set("max", self.max)
            .set("mean", self.mean())
            .set("p50", self.percentile(0.50))
            .set("p95", self.percentile(0.95))
            .set("p99", self.percentile(0.99));
        o
    }
}

/// Named counters, gauges, and histograms. Usable standalone; the
/// process-wide instance behind the free functions is what
/// `--metrics-json` exports.
#[derive(Debug)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    pub const fn new() -> Registry {
        Registry {
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            histograms: BTreeMap::new(),
        }
    }

    pub fn counter_add(&mut self, name: &str, delta: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    pub fn counter_set(&mut self, name: &str, value: u64) {
        self.counters.insert(name.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Set a gauge; non-finite values are dropped.
    pub fn gauge_set(&mut self, name: &str, value: f64) {
        if value.is_finite() {
            self.gauges.insert(name.to_string(), value);
        }
    }

    pub fn observe(&mut self, name: &str, value: f64) {
        self.histograms.entry(name.to_string()).or_default().record(value);
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    pub fn clear(&mut self) {
        self.counters.clear();
        self.gauges.clear();
        self.histograms.clear();
    }

    /// The `bombyx-metrics-v1` document:
    /// `{schema, counters: {name: int}, gauges: {name: float},
    ///   histograms: {name: {count, sum, min, max, mean, p50, p95, p99}}}`.
    pub fn to_json(&self) -> Json {
        let mut counters = Json::object();
        for (k, v) in &self.counters {
            counters.set(k, *v as i64);
        }
        let mut gauges = Json::object();
        for (k, v) in &self.gauges {
            gauges.set(k, *v);
        }
        let mut histograms = Json::object();
        for (k, h) in &self.histograms {
            histograms.set(k, h.to_json());
        }
        let mut doc = Json::object();
        doc.set("schema", SCHEMA)
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", histograms);
        doc
    }
}

static GLOBAL: Mutex<Registry> = Mutex::new(Registry::new());

/// Add to a process-wide counter (no-op when metrics are disabled).
pub fn counter_add(name: &str, delta: u64) {
    if !crate::obs::metrics_enabled() {
        return;
    }
    GLOBAL.lock().unwrap().counter_add(name, delta);
}

/// Overwrite a process-wide counter (no-op when metrics are disabled).
pub fn counter_set(name: &str, value: u64) {
    if !crate::obs::metrics_enabled() {
        return;
    }
    GLOBAL.lock().unwrap().counter_set(name, value);
}

/// Set a process-wide gauge (no-op when metrics are disabled).
pub fn gauge_set(name: &str, value: f64) {
    if !crate::obs::metrics_enabled() {
        return;
    }
    GLOBAL.lock().unwrap().gauge_set(name, value);
}

/// Record into a process-wide histogram (no-op when disabled).
pub fn observe(name: &str, value: f64) {
    if !crate::obs::metrics_enabled() {
        return;
    }
    GLOBAL.lock().unwrap().observe(name, value);
}

/// Record a duration in milliseconds.
pub fn observe_ms(name: &str, d: Duration) {
    observe(name, d.as_secs_f64() * 1e3);
}

/// Export the process-wide registry (the `--metrics-json` document).
pub fn export_json() -> Json {
    GLOBAL.lock().unwrap().to_json()
}

/// Read one process-wide counter (tests).
pub fn counter(name: &str) -> u64 {
    GLOBAL.lock().unwrap().counter(name)
}

/// Clear the process-wide registry (test isolation).
pub fn reset() {
    GLOBAL.lock().unwrap().clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_all_zero_and_finite() {
        let h = Histogram::new();
        assert_eq!(h.percentile(0.50), 0.0);
        assert_eq!(h.percentile(0.99), 0.0);
        assert_eq!(h.mean(), 0.0);
        let doc = h.to_json();
        let text = doc.pretty();
        assert!(crate::util::json::parse(&text).is_ok(), "finite JSON: {text}");
    }

    #[test]
    fn single_sample_percentiles_clamp_to_it() {
        let h = Histogram::from_samples(&[7.5]);
        assert_eq!(h.percentile(0.0), 7.5);
        assert_eq!(h.percentile(0.50), 7.5);
        assert_eq!(h.percentile(0.99), 7.5);
        assert_eq!(h.percentile(1.0), 7.5);
    }

    #[test]
    fn nearest_rank_matches_definition() {
        // n=4: p50 → rank ceil(2)=2 → 2nd smallest; p99 → rank 4 → max.
        let h = Histogram::from_samples(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(h.percentile(0.50), 2.0);
        assert_eq!(h.percentile(0.75), 3.0);
        assert_eq!(h.percentile(0.99), 4.0);
    }

    #[test]
    fn non_finite_samples_are_dropped() {
        let h = Histogram::from_samples(&[f64::NAN, f64::INFINITY, -1.0, 2.0]);
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(0.50), 2.0);
    }

    #[test]
    fn bucket_fallback_stays_in_range() {
        let mut h = Histogram::new();
        for i in 0..(RESERVOIR + 100) {
            h.record((i % 1000) as f64);
        }
        let p99 = h.percentile(0.99);
        assert!(p99.is_finite());
        assert!(p99 >= h.min() && p99 <= h.max());
    }

    #[test]
    fn registry_schema_shape() {
        let mut r = Registry::new();
        r.counter_add("a.b", 2);
        r.gauge_set("g", 1.5);
        r.gauge_set("bad", f64::NAN);
        r.observe("h", 3.0);
        let doc = r.to_json();
        assert_eq!(doc.get("schema").and_then(|v| v.as_str()), Some(SCHEMA));
        assert_eq!(
            doc.get("counters").and_then(|c| c.get("a.b")).and_then(|v| v.as_i64()),
            Some(2)
        );
        assert!(doc.get("gauges").and_then(|g| g.get("bad")).is_none());
        let text = doc.pretty();
        assert!(crate::util::json::parse(&text).is_ok());
    }
}
