//! Unified telemetry: structured span tracing, a metrics registry, and a
//! per-kernel hotness profile — dependency-free (serialized through
//! [`crate::util::json`]), shared by the compiler pipeline and all four
//! execution engines.
//!
//! Three independently-switchable facilities, all **off by default** and
//! free when off (every recording entry point is gated on one relaxed
//! atomic load; see `rust/src/obs/README.md` for the overhead contract):
//!
//! - [`trace`]: per-thread lock-free event rings drained into a Chrome
//!   trace-event / Perfetto-compatible JSON file (`--trace <file>`);
//! - [`metrics`]: named counters, gauges and log2-bucketed histograms
//!   with the stable `bombyx-metrics-v1` schema (`--metrics-json <file>`);
//! - [`profile`]: retired-dispatch counts per kernel, hooked through
//!   `Machine::on_dispatch` — never inside the retired dispatch loop
//!   (grep-pinned by `obs_tests`).

pub mod metrics;
pub mod profile;
pub mod trace;

use std::borrow::Cow;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

static TRACE_ON: AtomicBool = AtomicBool::new(false);
static METRICS_ON: AtomicBool = AtomicBool::new(false);
static PROFILE_ON: AtomicBool = AtomicBool::new(false);

/// Is span tracing on? One relaxed load — safe on warm paths.
#[inline(always)]
pub fn trace_enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// Is the metrics registry recording? One relaxed load.
#[inline(always)]
pub fn metrics_enabled() -> bool {
    METRICS_ON.load(Ordering::Relaxed)
}

/// Is the kernel hotness profile recording? One relaxed load.
#[inline(always)]
pub fn profile_enabled() -> bool {
    PROFILE_ON.load(Ordering::Relaxed)
}

/// Switch span tracing; enabling pins the trace epoch.
pub fn set_trace(on: bool) {
    if on {
        trace::init_epoch();
    }
    TRACE_ON.store(on, Ordering::SeqCst);
}

/// Switch the metrics registry.
pub fn set_metrics(on: bool) {
    METRICS_ON.store(on, Ordering::SeqCst);
}

/// Switch the per-kernel hotness profile.
pub fn set_profile(on: bool) {
    PROFILE_ON.store(on, Ordering::SeqCst);
}

/// Disable everything and drop all recorded state (test isolation).
pub fn reset_all() {
    set_trace(false);
    set_metrics(false);
    set_profile(false);
    trace::reset();
    metrics::reset();
    profile::reset();
}

/// RAII duration span. Always captures its start [`Instant`] — so callers
/// that need the wall-clock (e.g. `PassTiming`) read it from the span and
/// the timing is *the same data* the trace records — but emits `B`/`E`
/// events only while tracing is enabled.
pub struct Span {
    name: Cow<'static, str>,
    cat: &'static str,
    start: Instant,
    emitted: bool,
}

impl Span {
    pub fn enter(name: impl Into<Cow<'static, str>>, cat: &'static str) -> Span {
        let name = name.into();
        let emitted = trace_enabled();
        if emitted {
            trace::begin(name.clone(), cat);
        }
        Span { name, cat, start: Instant::now(), emitted }
    }

    /// Close the span and return its wall-clock duration.
    pub fn finish(mut self) -> Duration {
        let elapsed = self.start.elapsed();
        self.close();
        elapsed
    }

    fn close(&mut self) {
        if self.emitted {
            trace::end(self.name.clone(), self.cat);
            self.emitted = false;
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        self.close();
    }
}
