//! Sampled per-kernel hotness profile: retired dispatch counts attributed
//! per function name, recorded through the `Machine::on_dispatch` seam of
//! all four engines.
//!
//! A dispatch hit is one thread-local hash-map bump (no locks, no
//! allocation after a kernel's first hit on that thread); per-thread
//! counts fold into the process-wide totals when a thread exits or when
//! [`snapshot`] runs on it. Worker threads must be joined (the WS
//! executor dropped) before a snapshot is complete.
//!
//! # Sampling
//!
//! By default every dispatch is counted. For high-throughput runs (e.g.
//! JIT-tiered workloads where per-dispatch hashing dominates the profile
//! itself) the profiler can record every Nth dispatch per thread and
//! scale each sample by N, keeping expected counts unbiased:
//! `BOMBYX_PROFILE_SAMPLE=N` or the `--profile-sample N` CLI flag
//! ([`set_sample_every`]). N=1 (the default) is exact counting.
//!
//! When profiling is disabled the engines skip the hit entirely behind
//! one relaxed load ([`crate::obs::profile_enabled`]) — and the kernel
//! core's retired dispatch loop never calls in here at all (that path is
//! grep-pinned by `obs_tests::retired_fast_path_has_no_telemetry`).

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

static TOTALS: Mutex<BTreeMap<String, u64>> = Mutex::new(BTreeMap::new());

/// Sampling period: record every Nth dispatch, weighted by N. 0 = not
/// yet resolved from the environment.
static SAMPLE_EVERY: AtomicU64 = AtomicU64::new(0);

/// Set the sampling period programmatically (the `--profile-sample` CLI
/// flag; wins over `BOMBYX_PROFILE_SAMPLE`). Values below 1 are clamped
/// to 1 (exact counting).
pub fn set_sample_every(n: u64) {
    SAMPLE_EVERY.store(n.max(1), Ordering::Relaxed);
}

/// Current sampling period, resolving `BOMBYX_PROFILE_SAMPLE` on first
/// use (default 1 = every dispatch). The benign race on first resolution
/// stores the same value from every thread.
pub fn sample_every() -> u64 {
    match SAMPLE_EVERY.load(Ordering::Relaxed) {
        0 => {
            let n = std::env::var("BOMBYX_PROFILE_SAMPLE")
                .ok()
                .and_then(|v| v.parse::<u64>().ok())
                .unwrap_or(1)
                .max(1);
            SAMPLE_EVERY.store(n, Ordering::Relaxed);
            n
        }
        n => n,
    }
}

struct LocalCounts {
    counts: HashMap<String, u64>,
    /// Hits remaining until the next recorded sample (sampling mode).
    skip: u64,
}

impl Drop for LocalCounts {
    fn drop(&mut self) {
        fold(&mut self.counts);
    }
}

thread_local! {
    static LOCAL: RefCell<LocalCounts> =
        RefCell::new(LocalCounts { counts: HashMap::new(), skip: 1 });
}

fn fold(counts: &mut HashMap<String, u64>) {
    if counts.is_empty() {
        return;
    }
    let mut totals = TOTALS.lock().unwrap();
    for (name, n) in counts.drain() {
        *totals.entry(name).or_insert(0) += n;
    }
}

/// Record one retired dispatch of `name` on the calling thread. In
/// sampling mode only every Nth call per thread lands in the map, with
/// weight N.
#[inline]
pub fn hit(name: &str) {
    let n = sample_every();
    LOCAL.with(|l| {
        let mut local = l.borrow_mut();
        if n > 1 {
            if local.skip > 1 {
                local.skip -= 1;
                return;
            }
            local.skip = n;
        }
        if let Some(c) = local.counts.get_mut(name) {
            *c += n;
        } else {
            local.counts.insert(name.to_string(), n);
        }
    });
}

/// Fold the calling thread's counts and clone the process totals.
pub fn snapshot() -> BTreeMap<String, u64> {
    LOCAL.with(|l| fold(&mut l.borrow_mut().counts));
    TOTALS.lock().unwrap().clone()
}

/// Drop all counts (test isolation; other live threads' local counts are
/// not reachable — join workers first).
pub fn reset() {
    LOCAL.with(|l| {
        let mut local = l.borrow_mut();
        local.counts.clear();
        local.skip = 1;
    });
    TOTALS.lock().unwrap().clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_scales_counts_and_stays_unbiased_in_total() {
        // Serialize against other profile tests via the totals lock
        // pattern: reset clears only this thread's state, which is all
        // these hits touch before the snapshot folds them.
        reset();
        set_sample_every(1);
        for _ in 0..100 {
            hit("exact");
        }
        set_sample_every(4);
        for _ in 0..100 {
            hit("sampled");
        }
        let snap = snapshot();
        assert_eq!(snap.get("exact"), Some(&100));
        // 100 hits at N=4: 25 samples recorded, each weighted 4.
        assert_eq!(snap.get("sampled"), Some(&100));
        set_sample_every(1);
        reset();
    }
}
