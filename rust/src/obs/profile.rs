//! Sampled per-kernel hotness profile: retired dispatch counts attributed
//! per function name, recorded through the `Machine::on_dispatch` seam of
//! all four engines.
//!
//! A dispatch hit is one thread-local hash-map bump (no locks, no
//! allocation after a kernel's first hit on that thread); per-thread
//! counts fold into the process-wide totals when a thread exits or when
//! [`snapshot`] runs on it. Worker threads must be joined (the WS
//! executor dropped) before a snapshot is complete.
//!
//! When profiling is disabled the engines skip the hit entirely behind
//! one relaxed load ([`crate::obs::profile_enabled`]) — and the kernel
//! core's retired dispatch loop never calls in here at all (that path is
//! grep-pinned by `obs_tests::retired_fast_path_has_no_telemetry`).

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

static TOTALS: Mutex<BTreeMap<String, u64>> = Mutex::new(BTreeMap::new());

struct LocalCounts {
    counts: HashMap<String, u64>,
}

impl Drop for LocalCounts {
    fn drop(&mut self) {
        fold(&mut self.counts);
    }
}

thread_local! {
    static LOCAL: RefCell<LocalCounts> =
        RefCell::new(LocalCounts { counts: HashMap::new() });
}

fn fold(counts: &mut HashMap<String, u64>) {
    if counts.is_empty() {
        return;
    }
    let mut totals = TOTALS.lock().unwrap();
    for (name, n) in counts.drain() {
        *totals.entry(name).or_insert(0) += n;
    }
}

/// Record one retired dispatch of `name` on the calling thread.
#[inline]
pub fn hit(name: &str) {
    LOCAL.with(|l| {
        let mut local = l.borrow_mut();
        if let Some(c) = local.counts.get_mut(name) {
            *c += 1;
        } else {
            local.counts.insert(name.to_string(), 1);
        }
    });
}

/// Fold the calling thread's counts and clone the process totals.
pub fn snapshot() -> BTreeMap<String, u64> {
    LOCAL.with(|l| fold(&mut l.borrow_mut().counts));
    TOTALS.lock().unwrap().clone()
}

/// Drop all counts (test isolation; other live threads' local counts are
/// not reachable — join workers first).
pub fn reset() {
    LOCAL.with(|l| l.borrow_mut().counts.clear());
    TOTALS.lock().unwrap().clear();
}
